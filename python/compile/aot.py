"""AOT lowering: JAX/Pallas (L2+L1) -> HLO *text* artifacts for the Rust
runtime (L3).

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per (graph, shape) plus ``manifest.txt``,
a line-per-artifact key=value index the Rust runtime parses:

    name=trial_p256 kind=trial p=256 file=trial_p256.hlo.txt

The artifact set covers the single-node hot path (fused line-search trial,
gradient+objective, gram) at canonical sizes, and plain GEMMs at the
distributed algorithm's local-block shapes.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DTYPE = jnp.float64

# Canonical single-node problem sizes (p) and gram shapes (n, p).
TRIAL_SIZES = (64, 128, 256)
GRAM_SHAPES = ((100, 256), (50, 128))
MATMUL_SHAPES = ((128, 128, 128), (256, 256, 256))


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def artifact_plan():
    """Yields (name, manifest_extras, fn, arg_specs)."""
    for p in TRIAL_SIZES:
        pp = _spec(p, p)
        one = _spec(1)
        yield (
            f"trial_p{p}",
            {"kind": "trial", "p": p},
            model.concord_trial,
            (pp, pp, pp, one, one, one, one),
        )
        yield (
            f"gradobj_p{p}",
            {"kind": "gradobj", "p": p},
            model.gradient_obj,
            (pp, pp, one),
        )
    for n, p in GRAM_SHAPES:
        yield (
            f"gram_n{n}_p{p}",
            {"kind": "gram", "n": n, "p": p},
            model.gram,
            (_spec(n, p),),
        )
    for m, k, n in MATMUL_SHAPES:
        yield (
            f"matmul_{m}x{k}x{n}",
            {"kind": "matmul", "m": m, "k": k, "n": n},
            model.matmul,
            (_spec(m, k), _spec(k, n)),
        )


def emit(out_dir: str, verbose: bool = True) -> int:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    count = 0
    for name, extras, fn, specs in artifact_plan():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        kv = " ".join(f"{k}={v}" for k, v in extras.items())
        manifest_lines.append(f"name={name} {kv} file={fname}")
        count += 1
        if verbose:
            print(f"  {fname}  ({len(text)} chars)", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return count


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()
    n = emit(args.out, verbose=not args.quiet)
    print(f"wrote {n} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
