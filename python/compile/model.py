"""L2: CONCORD/PseudoNet compute graphs in JAX, composed from the L1
Pallas kernels (``kernels.matmul``, ``kernels.concord``).

These functions are the *build-time* definition of the math the Rust
coordinator drives at runtime. ``aot.py`` lowers each of them, for a grid
of canonical shapes, to HLO text artifacts that the Rust runtime loads via
PJRT. Python never runs on the request path.

Scalar-ish inputs (tau, lam1, lam2, g_prev) are passed as shape-(1,)
arrays: rank-1 literals are the simplest common denominator between jax
lowering and the ``xla`` crate's Literal constructors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import concord as k
from .kernels import matmul as mm


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """S = (1/n) X^T X (Algorithm 2, line 2), via the tiled Pallas GEMM."""
    return mm.gram(x)


def w_step(omega: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """W = Omega @ S (Algorithm 2, lines 3/10)."""
    return mm.matmul(omega, s)


def gradient_obj(omega: jnp.ndarray, w: jnp.ndarray, lam2: jnp.ndarray):
    """Start-of-iteration fused graph (Algorithm 2, lines 6-7):

    returns (G, g(Omega)) from the current iterate and W = Omega S.
    """
    lam2s = lam2[0]
    g_mat = k.gradient(omega, w, lam2s)
    parts = k.objective_parts(omega, w)
    g_val = -parts[0] + 0.5 * parts[1] + 0.5 * lam2s * parts[2]
    return g_mat, g_val.reshape((1,))


def concord_trial(
    omega: jnp.ndarray,
    grad: jnp.ndarray,
    s: jnp.ndarray,
    g_prev: jnp.ndarray,
    tau: jnp.ndarray,
    lam1: jnp.ndarray,
    lam2: jnp.ndarray,
):
    """One fused line-search trial (Algorithm 2, lines 9-12), Cov variant:

        Omega' = S_{tau lam1}(Omega - tau G)      (Pallas prox kernel)
        W'     = Omega' S                          (Pallas GEMM)
        g'     = -sum log diag + tr(W'Omega')/2 + lam2/2 ||Omega'||_F^2
        rhs    = g - tr((Omega-Omega')^T G) + ||Omega-Omega'||_F^2 / (2 tau)

    Returns (Omega', W', g', rhs, accept) with accept = 1.0 iff g' <= rhs.
    The L3 coordinator halves tau and re-invokes until accept.
    """
    taus, lam1s, lam2s = tau[0], lam1[0], lam2[0]
    omega_new = k.prox(omega, grad, taus, lam1s)
    w_new = mm.matmul(omega_new, s)
    parts = k.objective_parts(omega_new, w_new)
    g_new = -parts[0] + 0.5 * parts[1] + 0.5 * lam2s * parts[2]
    ls = k.linesearch_parts(omega, omega_new, grad)
    rhs = g_prev[0] - ls[0] + ls[1] / (2.0 * taus)
    accept = (g_new <= rhs).astype(omega.dtype)
    return (
        omega_new,
        w_new,
        g_new.reshape((1,)),
        rhs.reshape((1,)),
        accept.reshape((1,)),
    )


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain tiled GEMM artifact (the distributed algorithm's local-block
    multiply; also used by the runtime micro-benchmarks)."""
    return mm.matmul(a, b)


# ---------------------------------------------------------------------------
# Reference (pure-jnp) composition used by the python test-suite to check
# the kernel-backed graphs end to end.
# ---------------------------------------------------------------------------

def concord_fit_reference(x: jnp.ndarray, lam1: float, lam2: float,
                          tol: float = 1e-6, max_iter: int = 500):
    """Reference CONCORD solver (Algorithm 1) in pure jnp; ground truth for
    both the python tests and the Rust solver's golden-value tests."""
    from .kernels import ref

    n, p = x.shape
    s = ref.gram(x)
    omega = jnp.eye(p, dtype=x.dtype)
    w = omega @ s
    iters = 0
    for it in range(max_iter):
        iters = it + 1
        grad = ref.gradient(omega, w, lam2)
        g_val = ref.objective_smooth(omega, w, lam2)
        tau = 1.0
        while True:
            omega_new, w_new, g_new, rhs = ref.concord_trial(
                omega, grad, s, g_val, tau, lam1, lam2
            )
            if g_new <= rhs or tau < 1e-12:
                break
            tau *= 0.5
        delta = jnp.max(jnp.abs(omega_new - omega))
        omega, w = omega_new, w_new
        if delta < tol:
            break
    return omega, iters
