"""L1 Pallas kernels: fused CONCORD/PseudoNet elementwise passes.

The paper's proximal gradient iteration (Algorithm 1/2) spends its
non-GEMM time in elementwise sweeps over p x p matrices: gradient
assembly, soft-threshold prox, and the objective/line-search reductions.
On the paper's CPU nodes these were separate BLAS-1 loops; here each is a
single fused Pallas pass (one HBM read per operand, one write), tiled for
VMEM with ``BlockSpec``:

- ``gradient``   G = -(Omega_D)^{-1} + (W + W^T)/2 + lam2 * Omega.
  W^T is *not* materialised: the same W buffer is streamed twice, once
  with the transposed index map, and transposed tile-locally in VMEM.
- ``prox``       Omega' = S_{tau lam1}(Omega - tau G) off-diagonal,
  (Omega - tau G) on the diagonal.
- ``objective_parts`` / ``linesearch_parts``: tree reductions into a tiny
  accumulator that stays resident across the (sequential) grid sweep.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 128


def _pick_block(p: int, b: int) -> int:
    """Largest tile <= b that divides p (p is padded by callers if prime)."""
    b = min(b, p)
    while p % b != 0:
        b -= 1
    return b


def _diag_mask(i, j, bm, bn, dtype):
    """1.0 where the global (row, col) of tile (i, j) lies on the diagonal."""
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    return (rows == cols).astype(dtype)


# ---------------------------------------------------------------------------
# Gradient assembly
# ---------------------------------------------------------------------------

def _gradient_kernel(omega_ref, w_ref, wt_ref, lam2_ref, o_ref):
    i, j = pl.program_id(0), pl.program_id(1)
    bm, bn = o_ref.shape
    omega = omega_ref[...]
    dtype = omega.dtype
    mask = _diag_mask(i, j, bm, bn, dtype)
    sym = 0.5 * (w_ref[...] + wt_ref[...].T)
    # -(Omega_D)^{-1}: only diagonal entries contribute; guard the
    # reciprocal off-diagonal where omega may be 0.
    inv_diag = mask * (1.0 / jnp.where(mask > 0, omega, 1.0))
    o_ref[...] = -inv_diag + sym + lam2_ref[0] * omega


@functools.partial(jax.jit, static_argnames=("block",))
def gradient(omega: jnp.ndarray, w: jnp.ndarray, lam2, *,
             block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """G = -(Omega_D)^{-1} + (W + W^T)/2 + lam2*Omega (Alg. 2 line 6)."""
    p = omega.shape[0]
    b = _pick_block(p, block)
    lam2v = jnp.asarray(lam2, dtype=omega.dtype).reshape((1,))
    return pl.pallas_call(
        _gradient_kernel,
        grid=(p // b, p // b),
        in_specs=[
            pl.BlockSpec((b, b), lambda i, j: (i, j)),   # Omega[i, j]
            pl.BlockSpec((b, b), lambda i, j: (i, j)),   # W[i, j]
            pl.BlockSpec((b, b), lambda i, j: (j, i)),   # W[j, i] -> (W^T)[i, j]
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, p), omega.dtype),
        interpret=True,
    )(omega, w, w, lam2v)


# ---------------------------------------------------------------------------
# Proximal (soft-threshold) step
# ---------------------------------------------------------------------------

def _prox_kernel(omega_ref, g_ref, scal_ref, o_ref):
    i, j = pl.program_id(0), pl.program_id(1)
    bm, bn = o_ref.shape
    tau, lam1 = scal_ref[0], scal_ref[1]
    z = omega_ref[...] - tau * g_ref[...]
    soft = jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau * lam1, 0.0)
    mask = _diag_mask(i, j, bm, bn, z.dtype)
    o_ref[...] = soft * (1.0 - mask) + z * mask


@functools.partial(jax.jit, static_argnames=("block",))
def prox(omega: jnp.ndarray, g: jnp.ndarray, tau, lam1, *,
         block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Omega' = S_{tau lam1}(Omega - tau G), diagonal un-thresholded
    (Alg. 2 line 9; the l1 penalty is on Omega_X only)."""
    p = omega.shape[0]
    b = _pick_block(p, block)
    scal = jnp.stack([jnp.asarray(tau, omega.dtype),
                      jnp.asarray(lam1, omega.dtype)])
    return pl.pallas_call(
        _prox_kernel,
        grid=(p // b, p // b),
        in_specs=[
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, p), omega.dtype),
        interpret=True,
    )(omega, g, scal)


# ---------------------------------------------------------------------------
# Objective reduction: (sum log diag, sum W*Omega, sum Omega^2)
# ---------------------------------------------------------------------------

def _objective_kernel(omega_ref, w_ref, acc_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bn = omega_ref.shape
    omega = omega_ref[...]
    mask = _diag_mask(i, j, bm, bn, omega.dtype)
    # log of diagonal entries only; off-diagonal replaced by 1 (log 1 = 0).
    logd = jnp.sum(jnp.log(jnp.where(mask > 0, omega, 1.0)))
    tr = jnp.sum(w_ref[...] * omega)
    fro = jnp.sum(omega * omega)
    acc_ref[...] += jnp.stack([logd, tr, fro])


@functools.partial(jax.jit, static_argnames=("block",))
def objective_parts(omega: jnp.ndarray, w: jnp.ndarray, *,
                    block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Returns [sum_i log Omega_ii, sum(W*Omega), ||Omega||_F^2]; the caller
    combines them as g = -2*logd + tr + lam2/2 * fro (Alg. 2 line 7)."""
    p = omega.shape[0]
    b = _pick_block(p, block)
    return pl.pallas_call(
        _objective_kernel,
        grid=(p // b, p // b),
        in_specs=[
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((3,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((3,), omega.dtype),
        interpret=True,
    )(omega, w)


# ---------------------------------------------------------------------------
# Line-search reduction: (sum diff*G, sum diff^2)
# ---------------------------------------------------------------------------

def _linesearch_kernel(omega_ref, new_ref, g_ref, acc_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    diff = omega_ref[...] - new_ref[...]
    acc_ref[...] += jnp.stack(
        [jnp.sum(diff * g_ref[...]), jnp.sum(diff * diff)]
    )


@functools.partial(jax.jit, static_argnames=("block",))
def linesearch_parts(omega: jnp.ndarray, omega_new: jnp.ndarray,
                     g: jnp.ndarray, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Returns [tr((Omega-Omega')^T G), ||Omega-Omega'||_F^2] for the
    sufficient-decrease check (Alg. 2 line 12)."""
    p = omega.shape[0]
    b = _pick_block(p, block)
    return pl.pallas_call(
        _linesearch_kernel,
        grid=(p // b, p // b),
        in_specs=[
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), omega.dtype),
        interpret=True,
    )(omega, omega_new, g)
