"""L1 Pallas kernel: tiled local matrix multiplication.

This is the MKL-replacement local GEMM under HP-CONCORD's distributed
algorithm, re-thought for a TPU-like memory hierarchy (DESIGN.md
§Hardware-Adaptation):

- the (bm, bk) x (bk, bn) tiles are the HBM->VMEM working set, expressed
  with ``BlockSpec`` index maps instead of threadblock indexing;
- the K loop is the innermost grid dimension so the output tile stays
  resident in VMEM as an accumulator across K steps (double-buffered input
  streams on real hardware);
- the default 128x128 tile matches the MXU systolic array shape.

``interpret=True`` is mandatory on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
both the python tests and the Rust runtime can run bit-identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Grid point (i, j, l): accumulate X[i,l] @ Y[l,j] into O[i,j].

    The accumulator initialisation is guarded on l == 0 so O[i,j] lives in
    VMEM across the whole K sweep.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _ceil_to(v: int, b: int) -> int:
    return (v + b - 1) // b * b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128, bk: int = 128,
           bn: int = 128) -> jnp.ndarray:
    """C = X @ Y with (bm, bk, bn) VMEM tiling.

    Inputs whose dimensions are not multiples of the tile shape are
    zero-padded (zeros contribute nothing to the accumulation) and the
    result is sliced back, so any shape is accepted.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm_, bk_, bn_ = min(bm, _ceil_to(m, 8)), min(bk, _ceil_to(k, 8)), min(bn, _ceil_to(n, 8))
    mp, kp, np_ = _ceil_to(m, bm_), _ceil_to(k, bk_), _ceil_to(n, bn_)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm_, np_ // bn_, kp // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk_, bn_), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def gram(x: jnp.ndarray, *, bm: int = 128, bk: int = 128,
         bn: int = 128) -> jnp.ndarray:
    """S = (1/n) X^T X through the tiled kernel (paper §2, Cov variant)."""
    n = x.shape[0]
    return matmul(x.T, x, bm=bm, bk=bk, bn=bn) / n


def vmem_footprint_bytes(bm: int, bk: int, bn: int, itemsize: int = 8) -> int:
    """Estimated VMEM working set of one grid step: one X tile, one Y tile,
    one resident output accumulator tile (double-buffering of the two input
    streams doubles their share on real hardware).
    """
    return itemsize * (2 * (bm * bk + bk * bn) + bm * bn)


def mxu_utilization_estimate(bm: int, bk: int, bn: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes kept busy by a (bm, bk, bn) tile: each matmul
    dimension is utilized ceil-free as dim/ceil(dim/mxu)/mxu.
    """

    def eff(d: int) -> float:
        import math

        return d / (math.ceil(d / mxu) * mxu)

    return eff(bm) * eff(bk) * eff(bn)
