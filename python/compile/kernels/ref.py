"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in ``matmul.py`` and ``concord.py`` has a reference here,
written with plain ``jax.numpy`` so the semantics are unambiguous. The
pytest suite (``python/tests``) sweeps shapes/values with hypothesis and
asserts ``assert_allclose`` between kernel and reference.

These functions are also the executable specification of the CONCORD /
PseudoNet math (Algorithm 1 of the paper): the Rust solver implements the
same formulas and its unit tests pin the same closed-form cases.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """C = X @ Y."""
    return x @ y


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """Sample covariance S = (1/n) X^T X for X in R^{n x p} (paper §2)."""
    n = x.shape[0]
    return (x.T @ x) / n


def soft_threshold(z: jnp.ndarray, alpha) -> jnp.ndarray:
    """Elementwise soft-thresholding operator S_alpha (paper eq. (2))."""
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - alpha, 0.0)


def gradient(omega: jnp.ndarray, w: jnp.ndarray, lam2) -> jnp.ndarray:
    """Smooth-part gradient (Algorithm 2, line 6):

        G = -(Omega_D)^{-1} + (W + W^T)/2 + lam2 * Omega,

    where W = Omega @ S and Omega_D is the diagonal part of Omega.
    """
    d = jnp.diag(omega)
    return -jnp.diag(1.0 / d) + 0.5 * (w + w.T) + lam2 * omega


def prox_step(omega: jnp.ndarray, g: jnp.ndarray, tau, lam1) -> jnp.ndarray:
    """Proximal step (Algorithm 2, line 9):

        Omega' = S_{tau*lam1}(Omega - tau*G)   off the diagonal,
        Omega' = Omega - tau*G                 on the diagonal.

    The l1 penalty applies to Omega_X (off-diagonal entries) only, so the
    diagonal is not thresholded.
    """
    z = omega - tau * g
    off = soft_threshold(z, tau * lam1)
    p = omega.shape[0]
    eye = jnp.eye(p, dtype=omega.dtype)
    return off * (1.0 - eye) + z * eye


def objective_smooth(omega: jnp.ndarray, w: jnp.ndarray, lam2):
    """Smooth part of the CONCORD/PseudoNet objective:

        g(Omega) = -sum_i log(Omega_ii) + tr(W Omega)/2 + lam2/2 ||Omega||_F^2

    with W = Omega @ S, so tr(W Omega) = tr(Omega S Omega); Omega stays
    symmetric through the iteration, hence tr(W Omega) = sum(W * Omega).

    NOTE: this is the function whose exact gradient is Algorithm 2's
        G = -(Omega_D)^{-1} + (W + W^T)/2 + lam2*Omega.
    The paper's line 7 prints the doubled log/trace form, which is
    inconsistent with its own gradient line (it would need 2x the log and
    trace gradients but 1x the lam2 term); using the consistent pair keeps
    the backtracking line search textbook-valid, and only reparametrizes
    (lam1, lam2) by a factor of 2 relative to criterion (1) — harmless, as
    every experiment sweeps the lambda grid. See DESIGN.md.
    """
    d = jnp.diag(omega)
    return (
        -jnp.sum(jnp.log(d))
        + 0.5 * jnp.sum(w * omega)
        + 0.5 * lam2 * jnp.sum(omega * omega)
    )


def objective_smooth_obs(omega: jnp.ndarray, y: jnp.ndarray, n, lam2):
    """Obs-variant smooth objective (Algorithm 3 analogue):

        g(Omega) = -sum_i log(Omega_ii) + (1/2n)||Y||_F^2
                   + lam2/2 ||Omega||_F^2,

    with Y = Omega @ X^T (un-normalized; the 1/n shows up here), since
    tr(Omega S Omega) = ||Omega X^T||_F^2 / n. Same consistent-gradient
    normalization as ``objective_smooth``.
    """
    d = jnp.diag(omega)
    return (
        -jnp.sum(jnp.log(d))
        + 0.5 * jnp.sum(y * y) / n
        + 0.5 * lam2 * jnp.sum(omega * omega)
    )


def linesearch_rhs(omega, omega_new, g_val, grad, tau):
    """Sufficient-decrease RHS (Algorithm 2, line 12):

        g(Omega) - tr((Omega - Omega')^T G) + 1/(2 tau) ||Omega - Omega'||_F^2
    """
    diff = omega - omega_new
    return (
        g_val
        - jnp.sum(diff * grad)
        + jnp.sum(diff * diff) / (2.0 * tau)
    )


def concord_trial(omega, grad, s, g_prev, tau, lam1, lam2):
    """One fused line-search trial for the Cov variant: proximal step, new
    W = Omega' S, new objective, and the sufficient-decrease RHS.

    Returns (omega_new, w_new, g_new, rhs); the trial is accepted when
    g_new <= rhs.
    """
    omega_new = prox_step(omega, grad, tau, lam1)
    w_new = omega_new @ s
    g_new = objective_smooth(omega_new, w_new, lam2)
    rhs = linesearch_rhs(omega, omega_new, g_prev, grad, tau)
    return omega_new, w_new, g_new, rhs
