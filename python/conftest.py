"""Pytest root conftest: enable f64 before any kernel module is imported
(the artifacts and the Rust runtime are double precision, matching the
paper's Edison runs)."""

import jax

jax.config.update("jax_enable_x64", True)
