"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, seeds, block sizes, and dtypes; every kernel is
asserted allclose against its reference. These run at build time — the
artifacts are only emitted once this suite is green (`make test`).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import concord as k
from compile.kernels import matmul as mm
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape, dtype=np.float64):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _spd_omega(rng, p, dtype=np.float64):
    """A symmetric iterate with a strictly positive diagonal, as the
    CONCORD iterates are (diagonal entries enter through log)."""
    a = rng.standard_normal((p, p)) * 0.1
    a = (a + a.T) / 2
    np.fill_diagonal(a, 1.0 + rng.random(p))
    return jnp.asarray(a, dtype=dtype)


# ---------------------------------------------------------------------------
# matmul / gram
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    kk=st.integers(1, 40),
    n=st.integers(1, 40),
    bm=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**32 - 1),
)
def test_matmul_matches_ref(m, kk, n, bm, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, m, kk), _rand(rng, kk, n)
    got = mm.matmul(x, y, bm=bm, bk=bm, bn=bm)
    assert_allclose(np.asarray(got), np.asarray(ref.matmul(x, y)),
                    rtol=1e-12, atol=1e-12)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 30),
    p=st.integers(1, 30),
    seed=st.integers(0, 2**32 - 1),
)
def test_gram_matches_ref(n, p, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, p)
    assert_allclose(np.asarray(mm.gram(x)), np.asarray(ref.gram(x)),
                    rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_matmul_dtypes(dtype):
    rng = np.random.default_rng(0)
    x, y = _rand(rng, 17, 9, dtype=dtype), _rand(rng, 9, 23, dtype=dtype)
    got = mm.matmul(x, y)
    assert got.dtype == x.dtype
    tol = 1e-5 if dtype == np.float32 else 1e-12
    assert_allclose(np.asarray(got), np.asarray(ref.matmul(x, y)),
                    rtol=tol, atol=tol)


def test_matmul_identity():
    x = jnp.eye(16, dtype=jnp.float64)
    assert_allclose(np.asarray(mm.matmul(x, x)), np.eye(16))


def test_vmem_and_mxu_estimates():
    # 128^3 f64 tiles: 2*(128*128*8)*2 inputs + one output tile.
    assert mm.vmem_footprint_bytes(128, 128, 128) == 8 * (4 * 128 * 128 + 128 * 128)
    assert mm.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mm.mxu_utilization_estimate(64, 128, 128) == 0.5


# ---------------------------------------------------------------------------
# gradient
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    p=st.sampled_from([2, 3, 8, 16, 24]),
    block=st.sampled_from([4, 8, 128]),
    lam2=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**32 - 1),
)
def test_gradient_matches_ref(p, block, lam2, seed):
    rng = np.random.default_rng(seed)
    omega = _spd_omega(rng, p)
    w = _rand(rng, p, p)
    got = k.gradient(omega, w, lam2, block=block)
    want = ref.gradient(omega, w, lam2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_gradient_symmetry():
    """G is symmetric whenever Omega is (drives iterate symmetry)."""
    rng = np.random.default_rng(7)
    omega = _spd_omega(rng, 12)
    w = _rand(rng, 12, 12)
    g = np.asarray(k.gradient(omega, w, 0.5))
    assert_allclose(g, g.T, rtol=0, atol=1e-12)


# ---------------------------------------------------------------------------
# prox
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    p=st.sampled_from([2, 5, 8, 16]),
    tau=st.floats(1e-3, 1.0),
    lam1=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**32 - 1),
)
def test_prox_matches_ref(p, tau, lam1, seed):
    rng = np.random.default_rng(seed)
    omega, g = _spd_omega(rng, p), _rand(rng, p, p)
    got = k.prox(omega, g, tau, lam1, block=8)
    want = ref.prox_step(omega, g, tau, lam1)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_prox_diagonal_not_thresholded():
    """The l1 penalty is on Omega_X only: diagonal passes through
    un-thresholded even with a huge lam1."""
    p = 6
    omega = jnp.eye(p, dtype=jnp.float64) * 3.0
    g = jnp.zeros((p, p), dtype=jnp.float64)
    out = np.asarray(k.prox(omega, g, 1.0, 100.0))
    assert_allclose(np.diag(out), 3.0 * np.ones(p))
    assert_allclose(out - np.diag(np.diag(out)), 0.0)


def test_prox_kills_small_offdiagonals():
    rng = np.random.default_rng(3)
    p = 8
    omega = _spd_omega(rng, p) * 0.01 + jnp.eye(p)
    g = jnp.zeros((p, p), dtype=jnp.float64)
    out = np.asarray(k.prox(omega, g, 1.0, 1.0))
    off = out - np.diag(np.diag(out))
    assert np.all(off == 0.0)


# ---------------------------------------------------------------------------
# objective / line-search reductions
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    p=st.sampled_from([2, 4, 8, 16, 24]),
    block=st.sampled_from([4, 8, 128]),
    lam2=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**32 - 1),
)
def test_objective_matches_ref(p, block, lam2, seed):
    rng = np.random.default_rng(seed)
    omega, w = _spd_omega(rng, p), _rand(rng, p, p)
    parts = np.asarray(k.objective_parts(omega, w, block=block))
    got = -parts[0] + 0.5 * parts[1] + 0.5 * lam2 * parts[2]
    want = float(ref.objective_smooth(omega, w, lam2))
    assert_allclose(got, want, rtol=1e-11, atol=1e-11)


@settings(**SETTINGS)
@given(
    p=st.sampled_from([2, 4, 8, 16]),
    tau=st.floats(1e-3, 1.0),
    seed=st.integers(0, 2**32 - 1),
)
def test_linesearch_matches_ref(p, tau, seed):
    rng = np.random.default_rng(seed)
    omega, new, g = _spd_omega(rng, p), _spd_omega(rng, p), _rand(rng, p, p)
    parts = np.asarray(k.linesearch_parts(omega, new, g, block=8))
    g_val = 1.234
    got = g_val - parts[0] + parts[1] / (2.0 * tau)
    want = float(ref.linesearch_rhs(omega, new, g_val, g, tau))
    assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_objective_identity_omega():
    """Closed form: Omega = I gives g = tr(S)/2 + lam2*p/2."""
    p = 8
    rng = np.random.default_rng(0)
    x = _rand(rng, 20, p)
    s = ref.gram(x)
    omega = jnp.eye(p, dtype=jnp.float64)
    parts = np.asarray(k.objective_parts(omega, omega @ s))
    got = -parts[0] + 0.5 * parts[1] + 0.5 * 0.4 * parts[2]
    assert_allclose(got, float(jnp.trace(s)) / 2 + 0.4 * p / 2, rtol=1e-12)
