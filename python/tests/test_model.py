"""L2 graph correctness: kernel-backed model graphs vs composed references,
line-search semantics, and end-to-end reference solver sanity on tiny
synthetic problems (the same problems the Rust golden tests pin)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def _problem(seed, n=20, p=8):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, p)))
    s = ref.gram(x)
    a = rng.standard_normal((p, p)) * 0.1
    a = (a + a.T) / 2
    np.fill_diagonal(a, 1.0 + rng.random(p))
    omega = jnp.asarray(a)
    return x, s, omega


def _one(v):
    return jnp.asarray([v], dtype=jnp.float64)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**32 - 1), lam2=st.floats(0.0, 1.0))
def test_gradient_obj_matches_ref(seed, lam2):
    _, s, omega = _problem(seed)
    w = omega @ s
    g_mat, g_val = model.gradient_obj(omega, w, _one(lam2))
    assert_allclose(np.asarray(g_mat), np.asarray(ref.gradient(omega, w, lam2)),
                    rtol=1e-12, atol=1e-12)
    assert_allclose(
        float(g_val[0]), float(ref.objective_smooth(omega, w, lam2)),
        rtol=1e-11,
    )


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**32 - 1),
    tau=st.floats(0.05, 1.0),
    lam1=st.floats(0.0, 1.0),
    lam2=st.floats(0.0, 1.0),
)
def test_trial_matches_ref(seed, tau, lam1, lam2):
    _, s, omega = _problem(seed)
    w = omega @ s
    grad = ref.gradient(omega, w, lam2)
    g_prev = float(ref.objective_smooth(omega, w, lam2))

    o_new, w_new, g_new, rhs, accept = model.concord_trial(
        omega, grad, s, _one(g_prev), _one(tau), _one(lam1), _one(lam2)
    )
    ro, rw, rg, rrhs = ref.concord_trial(omega, grad, s, g_prev, tau, lam1, lam2)
    assert_allclose(np.asarray(o_new), np.asarray(ro), rtol=1e-12, atol=1e-12)
    assert_allclose(np.asarray(w_new), np.asarray(rw), rtol=1e-11, atol=1e-11)
    assert_allclose(float(g_new[0]), float(rg), rtol=1e-10, atol=1e-10)
    assert_allclose(float(rhs[0]), float(rrhs), rtol=1e-10, atol=1e-10)
    assert float(accept[0]) == (1.0 if float(rg) <= float(rrhs) else 0.0)


def test_linesearch_eventually_accepts():
    """Halving tau must eventually satisfy sufficient decrease (the smooth
    part has Lipschitz gradient on the iterate's neighbourhood)."""
    _, s, omega = _problem(11)
    w = omega @ s
    lam1, lam2 = 0.3, 0.1
    grad = ref.gradient(omega, w, lam2)
    g_prev = float(ref.objective_smooth(omega, w, lam2))
    tau, accepted = 1.0, False
    for _ in range(40):
        _, _, g_new, rhs = ref.concord_trial(omega, grad, s, g_prev, tau, lam1, lam2)
        if float(g_new) <= float(rhs):
            accepted = True
            break
        tau *= 0.5
    assert accepted


def test_reference_solver_identity_covariance():
    """With S = I and lam1 big enough, the optimum is diagonal: each
    diagonal entry solves -1/w + (1 + lam2) w = 0, w = 1/sqrt(1+lam2)."""
    p = 6
    lam2 = 0.5
    rng = np.random.default_rng(0)
    # Draw x with exact identity sample covariance via QR-orthogonalisation.
    n = 64
    z = rng.standard_normal((n, p))
    q, _ = np.linalg.qr(z)
    x = jnp.asarray(q * np.sqrt(n))  # columns orthonormal * sqrt(n): S = I
    omega, iters = model.concord_fit_reference(x, lam1=2.0, lam2=lam2, tol=1e-7)
    omega = np.asarray(omega)
    off = omega - np.diag(np.diag(omega))
    assert_allclose(off, 0.0, atol=1e-8)
    assert_allclose(np.diag(omega), 1.0 / np.sqrt(1.0 + lam2), rtol=1e-6)
    assert iters < 100


def test_reference_solver_recovers_chain_support():
    """On an easy chain-precision problem with plenty of samples, the
    estimate's support should cover the chain edges (high recall) without
    being dense."""
    p, n = 10, 4000
    rng = np.random.default_rng(42)
    omega0 = np.eye(p) * 1.25
    for i in range(p - 1):
        omega0[i, i + 1] = omega0[i + 1, i] = -0.5
    cov = np.linalg.inv(omega0)
    ch = np.linalg.cholesky(cov)
    x = jnp.asarray(rng.standard_normal((n, p)) @ ch.T)
    omega, _ = model.concord_fit_reference(x, lam1=0.12, lam2=0.0, tol=1e-7)
    est = np.abs(np.asarray(omega)) > 1e-8
    true = omega0 != 0
    np.fill_diagonal(est, False)
    np.fill_diagonal(true, False)
    recall = est[true].mean()
    density = est.mean()
    assert recall > 0.9
    assert density < 0.6
