"""AOT artifact emission: HLO text is produced, has an ENTRY computation
with the expected parameter count, and the manifest indexes every file."""

import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    n = aot.emit(str(d), verbose=False)
    assert n > 0
    return str(d)


def _manifest(out_dir):
    with open(os.path.join(out_dir, "manifest.txt")) as f:
        lines = [l.strip() for l in f if l.strip()]
    entries = []
    for line in lines:
        entries.append(dict(kv.split("=", 1) for kv in line.split()))
    return entries


def test_manifest_indexes_every_artifact(out_dir):
    entries = _manifest(out_dir)
    files = {e["file"] for e in entries}
    on_disk = {f for f in os.listdir(out_dir) if f.endswith(".hlo.txt")}
    assert files == on_disk
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names)), "duplicate artifact names"


def test_every_artifact_has_entry_computation(out_dir):
    for e in _manifest(out_dir):
        with open(os.path.join(out_dir, e["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, f"{e['file']} missing ENTRY"
        assert "HloModule" in text


def test_trial_artifact_parameter_count(out_dir):
    """concord_trial takes 7 parameters (omega, grad, s, g_prev, tau,
    lam1, lam2); the lowered HLO entry must expose all of them."""
    entries = [e for e in _manifest(out_dir) if e.get("kind") == "trial"]
    assert entries, "no trial artifacts emitted"
    for e in entries:
        with open(os.path.join(out_dir, e["file"])) as f:
            text = f.read()
        # This HLO text form lists parameters as instructions of the ENTRY
        # computation body rather than in a signature line.
        entry = text[text.index("ENTRY"):]
        n_params = entry.count("parameter(")
        assert n_params == 7, f"{e['file']}: {n_params} parameters"


def test_expected_kinds_present(out_dir):
    kinds = {e["kind"] for e in _manifest(out_dir)}
    assert {"trial", "gradobj", "gram", "matmul"} <= kinds
