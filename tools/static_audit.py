#!/usr/bin/env python3
"""Repo-wide static audit for the offline builder image.

The build container ships no Rust toolchain (no cargo/rustc, no network),
so tier-1 cannot run locally.  This audit is the CI-runnable fallback the
ISSUE-7 acceptance criteria name: a Rust-aware lexer plus cross-reference
checks that catch the defect classes a first `cargo build` would surface.

Checks (each a numbered section below):
  1. delimiter balance   — {}/()/[] per file, comment/string/char aware
  2. line discipline     — <=100 columns, no tabs, no trailing whitespace
  3. cargo targets       — every `path = "..."` target in Cargo.toml exists
  4. module tree         — every `mod foo;` resolves to foo.rs or foo/mod.rs
  5. anyhow shim surface — every `use anyhow::X` / `anyhow::X` path and every
                           anyhow!/bail!/ensure! invocation is covered by the
                           vendored shim's exported items and macro arms
  6. crate-path usage    — `use crate::...` / `use hpconcord::...` module
                           segments resolve against the real module tree
  7. feature gates       — every cfg(feature = "x") is declared in Cargo.toml
  8. pub-item resolution — the terminal item of each crate-path use exists as
                           a pub definition in the resolved module file
  9. entry points        — every declared bench/example/bin file has a `fn main`
 10. doc-tests           — fenced /// examples balance and their crate paths
                           resolve (they compile under `cargo test --doc`)
 11. struct literals     — grown option structs are built with full field
                           coverage or a `..` default tail
 12. format arguments    — positional placeholder counts match the argument
                           lists of the std/anyhow format macros
 13. deprecated wrappers — the `_mat`/`_src` compatibility shims are only
                           spelled in their definition and re-export files
 14. unsafe containment  — the `unsafe` keyword is only spelled in the two
                           audited homes (the SIMD microkernel module and
                           the vendored affinity shim); every other file
                           stays in safe Rust

Exit 0 iff every check passes.  Run via tools/static_audit.sh.
"""

import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MAX_COLS = 100

errors = []


def err(path, line, msg):
    rel = path.relative_to(REPO) if isinstance(path, Path) else path
    errors.append(f"{rel}:{line}: {msg}")


# ---------------------------------------------------------------------------
# Rust lexer: produce code-only text (strings/chars/comments blanked) so the
# structural checks never trip on a brace inside a doc comment or literal.
# ---------------------------------------------------------------------------
def strip_noncode(src):
    """Return src with comments and string/char literal bodies replaced by
    spaces (newlines preserved so line numbers survive)."""
    out = []
    i, n = 0, len(src)

    def blank_until(j):
        nonlocal i
        for k in range(i, j):
            out.append("\n" if src[k] == "\n" else " ")
        i = j

    while i < n:
        c = src[i]
        two = src[i : i + 2]
        if two == "//":
            j = src.find("\n", i)
            j = n if j == -1 else j
            blank_until(j)
        elif two == "/*":
            depth, j = 1, i + 2
            while j < n and depth:
                if src[j : j + 2] == "/*":
                    depth, j = depth + 1, j + 2
                elif src[j : j + 2] == "*/":
                    depth, j = depth - 1, j + 2
                else:
                    j += 1
            blank_until(j)
        elif c == '"' or two in ('b"',):
            if c == "b":
                out.append("b")
                i += 1
            out.append('"')
            i += 1
            while i < n:
                if src[i] == "\\":
                    blank_until(min(i + 2, n))
                elif src[i] == '"':
                    out.append('"')
                    i += 1
                    break
                else:
                    blank_until(i + 1)
        elif re.match(r'r#*"', src[i:]):
            m = re.match(r'r(#*)"', src[i:])
            closer = '"' + m.group(1)
            j = src.find(closer, i + len(m.group(0)))
            j = n if j == -1 else j + len(closer)
            blank_until(j)
        elif c == "'":
            # lifetime ('a, 'static) vs char literal ('x', '\n', '\u{..}')
            m = re.match(r"'([A-Za-z_][A-Za-z0-9_]*)(?!')", src[i:])
            if m and src[i + m.end() : i + m.end() + 1] != "'":
                out.append(src[i : i + m.end()])
                i += m.end()
            else:
                m2 = re.match(r"'(\\.[^']*|[^'\\])'", src[i:], re.S)
                if m2:
                    blank_until(i + m2.end())
                else:
                    out.append(c)
                    i += 1
        else:
            out.append(c)
            i += 1
    return "".join(out)


def rust_files():
    skip = {".git", "target"}
    for p in sorted(REPO.rglob("*.rs")):
        if not any(part in skip for part in p.parts):
            yield p


# ---------------------------------------------------------------------------
# 1 + 2: delimiter balance and line discipline
# ---------------------------------------------------------------------------
def check_balance_and_lines():
    pairs = {"}": "{", ")": "(", "]": "["}
    for path in rust_files():
        src = path.read_text()
        code = strip_noncode(src)
        stack = []
        line = 1
        for ch in code:
            if ch == "\n":
                line += 1
            elif ch in "{([":
                stack.append((ch, line))
            elif ch in ")}]":
                if not stack:
                    err(path, line, f"unmatched closing {ch!r}")
                    break
                top, tline = stack.pop()
                if top != pairs[ch]:
                    err(path, line, f"closing {ch!r} does not match {top!r} from line {tline}")
                    break
        else:
            for top, tline in stack:
                err(path, tline, f"unclosed {top!r}")
        for lineno, text in enumerate(src.splitlines(), 1):
            if len(text) > MAX_COLS:
                err(path, lineno, f"line exceeds {MAX_COLS} columns ({len(text)})")
            if text != text.rstrip():
                err(path, lineno, "trailing whitespace")
            if "\t" in text:
                err(path, lineno, "tab character (rustfmt uses spaces)")


# ---------------------------------------------------------------------------
# 3: Cargo.toml target paths
# ---------------------------------------------------------------------------
def check_cargo_targets():
    for toml in [REPO / "Cargo.toml", REPO / "vendor/anyhow/Cargo.toml"]:
        if not toml.exists():
            err(toml, 0, "missing Cargo.toml")
            continue
        for lineno, line in enumerate(toml.read_text().splitlines(), 1):
            m = re.match(r'\s*path\s*=\s*"([^"]+)"', line)
            if m and not (toml.parent / m.group(1)).exists():
                err(toml, lineno, f"target path {m.group(1)!r} does not exist")


# ---------------------------------------------------------------------------
# 4: module tree — `mod foo;` must resolve; also build the tree for check 6.
# Inline `pub mod name { ... }` bodies map the child module to the same file.
# ---------------------------------------------------------------------------
def module_dir(path):
    """Directory in which `mod foo;` inside `path` resolves."""
    if path.name in ("mod.rs", "lib.rs", "main.rs"):
        return path.parent
    return path.parent / path.stem


def check_mod_tree():
    tree = {}  # module path tuple -> file
    roots = [(REPO / "rust/src/lib.rs", ()), (REPO / "vendor/anyhow/src/lib.rs", ("anyhow",))]
    todo = list(roots)
    while todo:
        path, prefix = todo.pop()
        if not path.exists():
            err(path, 0, "module file missing")
            continue
        tree[prefix] = path
        code = strip_noncode(path.read_text())
        for lineno, line in enumerate(code.splitlines(), 1):
            m = re.match(r"\s*(?:pub\s+)?mod\s+([A-Za-z_][A-Za-z0-9_]*)\s*;", line)
            if not m:
                continue
            name = m.group(1)
            base = module_dir(path)
            cand = [base / f"{name}.rs", base / name / "mod.rs"]
            hits = [c for c in cand if c.exists()]
            if not hits:
                err(path, lineno, f"mod {name}; resolves to neither {cand[0].name} "
                                  f"nor {name}/mod.rs under {base.relative_to(REPO)}")
            else:
                todo.append((hits[0], prefix + (name,)))
        # inline module bodies (e.g. `pub mod prelude { ... }` in lib.rs)
        for m in re.finditer(r"(?:^|\n)\s*pub\s+mod\s+([A-Za-z_][A-Za-z0-9_]*)\s*\{", code):
            tree[prefix + (m.group(1),)] = path
    return tree


# ---------------------------------------------------------------------------
# 5: anyhow shim surface
# ---------------------------------------------------------------------------
def shim_exports():
    src = (REPO / "vendor/anyhow/src/lib.rs").read_text()
    code = strip_noncode(src)
    items = set(re.findall(
        r"pub\s+(?:struct|enum|trait|type|fn)\s+([A-Za-z_][A-Za-z0-9_]*)", code))
    macros = set()
    for m in re.finditer(r"#\[macro_export\]", code):
        tail = code[m.end():]
        mm = re.search(r"macro_rules!\s+([A-Za-z_][A-Za-z0-9_]*)", tail)
        if mm:
            macros.add(mm.group(1))
    return items, macros


def check_anyhow_usage():
    items, macros = shim_exports()
    exported = items | macros
    use_re = re.compile(r"use\s+anyhow::(?:\{([^}]*)\}|([A-Za-z_][A-Za-z0-9_]*))")
    for path in rust_files():
        if REPO / "vendor" in path.parents:
            continue
        code = strip_noncode(path.read_text())
        for lineno, line in enumerate(code.splitlines(), 1):
            for m in use_re.finditer(line):
                names = m.group(1).split(",") if m.group(1) else [m.group(2)]
                for name in (n.strip() for n in names):
                    if name and name not in exported:
                        err(path, lineno, f"`use anyhow::{name}` not exported by the shim")
            for m in re.finditer(r"\banyhow::([A-Za-z_][A-Za-z0-9_]*)", line):
                if m.group(1) not in exported | {"Result", "Error"}:
                    err(path, lineno, f"path anyhow::{m.group(1)} not exported by the shim")
        # macro invocations the shim must support
        for lineno, line in enumerate(code.splitlines(), 1):
            for m in re.finditer(r"\b(anyhow|bail|ensure)!\s*[\(\[]", line):
                if m.group(1) not in macros:
                    err(path, lineno, f"macro {m.group(1)}! not provided by the shim")


# ---------------------------------------------------------------------------
# 6 + 8: crate-path resolution
# ---------------------------------------------------------------------------
_file_code = {}


def code_of(path):
    if path not in _file_code:
        _file_code[path] = strip_noncode(path.read_text())
    return _file_code[path]



def norm_spec(raw):
    """Collapse a use-spec: whitespace removed except the ` as ` keyword,
    which is kept as `@` so aliases survive tokenization."""
    s = re.sub(r"\s+", " ", raw.strip())
    s = re.sub(r"\bas\b", "@", s)
    return s.replace(" ", "")


def pub_items(path):
    """Names a module file makes visible: direct pub defs, `pub use`
    re-exports (last segment or `as` alias), and exported macros."""
    code = code_of(path)
    names = set(re.findall(
        r"pub(?:\s*\(\s*crate\s*\))?\s+(?:unsafe\s+)?"
        r"(?:struct|enum|trait|fn|const|static|type|mod)\s+([A-Za-z_][A-Za-z0-9_]*)", code))
    for m in re.finditer(r"pub(?:\s*\(\s*crate\s*\))?\s+use\s+([^;]+);", code):
        spec = norm_spec(m.group(1))
        for leaf in expand_use(spec):
            alias = re.search(r"@([A-Za-z_][A-Za-z0-9_]*)$", leaf)
            names.add(alias.group(1) if alias else leaf.rsplit("::", 1)[-1])
    for m in re.finditer(r"#\[macro_export\]", code):
        mm = re.search(r"macro_rules!\s+([A-Za-z_][A-Za-z0-9_]*)", code[m.end():])
        if mm:
            names.add(mm.group(1))
    return names


def check_crate_paths(tree):
    use_re = re.compile(r"use\s+((?:crate|hpconcord)::[A-Za-z0-9_:{}, *\n]+?);", re.S)
    items_cache = {}
    # #[macro_export] exports at the crate root regardless of module, so
    # `use hpconcord::some_macro;` resolves even though lib.rs never names it.
    crate_macros = set()
    for f in rust_files():
        if REPO / "vendor" not in f.parents:
            c = code_of(f)
            for m in re.finditer(r"#\[macro_export\]", c):
                mm = re.search(r"macro_rules!\s+([A-Za-z_][A-Za-z0-9_]*)", c[m.end():])
                if mm:
                    crate_macros.add(mm.group(1))

    def items_of(f):
        if f not in items_cache:
            items_cache[f] = pub_items(f)
        return items_cache[f]

    for path in rust_files():
        if REPO / "vendor" in path.parents:
            continue
        code = code_of(path)
        for m in use_re.finditer(code):
            lineno = code[: m.start()].count("\n") + 1
            spec = norm_spec(m.group(1))
            for full in expand_use(spec):
                segs = re.sub(r"@[A-Za-z_][A-Za-z0-9_]*$", "", full).split("::")
                segs[0:1] = []  # drop crate/hpconcord
                if not segs:
                    continue
                # walk the module tree as deep as possible
                depth = 0
                while depth < len(segs) and tuple(segs[: depth + 1]) in tree:
                    depth += 1
                if depth == len(segs):
                    continue  # imports a module itself
                mod_file = tree.get(tuple(segs[:depth]))
                if mod_file is None:
                    err(path, lineno, f"use {full}: module path not found")
                    continue
                item = segs[depth]
                if item in ("*", "self"):
                    continue
                if depth + 1 < len(segs):
                    err(path, lineno,
                        f"use {full}: `{'::'.join(segs[:depth + 1])}` is not a module")
                    continue
                if item in crate_macros and depth == 0:
                    continue
                if item not in items_of(mod_file):
                    err(path, lineno,
                        f"use {full}: no pub item `{item}` in "
                        f"{mod_file.relative_to(REPO)}")


def expand_use(spec):
    """Expand a (whitespace-free) use spec with nested braces into leaf paths."""
    m = re.search(r"\{([^{}]*)\}", spec)
    if not m:
        yield spec
        return
    head, tail = spec[: m.start()], spec[m.end():]
    for part in m.group(1).split(","):
        if part:
            yield from expand_use(head + part + tail)


# ---------------------------------------------------------------------------
# 7: feature gates
# ---------------------------------------------------------------------------
def check_features():
    cargo = (REPO / "Cargo.toml").read_text()
    m = re.search(r"\[features\](.*?)(\n\[|\Z)", cargo, re.S)
    declared = set(re.findall(r"^([A-Za-z0-9_-]+)\s*=", m.group(1), re.M)) if m else set()
    declared.add("default")
    for path in rust_files():
        if REPO / "vendor" in path.parents:
            continue
        code = strip_noncode(path.read_text())
        for lineno, line in enumerate(code.splitlines(), 1):
            for fm in re.finditer(r'feature\s*=\s*"([^"]+)"', path.read_text().splitlines()
                                  [lineno - 1]):
                if fm.group(1) not in declared:
                    err(path, lineno, f"cfg feature {fm.group(1)!r} not declared in Cargo.toml")


# ---------------------------------------------------------------------------
# 9: entry points — every harness=false bench, every example, and main.rs
# must define fn main (cargo fails the build otherwise).
# ---------------------------------------------------------------------------
def check_entry_points():
    targets = list((REPO / "rust/benches").glob("*.rs"))
    targets += list((REPO / "examples").glob("*.rs"))
    targets.append(REPO / "rust/src/main.rs")
    for path in targets:
        if not path.exists():
            continue
        if not re.search(r"\bfn\s+main\s*\(", code_of(path)):
            err(path, 1, "no fn main (bench targets use harness = false)")


# ---------------------------------------------------------------------------
# 10: doc-tests — fenced code blocks in /// comments compile under
# `cargo test --doc`; check delimiter balance and crate-path resolution so
# a drifted example fails here instead of in the first real doc-test run.
# ---------------------------------------------------------------------------
def check_doc_tests(tree):
    pairs = {"}": "{", ")": "(", "]": "["}
    for path in rust_files():
        if REPO / "vendor" in path.parents:
            continue
        src = path.read_text()
        lines = src.splitlines()
        block, start, fence = None, 0, None
        for lineno, raw in enumerate(lines, 1):
            m = re.match(r"\s*(?:///|//!)\s?(.*)$", raw)
            if not m:
                if block is not None or fence == "skip":
                    err(path, start, "doc comment block ends inside a ``` fence")
                    block, fence = None, None
                continue
            text = m.group(1)
            if text.strip().startswith("```"):
                tag = text.strip()[3:].strip()
                if fence == "skip":
                    fence = None  # closing a non-Rust fence
                elif block is None:
                    # ignore non-Rust fences (text, ignore, sh, ...)
                    if tag in ("", "rust", "no_run", "should_panic"):
                        block, start = [], lineno
                    else:
                        fence = "skip"
                else:
                    body = "\n".join(block)
                    stack = []
                    for ch in strip_noncode(body):
                        if ch in "{([":
                            stack.append(ch)
                        elif ch in ")}]":
                            if not stack or stack.pop() != pairs[ch]:
                                err(path, start, "unbalanced delimiters in doc example")
                                stack = None
                                break
                    if stack:
                        err(path, start, "unclosed delimiter in doc example")
                    for um in re.finditer(
                            r"use\s+hpconcord::([A-Za-z0-9_:]+)", body):
                        segs = um.group(1).split("::")
                        depth = 0
                        while depth < len(segs) and tuple(segs[: depth + 1]) in tree:
                            depth += 1
                        if depth < len(segs) - 1:
                            err(path, start,
                                f"doc example: hpconcord::{um.group(1)} not a module path")
                    block = None
            elif block is not None:
                block.append(text.lstrip("# ") if text.strip().startswith("#") else text)
        if block is not None:
            err(path, start, "unterminated ``` fence in doc comment")


# ---------------------------------------------------------------------------
# 11: struct-literal field coverage.  PRs 4-6 repeatedly grew option structs
# (ScreenedDistOptions, ExecutorTask, ...) and the historical failure mode is
# a stale literal in a test or bench that no longer names every field.  For
# every `Name { ... }` expression or pattern whose Name is a struct defined
# in this repo: unknown fields are an error, and a literal without `..` must
# name every field (Rust's own rule for both literals and patterns).
# ---------------------------------------------------------------------------
STRUCT_DEF_RE = re.compile(
    r"\bstruct\s+([A-Z][A-Za-z0-9_]*)\s*(?:<[^>{;]*>)?\s*(?:where[^{;]*)?\{")
FIELD_RE = re.compile(r"(?:pub(?:\s*\(\s*crate\s*\))?\s+)?([a-z_][A-Za-z0-9_]*)\s*:")


def collect_struct_defs():
    """name -> list of field-name sets (one per definition site)."""
    defs = {}
    for path in rust_files():
        code = code_of(path)
        for m in STRUCT_DEF_RE.finditer(code):
            body, _ = balanced_span(code, m.end() - 1)
            if body is None:
                continue
            fields = set()
            for part in split_top_level(body):
                fm = FIELD_RE.match(part.strip())
                if fm:
                    fields.add(fm.group(1))
            defs.setdefault(m.group(1), []).append(fields)
    return defs


def balanced_span(code, open_idx):
    """Return (inner_text, end_idx) for the {...} starting at open_idx."""
    depth = 0
    for j in range(open_idx, len(code)):
        if code[j] in "{([":
            depth += 1
        elif code[j] in ")}]":
            depth -= 1
            if depth == 0:
                return code[open_idx + 1 : j], j
    return None, None


def split_top_level(text):
    """Split on commas outside {}, (), [].  Angle brackets are NOT tracked
    (`=>` and comparisons would confuse them); a comma inside a generic list
    mis-splits into a part that fails the field regex, which callers treat
    as \"not a field list\" — a safe skip, never a false report."""
    parts, depth, cur = [], 0, []
    for ch in text:
        if ch in "{([":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth <= 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


# Tokens before `Name {` that mean the braces are NOT a field list.
NOT_LITERAL_PREV = {
    "struct", "enum", "union", "trait", "impl", "for", "mod", "use", "dyn",
    "as", "->", ":", "&", "<", "+", "==", "!=", "&&", "||", "where", "if",
    "while", "match", "in", "|",
}


def check_struct_literals():
    defs = collect_struct_defs()
    lit_re = re.compile(r"\b([A-Z][A-Za-z0-9_]*)\s*\{")
    for path in rust_files():
        code = code_of(path)
        for m in lit_re.finditer(code):
            name = m.group(1)
            if name not in defs:
                continue
            prev = code[: m.start()].rstrip()
            prev_tok = re.search(r"([A-Za-z_][A-Za-z0-9_]*|::|->|==|!=|&&|\|\||[^\s])\Z", prev)
            if prev_tok and prev_tok.group(1) in NOT_LITERAL_PREV:
                continue
            body, _ = balanced_span(code, m.end() - 1)
            if body is None:
                continue
            lineno = code[: m.start()].count("\n") + 1
            used, has_base, malformed = set(), False, False
            for part in split_top_level(body):
                part = part.strip()
                if not part:
                    continue
                if part.startswith(".."):
                    has_base = True
                    continue
                fm = re.match(r"([A-Za-z_][A-Za-z0-9_]*)\s*(?::|$|@)", part)
                if fm:
                    used.add(fm.group(1))
                else:
                    malformed = True  # an expression, so this is a block, not a literal
            if malformed:
                continue
            field_sets = defs[name]
            if not any(used <= fs for fs in field_sets):
                extra = used - set.union(*field_sets)
                err(path, lineno, f"{name} {{ ... }}: unknown field(s) {sorted(extra)}")
            elif not has_base and len(field_sets) == 1 and used and \
                    used != field_sets[0]:
                err(path, lineno,
                    f"{name} {{ ... }} misses field(s) {sorted(field_sets[0] - used)} "
                    f"and has no `..` base")


# ---------------------------------------------------------------------------
# 12: format-argument counts.  `println!("{} {}", a)` is a compile error the
# lexer can see: count positional placeholders in the literal vs the argument
# tail (named/indexed placeholders and `name = value` args are skipped).
# ---------------------------------------------------------------------------
FMT_MACROS = {"println": 0, "print": 0, "eprintln": 0, "eprint": 0, "format": 0,
              "panic": 0, "anyhow": 0, "bail": 0, "write": 1, "writeln": 1,
              "assert": 1, "ensure": 1, "assert_eq": 2, "assert_ne": 2}


def count_positional(fmt):
    """(positional, saw_indexed): placeholders in a format literal body."""
    pos, indexed, i = 0, False, 0
    while i < len(fmt):
        if fmt[i : i + 2] in ("{{", "}}"):
            i += 2
            continue
        if fmt[i] == "{":
            j = fmt.find("}", i)
            if j == -1:
                break
            body = fmt[i + 1 : j]
            head = body.split(":", 1)[0].split("$", 1)[0]
            if head == "":
                pos += 1
            elif head.isdigit():
                indexed = True
            # width/precision `$` args also consume positionals
            for spec in re.findall(r"(?<![A-Za-z0-9_.])(\d*)\$", body.partition(":")[2]):
                if spec == "":
                    pos += 1
            i = j + 1
        else:
            i += 1
    return pos, indexed


def check_format_args():
    call_re = re.compile(r"\b([a-z_]+)!\s*\(")
    for path in rust_files():
        code = code_of(path)
        src = path.read_text()
        for m in call_re.finditer(code):
            name = m.group(1)
            if name not in FMT_MACROS:
                continue
            body, _ = balanced_span(code, m.end() - 1)
            if body is None:
                continue
            lineno = code[: m.start()].count("\n") + 1
            raw_body = src[m.end() : m.end() + len(body)]
            args = split_top_level(body)
            skip = FMT_MACROS[name]
            if len(args) <= skip:
                continue
            # the format literal must be a plain string literal
            offset = sum(len(a) + 1 for a in args[:skip])
            lit_blank = args[skip].strip()
            if not lit_blank.startswith('"'):
                continue
            lit_raw = raw_body[offset:offset + len(args[skip])].strip()
            lm = re.match(r'"((?:\\.|[^"\\])*)"\s*$', lit_raw, re.S)
            if not lm:
                continue
            pos, indexed = count_positional(lm.group(1))
            tail = [a for a in args[skip + 1 :] if a.strip()]
            if any(re.match(r"\s*[A-Za-z_][A-Za-z0-9_]*\s*=[^=]", a) for a in tail):
                continue  # named arguments — out of scope
            if not indexed and pos != len(tail):
                err(path, lineno,
                    f"{name}!: format literal has {pos} positional placeholder(s) "
                    f"but {len(tail)} argument(s)")


# ---------------------------------------------------------------------------
# 13: deprecated-wrapper containment.  The `_mat`/`_src` compatibility shims
# around the canonical XSource entry points survive for one release, but no
# non-compat code may call them: only the files that define the shims and the
# two `#[allow(deprecated)]` re-export relays may spell the names.  Comments
# and string literals (USAGE text) are stripped before matching.
# ---------------------------------------------------------------------------
DEPRECATED_WRAPPERS = [
    "fit_screened_distributed_mat", "fit_screened_distributed_src",
    "run_sweep_screened_dist_mat", "run_sweep_screened_dist_src",
    "stability_selection_dist_mat", "stability_selection_dist_src",
]
WRAPPER_HOMES = {
    "rust/src/concord/screened_dist.rs",  # defines fit_screened_distributed_{mat,src}
    "rust/src/coordinator/sweep.rs",      # defines run_sweep_screened_dist_{mat,src}
    "rust/src/coordinator/stability.rs",  # defines stability_selection_dist_{mat,src}
    "rust/src/concord/mod.rs",            # deprecation re-export relay
    "rust/src/coordinator/mod.rs",        # deprecation re-export relay
}


def check_deprecated_wrappers():
    pat = re.compile(r"\b(" + "|".join(DEPRECATED_WRAPPERS) + r")\b")
    for path in rust_files():
        if str(path.relative_to(REPO)) in WRAPPER_HOMES:
            continue
        code = code_of(path)
        for m in pat.finditer(code):
            lineno = code[: m.start()].count("\n") + 1
            err(path, lineno,
                f"{m.group(1)} is a deprecated compatibility shim — call the "
                "canonical XSource-taking entry point instead")


# ---------------------------------------------------------------------------
# 14: unsafe containment.  Determinism rule 10 rests on exactly two audited
# unsafe surfaces: the `target_feature` SIMD microkernels (whose safe wrappers
# re-check CPU support) and the vendored sched_setaffinity shim.  No other
# file may spell `unsafe` — a third unsafe block must either move into one of
# these homes or grow this allowlist in review.  Comments and string literals
# are stripped first, so prose about unsafety stays legal.
# ---------------------------------------------------------------------------
UNSAFE_HOMES = {
    "rust/src/linalg/simd.rs",    # AVX2/AVX-512 microkernels + safe wrappers
    "vendor/affinity/src/lib.rs", # sched_setaffinity syscall shim
}


def check_unsafe_containment():
    pat = re.compile(r"\bunsafe\b")
    for path in rust_files():
        if str(path.relative_to(REPO)) in UNSAFE_HOMES:
            continue
        code = code_of(path)
        for m in pat.finditer(code):
            lineno = code[: m.start()].count("\n") + 1
            err(path, lineno,
                "`unsafe` outside the audited homes (rust/src/linalg/simd.rs, "
                "vendor/affinity/src/lib.rs) — keep new code in safe Rust or "
                "grow the check-14 allowlist in review")


def selftest_unsafe_containment():
    """Negative self-test: the check must flag an unsafe block in a
    non-allowlisted file and stay quiet about one in an audited home."""
    code = strip_noncode("fn f() { unsafe { core::hint::unreachable_unchecked() } }\n"
                         "// unsafe in a comment is fine\n"
                         'let s = "unsafe in a string is fine";\n')
    hits = list(re.finditer(r"\bunsafe\b", code))
    assert len(hits) == 1, "check 14 self-test: lexer must keep exactly the code `unsafe`"
    assert "rust/src/linalg/simd.rs" in UNSAFE_HOMES and len(UNSAFE_HOMES) == 2, \
        "check 14 self-test: allowlist drifted"


def main():
    selftest_unsafe_containment()
    check_balance_and_lines()
    check_cargo_targets()
    tree = check_mod_tree()
    check_anyhow_usage()
    check_crate_paths(tree)
    check_features()
    check_entry_points()
    check_doc_tests(tree)
    check_struct_literals()
    check_format_args()
    check_deprecated_wrappers()
    check_unsafe_containment()
    n_files = sum(1 for _ in rust_files())
    if errors:
        for e in errors:
            print(e)
        print(f"\nstatic audit: {len(errors)} finding(s) across {n_files} Rust files",
              file=sys.stderr)
        return 1
    print(f"static audit: OK ({n_files} Rust files, 14 check classes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
