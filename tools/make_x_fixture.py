#!/usr/bin/env python3
"""Write HPCX x-files without the Rust toolchain (offline builder
image companion to `hpconcord convert`; see tools/static_audit.sh for
why the image cannot run cargo).

The writer mirrors the Rust chain-workload generator bit-faithfully:

  * SplitMix64 and the Box-Muller draw order are integer-level mirrors
    of rust/src/rng.rs (the stream is checked against the published
    SplitMix64 test vectors in --self-check);
  * the banded Cholesky (bw = 1) and its transpose solve replay
    rust/src/linalg/chol.rs op for op — every add, multiply, divide
    and sqrt in the same order, so IEEE-754 gives the same bits;
  * the HPCX layout (24-byte header: magic "HPCX", u32 LE version,
    u64 LE n, u64 LE p; row-major LE f64 payload) matches
    rust/src/io/mod.rs, and the reader validates exactly what
    `XDisk::open` validates.

So `make_x_fixture.py --p 256 --n 150 --seed 42 --out x.xbin` writes
the same bytes `hpconcord convert --workload chain --p 256 --n 150
--seed 42 --out x.xbin` writes (libm caveat: ln/sin/cos inside
Box-Muller come from the platform libm in both languages; on the
glibc images CI uses they agree to the bit).

`--self-check` needs no numpy and is wired into the offline CI job;
tools/verify_fixture_margins.py additionally cross-checks this
module's chain sampler against its independent numpy mirror.
"""

import argparse
import math
import os
import struct
import sys
import tempfile

MASK = (1 << 64) - 1

X_MAGIC = b"HPCX"
X_VERSION = 1
X_HEADER_BYTES = 24


class Rng:
    """SplitMix64 + Box-Muller pair cache — mirror of rust/src/rng.rs."""

    def __init__(self, seed):
        self.state = seed & MASK
        self.spare = None

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self):
        if self.spare is not None:
            v, self.spare = self.spare, None
            return v
        u1 = 1.0 - self.uniform()
        u2 = self.uniform()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.spare = r * math.sin(theta)
        return r * math.cos(theta)

    def normal_vec(self, n):
        return [self.normal() for _ in range(n)]


def chain_entry(i, j):
    """chain_precision(p) as an entry accessor: tridiagonal 1.25 / -0.5."""
    if i == j:
        return 1.25
    if abs(i - j) == 1:
        return -0.5
    return 0.0


def banded_cholesky_bw1(p, entry):
    """rust/src/linalg/chol.rs::banded_cholesky at bw = 1, op for op.

    Returns L as {(i, j): value} over the band j in [max(i-1,0), i].
    """
    l = {}
    for i in range(p):
        jmin = max(i - 1, 0)
        for j in range(jmin, i + 1):
            s = entry(i, j)
            kmin = max(jmin, max(j - 1, 0))
            for k in range(kmin, j):
                s -= l[(i, k)] * l[(j, k)]
            if i == j:
                if s <= 0.0:
                    raise ValueError(f"not positive definite (pivot {i}: {s})")
                l[(i, i)] = math.sqrt(s)
            else:
                l[(i, j)] = s / l[(j, j)]
    return l


def solve_transpose_bw1(l, p, b):
    """BandedChol::solve_transpose at bw = 1, op for op (backward)."""
    x = [0.0] * p
    for i in range(p - 1, -1, -1):
        s = b[i]
        kmax = min(i + 1, p - 1)
        for k in range(i + 1, kmax + 1):
            s -= l[(k, i)] * x[k]
        x[i] = s / l[(i, i)]
    return x


def chain_x_rows(p, n, rng):
    """gen::chain_problem(p, n, rng).x one row at a time: z ~ N(0, I),
    x_i = L^-T z through the banded factor of the chain precision."""
    l = banded_cholesky_bw1(p, chain_entry)
    for _ in range(n):
        z = rng.normal_vec(p)
        yield solve_transpose_bw1(l, p, z)


def write_hpcx(path, n, p, rows):
    """Write an HPCX file atomically (temp sibling + rename, mirroring
    io::write_x): header then row-major LE f64 rows from `rows`."""
    tmp = path + ".tmp"
    row_fmt = "<%dd" % p
    try:
        with open(tmp, "wb") as f:
            f.write(struct.pack("<4sIQQ", X_MAGIC, X_VERSION, n, p))
            count = 0
            for row in rows:
                f.write(struct.pack(row_fmt, *row))
                count += 1
            if count != n:
                raise ValueError(f"row iterator yielded {count} rows, header says {n}")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def read_hpcx(path):
    """Read and validate an HPCX file (the checks `XDisk::open` makes).

    Returns (n, p, payload) with the payload as the raw bytes — bit
    comparisons need no float round trip.
    """
    with open(path, "rb") as f:
        header = f.read(X_HEADER_BYTES)
        if len(header) < X_HEADER_BYTES:
            raise ValueError(f"{path}: truncated header (want {X_HEADER_BYTES} bytes)")
        magic, version, n, p = struct.unpack("<4sIQQ", header)
        if magic != X_MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r} (want {X_MAGIC!r})")
        if version != X_VERSION:
            raise ValueError(f"{path}: unsupported HPCX version {version} (want {X_VERSION})")
        payload = f.read()
    if len(payload) != n * p * 8:
        raise ValueError(
            f"{path}: file length {X_HEADER_BYTES + len(payload)} does not match "
            f"header n={n} p={p}"
        )
    return n, p, payload


def self_check():
    """Toolchain-free gate: RNG test vectors, bit-exact round trip,
    atomicity, and every header-validation failure mode."""
    # SplitMix64 reference stream (seed 0): the published test vector.
    r = Rng(0)
    got = [r.next_u64() for _ in range(3)]
    want = [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]
    assert got == want, f"SplitMix64 mirror drifted: {[hex(v) for v in got]}"

    p, n, seed = 8, 13, 0xC0DE
    rows = list(chain_x_rows(p, n, Rng(seed)))
    assert all(math.isfinite(v) for row in rows for v in row)
    # The chain factor is exact on paper: L[0][0] = sqrt(1.25).
    l = banded_cholesky_bw1(p, chain_entry)
    assert l[(0, 0)] == math.sqrt(1.25)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.xbin")
        write_hpcx(path, n, p, iter(rows))
        assert not os.path.exists(path + ".tmp"), "temp sibling left behind"
        rn, rp, payload = read_hpcx(path)
        assert (rn, rp) == (n, p)
        want_payload = b"".join(struct.pack("<%dd" % p, *row) for row in rows)
        assert payload == want_payload, "round trip is not bit-exact"

        # A lying row iterator must not leave a file under the target.
        bad = os.path.join(d, "bad.xbin")
        try:
            write_hpcx(bad, n + 1, p, iter(rows))
            raise AssertionError("short row iterator accepted")
        except ValueError:
            pass
        assert not os.path.exists(bad) and not os.path.exists(bad + ".tmp")

        raw = open(path, "rb").read()

        def expect_invalid(name, data):
            broken = os.path.join(d, name)
            with open(broken, "wb") as f:
                f.write(data)
            try:
                read_hpcx(broken)
                raise AssertionError(f"{name} accepted")
            except ValueError:
                pass

        expect_invalid("trunc.xbin", raw[:10])
        expect_invalid("magic.xbin", b"JUNK" + raw[4:])
        expect_invalid("version.xbin", raw[:4] + struct.pack("<I", 9) + raw[8:])
        expect_invalid("short.xbin", raw[:-8])
        expect_invalid("long.xbin", raw + b"\x00" * 8)

    print("make_x_fixture self-check: OK (RNG vectors, bit-exact round "
          "trip, atomic write, header validation)")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--p", type=int, default=32, help="variables (columns)")
    ap.add_argument("--n", type=int, default=100, help="samples (rows)")
    ap.add_argument("--seed", type=lambda s: int(s, 0), default=42,
                    help="SplitMix64 seed (0x.. accepted); must match the solve run's --seed")
    ap.add_argument("--out", help="HPCX output path")
    ap.add_argument("--self-check", action="store_true",
                    help="run the toolchain-free gate and exit")
    args = ap.parse_args()
    if args.self_check:
        self_check()
        return 0
    if not args.out:
        ap.error("--out FILE is required (or use --self-check)")
    write_hpcx(args.out, args.n, args.p, chain_x_rows(args.p, args.n, Rng(args.seed)))
    size = os.path.getsize(args.out)
    print(f"wrote {args.out}: HPCX v{X_VERSION} n={args.n} p={args.p} ({size} bytes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
