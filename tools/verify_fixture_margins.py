#!/usr/bin/env python3
"""Empirically verify the screening margins of the analytically-sized
test fixtures (offline companion to the Rust test suites; the builder
image has no Rust toolchain, see tools/static_audit.sh).

The screening suites assert *component structure* of thresholded sample
grams: `chain_problem(16, 200, 0xC0DE)` must stay connected at
lambda1 = 0.05 (rust/tests/screening_equivalence.rs), and every
`disjoint_blocks` fixture must keep each block internally connected at
the suite's lambda1 while the cross-block entries are exactly 0.0 by
construction (rust/tests/common/mod.rs). Those asserts are
deterministic — the RNG is a fixed SplitMix64 stream — but their safety
margin decides whether an unrelated change that re-orders RNG draws
turns into a confusing screening failure. This script mirrors the Rust
generators bit-faithfully at the integer level (SplitMix64, Box-Muller
draw order, banded-Cholesky sampling, disjoint-row block embedding),
recomputes each fixture's gram, and reports for every (fixture,
lambda1) pair:

  * the realized component count (must match the suite's assert);
  * the minimum connecting |S_ij| over the chain edges that hold each
    block together, and its margin above lambda1;
  * that margin in units of the analytic sampling sigma of a gram
    entry, sigma ~= sqrt((Sii*Sjj + Sij^2)/n_each) / n_blocks — the
    ">= 4 sigma" rule the fixture comments promise;
  * the maximum spurious |S_ij| over pairs that are *far* in the chain
    (graph distance > 2), whose margin below lambda1 guards the
    all-singletons edge cases.

Exit status is nonzero if any fixture's component structure or 4-sigma
margin fails, so CI can run this as a gate. Float caveat: Python's libm
may differ from Rust's in the last ulp of ln/sin/cos; margins are
~1e-2, twelve orders above that noise.

Measured margins (this container, 2026-08-08) are recorded in
rust/tests/common/mod.rs and the suites' fixture comments.
"""

import math
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import make_x_fixture  # noqa: E402  (sibling tool: the HPCX writer mirror)

MASK = (1 << 64) - 1


class Rng:
    """SplitMix64 + Box-Muller pair cache — mirror of rust/src/rng.rs."""

    def __init__(self, seed):
        self.state = seed & MASK
        self.spare = None

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self):
        if self.spare is not None:
            v, self.spare = self.spare, None
            return v
        u1 = 1.0 - self.uniform()
        u2 = self.uniform()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.spare = r * math.sin(theta)
        return r * math.cos(theta)

    def normal_vec(self, n):
        return [self.normal() for _ in range(n)]


def chain_precision(p):
    om = np.zeros((p, p))
    for i in range(p):
        om[i, i] = 1.25
        if i + 1 < p:
            om[i, i + 1] = om[i + 1, i] = -0.5
    return om


def chain_problem_x(p, n, rng):
    """Mirror of gen::chain_problem: x[i] = L^-T z, z ~ N(0, I) row-wise.

    Cholesky factors are unique for PD matrices, so numpy's L equals the
    banded factorization in rust/src/linalg up to rounding.
    """
    om = chain_precision(p)
    l = np.linalg.cholesky(om)
    x = np.zeros((n, p))
    for i in range(n):
        z = np.array(rng.normal_vec(p))
        x[i] = np.linalg.solve(l.T, z)
    return x


def disjoint_blocks(sizes, n_each, seed):
    """Mirror of rust/tests/common/mod.rs::disjoint_blocks: block b's
    chain sample occupies rows [b*n_each, (b+1)*n_each) and its own
    column band; everything else stays exactly 0.0."""
    rng = Rng(seed)
    p = sum(sizes)
    x = np.zeros((n_each * len(sizes), p))
    col0 = 0
    for b, sz in enumerate(sizes):
        xb = chain_problem_x(sz, n_each, rng)
        x[b * n_each:(b + 1) * n_each, col0:col0 + sz] = xb
        col0 += sz
    return x


def gram(x):
    return x.T @ x / x.shape[0]


def components(s, thr):
    """Union-find over |S_ij| > thr, renumbered by first appearance —
    mirror of covariance_components."""
    p = s.shape[0]
    parent = list(range(p))

    def find(i):
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    for i in range(p):
        for j in range(i + 1, p):
            if abs(s[i, j]) > thr:
                ri, rj = find(i), find(j)
                if ri != rj:
                    parent[max(ri, rj)] = min(ri, rj)
    seen = {}
    return [seen.setdefault(find(i), len(seen)) for i in range(p)]


def analyze(name, sizes, n_each, seed, lambdas, x=None):
    """Report margins for one disjoint_blocks fixture (or a plain chain
    problem when sizes has one block and x is given)."""
    if x is None:
        x = disjoint_blocks(sizes, n_each, seed)
    s = gram(x)
    nblocks = len(sizes)
    ok = True

    # Chain edges (i, i+1) within each block are what keep it connected.
    edges, far_pairs = [], []
    col0 = 0
    for sz in sizes:
        for j in range(sz - 1):
            edges.append((col0 + j, col0 + j + 1))
        for a in range(sz):
            for b in range(a + 3, sz):  # graph distance > 2: tiny true cov
                far_pairs.append((col0 + a, col0 + b))
        col0 += sz
    min_edge = min(abs(s[i, j]) for i, j in edges)
    arg_edge = min(edges, key=lambda e: abs(s[e[0], e[1]]))
    max_far = max((abs(s[i, j]) for i, j in far_pairs), default=0.0)

    # Analytic sigma of a gram entry at the weakest edge. Each block
    # contributes n_each live rows out of n_each*nblocks, so the entry
    # and its sigma are both divided by nblocks.
    i, j = arg_edge
    sii, sjj, sij = s[i, i] * nblocks, s[j, j] * nblocks, s[i, j] * nblocks
    sigma = math.sqrt((sii * sjj + sij * sij) / n_each) / nblocks

    print(f"{name}: min connecting |S_ij| = {min_edge:.4f} at {arg_edge}, "
          f"sigma = {sigma:.4f}, max far-pair |S_ij| = {max_far:.4f}")
    for lam in lambdas:
        comp = components(s, lam)
        ncomp = max(comp) + 1
        margin = min_edge - lam
        nsig = margin / sigma
        status = "ok" if ncomp == nblocks and nsig >= 4.0 else "FAIL"
        if status == "FAIL":
            ok = False
        print(f"  lambda1={lam}: components={ncomp} (want {nblocks}), "
              f"margin={margin:+.4f} = {nsig:.1f} sigma   [{status}]")
    return ok


def check_x_fixture_writer():
    """Cross-check tools/make_x_fixture.py against this script's
    independent numpy mirror: the two chain samplers share the RNG
    stream but factor the precision differently (op-for-op banded
    Cholesky vs numpy's dense LAPACK), so agreement to float rounding
    pins both; the written HPCX file must round-trip bit-exactly."""
    p, n, seed = 12, 40, 0xC0DE
    ours = chain_problem_x(p, n, Rng(seed))
    rows = list(make_x_fixture.chain_x_rows(p, n, make_x_fixture.Rng(seed)))
    theirs = np.array(rows)
    drift = np.abs(ours - theirs).max()
    if drift > 1e-10:
        print(f"make_x_fixture writer: FAIL (chain sampler drift {drift:.2e})")
        return False
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.xbin")
        make_x_fixture.write_hpcx(path, n, p, iter(rows))
        rn, rp, payload = make_x_fixture.read_hpcx(path)
        back = np.frombuffer(payload, dtype="<f8").reshape(rn, rp)
        if (rn, rp) != (n, p) or not (back == theirs).all():
            print("make_x_fixture writer: FAIL (HPCX round trip not bit-exact)")
            return False
    print(f"make_x_fixture writer: OK (sampler drift {drift:.2e} <= 1e-10, "
          "HPCX round trip bit-exact)")
    return True


def main():
    ok = True

    # The HPCX fixture writer must mirror the generators this script
    # (and `hpconcord convert`) mirrors.
    ok &= check_x_fixture_writer()

    # screening_equivalence.rs: the connected acceptance fixture must
    # stay ONE component at lambda1 = 0.05.
    rng = Rng(0xC0DE)
    x = chain_problem_x(16, 200, rng)
    ok &= analyze("chain_problem(16,200,0xC0DE) [connected]",
                  [16], 200, None, [0.05], x=x)
    # ...and connected means exactly one component, not just margins:
    s = gram(x)
    if max(components(s, 0.05)) != 0:
        print("  FAIL: connected fixture split at 0.05")
        ok = False

    # screening_equivalence.rs block fixtures, all at lambda1 = 0.05.
    ok &= analyze("disjoint_blocks([12,12],200,0xB10C)", [12, 12], 200, 0xB10C, [0.05])
    ok &= analyze("disjoint_blocks([10,8],400,0xB17)", [10, 8], 400, 0xB17, [0.05])
    ok &= analyze("disjoint_blocks([12,12],400,0xFAB)", [12, 12], 400, 0xFAB, [0.05])
    ok &= analyze("disjoint_blocks([10,6],400,0x57A7)", [10, 6], 400, 0x57A7, [0.05])

    # grid_schedule.rs sweeps screen at lambda1 in {0.02, 0.05} with
    # FOUR blocks (within-block gram scaled by 1/4 — the tight case, so
    # these fixtures carry n_each = 800).
    ok &= analyze("disjoint_blocks([10]*4,800,0x9A1D)", [10] * 4, 800, 0x9A1D, [0.02, 0.05])
    ok &= analyze("disjoint_blocks([12,6,6,6],800,0x6B11)", [12, 6, 6, 6], 800, 0x6B11,
                  [0.02, 0.05])
    ok &= analyze("disjoint_blocks([10]*4,800,0x5E9)", [10] * 4, 800, 0x5E9, [0.02, 0.05])

    # grid_schedule.rs stability fixture screens subsamples (fraction
    # 0.5) at lambda1 = 0.1; the full-gram margin must carry ~sqrt(2)
    # more sigma so the half-sample margins stay >= 4 sigma too.
    ok &= analyze("disjoint_blocks([8,8],800,0xED6E)", [8, 8], 800, 0xED6E, [0.1])

    # concurrent_schedule.rs: five four-block fixtures, all screened at
    # lambda1 = 0.02.
    for seed, n in ((0x4A7E, 400), (0xC0C0, 400), (0x0B1, 400), (0xACCE, 400), (0xFADE, 200)):
        ok &= analyze(f"disjoint_blocks([10]*4,{n},{seed:#x})", [10] * 4, n, seed, [0.02])

    # memory_budget.rs (lambda1 = 0.02).
    ok &= analyze("disjoint_blocks([10]*4,400,0x9A1D)", [10] * 4, 400, 0x9A1D, [0.02])
    ok &= analyze("disjoint_blocks([12,6,6,6],200,0x51ab)", [12, 6, 6, 6], 200, 0x51AB, [0.02])
    ok &= analyze("disjoint_blocks([10,10],200,0x0BAD)", [10, 10], 200, 0x0BAD, [0.02])
    ok &= analyze("disjoint_blocks([8,8,8],200,0xF00D)", [8, 8, 8], 200, 0xF00D, [0.02])

    # lemma_counts.rs (lambda1 = 0.02) and parallel_determinism.rs
    # (lambda1 = 0.05) block fixtures.
    ok &= analyze("disjoint_blocks([12,12],200,0x5EED5)", [12, 12], 200, 0x5EED5, [0.02])
    ok &= analyze("disjoint_blocks([10,8],300,0x5C1)", [10, 8], 300, 0x5C1, [0.05])
    ok &= analyze("disjoint_blocks([12,12],400,0x5C2)", [12, 12], 400, 0x5C2, [0.05])
    ok &= analyze("disjoint_blocks([12,12],300,0x5C3)", [12, 12], 300, 0x5C3, [0.05])

    print()
    if not ok:
        print("fixture margins: FAIL (see lines above)")
        return 1
    print("fixture margins: OK (every fixture holds its component "
          "structure with >= 4 sigma to spare)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
