#!/usr/bin/env python3
"""Compare two BENCH_*.json files and fail on a >10% throughput regression.

Rule:
  Records are matched across the two files by their "name" field.  A
  record whose "oracle" string names a reference record ("bitwise ==
  NAME ...") present in both files compares by its SPEEDUP over that
  reference (record gflops / reference gflops), baseline vs candidate —
  a candidate speedup below  (1 - tolerance) * baseline speedup  is a
  regression.  Normalizing by the in-file reference cancels host-speed
  drift: CI runners and the recording image differ in absolute GF/s,
  but blocked-vs-naive and SIMD-vs-scalar ratios are architectural.

  Shared records without a resolvable reference fall back to absolute
  comparison — gflops when both sides report one, wall_s otherwise —
  except that *reference* records (named as some other record's oracle
  reference) are informational only: they are the measuring stick, and
  an absolute move there means the host changed speed, not the code.

  The tolerance defaults to 0.10 — right for two runs on the same host
  in the same thermal window.  `--tolerance F` overrides it: the CI
  gate passes 0.5, because across hosts and time windows AVX-512
  frequency licensing and shared-VM steal swing honest SIMD-vs-scalar
  ratios by ±40%, while the defect classes the gate exists for (a
  vector lane silently degrading to scalar ≈ −80%, a lost SpMM pack
  win, a lost fusion win) sit far below −50%.

  Records present in only one file are reported but never fail the
  diff (the benchmark surface is allowed to grow).  Improvements are
  printed for the log and never fail.

Exit status: 0 when no shared record regresses past the tolerance, 1
otherwise (and 2 on malformed input).

Usage:
  python3 tools/bench_diff.py [--tolerance F] BASELINE.json CANDIDATE.json
  python3 tools/bench_diff.py --help

CI runs this after the C-mirror bench regenerates BENCH_c_mirror.json,
with the committed BENCH_simd_baseline.json as the baseline, so a code
change that silently slows a measured kernel relative to its own
reference fails the offline job.
"""

import json
import re
import sys
from pathlib import Path

DEFAULT_TOLERANCE = 0.10
ORACLE_REF_RE = re.compile(r"bitwise == (\w+)")


def load_records(path):
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    records = doc.get("records")
    if not isinstance(records, list):
        print(f"bench_diff: {path} has no \"records\" array", file=sys.stderr)
        sys.exit(2)
    out = {}
    for rec in records:
        name = rec.get("name")
        if not name:
            print(f"bench_diff: {path}: record without a name: {rec}", file=sys.stderr)
            sys.exit(2)
        if name in out:
            print(f"bench_diff: {path}: duplicate record name {name!r}", file=sys.stderr)
            sys.exit(2)
        out[name] = rec
    return out


def gf(rec):
    return float(rec.get("gflops", 0.0))


def reference_of(rec, records):
    """Name of the record this one's oracle compares against, if the
    oracle string names one that exists in `records` with a rate."""
    m = ORACLE_REF_RE.search(str(rec.get("oracle", "")))
    if m and m.group(1) in records and gf(records[m.group(1)]) > 0.0:
        return m.group(1)
    return None


def main(argv):
    if "--help" in argv or "-h" in argv:
        print(__doc__)
        return 0
    tol = DEFAULT_TOLERANCE
    if "--tolerance" in argv:
        i = argv.index("--tolerance")
        try:
            tol = float(argv[i + 1])
            if not 0.0 < tol < 1.0:
                raise ValueError
        except (IndexError, ValueError):
            print("bench_diff: --tolerance needs a number in (0, 1)", file=sys.stderr)
            return 2
        argv = argv[:i] + argv[i + 2:]
    if len(argv) != 2:
        print("usage: bench_diff.py [--tolerance F] BASELINE.json CANDIDATE.json "
              "(see --help)", file=sys.stderr)
        return 2
    base_path, cand_path = argv
    base = load_records(base_path)
    cand = load_records(cand_path)

    shared = sorted(set(base) & set(cand))
    only_base = sorted(set(base) - set(cand))
    only_cand = sorted(set(cand) - set(base))
    if not shared:
        print("bench_diff: the two files share no record names — nothing to compare",
              file=sys.stderr)
        return 2

    # A record is a "reference" if any shared record's oracle names it
    # on both sides; references are the measuring stick, never gated.
    ref_names = set()
    for name in shared:
        rb = reference_of(base[name], base)
        rc = reference_of(cand[name], cand)
        if rb and rb == rc:
            ref_names.add(rb)

    regressions = []
    print(f"bench_diff: {base_path} vs {cand_path} "
          f"({len(shared)} shared record(s), tolerance {tol:.0%})")
    for name in shared:
        b, c = base[name], cand[name]
        rb = reference_of(b, base)
        rc = reference_of(c, cand)
        if rb and rb == rc and gf(b) > 0.0 and gf(c) > 0.0:
            bs = gf(b) / gf(base[rb])
            cs = gf(c) / gf(cand[rb])
            ratio = cs / bs
            regressed = cs < (1.0 - tol) * bs
            detail = (f"{bs:.2f}x -> {cs:.2f}x vs {rb} "
                      f"({ratio - 1.0:+.1%} relative to baseline)")
        elif name in ref_names:
            print(f"  =  {name}: reference record ({gf(b):.4f} -> {gf(c):.4f} GF/s; "
                  "gated through the ratios above, host-speed drift expected)")
            continue
        elif gf(b) > 0.0 and gf(c) > 0.0:
            ratio = gf(c) / gf(b)
            regressed = gf(c) < (1.0 - tol) * gf(b)
            detail = f"{gf(b):.4f} -> {gf(c):.4f} GF/s ({ratio - 1.0:+.1%} vs baseline)"
        else:
            bw, cw = float(b.get("wall_s", 0.0)), float(c.get("wall_s", 0.0))
            if bw <= 0.0 or cw <= 0.0:
                print(f"  ?  {name}: no usable gflops or wall_s on one side — skipped")
                continue
            ratio = bw / cw  # >1 means the candidate got faster
            regressed = cw > (1.0 + tol) * bw
            detail = f"{bw:.6f}s -> {cw:.6f}s wall ({ratio - 1.0:+.1%} vs baseline)"
        mark = "FAIL" if regressed else ("  + " if ratio > 1.0 else "  ok")
        print(f"{mark} {name}: {detail}")
        if regressed:
            regressions.append(name)

    for name in only_base:
        print(f"  -  {name}: only in baseline (informational)")
    for name in only_cand:
        print(f"  +  {name}: only in candidate (informational)")

    if regressions:
        print(f"\nbench_diff: {len(regressions)} record(s) regressed by more than "
              f"{tol:.0%}: {', '.join(regressions)}", file=sys.stderr)
        return 1
    print(f"bench_diff: OK — no shared record regressed by more than {tol:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
