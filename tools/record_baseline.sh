#!/bin/sh
# Record BENCH_simd_baseline.json via the C mirror harness.
#
# (BENCH_baseline.json, recorded before the SIMD kernel tier landed, is
# kept committed as the scalar-era historical record; this script now
# writes the superseding record with the AVX2/AVX-512 lanes, the
# predicated SpMM, and the row-buffered fused pass.)
#
# The preferred recorder is the Rust one:
#
#   cargo bench --bench perf_hotpath -- --record
#
# which writes BENCH_perf_hotpath.json through
# rust/src/util/bench_record.rs. The offline builder image has no Rust
# toolchain (see tools/static_audit.sh), so this script compiles
# tools/bench_mirror.c — a C mirror of the three hot kernels with
# identical f64 op sequences and inline bit-identity oracles — and
# records the baseline from that. -ffp-contract=off is load-bearing:
# FMA contraction would break add-for-add equivalence between the
# blocked and reference paths.
set -e
cd "$(dirname "$0")/.."

CC="${CC:-cc}"
OUT="${1:-BENCH_simd_baseline.json}"
BIN="$(mktemp -t bench_mirror.XXXXXX)"
trap 'rm -f "$BIN"' EXIT

"$CC" -O2 -std=c99 -ffp-contract=off -o "$BIN" tools/bench_mirror.c -lm

GIT_REV="$(git rev-parse --short HEAD 2>/dev/null || echo unknown)"
DATE="$(date -u +%Y-%m-%dT%H:%M:%SZ)"
"$BIN" "$GIT_REV" "$DATE" > "$OUT"

# The mirror exits nonzero (and we abort above, via set -e) unless every
# blocked-vs-reference oracle held bitwise.
python3 - "$OUT" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
names = [r["name"] for r in doc["records"]]
assert len(doc["records"]) >= 3, names
print(f"wrote {sys.argv[1]}: {len(doc['records'])} records ({', '.join(names)})")
EOF
