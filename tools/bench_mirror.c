/* C mirror of the repo's hot kernels, used to record a *measured*
 * baseline in the offline builder image (which ships gcc and python
 * but no Rust toolchain — see tools/static_audit.sh for the full
 * rationale).
 *
 * Each benchmark mirrors the Rust kernel's floating-point semantics
 * exactly — same loop order, one multiply-add per (element, k) in
 * ascending k, single accumulator — so the bit-identity oracles that
 * perf_hotpath.rs asserts inline are asserted here too, on the same
 * contract:
 *
 *   1. gemm_blocked vs gemm_naive   (rust/src/linalg/dense.rs::gemm_rows
 *      vs Mat::matmul_naive; BLIS jc->pc->ic nest, packed B panel,
 *      per-element ascending-k accumulation)
 *   1b. gemm_blocked_avx2 / gemm_blocked_avx512 vs gemm_naive
 *      (rust/src/linalg/simd.rs microkernel lanes: the j loop over the
 *      packed panel runs 4- or 8-wide with explicit vmulpd+vaddpd —
 *      never FMA — so each output element still sees one mul and one
 *      add per k in ascending k, and every lane is bit-identical to
 *      the scalar kernel; lanes picked by __builtin_cpu_supports, the
 *      C twin of std::arch::is_x86_feature_detected!)
 *   2. spmm_blocked vs spmm_reference (rust/src/linalg/sparse.rs::
 *      Csr::spmm vs spmm_reference; column panels, packed panel, CSR
 *      nonzeros applied in ascending order). The pack predicate is the
 *      traffic-model one: pack only when the panel fits the tile's
 *      kc-resident B budget and the copy amortizes against the modeled
 *      naive-vs-blocked words/flop gap — measured here both where it
 *      engages (and wins) and where it falls back to the direct path.
 *   3. fused_concord_pass vs composed gradient+prox
 *      (rust/src/concord/ops.rs::gradient_block / prox_block_into; the
 *      fused sweep stages each row's gradient in an L1-resident row
 *      buffer instead of a p×p G round trip — same per-element op
 *      sequence, so it must reproduce the two-pass composition)
 *
 * Any oracle failure aborts with a nonzero exit — a baseline is only
 * written when every equivalence holds bitwise.
 *
 * Build/run: tools/record_baseline.sh (compiles with -ffp-contract=off:
 * contraction to FMA would break add-for-add equivalence with the
 * strict-IEEE Rust kernels).
 *
 * Usage: bench_mirror <git_rev> <utc_date>   (JSON on stdout)
 */

#define _POSIX_C_SOURCE 200809L /* clock_gettime under -std=c99 */

#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

#if defined(__x86_64__)
#include <immintrin.h>
#endif

#ifndef M_PI
#define M_PI 3.14159265358979323846
#endif

/* TileConfig::DEFAULT in rust/src/linalg/tile.rs */
#define MC 128
#define KC 256
#define NC 512

/* TileConfig::NAIVE_WORDS_PER_FLOP and gemm_words_per_flop() for the
 * default tile: the traffic model the SpMM pack predicate prices. */
#define NAIVE_WORDS_PER_FLOP 0.5
#define TILE_WORDS_PER_FLOP (1.0 / (2.0 * NC) + 1.0 / (2.0 * MC) + 1.0 / KC)

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

static int cmp_f64(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

/* Best-of-reps: on shared/steal-prone hosts interference only ever
 * slows a rep down, so the minimum is the least-noisy estimate of the
 * kernel's true rate (and what the >10% bench_diff gate compares). */
static double best_of(double *v, int n) {
    qsort(v, n, sizeof(double), cmp_f64);
    return v[0];
}

/* xorshift64* — any fixed deterministic stream will do here; the
 * equivalence being asserted is blocked-vs-reference on *identical*
 * inputs, not cross-language value identity. */
static uint64_t rng_state = 0xBEuLL;
static double rng_uniform(void) {
    uint64_t x = rng_state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    rng_state = x;
    return (double)((x * 0x2545F4914F6CDD1DuLL) >> 11) / 9007199254740992.0;
}
static double rng_normal(void) { /* Box–Muller, one branch of the pair */
    double u1 = rng_uniform(), u2 = rng_uniform();
    if (u1 < 1e-300) u1 = 1e-300;
    return sqrt(-2.0 * log(u1)) * cos(2.0 * M_PI * u2);
}

static int bits_equal(const double *a, const double *b, size_t n) {
    return memcmp(a, b, n * sizeof(double)) == 0;
}

/* Runtime ISA detection — the C twin of the Rust dispatcher's
 * std::arch::is_x86_feature_detected! calls. */
static int has_avx2(void) {
#if defined(__x86_64__)
    return __builtin_cpu_supports("avx2");
#else
    return 0;
#endif
}
static int has_avx512(void) {
#if defined(__x86_64__)
    return __builtin_cpu_supports("avx512f");
#else
    return 0;
#endif
}

/* --- 1. GEMM: naive reference vs blocked packed ---------------------- */

static void gemm_naive(const double *a, const double *b, double *c, int p) {
    for (int i = 0; i < p; i++)
        for (int j = 0; j < p; j++) {
            double s = 0.0;
            for (int k = 0; k < p; k++) s += a[i * p + k] * b[k * p + j];
            c[i * p + j] = s;
        }
}

/* BLIS-order nest mirroring gemm_rows: jc (NC-wide B panels) -> pc
 * (KC-deep k panels, B panel packed) -> ic (MC-high row blocks); within
 * a panel each output element accumulates ascending k, one mul-add per
 * step, partials parked in C between panels — the identical per-element
 * op sequence as the naive register accumulation, hence bit-identical. */
static void gemm_blocked(const double *a, const double *b, double *c, int p, double *bpack) {
    memset(c, 0, (size_t)p * p * sizeof(double));
    for (int jc = 0; jc < p; jc += NC) {
        int jb = (p - jc < NC) ? p - jc : NC;
        for (int pc = 0; pc < p; pc += KC) {
            int kb = (p - pc < KC) ? p - pc : KC;
            for (int k = 0; k < kb; k++)
                memcpy(bpack + (size_t)k * jb, b + (size_t)(pc + k) * p + jc,
                       (size_t)jb * sizeof(double));
            for (int ic = 0; ic < p; ic += MC) {
                int ib = (p - ic < MC) ? p - ic : MC;
                for (int i = ic; i < ic + ib; i++) {
                    double *crow = c + (size_t)i * p + jc;
                    for (int k = 0; k < kb; k++) {
                        double aik = a[(size_t)i * p + pc + k];
                        const double *brow = bpack + (size_t)k * jb;
                        for (int j = 0; j < jb; j++) crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

#if defined(__x86_64__)

/* SIMD microkernel lanes — C twins of rust/src/linalg/simd.rs.
 *
 * Structure: the same jc->pc->ic panel nest, but inside a panel a 4-row
 * MR slab accumulates an 8-wide NR sliver in registers across the whole
 * kb sweep (partials loaded from / parked back into C, exactly like the
 * Rust micro_full). The vectorization is across the 8 *independent*
 * output columns, and every step is an explicit mul intrinsic followed
 * by an add intrinsic (vmulpd+vaddpd, never vfmadd): per output element
 * that is still one multiply and one add per k, in ascending k — the
 * identical op sequence as the scalar kernel, hence bit-identical.
 * Ragged row/column tails fall back to the scalar order. */

__attribute__((target("avx2"))) static void gemm_blocked_avx2(const double *a, const double *b,
                                                              double *c, int p, double *bpack) {
    memset(c, 0, (size_t)p * p * sizeof(double));
    for (int jc = 0; jc < p; jc += NC) {
        int jb = (p - jc < NC) ? p - jc : NC;
        for (int pc = 0; pc < p; pc += KC) {
            int kb = (p - pc < KC) ? p - pc : KC;
            for (int k = 0; k < kb; k++)
                memcpy(bpack + (size_t)k * jb, b + (size_t)(pc + k) * p + jc,
                       (size_t)jb * sizeof(double));
            for (int ic = 0; ic < p; ic += MC) {
                int ib = (p - ic < MC) ? p - ic : MC;
                int iend4 = ic + (ib / 4) * 4;
                int jend8 = (jb / 8) * 8;
                for (int i = ic; i < iend4; i += 4) {
                    const double *a0 = a + (size_t)i * p + pc;
                    const double *a1 = a0 + p, *a2 = a1 + p, *a3 = a2 + p;
                    double *c0 = c + (size_t)i * p + jc;
                    double *c1 = c0 + p, *c2 = c1 + p, *c3 = c2 + p;
                    for (int j = 0; j < jend8; j += 8) {
                        __m256d s00 = _mm256_loadu_pd(c0 + j), s01 = _mm256_loadu_pd(c0 + j + 4);
                        __m256d s10 = _mm256_loadu_pd(c1 + j), s11 = _mm256_loadu_pd(c1 + j + 4);
                        __m256d s20 = _mm256_loadu_pd(c2 + j), s21 = _mm256_loadu_pd(c2 + j + 4);
                        __m256d s30 = _mm256_loadu_pd(c3 + j), s31 = _mm256_loadu_pd(c3 + j + 4);
                        for (int k = 0; k < kb; k++) {
                            const double *brow = bpack + (size_t)k * jb + j;
                            __m256d b0 = _mm256_loadu_pd(brow);
                            __m256d b1 = _mm256_loadu_pd(brow + 4);
                            __m256d av;
                            av = _mm256_set1_pd(a0[k]);
                            s00 = _mm256_add_pd(s00, _mm256_mul_pd(av, b0));
                            s01 = _mm256_add_pd(s01, _mm256_mul_pd(av, b1));
                            av = _mm256_set1_pd(a1[k]);
                            s10 = _mm256_add_pd(s10, _mm256_mul_pd(av, b0));
                            s11 = _mm256_add_pd(s11, _mm256_mul_pd(av, b1));
                            av = _mm256_set1_pd(a2[k]);
                            s20 = _mm256_add_pd(s20, _mm256_mul_pd(av, b0));
                            s21 = _mm256_add_pd(s21, _mm256_mul_pd(av, b1));
                            av = _mm256_set1_pd(a3[k]);
                            s30 = _mm256_add_pd(s30, _mm256_mul_pd(av, b0));
                            s31 = _mm256_add_pd(s31, _mm256_mul_pd(av, b1));
                        }
                        _mm256_storeu_pd(c0 + j, s00);
                        _mm256_storeu_pd(c0 + j + 4, s01);
                        _mm256_storeu_pd(c1 + j, s10);
                        _mm256_storeu_pd(c1 + j + 4, s11);
                        _mm256_storeu_pd(c2 + j, s20);
                        _mm256_storeu_pd(c2 + j + 4, s21);
                        _mm256_storeu_pd(c3 + j, s30);
                        _mm256_storeu_pd(c3 + j + 4, s31);
                    }
                    for (int j = jend8; j < jb; j++) {
                        double s0 = c0[j], s1 = c1[j], s2 = c2[j], s3 = c3[j];
                        for (int k = 0; k < kb; k++) {
                            double bv = bpack[(size_t)k * jb + j];
                            s0 += a0[k] * bv;
                            s1 += a1[k] * bv;
                            s2 += a2[k] * bv;
                            s3 += a3[k] * bv;
                        }
                        c0[j] = s0;
                        c1[j] = s1;
                        c2[j] = s2;
                        c3[j] = s3;
                    }
                }
                for (int i = iend4; i < ic + ib; i++) {
                    double *crow = c + (size_t)i * p + jc;
                    for (int k = 0; k < kb; k++) {
                        double aik = a[(size_t)i * p + pc + k];
                        const double *brow = bpack + (size_t)k * jb;
                        for (int j = 0; j < jb; j++) crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

__attribute__((target("avx512f"))) static void gemm_blocked_avx512(const double *a,
                                                                   const double *b, double *c,
                                                                   int p, double *bpack) {
    memset(c, 0, (size_t)p * p * sizeof(double));
    for (int jc = 0; jc < p; jc += NC) {
        int jb = (p - jc < NC) ? p - jc : NC;
        for (int pc = 0; pc < p; pc += KC) {
            int kb = (p - pc < KC) ? p - pc : KC;
            for (int k = 0; k < kb; k++)
                memcpy(bpack + (size_t)k * jb, b + (size_t)(pc + k) * p + jc,
                       (size_t)jb * sizeof(double));
            for (int ic = 0; ic < p; ic += MC) {
                int ib = (p - ic < MC) ? p - ic : MC;
                int iend4 = ic + (ib / 4) * 4;
                int jend8 = (jb / 8) * 8;
                for (int i = ic; i < iend4; i += 4) {
                    const double *a0 = a + (size_t)i * p + pc;
                    const double *a1 = a0 + p, *a2 = a1 + p, *a3 = a2 + p;
                    double *c0 = c + (size_t)i * p + jc;
                    double *c1 = c0 + p, *c2 = c1 + p, *c3 = c2 + p;
                    for (int j = 0; j < jend8; j += 8) {
                        __m512d s0 = _mm512_loadu_pd(c0 + j);
                        __m512d s1 = _mm512_loadu_pd(c1 + j);
                        __m512d s2 = _mm512_loadu_pd(c2 + j);
                        __m512d s3 = _mm512_loadu_pd(c3 + j);
                        for (int k = 0; k < kb; k++) {
                            __m512d bv = _mm512_loadu_pd(bpack + (size_t)k * jb + j);
                            s0 = _mm512_add_pd(s0, _mm512_mul_pd(_mm512_set1_pd(a0[k]), bv));
                            s1 = _mm512_add_pd(s1, _mm512_mul_pd(_mm512_set1_pd(a1[k]), bv));
                            s2 = _mm512_add_pd(s2, _mm512_mul_pd(_mm512_set1_pd(a2[k]), bv));
                            s3 = _mm512_add_pd(s3, _mm512_mul_pd(_mm512_set1_pd(a3[k]), bv));
                        }
                        _mm512_storeu_pd(c0 + j, s0);
                        _mm512_storeu_pd(c1 + j, s1);
                        _mm512_storeu_pd(c2 + j, s2);
                        _mm512_storeu_pd(c3 + j, s3);
                    }
                    for (int j = jend8; j < jb; j++) {
                        double s0 = c0[j], s1 = c1[j], s2 = c2[j], s3 = c3[j];
                        for (int k = 0; k < kb; k++) {
                            double bv = bpack[(size_t)k * jb + j];
                            s0 += a0[k] * bv;
                            s1 += a1[k] * bv;
                            s2 += a2[k] * bv;
                            s3 += a3[k] * bv;
                        }
                        c0[j] = s0;
                        c1[j] = s1;
                        c2[j] = s2;
                        c3[j] = s3;
                    }
                }
                for (int i = iend4; i < ic + ib; i++) {
                    double *crow = c + (size_t)i * p + jc;
                    for (int k = 0; k < kb; k++) {
                        double aik = a[(size_t)i * p + pc + k];
                        const double *brow = bpack + (size_t)k * jb;
                        for (int j = 0; j < jb; j++) crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

#endif /* __x86_64__ */

/* --- 2. SpMM: row-at-a-time reference vs column-blocked -------------- */

typedef struct {
    int rows, cols, nnz;
    int *indptr;  /* rows + 1 */
    int *indices; /* ascending within each row, as Csr::from_dense */
    double *values;
} Csr;

static Csr csr_random(int p, double density) {
    Csr m;
    m.rows = m.cols = p;
    m.indptr = malloc((p + 1) * sizeof(int));
    int cap = (int)(p * p * density * 1.5) + p + 16;
    m.indices = malloc(cap * sizeof(int));
    m.values = malloc(cap * sizeof(double));
    int nnz = 0;
    for (int i = 0; i < p; i++) {
        m.indptr[i] = nnz;
        for (int j = 0; j < p; j++) {
            double v = (i == j) ? 2.0 : (rng_uniform() < density ? rng_normal() : 0.0);
            if (v != 0.0) {
                if (nnz == cap) {
                    cap *= 2;
                    m.indices = realloc(m.indices, cap * sizeof(int));
                    m.values = realloc(m.values, cap * sizeof(double));
                }
                m.indices[nnz] = j;
                m.values[nnz] = v;
                nnz++;
            }
        }
    }
    m.indptr[p] = nnz;
    m.nnz = nnz;
    return m;
}

static void spmm_reference(const Csr *a, const double *b, double *c, int n) {
    memset(c, 0, (size_t)a->rows * n * sizeof(double));
    for (int i = 0; i < a->rows; i++) {
        double *crow = c + (size_t)i * n;
        for (int t = a->indptr[i]; t < a->indptr[i + 1]; t++) {
            double av = a->values[t];
            const double *brow = b + (size_t)a->indices[t] * n;
            for (int j = 0; j < n; j++) crow[j] += av * brow[j];
        }
    }
}

/* Traffic-model pack predicate, mirroring Csr::spmm_mt_with: pack a
 * column panel only when (a) the output is wider than one panel, (b)
 * the packed b->rows × NC panel fits the tile's kc-resident B budget —
 * the residency gemm_words_per_flop assumes; a bigger panel is
 * re-streamed from slow memory and the copy is pure overhead — and (c)
 * the copy (rows·jb words) amortizes against the modeled traffic gap
 * between the naive stream and the blocked schedule across the panel's
 * 2·nnz·jb flops. Either path is bitwise identical; the predicate only
 * picks the faster one. */
static int spmm_should_pack(const Csr *a, int b_rows, int n) {
    double gap = NAIVE_WORDS_PER_FLOP - TILE_WORDS_PER_FLOP;
    return n > NC && b_rows <= KC && (double)b_rows <= 2.0 * (double)a->nnz * gap;
}

/* Column-blocked packed path of Csr::spmm_mt_with (serial): NC-wide
 * panels of B packed contiguous, nonzeros applied in ascending CSR
 * order per panel — per element the same ascending-k op sequence as
 * reference. */
static void spmm_packed(const Csr *a, const double *b, double *c, int n, double *bpack) {
    memset(c, 0, (size_t)a->rows * n * sizeof(double));
    for (int jc = 0; jc < n; jc += NC) {
        int jb = (n - jc < NC) ? n - jc : NC;
        for (int k = 0; k < a->cols; k++)
            memcpy(bpack + (size_t)k * jb, b + (size_t)k * n + jc, (size_t)jb * sizeof(double));
        for (int i = 0; i < a->rows; i++) {
            double *crow = c + (size_t)i * n + jc;
            for (int t = a->indptr[i]; t < a->indptr[i + 1]; t++) {
                double av = a->values[t];
                const double *brow = bpack + (size_t)a->indices[t] * jb;
                for (int j = 0; j < jb; j++) crow[j] += av * brow[j];
            }
        }
    }
}

/* The predicated kernel the Rust spmm_mt_with now is: the traffic
 * model picks the packed or the direct path. */
static void spmm_blocked(const Csr *a, const double *b, double *c, int n, double *bpack) {
    if (spmm_should_pack(a, a->cols, n))
        spmm_packed(a, b, c, n, bpack);
    else
        spmm_reference(a, b, c, n);
}

/* --- 3. fused CONCORD gradient+prox pass ----------------------------- */

static double soft(double z, double a) {
    if (z > a) return z - a;
    if (z < -a) return z + a;
    return 0.0;
}

/* Composed reference: gradient_block into G, then prox_block_into. */
static void concord_composed(const double *omega, const double *w, const double *wt, double *g,
                             double *out, int p, double lam1, double lam2, double tau) {
    double thresh = tau * lam1;
    for (int i = 0; i < p; i++) {
        const double *orow = omega + (size_t)i * p;
        double *grow = g + (size_t)i * p;
        for (int j = 0; j < p; j++)
            grow[j] = 0.5 * (w[(size_t)i * p + j] + wt[(size_t)i * p + j]) + lam2 * orow[j];
        grow[i] -= 1.0 / orow[i];
    }
    for (int i = 0; i < p; i++) {
        const double *orow = omega + (size_t)i * p;
        const double *grow = g + (size_t)i * p;
        double *dst = out + (size_t)i * p;
        for (int j = 0; j < p; j++) dst[j] = soft(orow[j] - tau * grow[j], thresh);
        dst[i] = orow[i] - tau * grow[i];
    }
}

/* Fused sweep, row-buffered: each row's gradient is staged in `gbuf`
 * (p doubles, L1-resident) instead of a p×p G matrix round trip, then
 * the prox applies from the hot buffer. The two inner loops are
 * composed's loops verbatim — same per-element op sequence — so the
 * result is bitwise identical; only the G traffic is gone. (The
 * earlier fused form interleaved the branchy soft() with the gradient
 * math per element, which both defeated vectorization of the gradient
 * arithmetic and still measured *slower* than composed — see
 * BENCH_baseline.json.) */
static void concord_fused(const double *omega, const double *w, const double *wt, double *out,
                          double *gbuf, int p, double lam1, double lam2, double tau) {
    double thresh = tau * lam1;
    for (int i = 0; i < p; i++) {
        const double *orow = omega + (size_t)i * p;
        double *dst = out + (size_t)i * p;
        for (int j = 0; j < p; j++)
            gbuf[j] = 0.5 * (w[(size_t)i * p + j] + wt[(size_t)i * p + j]) + lam2 * orow[j];
        gbuf[i] -= 1.0 / orow[i];
        for (int j = 0; j < p; j++) dst[j] = soft(orow[j] - tau * gbuf[j], thresh);
        dst[i] = orow[i] - tau * gbuf[i];
    }
}

/* --- harness --------------------------------------------------------- */

static int first_record = 1;
static void emit(const char *name, const char *shape, int threads, const char *tile,
                 double gflops, double wall_s, int reps, const char *oracle) {
    printf("%s    {\"name\": \"%s\", \"shape\": \"%s\", \"threads\": %d, \"tile\": \"%s\", "
           "\"gflops\": %.4f, \"wall_s\": %.6f, \"reps\": %d, \"oracle\": \"%s\"}",
           first_record ? "" : ",\n", name, shape, threads, tile, gflops, wall_s, reps, oracle);
    first_record = 0;
}

static double *rand_mat(int r, int c) {
    double *m = malloc((size_t)r * c * sizeof(double));
    for (size_t i = 0; i < (size_t)r * c; i++) m[i] = rng_normal();
    return m;
}

typedef void (*GemmFn)(const double *, const double *, double *, int, double *);

static double time_gemm(GemmFn f, const double *a, const double *b, double *c, int p,
                        double *bpack, int reps) {
    double t[16], t0;
    for (int r = 0; r < reps; r++) {
        t0 = now_s();
        f(a, b, c, p, bpack);
        t[r] = now_s() - t0;
    }
    return best_of(t, reps);
}

int main(int argc, char **argv) {
    const char *git_rev = argc > 1 ? argv[1] : "unknown";
    const char *date = argc > 2 ? argv[2] : "unknown";
    /* Best-of-15: interference on shared hosts only slows reps down,
     * so more reps tighten the minimum toward the true rate (the
     * sub-50ms SpMM/fused records jitter ~5% at best-of-5). */
    const int reps = 15;
    double t[16], t0;
    char shape[64];
    long cpus = sysconf(_SC_NPROCESSORS_ONLN);

    printf("{\n  \"bench\": \"simd_baseline\",\n  \"git_rev\": \"%s\",\n  \"date\": \"%s\",\n",
           git_rev, date);
    printf("  \"harness\": \"tools/bench_mirror.c — C mirror of the Rust kernels (same loop "
           "order and f64 op sequence, -ffp-contract=off), measured in the offline builder "
           "image; no Rust toolchain is available there, see tools/static_audit.sh\",\n");
    printf("  \"host\": {\n    \"os\": \"linux\",\n    \"arch\": \"%s\",\n    \"cpus\": %ld,\n"
           "    \"simd\": \"%s%s%s\"\n  },\n  \"records\": [\n",
#if defined(__x86_64__)
           "x86_64",
#elif defined(__aarch64__)
           "aarch64",
#else
           "unknown",
#endif
           cpus > 0 ? cpus : 1, "scalar", has_avx2() ? " avx2" : "",
           has_avx512() ? " avx512f" : "");

    /* 1. GEMM lanes vs naive, p = 512. */
    {
        int p = 512;
        double flops = 2.0 * (double)p * p * p;
        double *a = rand_mat(p, p), *b = rand_mat(p, p);
        double *cn = malloc((size_t)p * p * sizeof(double));
        double *cb = malloc((size_t)p * p * sizeof(double));
        double *bpack = malloc((size_t)KC * NC * sizeof(double));
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            gemm_naive(a, b, cn, p);
            t[r] = now_s() - t0;
        }
        double naive_s = best_of(t, reps);
        double blk_s = time_gemm(gemm_blocked, a, b, cb, p, bpack, reps);
        if (!bits_equal(cn, cb, (size_t)p * p)) {
            fprintf(stderr, "FATAL: blocked GEMM != naive bitwise at p=%d\n", p);
            return 1;
        }
        snprintf(shape, sizeof shape, "p=%d", p);
        emit("gemm_naive", shape, 1, "-", flops / naive_s / 1e9, naive_s, reps, "");
        emit("gemm_blocked", shape, 1, "128,256,512", flops / blk_s / 1e9, blk_s, reps,
             "bitwise == gemm_naive (asserted this run)");
#if defined(__x86_64__)
        if (has_avx2()) {
            double s = time_gemm(gemm_blocked_avx2, a, b, cb, p, bpack, reps);
            if (!bits_equal(cn, cb, (size_t)p * p)) {
                fprintf(stderr, "FATAL: AVX2 GEMM != naive bitwise at p=%d\n", p);
                return 1;
            }
            emit("gemm_blocked_avx2", shape, 1, "128,256,512", flops / s / 1e9, s, reps,
                 "bitwise == gemm_naive (asserted this run; vmulpd+vaddpd, no FMA)");
        }
        if (has_avx512()) {
            double s = time_gemm(gemm_blocked_avx512, a, b, cb, p, bpack, reps);
            if (!bits_equal(cn, cb, (size_t)p * p)) {
                fprintf(stderr, "FATAL: AVX-512 GEMM != naive bitwise at p=%d\n", p);
                return 1;
            }
            emit("gemm_blocked_avx512", shape, 1, "128,256,512", flops / s / 1e9, s, reps,
                 "bitwise == gemm_naive (asserted this run; vmulpd+vaddpd, no FMA)");
        }
        /* The dispatched lane (__builtin_cpu_supports, best available)
         * — what the Rust side's --kernel auto resolves to. */
        {
            GemmFn best = has_avx512() ? gemm_blocked_avx512
                          : has_avx2() ? gemm_blocked_avx2
                                       : gemm_blocked;
            const char *lane = has_avx512() ? "avx512" : has_avx2() ? "avx2" : "scalar";
            double s = time_gemm(best, a, b, cb, p, bpack, reps);
            if (!bits_equal(cn, cb, (size_t)p * p)) {
                fprintf(stderr, "FATAL: dispatched GEMM != naive bitwise at p=%d\n", p);
                return 1;
            }
            char oracle[96];
            snprintf(oracle, sizeof oracle,
                     "dispatch picked %s; bitwise == gemm_naive (asserted this run)", lane);
            emit("gemm_kernel_auto", shape, 1, "128,256,512", flops / s / 1e9, s, reps, oracle);
        }
#endif
        free(a);
        free(b);
        free(cn);
        free(cb);
        free(bpack);
    }

    /* 2a. SpMM where the traffic model says pack: short B (rows <= KC,
     * panel resident) and a wide output (n >> NC, so the direct path
     * re-streams a crow far beyond L1 per nonzero). */
    {
        int rows = 128, n = 8192;
        double density = 0.5;
        Csr m = csr_random(rows, density);
        double *b = rand_mat(rows, n);
        double *cr = malloc((size_t)rows * n * sizeof(double));
        double *cb = malloc((size_t)rows * n * sizeof(double));
        double *bpack = malloc((size_t)rows * NC * sizeof(double));
        double flops = 2.0 * (double)m.nnz * n;
        if (!spmm_should_pack(&m, rows, n)) {
            fprintf(stderr, "FATAL: pack predicate refused the pack-profitable shape\n");
            return 1;
        }
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            spmm_reference(&m, b, cr, n);
            t[r] = now_s() - t0;
        }
        double ref_s = best_of(t, reps);
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            spmm_blocked(&m, b, cb, n, bpack);
            t[r] = now_s() - t0;
        }
        double blk_s = best_of(t, reps);
        if (!bits_equal(cr, cb, (size_t)rows * n)) {
            fprintf(stderr, "FATAL: blocked SpMM != reference bitwise (packed path)\n");
            return 1;
        }
        snprintf(shape, sizeof shape, "rows=%d n=%d density=%.2f", rows, n, density);
        emit("spmm_reference", shape, 1, "-", flops / ref_s / 1e9, ref_s, reps, "");
        emit("spmm_blocked", shape, 1, "128,256,512", flops / blk_s / 1e9, blk_s, reps,
             "bitwise == spmm_reference (asserted this run; predicate packed)");
        free(m.indptr);
        free(m.indices);
        free(m.values);
        free(b);
        free(cr);
        free(cb);
        free(bpack);
    }

    /* 2b. The old square shape (p=1024, d=0.02) where packing measured
     * *slower* than reference in BENCH_baseline.json: the predicate now
     * prices the 1024-row panel over the kc=256 residency budget and
     * takes the direct path, so the regression is gone by construction
     * — recorded to pin that the fallback costs nothing. */
    {
        int p = 1024;
        double density = 0.02;
        Csr m = csr_random(p, density);
        double *b = rand_mat(p, p);
        double *cr = malloc((size_t)p * p * sizeof(double));
        double *cb = malloc((size_t)p * p * sizeof(double));
        double *bpack = malloc((size_t)p * NC * sizeof(double));
        double flops = 2.0 * (double)m.nnz * p;
        if (spmm_should_pack(&m, p, p)) {
            fprintf(stderr, "FATAL: pack predicate packed the regression shape\n");
            return 1;
        }
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            spmm_reference(&m, b, cr, p);
            t[r] = now_s() - t0;
        }
        double ref_s = best_of(t, reps);
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            spmm_blocked(&m, b, cb, p, bpack);
            t[r] = now_s() - t0;
        }
        double blk_s = best_of(t, reps);
        if (!bits_equal(cr, cb, (size_t)p * p)) {
            fprintf(stderr, "FATAL: blocked SpMM != reference bitwise (direct path)\n");
            return 1;
        }
        snprintf(shape, sizeof shape, "p=%d density=%.2f", p, density);
        emit("spmm_reference_square", shape, 1, "-", flops / ref_s / 1e9, ref_s, reps, "");
        emit("spmm_auto_square", shape, 1, "128,256,512", flops / blk_s / 1e9, blk_s, reps,
             "bitwise == spmm_reference_square (asserted this run; predicate took direct path)");
        free(m.indptr);
        free(m.indices);
        free(m.values);
        free(b);
        free(cr);
        free(cb);
        free(bpack);
    }

    /* 3. Fused CONCORD gradient+prox pass vs composed, p = 512. */
    {
        int p = 512;
        double *omega = rand_mat(p, p);
        /* Symmetrize and set a strictly positive diagonal, as the
         * solver's iterates have (1/omega_ii must be finite). */
        for (int i = 0; i < p; i++) {
            for (int j = i + 1; j < p; j++) {
                double v = 0.5 * (omega[(size_t)i * p + j] + omega[(size_t)j * p + i]);
                omega[(size_t)i * p + j] = v;
                omega[(size_t)j * p + i] = v;
            }
            omega[(size_t)i * p + i] = 2.0 + rng_uniform();
        }
        double *w = rand_mat(p, p);
        double *wt = malloc((size_t)p * p * sizeof(double));
        for (int i = 0; i < p; i++)
            for (int j = 0; j < p; j++) wt[(size_t)i * p + j] = w[(size_t)j * p + i];
        double *g = malloc((size_t)p * p * sizeof(double));
        double *gbuf = malloc((size_t)p * sizeof(double));
        double *oc = malloc((size_t)p * p * sizeof(double));
        double *of = malloc((size_t)p * p * sizeof(double));
        double lam1 = 0.3, lam2 = 0.1, tau = 0.5;
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            concord_composed(omega, w, wt, g, oc, p, lam1, lam2, tau);
            t[r] = now_s() - t0;
        }
        double comp_s = best_of(t, reps);
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            concord_fused(omega, w, wt, of, gbuf, p, lam1, lam2, tau);
            t[r] = now_s() - t0;
        }
        double fused_s = best_of(t, reps);
        if (!bits_equal(oc, of, (size_t)p * p)) {
            fprintf(stderr, "FATAL: fused CONCORD pass != composed bitwise at p=%d\n", p);
            return 1;
        }
        /* ~7 flops/element: gradient (3) + prox threshold chain (~4). */
        double flops = 7.0 * (double)p * p;
        snprintf(shape, sizeof shape, "p=%d", p);
        emit("concord_gradient_prox_composed", shape, 1, "-", flops / comp_s / 1e9, comp_s,
             reps, "");
        emit("fused_concord_pass", shape, 1, "-", flops / fused_s / 1e9, fused_s, reps,
             "bitwise == concord_gradient_prox_composed (asserted this run; row-buffered)");
        free(omega);
        free(w);
        free(wt);
        free(g);
        free(gbuf);
        free(oc);
        free(of);
    }

    printf("\n  ]\n}\n");
    return 0;
}
