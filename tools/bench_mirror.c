/* C mirror of the repo's three hot kernels, used to record a *measured*
 * BENCH_baseline.json in the offline builder image (which ships gcc and
 * python but no Rust toolchain — see tools/static_audit.sh for the full
 * rationale).
 *
 * Each benchmark mirrors the Rust kernel's floating-point semantics
 * exactly — same loop order, one multiply-add per (element, k) in
 * ascending k, single accumulator — so the bit-identity oracles that
 * perf_hotpath.rs asserts inline are asserted here too, on the same
 * contract:
 *
 *   1. gemm_blocked vs gemm_naive   (rust/src/linalg/dense.rs::gemm_rows
 *      vs Mat::matmul_naive; BLIS jc->pc->ic nest, packed B panel,
 *      per-element ascending-k accumulation)
 *   2. spmm_blocked vs spmm_reference (rust/src/linalg/sparse.rs::
 *      Csr::spmm vs spmm_reference; column panels, packed panel, CSR
 *      nonzeros applied in ascending order)
 *   3. fused_concord_pass vs composed gradient+prox
 *      (rust/src/concord/ops.rs::gradient_block / prox_block_into; the
 *      fused single sweep must reproduce the two-pass composition)
 *
 * Any oracle failure aborts with a nonzero exit — a baseline is only
 * written when every equivalence holds bitwise.
 *
 * Build/run: tools/record_baseline.sh (compiles with -ffp-contract=off:
 * contraction to FMA would break add-for-add equivalence with the
 * strict-IEEE Rust kernels).
 *
 * Usage: bench_mirror <git_rev> <utc_date>   (JSON on stdout)
 */

#define _POSIX_C_SOURCE 200809L /* clock_gettime under -std=c99 */

#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

#ifndef M_PI
#define M_PI 3.14159265358979323846
#endif

/* TileConfig::DEFAULT in rust/src/linalg/tile.rs */
#define MC 128
#define KC 256
#define NC 512

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

static int cmp_f64(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static double median(double *v, int n) {
    qsort(v, n, sizeof(double), cmp_f64);
    return (n % 2) ? v[n / 2] : 0.5 * (v[n / 2 - 1] + v[n / 2]);
}

/* xorshift64* — any fixed deterministic stream will do here; the
 * equivalence being asserted is blocked-vs-reference on *identical*
 * inputs, not cross-language value identity. */
static uint64_t rng_state = 0xBEuLL;
static double rng_uniform(void) {
    uint64_t x = rng_state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    rng_state = x;
    return (double)((x * 0x2545F4914F6CDD1DuLL) >> 11) / 9007199254740992.0;
}
static double rng_normal(void) { /* Box–Muller, one branch of the pair */
    double u1 = rng_uniform(), u2 = rng_uniform();
    if (u1 < 1e-300) u1 = 1e-300;
    return sqrt(-2.0 * log(u1)) * cos(2.0 * M_PI * u2);
}

static int bits_equal(const double *a, const double *b, size_t n) {
    return memcmp(a, b, n * sizeof(double)) == 0;
}

/* --- 1. GEMM: naive reference vs blocked packed ---------------------- */

static void gemm_naive(const double *a, const double *b, double *c, int p) {
    for (int i = 0; i < p; i++)
        for (int j = 0; j < p; j++) {
            double s = 0.0;
            for (int k = 0; k < p; k++) s += a[i * p + k] * b[k * p + j];
            c[i * p + j] = s;
        }
}

/* BLIS-order nest mirroring gemm_rows: jc (NC-wide B panels) -> pc
 * (KC-deep k panels, B panel packed) -> ic (MC-high row blocks); within
 * a panel each output element accumulates ascending k, one mul-add per
 * step, partials parked in C between panels — the identical per-element
 * op sequence as the naive register accumulation, hence bit-identical. */
static void gemm_blocked(const double *a, const double *b, double *c, int p, double *bpack) {
    memset(c, 0, (size_t)p * p * sizeof(double));
    for (int jc = 0; jc < p; jc += NC) {
        int jb = (p - jc < NC) ? p - jc : NC;
        for (int pc = 0; pc < p; pc += KC) {
            int kb = (p - pc < KC) ? p - pc : KC;
            for (int k = 0; k < kb; k++)
                memcpy(bpack + (size_t)k * jb, b + (size_t)(pc + k) * p + jc,
                       (size_t)jb * sizeof(double));
            for (int ic = 0; ic < p; ic += MC) {
                int ib = (p - ic < MC) ? p - ic : MC;
                for (int i = ic; i < ic + ib; i++) {
                    double *crow = c + (size_t)i * p + jc;
                    for (int k = 0; k < kb; k++) {
                        double aik = a[(size_t)i * p + pc + k];
                        const double *brow = bpack + (size_t)k * jb;
                        for (int j = 0; j < jb; j++) crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

/* --- 2. SpMM: row-at-a-time reference vs column-blocked -------------- */

typedef struct {
    int rows, cols, nnz;
    int *indptr;  /* rows + 1 */
    int *indices; /* ascending within each row, as Csr::from_dense */
    double *values;
} Csr;

static Csr csr_random(int p, double density) {
    Csr m;
    m.rows = m.cols = p;
    m.indptr = malloc((p + 1) * sizeof(int));
    int cap = (int)(p * p * density * 1.5) + p + 16;
    m.indices = malloc(cap * sizeof(int));
    m.values = malloc(cap * sizeof(double));
    int nnz = 0;
    for (int i = 0; i < p; i++) {
        m.indptr[i] = nnz;
        for (int j = 0; j < p; j++) {
            double v = (i == j) ? 2.0 : (rng_uniform() < density ? rng_normal() : 0.0);
            if (v != 0.0) {
                if (nnz == cap) {
                    cap *= 2;
                    m.indices = realloc(m.indices, cap * sizeof(int));
                    m.values = realloc(m.values, cap * sizeof(double));
                }
                m.indices[nnz] = j;
                m.values[nnz] = v;
                nnz++;
            }
        }
    }
    m.indptr[p] = nnz;
    m.nnz = nnz;
    return m;
}

static void spmm_reference(const Csr *a, const double *b, double *c, int n) {
    memset(c, 0, (size_t)a->rows * n * sizeof(double));
    for (int i = 0; i < a->rows; i++) {
        double *crow = c + (size_t)i * n;
        for (int t = a->indptr[i]; t < a->indptr[i + 1]; t++) {
            double av = a->values[t];
            const double *brow = b + (size_t)a->indices[t] * n;
            for (int j = 0; j < n; j++) crow[j] += av * brow[j];
        }
    }
}

/* Column-blocked mirror of Csr::spmm_mt_with (serial): NC-wide panels
 * of B packed contiguous, nonzeros applied in ascending CSR order per
 * panel — per element the same ascending-k op sequence as reference. */
static void spmm_blocked(const Csr *a, const double *b, double *c, int n, double *bpack) {
    memset(c, 0, (size_t)a->rows * n * sizeof(double));
    for (int jc = 0; jc < n; jc += NC) {
        int jb = (n - jc < NC) ? n - jc : NC;
        for (int k = 0; k < a->cols; k++)
            memcpy(bpack + (size_t)k * jb, b + (size_t)k * n + jc, (size_t)jb * sizeof(double));
        for (int i = 0; i < a->rows; i++) {
            double *crow = c + (size_t)i * n + jc;
            for (int t = a->indptr[i]; t < a->indptr[i + 1]; t++) {
                double av = a->values[t];
                const double *brow = bpack + (size_t)a->indices[t] * jb;
                for (int j = 0; j < jb; j++) crow[j] += av * brow[j];
            }
        }
    }
}

/* --- 3. fused CONCORD gradient+prox pass ----------------------------- */

static double soft(double z, double a) {
    if (z > a) return z - a;
    if (z < -a) return z + a;
    return 0.0;
}

/* Composed reference: gradient_block into G, then prox_block_into. */
static void concord_composed(const double *omega, const double *w, const double *wt, double *g,
                             double *out, int p, double lam1, double lam2, double tau) {
    double thresh = tau * lam1;
    for (int i = 0; i < p; i++) {
        const double *orow = omega + (size_t)i * p;
        double *grow = g + (size_t)i * p;
        for (int j = 0; j < p; j++)
            grow[j] = 0.5 * (w[(size_t)i * p + j] + wt[(size_t)i * p + j]) + lam2 * orow[j];
        grow[i] -= 1.0 / orow[i];
    }
    for (int i = 0; i < p; i++) {
        const double *orow = omega + (size_t)i * p;
        const double *grow = g + (size_t)i * p;
        double *dst = out + (size_t)i * p;
        for (int j = 0; j < p; j++) dst[j] = soft(orow[j] - tau * grow[j], thresh);
        dst[i] = orow[i] - tau * grow[i];
    }
}

/* Fused single sweep: same per-element op sequence, no G round trip. */
static void concord_fused(const double *omega, const double *w, const double *wt, double *out,
                          int p, double lam1, double lam2, double tau) {
    double thresh = tau * lam1;
    for (int i = 0; i < p; i++) {
        const double *orow = omega + (size_t)i * p;
        double *dst = out + (size_t)i * p;
        for (int j = 0; j < p; j++) {
            double gij = 0.5 * (w[(size_t)i * p + j] + wt[(size_t)i * p + j]) + lam2 * orow[j];
            dst[j] = soft(orow[j] - tau * gij, thresh);
        }
        double gii = 0.5 * (w[(size_t)i * p + i] + wt[(size_t)i * p + i]) + lam2 * orow[i]
                     - 1.0 / orow[i];
        dst[i] = orow[i] - tau * gii;
    }
}

/* --- harness --------------------------------------------------------- */

static int first_record = 1;
static void emit(const char *name, const char *shape, int threads, const char *tile,
                 double gflops, double wall_s, int reps, const char *oracle) {
    printf("%s    {\"name\": \"%s\", \"shape\": \"%s\", \"threads\": %d, \"tile\": \"%s\", "
           "\"gflops\": %.4f, \"wall_s\": %.6f, \"reps\": %d, \"oracle\": \"%s\"}",
           first_record ? "" : ",\n", name, shape, threads, tile, gflops, wall_s, reps, oracle);
    first_record = 0;
}

static double *rand_mat(int r, int c) {
    double *m = malloc((size_t)r * c * sizeof(double));
    for (size_t i = 0; i < (size_t)r * c; i++) m[i] = rng_normal();
    return m;
}

int main(int argc, char **argv) {
    const char *git_rev = argc > 1 ? argv[1] : "unknown";
    const char *date = argc > 2 ? argv[2] : "unknown";
    const int reps = 5;
    double t[16], t0;
    char shape[64];
    long cpus = sysconf(_SC_NPROCESSORS_ONLN);

    printf("{\n  \"bench\": \"baseline\",\n  \"git_rev\": \"%s\",\n  \"date\": \"%s\",\n",
           git_rev, date);
    printf("  \"harness\": \"tools/bench_mirror.c — C mirror of the Rust kernels (same loop "
           "order and f64 op sequence, -ffp-contract=off), measured in the offline builder "
           "image; no Rust toolchain is available there, see tools/static_audit.sh\",\n");
    printf("  \"host\": {\n    \"os\": \"linux\",\n    \"arch\": \"%s\",\n    \"cpus\": %ld\n"
           "  },\n  \"records\": [\n",
#if defined(__x86_64__)
           "x86_64",
#elif defined(__aarch64__)
           "aarch64",
#else
           "unknown",
#endif
           cpus > 0 ? cpus : 1);

    /* 1. GEMM blocked vs naive, p = 512. */
    {
        int p = 512;
        double flops = 2.0 * (double)p * p * p;
        double *a = rand_mat(p, p), *b = rand_mat(p, p);
        double *cn = malloc((size_t)p * p * sizeof(double));
        double *cb = malloc((size_t)p * p * sizeof(double));
        double *bpack = malloc((size_t)KC * NC * sizeof(double));
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            gemm_naive(a, b, cn, p);
            t[r] = now_s() - t0;
        }
        double naive_s = median(t, reps);
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            gemm_blocked(a, b, cb, p, bpack);
            t[r] = now_s() - t0;
        }
        double blk_s = median(t, reps);
        if (!bits_equal(cn, cb, (size_t)p * p)) {
            fprintf(stderr, "FATAL: blocked GEMM != naive bitwise at p=%d\n", p);
            return 1;
        }
        snprintf(shape, sizeof shape, "p=%d", p);
        emit("gemm_naive", shape, 1, "-", flops / naive_s / 1e9, naive_s, reps, "");
        emit("gemm_blocked", shape, 1, "128,256,512", flops / blk_s / 1e9, blk_s, reps,
             "bitwise == gemm_naive (asserted this run)");
        free(a); free(b); free(cn); free(cb); free(bpack);
    }

    /* 2. SpMM blocked vs reference, p = 1024, density 0.02. */
    {
        int p = 1024;
        double density = 0.02;
        Csr m = csr_random(p, density);
        double *b = rand_mat(p, p);
        double *cr = malloc((size_t)p * p * sizeof(double));
        double *cb = malloc((size_t)p * p * sizeof(double));
        double *bpack = malloc((size_t)p * NC * sizeof(double));
        double flops = 2.0 * (double)m.nnz * p;
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            spmm_reference(&m, b, cr, p);
            t[r] = now_s() - t0;
        }
        double ref_s = median(t, reps);
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            spmm_blocked(&m, b, cb, p, bpack);
            t[r] = now_s() - t0;
        }
        double blk_s = median(t, reps);
        if (!bits_equal(cr, cb, (size_t)p * p)) {
            fprintf(stderr, "FATAL: blocked SpMM != reference bitwise at p=%d\n", p);
            return 1;
        }
        snprintf(shape, sizeof shape, "p=%d density=%.2f", p, density);
        emit("spmm_reference", shape, 1, "-", flops / ref_s / 1e9, ref_s, reps, "");
        emit("spmm_blocked", shape, 1, "128,256,512", flops / blk_s / 1e9, blk_s, reps,
             "bitwise == spmm_reference (asserted this run)");
        free(m.indptr); free(m.indices); free(m.values);
        free(b); free(cr); free(cb); free(bpack);
    }

    /* 3. Fused CONCORD gradient+prox pass vs composed, p = 512. */
    {
        int p = 512;
        double *omega = rand_mat(p, p);
        /* Symmetrize and set a strictly positive diagonal, as the
         * solver's iterates have (1/omega_ii must be finite). */
        for (int i = 0; i < p; i++) {
            for (int j = i + 1; j < p; j++) {
                double v = 0.5 * (omega[(size_t)i * p + j] + omega[(size_t)j * p + i]);
                omega[(size_t)i * p + j] = v;
                omega[(size_t)j * p + i] = v;
            }
            omega[(size_t)i * p + i] = 2.0 + rng_uniform();
        }
        double *w = rand_mat(p, p);
        double *wt = malloc((size_t)p * p * sizeof(double));
        for (int i = 0; i < p; i++)
            for (int j = 0; j < p; j++) wt[(size_t)i * p + j] = w[(size_t)j * p + i];
        double *g = malloc((size_t)p * p * sizeof(double));
        double *oc = malloc((size_t)p * p * sizeof(double));
        double *of = malloc((size_t)p * p * sizeof(double));
        double lam1 = 0.3, lam2 = 0.1, tau = 0.5;
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            concord_composed(omega, w, wt, g, oc, p, lam1, lam2, tau);
            t[r] = now_s() - t0;
        }
        double comp_s = median(t, reps);
        for (int r = 0; r < reps; r++) {
            t0 = now_s();
            concord_fused(omega, w, wt, of, p, lam1, lam2, tau);
            t[r] = now_s() - t0;
        }
        double fused_s = median(t, reps);
        if (!bits_equal(oc, of, (size_t)p * p)) {
            fprintf(stderr, "FATAL: fused CONCORD pass != composed bitwise at p=%d\n", p);
            return 1;
        }
        /* ~7 flops/element: gradient (3) + prox threshold chain (~4). */
        double flops = 7.0 * (double)p * p;
        snprintf(shape, sizeof shape, "p=%d", p);
        emit("concord_gradient_prox_composed", shape, 1, "-", flops / comp_s / 1e9, comp_s,
             reps, "");
        emit("fused_concord_pass", shape, 1, "-", flops / fused_s / 1e9, fused_s, reps,
             "bitwise == composed gradient+prox (asserted this run)");
        free(omega); free(w); free(wt); free(g); free(oc); free(of);
    }

    printf("\n  ]\n}\n");
    return 0;
}
