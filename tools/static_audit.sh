#!/usr/bin/env sh
# CI-runnable static audit — the offline fallback for the tier-1 gate.
#
# The builder image ships no Rust toolchain (no cargo/rustc, no rustup,
# no network), so `cargo build --release && cargo test -q` cannot run
# there.  This script is the documented fallback named by ISSUE-7's
# acceptance criteria: it runs the Rust-aware static audit
# (tools/static_audit.py, 14 check classes: delimiter balance, line
# discipline, cargo target paths, module tree, anyhow shim coverage,
# crate-path/use resolution, feature gates, pub-item resolution, bench
# entry points, doc-test examples, struct-literal field coverage,
# format-argument counts, deprecated-wrapper containment, unsafe
# containment) and exits non-zero on any finding.
#
# When a real toolchain IS present (GitHub CI), run the tier-1 commands
# instead — this audit is a floor, not a substitute:
#   cargo build --release && cargo test -q
#   cargo clippy --all-targets -- -D warnings
set -eu
cd "$(dirname "$0")/.."
exec python3 tools/static_audit.py "$@"
