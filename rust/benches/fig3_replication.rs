//! **Figure 3 reproduction**: Obs runtime over the full (c_X, c_Ω)
//! replication grid (paper: 256 nodes × 2 procs, chain p = 40k, n = 100,
//! best cell 5× faster than c_X = c_Ω = 1; here: 32 simulated ranks,
//! p = 256, n = 32, fixed iteration budget so the comparison isolates
//! communication, plus the analytic grid at the paper's exact scale).
//!
//! Run: `cargo bench --bench fig3_replication`

use hpconcord::concord::{fit_distributed, ConcordConfig, Variant};
use hpconcord::cost::model::obs_cost;
use hpconcord::cost::{ProblemShape, ReplicationChoice};
use hpconcord::prelude::*;
use hpconcord::util::Table;

fn measured_grid(ranks: usize, p: usize, n: usize) {
    println!("\n=== Fig. 3 measured (simulated {ranks} ranks, chain p={p}, n={n}) ===");
    let mut rng = Rng::new(0xF3);
    let problem = gen::chain_problem(p, n, &mut rng);
    let cfg = ConcordConfig {
        lambda1: 0.35,
        tol: 0.0,
        max_iter: 8, // fixed budget: isolate per-iteration communication
        variant: Variant::Obs,
        ..Default::default()
    };
    let machine = MachineParams::edison_like();

    let mut cxs = Vec::new();
    let mut cx = 1;
    while cx <= ranks {
        cxs.push(cx);
        cx *= 2;
    }
    let header: Vec<String> = std::iter::once("c_Ω \\ c_X".to_string())
        .chain(cxs.iter().map(|c| c.to_string()))
        .collect();
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    let mut baseline = f64::NAN;
    let mut best = (f64::INFINITY, 1, 1);
    let mut co = 1;
    while co <= ranks {
        let mut row = vec![co.to_string()];
        for &cx in &cxs {
            if cx * co > ranks {
                row.push("-".into());
                continue;
            }
            let out = fit_distributed(&problem.x, &cfg, ranks, cx, co, machine);
            let t = out.cost.time;
            if cx == 1 && co == 1 {
                baseline = t;
            }
            if t < best.0 {
                best = (t, cx, co);
            }
            row.push(format!("{:.5}", t));
        }
        table.row(row);
        co *= 2;
    }
    print!("{table}");
    println!(
        "worst (1,1) {baseline:.5}s → best (c_X={}, c_Ω={}) {:.5}s: {:.2}× speedup",
        best.1,
        best.2,
        best.0,
        baseline / best.0
    );
}

fn analytic_grid_paper_scale() {
    // The paper's exact cell: 256 nodes × 2 MPI procs = 512, p=40k, n=100.
    println!("\n=== Fig. 3 analytic at paper scale (P=512, chain p=40k, n=100) ===");
    let machine = MachineParams::edison_like();
    let shape = ProblemShape { p: 40_000.0, n: 100.0, s: 37.0, t: 10.0, d: 3.0 };
    let procs = 512;
    let mut cxs = Vec::new();
    let mut cx = 1;
    while cx <= procs {
        cxs.push(cx);
        cx *= 2;
    }
    let header: Vec<String> = std::iter::once("c_Ω \\ c_X".to_string())
        .chain(cxs.iter().map(|c| c.to_string()))
        .collect();
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);
    let mut baseline = f64::NAN;
    let mut best = (f64::INFINITY, 1, 1);
    let mut co = 1;
    while co <= procs {
        let mut row = vec![co.to_string()];
        for &cx in &cxs {
            if cx * co > procs {
                row.push("-".into());
                continue;
            }
            let rep = ReplicationChoice { p_procs: procs, c_x: cx, c_omega: co };
            let t = obs_cost(&shape, &rep).time(&machine, procs);
            if cx == 1 && co == 1 {
                baseline = t;
            }
            if t < best.0 {
                best = (t, cx, co);
            }
            row.push(format!("{:.2}", t));
        }
        table.row(row);
        co *= 2;
    }
    print!("{table}");
    println!(
        "worst (1,1) {baseline:.2}s → best (c_X={}, c_Ω={}) {:.2}s: {:.2}× speedup \
         (paper: best at c_X=8, c_Ω=16, 5×)",
        best.1,
        best.2,
        best.0,
        baseline / best.0
    );
}

fn main() {
    measured_grid(32, 256, 32);
    analytic_grid_paper_scale();
}
