//! **Table 1 reproduction**: iterations-to-convergence for BigQUIC vs
//! HP-CONCORD on chain (n = 100) and random (n = 100, n = p/4) graphs,
//! plus PPV/FDR for the n = p/4 random rows (the paper's support
//! recovery comparison).
//!
//! Expected shape: BigQUIC (second order) converges in ~5–6 outer
//! iterations at every size; HP-CONCORD needs tens (chain) to hundreds
//! (random) of proximal steps but each is vastly cheaper; HP-CONCORD's
//! PPV ≥ BigQUIC's at matched sparsity.
//!
//! Run: `cargo bench --bench table1_iterations`

use hpconcord::bigquic::{fit_bigquic_data, QuicConfig};
use hpconcord::concord::{fit_single_node, ConcordConfig, Variant};
use hpconcord::metrics::support_metrics;
use hpconcord::prelude::*;
use hpconcord::util::Table;

fn concord_cfg(l1: f64) -> ConcordConfig {
    ConcordConfig {
        lambda1: l1,
        lambda2: 0.1,
        tol: 1e-4,
        max_iter: 600,
        variant: Variant::Auto,
        ..Default::default()
    }
}

fn main() {
    let sizes = [64usize, 128, 256];

    println!("\n=== Table 1: iterations to convergence ===");
    let mut table = Table::new(&["graph", "method", "p (small)", "p (mid)", "p (large)"]);
    println!("(chain/random n=100 rows: p = 64/128/256; n=p/4 rows: p = 128/256/512)");

    // Chain, n = 100.
    let mut bq_row = vec!["chain (n=100)".to_string(), "BigQUIC".to_string()];
    let mut cc_row = vec!["chain (n=100)".to_string(), "HP-CONCORD".to_string()];
    for &p in &sizes {
        let mut rng = Rng::new(0x71 + p as u64);
        let prob = gen::chain_problem(p, 100, &mut rng);
        let bq = fit_bigquic_data(&prob.x, &QuicConfig { lambda: 0.25, ..Default::default() })
            .unwrap();
        let cc = fit_single_node(&prob.x, &concord_cfg(0.4)).unwrap();
        bq_row.push(bq.iterations.to_string());
        cc_row.push(cc.iterations.to_string());
    }
    table.row(bq_row);
    table.row(cc_row);

    // Random, n = 100 (degree 4 ≈ the paper's degree-60 graphs scaled to
    // these p; see DESIGN.md).
    let mut bq_row = vec!["random (n=100)".to_string(), "BigQUIC".to_string()];
    let mut cc_row = vec!["random (n=100)".to_string(), "HP-CONCORD".to_string()];
    for &p in &sizes {
        let mut rng = Rng::new(0x72 + p as u64);
        let prob = gen::random_problem(p, 100, 4, &mut rng);
        let bq = fit_bigquic_data(&prob.x, &QuicConfig { lambda: 0.3, ..Default::default() })
            .unwrap();
        let cc = fit_single_node(&prob.x, &concord_cfg(0.35)).unwrap();
        bq_row.push(bq.iterations.to_string());
        cc_row.push(cc.iterations.to_string());
    }
    table.row(bq_row);
    table.row(cc_row);

    // Random, n = p/4, with PPV/FDR. Support recovery needs absolute
    // sample counts, so this row uses the larger sizes (the paper's
    // n = p/4 means n ≥ 2500; at our scale p/4 only becomes informative
    // from p ≈ 256 up — expect PPV to climb with p).
    let sizes = [128usize, 256, 512];
    let mut bq_row = vec!["random (n=p/4)".to_string(), "BigQUIC".to_string()];
    let mut cc_row = vec!["random (n=p/4)".to_string(), "HP-CONCORD".to_string()];
    let mut metrics_rows: Vec<Vec<String>> = vec![
        vec!["random (n=p/4)".to_string(), "• BigQUIC PPV/FDR %".to_string()],
        vec!["random (n=p/4)".to_string(), "• CONCORD PPV/FDR %".to_string()],
    ];
    for &p in &sizes {
        let mut rng = Rng::new(0x73 + p as u64);
        let prob = gen::random_problem(p, p / 4, 4, &mut rng);
        // Density-match both methods to the truth, as the paper does.
        let target = (prob.omega0.nnz() - p) as f64 / (p * p - p) as f64;
        let bq_lambda = {
            let (mut lo, mut hi) = (0.01, 1.2);
            for _ in 0..8 {
                let mid = 0.5 * (lo + hi);
                let f = fit_bigquic_data(
                    &prob.x,
                    &QuicConfig { lambda: mid, max_iter: 15, ..Default::default() },
                )
                .unwrap();
                let d = (f.omega.nnz() - p) as f64 / (p * p - p) as f64;
                if d > target {
                    lo = mid
                } else {
                    hi = mid
                }
            }
            0.5 * (lo + hi)
        };
        let bq = fit_bigquic_data(&prob.x, &QuicConfig { lambda: bq_lambda, ..Default::default() })
            .unwrap();
        let cc_lambda = {
            // density-matched CONCORD λ1 by bisection too
            let (mut lo, mut hi) = (0.05, 1.5);
            for _ in 0..8 {
                let mid = 0.5 * (lo + hi);
                let mut c = concord_cfg(mid);
                c.max_iter = 60;
                c.tol = 1e-3;
                let f = fit_single_node(&prob.x, &c).unwrap();
                let d = (f.omega.nnz() - p) as f64 / (p * p - p) as f64;
                if d > target {
                    lo = mid
                } else {
                    hi = mid
                }
            }
            0.5 * (lo + hi)
        };
        let cc = fit_single_node(&prob.x, &concord_cfg(cc_lambda)).unwrap();
        let mb = support_metrics(&bq.omega, &prob.omega0, 1e-6);
        let mc = support_metrics(&cc.omega, &prob.omega0, 1e-6);
        bq_row.push(bq.iterations.to_string());
        cc_row.push(cc.iterations.to_string());
        metrics_rows[0].push(format!("{:.1}/{:.1}", 100.0 * mb.ppv, 100.0 * mb.fdr));
        metrics_rows[1].push(format!("{:.1}/{:.1}", 100.0 * mc.ppv, 100.0 * mc.fdr));
    }
    table.row(bq_row);
    table.row(cc_row);
    for r in metrics_rows {
        table.row(r);
    }
    print!("{table}");
    println!(
        "(paper Table 1: BigQUIC 5-6 iters everywhere; CONCORD 25-69 chain, 114-330 random,\n\
         16-35 at n=p/4; CONCORD PPV ≥ BigQUIC PPV at matched sparsity)"
    );
}
