//! **Table 2 / Figure 5 / supplementary S.9–S.16 reproduction**: the
//! fMRI case study on the synthetic cortex — clustering quality
//! (modified Jaccard vs the ground-truth parcellation, standing in for
//! Glasser et al.) per method and hemisphere, plus the (λ₁, λ₂) Jaccard
//! grids of the supplementary tables.
//!
//! Expected shape: partial-correlation clusterings beat the
//! covariance-threshold (marginal) baseline; the estimate is
//! block-diagonal by hemisphere (§S.3.3); ε coarsens persistence
//! parcellations; scores degrade at over-sparsifying λ.
//!
//! Run: `cargo bench --bench fmri_table2` (set HPC_FULL=1 for the full
//! supplementary grids).

use hpconcord::cluster::{louvain_levels, watershed_persistence, Graph};
use hpconcord::concord::ConcordConfig;
use hpconcord::coordinator::fmri::hemisphere_mesh;
use hpconcord::coordinator::{run_fmri_study, run_sweep, FmriParams, GridSpec};
use hpconcord::gen::synthetic_cortex;
use hpconcord::metrics::jaccard_similarity;
use hpconcord::prelude::*;
use hpconcord::util::Table;

fn main() {
    // --- Table 2: best clusterings per method -------------------------
    let params = FmriParams::default();
    let out = run_fmri_study(&params);
    println!(
        "=== Table 2 (best clusterings; synthetic cortex, p={}, n={}) ===",
        2 * params.p_hemi,
        params.samples
    );
    println!(
        "selected λ1={} λ2={}; density {:.4} (target {:.4}); cross-hemisphere edges {:.2}%",
        out.lambda1,
        out.lambda2,
        out.density,
        out.target_density,
        100.0 * out.cross_hemisphere_fraction
    );
    let mut table = Table::new(&["hemisphere", "method", "clusters", "Jaccard"]);
    for s in &out.scores {
        table.row(vec![
            (if s.hemisphere == 0 { "left" } else { "right" }).to_string(),
            s.method.clone(),
            s.clusters.to_string(),
            format!("{:.4}", s.jaccard),
        ]);
    }
    print!("{table}");

    // --- Supplementary S.9-S.16: Jaccard over the (λ1, λ2) grid -------
    let full = std::env::var("HPC_FULL").is_ok();
    let (l1s, l2s) = if full {
        (vec![0.15, 0.2, 0.25, 0.3, 0.4, 0.5, 0.65], vec![0.0, 0.1, 0.25])
    } else {
        (vec![0.2, 0.3, 0.45], vec![0.0, 0.1])
    };
    let mut rng = Rng::new(params.seed);
    let cortex =
        synthetic_cortex(params.p_hemi, params.parcels, params.knn, params.samples, &mut rng);
    let base = ConcordConfig { tol: 1e-4, max_iter: 150, ..Default::default() };
    let sweep = run_sweep(
        &cortex.x,
        &GridSpec { lambda1: l1s.clone(), lambda2: l2s.clone() },
        &base,
        2,
    );

    for (method_name, eps) in [
        ("persistence ε=0", Some(0.0)),
        ("persistence ε=3", Some(3.0)),
        ("louvain k=0", None),
    ] {
        for h in 0..2u8 {
            println!(
                "\n=== S-table: {method_name}, {} hemisphere — Jaccard over (λ1, λ2) ===",
                if h == 0 { "left" } else { "right" }
            );
            let idx = cortex.hemi_indices(h);
            let truth = cortex.hemi_parcels(h);
            let mesh = hemisphere_mesh(&cortex, h, params.knn);
            let header: Vec<String> = std::iter::once("λ1 \\ λ2".to_string())
                .chain(l2s.iter().map(|v| format!("{v}")))
                .collect();
            let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
            let mut t = Table::new(&hdr);
            for (i, &l1) in l1s.iter().enumerate() {
                let mut row = vec![format!("{l1}")];
                for (j, _l2) in l2s.iter().enumerate() {
                    let r = sweep
                        .results
                        .iter()
                        .find(|r| r.job.grid_pos == (i, j))
                        .unwrap();
                    let sub = Graph::from_sparsity(&r.fit.omega, 1e-12).subgraph(&idx);
                    let labels = match eps {
                        Some(e) => watershed_persistence(&mesh, &sub.edge_counts(), e),
                        None => louvain_levels(&sub).pop().unwrap(),
                    };
                    let k = {
                        let mut s = labels.clone();
                        s.sort_unstable();
                        s.dedup();
                        s.len()
                    };
                    // "—" marks degenerate clusterings, as in the paper.
                    if k <= 1 || k >= idx.len() {
                        row.push("—".to_string());
                    } else {
                        row.push(format!("{:.4}", jaccard_similarity(&labels, &truth)));
                    }
                }
                t.row(row);
            }
            print!("{t}");
        }
    }
    println!(
        "\n(paper S.9-S.16: scores peak at moderate λ and collapse to '—' at the sparse corner)"
    );
}
