//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Bruck vs direct all-to-all** inside the distributed transpose —
//!    the log-round exchange is what gives Lemma 3.2 its log₂(Q) latency.
//! 2. **Sparse (CSR) vs dense local W-step** — the sparse-dense local
//!    multiply is why shifting Ω beats 2D/2.5D/3D algorithms; the
//!    crossover density shows where γ_sparse stops paying.
//! 3. **Covariance screening on/off** — the paper's divide-and-conquer
//!    future-work item: block decomposition before solving.
//!
//! Run: `cargo bench --bench ablations`

use std::sync::Arc;

use hpconcord::concord::{fit_single_node, fit_with_screening, ConcordConfig, Variant};
use hpconcord::linalg::{Csr, Mat};
use hpconcord::prelude::*;
use hpconcord::util::{time_fn, Table};

fn ablation_alltoall() {
    println!("=== Ablation 1: Bruck vs direct all-to-all (per-rank costs) ===");
    let mut table = Table::new(&["ranks", "algo", "msgs/rank", "words/rank", "modeled (µs)"]);
    let machine = MachineParams::edison_like();
    for p in [8usize, 16, 32] {
        for bruck in [false, true] {
            let run = Fabric::with_machine(p, machine).run(move |comm| {
                let team: Vec<usize> = (0..comm.size()).collect();
                let parts: Vec<Vec<f64>> = (0..comm.size()).map(|i| vec![i as f64; 64]).collect();
                if bruck {
                    comm.alltoall_bruck(&team, 1, parts);
                } else {
                    comm.alltoall_direct(&team, 1, parts);
                }
            });
            let s = run.summary();
            table.row(vec![
                p.to_string(),
                (if bruck { "bruck" } else { "direct" }).to_string(),
                s.max_per_rank.messages.to_string(),
                s.max_per_rank.words.to_string(),
                format!("{:.2}", s.comm_time * 1e6),
            ]);
        }
    }
    print!("{table}");
    println!(
        "(Bruck: log₂(P) messages at ~P/2·log₂(P)/(P-1)× the words — wins when α dominates)"
    );
}

fn ablation_wstep() {
    println!("\n=== Ablation 2: sparse (CSR) vs dense local W = Ω·S ===");
    let mut rng = Rng::new(2);
    let p = 384;
    let s = Mat::from_fn(p, p, |_, _| rng.normal());
    let mut table = Table::new(&["density", "dense (ms)", "CSR (ms)", "winner"]);
    for density in [0.01, 0.05, 0.1, 0.2, 0.4, 0.8] {
        let omega = Mat::from_fn(p, p, |i, j| {
            if i == j {
                2.0
            } else if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        });
        let csr = Csr::from_dense(&omega, 0.0);
        let (td, _) = time_fn(1, 3, || omega.matmul(&s));
        let (ts, _) = time_fn(1, 3, || csr.spmm(&s));
        table.row(vec![
            format!("{density}"),
            format!("{:.2}", td.median * 1e3),
            format!("{:.2}", ts.median * 1e3),
            (if ts.median < td.median { "CSR" } else { "dense" }).to_string(),
        ]);
    }
    print!("{table}");
    println!("(the solver's w_step switches to CSR below ~40% density)");
}

fn ablation_screening() {
    println!("\n=== Ablation 3: covariance screening on/off (blocky problem) ===");
    // Four independent 16-variable chain blocks.
    let blocks = 4usize;
    let bp = 16usize;
    let n = 600usize;
    let mut rng = Rng::new(3);
    let parts: Vec<Mat> = (0..blocks).map(|_| gen::chain_problem(bp, n, &mut rng).x).collect();
    let x = Mat::from_fn(n, blocks * bp, |i, j| parts[j / bp].get(i, j % bp));
    let cfg = ConcordConfig {
        lambda1: 0.3,
        lambda2: 0.1,
        tol: 1e-5,
        variant: Variant::Cov,
        ..Default::default()
    };
    let x = Arc::new(x);
    let x1 = Arc::clone(&x);
    let cfg1 = cfg;
    let (t_plain, plain) = time_fn(0, 3, move || fit_single_node(&x1, &cfg1).unwrap());
    let x2 = Arc::clone(&x);
    let (t_screen, screened) = time_fn(0, 3, move || fit_with_screening(&x2, &cfg).unwrap());
    println!(
        "plain    : {:.1} ms ({} iterations)",
        t_plain.median * 1e3,
        plain.iterations
    );
    println!(
        "screened : {:.1} ms ({} components, largest {})",
        t_screen.median * 1e3,
        screened.components,
        screened.largest
    );
    println!(
        "speedup  : {:.2}× (estimates agree to {:.1e})",
        t_plain.median / t_screen.median,
        screened.fit.omega.max_abs_diff(&plain.omega)
    );
}

fn main() {
    ablation_alltoall();
    ablation_wstep();
    ablation_screening();
}
