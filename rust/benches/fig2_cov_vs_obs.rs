//! **Figure 2 reproduction**: Cov vs Obs runtimes as n grows, on chain
//! and random graphs (paper: p = 40k, 16 nodes, n ∈ {100 … 12,800};
//! here: p scaled to 256, 16 simulated ranks, n swept over 5 octaves,
//! with a cost-model extrapolation row at the paper's scale).
//!
//! Expected shape (paper §4): Obs grows linearly in n, Cov stays flat;
//! Cov needs more iterations at tiny n; the measured crossover comes
//! *later* than Lemma 3.1 predicts because γ_sparse ≫ γ_dense.
//!
//! Run: `cargo bench --bench fig2_cov_vs_obs`

use hpconcord::concord::{fit_distributed, ConcordConfig, Variant};
use hpconcord::cost::model::{cov_cost, cov_is_cheaper_flops, obs_cost};
use hpconcord::cost::{ProblemShape, ReplicationChoice};
use hpconcord::prelude::*;
use hpconcord::util::Table;

fn main() {
    let ranks = 16;
    let machine = MachineParams::edison_like();
    let p = 256usize;

    for (graph, deg) in [("chain", 0usize), ("random", 8)] {
        println!("\n=== Fig. 2 ({graph} graph, p={p}, {ranks} simulated ranks) ===");
        let mut table = Table::new(&[
            "n",
            "Cov iters",
            "Obs iters",
            "T_Cov (model s)",
            "T_Obs (model s)",
            "winner",
            "Lemma 3.1",
        ]);
        for n in [16usize, 32, 64, 128, 256, 512] {
            let mut rng = Rng::new(0xF16 + n as u64);
            let problem = if graph == "chain" {
                gen::chain_problem(p, n, &mut rng)
            } else {
                gen::random_problem(p, n, deg, &mut rng)
            };
            let cfg = ConcordConfig {
                lambda1: 0.35,
                tol: 1e-4,
                max_iter: 120,
                ..Default::default()
            };
            let fit = |variant| {
                let mut c = cfg;
                c.variant = variant;
                fit_distributed(&problem.x, &c, ranks, 2, 2, machine)
            };
            let cov = fit(Variant::Cov);
            let obs = fit(Variant::Obs);
            let shape = ProblemShape {
                p: p as f64,
                n: n as f64,
                s: cov.fit.iterations as f64,
                t: cov.fit.mean_linesearch.max(1.0),
                d: cov.fit.mean_row_nnz,
            };
            table.row(vec![
                n.to_string(),
                cov.fit.iterations.to_string(),
                obs.fit.iterations.to_string(),
                format!("{:.4}", cov.cost.time),
                format!("{:.4}", obs.cost.time),
                (if cov.cost.time < obs.cost.time { "Cov" } else { "Obs" }).to_string(),
                (if cov_is_cheaper_flops(&shape) { "Cov" } else { "Obs" }).to_string(),
            ]);
        }
        print!("{table}");
    }

    // Extrapolation to the paper's scale via the analytic model
    // (p = 40k, 16 nodes × 2 procs, chain statistics from Table 1).
    println!("\n=== Extrapolation to paper scale (p=40k, P=32 procs, chain) ===");
    let rep = ReplicationChoice { p_procs: 32, c_x: 2, c_omega: 2 };
    let mut table = Table::new(&["n", "T_Cov (model s)", "T_Obs (model s)", "winner"]);
    for n in [100.0, 400.0, 1600.0, 6400.0, 12800.0] {
        let shape = ProblemShape { p: 40_000.0, n, s: 37.0, t: 10.0, d: 3.0 };
        let tc = cov_cost(&shape, &rep).time(&machine, 32);
        let to = obs_cost(&shape, &rep).time(&machine, 32);
        table.row(vec![
            format!("{n}"),
            format!("{tc:.2}"),
            format!("{to:.2}"),
            (if tc < to { "Cov" } else { "Obs" }).to_string(),
        ]);
    }
    print!("{table}");
    println!("(paper Fig. 2: Obs linear in n, Cov flat; crossover ~n in the thousands)");
}
