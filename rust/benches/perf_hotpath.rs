//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): local GEMM
//! throughput (the MKL-replacement kernel under everything) serial and
//! multithreaded, sparse SpMM, the fused CONCORD elementwise passes,
//! the single-node solver at several thread counts, the distributed
//! transpose, and PJRT-artifact vs native fused-trial latency.
//!
//! Run: `cargo bench --bench perf_hotpath`

use hpconcord::concord::{fit_single_node, ops, ConcordConfig, Variant};
use hpconcord::linalg::{Csr, Mat};
use hpconcord::prelude::*;
use hpconcord::runtime::{native, Engine};
use hpconcord::util::{time_fn, Table};

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.normal())
}

fn main() {
    let mut rng = Rng::new(0xBE);
    let host_threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);

    // --- Dense GEMM ----------------------------------------------------
    println!("=== L3 local GEMM (the paper's MKL substitute) ===");
    let mut table = Table::new(&["size", "median (ms)", "GFLOP/s"]);
    for p in [128usize, 256, 512] {
        let a = random_mat(&mut rng, p, p);
        let b = random_mat(&mut rng, p, p);
        let (stats, _) = time_fn(1, 5, || a.matmul(&b));
        let gflops = 2.0 * (p as f64).powi(3) / stats.median / 1e9;
        table.row(vec![
            format!("{p}³"),
            format!("{:.2}", stats.median * 1e3),
            format!("{gflops:.2}"),
        ]);
    }
    print!("{table}");

    // --- Dense GEMM, node-local threads (the paper's per-node t) --------
    println!("\n=== GEMM, intra-node threads (host has {host_threads}) ===");
    let mut table = Table::new(&["size", "t", "median (ms)", "GFLOP/s", "vs t=1"]);
    for p in [512usize, 1024] {
        let a = random_mat(&mut rng, p, p);
        let b = random_mat(&mut rng, p, p);
        let mut t1_median = 0.0;
        for threads in [1usize, 2, 4] {
            let (stats, _) = time_fn(1, 5, || a.matmul_mt(&b, threads));
            if threads == 1 {
                t1_median = stats.median;
            }
            let gflops = 2.0 * (p as f64).powi(3) / stats.median / 1e9;
            table.row(vec![
                format!("{p}³"),
                threads.to_string(),
                format!("{:.2}", stats.median * 1e3),
                format!("{gflops:.2}"),
                format!("{:.2}×", t1_median / stats.median),
            ]);
        }
    }
    print!("{table}");

    // --- Sparse-dense SpMM (Cov's W = Ω·S) ------------------------------
    println!("\n=== sparse·dense SpMM (γ_sparse path) ===");
    let mut table = Table::new(&["p", "density", "median (ms)", "GFLOP/s (nnz)"]);
    for (p, density) in [(512usize, 0.02), (512, 0.1), (1024, 0.02)] {
        let dense = Mat::from_fn(p, p, |i, j| {
            if i == j {
                2.0
            } else if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        });
        let omega = Csr::from_dense(&dense, 0.0);
        let s = random_mat(&mut rng, p, p);
        let (stats, _) = time_fn(1, 5, || omega.spmm(&s));
        let gflops = omega.spmm_flops(p) as f64 / stats.median / 1e9;
        table.row(vec![
            p.to_string(),
            format!("{density}"),
            format!("{:.2}", stats.median * 1e3),
            format!("{gflops:.2}"),
        ]);
    }
    print!("{table}");

    // --- SpMM, node-local threads --------------------------------------
    println!("\n=== SpMM, intra-node threads (p=1024, density 0.05) ===");
    let mut table = Table::new(&["t", "median (ms)", "vs t=1"]);
    {
        let p = 1024usize;
        let dense = Mat::from_fn(p, p, |i, j| {
            if i == j {
                2.0
            } else if rng.uniform() < 0.05 {
                rng.normal()
            } else {
                0.0
            }
        });
        let omega = Csr::from_dense(&dense, 0.0);
        let s = random_mat(&mut rng, p, p);
        let mut t1_median = 0.0;
        for threads in [1usize, 2, 4] {
            let (stats, _) = time_fn(1, 5, || omega.spmm_mt(&s, threads));
            if threads == 1 {
                t1_median = stats.median;
            }
            table.row(vec![
                threads.to_string(),
                format!("{:.2}", stats.median * 1e3),
                format!("{:.2}×", t1_median / stats.median),
            ]);
        }
    }
    print!("{table}");

    // --- Fused elementwise passes ---------------------------------------
    println!("\n=== fused CONCORD passes (per-element ns) ===");
    let p = 512;
    let omega = {
        let mut m = random_mat(&mut rng, p, p);
        m.symmetrize();
        for i in 0..p {
            m.set(i, i, 2.0 + rng.uniform());
        }
        m
    };
    let w = random_mat(&mut rng, p, p);
    let wt = w.transpose();
    let g = ops::gradient_block(&omega, &w, &wt, 0, 0.1);
    let mut table = Table::new(&["pass", "median (ms)", "ns/element"]);
    let elems = (p * p) as f64;
    let mut bench = |name: &str, f: &mut dyn FnMut()| {
        let (stats, _) = time_fn(1, 5, || f());
        table.row(vec![
            name.to_string(),
            format!("{:.3}", stats.median * 1e3),
            format!("{:.2}", stats.median / elems * 1e9),
        ]);
    };
    bench("gradient", &mut || {
        std::hint::black_box(ops::gradient_block(&omega, &w, &wt, 0, 0.1));
    });
    bench("prox", &mut || {
        std::hint::black_box(ops::prox_block(&omega, &g, 0, 0.5, 0.3));
    });
    let mut out = Mat::zeros(p, p);
    bench("prox (in-place)", &mut || {
        ops::prox_block_into(&omega, &g, 0, 0.5, 0.3, &mut out);
    });
    bench("objective", &mut || {
        std::hint::black_box(ops::objective_parts_block(&omega, &w, 0));
    });
    bench("linesearch", &mut || {
        std::hint::black_box(ops::linesearch_parts_block(&omega, &w, &g));
    });
    print!("{table}");

    // --- Whole fused trial: native vs PJRT artifact ----------------------
    println!("\n=== fused line-search trial: native vs PJRT (p=256) ===");
    let mut rng2 = Rng::new(1);
    let prob = gen::chain_problem(256, 100, &mut rng2);
    let s = native::gram(&prob.x);
    let om = Mat::eye(256);
    let w0 = native::w_step(&om, &s);
    let (grad, g0) = native::gradobj(&om, &w0, 0.1);
    let (nat, _) = time_fn(1, 5, || native::trial(&om, &grad, &s, g0, 0.5, 0.3, 0.1));
    println!("native trial   : {nat}");
    match Engine::load("artifacts") {
        Ok(mut engine) if engine.has_trial(256) => {
            let (pj, _) =
                time_fn(1, 5, || engine.trial(&om, &grad, &s, g0, 0.5, 0.3, 0.1).unwrap());
            println!("PJRT trial     : {pj}");
            println!(
                "PJRT/native    : {:.2}× (XLA fuses the elementwise chain; includes FFI copies)",
                pj.median / nat.median
            );
        }
        _ => println!("PJRT trial     : artifacts/ not built — run `make artifacts`"),
    }

    // --- Single-node solver across thread counts -------------------------
    println!("\n=== single-node solver, intra-node threads (chain p=512, fixed 3 iters) ===");
    let mut table = Table::new(&["t", "median (s)", "vs t=1"]);
    {
        let mut rng3 = Rng::new(0x7E);
        let problem = gen::chain_problem(512, 200, &mut rng3);
        let mut t1_median = 0.0;
        for threads in [1usize, 2, 4] {
            let cfg = ConcordConfig {
                lambda1: 0.3,
                lambda2: 0.1,
                tol: 0.0,
                max_iter: 3, // fixed budget: isolate per-iteration cost
                variant: Variant::Cov,
                threads,
                ..Default::default()
            };
            let (stats, fit) = time_fn(0, 3, || fit_single_node(&problem.x, &cfg).unwrap());
            if threads == 1 {
                t1_median = stats.median;
            }
            assert_eq!(fit.iterations, 3);
            table.row(vec![
                threads.to_string(),
                format!("{:.3}", stats.median),
                format!("{:.2}×", t1_median / stats.median),
            ]);
        }
    }
    print!("{table}");

    // --- Distributed transpose ------------------------------------------
    println!("\n=== distributed transpose (16 ranks, c=2, 512×512) ===");
    let grid = hpconcord::dist::RepGrid::new(16, 2);
    let layout = hpconcord::dist::Layout1D::new(512, grid.teams());
    let full = std::sync::Arc::new(random_mat(&mut rng, 512, 512));
    let (stats, run) = time_fn(1, 3, || {
        let full = full.clone();
        Fabric::new(16).run(move |comm| {
            let (s, e) = layout.range(grid.team_of(comm.rank()));
            let local = full.row_block(s, e);
            hpconcord::dist::transpose_block_rows(comm, &grid, 0, &local, &layout);
        })
    });
    let summary = run.summary();
    println!(
        "wallclock {stats}; per-rank max: {} msgs, {} words (modeled {:.2} ms)",
        summary.max_per_rank.messages,
        summary.max_per_rank.words,
        summary.comm_time * 1e3,
    );
}
