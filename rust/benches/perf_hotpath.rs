//! Hot-path microbenchmarks: the blocked packed
//! GEMM against the retained naive reference (the tentpole win, in
//! GFLOP/s), kernel thread scaling, blocked SpMM vs the row-at-a-time
//! reference, the fused CONCORD elementwise passes, the single-node
//! solver at several thread counts, the distributed transpose, and
//! PJRT-artifact vs native fused-trial latency.
//!
//! Run: `cargo bench --bench perf_hotpath`
//!
//! `cargo bench --bench perf_hotpath -- --smoke` runs a fast subset at
//! small sizes with **bitwise blocked-vs-reference asserts** — the CI
//! step that makes kernel regressions fail fast. Perf numbers from
//! smoke mode are meaningless; only the asserts matter there.
//!
//! With `--record` (or `BENCH_RECORD=<path>` in the environment) every
//! measured number is also written as a structured `BENCH_*.json`
//! record — see [`hpconcord::util::bench_record`]. That file is the
//! perf trajectory ROADMAP item 1 asks for; `BENCH_baseline.json` at
//! the repo root is the committed first point.

use hpconcord::concord::{fit_single_node, ops, ConcordConfig, Variant};
use hpconcord::linalg::{simd, Csr, KernelLane, Mat, TileConfig};
use hpconcord::prelude::*;
use hpconcord::runtime::{native, Engine};
use hpconcord::util::{time_fn, BenchRecord, BenchRecorder, Table};

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.normal())
}

fn bitwise_eq(a: &Mat, b: &Mat) -> bool {
    a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn rate(flops: f64, seconds: f64) -> f64 {
    flops / seconds / 1e9
}

fn gflops(flops: f64, seconds: f64) -> String {
    format!("{:.2}", rate(flops, seconds))
}

fn write_records(rec: &BenchRecorder) {
    if !rec.enabled() {
        return;
    }
    match rec.write() {
        Ok(path) => println!("\nbench records: wrote {} ({} records)", path.display(), rec.len()),
        Err(e) => eprintln!("bench records: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rng = Rng::new(0xBE);
    let host_threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    let reps = if smoke { 2 } else { 5 };
    let mut recorder = BenchRecorder::new("perf_hotpath");
    let default_tile = {
        let t = TileConfig::DEFAULT;
        format!("{},{},{}", t.mc, t.kc, t.nc)
    };

    // --- Blocked packed GEMM vs the naive reference ---------------------
    println!("=== local GEMM: blocked packed kernel vs naive reference ===");
    let mut table = Table::new(&[
        "size",
        "naive (ms)",
        "naive GF/s",
        "blocked (ms)",
        "blocked GF/s",
        "speedup",
    ]);
    let gemm_sizes: &[usize] = if smoke { &[64, 97] } else { &[128, 256, 512, 1024] };
    for &p in gemm_sizes {
        let a = random_mat(&mut rng, p, p);
        let b = random_mat(&mut rng, p, p);
        let flops = 2.0 * (p as f64).powi(3);
        // The naive kernel is slow by design; don't over-sample it.
        let naive_reps = if p >= 1024 { 2 } else { reps };
        let (naive_stats, naive_c) = time_fn(0, naive_reps, || a.matmul_naive(&b));
        let (blk_stats, blk_c) = time_fn(1, reps, || a.matmul(&b));
        // The determinism contract, asserted right here in the bench:
        // the blocked kernel must reproduce the naive bits exactly.
        assert!(bitwise_eq(&naive_c, &blk_c), "blocked GEMM != naive at p={p}");
        recorder.push(BenchRecord {
            name: "gemm_naive".into(),
            shape: format!("p={p}"),
            threads: 1,
            tile: "-".into(),
            gflops: rate(flops, naive_stats.median),
            wall_s: naive_stats.median,
            reps: naive_reps,
            oracle: String::new(),
        });
        recorder.push(BenchRecord {
            name: "gemm_blocked".into(),
            shape: format!("p={p}"),
            threads: 1,
            tile: default_tile.clone(),
            gflops: rate(flops, blk_stats.median),
            wall_s: blk_stats.median,
            reps,
            oracle: "bitwise == matmul_naive".into(),
        });
        table.row(vec![
            format!("{p}³"),
            format!("{:.2}", naive_stats.median * 1e3),
            gflops(flops, naive_stats.median),
            format!("{:.2}", blk_stats.median * 1e3),
            gflops(flops, blk_stats.median),
            format!("{:.2}×", naive_stats.median / blk_stats.median),
        ]);
    }
    print!("{table}");

    // --- Dense GEMM, node-local threads (the paper's per-node t) --------
    println!("\n=== GEMM, intra-node threads (host has {host_threads}) ===");
    let mut table = Table::new(&["size", "t", "median (ms)", "GFLOP/s", "vs t=1"]);
    let mt_sizes: &[usize] = if smoke { &[96] } else { &[512, 1024] };
    for &p in mt_sizes {
        let a = random_mat(&mut rng, p, p);
        let b = random_mat(&mut rng, p, p);
        let mut t1_median = 0.0;
        for threads in [1usize, 2, 4] {
            let (stats, _) = time_fn(1, reps, || a.matmul_mt(&b, threads));
            if threads == 1 {
                t1_median = stats.median;
            }
            recorder.push(BenchRecord {
                name: "gemm_mt".into(),
                shape: format!("p={p}"),
                threads,
                tile: default_tile.clone(),
                gflops: rate(2.0 * (p as f64).powi(3), stats.median),
                wall_s: stats.median,
                reps,
                oracle: "schedule-only knob: bitwise == t=1 (tests/parallel_determinism)".into(),
            });
            table.row(vec![
                format!("{p}³"),
                threads.to_string(),
                format!("{:.2}", stats.median * 1e3),
                gflops(2.0 * (p as f64).powi(3), stats.median),
                format!("{:.2}×", t1_median / stats.median),
            ]);
        }
    }
    print!("{table}");

    // --- Sparse-dense SpMM (Cov's W = Ω·S): blocked vs reference --------
    println!("\n=== sparse·dense SpMM (γ_sparse path): column-blocked vs reference ===");
    let mut table = Table::new(&[
        "p",
        "density",
        "ref (ms)",
        "blocked (ms)",
        "blocked GF/s",
        "speedup",
    ]);
    let spmm_cases: &[(usize, f64)] =
        if smoke { &[(96, 0.1)] } else { &[(512, 0.02), (512, 0.1), (1024, 0.02), (2048, 0.02)] };
    for &(p, density) in spmm_cases {
        let dense = Mat::from_fn(p, p, |i, j| {
            if i == j {
                2.0
            } else if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        });
        let omega = Csr::from_dense(&dense, 0.0);
        let s = random_mat(&mut rng, p, p);
        let flops = omega.spmm_flops(p) as f64;
        let (ref_stats, ref_c) = time_fn(0, reps, || omega.spmm_reference(&s));
        let (blk_stats, blk_c) = time_fn(1, reps, || omega.spmm(&s));
        assert!(bitwise_eq(&ref_c, &blk_c), "blocked SpMM != reference at p={p}");
        recorder.push(BenchRecord {
            name: "spmm_reference".into(),
            shape: format!("p={p} density={density}"),
            threads: 1,
            tile: "-".into(),
            gflops: rate(flops, ref_stats.median),
            wall_s: ref_stats.median,
            reps,
            oracle: String::new(),
        });
        recorder.push(BenchRecord {
            name: "spmm_blocked".into(),
            shape: format!("p={p} density={density}"),
            threads: 1,
            tile: default_tile.clone(),
            gflops: rate(flops, blk_stats.median),
            wall_s: blk_stats.median,
            reps,
            oracle: "bitwise == spmm_reference".into(),
        });
        table.row(vec![
            p.to_string(),
            format!("{density}"),
            format!("{:.2}", ref_stats.median * 1e3),
            format!("{:.2}", blk_stats.median * 1e3),
            gflops(flops, blk_stats.median),
            format!("{:.2}×", ref_stats.median / blk_stats.median),
        ]);
    }
    print!("{table}");

    // --- SpMM, node-local threads --------------------------------------
    let spmm_mt_p = if smoke { 96 } else { 1024 };
    println!("\n=== SpMM, intra-node threads (p={spmm_mt_p}, density 0.05) ===");
    let mut table = Table::new(&["t", "median (ms)", "GFLOP/s", "vs t=1"]);
    {
        let p = spmm_mt_p;
        let dense = Mat::from_fn(p, p, |i, j| {
            if i == j {
                2.0
            } else if rng.uniform() < 0.05 {
                rng.normal()
            } else {
                0.0
            }
        });
        let omega = Csr::from_dense(&dense, 0.0);
        let s = random_mat(&mut rng, p, p);
        let flops = omega.spmm_flops(p) as f64;
        let mut t1_median = 0.0;
        for threads in [1usize, 2, 4] {
            let (stats, _) = time_fn(1, reps, || omega.spmm_mt(&s, threads));
            if threads == 1 {
                t1_median = stats.median;
            }
            recorder.push(BenchRecord {
                name: "spmm_mt".into(),
                shape: format!("p={p} density=0.05"),
                threads,
                tile: default_tile.clone(),
                gflops: rate(flops, stats.median),
                wall_s: stats.median,
                reps,
                oracle: "schedule-only knob: bitwise == t=1 (tests/parallel_determinism)".into(),
            });
            table.row(vec![
                threads.to_string(),
                format!("{:.2}", stats.median * 1e3),
                gflops(flops, stats.median),
                format!("{:.2}×", t1_median / stats.median),
            ]);
        }
    }
    print!("{table}");

    // --- Tile-shape sweep (blocked kernel only) -------------------------
    if !smoke {
        println!("\n=== GEMM tile-shape sweep (p=768, bit-identical results by contract) ===");
        let mut table = Table::new(&["tile mc,kc,nc", "median (ms)", "GFLOP/s"]);
        let p = 768usize;
        let a = random_mat(&mut rng, p, p);
        let b = random_mat(&mut rng, p, p);
        let flops = 2.0 * (p as f64).powi(3);
        for tile in [
            TileConfig::new(8, 8, 8),
            TileConfig::new(32, 64, 128),
            TileConfig::DEFAULT,
            TileConfig::new(4096, 4096, 4096),
        ] {
            let (stats, _) = time_fn(1, reps, || {
                let mut c = Mat::zeros(p, p);
                a.matmul_into_with(&b, &mut c, &tile);
                c
            });
            recorder.push(BenchRecord {
                name: "gemm_tile_sweep".into(),
                shape: format!("p={p}"),
                threads: 1,
                tile: format!("{},{},{}", tile.mc, tile.kc, tile.nc),
                gflops: rate(flops, stats.median),
                wall_s: stats.median,
                reps,
                oracle: "schedule-only knob: bitwise at any tile (tests/parallel_determinism)"
                    .into(),
            });
            table.row(vec![
                format!("{},{},{}", tile.mc, tile.kc, tile.nc),
                format!("{:.2}", stats.median * 1e3),
                gflops(flops, stats.median),
            ]);
        }
        print!("{table}");
    }

    // --- Kernel ISA lanes (runtime-dispatched microkernels) -------------
    {
        let p = if smoke { 96 } else { 512 };
        println!("\n=== GEMM kernel lanes (p={p}, every lane bitwise == scalar) ===");
        let mut table = Table::new(&["lane", "median (ms)", "GFLOP/s", "vs scalar"]);
        let a = random_mat(&mut rng, p, p);
        let b = random_mat(&mut rng, p, p);
        let flops = 2.0 * (p as f64).powi(3);
        let oracle = a.matmul_naive(&b);
        let prev = simd::active();
        let mut scalar_median = 0.0;
        for lane in [KernelLane::Scalar, KernelLane::Avx2, KernelLane::Avx512] {
            if !lane.available() {
                println!("  {} lane: host lacks it — skipped", lane.as_str());
                continue;
            }
            simd::install(lane);
            let (stats, c) = time_fn(1, reps, || a.matmul(&b));
            // Determinism rule 10, asserted in the bench itself: every
            // lane reproduces the scalar oracle's exact bits.
            assert!(bitwise_eq(&oracle, &c), "{} lane != naive at p={p}", lane.as_str());
            if lane == KernelLane::Scalar {
                scalar_median = stats.median;
            }
            recorder.push(BenchRecord {
                name: format!("gemm_kernel_{}", lane.as_str()),
                shape: format!("p={p}"),
                threads: 1,
                tile: default_tile.clone(),
                gflops: rate(flops, stats.median),
                wall_s: stats.median,
                reps,
                oracle: "bitwise == matmul_naive (rule 10: lanes are value-preserving)".into(),
            });
            table.row(vec![
                lane.as_str().to_string(),
                format!("{:.2}", stats.median * 1e3),
                gflops(flops, stats.median),
                format!("{:.2}×", scalar_median / stats.median),
            ]);
        }
        simd::install(prev);
        print!("{table}");
    }

    // --- Fused elementwise passes ---------------------------------------
    let fused_p = if smoke { 128 } else { 512 };
    println!("\n=== fused CONCORD passes (p={fused_p}) ===");
    let p = fused_p;
    let omega = {
        let mut m = random_mat(&mut rng, p, p);
        m.symmetrize();
        for i in 0..p {
            m.set(i, i, 2.0 + rng.uniform());
        }
        m
    };
    let w = random_mat(&mut rng, p, p);
    let wt = w.transpose();
    let g = ops::gradient_block(&omega, &w, &wt, 0, 0.1);
    let mut table = Table::new(&["pass", "median (ms)", "ns/element", "≈GFLOP/s"]);
    let elems = (p * p) as f64;
    let mut bench = |name: &str, flops_per_elem: f64, f: &mut dyn FnMut()| {
        let (stats, _) = time_fn(1, reps, || f());
        recorder.push(BenchRecord {
            name: format!("fused_{}", name.replace([' ', '(', ')'], "")),
            shape: format!("p={p}"),
            threads: 1,
            tile: "-".into(),
            gflops: rate(flops_per_elem * elems, stats.median),
            wall_s: stats.median,
            reps,
            oracle: "fused == composed reference (tests/lemma_counts, concord unit tests)".into(),
        });
        table.row(vec![
            name.to_string(),
            format!("{:.3}", stats.median * 1e3),
            format!("{:.2}", stats.median / elems * 1e9),
            gflops(flops_per_elem * elems, stats.median),
        ]);
    };
    bench("gradient", 4.0, &mut || {
        std::hint::black_box(ops::gradient_block(&omega, &w, &wt, 0, 0.1));
    });
    bench("prox", 3.0, &mut || {
        std::hint::black_box(ops::prox_block(&omega, &g, 0, 0.5, 0.3));
    });
    let mut out = Mat::zeros(p, p);
    bench("prox (in-place)", 3.0, &mut || {
        ops::prox_block_into(&omega, &g, 0, 0.5, 0.3, &mut out);
    });
    bench("gradient+prox (composed)", 7.0, &mut || {
        let g = ops::gradient_block(&omega, &w, &wt, 0, 0.1);
        std::hint::black_box(ops::prox_block(&omega, &g, 0, 0.5, 0.3));
    });
    bench("gradient+prox (fused)", 7.0, &mut || {
        std::hint::black_box(ops::fused_gradient_prox_block(&omega, &w, &wt, 0, 0.5, 0.3, 0.1));
    });
    // The fused pass's oracle, asserted here too: identical bits to the
    // composed pair (the ops unit test covers the _mt variants).
    {
        let g1 = ops::gradient_block(&omega, &w, &wt, 0, 0.1);
        let composed = ops::prox_block(&omega, &g1, 0, 0.5, 0.3);
        let fused = ops::fused_gradient_prox_block(&omega, &w, &wt, 0, 0.5, 0.3, 0.1);
        assert!(bitwise_eq(&composed, &fused), "fused pass != composed at p={p}");
    }
    bench("objective", 4.0, &mut || {
        std::hint::black_box(ops::objective_parts_block(&omega, &w, 0));
    });
    bench("linesearch", 4.0, &mut || {
        std::hint::black_box(ops::linesearch_parts_block(&omega, &w, &g));
    });
    print!("{table}");

    if smoke {
        println!("\nperf_hotpath --smoke OK (blocked GEMM/SpMM bitwise == reference)");
        write_records(&recorder);
        return;
    }

    // --- Whole fused trial: native vs PJRT artifact ----------------------
    println!("\n=== fused line-search trial: native vs PJRT (p=256) ===");
    let mut rng2 = Rng::new(1);
    let prob = gen::chain_problem(256, 100, &mut rng2);
    let s = native::gram(&prob.x);
    let om = Mat::eye(256);
    let w0 = native::w_step(&om, &s);
    let (grad, g0) = native::gradobj(&om, &w0, 0.1);
    let (nat, _) = time_fn(1, 5, || native::trial(&om, &grad, &s, g0, 0.5, 0.3, 0.1));
    recorder.push(BenchRecord {
        name: "fused_trial_native".into(),
        shape: "p=256".into(),
        threads: 1,
        tile: "-".into(),
        gflops: 0.0,
        wall_s: nat.median,
        reps: 5,
        oracle: "trial == w_step+gradobj composition (runtime unit tests)".into(),
    });
    println!("native trial   : {nat}");
    match Engine::load("artifacts") {
        Ok(mut engine) if engine.has_trial(256) => {
            let (pj, _) =
                time_fn(1, 5, || engine.trial(&om, &grad, &s, g0, 0.5, 0.3, 0.1).unwrap());
            println!("PJRT trial     : {pj}");
            println!(
                "PJRT/native    : {:.2}× (XLA fuses the elementwise chain; includes FFI copies)",
                pj.median / nat.median
            );
        }
        _ => println!("PJRT trial     : artifacts/ not built — run `make artifacts`"),
    }

    // --- Single-node solver across thread counts -------------------------
    println!("\n=== single-node solver, intra-node threads (chain p=512, fixed 3 iters) ===");
    let mut table = Table::new(&["t", "median (s)", "vs t=1"]);
    {
        let mut rng3 = Rng::new(0x7E);
        let problem = gen::chain_problem(512, 200, &mut rng3);
        let mut t1_median = 0.0;
        for threads in [1usize, 2, 4] {
            let cfg = ConcordConfig {
                lambda1: 0.3,
                lambda2: 0.1,
                tol: 0.0,
                max_iter: 3, // fixed budget: isolate per-iteration cost
                variant: Variant::Cov,
                threads,
                ..Default::default()
            };
            let (stats, fit) = time_fn(0, 3, || fit_single_node(&problem.x, &cfg).unwrap());
            if threads == 1 {
                t1_median = stats.median;
            }
            assert_eq!(fit.iterations, 3);
            recorder.push(BenchRecord {
                name: "solver_single_node".into(),
                shape: "chain p=512 n=200 iters=3".into(),
                threads,
                tile: default_tile.clone(),
                gflops: 0.0,
                wall_s: stats.median,
                reps: 3,
                oracle: "schedule-only knob: bitwise == t=1 (tests/parallel_determinism)".into(),
            });
            table.row(vec![
                threads.to_string(),
                format!("{:.3}", stats.median),
                format!("{:.2}×", t1_median / stats.median),
            ]);
        }
    }
    print!("{table}");

    // --- Distributed transpose ------------------------------------------
    println!("\n=== distributed transpose (16 ranks, c=2, 512×512) ===");
    let grid = hpconcord::dist::RepGrid::new(16, 2);
    let layout = hpconcord::dist::Layout1D::new(512, grid.teams());
    let full = std::sync::Arc::new(random_mat(&mut rng, 512, 512));
    let (stats, run) = time_fn(1, 3, || {
        let full = full.clone();
        Fabric::new(16).run(move |comm| {
            let (s, e) = layout.range(grid.team_of(comm.rank()));
            let local = full.row_block(s, e);
            hpconcord::dist::transpose_block_rows(comm, &grid, 0, &local, &layout);
        })
    });
    let summary = run.summary();
    recorder.push(BenchRecord {
        name: "dist_transpose".into(),
        shape: "p=512 ranks=16 c=2".into(),
        threads: 16,
        tile: "-".into(),
        gflops: 0.0,
        wall_s: stats.median,
        reps: 3,
        oracle: String::new(),
    });
    println!(
        "wallclock {stats}; per-rank max: {} msgs, {} words (modeled {:.2} ms)",
        summary.max_per_rank.messages,
        summary.max_per_rank.words,
        summary.comm_time * 1e3,
    );
    write_records(&recorder);
}
