//! **Figure 4 reproduction**: HP-CONCORD vs BigQUIC runtimes vs p, on
//! (a) chain graphs n = 100, (b) random graphs n = 100, (c) random
//! graphs n = p/4 (paper: p from 10k to 1.28M, 1–1024 nodes; here: p
//! over 4 octaves single-node measured, plus simulated-distributed
//! modeled scaling and a cost-model extrapolation to the paper's sizes).
//!
//! Expected shape: single-node HP-CONCORD matches/beats BigQUIC and the
//! gap widens with p (the paper reports ~an order of magnitude on the
//! random graphs); adding ranks scales the distributed variant down.
//!
//! Run: `cargo bench --bench fig4_vs_bigquic`

use hpconcord::bigquic::{fit_bigquic_data, QuicConfig};
use hpconcord::concord::{fit_distributed, fit_single_node, ConcordConfig, Variant};
use hpconcord::coordinator::{run_sweep, select_by_density, GridSpec};
use hpconcord::cost::ProblemShape;
use hpconcord::prelude::*;
use hpconcord::util::{BenchRecord, BenchRecorder, Table};
use std::time::Instant;

/// Tune each method to the problem's true density (the paper equalizes
/// sparsity before timing), then time the fit at the chosen λ.
fn equal_sparsity_lambdas(problem: &gen::Problem, variant: Variant) -> (f64, f64) {
    let p = problem.x.cols();
    let target = (problem.omega0.nnz() - p) as f64 / (p * p - p) as f64;
    // CONCORD: quick sweep, density-matched selection.
    let base = ConcordConfig { tol: 1e-3, max_iter: 40, variant, ..Default::default() };
    let grid = GridSpec { lambda1: vec![0.2, 0.3, 0.45, 0.65, 0.9], lambda2: vec![0.1] };
    let out = run_sweep(&problem.x, &grid, &base, 2);
    let concord_l1 = select_by_density(&out.results, target).unwrap().job.cfg.lambda1;
    // BigQUIC: bisection on its own λ to the same density.
    let mut lo = 0.01;
    let mut hi = 1.5;
    for _ in 0..8 {
        let mid = 0.5 * (lo + hi);
        let fit = fit_bigquic_data(
            &problem.x,
            &QuicConfig { lambda: mid, tol: 1e-4, max_iter: 20, ..Default::default() },
        )
        .unwrap();
        let d = (fit.omega.nnz() - p) as f64 / (p * p - p) as f64;
        if d > target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (concord_l1, 0.5 * (lo + hi))
}

fn head_to_head(
    title: &str,
    mk: impl Fn(usize, &mut Rng) -> gen::Problem,
    variant: Variant,
    recorder: &mut BenchRecorder,
) {
    println!("\n=== Fig. 4 {title} ===");
    let mut table = Table::new(&[
        "p",
        "BigQUIC iters",
        "BigQUIC (s)",
        "CONCORD iters",
        "CONCORD-1 (s)",
        "speedup",
        "Dist-8 model (s)",
    ]);
    for p in [64usize, 128, 256, 512] {
        let mut rng = Rng::new(0xF4 + p as u64);
        let problem = mk(p, &mut rng);
        let (l1, lq) = equal_sparsity_lambdas(&problem, variant);

        let t0 = Instant::now();
        let quic = fit_bigquic_data(
            &problem.x,
            &QuicConfig { lambda: lq, tol: 1e-5, max_iter: 30, ..Default::default() },
        )
        .unwrap();
        let t_quic = t0.elapsed().as_secs_f64();

        let cfg = ConcordConfig {
            lambda1: l1,
            lambda2: 0.1,
            tol: 1e-4,
            max_iter: 400,
            variant,
            ..Default::default()
        };
        let t0 = Instant::now();
        let concord = fit_single_node(&problem.x, &cfg).unwrap();
        let t_concord = t0.elapsed().as_secs_f64();

        // Simulated distributed run, modeled at Edison-like constants.
        let dist = fit_distributed(&problem.x, &cfg, 8, 2, 2, MachineParams::edison_like());

        recorder.push(BenchRecord {
            name: "bigquic_single_node".into(),
            shape: format!("{title} p={p}"),
            threads: 1,
            tile: "-".into(),
            gflops: 0.0,
            wall_s: t_quic,
            reps: 1,
            oracle: String::new(),
        });
        recorder.push(BenchRecord {
            name: "concord_single_node".into(),
            shape: format!("{title} p={p}"),
            threads: 1,
            tile: "-".into(),
            gflops: 0.0,
            wall_s: t_concord,
            reps: 1,
            oracle: "density-matched to BigQUIC before timing".into(),
        });
        table.row(vec![
            p.to_string(),
            quic.iterations.to_string(),
            format!("{t_quic:.3}"),
            concord.iterations.to_string(),
            format!("{t_concord:.3}"),
            format!("{:.1}×", t_quic / t_concord),
            format!("{:.4}", dist.cost.time),
        ]);
    }
    print!("{table}");
}

fn extrapolation() {
    println!("\n=== Fig. 4a extrapolation (chain, n=100; model at paper scale) ===");
    println!("(replication chosen by the optimizer per cell; iterations from Table 1)");
    let machine = MachineParams::edison_like();
    let mut table = Table::new(&["p", "nodes", "procs", "variant", "c_X", "c_Ω", "T model (s)"]);
    // (p, nodes, measured-iterations from the paper's Table 1 chain row)
    for (p, nodes, s_iters) in [
        (10_000.0, 1usize, 25.0),
        (40_000.0, 16, 37.0),
        (80_000.0, 1024, 36.0),
        (320_000.0, 256, 51.0),
        (1_280_000.0, 1024, 57.0),
    ] {
        let procs = nodes * 2;
        let shape = ProblemShape { p, n: 100.0, s: s_iters, t: 10.0, d: 3.0 };
        let best = hpconcord::cost::optimize_replication(
            &shape,
            procs,
            Variant::Auto,
            &machine,
            f64::INFINITY,
        )
        .expect("feasible configuration");
        table.row(vec![
            format!("{p}"),
            nodes.to_string(),
            procs.to_string(),
            format!("{:?}", best.variant),
            best.choice.c_x.to_string(),
            best.choice.c_omega.to_string(),
            format!("{:.1}", best.time),
        ]);
    }
    print!("{table}");
    println!(
        "(paper: p=1.28M in ≈17 min on 1024 nodes; p=80k in <4 s on 1024 nodes —\n\
         our per-process γ is ~10× Edison's per-node rate, so absolute times scale up;\n\
         the who-wins/scaling shape is the claim under test)"
    );
}

fn main() {
    let mut recorder = BenchRecorder::new("fig4_vs_bigquic");
    // (a) chain graphs, n = 100.
    head_to_head(
        "(a) chain, n=100",
        |p, rng| gen::chain_problem(p, 100, rng),
        Variant::Obs,
        &mut recorder,
    );
    // (b) random graphs, n = 100 (degree scaled with p as the paper
    // scales its degree-60 graphs down).
    head_to_head(
        "(b) random, n=100",
        |p, rng| gen::random_problem(p, 100, 4, rng),
        Variant::Obs,
        &mut recorder,
    );
    // (c) random graphs, n = p/4: large n → Cov.
    head_to_head(
        "(c) random, n=p/4",
        |p, rng| gen::random_problem(p, p / 4, 4, rng),
        Variant::Cov,
        &mut recorder,
    );
    extrapolation();
    if recorder.enabled() {
        match recorder.write() {
            Ok(path) => println!("\nbench records: wrote {}", path.display()),
            Err(e) => eprintln!("bench records: {e}"),
        }
    }
}
