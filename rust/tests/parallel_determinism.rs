//! The parallel linear algebra layer's contract, enforced end to end:
//!
//! 1. **Bit-for-bit kernel equivalence** — the multithreaded
//!    `matmul_into` / `matmul_bt` / `spmm` are property-tested against
//!    the serial reference across randomized shapes (empty, 1×n, odd
//!    remainders) and thread counts 1–8. The parallel kernels partition
//!    rows on aligned boundaries and run the unmodified serial inner
//!    loops, so equality here is exact, not approximate.
//! 2. **Solver determinism** — `fit_distributed` on a fixed seed
//!    returns a byte-identical estimate and identical metered
//!    communication/flop counters across `threads ∈ {1, 2, 4}` and
//!    across repeated runs: intra-node threading must only change
//!    wall-clock time, never results or the paper's L/W counts.

use hpconcord::concord::{
    fit_distributed, fit_screened_distributed, fit_single_node, fit_with_screening,
    ConcordConfig, ScreenedDistOptions, Variant,
};
use hpconcord::linalg::{Csr, Mat};
use hpconcord::prelude::*;
use hpconcord::prop_assert;
use hpconcord::simnet::cost::Counters;
use hpconcord::util::proptest::check;

mod common;
use common::disjoint_blocks;

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.normal())
}

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// Shapes that exercise the kernels' edges: empty dims, single rows
/// (no 2-row pairing), odd remainders against the 2-row/4-k unrolling,
/// and sizes straddling the k-blocking boundary.
fn edge_dim(rng: &mut Rng) -> usize {
    match rng.below(6) {
        0 => 0,
        1 => 1,
        2 => 2 + rng.below(3) as usize,          // tiny
        3 => 15 + rng.below(4) as usize,         // odd-ish remainders
        4 => 64,                                 // exact unroll multiples
        _ => 30 + rng.below(40) as usize,        // general
    }
}

#[test]
fn prop_matmul_mt_bitwise_equals_serial() {
    check(0xD15E1, 40, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let a = random_mat(rng, m, k);
        let b = random_mat(rng, k, n);
        let serial = a.matmul(&b);
        for threads in 1..=8 {
            let par = a.matmul_mt(&b, threads);
            prop_assert!(
                bits(&serial) == bits(&par),
                "matmul {m}x{k}x{n} differs at threads={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_matmul_bt_mt_bitwise_equals_serial() {
    check(0xD15E2, 40, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let a = random_mat(rng, m, k);
        let bt = random_mat(rng, n, k); // B already transposed: n × k
        let serial = a.matmul_bt(&bt);
        for threads in 1..=8 {
            let par = a.matmul_bt_mt(&bt, threads);
            prop_assert!(
                bits(&serial) == bits(&par),
                "matmul_bt {m}x{k}x{n} differs at threads={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_mt_bitwise_equals_serial() {
    check(0xD15E3, 40, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let density = rng.uniform();
        let dense = Mat::from_fn(m, k, |_, _| {
            if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        });
        let a = Csr::from_dense(&dense, 0.0);
        let b = random_mat(rng, k, n);
        let serial = a.spmm(&b);
        for threads in 1..=8 {
            let par = a.spmm_mt(&b, threads);
            prop_assert!(
                bits(&serial) == bits(&par),
                "spmm {m}x{k}x{n} (density {density:.2}) differs at threads={threads}"
            );
        }
        Ok(())
    });
}

/// Shared fixture for the solver determinism tests: a fixed-seed chain
/// problem solved distributed on 8 ranks with replication.
fn dist_fixture(variant: Variant, threads: usize) -> (Vec<u64>, usize, Counters, Counters) {
    let mut rng = Rng::new(0xF1D0);
    let problem = gen::chain_problem(32, 40, &mut rng);
    let cfg = ConcordConfig {
        lambda1: 0.3,
        lambda2: 0.1,
        tol: 1e-5,
        max_iter: 60,
        variant,
        threads,
        ..Default::default()
    };
    let out = fit_distributed(&problem.x, &cfg, 8, 2, 2, MachineParams::edison_like());
    (bits(&out.fit.omega), out.fit.iterations, out.cost.total, out.cost.max_per_rank)
}

#[test]
fn fit_distributed_is_byte_identical_across_thread_counts() {
    for variant in [Variant::Cov, Variant::Obs] {
        let (omega1, iters1, total1, max1) = dist_fixture(variant, 1);
        for threads in [2usize, 4] {
            let (omega, iters, total, max) = dist_fixture(variant, threads);
            assert_eq!(iters, iters1, "{variant:?}: iterations changed at threads={threads}");
            assert_eq!(
                omega, omega1,
                "{variant:?}: estimate not byte-identical at threads={threads}"
            );
            assert_eq!(
                total, total1,
                "{variant:?}: total counters changed at threads={threads}"
            );
            assert_eq!(
                max, max1,
                "{variant:?}: per-rank max counters changed at threads={threads}"
            );
        }
    }
}

#[test]
fn fit_distributed_is_byte_identical_across_repeated_runs() {
    let first = dist_fixture(Variant::Obs, 2);
    for _ in 0..2 {
        let again = dist_fixture(Variant::Obs, 2);
        assert_eq!(first.0, again.0, "estimate drifted between runs");
        assert_eq!(first.1, again.1);
        assert_eq!(first.2, again.2, "counters drifted between runs");
        assert_eq!(first.3, again.3);
    }
}

fn screened_base_cfg(threads: usize) -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.05,
        lambda2: 0.1,
        tol: 1e-5,
        max_iter: 60,
        variant: Variant::Cov,
        threads,
        ..Default::default()
    }
}

/// The screened single-node path (gram + component split + per-block
/// solves) is bit-identical across node-local thread counts.
#[test]
fn fit_with_screening_is_byte_identical_across_thread_counts() {
    let x = disjoint_blocks(&[10, 8], 300, 0x5C1);
    let base = fit_with_screening(&x, &screened_base_cfg(1)).unwrap();
    for threads in [2usize, 4] {
        let out = fit_with_screening(&x, &screened_base_cfg(threads)).unwrap();
        assert_eq!(out.components, base.components, "threads={threads}");
        assert_eq!(out.fit.iterations, base.fit.iterations, "threads={threads}");
        assert_eq!(
            bits(&out.fit.omega),
            bits(&base.fit.omega),
            "screened estimate not byte-identical at threads={threads}"
        );
    }
}

/// The screened *distributed* composition — screening fabric, one sized
/// fabric per component, reassembly — is bit-identical across thread
/// counts, and its metered counters (screening pass included) never
/// move: threading only divides flop time.
#[test]
fn fit_screened_distributed_is_byte_identical_across_thread_counts() {
    let x = disjoint_blocks(&[12, 12], 300, 0x5C2);
    let run = |threads: usize| {
        let cfg = screened_base_cfg(threads);
        let opts = ScreenedDistOptions {
            total_ranks: 8,
            machine: MachineParams::edison_like(),
            small_cutoff: 4,
            fixed: Some((4, 2, 2)),
        };
        fit_screened_distributed(&x, &cfg, &opts).unwrap()
    };
    let base = run(1);
    assert_eq!(base.components, 2, "fixture must split in two");
    assert_eq!(base.solves.len(), 2);
    for threads in [2usize, 4] {
        let out = run(threads);
        assert_eq!(out.components, base.components);
        assert_eq!(
            bits(&out.fit.omega),
            bits(&base.fit.omega),
            "screened-dist estimate not byte-identical at threads={threads}"
        );
        assert_eq!(out.fit.iterations, base.fit.iterations);
        assert_eq!(
            out.screen_cost.total, base.screen_cost.total,
            "screening-pass counters changed at threads={threads}"
        );
        assert_eq!(
            out.cost.total, base.cost.total,
            "aggregate counters changed at threads={threads}"
        );
        assert_eq!(out.cost.max_per_rank, base.cost.max_per_rank);
        for (a, b) in out.solves.iter().zip(&base.solves) {
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.counters, b.counters, "per-rank counters changed");
        }
    }
}

#[test]
fn fit_single_node_is_byte_identical_across_thread_counts() {
    let mut rng = Rng::new(0xF1D1);
    let problem = gen::chain_problem(48, 60, &mut rng);
    let base = ConcordConfig {
        lambda1: 0.25,
        lambda2: 0.05,
        tol: 1e-6,
        max_iter: 80,
        variant: Variant::Cov,
        ..Default::default()
    };
    let f1 = fit_single_node(&problem.x, &ConcordConfig { threads: 1, ..base }).unwrap();
    for threads in [2usize, 4, 8] {
        let ft = fit_single_node(&problem.x, &ConcordConfig { threads, ..base }).unwrap();
        assert_eq!(f1.iterations, ft.iterations, "threads={threads}");
        assert_eq!(bits(&f1.omega), bits(&ft.omega), "threads={threads}");
        assert_eq!(f1.objective.to_bits(), ft.objective.to_bits(), "threads={threads}");
    }
}
