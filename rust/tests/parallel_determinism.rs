//! The parallel linear algebra layer's contract, enforced end to end:
//!
//! 1. **Bit-for-bit kernel equivalence** — the multithreaded
//!    `matmul_into` / `matmul_bt` / `spmm` are property-tested against
//!    the serial reference across randomized shapes (empty, 1×n, odd
//!    remainders) and thread counts 1–8. Every kernel accumulates each
//!    output element in ascending-k order (the kernel layer's
//!    determinism rule; see ARCHITECTURE.md), so equality here is
//!    exact, not approximate.
//! 2. **Tile invariance** — the blocked packed kernels are bitwise
//!    equal to the retained naive references (`Mat::matmul_naive`,
//!    `Csr::spmm_reference`) at *every* `mc × kc × nc` tile shape:
//!    tiny, default, larger-than-matrix, and ragged (dimensions not
//!    divisible by mc/nc, so final panels are partial).
//! 3. **Solver determinism** — `fit_distributed` on a fixed seed
//!    returns a byte-identical estimate and identical metered
//!    communication/flop counters across `threads ∈ {1, 2, 4}`, across
//!    tile overrides, and across repeated runs: threading and blocking
//!    must only change wall-clock time, never results or the paper's
//!    L/W counts.

use hpconcord::concord::{
    fit_distributed, fit_screened_distributed, fit_single_node, fit_with_screening,
    ConcordConfig, ScreenedDistOptions, Variant,
};
use hpconcord::io::XSource;
use hpconcord::linalg::{Csr, Mat, TileConfig};
use hpconcord::prelude::*;
use hpconcord::prop_assert;
use hpconcord::simnet::cost::Counters;
use hpconcord::util::proptest::check;

mod common;
use common::disjoint_blocks;

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.normal())
}

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// Shapes that exercise the kernels' edges: empty dims, single rows
/// (a lone ragged MR-slab), odd remainders against the MR×NR register
/// grid, and sizes straddling panel boundaries.
fn edge_dim(rng: &mut Rng) -> usize {
    match rng.below(6) {
        0 => 0,
        1 => 1,
        2 => 2 + rng.below(3) as usize,          // tiny
        3 => 15 + rng.below(4) as usize,         // odd-ish remainders
        4 => 64,                                 // exact unroll multiples
        _ => 30 + rng.below(40) as usize,        // general
    }
}

#[test]
fn prop_matmul_mt_bitwise_equals_serial() {
    check(0xD15E1, 40, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let a = random_mat(rng, m, k);
        let b = random_mat(rng, k, n);
        let serial = a.matmul(&b);
        for threads in 1..=8 {
            let par = a.matmul_mt(&b, threads);
            prop_assert!(
                bits(&serial) == bits(&par),
                "matmul {m}x{k}x{n} differs at threads={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_matmul_bt_mt_bitwise_equals_serial() {
    check(0xD15E2, 40, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let a = random_mat(rng, m, k);
        let bt = random_mat(rng, n, k); // B already transposed: n × k
        let serial = a.matmul_bt(&bt);
        for threads in 1..=8 {
            let par = a.matmul_bt_mt(&bt, threads);
            prop_assert!(
                bits(&serial) == bits(&par),
                "matmul_bt {m}x{k}x{n} differs at threads={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_mt_bitwise_equals_serial() {
    check(0xD15E3, 40, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let density = rng.uniform();
        let dense = Mat::from_fn(m, k, |_, _| {
            if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        });
        let a = Csr::from_dense(&dense, 0.0);
        let b = random_mat(rng, k, n);
        let serial = a.spmm(&b);
        for threads in 1..=8 {
            let par = a.spmm_mt(&b, threads);
            prop_assert!(
                bits(&serial) == bits(&par),
                "spmm {m}x{k}x{n} (density {density:.2}) differs at threads={threads}"
            );
        }
        Ok(())
    });
}

/// Tile shapes from degenerate through default to larger than any test
/// matrix; a shape-derived ragged tile is added per property case.
fn tile_zoo(m: usize, k: usize, n: usize) -> Vec<TileConfig> {
    vec![
        TileConfig::new(1, 1, 1),
        TileConfig::new(3, 5, 7),
        // One below a half-divisor of the actual shape: forces ragged
        // final panels whenever the dims aren't tiny.
        TileConfig::new((m / 2).max(1), (k / 2).max(1), (n / 2).max(1)),
        TileConfig::DEFAULT,
        TileConfig::new(4096, 4096, 4096),
    ]
}

#[test]
fn prop_blocked_gemm_bitwise_equals_naive_across_tiles() {
    check(0xD15E4, 25, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let a = random_mat(rng, m, k);
        let b = random_mat(rng, k, n);
        let naive = a.matmul_naive(&b);
        for tile in tile_zoo(m, k, n) {
            for threads in [1usize, 2, 4] {
                let mut c = Mat::zeros(m, n);
                a.matmul_into_mt_with(&b, &mut c, threads, &tile);
                prop_assert!(
                    bits(&naive) == bits(&c),
                    "gemm {m}x{k}x{n} tile {tile:?} threads={threads} != naive"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blocked_spmm_bitwise_equals_reference_across_tiles() {
    check(0xD15E5, 25, |rng| {
        let (m, k, n) = (edge_dim(rng), edge_dim(rng), edge_dim(rng));
        let density = rng.uniform();
        let dense = Mat::from_fn(m, k, |_, _| {
            if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        });
        let a = Csr::from_dense(&dense, 0.0);
        let b = random_mat(rng, k, n);
        let reference = a.spmm_reference(&b);
        for tile in tile_zoo(m, k, n) {
            for threads in [1usize, 2, 4] {
                let c = a.spmm_mt_with(&b, threads, &tile);
                prop_assert!(
                    bits(&reference) == bits(&c),
                    "spmm {m}x{k}x{n} (density {density:.2}) tile {tile:?} \
                     threads={threads} != reference"
                );
            }
        }
        Ok(())
    });
}

/// Ragged final panels, deterministically: every dimension sits just
/// past a tile-dimension multiple (and off the `MR`/`NR` grid), so
/// each macro loop ends in a partial panel and the microkernel edges
/// run. Shapes are sized above the kernel's tiny-product cutoff so the
/// packed path (not the allocation-free fallback) is what's exercised.
#[test]
fn gemm_ragged_final_panels_match_naive() {
    let mut rng = Rng::new(0xD15E6);
    // (tile, shapes): every dim is coprime-ish with mc/kc/nc and the
    // MR=4/NR=8 register grid, and every product exceeds 2¹⁵ flops.
    let cases: &[((usize, usize, usize), [(usize, usize, usize); 3])] = &[
        ((8, 8, 8), [(33, 33, 33), (39, 51, 37), (99, 98, 7)]),
        ((16, 32, 24), [(65, 129, 97), (47, 67, 101), (67, 130, 23)]),
    ];
    for &((mc, kc, nc), shapes) in cases {
        let tile = TileConfig::new(mc, kc, nc);
        for &(m, k, n) in &shapes {
            assert!(m * k * n >= 1 << 15, "shape under the tiny-product cutoff");
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let naive = a.matmul_naive(&b);
            for threads in [1usize, 2, 4] {
                let mut c = Mat::zeros(m, n);
                a.matmul_into_mt_with(&b, &mut c, threads, &tile);
                assert_eq!(
                    bits(&naive),
                    bits(&c),
                    "ragged {m}x{k}x{n} tile {mc},{kc},{nc} t={threads}"
                );
            }
        }
    }
}

/// Whole fits are byte-identical across tile overrides (tiny, default,
/// larger-than-matrix) at several thread counts: `ConcordConfig::tile`
/// is a pure throughput knob end to end.
#[test]
fn fit_single_node_is_byte_identical_across_tile_shapes() {
    let mut rng = Rng::new(0xF1D2);
    let problem = gen::chain_problem(48, 60, &mut rng);
    let base = ConcordConfig {
        lambda1: 0.25,
        lambda2: 0.05,
        tol: 1e-6,
        max_iter: 80,
        variant: Variant::Cov,
        ..Default::default()
    };
    let reference = fit_single_node(&problem.x, &base).unwrap();
    let tiles =
        [TileConfig::new(1, 2, 3), TileConfig::new(8, 8, 8), TileConfig::new(4096, 4096, 4096)];
    for tile in tiles {
        for threads in [1usize, 4] {
            let cfg = ConcordConfig { tile, threads, ..base };
            let fit = fit_single_node(&problem.x, &cfg).unwrap();
            assert_eq!(fit.iterations, reference.iterations, "tile {tile:?} t={threads}");
            assert_eq!(
                bits(&fit.omega),
                bits(&reference.omega),
                "estimate not byte-identical at tile {tile:?} t={threads}"
            );
            assert_eq!(fit.objective.to_bits(), reference.objective.to_bits());
        }
    }
}

/// Shared fixture for the solver determinism tests: a fixed-seed chain
/// problem solved distributed on 8 ranks with replication.
fn dist_fixture(
    variant: Variant,
    threads: usize,
    tile: TileConfig,
) -> (Vec<u64>, usize, Counters, Counters) {
    let mut rng = Rng::new(0xF1D0);
    let problem = gen::chain_problem(32, 40, &mut rng);
    let cfg = ConcordConfig {
        lambda1: 0.3,
        lambda2: 0.1,
        tol: 1e-5,
        max_iter: 60,
        variant,
        threads,
        tile,
        ..Default::default()
    };
    let out = fit_distributed(&problem.x, &cfg, 8, 2, 2, MachineParams::edison_like());
    (bits(&out.fit.omega), out.fit.iterations, out.cost.total, out.cost.max_per_rank)
}

#[test]
fn fit_distributed_is_byte_identical_across_thread_counts_and_tiles() {
    for variant in [Variant::Cov, Variant::Obs] {
        let (omega1, iters1, total1, max1) = dist_fixture(variant, 1, TileConfig::DEFAULT);
        for (threads, tile) in [
            (2usize, TileConfig::DEFAULT),
            (4, TileConfig::DEFAULT),
            (2, TileConfig::new(2, 3, 5)),
            (1, TileConfig::new(4096, 4096, 4096)),
        ] {
            let (omega, iters, total, max) = dist_fixture(variant, threads, tile);
            assert_eq!(
                iters, iters1,
                "{variant:?}: iterations changed at threads={threads} tile {tile:?}"
            );
            assert_eq!(
                omega, omega1,
                "{variant:?}: estimate not byte-identical at threads={threads} tile {tile:?}"
            );
            assert_eq!(
                total, total1,
                "{variant:?}: total counters changed at threads={threads} tile {tile:?}"
            );
            assert_eq!(
                max, max1,
                "{variant:?}: per-rank max counters changed at threads={threads} tile {tile:?}"
            );
        }
    }
}

#[test]
fn fit_distributed_is_byte_identical_across_repeated_runs() {
    let first = dist_fixture(Variant::Obs, 2, TileConfig::DEFAULT);
    for _ in 0..2 {
        let again = dist_fixture(Variant::Obs, 2, TileConfig::DEFAULT);
        assert_eq!(first.0, again.0, "estimate drifted between runs");
        assert_eq!(first.1, again.1);
        assert_eq!(first.2, again.2, "counters drifted between runs");
        assert_eq!(first.3, again.3);
    }
}

fn screened_base_cfg(threads: usize) -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.05,
        lambda2: 0.1,
        tol: 1e-5,
        max_iter: 60,
        variant: Variant::Cov,
        threads,
        ..Default::default()
    }
}

/// The screened single-node path (gram + component split + per-block
/// solves) is bit-identical across node-local thread counts.
#[test]
fn fit_with_screening_is_byte_identical_across_thread_counts() {
    let x = disjoint_blocks(&[10, 8], 300, 0x5C1);
    let base = fit_with_screening(&x, &screened_base_cfg(1)).unwrap();
    for threads in [2usize, 4] {
        let out = fit_with_screening(&x, &screened_base_cfg(threads)).unwrap();
        assert_eq!(out.components, base.components, "threads={threads}");
        assert_eq!(out.fit.iterations, base.fit.iterations, "threads={threads}");
        assert_eq!(
            bits(&out.fit.omega),
            bits(&base.fit.omega),
            "screened estimate not byte-identical at threads={threads}"
        );
    }
}

/// The screened *distributed* composition — screening fabric, one sized
/// fabric per component, reassembly — is bit-identical across thread
/// counts, and its metered counters (screening pass included) never
/// move: threading only divides flop time.
#[test]
fn fit_screened_distributed_is_byte_identical_across_thread_counts() {
    // n_each = 400 measures 4.7σ at λ₁ = 0.05 on this seed (300 sat
    // under 4σ — tools/verify_fixture_margins.py).
    let x = disjoint_blocks(&[12, 12], 400, 0x5C2);
    let run = |threads: usize| {
        let cfg = screened_base_cfg(threads);
        let opts = ScreenedDistOptions {
            total_ranks: 8,
            machine: MachineParams::edison_like(),
            small_cutoff: 4,
            fixed: Some((4, 2, 2)),
            sequential: false,
            gram_block: 0,
        };
        fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap()
    };
    let base = run(1);
    assert_eq!(base.components, 2, "fixture must split in two");
    assert_eq!(base.solves.len(), 2);
    for threads in [2usize, 4] {
        let out = run(threads);
        assert_eq!(out.components, base.components);
        assert_eq!(
            bits(&out.fit.omega),
            bits(&base.fit.omega),
            "screened-dist estimate not byte-identical at threads={threads}"
        );
        assert_eq!(out.fit.iterations, base.fit.iterations);
        assert_eq!(
            out.screen_cost.total, base.screen_cost.total,
            "screening-pass counters changed at threads={threads}"
        );
        assert_eq!(
            out.cost.total, base.cost.total,
            "aggregate counters changed at threads={threads}"
        );
        assert_eq!(out.cost.max_per_rank, base.cost.max_per_rank);
        for (a, b) in out.solves.iter().zip(&base.solves) {
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.counters, b.counters, "per-rank counters changed");
        }
    }
}

/// With pinned plans, the whole (rank budget × thread count × launch
/// order) grid collapses to one bit pattern: the wave schedule and the
/// node-local pool are both pure launch-order knobs.
#[test]
fn fit_screened_distributed_is_byte_identical_across_budgets_and_threads() {
    let x = disjoint_blocks(&[12, 12], 300, 0x5C3);
    let run = |threads: usize, budget: usize, sequential: bool| {
        let cfg = ConcordConfig { ranks_budget: budget, ..screened_base_cfg(threads) };
        let opts = ScreenedDistOptions {
            total_ranks: 8,
            machine: MachineParams::edison_like(),
            small_cutoff: 4,
            fixed: Some((4, 2, 2)),
            sequential,
            gram_block: 0,
        };
        fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap()
    };
    let base = run(1, 4, true);
    assert_eq!(base.solves.len(), 2);
    // Budget 4 serializes the two pinned 4-rank fabrics into two waves;
    // budget 8 packs them into one. Either way, at any thread count,
    // results and counters are those of the sequential reference.
    for budget in [4usize, 8] {
        for threads in [1usize, 2, 4] {
            for sequential in [false, true] {
                let out = run(threads, budget, sequential);
                let tag = format!("budget={budget} threads={threads} sequential={sequential}");
                assert_eq!(
                    bits(&out.fit.omega),
                    bits(&base.fit.omega),
                    "{tag}: omega not byte-identical"
                );
                assert_eq!(out.fit.iterations, base.fit.iterations, "{tag}");
                assert_eq!(out.cost.total, base.cost.total, "{tag}: counters moved");
                for (a, b) in out.solves.iter().zip(&base.solves) {
                    assert_eq!(a.counters, b.counters, "{tag}: per-rank counters moved");
                }
            }
        }
    }
}

#[test]
fn fit_single_node_is_byte_identical_across_thread_counts() {
    let mut rng = Rng::new(0xF1D1);
    let problem = gen::chain_problem(48, 60, &mut rng);
    let base = ConcordConfig {
        lambda1: 0.25,
        lambda2: 0.05,
        tol: 1e-6,
        max_iter: 80,
        variant: Variant::Cov,
        ..Default::default()
    };
    let f1 = fit_single_node(&problem.x, &ConcordConfig { threads: 1, ..base }).unwrap();
    for threads in [2usize, 4, 8] {
        let ft = fit_single_node(&problem.x, &ConcordConfig { threads, ..base }).unwrap();
        assert_eq!(f1.iterations, ft.iterations, "threads={threads}");
        assert_eq!(bits(&f1.omega), bits(&ft.omega), "threads={threads}");
        assert_eq!(f1.objective.to_bits(), ft.objective.to_bits(), "threads={threads}");
    }
}
