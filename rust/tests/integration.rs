//! Cross-module integration: generators → solvers → metrics →
//! coordinator → clustering, at small but realistic sizes.

use hpconcord::bigquic::{fit_bigquic_data, QuicConfig};
use hpconcord::concord::{fit_distributed, fit_single_node, ConcordConfig, Variant};
use hpconcord::coordinator::{run_sweep, select_by_density, GridSpec};
use hpconcord::metrics::support_metrics;
use hpconcord::prelude::*;

/// Chain-graph support recovery end to end, with the distributed solver.
#[test]
fn distributed_fit_recovers_chain_support() {
    let mut rng = Rng::new(10);
    let problem = gen::chain_problem(64, 400, &mut rng);
    let cfg = ConcordConfig {
        lambda1: 0.3,
        lambda2: 0.05,
        tol: 1e-5,
        variant: Variant::Auto,
        ..Default::default()
    };
    let out = fit_distributed(&problem.x, &cfg, 8, 2, 2, MachineParams::edison_like());
    let m = support_metrics(&out.fit.omega, &problem.omega0, 1e-8);
    assert!(m.ppv > 0.85, "ppv {}", m.ppv);
    assert!(m.recall > 0.85, "recall {}", m.recall);
    assert!(out.cost.time > 0.0);
}

/// Cov and Obs agree with each other and the single-node path on a
/// random-graph problem (three routes to the same estimator).
#[test]
fn three_solver_paths_agree_on_random_graph() {
    let mut rng = Rng::new(11);
    let problem = gen::random_problem(32, 64, 4, &mut rng);
    let mk = |variant| ConcordConfig {
        lambda1: 0.3,
        lambda2: 0.1,
        tol: 1e-6,
        variant,
        ..Default::default()
    };
    let single = fit_single_node(&problem.x, &mk(Variant::Cov)).unwrap();
    let cov = fit_distributed(&problem.x, &mk(Variant::Cov), 4, 2, 2, MachineParams::default());
    let obs = fit_distributed(&problem.x, &mk(Variant::Obs), 4, 1, 4, MachineParams::default());
    assert!(single.omega.max_abs_diff(&cov.fit.omega) < 1e-8);
    assert!(single.omega.max_abs_diff(&obs.fit.omega) < 1e-7);
}

/// BigQUIC and CONCORD, density-matched, both recover an easy chain; the
/// second-order method uses far fewer (outer) iterations — Table 1's
/// qualitative content.
#[test]
fn bigquic_vs_concord_iteration_profile() {
    let mut rng = Rng::new(12);
    let problem = gen::chain_problem(48, 600, &mut rng);
    let bq = fit_bigquic_data(
        &problem.x,
        &QuicConfig { lambda: 0.12, tol: 1e-7, ..Default::default() },
    )
    .unwrap();
    let cc = fit_single_node(
        &problem.x,
        &ConcordConfig { lambda1: 0.2, tol: 1e-5, ..Default::default() },
    )
    .unwrap();
    assert!(bq.iterations < cc.iterations, "{} !< {}", bq.iterations, cc.iterations);
    let mb = support_metrics(&bq.omega, &problem.omega0, 1e-6);
    let mc = support_metrics(&cc.omega, &problem.omega0, 1e-6);
    assert!(mb.recall > 0.9 && mc.recall > 0.9);
}

/// Sweep + model selection finds a λ with high PPV on a well-sampled
/// problem (the §5 workflow in miniature).
#[test]
fn sweep_then_select_gives_good_estimate() {
    let mut rng = Rng::new(13);
    let problem = gen::chain_problem(40, 500, &mut rng);
    let p = 40;
    let target = (problem.omega0.nnz() - p) as f64 / ((p * p - p) as f64);
    let grid = GridSpec { lambda1: vec![0.1, 0.2, 0.35, 0.55, 0.8], lambda2: vec![0.05] };
    let base = ConcordConfig { tol: 1e-4, max_iter: 200, ..Default::default() };
    let out = run_sweep(&problem.x, &grid, &base, 3);
    let sel = select_by_density(&out.results, target).unwrap();
    let m = support_metrics(&sel.fit.omega, &problem.omega0, 1e-8);
    assert!(m.ppv > 0.8, "ppv {}", m.ppv);
    assert!(m.recall > 0.8, "recall {}", m.recall);
}

/// Failure injection: degenerate inputs must not panic and must keep the
/// estimator well-defined.
#[test]
fn degenerate_inputs_are_handled() {
    // (a) constant column: its sample variance is 0, but the iterate's
    // diagonal stays positive through the line search.
    let mut x = Mat::zeros(20, 6);
    let mut rng = Rng::new(14);
    for i in 0..20 {
        for j in 0..5 {
            x.set(i, j, rng.normal());
        }
        x.set(i, 5, 3.0); // constant
    }
    let fit = fit_single_node(
        &x,
        &ConcordConfig { lambda1: 0.3, max_iter: 50, ..Default::default() },
    )
    .unwrap();
    assert!(fit.omega.diag().iter().all(|&d| d > 0.0));
    assert!(fit.objective.is_finite());

    // (b) single sample.
    let x1 = Mat::from_fn(1, 5, |_, j| j as f64 + 1.0);
    let fit = fit_single_node(
        &x1,
        &ConcordConfig { lambda1: 0.5, max_iter: 30, ..Default::default() },
    )
    .unwrap();
    assert!(fit.objective.is_finite());

    // (c) duplicated (perfectly collinear) features.
    let mut xd = Mat::zeros(30, 4);
    for i in 0..30 {
        let v = rng.normal();
        xd.set(i, 0, v);
        xd.set(i, 1, v);
        xd.set(i, 2, rng.normal());
        xd.set(i, 3, rng.normal());
    }
    let fit = fit_single_node(
        &xd,
        &ConcordConfig { lambda1: 0.2, max_iter: 80, ..Default::default() },
    )
    .unwrap();
    assert!(fit.omega.diag().iter().all(|&d| d.is_finite() && d > 0.0));
}

/// Lemma 3.1's Auto selection reacts to the sample/dimension regime.
#[test]
fn auto_variant_switches_with_regime() {
    let mut rng = Rng::new(15);
    // Plenty of samples → Cov.
    let many = gen::chain_problem(32, 256, &mut rng);
    let cfg =
        ConcordConfig { lambda1: 0.3, max_iter: 30, variant: Variant::Auto, ..Default::default() };
    let out = fit_distributed(&many.x, &cfg, 4, 1, 1, MachineParams::default());
    assert_eq!(out.variant, Variant::Cov);
}
