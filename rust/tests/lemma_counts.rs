//! Integration tests: the simulated fabric's *measured* message/word
//! counters against the paper's closed forms (Lemmas 3.2–3.4).
//! The unit tests in `dist::mult15d` pin the single-multiply counts
//! (Lemma 3.3) exactly; these tests check the solver-level scaling laws
//! that Figures 2–3 rely on.

use std::sync::Arc;

use hpconcord::concord::screening::extract_columns;
use hpconcord::concord::{
    fit_screened_distributed, obs::fit_obs_rank, run_distributed, ConcordConfig,
    ScreenedDistOptions, Variant,
};
use hpconcord::dist::{rotate_parts, Block, RepGrid};
use hpconcord::io::XSource;
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;

mod common;
use common::disjoint_blocks;

fn fixed_budget_cfg() -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.35,
        lambda2: 0.1,
        tol: 0.0,
        max_iter: 6,
        variant: Variant::Obs,
        ..Default::default()
    }
}

fn obs_words_per_rank(p_ranks: usize, c_x: usize, c_o: usize, x: &Mat) -> u64 {
    let x = Arc::new(x.clone());
    let cfg = fixed_budget_cfg();
    let run = Fabric::new(p_ranks).run(move |comm| fit_obs_rank(comm, &x, &cfg, c_x, c_o));
    run.summary().max_per_rank.words
}

/// Lemma 3.4: Obs's dominant rotation-bandwidth term is s(t+1)·np/c_Ω —
/// raising c_Ω cuts per-rank words. (The p²·c_Xc_Ω/P transpose term
/// *grows* with replication in the paper's own model, so heavy combined
/// replication is judged on modeled time, not raw words — see Fig. 3.)
#[test]
fn obs_bandwidth_scales_inversely_with_replication() {
    let mut rng = Rng::new(1);
    let problem = gen::chain_problem(64, 32, &mut rng);
    let w11 = obs_words_per_rank(8, 1, 1, &problem.x);
    let w12 = obs_words_per_rank(8, 1, 2, &problem.x);
    assert!(w12 < w11, "c_Ω=2 should cut words: {w12} !< {w11}");
}

/// Lemma 3.3 at the operation level: per-rank messages ≤ P/(c_R·c_F)
/// and words ≤ nnz(R)/c_F exactly — at a large configuration and at the
/// small fabric sizes the screened scheduler hands individual
/// components (P ∈ {4, 8}).
#[test]
fn lemma33_bounds_hold_at_scale() {
    for p_ranks in [4usize, 8, 32] {
        lemma33_bounds_at(p_ranks);
    }
}

fn lemma33_bounds_at(p_ranks: usize) {
    for (c_r, c_f) in [
        (1usize, 1usize),
        (2, 1),
        (1, 4),
        (2, 2),
        (2, 4),
        (4, 2),
        (4, 8),
        (8, 4),
        (16, 2),
        (1, 32),
    ] {
        if c_r * c_f > p_ranks {
            continue;
        }
        let grid_r = RepGrid::new(p_ranks, c_r);
        let grid_f = RepGrid::new(p_ranks, c_f);
        let elems = 12usize; // 3x4 part
        let run = Fabric::new(p_ranks).run(move |comm| {
            let my = Block::Dense(Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64));
            rotate_parts(comm, &grid_r, &grid_f, 0, &my, |_c, _i, _b| {});
        });
        let rounds = (p_ranks / (c_r * c_f)) as u64;
        let nnz_r = (grid_r.teams() * elems) as u64;
        for c in &run.counters {
            assert!(
                c.messages <= rounds,
                "messages {} > {rounds} (c_R={c_r}, c_F={c_f})",
                c.messages
            );
            assert!(
                c.words <= nnz_r / c_f as u64,
                "words {} > nnz(R)/c_F = {} (c_R={c_r}, c_F={c_f})",
                c.words,
                nnz_r / c_f as u64
            );
        }
    }
}

/// Lemma 3.2: replication limits the transpose's *latency* — the
/// cross-team exchange shrinks to log₂(T) partners (messages), which is
/// the term the paper's analysis optimizes. (Per-rank words grow with c
/// in the paper's model too: each replica holds, and must receive, a
/// c×-larger block.)
#[test]
fn transpose_messages_shrink_with_replication() {
    use hpconcord::dist::{transpose_block_rows, Layout1D};
    let rows = 64;
    let msgs = |c: usize| {
        let grid = RepGrid::new(16, c);
        let layout = Layout1D::new(rows, grid.teams());
        let full = Arc::new(Mat::from_fn(rows, rows, |i, j| (i * rows + j) as f64));
        let run = Fabric::new(16).run(move |comm| {
            let (s, e) = layout.range(grid.team_of(comm.rank()));
            let local = full.row_block(s, e);
            transpose_block_rows(comm, &grid, 0, &local, &layout);
        });
        run.summary().max_per_rank.messages
    };
    let m1 = msgs(1); // log2(16) = 4 exchange messages
    let m4 = msgs(4); // log2(4) + 3 allgather = 5... compare to m1 via exchange only
    // The c=1 all-to-all group is 16 ranks; at c=4 it is 4 ranks. With
    // Bruck both are logarithmic: 4 vs 2 (+3 team-sync messages).
    assert_eq!(m1, 4, "log2(16) Bruck rounds");
    assert_eq!(m4, 2 + 3, "log2(4) Bruck rounds + (c-1) allgather");
}

/// Regression: intra-node threading must never touch communication.
/// The metered per-rank and total L (messages) and W (words) — and the
/// analytic flop tallies — are identical whether each simulated rank
/// runs its local kernels on 1 or 4 threads, for both variants and a
/// replicated configuration. Threading only divides the γ flop *time*
/// (Lemma 3.5's F/t term); the counts are machine facts.
#[test]
fn threading_leaves_message_and_word_counts_unchanged() {
    use hpconcord::concord::cov::fit_cov_rank;
    let mut rng = Rng::new(9);
    let problem = gen::chain_problem(32, 24, &mut rng);

    let run_counts = |variant: Variant, threads: usize| {
        let x = Arc::new(problem.x.clone());
        let mut cfg = fixed_budget_cfg();
        cfg.variant = variant;
        cfg.threads = threads;
        let run = Fabric::new(8).run(move |comm| match variant {
            Variant::Cov => fit_cov_rank(comm, &x, &cfg, 2, 2),
            _ => fit_obs_rank(comm, &x, &cfg, 2, 2),
        });
        (run.counters.clone(), run.summary())
    };

    for variant in [Variant::Cov, Variant::Obs] {
        let (per_rank_1, sum_1) = run_counts(variant, 1);
        let (per_rank_4, sum_4) = run_counts(variant, 4);
        assert_eq!(per_rank_1, per_rank_4, "{variant:?}: per-rank counters changed");
        assert_eq!(sum_1.total, sum_4.total, "{variant:?}: totals changed");
        assert_eq!(
            sum_1.max_per_rank, sum_4.max_per_rank,
            "{variant:?}: critical-path counts changed"
        );
    }
}

/// Screening composition vs Lemma 3.2/3.3: inside each component's
/// sized sub-fabric, the per-rank message/word (and flop) counters are
/// *exactly* what the same sub-problem meters when run standalone —
/// screening changes which fabrics run, never what happens within one.
/// Checked for both variants over a replicated sub-fabric configuration.
#[test]
fn screening_leaves_subfabric_counts_unchanged() {
    // Two 12-column blocks on disjoint sample rows: cross-block S
    // entries are exactly 0.0, so the split is guaranteed.
    let x = disjoint_blocks(&[12, 12], 200, 0x5EED5);

    let machine = MachineParams::edison_like();
    for variant in [Variant::Cov, Variant::Obs] {
        let mut cfg = fixed_budget_cfg();
        cfg.variant = variant;
        cfg.lambda1 = 0.02;
        let opts = ScreenedDistOptions {
            total_ranks: 8,
            machine,
            small_cutoff: 0,
            fixed: Some((4, 2, 2)),
            sequential: false,
            gram_block: 0,
        };
        let screened = fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap();
        assert_eq!(screened.solves.len(), 2, "{variant:?}: expected one fabric per block");
        for sv in &screened.solves {
            assert_eq!(sv.counters.len(), 4, "{variant:?}: sized sub-fabric has P = 4");
            let standalone =
                run_distributed(&extract_columns(&x, &sv.indices), &cfg, 4, 2, 2, machine);
            assert_eq!(
                standalone.counters, sv.counters,
                "{variant:?}: per-rank counters inside the component fabric differ \
                 from the standalone run"
            );
            // And the summary derived from them is byte-equal too.
            assert_eq!(standalone.cost.total, sv.cost.total);
            assert_eq!(standalone.cost.max_per_rank, sv.cost.max_per_rank);
        }
    }
}

/// The end-to-end modeled time improves when the replication optimizer's
/// choice is used instead of (1, 1) — the Figure 3 effect, measured.
#[test]
fn optimizer_choice_beats_naive_on_measured_counters() {
    let mut rng = Rng::new(5);
    let problem = gen::chain_problem(64, 16, &mut rng);
    let machine = MachineParams::edison_like();
    let run_cfg = |c_x: usize, c_o: usize| {
        let x = Arc::new(problem.x.clone());
        let cfg = fixed_budget_cfg();
        let run = Fabric::with_machine(16, machine)
            .run(move |comm| fit_obs_rank(comm, &x, &cfg, c_x, c_o));
        run.summary().comm_time
    };
    let naive = run_cfg(1, 1);
    let replicated = run_cfg(2, 4);
    assert!(
        replicated < naive,
        "replicated comm time {replicated} !< naive {naive}"
    );
}
