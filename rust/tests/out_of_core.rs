//! Out-of-core X: the bit-identity test wall.
//!
//! The contract under test is **determinism rule 8** in
//! `ARCHITECTURE.md`: the X backend (`InCore` vs `OnDisk`, the CLI's
//! `--x-file`) is a **schedule-only** knob. Every code path that reads
//! X — the streamed screening gram, the executor's per-wave column
//! extraction, the stability coordinator's subsample row views, packed
//! grid sweeps — must produce bit-identical omegas, objectives, and
//! Lemma-3.3/3.5 counters on either backend, across the gram-block ×
//! mem-budget × threads matrix. Only the modeled source residency
//! (`CostSummary::x_panel_words`, and the screening pass's
//! `peak_mem_words` when the effective panels differ) may move: an
//! on-disk run's modeled peak under a tight budget sits strictly below
//! the in-core unbounded run's.

use hpconcord::concord::{fit_screened_distributed, ConcordConfig, ScreenedDistOptions, Variant};
use hpconcord::coordinator::{
    run_sweep_screened_dist, stability_selection_dist, GridSchedule, GridSpec, StabilityConfig,
};
use hpconcord::cost::MemFootprint;
use hpconcord::io::{write_x, XDisk, XSource, DEFAULT_PANEL_ROWS};
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;

mod common;
use common::{disjoint_blocks, TempPath};

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// Write `x` to a self-cleaning HPCX temp file and open it back.
fn disk_fixture(name: &str, x: &Mat) -> (TempPath, XDisk) {
    let tmp = TempPath::new(&format!("ooc_{name}.xbin"));
    write_x(tmp.path(), x).expect("fixture write");
    let xd = XDisk::open(tmp.path()).expect("fixture open");
    (tmp, xd)
}

/// A machine whose flops dwarf its communication: the planner then
/// gives even small screened components multi-rank fabrics, so every
/// component enters the wave packer on both backends.
fn flop_heavy() -> MachineParams {
    MachineParams {
        alpha: 1.0e-13,
        beta: 1.0e-13,
        gamma_dense: 1.0e-6,
        gamma_sparse: 8.0e-6,
        beta_mem: 0.0,
    }
}

fn base_cfg(threads: usize, mem_budget: u64) -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.02,
        lambda2: 0.1,
        tol: 0.0, // fixed budget: every component runs exactly max_iter
        max_iter: 6,
        variant: Variant::Cov,
        threads,
        ranks_budget: 32,
        mem_budget,
        ..Default::default()
    }
}

fn dist_opts(gram_block: usize) -> ScreenedDistOptions {
    ScreenedDistOptions {
        total_ranks: 8,
        machine: flop_heavy(),
        small_cutoff: 0,
        fixed: None,
        sequential: false,
        gram_block,
    }
}

/// The tentpole matrix: `solve` on `InCore` vs `OnDisk` across
/// gram-block {1, 7, n+13} × mem-budget {0, tight} × threads {1, 4} —
/// omegas, objective bits, iterations, component counts, the
/// Lemma-3.3/3.5 counters, both modeled times, and (the gram panels
/// being equal at every `gram_block > 0`) the modeled peak are all
/// bit-identical. The source residency is the only thing allowed to
/// move, and only downward: on disk it never exceeds the in-core
/// matrix, strictly undercutting it whenever the panel is smaller
/// than X.
#[test]
fn solve_is_backend_invariant_across_the_knob_matrix() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0x9A1D);
    let (n, p) = (x.rows(), x.cols());
    let (_tmp, xd) = disk_fixture("solve_matrix", &x);
    let tight = MemFootprint::for_component(n, 10).words();

    for gram_block in [1usize, 7, n + 13] {
        let opts = dist_opts(gram_block);
        for mem_budget in [0u64, tight] {
            for threads in [1usize, 4] {
                let tag = format!("gram {gram_block} mem {mem_budget} threads {threads}");
                let cfg = base_cfg(threads, mem_budget);
                let incore = fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap();
                let disk =
                    fit_screened_distributed(XSource::OnDisk(&xd), &cfg, &opts).unwrap();

                assert_eq!(bits(&disk.fit.omega), bits(&incore.fit.omega), "{tag}: omega");
                assert_eq!(
                    disk.fit.objective.to_bits(),
                    incore.fit.objective.to_bits(),
                    "{tag}: objective"
                );
                assert_eq!(disk.fit.iterations, incore.fit.iterations, "{tag}");
                assert_eq!(disk.components, incore.components, "{tag}");
                assert_eq!(disk.largest, incore.largest, "{tag}");
                // Counters are machine facts: the backend cannot move
                // a single message, word, or flop — or a priced
                // second.
                assert_eq!(disk.cost.total, incore.cost.total, "{tag}: counters");
                assert_eq!(disk.cost.max_per_rank, incore.cost.max_per_rank, "{tag}");
                assert_eq!(disk.cost.time.to_bits(), incore.cost.time.to_bits(), "{tag}");
                assert_eq!(
                    disk.cost.comm_time.to_bits(),
                    incore.cost.comm_time.to_bits(),
                    "{tag}"
                );
                // At gram_block > 0 both backends screen over the same
                // effective panel, so even the modeled peak agrees.
                assert_eq!(disk.cost.peak_mem_words, incore.cost.peak_mem_words, "{tag}");
                // Source residency: panels on disk, the matrix in
                // core. gram_block = n + 13 clamps to n — the one cell
                // where the disk "panel" is the whole matrix.
                assert_eq!(incore.cost.x_panel_words, (n * p) as u64, "{tag}");
                if gram_block < n {
                    assert!(
                        disk.cost.x_panel_words < incore.cost.x_panel_words,
                        "{tag}: disk residency {} must undercut in-core {}",
                        disk.cost.x_panel_words,
                        incore.cost.x_panel_words
                    );
                } else {
                    assert_eq!(disk.cost.x_panel_words, incore.cost.x_panel_words, "{tag}");
                }
            }
        }
    }
}

/// ISSUE acceptance: on the ragged `[12, 6, 6, 6]`-block fixture the
/// on-disk tight-budget run reproduces the in-core unbounded run bit
/// for bit while its modeled peak residency — default read panels plus
/// one component footprint per wave — sits strictly below the in-core
/// peak, with both sides' residency terms pinned to their closed
/// forms.
#[test]
fn on_disk_tight_budget_peak_undercuts_in_core_unbounded() {
    let x = disjoint_blocks(&[12, 6, 6, 6], 200, 0x51ab);
    let (n, p) = (x.rows(), x.cols());
    let (_tmp, xd) = disk_fixture("acceptance", &x);
    let opts = dist_opts(0);

    let incore = fit_screened_distributed(XSource::InCore(&x), &base_cfg(1, 0), &opts).unwrap();
    let tight = MemFootprint::for_component(n, 12).words();
    let disk =
        fit_screened_distributed(XSource::OnDisk(&xd), &base_cfg(1, tight), &opts).unwrap();

    // Same estimate, same counters — rules 7 and 8 jointly.
    assert_eq!(bits(&disk.fit.omega), bits(&incore.fit.omega));
    assert_eq!(disk.fit.objective.to_bits(), incore.fit.objective.to_bits());
    assert_eq!(disk.cost.total, incore.cost.total);

    // In-core unbounded: the screening pass holds all of X plus the
    // gram rows — the modeled peak of the whole fit.
    assert_eq!(incore.cost.peak_mem_words, ((n * p) + p * p) as u64);
    assert_eq!(incore.cost.x_panel_words, (n * p) as u64);

    // On disk under the tight budget the peak is the largest wave's
    // single component footprint, and the X residency is one default
    // read panel — both strictly below their in-core twins.
    assert_eq!(disk.cost.peak_mem_words, tight);
    assert_eq!(disk.cost.x_panel_words, (DEFAULT_PANEL_ROWS.min(n) * p) as u64);
    assert!(
        disk.cost.peak_mem_words < incore.cost.peak_mem_words,
        "on-disk tight peak {} must undercut in-core unbounded peak {}",
        disk.cost.peak_mem_words,
        incore.cost.peak_mem_words
    );
    assert!(disk.cost.x_panel_words < incore.cost.x_panel_words);
}

/// `sweep --mode dist` on both grid schedules: every grid point's
/// omega, density, iteration count and the grid bill's counters are
/// backend-invariant — cross-job packing composes with the on-disk
/// source.
#[test]
fn dist_sweep_is_backend_invariant_on_both_schedules() {
    let x = disjoint_blocks(&[10, 10], 200, 0x0BAD);
    let (_tmp, xd) = disk_fixture("sweep", &x);
    let grid = GridSpec { lambda1: vec![0.01, 0.02], lambda2: vec![0.0, 0.1] };
    let base = base_cfg(2, 0);
    let opts = dist_opts(7);

    for mode in [GridSchedule::Packed, GridSchedule::PerPoint] {
        let incore =
            run_sweep_screened_dist(XSource::InCore(&x), &grid, &base, &opts, mode).unwrap();
        let disk =
            run_sweep_screened_dist(XSource::OnDisk(&xd), &grid, &base, &opts, mode).unwrap();
        assert_eq!(disk.results.len(), incore.results.len(), "{mode:?}");
        for (d, i) in disk.results.iter().zip(&incore.results) {
            let tag = format!("{mode:?} job {}", i.job.id);
            assert_eq!(d.job.id, i.job.id, "{tag}");
            assert_eq!(bits(&d.fit.omega), bits(&i.fit.omega), "{tag}: omega");
            assert_eq!(d.density.to_bits(), i.density.to_bits(), "{tag}: density");
            assert_eq!(d.fit.iterations, i.fit.iterations, "{tag}");
        }
        assert_eq!(disk.components, incore.components, "{mode:?}");
        assert_eq!(disk.cost.total, incore.cost.total, "{mode:?}: counters");
        assert_eq!(disk.cost.max_per_rank, incore.cost.max_per_rank, "{mode:?}");
        assert_eq!(disk.cost.time.to_bits(), incore.cost.time.to_bits(), "{mode:?}");
        assert_eq!(disk.bill.per_job.len(), incore.bill.per_job.len(), "{mode:?}");
        for (d, i) in disk.bill.per_job.iter().zip(&incore.bill.per_job) {
            assert_eq!(d.total, i.total, "{mode:?}: per-job counters");
        }
    }
}

/// Stability selection: the on-disk subsample row views gather bit-for
/// bit the in-core rows, so frequencies, stable edges and the bill's
/// counters are backend-invariant — while the wave schedule's source
/// residency shrinks to read panels.
#[test]
fn stability_selection_is_backend_invariant() {
    let x = disjoint_blocks(&[8, 8, 8], 200, 0xF00D);
    let (n, p) = (x.rows(), x.cols());
    let (_tmp, xd) = disk_fixture("stability", &x);
    let base = base_cfg(1, 0);
    let cfg = StabilityConfig { subsamples: 4, fraction: 0.5, threshold: 0.6, seed: 7, workers: 2 };
    let opts = ScreenedDistOptions { total_ranks: 4, ..dist_opts(0) };

    let incore = stability_selection_dist(XSource::InCore(&x), &base, &cfg, &opts).unwrap();
    let disk = stability_selection_dist(XSource::OnDisk(&xd), &base, &cfg, &opts).unwrap();

    assert_eq!(bits(&disk.frequency), bits(&incore.frequency), "frequency drift");
    assert_eq!(disk.edges, incore.edges);
    assert_eq!(disk.subsamples, incore.subsamples);
    assert_eq!(disk.cost.total, incore.cost.total, "counter drift");
    assert_eq!(disk.cost.max_per_rank, incore.cost.max_per_rank);
    assert_eq!(disk.bill.screen.total, incore.bill.screen.total);
    assert_eq!(disk.bill.waves.total, incore.bill.waves.total);
    // The executor's lazy row views read panels on disk, the whole
    // matrix in core.
    assert_eq!(incore.bill.waves.x_panel_words, (n * p) as u64);
    assert_eq!(disk.bill.waves.x_panel_words, (DEFAULT_PANEL_ROWS.min(n) * p) as u64);
    assert!(disk.bill.waves.x_panel_words < incore.bill.waves.x_panel_words);
}

fn random_mat(n: usize, p: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, p, |_, _| rng.normal())
}

/// Panel-read property: reads at every width — single-row panels, a
/// ragged final panel, one whole-matrix panel, a panel wider than the
/// matrix — concatenate to exactly the written rows.
#[test]
fn panel_reads_tile_the_matrix_at_every_width() {
    let n = DEFAULT_PANEL_ROWS + 44; // forces a ragged default panel too
    let x = random_mat(n, 7, 0xA11CE);
    let (_tmp, xd) = disk_fixture("panel_widths", &x);
    for width in [1usize, 7, n, n + 13] {
        let mut got: Vec<u64> = Vec::with_capacity(n * 7);
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + width).min(n);
            let panel = xd.read_rows(r0, r1).unwrap();
            assert_eq!(panel.rows(), r1 - r0, "width {width}: panel {r0}..{r1}");
            got.extend(panel.data().iter().map(|v| v.to_bits()));
            r0 = r1;
        }
        assert_eq!(got, bits(&x), "width {width}: payload drift");
    }
}

/// Column extraction property: empty, singleton, unsorted-with-repeats
/// and full index lists all equal the in-core gather element for
/// element — on a matrix tall enough that the on-disk walk crosses the
/// default panel boundary mid-extraction.
#[test]
fn column_extraction_matches_in_core_for_every_index_shape() {
    let n = DEFAULT_PANEL_ROWS + 44;
    let x = random_mat(n, 7, 0xBEE);
    let (_tmp, xd) = disk_fixture("extract_cols", &x);
    let incore = XSource::InCore(&x);
    let disk = XSource::OnDisk(&xd);
    let full: Vec<usize> = (0..7).collect();
    let cases: Vec<Vec<usize>> =
        vec![vec![], vec![3], vec![6, 0, 2, 6], full, vec![5, 4, 3, 2, 1, 0]];
    for idx in &cases {
        let a = incore.extract_columns(idx).unwrap();
        let b = disk.extract_columns(idx).unwrap();
        assert_eq!((b.rows(), b.cols()), (a.rows(), a.cols()), "idx {idx:?}");
        assert_eq!(bits(&b), bits(&a), "idx {idx:?}: element drift");
    }
}

/// Row-and-column extraction property: row lists that sit on, straddle
/// and repeat across the default panel boundary (and empty/singleton
/// lists) equal the in-core gather bit for bit — the lazy subsample
/// view the stability executor reads through.
#[test]
fn row_views_match_in_core_across_panel_boundaries() {
    let n = DEFAULT_PANEL_ROWS + 44;
    let x = random_mat(n, 6, 0xD15C);
    let (_tmp, xd) = disk_fixture("row_views", &x);
    let incore = XSource::InCore(&x);
    let disk = XSource::OnDisk(&xd);
    let straddle = vec![0, DEFAULT_PANEL_ROWS - 1, DEFAULT_PANEL_ROWS, n - 1];
    let row_cases: Vec<Vec<usize>> =
        vec![vec![], vec![n - 1], straddle, vec![3, 3, 2, DEFAULT_PANEL_ROWS]];
    let idx_cases: Vec<Vec<usize>> = vec![vec![], vec![0], vec![5, 1, 1]];
    for rows in &row_cases {
        for idx in &idx_cases {
            let a = incore.extract_rows_columns(rows, idx).unwrap();
            let b = disk.extract_rows_columns(rows, idx).unwrap();
            assert_eq!(bits(&b), bits(&a), "rows {rows:?} idx {idx:?}");
        }
        let a = incore.subsample(rows).unwrap();
        let b = disk.subsample(rows).unwrap();
        assert_eq!(bits(&b), bits(&a), "subsample rows {rows:?}");
    }
}
