//! PJRT-executed AOT artifacts vs their native Rust twins: the L1/L2
//! layers (Pallas kernels lowered through JAX) must agree with the L3
//! fallback to near machine precision for every artifact in the
//! manifest.
//!
//! Gated twice so the suite is a clean no-op wherever the PJRT runtime
//! cannot exist: the whole file compiles only with the `pjrt` cargo
//! feature (the default offline build has no `xla` crate or
//! `libxla_extension`), and at runtime each test additionally skips
//! (with a notice) when `make artifacts` has not been run.
#![cfg(feature = "pjrt")]

use hpconcord::concord::{
    fit_single_node, single_node::fit_single_node_with_engine, ConcordConfig, Variant,
};
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;
use hpconcord::runtime::{native, Engine};

fn engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(_) => {
            eprintln!("skipping PJRT tests: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn trial_artifacts_match_native_at_every_size() {
    let Some(mut engine) = engine() else { return };
    for p in engine.trial_sizes() {
        let mut rng = Rng::new(p as u64);
        let prob = gen::chain_problem(p, 50, &mut rng);
        let s = native::gram(&prob.x);
        let mut omega = Mat::eye(p);
        // Take one genuine prox step first so the trial sees a non-trivial
        // sparse iterate.
        let w = native::w_step(&omega, &s);
        let (grad, g0) = native::gradobj(&omega, &w, 0.1);
        omega = native::trial(&omega, &grad, &s, g0, 0.25, 0.3, 0.1).omega_new;
        let w = native::w_step(&omega, &s);
        let (grad, g0) = native::gradobj(&omega, &w, 0.1);

        for tau in [1.0, 0.5, 0.125] {
            let nat = native::trial(&omega, &grad, &s, g0, tau, 0.3, 0.1);
            let pjrt = engine.trial(&omega, &grad, &s, g0, tau, 0.3, 0.1).unwrap();
            assert!(
                pjrt.omega_new.max_abs_diff(&nat.omega_new) < 1e-10,
                "p={p} tau={tau}: omega mismatch"
            );
            assert!(pjrt.w_new.max_abs_diff(&nat.w_new) < 1e-9, "p={p}: w mismatch");
            assert!((pjrt.g_new - nat.g_new).abs() < 1e-8, "p={p}: g mismatch");
            assert!((pjrt.rhs - nat.rhs).abs() < 1e-8, "p={p}: rhs mismatch");
            assert_eq!(pjrt.accept, nat.accept, "p={p}: accept mismatch");
        }
    }
}

#[test]
fn gradobj_artifacts_match_native() {
    let Some(mut engine) = engine() else { return };
    for p in engine.trial_sizes() {
        let mut rng = Rng::new(100 + p as u64);
        let prob = gen::chain_problem(p, 40, &mut rng);
        let s = native::gram(&prob.x);
        let omega = Mat::eye(p);
        let w = native::w_step(&omega, &s);
        let (g_nat, v_nat) = native::gradobj(&omega, &w, 0.2);
        let (g_pjrt, v_pjrt) = engine.gradobj(&omega, &w, 0.2).unwrap();
        assert!(g_pjrt.max_abs_diff(&g_nat) < 1e-10, "p={p}");
        assert!((v_pjrt - v_nat).abs() < 1e-9, "p={p}");
    }
}

#[test]
fn gram_and_matmul_artifacts_match_native() {
    let Some(mut engine) = engine() else { return };
    // gram_n100_p256 (canonical shape from the manifest).
    let mut rng = Rng::new(1);
    let x = Mat::from_fn(100, 256, |_, _| rng.normal());
    if let Ok(s_pjrt) = engine.gram(&x) {
        assert!(s_pjrt.max_abs_diff(&native::gram(&x)) < 1e-10);
    }
    let a = Mat::from_fn(128, 128, |_, _| rng.normal());
    let b = Mat::from_fn(128, 128, |_, _| rng.normal());
    if let Ok(c_pjrt) = engine.matmul(&a, &b) {
        assert!(c_pjrt.max_abs_diff(&a.matmul(&b)) < 1e-9);
    }
}

/// The whole single-node solve, engine-backed vs native: identical
/// iterate sequences (the fused trial is the entire inner loop).
#[test]
fn engine_backed_solve_matches_native_solve() {
    let Some(mut engine) = engine() else { return };
    let Some(&p) = engine.trial_sizes().first() else { return };
    let mut rng = Rng::new(2);
    let prob = gen::chain_problem(p, 80, &mut rng);
    let cfg = ConcordConfig {
        lambda1: 0.35,
        lambda2: 0.1,
        tol: 1e-5,
        max_iter: 50,
        variant: Variant::Cov,
        ..Default::default()
    };
    let native_fit = fit_single_node(&prob.x, &cfg).unwrap();
    let engine_fit = fit_single_node_with_engine(&prob.x, &cfg, &mut engine).unwrap();
    assert_eq!(native_fit.iterations, engine_fit.iterations);
    assert!(native_fit.omega.max_abs_diff(&engine_fit.omega) < 1e-9);
}
