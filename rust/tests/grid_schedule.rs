//! Grid-level fabric orchestration: equivalence and billing suite for
//! the executor layer (`concord::executor`), the amortized
//! multi-threshold screening pass, and the cross-job packed
//! coordinators (`run_sweep_screened_dist`, `stability_selection_dist`).
//!
//! The contract under test: grid amortization and cross-job packing are
//! **schedule-only** (determinism rule 6 in `ARCHITECTURE.md`) —
//! every grid point's omega from the cross-packed amortized sweep is
//! bit-identical to standalone `fit_screened_distributed` on that
//! point, at every rank budget and thread count — while the grid bill
//! (one screening pass + the cross-job critical path) drops strictly
//! below the old per-point serial fold, with the screening gram billed
//! exactly once for the whole λ₁ list.

use hpconcord::concord::{fit_screened_distributed, ConcordConfig, ScreenedDistOptions, Variant};
use hpconcord::coordinator::{
    run_sweep_screened_dist, select_by_density, stability_selection, stability_selection_dist,
    subsample_rows, GridSchedule, GridSpec, StabilityConfig, SweepResult,
};
use hpconcord::cost::MemFootprint;
use hpconcord::io::XSource;
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;

mod common;
use common::disjoint_blocks;

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// A machine whose flops dwarf its communication: the planner then
/// gives even small screened components multi-rank fabrics, so the
/// budget sweep genuinely exercises cross-job packing and shrinking.
fn flop_heavy() -> MachineParams {
    MachineParams {
        alpha: 1.0e-13,
        beta: 1.0e-13,
        gamma_dense: 1.0e-6,
        gamma_sparse: 8.0e-6,
        beta_mem: 0.0,
    }
}

fn grid() -> GridSpec {
    GridSpec { lambda1: vec![0.02, 0.05], lambda2: vec![0.1, 0.3] }
}

fn base_cfg(threads: usize, budget: usize) -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.02,
        lambda2: 0.1,
        tol: 0.0, // fixed budget: every component runs exactly max_iter
        max_iter: 6,
        variant: Variant::Cov,
        threads,
        ranks_budget: budget,
        ..Default::default()
    }
}

fn dist_opts() -> ScreenedDistOptions {
    ScreenedDistOptions {
        total_ranks: 8,
        machine: flop_heavy(),
        small_cutoff: 0,
        fixed: None,
        sequential: false,
        gram_block: 0,
    }
}

/// ISSUE acceptance: every grid point's omega from the cross-packed
/// amortized sweep is bit-identical to standalone
/// `fit_screened_distributed` on that point, across budgets
/// {1, 4, 32} × threads {1, 4} — and the per-point reference mode of
/// the sweep agrees bit for bit too.
#[test]
fn packed_sweep_bit_identical_to_standalone_points() {
    // Four blocks at λ₁ up to 0.05 need n_each = 800 (measured 5.3σ at
    // 0.05, 8.3σ at 0.02 — tools/verify_fixture_margins.py).
    let x = disjoint_blocks(&[10, 10, 10, 10], 800, 0x9A1D);
    let grid = grid();
    let opts = dist_opts();
    for budget in [1usize, 4, 32] {
        for threads in [1usize, 4] {
            let base = base_cfg(threads, budget);
            let tag = format!("budget {budget} threads {threads}");
            let packed =
                run_sweep_screened_dist(xs, &grid, &base, &opts, GridSchedule::Packed).unwrap();
            let per_point =
                run_sweep_screened_dist(xs, &grid, &base, &opts, GridSchedule::PerPoint).unwrap();
            assert_eq!(packed.results.len(), 4, "{tag}");
            assert_eq!(packed.results.len(), per_point.results.len(), "{tag}");
            for (rp, rs) in packed.results.iter().zip(&per_point.results) {
                assert_eq!(rp.job.id, rs.job.id, "{tag}");
                assert_eq!(
                    bits(&rp.fit.omega),
                    bits(&rs.fit.omega),
                    "{tag}: packed vs per-point drift at job {}",
                    rp.job.id
                );
            }
            for r in &packed.results {
                let direct = fit_screened_distributed(xs, &r.job.cfg, &opts).unwrap();
                assert_eq!(
                    bits(&r.fit.omega),
                    bits(&direct.fit.omega),
                    "{tag}: job {} differs from the standalone solver",
                    r.job.id
                );
                assert_eq!(r.fit.iterations, direct.fit.iterations, "{tag}");
                assert_eq!(
                    r.fit.objective.to_bits(),
                    direct.fit.objective.to_bits(),
                    "{tag}: objective accumulation must not depend on the schedule"
                );
            }
            // Component counts line up with the standalone decomposition.
            assert_eq!(packed.components, per_point.components, "{tag}");
        }
    }
}

/// ISSUE acceptance: on a multi-point multi-block fixture the grid
/// bill (one screening pass + cross-job critical path) is strictly
/// below the old per-point serial fold, and the screening gram is
/// billed exactly once for the whole grid.
#[test]
fn grid_bill_undercuts_per_point_fold_and_gram_is_billed_once() {
    // Unequal block sizes → unequal fabric plans (the p = 12 component
    // wants 8 ranks, the p = 6 ones 4), so the 32-rank budget provably
    // packs fabrics from different grid points into one wave: LPT
    // schedules the four jobs' p = 12 fabrics first, and 4 × 8 ranks
    // fill wave 0 with four different jobs.
    let x = disjoint_blocks(&[12, 6, 6, 6], 800, 0x6B11);
    let xs = XSource::InCore(&x);
    let grid = grid();
    let base = base_cfg(1, 32);
    let opts = dist_opts();
    let packed = run_sweep_screened_dist(xs, &grid, &base, &opts, GridSchedule::Packed).unwrap();
    let per_point =
        run_sweep_screened_dist(xs, &grid, &base, &opts, GridSchedule::PerPoint).unwrap();

    // The shared schedule really packs across jobs: some wave holds
    // fabrics from at least two different grid points.
    assert_eq!(packed.schedules.len(), 1);
    let sched = &packed.schedules[0];
    assert!(
        sched.waves.iter().any(|w| {
            w.entries.iter().any(|e| e.tag.job != w.entries[0].tag.job)
        }),
        "a wave must mix fabrics from different grid points"
    );

    // One screening pass for the whole grid: its gram flops equal a
    // single standalone point's, not four of them — and the labeling
    // collective's messages are paid once too (allgather messages are
    // payload-size independent).
    let standalone =
        fit_screened_distributed(xs, &packed.results[0].job.cfg, &opts).unwrap();
    assert_eq!(
        packed.bill.screen.total.flops_dense, standalone.screen_cost.total.flops_dense,
        "amortized screening must form the gram exactly once"
    );
    assert_eq!(
        packed.bill.screen.total.messages, standalone.screen_cost.total.messages,
        "amortized screening must gather labelings in one collective"
    );
    assert_eq!(
        per_point.bill.screen.total.flops_dense,
        4 * standalone.screen_cost.total.flops_dense,
        "the per-point fold pays the gram once per grid point"
    );

    // The grid bill is strictly below the old per-point serial fold.
    assert!(
        packed.cost.time < per_point.cost.time,
        "grid bill {} must be strictly below the per-point fold {}",
        packed.cost.time,
        per_point.cost.time
    );
    // And internally consistent: screening + waves, never above the
    // no-packing serial view of the same work.
    let total = packed.bill.total();
    assert!((packed.cost.time - total.time).abs() < 1e-15);
    assert_eq!(packed.cost.total, total.total);
    assert!(packed.bill.total().time <= packed.bill.sequential().time + 1e-15);
}

/// The executor's sequential reference mode launches the same packed
/// plans one at a time — results bit-identical, bill never below the
/// concurrent critical path.
#[test]
fn packed_sweep_sequential_reference_is_bit_identical() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 800, 0x5E9);
    let xs = XSource::InCore(&x);
    let grid = grid();
    let base = base_cfg(2, 32);
    let conc =
        run_sweep_screened_dist(xs, &grid, &base, &dist_opts(), GridSchedule::Packed).unwrap();
    let seq_opts = ScreenedDistOptions { sequential: true, ..dist_opts() };
    let seq =
        run_sweep_screened_dist(xs, &grid, &base, &seq_opts, GridSchedule::Packed).unwrap();
    for (a, b) in conc.results.iter().zip(&seq.results) {
        assert_eq!(bits(&a.fit.omega), bits(&b.fit.omega), "job {}", a.job.id);
    }
    assert_eq!(conc.cost.total, seq.cost.total, "counters are machine facts");
    assert!(conc.cost.time <= seq.cost.time + 1e-15);
}

/// Determinism rule 7 at the grid level: a memory budget tight enough
/// to force one fabric per wave leaves every grid point's omega (and
/// the counter totals) bit-identical to the unbounded packed sweep —
/// only the wave layout and the modeled peak residency move.
#[test]
fn packed_sweep_bit_identical_under_tight_memory_budget() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 800, 0x9A1D);
    let xs = XSource::InCore(&x);
    let grid = grid();
    let opts = dist_opts();
    let unbounded =
        run_sweep_screened_dist(xs, &grid, &base_cfg(4, 32), &opts, GridSchedule::Packed).unwrap();
    // Every component is a 10-column block of the 3200-row fixture.
    let tight = MemFootprint::for_component(x.rows(), 10).words();
    let base = ConcordConfig { mem_budget: tight, ..base_cfg(4, 32) };
    let bounded = run_sweep_screened_dist(xs, &grid, &base, &opts, GridSchedule::Packed).unwrap();
    for (a, b) in bounded.results.iter().zip(&unbounded.results) {
        assert_eq!(a.job.id, b.job.id);
        assert_eq!(bits(&a.fit.omega), bits(&b.fit.omega), "job {}", a.job.id);
    }
    assert_eq!(bounded.cost.total, unbounded.cost.total, "counters are machine facts");
    assert_eq!(bounded.schedules.len(), 1);
    let sched = &bounded.schedules[0];
    for wave in &sched.waves {
        assert!(wave.mem_words() <= tight, "wave over the memory budget");
        assert_eq!(wave.entries.len(), 1, "tight budget: one fabric per wave");
    }
    assert!(
        bounded.bill.waves.peak_mem_words < unbounded.bill.waves.peak_mem_words,
        "tight budget must shrink the modeled peak"
    );
}

fn stability_base() -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.1,
        lambda2: 0.05,
        tol: 1e-4,
        max_iter: 150,
        variant: Variant::Cov,
        ..Default::default()
    }
}

/// Subsample wiring: with the seed fixed, the dist path fits exactly
/// the subsamples `subsample_rows` describes — its frequency matrix is
/// bit-identical to accumulating standalone screened-distributed fits
/// on the rebuilt subsamples, in subsample order.
#[test]
fn stability_dist_subsample_wiring_matches_direct_fits() {
    let mut rng = Rng::new(21);
    let prob = gen::chain_problem(10, 120, &mut rng);
    let (n, p) = prob.x.shape();
    let base = stability_base();
    let cfg = StabilityConfig { subsamples: 3, seed: 17, workers: 1, ..Default::default() };
    let machine = MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() };
    let opts = ScreenedDistOptions { total_ranks: 4, machine, ..Default::default() };
    let out = stability_selection_dist(XSource::InCore(&prob.x), &base, &cfg, &opts).unwrap();

    let m = ((n as f64) * cfg.fraction).round().max(2.0) as usize;
    let mut want = Mat::zeros(p, p);
    for b in 0..cfg.subsamples {
        let rows = subsample_rows(n, m, cfg.seed, b);
        let sub = Mat::from_fn(m, p, |i, j| prob.x.get(rows[i], j));
        let fit = fit_screened_distributed(XSource::InCore(&sub), &base, &opts).unwrap();
        for i in 0..p {
            for j in 0..p {
                if i != j && fit.fit.omega.get(i, j) != 0.0 {
                    want.set(i, j, want.get(i, j) + 1.0 / cfg.subsamples as f64);
                }
            }
        }
    }
    assert!(out.frequency.max_abs_diff(&want) == 0.0, "frequency drift vs rebuilt subsamples");
    assert_eq!(out.subsamples, 3);
    assert_eq!(out.bill.per_job.len(), 3);
}

/// Determinism across thread counts and repeated runs: the shared
/// cross-subsample schedule changes nothing — frequencies, edges, and
/// counter totals are identical at any `threads`.
#[test]
fn stability_dist_thread_count_invariant() {
    let mut rng = Rng::new(22);
    let prob = gen::chain_problem(10, 120, &mut rng);
    let cfg = StabilityConfig { subsamples: 4, seed: 11, workers: 1, ..Default::default() };
    let machine = MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() };
    let opts = ScreenedDistOptions { total_ranks: 4, machine, ..Default::default() };
    let mut runs = Vec::new();
    for threads in [1usize, 4, 1] {
        let base = ConcordConfig { threads, ..stability_base() };
        runs.push(stability_selection_dist(XSource::InCore(&prob.x), &base, &cfg, &opts).unwrap());
    }
    for r in &runs[1..] {
        assert!(runs[0].frequency.max_abs_diff(&r.frequency) == 0.0);
        assert_eq!(runs[0].edges, r.edges);
        assert_eq!(runs[0].cost.total, r.cost.total, "counters must be thread-invariant");
    }
    assert!(runs[0].cost.total.messages > 0, "screening passes must be metered");
}

/// Stable-edge agreement with the single-node stability path on a
/// wide-margin block fixture: both paths draw the same subsamples
/// (shared `subsample_rows` stream), and with strong within-block
/// chain signal and exactly-zero cross-block gram entries the stable
/// edge sets coincide.
#[test]
fn stability_dist_stable_edges_agree_with_single_node_path() {
    // Subsamples keep half the rows, so the full-gram margin carries
    // ~√2 extra sigma: measured 5.9σ at λ₁ = 0.1 with n_each = 800.
    let x = disjoint_blocks(&[8, 8], 800, 0xED6E);
    let base = stability_base();
    let cfg = StabilityConfig {
        subsamples: 6,
        fraction: 0.5,
        threshold: 0.7,
        seed: 5,
        workers: 2,
    };
    let machine = MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() };
    let opts = ScreenedDistOptions { total_ranks: 4, machine, ..Default::default() };
    let single = stability_selection(&x, &base, &cfg);
    let dist = stability_selection_dist(XSource::InCore(&x), &base, &cfg, &opts).unwrap();
    assert!(!dist.edges.is_empty(), "no stable edges found");
    assert_eq!(dist.edges, single.edges, "stable edge sets must agree");
    // No stable edge crosses the (exactly screened-apart) blocks.
    for &(i, j) in &dist.edges {
        assert_eq!(i / 8, j / 8, "cross-block stable edge ({i}, {j})");
    }
}

/// `select_by_density` survives NaN densities (and NaN targets):
/// total_cmp sorts NaN distances last, so a finite candidate wins.
#[test]
fn select_by_density_is_nan_safe() {
    let mut rng = Rng::new(23);
    let prob = gen::chain_problem(8, 60, &mut rng);
    let grid = GridSpec { lambda1: vec![0.2, 0.6], lambda2: vec![0.0] };
    let base = ConcordConfig { max_iter: 40, ..Default::default() };
    let out = hpconcord::coordinator::run_sweep(&prob.x, &grid, &base, 2);
    let mut results: Vec<SweepResult> = out.results;
    results[0].density = f64::NAN;
    let sel = select_by_density(&results, 0.0).expect("non-empty");
    assert_eq!(sel.job.id, 1, "the finite density must win over NaN");
    // NaN target: no panic, some result comes back.
    assert!(select_by_density(&results, f64::NAN).is_some());
    assert!(select_by_density(&[], 0.1).is_none());
}
