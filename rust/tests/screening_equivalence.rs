//! Screened solving: equivalence and property suite.
//!
//! - `covariance_components` against a brute-force label-propagation
//!   reference on randomized symmetric matrices plus edge cases
//!   (threshold 0 on a dense matrix → one component; threshold above
//!   max |S_ij| → all singletons; p ∈ {1, 2});
//! - the ISSUE's acceptance pair: on a *connected* problem the screened
//!   distributed solver is bit-identical to the unscreened fabric run
//!   (same rank program, same schedule); on a k-block problem it runs k
//!   independent fabrics whose summed flop counters are strictly below
//!   the single-fabric count;
//! - per-block bitwise equivalence of both screened paths against plain
//!   `fit_single_node` on the extracted component columns;
//! - the regression pinning the fixed iteration-statistics semantics:
//!   `iterations` *sums* across components and `mean_linesearch` is the
//!   trial-weighted mean (the old code took the max and divided by it).

use hpconcord::concord::screening::{
    covariance_components, extract_columns, gram_components, nested_components,
};
use hpconcord::concord::{
    fit_distributed, fit_screened_distributed, fit_single_node, fit_with_screening,
    ConcordConfig, ScreenedDistOptions, Variant,
};
use hpconcord::io::XSource;
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;
use hpconcord::prop_assert;
use hpconcord::runtime::native;
use hpconcord::util::proptest::check;

mod common;
use common::disjoint_blocks;

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// Brute-force connected-components reference: propagate minimum labels
/// across thresholded edges until fixpoint, then renumber densely by
/// first appearance — an algorithm with nothing in common with the
/// union-find under test.
fn reference_components(s: &Mat, thr: f64) -> Vec<usize> {
    let p = s.rows();
    let mut label: Vec<usize> = (0..p).collect();
    loop {
        let mut changed = false;
        for i in 0..p {
            for j in 0..p {
                if i != j && (s.get(i, j).abs() > thr || s.get(j, i).abs() > thr) {
                    let m = label[i].min(label[j]);
                    if label[i] != m {
                        label[i] = m;
                        changed = true;
                    }
                    if label[j] != m {
                        label[j] = m;
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
    let mut map = std::collections::HashMap::new();
    label
        .iter()
        .map(|&r| {
            let next = map.len();
            *map.entry(r).or_insert(next)
        })
        .collect()
}

/// A random symmetric matrix with all off-diagonal magnitudes in
/// (lo, lo + span) — every entry is nonzero, so threshold 0 must give a
/// single component.
fn random_symmetric(rng: &mut Rng, p: usize, lo: f64, span: f64) -> Mat {
    let mut s = Mat::eye(p);
    for i in 0..p {
        for j in (i + 1)..p {
            let sign = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
            let v = sign * (lo + span * rng.uniform());
            s.set(i, j, v);
            s.set(j, i, v);
        }
    }
    s
}

#[test]
fn prop_components_match_brute_force_reference() {
    check(0x5c4ee, 30, |rng| {
        let p = match rng.below(5) {
            0 => 1,
            1 => 2,
            _ => 3 + rng.below(14) as usize,
        };
        let s = random_symmetric(rng, p, 0.05, 0.9);
        for _ in 0..3 {
            let thr = rng.uniform();
            let got = covariance_components(&s, thr);
            let want = reference_components(&s, thr);
            prop_assert!(got == want, "p={p} thr={thr}: {got:?} != {want:?}");
        }
        // Edge cases on the same matrix: every off-diagonal exceeds 0,
        // so threshold 0 is one component; anything above the max
        // magnitude is all singletons.
        let zero = covariance_components(&s, 0.0);
        prop_assert!(zero.iter().all(|&c| c == 0), "threshold 0 must connect: {zero:?}");
        let hi = covariance_components(&s, 2.0);
        prop_assert!(
            hi == (0..p).collect::<Vec<_>>(),
            "threshold > max must isolate: {hi:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_nested_components_match_direct() {
    check(0x0e57ed, 20, |rng| {
        let p = 2 + rng.below(12) as usize;
        let s = random_symmetric(rng, p, 0.0, 1.0);
        let thresholds: Vec<f64> = (0..1 + rng.below(4) as usize)
            .map(|_| rng.uniform())
            .collect();
        let nested = nested_components(&s, &thresholds);
        for (k, &thr) in thresholds.iter().enumerate() {
            let direct = gram_components(&s, thr);
            prop_assert!(
                nested[k] == direct,
                "p={p} thr={thr}: nested {:?} != direct {:?}",
                nested[k].comp,
                direct.comp
            );
        }
        Ok(())
    });
}

fn screened_cfg() -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.05,
        lambda2: 0.1,
        tol: 1e-6,
        max_iter: 60,
        variant: Variant::Cov,
        ..Default::default()
    }
}

/// Acceptance, part 1: with the threshold below every off-diagonal
/// |S_ij| the graph is connected — one component spanning everything —
/// and the screened distributed solver must reproduce the unscreened
/// fabric run *identically*: same omega bits, same iteration count,
/// same solve-fabric counters.
#[test]
fn connected_problem_screened_dist_identical_to_unscreened() {
    let mut rng = Rng::new(0xC0DE);
    let problem = gen::chain_problem(16, 200, &mut rng);
    let cfg = screened_cfg();
    let s = native::gram(&problem.x);
    assert_eq!(
        gram_components(&s, cfg.lambda1).count,
        1,
        "fixture must be connected at λ1 = {}",
        cfg.lambda1
    );

    let machine = MachineParams::edison_like();
    let plain = fit_distributed(&problem.x, &cfg, 4, 2, 2, machine);
    let opts = ScreenedDistOptions {
        total_ranks: 4,
        machine,
        small_cutoff: 0,
        fixed: Some((4, 2, 2)),
        sequential: false,
        gram_block: 0,
    };
    let screened = fit_screened_distributed(XSource::InCore(&problem.x), &cfg, &opts).unwrap();

    assert_eq!(screened.components, 1);
    assert_eq!(screened.solves.len(), 1);
    assert_eq!(bits(&screened.fit.omega), bits(&plain.fit.omega), "omega must be identical");
    assert_eq!(screened.fit.iterations, plain.fit.iterations);
    assert_eq!(screened.fit.objective.to_bits(), plain.fit.objective.to_bits());
    // The one component fabric metered exactly what the unscreened
    // fabric metered.
    assert_eq!(screened.solves[0].cost.total, plain.cost.total);
    assert_eq!(screened.solves[0].cost.max_per_rank, plain.cost.max_per_rank);
}

/// Acceptance, part 2: a k-block problem runs k independent fabrics
/// whose *summed* flop counters are strictly below the single-fabric
/// count (under an identical fixed iteration budget), and the estimate
/// is exactly block-diagonal.
#[test]
fn k_block_problem_runs_k_smaller_fabrics() {
    let sizes = [12usize, 12];
    let x = disjoint_blocks(&sizes, 200, 0xB10C);
    let mut cfg = screened_cfg();
    cfg.tol = 0.0; // fixed budget: both paths run exactly max_iter
    cfg.max_iter = 8;

    let machine = MachineParams::edison_like();
    let plain = fit_distributed(&x, &cfg, 4, 2, 2, machine);
    let opts = ScreenedDistOptions {
        total_ranks: 4,
        machine,
        small_cutoff: 0,
        fixed: Some((4, 2, 2)),
        sequential: false,
        gram_block: 0,
    };
    let screened = fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap();

    assert_eq!(screened.components, sizes.len());
    assert_eq!(screened.solves.len(), sizes.len(), "every block gets its own fabric");
    for sv in &screened.solves {
        assert_eq!(sv.plan.ranks, 4);
        assert!(!sv.counters.is_empty());
    }
    let screened_flops: u64 = screened
        .solves
        .iter()
        .map(|sv| sv.cost.total.flops_dense + sv.cost.total.flops_sparse)
        .sum();
    let plain_flops = plain.cost.total.flops_dense + plain.cost.total.flops_sparse;
    assert!(
        screened_flops < plain_flops,
        "summed per-component flops {screened_flops} must undercut the \
         single fabric's {plain_flops}"
    );
    // Exactly block-diagonal: no cross-component entry was ever touched.
    for i in 0..sizes[0] {
        for j in sizes[0]..(sizes[0] + sizes[1]) {
            assert_eq!(screened.fit.omega.get(i, j), 0.0, "cross entry ({i},{j})");
            assert_eq!(screened.fit.omega.get(j, i), 0.0, "cross entry ({j},{i})");
        }
    }
}

/// Per-block bitwise equivalence: both screened paths solve each
/// component by running the plain single-node solver on the extracted
/// columns, so each block of their omega is bit-for-bit the standalone
/// `fit_single_node` estimate (screened-dist routed through the
/// single-node path via `small_cutoff`).
#[test]
fn screened_paths_match_single_node_bitwise_per_block() {
    let sizes = [10usize, 8];
    let x = disjoint_blocks(&sizes, 400, 0xB17);
    let cfg = screened_cfg();

    let s = native::gram(&x);
    let comps = gram_components(&s, cfg.lambda1);
    assert_eq!(comps.count, 2, "disjoint blocks must split exactly in two");

    let screened = fit_with_screening(&x, &cfg).unwrap();
    let opts = ScreenedDistOptions {
        total_ranks: 8,
        machine: MachineParams::edison_like(),
        small_cutoff: 64, // force every component onto the single-node path
        fixed: None,
        sequential: false,
        gram_block: 0,
    };
    let sdist = fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap();
    assert_eq!(sdist.components, 2);
    assert_eq!(
        bits(&screened.fit.omega),
        bits(&sdist.fit.omega),
        "single-node and distributed screened paths must agree bitwise"
    );

    for c in 0..comps.count {
        let idx = comps.members(c);
        let sub = fit_single_node(&extract_columns(&x, idx), &cfg).unwrap();
        for (a, &i) in idx.iter().enumerate() {
            for (b, &j) in idx.iter().enumerate() {
                assert_eq!(
                    screened.fit.omega.get(i, j).to_bits(),
                    sub.omega.get(a, b).to_bits(),
                    "component {c} entry ({i},{j}) is not the standalone solve"
                );
            }
        }
    }
}

/// The fabric-backed screened path stays within distributed-vs-serial
/// tolerance of the standalone per-block solves.
#[test]
fn screened_dist_fabric_blocks_match_single_node_closely() {
    let sizes = [12usize, 12];
    let x = disjoint_blocks(&sizes, 400, 0xFAB);
    let cfg = screened_cfg();
    let opts = ScreenedDistOptions {
        total_ranks: 4,
        machine: MachineParams::edison_like(),
        small_cutoff: 0,
        fixed: Some((4, 2, 2)),
        sequential: false,
        gram_block: 0,
    };
    let sdist = fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap();
    assert_eq!(sdist.components, 2);
    for sv in &sdist.solves {
        let sub = fit_single_node(&extract_columns(&x, &sv.indices), &cfg).unwrap();
        for (a, &i) in sv.indices.iter().enumerate() {
            for (b, &j) in sv.indices.iter().enumerate() {
                let diff = (sdist.fit.omega.get(i, j) - sub.omega.get(a, b)).abs();
                assert!(diff < 1e-8, "entry ({i},{j}) off by {diff}");
            }
        }
    }
}

/// Regression pinning the iteration-statistics semantics: `iterations`
/// sums across components (the old code took the max while
/// `mean_linesearch` divided by it), `mean_linesearch` is the
/// trial-weighted mean, and the per-component stats expose each
/// block's own counts.
#[test]
fn iteration_stats_sum_across_components() {
    let sizes = [10usize, 6];
    let x = disjoint_blocks(&sizes, 400, 0x57A7);
    let mut cfg = screened_cfg();
    cfg.tol = 1e-5;
    cfg.max_iter = 150;

    let s = native::gram(&x);
    let comps = gram_components(&s, cfg.lambda1);
    assert_eq!(comps.count, 2);
    let a = fit_single_node(&extract_columns(&x, comps.members(0)), &cfg).unwrap();
    let b = fit_single_node(&extract_columns(&x, comps.members(1)), &cfg).unwrap();
    assert!(a.iterations >= 1 && b.iterations >= 1);

    let screened = fit_with_screening(&x, &cfg).unwrap();
    assert_eq!(
        screened.fit.iterations,
        a.iterations + b.iterations,
        "iterations must sum across components"
    );
    assert!(
        screened.fit.iterations > a.iterations.max(b.iterations),
        "sum semantics must be distinguishable from the old max semantics"
    );
    let want_mean = (a.mean_linesearch * a.iterations as f64
        + b.mean_linesearch * b.iterations as f64)
        / (a.iterations + b.iterations) as f64;
    assert!(
        (screened.fit.mean_linesearch - want_mean).abs() < 1e-12,
        "mean_linesearch must be the trial-weighted mean: {} vs {want_mean}",
        screened.fit.mean_linesearch
    );
    assert!((screened.fit.objective - (a.objective + b.objective)).abs() < 1e-12);

    assert_eq!(screened.per_component.len(), 2);
    assert_eq!(screened.per_component[0].size, sizes[0]);
    assert_eq!(screened.per_component[1].size, sizes[1]);
    assert_eq!(screened.per_component[0].iterations, a.iterations);
    assert_eq!(screened.per_component[1].iterations, b.iterations);

    // The distributed composition reports the same summed semantics.
    let opts = ScreenedDistOptions {
        total_ranks: 4,
        machine: MachineParams::edison_like(),
        small_cutoff: 64,
        fixed: None,
        sequential: false,
        gram_block: 0,
    };
    let sdist = fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap();
    assert_eq!(sdist.fit.iterations, a.iterations + b.iterations);
    assert_eq!(sdist.per_component.len(), 2);
}
