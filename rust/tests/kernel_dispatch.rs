//! Determinism rule 10 end to end: the ISA kernel lane (`--kernel`),
//! the `--tile auto` calibration and worker→core pinning
//! (`--pin-cores`) are **value-preserving** knobs — whole fits return
//! byte-identical estimates, objectives and metered counters on every
//! available lane, at any calibrated tile, pinned or not.
//!
//! Lanes the host lacks are skipped with an explicit reason on stderr
//! (never silently passed): on a non-AVX host these tests still pin
//! scalar-vs-auto equality, which is the dispatch seam itself.

use hpconcord::concord::{fit_distributed, fit_single_node, ConcordConfig, Variant};
use hpconcord::linalg::{dense, simd, tile, KernelLane, Mat, TileConfig};
use hpconcord::prelude::*;
use hpconcord::util::pool;

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// Every lane this host can run (always includes `Scalar` — the oracle
/// — and `Auto` — the dispatch seam), with a printed reason for each
/// skipped one so a green run on a narrow host is auditable.
fn available_lanes() -> Vec<KernelLane> {
    let mut lanes = vec![KernelLane::Scalar];
    for lane in [KernelLane::Avx2, KernelLane::Avx512] {
        if lane.available() {
            lanes.push(lane);
        } else {
            eprintln!("skipping {} lane: host does not support it", lane.as_str());
        }
    }
    lanes.push(KernelLane::Auto);
    lanes
}

fn base_cfg() -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.25,
        lambda2: 0.05,
        tol: 1e-6,
        max_iter: 80,
        variant: Variant::Cov,
        ..Default::default()
    }
}

/// The acceptance matrix: every available lane × threads {1, 4} × tile
/// {default, auto-calibrated} returns the scalar reference's exact
/// bytes from a whole single-node fit. `--out-omega` writes a pure
/// function of these bits, so byte-equal omegas here are byte-equal
/// files there.
#[test]
fn fit_is_byte_identical_across_lanes_threads_and_auto_tile() {
    let mut rng = Rng::new(0xA51);
    let problem = gen::chain_problem(48, 60, &mut rng);
    let base = base_cfg();
    let reference =
        fit_single_node(&problem.x, &ConcordConfig { kernel: KernelLane::Scalar, ..base })
            .unwrap();
    // One calibration sweep, reused across the matrix (what `--tile
    // auto` installs); whichever candidate wins, bits may not move.
    let calibrated = dense::calibrate_tile().winner;
    assert!(tile::AUTO_CANDIDATES.contains(&calibrated));
    for lane in available_lanes() {
        for threads in [1usize, 4] {
            for tile in [TileConfig::DEFAULT, calibrated] {
                let cfg = ConcordConfig { kernel: lane, threads, tile, ..base };
                let fit = fit_single_node(&problem.x, &cfg).unwrap();
                let tag = format!("lane={} t={threads} tile={tile}", lane.as_str());
                assert_eq!(fit.iterations, reference.iterations, "{tag}");
                assert_eq!(fit.objective.to_bits(), reference.objective.to_bits(), "{tag}");
                assert_eq!(
                    bits(&fit.omega),
                    bits(&reference.omega),
                    "{tag}: estimate not byte-identical to the scalar lane"
                );
            }
        }
    }
}

/// The distributed fit's metered α-β-γ counters are lane-invariant too:
/// a wider lane moves wall-clock, never the paper's L/W counts or the
/// assembled estimate.
#[test]
fn fit_distributed_counters_and_bytes_are_lane_invariant() {
    let mut rng = Rng::new(0xA52);
    let problem = gen::chain_problem(32, 40, &mut rng);
    let base = base_cfg();
    let run = |kernel: KernelLane, threads: usize| {
        let cfg = ConcordConfig { kernel, threads, ..base };
        fit_distributed(&problem.x, &cfg, 8, 2, 2, MachineParams::edison_like())
    };
    let reference = run(KernelLane::Scalar, 1);
    for lane in available_lanes() {
        for threads in [1usize, 4] {
            let out = run(lane, threads);
            let tag = format!("lane={} t={threads}", lane.as_str());
            assert_eq!(out.fit.iterations, reference.fit.iterations, "{tag}");
            assert_eq!(
                bits(&out.fit.omega),
                bits(&reference.fit.omega),
                "{tag}: estimate moved"
            );
            assert_eq!(out.cost.total, reference.cost.total, "{tag}: total counters moved");
            assert_eq!(
                out.cost.max_per_rank, reference.cost.max_per_rank,
                "{tag}: per-rank max counters moved"
            );
        }
    }
}

/// `install` resolves `Auto` to a concrete available lane, and the
/// blocked GEMM reproduces the naive oracle's bits under every lane the
/// host offers — the kernel seam the whole-fit tests above rest on.
#[test]
fn installed_lanes_reproduce_the_naive_oracle() {
    let mut rng = Rng::new(0xA53);
    let a = Mat::from_fn(131, 67, |_, _| rng.normal());
    let b = Mat::from_fn(67, 75, |_, _| rng.normal());
    let oracle = a.matmul_naive(&b);
    let prev = simd::active();
    for lane in available_lanes() {
        let resolved = simd::install(lane);
        assert_ne!(resolved, KernelLane::Auto, "install must return a concrete lane");
        assert!(resolved.available());
        let c = a.matmul(&b);
        assert_eq!(bits(&oracle), bits(&c), "lane {} != naive", lane.as_str());
    }
    simd::install(prev);
}

/// Pinning is schedule-only end to end: the same fit pinned and
/// unpinned (at a thread count that actually spawns workers) returns
/// identical bytes and counters.
#[test]
fn pin_cores_is_schedule_only_end_to_end() {
    let mut rng = Rng::new(0xA54);
    let problem = gen::chain_problem(48, 60, &mut rng);
    let base = ConcordConfig { threads: 4, ..base_cfg() };
    let unpinned = fit_single_node(&problem.x, &ConcordConfig { pin_cores: false, ..base })
        .unwrap();
    let pinned =
        fit_single_node(&problem.x, &ConcordConfig { pin_cores: true, ..base }).unwrap();
    assert_eq!(unpinned.iterations, pinned.iterations);
    assert_eq!(unpinned.objective.to_bits(), pinned.objective.to_bits());
    assert_eq!(bits(&unpinned.omega), bits(&pinned.omega), "pinning moved a result bit");
    // Leave the process-wide switch where the other tests expect it.
    pool::set_pin_cores(false);
}

/// The calibration sweep itself: times every published candidate, picks
/// one of them, and the summary names the winner. (Which candidate wins
/// is host-dependent by design — rule 10 makes any outcome sound.)
#[test]
fn calibration_times_every_candidate_and_picks_one() {
    let cal = dense::calibrate_tile();
    assert_eq!(cal.timings.len(), tile::AUTO_CANDIDATES.len());
    assert!(tile::AUTO_CANDIDATES.contains(&cal.winner));
    for (cand, secs) in &cal.timings {
        assert!(*secs > 0.0, "non-positive timing for {cand}");
    }
    assert!(cal.summary().contains(&cal.winner.to_string()), "{}", cal.summary());
}
