//! Error-path coverage for the scheduling knobs added in PRs 4–6:
//! misuse must surface as a clean `anyhow` error (or a *defined*
//! degenerate result), never as a panic inside a spawned rank thread.
//!
//! Covered here: an oversized or non-runnable `--cx`/`--comega` pin vs
//! `--ranks-budget`, a `--mem-budget` below the largest screened
//! component at the sweep level, and NaN screening cutoffs (a
//! user-typed `--l1 nan` threshold admits no edges, so screening
//! degrades to all-singleton components instead of poisoning the
//! union-find). The CLI-flag guards themselves (`--per-point` outside
//! `--mode dist`, unknown `--mode`) are unit-tested next to the parser
//! in `src/main.rs`.
//!
//! PR 8 adds the on-disk X format's failure modes: a truncated,
//! mis-magicked, wrong-version or length-inconsistent HPCX file — and a
//! nonexistent `--x-file` path — are clean `anyhow` errors from
//! `XDisk::open`, and a failed `write_x` leaves no partial output file.

use hpconcord::concord::screening::{gram_components, nested_components};
use hpconcord::concord::{
    fit_screened_distributed, fit_with_screening, ConcordConfig, ScreenedDistOptions, Variant,
};
use hpconcord::coordinator::{run_sweep_screened_dist, GridSchedule, GridSpec};
use hpconcord::cost::MemFootprint;
use hpconcord::io::{write_x, XDisk, XSource};
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;
use hpconcord::runtime::native;

mod common;
use common::{disjoint_blocks, TempPath};

fn base_cfg() -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.02,
        lambda2: 0.1,
        tol: 0.0,
        max_iter: 4,
        variant: Variant::Obs,
        ..Default::default()
    }
}

/// Flop-heavy machine (as in memory_budget.rs): the planner gives even
/// small screened components multi-rank fabrics, so every component
/// enters the wave packer and the budget checks genuinely bind.
fn flop_heavy() -> MachineParams {
    MachineParams {
        alpha: 1.0e-13,
        beta: 1.0e-13,
        gamma_dense: 1.0e-6,
        gamma_sparse: 8.0e-6,
        beta_mem: 0.0,
    }
}

fn dist_opts() -> ScreenedDistOptions {
    ScreenedDistOptions {
        total_ranks: 8,
        machine: flop_heavy(),
        small_cutoff: 0,
        fixed: None,
        sequential: false,
        gram_block: 0,
    }
}

/// A pinned fabric wider than `--ranks-budget` is rejected up front
/// (shrinking it would silently violate the pin), and the message names
/// both knobs so the fix is obvious.
#[test]
fn pinned_fabric_over_ranks_budget_is_a_clean_error() {
    let x = disjoint_blocks(&[10, 8], 400, 0xB17);
    let mut cfg = base_cfg();
    cfg.ranks_budget = 4;
    let opts = ScreenedDistOptions { fixed: Some((8, 1, 1)), ..dist_opts() };
    let err = fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("exceeds the concurrent rank budget"), "unexpected error: {msg}");
    assert!(msg.contains("--ranks-budget"), "message should name the knob: {msg}");
    // The boundary case — pin exactly at the budget — still runs.
    cfg.ranks_budget = 8;
    assert!(fit_screened_distributed(XSource::InCore(&x), &cfg, &opts).is_ok());
}

/// A pin the 1.5D rank programs cannot execute (`c_X·c_Ω > P` here) is
/// caught by the same validator, before any rank thread spawns.
#[test]
fn non_runnable_pin_is_a_clean_error() {
    let x = disjoint_blocks(&[10, 8], 400, 0xB17);
    let opts = ScreenedDistOptions { fixed: Some((8, 4, 4)), ..dist_opts() };
    let err = fit_screened_distributed(XSource::InCore(&x), &base_cfg(), &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("not runnable"), "unexpected error: {msg}");
}

/// A sweep whose `--mem-budget` cannot hold the largest screened
/// component fails as a clean error through the grid coordinator too —
/// the packed schedule must not fall back to overrunning the budget.
#[test]
fn sweep_mem_budget_below_largest_component_is_a_clean_error() {
    let x = disjoint_blocks(&[10, 10], 200, 0x0BAD);
    let mut cfg = base_cfg();
    cfg.mem_budget = 100; // far below any 10-column component
    // λ₁ stays at or below 0.02, the fixture's measured ≥ 4.4σ regime
    // (tools/verify_fixture_margins.py on seed 0x0BAD).
    let grid = GridSpec { lambda1: vec![0.01, 0.02], lambda2: vec![0.1] };
    for mode in [GridSchedule::Packed, GridSchedule::PerPoint] {
        let err = run_sweep_screened_dist(XSource::InCore(&x), &grid, &cfg, &dist_opts(), mode)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("memory budget"), "unexpected error ({mode:?}): {msg}");
    }
    // The smallest feasible budget — exactly the largest component —
    // schedules in both modes.
    cfg.mem_budget = MemFootprint::for_component(x.rows(), 10).words();
    for mode in [GridSchedule::Packed, GridSchedule::PerPoint] {
        let ok = run_sweep_screened_dist(XSource::InCore(&x), &grid, &cfg, &dist_opts(), mode);
        assert!(ok.is_ok());
    }
}

/// `|S_ij| > NaN` is false for every entry, so a NaN cutoff screens to
/// all singletons — defined degenerate behavior, not a panic or a
/// half-merged union-find.
#[test]
fn nan_cutoff_screens_to_all_singletons() {
    let x = disjoint_blocks(&[10, 8], 400, 0xB17);
    let p = x.cols();
    let s = native::gram_mt(&x, 1);
    let comps = gram_components(&s, f64::NAN);
    assert_eq!(comps.count, p);
    // nested_components sorts thresholds with total_cmp, so a NaN mixed
    // into a λ₁ grid neither panics nor disturbs the finite levels.
    let levels = nested_components(&s, &[f64::NAN, 0.05]);
    assert_eq!(levels[0].count, p);
    assert_eq!(levels[1].comp, gram_components(&s, 0.05).comp);
}

/// A deterministic little matrix for corrupting HPCX files with.
fn tiny_x() -> Mat {
    Mat::from_fn(5, 3, |i, j| (i * 3 + j) as f64 - 6.5)
}

/// Write a valid HPCX file, then let `mangle` corrupt the raw bytes
/// before reopening — the per-failure-mode harness.
fn corrupted(name: &str, mangle: impl FnOnce(&mut Vec<u8>)) -> (TempPath, String) {
    let tmp = TempPath::new(&format!("corrupt_{name}.xbin"));
    write_x(tmp.path(), &tiny_x()).unwrap();
    let mut bytes = std::fs::read(tmp.path()).unwrap();
    mangle(&mut bytes);
    std::fs::write(tmp.path(), &bytes).unwrap();
    let err = XDisk::open(tmp.path()).unwrap_err();
    let msg = format!("{err:#}");
    (tmp, msg)
}

/// A file shorter than the 24-byte header is named as truncated (the
/// first thing a mid-transfer copy looks like).
#[test]
fn x_file_truncated_header_is_a_clean_error() {
    let (_tmp, msg) = corrupted("header", |b| b.truncate(10));
    assert!(msg.contains("truncated header"), "unexpected error: {msg}");
}

/// Four wrong leading bytes — any non-HPCX file — are rejected before
/// a single payload byte is interpreted.
#[test]
fn x_file_wrong_magic_is_a_clean_error() {
    let (_tmp, msg) = corrupted("magic", |b| b[..4].copy_from_slice(b"JUNK"));
    assert!(msg.contains("bad magic"), "unexpected error: {msg}");
}

/// A future (or garbage) format version is refused rather than
/// misparsed.
#[test]
fn x_file_wrong_version_is_a_clean_error() {
    let (_tmp, msg) = corrupted("version", |b| b[4..8].copy_from_slice(&9u32.to_le_bytes()));
    assert!(msg.contains("unsupported HPCX version 9"), "unexpected error: {msg}");
}

/// A payload that disagrees with the header's n·p — truncated or with
/// trailing garbage — is caught at open, not mid-solve in a panel read.
#[test]
fn x_file_length_mismatch_is_a_clean_error() {
    let n = 5 * 3 * 8; // payload bytes of tiny_x
    let (_tmp, short) = corrupted("short", |b| b.truncate(b.len() - 8));
    assert!(short.contains("does not match header"), "unexpected error: {short}");
    let (_tmp2, long) = corrupted("long", |b| b.extend_from_slice(&[0u8; 8]));
    assert!(long.contains("does not match header"), "unexpected error: {long}");
    // An honest header over an empty payload fails the same check.
    let (_tmp3, empty) = corrupted("empty", |b| b.truncate(b.len() - n));
    assert!(empty.contains("does not match header"), "unexpected error: {empty}");
}

/// A nonexistent `--x-file` path surfaces as a clean open error naming
/// the path, not a panic.
#[test]
fn x_file_nonexistent_path_is_a_clean_error() {
    let tmp = TempPath::new("does_not_exist.xbin");
    let err = XDisk::open(tmp.path()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("opening x-file"), "unexpected error: {msg}");
}

/// `write_x` is atomic: a write that cannot complete (target directory
/// missing here) errors cleanly and leaves neither a partial output
/// file nor its temp sibling behind.
#[test]
fn failed_write_leaves_no_partial_file() {
    let dir = TempPath::new("no_such_dir");
    let target = dir.path().join("x.xbin");
    let err = write_x(&target, &tiny_x()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("creating"), "unexpected error: {msg}");
    assert!(!target.exists(), "partial output file left behind");
    assert!(!dir.path().exists(), "temp sibling resurrected the directory");
}

/// The screened single-node fit under a NaN λ₁: every column is a
/// singleton and solves by the closed form, so the estimate comes back
/// finite and diagonal rather than NaN-poisoned.
#[test]
fn screened_fit_under_nan_cutoff_is_finite_and_diagonal() {
    let x = disjoint_blocks(&[10, 8], 400, 0xB17);
    let p = x.cols();
    let mut cfg = base_cfg();
    cfg.lambda1 = f64::NAN;
    let fit = fit_with_screening(&x, &cfg).unwrap();
    assert_eq!(fit.components, p);
    assert_eq!(fit.largest, 1);
    for i in 0..p {
        for j in 0..p {
            let v = fit.fit.omega.get(i, j);
            assert!(v.is_finite(), "omega[{i},{j}] = {v}");
            if i != j {
                assert_eq!(v, 0.0, "off-diagonal omega[{i},{j}] = {v}");
            }
        }
    }
}
