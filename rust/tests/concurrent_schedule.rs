//! Concurrent component-fabric scheduling: equivalence and billing
//! suite for the wave packer (`cost::schedule::plan_concurrent`) and
//! the screened distributed solver's wave execution.
//!
//! The contract under test: the rank budget shapes the *plans* (a
//! budget below a planned fabric re-plans it to the cheapest runnable
//! power-of-two that fits), and at any fixed budget the wave schedule
//! changes only *when* a fabric launches — per-component omegas,
//! counters, and solver statistics are bit-identical to running the
//! same plans one after another (`ScreenedDistOptions::sequential`),
//! while the aggregate bill drops from the serial sum to the
//! schedule's critical path.
//!
//! Fixture note: with k disjoint-row blocks the within-block gram
//! entries scale by 1/k, so assertions are written against the actual
//! decomposition (cross-block splits are *guaranteed* by the exact
//! zeros; within-block connectivity is not assumed) rather than a
//! hard-coded component count.

use hpconcord::concord::screening::gram_components;
use hpconcord::concord::{
    fit_screened_distributed, ConcordConfig, ScreenedDistFit, ScreenedDistOptions, Variant,
};
use hpconcord::io::XSource;
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;
use hpconcord::runtime::native;
use hpconcord::simnet::cost::CostSummary;

mod common;
use common::disjoint_blocks;

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// A machine whose flops dwarf its communication: the planner then
/// gives even small screened components multi-rank fabrics, so the
/// budget sweep genuinely exercises packing and shrinking (on the
/// Edison-like machine these fixtures would all be priced single-node).
fn flop_heavy() -> MachineParams {
    MachineParams {
        alpha: 1.0e-13,
        beta: 1.0e-13,
        gamma_dense: 1.0e-6,
        gamma_sparse: 8.0e-6,
        beta_mem: 0.0,
    }
}

fn k_block_cfg(threads: usize, budget: usize) -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.02,
        lambda2: 0.1,
        tol: 0.0, // fixed budget: every component runs exactly max_iter
        max_iter: 6,
        variant: Variant::Cov,
        threads,
        ranks_budget: budget,
        ..Default::default()
    }
}

fn run(x: &Mat, threads: usize, budget: usize, sequential: bool) -> ScreenedDistFit {
    let opts = ScreenedDistOptions {
        total_ranks: 8,
        machine: flop_heavy(),
        small_cutoff: 0,
        fixed: None,
        sequential,
        gram_block: 0,
    };
    fit_screened_distributed(XSource::InCore(x), &k_block_cfg(threads, budget), &opts).unwrap()
}

/// Every non-singleton component appears in exactly one wave, and no
/// wave's rank teams ever sum past the budget — at any budget,
/// including budgets below the planned fabrics (shrink fallback) and
/// above the fabric size (multi-fabric waves).
#[test]
fn waves_respect_budget_and_cover_every_component() {
    // Four blocks at λ₁ = 0.02: n_each = 400 measures 5.2–6.0σ across
    // this suite's seeds (tools/verify_fixture_margins.py).
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0x4A7E);
    let cfg = k_block_cfg(1, 0);
    // The reference decomposition (the distributed screening pass is
    // pinned to agree with it elsewhere): under the flop-heavy machine
    // every non-singleton component gets a multi-rank plan, so exactly
    // the non-singleton components must be scheduled.
    let comps = gram_components(&native::gram(&x), cfg.lambda1);
    let expected: Vec<usize> =
        (0..comps.count).filter(|&c| comps.members(c).len() > 1).collect();
    assert!(expected.len() >= 4, "k ≥ 4 disjoint blocks must yield ≥ 4 solvable components");

    for budget in [1usize, 2, 4, 8, 32] {
        let out = run(&x, 1, budget, false);
        assert_eq!(out.components, comps.count, "budget {budget}: decomposition drifted");
        let mut seen: Vec<usize> = out
            .schedule
            .waves
            .iter()
            .flat_map(|w| w.entries.iter().map(|e| e.tag.component))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, expected, "budget {budget}: schedule must cover each exactly once");
        for (w, wave) in out.schedule.waves.iter().enumerate() {
            assert!(
                wave.ranks() <= budget,
                "budget {budget}: wave {w} occupies {} ranks",
                wave.ranks()
            );
            assert!(!wave.entries.is_empty(), "budget {budget}: empty wave {w}");
        }
        // Every fabric solve's recorded wave really contains a matching
        // entry (solve i is the i-th non-singleton component).
        for (sv, &c) in out.solves.iter().zip(&expected) {
            assert_eq!(sv.indices, comps.members(c), "budget {budget}: solve order");
            if sv.plan.ranks > 1 {
                let w = sv.wave.expect("fabric solves carry their wave");
                assert!(
                    out.schedule.waves[w].entries.iter().any(|e| e.tag.component == c),
                    "budget {budget}: component {c} not in its recorded wave {w}"
                );
            }
        }
    }
}

/// The acceptance pair, swept over budgets and thread counts: at every
/// (budget, threads) the concurrent schedule is bit-identical to the
/// sequential launch of the same plans — omega bits, objective bits,
/// iteration statistics, per-component L/W counters — while plans,
/// costs and counters agree solve by solve.
#[test]
fn concurrent_bit_identical_to_sequential_across_budgets_and_threads() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0xC0C0);
    for budget in [1usize, 4, 32] {
        for threads in [1usize, 4] {
            let seq = run(&x, threads, budget, true);
            let conc = run(&x, threads, budget, false);
            let tag = format!("budget {budget} threads {threads}");
            assert_eq!(
                bits(&conc.fit.omega),
                bits(&seq.fit.omega),
                "{tag}: omega must be bit-identical to the sequential path"
            );
            assert_eq!(conc.fit.iterations, seq.fit.iterations, "{tag}");
            assert_eq!(
                conc.fit.objective.to_bits(),
                seq.fit.objective.to_bits(),
                "{tag}: objective accumulation order must not depend on the schedule"
            );
            assert_eq!(conc.solves.len(), seq.solves.len(), "{tag}");
            for (a, b) in conc.solves.iter().zip(&seq.solves) {
                assert_eq!(a.indices, b.indices, "{tag}");
                assert_eq!(a.plan, b.plan, "{tag}: plans must not depend on launch order");
                assert_eq!(a.counters, b.counters, "{tag}: per-rank L/W counters moved");
                assert_eq!(a.cost.total, b.cost.total, "{tag}");
                assert_eq!(a.cost.max_per_rank, b.cost.max_per_rank, "{tag}");
            }
            // Billing: totals are machine facts (identical), the
            // concurrent critical path never exceeds the serial bill.
            assert_eq!(conc.cost.total, seq.cost.total, "{tag}");
            assert!(conc.cost.time <= seq.cost.time + 1e-15, "{tag}");
            assert!(
                (seq.cost.time - seq.sequential_bill().time).abs() < 1e-12,
                "{tag}: sequential mode must bill the serial sum"
            );
        }
    }
}

/// Budget 1 shrinks every plan to a single rank: nothing runs on a
/// fabric, every solve takes the (unmetered) single-node path, and
/// only the screening pass is billed.
#[test]
fn budget_one_degrades_to_single_node_plans() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0x0B1);
    let out = run(&x, 2, 1, false);
    assert!(!out.solves.is_empty());
    for sv in &out.solves {
        assert_eq!(sv.plan.ranks, 1, "budget 1 must shrink every fabric away");
        assert!(sv.counters.is_empty(), "single-node solves are unmetered");
    }
    assert_eq!(out.cost.total, out.screen_cost.total);
}

/// ISSUE acceptance: on a k ≥ 4 block fixture the concurrent-schedule
/// modeled makespan is *strictly* below the sequential merged bill
/// (some wave packs at least two fabrics), while omegas stay
/// bit-identical (checked exhaustively above; spot-checked here on the
/// same runs being billed).
#[test]
fn concurrent_makespan_strictly_undercuts_sequential_bill() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0xACCE);
    let budget = 32; // roomy: the ≤ 8-rank plans pack several per wave
    let conc = run(&x, 1, budget, false);
    let seq = run(&x, 1, budget, true);

    assert!(
        conc.solves.iter().filter(|sv| sv.plan.ranks > 1).count() >= 2,
        "fixture must produce at least two fabric components"
    );
    assert!(
        conc.schedule.waves.iter().any(|w| w.entries.len() >= 2),
        "budget {budget} must pack at least one wave with two fabrics"
    );
    assert!(
        conc.cost.time < seq.cost.time,
        "concurrent bill {} must be strictly below the sequential bill {}",
        conc.cost.time,
        seq.cost.time
    );
    // Same holds for the model's view of the schedule itself.
    assert!(conc.schedule.makespan() < conc.schedule.sequential_time());
    // And the helper reconstructs the serial bill from the solves.
    assert!((conc.sequential_bill().time - seq.cost.time).abs() < 1e-12);
    assert_eq!(bits(&conc.fit.omega), bits(&seq.fit.omega));
}

/// `merge_concurrent` against `merge_sequential` on real fabric bills:
/// the concurrent fold of every component cost never exceeds the
/// sequential fold's time, and both agree on the counter totals.
#[test]
fn merge_concurrent_makespan_never_exceeds_sequential_total() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 200, 0xFADE);
    let out = run(&x, 1, 32, false);
    let fabric_costs: Vec<&CostSummary> =
        out.solves.iter().filter(|sv| sv.plan.ranks > 1).map(|sv| &sv.cost).collect();
    assert!(fabric_costs.len() >= 2, "need real fabric bills to fold");
    let mut conc = CostSummary::default();
    let mut seq = CostSummary::default();
    for c in &fabric_costs {
        conc.merge_concurrent(c);
        seq.merge_sequential(c);
    }
    assert!(conc.time <= seq.time);
    assert!(conc.comm_time <= seq.comm_time);
    assert!(conc.time > 0.0, "fabric bills must be nonzero");
    assert_eq!(conc.total, seq.total, "totals are schedule-independent machine facts");
    assert_eq!(conc.max_per_rank, seq.max_per_rank);
    // Strictness on ≥ 2 nonzero bills: the max is below the sum.
    assert!(conc.time < seq.time);
}
