//! Shared fixtures for the screening test suites.

use hpconcord::linalg::Mat;
use hpconcord::prelude::*;

/// X whose column blocks are supported on disjoint sample rows: the
/// cross-block entries of S = XᵀX/n are exactly 0.0, so screening is
/// *guaranteed* to split between blocks at any λ₁ ≥ 0. Within-block
/// connectivity margins are analytic (chain adjacent covariances sit
/// near 0.22 after the disjoint-row halving), so keep `n_each` ≥ 200
/// for ≥ 4σ clearance over the λ₁ values the suites use.
pub fn disjoint_blocks(sizes: &[usize], n_each: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let p: usize = sizes.iter().sum();
    let mut x = Mat::zeros(n_each * sizes.len(), p);
    let mut col0 = 0;
    for (b, &sz) in sizes.iter().enumerate() {
        let prob = gen::chain_problem(sz, n_each, &mut rng);
        for i in 0..n_each {
            for j in 0..sz {
                x.set(b * n_each + i, col0 + j, prob.x.get(i, j));
            }
        }
        col0 += sz;
    }
    x
}
