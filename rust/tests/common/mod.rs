//! Shared fixtures for the screening test suites.

use hpconcord::linalg::Mat;
use hpconcord::prelude::*;

/// A temp-file path that removes itself on drop, unique per test name
/// and process (parallel test binaries never collide). `dead_code` is
/// allowed because every test binary compiles this module whether or
/// not it uses the guard.
#[allow(dead_code)]
pub struct TempPath(pub std::path::PathBuf);

#[allow(dead_code)]
impl TempPath {
    pub fn new(name: &str) -> TempPath {
        TempPath(
            std::env::temp_dir().join(format!("hpcx_test_{}_{name}", std::process::id())),
        )
    }

    pub fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TempPath {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

/// X whose column blocks are supported on disjoint sample rows: the
/// cross-block entries of S = XᵀX/n are exactly 0.0, so screening is
/// *guaranteed* to split between blocks at any λ₁ ≥ 0. Within-block
/// connectivity margins shrink with the block count: the gram
/// normalizes by the total row count `n_each * nblocks`, so a chain's
/// adjacent true covariance ≈ 0.444 lands near 0.444/nblocks, with
/// sampling σ ≈ sqrt((SᵢᵢSⱼⱼ + Sᵢⱼ²)/n_each)/nblocks at the weakest
/// edge. Measured guidance (tools/verify_fixture_margins.py, which
/// mirrors this generator bit-faithfully and re-measures every suite
/// fixture; run 2026-08-08): 2–3 blocks hold ≥ 4.2σ at λ₁ ≤ 0.05 with
/// `n_each` = 200; 4 blocks need `n_each` ≥ 400 at λ₁ = 0.02 (≈ 5σ)
/// and `n_each` ≥ 800 at λ₁ = 0.05 (≈ 5–6σ) — at `n_each` = 200 a
/// four-block fixture can sag to ~1σ at λ₁ = 0.05 and flake.
pub fn disjoint_blocks(sizes: &[usize], n_each: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let p: usize = sizes.iter().sum();
    let mut x = Mat::zeros(n_each * sizes.len(), p);
    let mut col0 = 0;
    for (b, &sz) in sizes.iter().enumerate() {
        let prob = gen::chain_problem(sz, n_each, &mut rng);
        for i in 0..n_each {
            for j in 0..sz {
                x.set(b * n_each + i, col0 + j, prob.x.get(i, j));
            }
        }
        col0 += sz;
    }
    x
}
