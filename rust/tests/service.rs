//! The multi-tenant estimation service: protocol and equivalence suite.
//!
//! The contract under test is determinism rule 9 in `ARCHITECTURE.md`:
//! the service is a **schedule-only** layer. A served job's omega is
//! byte-for-byte the `--out-omega` bytes of the equivalent CLI
//! invocation — across thread counts, memory budgets, cross-tenant
//! packing, and screening-cache hits — while only the bills and wave
//! schedules reflect the multi-tenancy. Alongside that wall:
//!
//! - the `submit` frame codec round-trips every request field the wire
//!   carries, for every request kind (the client encodes exactly what
//!   the server decodes);
//! - concurrent clients get distinct job ids and each job's result is
//!   its own request's standalone answer (admission interleaving never
//!   leaks one tenant's result into another's);
//! - a repeated same-dataset sweep bills its screening pass exactly
//!   once: the warm bill reports `screen_cached` with a zero screening
//!   share and a strictly smaller total;
//! - malformed frames (non-JSON lines, unknown kinds, bad fingerprint
//!   claims, missing fields) get clean `{"ok":false}` replies and the
//!   connection survives.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use hpconcord::concord::{
    fit_screened_distributed, EstimationRequest, RequestKind, WorkloadSpec,
};
use hpconcord::coordinator::{
    run_sweep_screened_dist, stability_selection_dist, GridSchedule, GridSpec, StabilityConfig,
};
use hpconcord::io::{format_omega, XSource};
use hpconcord::serve::{request_from_frame, request_to_frame, Client, Json, ServeOptions, Server};

/// A small solve request the suite reuses: p=24 keeps every fit fast
/// while still splitting across fabric plans worth packing.
fn solve_req(lambda1: f64, threads: usize, mem_budget: u64) -> EstimationRequest {
    let mut req = EstimationRequest::new(RequestKind::Solve);
    req.workload = WorkloadSpec { p: 24, n: 60, ..WorkloadSpec::default() };
    req.cfg.lambda1 = lambda1;
    req.cfg.max_iter = 30;
    req.cfg.threads = threads;
    req.cfg.mem_budget = mem_budget;
    req.opts.total_ranks = 4;
    req
}

/// The CLI path's bytes for a solve request: exactly what
/// `hpconcord solve --mode dist --screen --out-omega` writes.
fn cli_solve_bytes(req: &EstimationRequest) -> String {
    let x = req.workload.generate().unwrap().x;
    let fit = fit_screened_distributed(XSource::InCore(&x), &req.cfg, &req.opts).unwrap();
    format_omega(&fit.fit.omega)
}

// ---------------------------------------------------------------- //
// Frame codec round-trips                                          //
// ---------------------------------------------------------------- //

/// Encode → decode and compare every field the wire carries. (The
/// tile shape is deliberately not a wire field — it is a node-local
/// throughput knob the server chooses — so requests here keep the
/// default tile.)
fn assert_round_trip(req: &EstimationRequest, fp: Option<u64>, density: f64) {
    let frame = request_to_frame(req, fp, density);
    // Through the actual wire representation, not just the value tree.
    let frame = Json::parse(&frame.encode()).unwrap();
    let (back, claim, sel) = request_from_frame(&frame).unwrap();
    match (&req.kind, &back.kind) {
        (RequestKind::Solve, RequestKind::Solve) => {}
        (
            RequestKind::Sweep { grid: a, per_point: pa },
            RequestKind::Sweep { grid: b, per_point: pb },
        ) => {
            assert_eq!(a.lambda1, b.lambda1);
            assert_eq!(a.lambda2, b.lambda2);
            assert_eq!(pa, pb);
        }
        (RequestKind::Stability { stab: a }, RequestKind::Stability { stab: b }) => {
            assert_eq!(a.subsamples, b.subsamples);
            assert_eq!(a.fraction.to_bits(), b.fraction.to_bits());
            assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
            assert_eq!(a.seed, b.seed);
        }
        (a, b) => panic!("kind changed over the wire: {a:?} vs {b:?}"),
    }
    assert_eq!(req.cfg.lambda1.to_bits(), back.cfg.lambda1.to_bits());
    assert_eq!(req.cfg.lambda2.to_bits(), back.cfg.lambda2.to_bits());
    assert_eq!(req.cfg.tol.to_bits(), back.cfg.tol.to_bits());
    assert_eq!(req.cfg.max_iter, back.cfg.max_iter);
    assert_eq!(req.cfg.max_linesearch, back.cfg.max_linesearch);
    assert_eq!(req.cfg.variant, back.cfg.variant);
    assert_eq!(req.cfg.threads.max(1), back.cfg.threads);
    assert_eq!(req.cfg.ranks_budget, back.cfg.ranks_budget);
    assert_eq!(req.cfg.mem_budget, back.cfg.mem_budget);
    assert_eq!(req.opts.total_ranks, back.opts.total_ranks);
    assert_eq!(req.opts.small_cutoff, back.opts.small_cutoff);
    assert_eq!(req.opts.gram_block, back.opts.gram_block);
    assert_eq!(req.opts.fixed, back.opts.fixed);
    assert_eq!(req.workload.name, back.workload.name);
    assert_eq!(req.workload.p, back.workload.p);
    assert_eq!(req.workload.n, back.workload.n);
    assert_eq!(req.workload.deg, back.workload.deg);
    assert_eq!(req.workload.seed, back.workload.seed);
    assert_eq!(req.x_file, back.x_file);
    assert_eq!(fp, claim);
    assert_eq!(density.to_bits(), sel.to_bits());
}

#[test]
fn submit_frame_round_trips_for_every_kind() {
    assert_round_trip(&EstimationRequest::new(RequestKind::Solve), None, 0.1);
    assert_round_trip(&solve_req(0.27, 4, 12_345), Some(0xfeed_f00d_dead_beef), 0.05);

    // A heavily tuned solve: pinned replication, on-disk X, odd knobs.
    let mut tuned = solve_req(0.31, 2, 0);
    tuned.cfg.tol = 3.5e-7;
    tuned.cfg.max_linesearch = 17;
    tuned.cfg.ranks_budget = 6;
    tuned.opts.fixed = Some((tuned.opts.total_ranks, 2, 1));
    tuned.opts.small_cutoff = 9;
    tuned.opts.gram_block = 37;
    tuned.workload = WorkloadSpec { name: "random".into(), p: 96, n: 50, deg: 5, seed: 99 };
    tuned.x_file = Some("fixtures/x.xbin".to_string());
    assert_round_trip(&tuned, Some(1), 0.25);

    for per_point in [false, true] {
        let grid = GridSpec { lambda1: vec![0.21, 0.34, 0.55], lambda2: vec![0.0, 0.07] };
        let mut req =
            EstimationRequest::new(RequestKind::Sweep { grid: grid.clone(), per_point });
        req.cfg.lambda1 = 0.4; // kind's grid wins server-side; still carried
        assert_round_trip(&req, None, 0.12);
    }

    let stab = StabilityConfig {
        subsamples: 13,
        fraction: 0.61,
        threshold: 0.82,
        seed: 7,
        ..StabilityConfig::default()
    };
    assert_round_trip(&EstimationRequest::new(RequestKind::Stability { stab }), None, 0.1);
}

#[test]
fn bad_submit_fields_are_clean_decode_errors() {
    let bad_kind = Json::parse(r#"{"op":"submit","kind":"spiral"}"#).unwrap();
    let err = request_from_frame(&bad_kind).unwrap_err();
    assert!(err.to_string().contains("unknown kind"), "{err}");

    let bad_fp =
        Json::parse(r#"{"op":"submit","kind":"solve","fingerprint":"xyzzy"}"#).unwrap();
    let err = request_from_frame(&bad_fp).unwrap_err();
    assert!(err.to_string().contains("fingerprint"), "{err}");
}

// ---------------------------------------------------------------- //
// Rule 9: served bytes == CLI bytes                                //
// ---------------------------------------------------------------- //

/// The tentpole wall: across threads {1, 4} × memory budget
/// {unbounded, tight}, a served solve returns byte-for-byte the bytes
/// the CLI's `--out-omega` writes for the same request.
#[test]
fn served_solve_is_bit_identical_to_the_cli_path() {
    // A tight-but-admitting budget: the unbounded schedule's own peak
    // residency (any admitted budget is bit-identical, rule 7).
    let probe = solve_req(0.3, 1, 0);
    let x = probe.workload.generate().unwrap().x;
    let unbounded =
        fit_screened_distributed(XSource::InCore(&x), &probe.cfg, &probe.opts).unwrap();
    let tight = unbounded.schedule.peak_mem_words().max(1);

    let server = Server::start(ServeOptions::default()).unwrap();
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for threads in [1usize, 4] {
        for mem_budget in [0u64, tight] {
            let req = solve_req(0.3, threads, mem_budget);
            let expected = cli_solve_bytes(&req);
            let job = client.submit(&req, None, 0.1).unwrap();
            client.wait(job).unwrap();
            let served = client.result_omega(job).unwrap();
            assert_eq!(
                served, expected,
                "threads {threads} mem {mem_budget}: served bytes differ from the CLI's"
            );
        }
    }
    client.shutdown().unwrap();
    server.join();
}

/// Stability selection over the wire returns the same frequency matrix
/// bytes as the direct coordinator call.
#[test]
fn served_stability_matches_the_direct_path() {
    let stab = StabilityConfig {
        subsamples: 4,
        fraction: 0.5,
        threshold: 0.7,
        seed: 3,
        ..StabilityConfig::default()
    };
    let mut req = EstimationRequest::new(RequestKind::Stability { stab });
    req.workload = WorkloadSpec { p: 16, n: 48, ..WorkloadSpec::default() };
    req.cfg.max_iter = 30;
    req.opts.total_ranks = 4;

    let x = req.workload.generate().unwrap().x;
    let direct =
        stability_selection_dist(XSource::InCore(&x), &req.cfg, &stab, &req.opts).unwrap();
    let expected = format_omega(&direct.frequency);

    let server = Server::start(ServeOptions::default()).unwrap();
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let job = client.submit(&req, None, 0.1).unwrap();
    client.wait(job).unwrap();
    assert_eq!(client.result_omega(job).unwrap(), expected);
    client.shutdown().unwrap();
    server.join();
}

// ---------------------------------------------------------------- //
// Multi-tenancy                                                    //
// ---------------------------------------------------------------- //

/// Two clients submitting concurrently get distinct job ids, and every
/// job's result is its own request's standalone answer — cross-tenant
/// wave packing never mixes results (rules 6 and 9).
#[test]
fn concurrent_clients_get_distinct_jobs_and_standalone_results() {
    let server = Server::start(ServeOptions::default()).unwrap();
    let addr = server.addr().to_string();
    // Distinct λ₁ per submission so every job has a distinguishable
    // right answer.
    let lambdas = [0.26, 0.30, 0.34, 0.38];
    let mut handles = Vec::new();
    for pair in lambdas.chunks(2) {
        let addr = addr.clone();
        let pair: Vec<f64> = pair.to_vec();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut submitted = Vec::new();
            for l1 in pair {
                let job = client.submit(&solve_req(l1, 1, 0), None, 0.1).unwrap();
                submitted.push((job, l1));
            }
            for &(job, _) in &submitted {
                client.wait(job).unwrap();
            }
            submitted
                .into_iter()
                .map(|(job, l1)| (job, l1, client.result_omega(job).unwrap()))
                .collect::<Vec<_>>()
        }));
    }
    let mut seen: Vec<usize> = Vec::new();
    for h in handles {
        for (job, l1, served) in h.join().unwrap() {
            assert!(!seen.contains(&job), "job id {job} assigned twice");
            seen.push(job);
            let expected = cli_solve_bytes(&solve_req(l1, 1, 0));
            assert_eq!(served, expected, "job {job} (λ1={l1}) is not its standalone answer");
        }
    }
    assert_eq!(seen.len(), lambdas.len());
    let mut client = Client::connect(&addr).unwrap();
    client.shutdown().unwrap();
    server.join();
}

// ---------------------------------------------------------------- //
// Screening-cache billing                                          //
// ---------------------------------------------------------------- //

/// A repeated same-dataset sweep reuses the cached screening pass: the
/// warm bill reports the hit, carries a zero screening share, and its
/// total is strictly below the cold bill — while the returned bytes
/// (and the CLI sweep's selected omega) stay identical.
#[test]
fn warm_sweep_bills_screening_once_and_keeps_the_bytes() {
    let grid = GridSpec { lambda1: vec![0.25, 0.3, 0.4], lambda2: vec![0.0] };
    let mut req =
        EstimationRequest::new(RequestKind::Sweep { grid: grid.clone(), per_point: false });
    req.workload = WorkloadSpec { p: 24, n: 60, ..WorkloadSpec::default() };
    req.cfg.max_iter = 30;
    req.opts.total_ranks = 4;

    // The CLI twin: packed screened dist sweep + density selection.
    let x = req.workload.generate().unwrap().x;
    let cli = run_sweep_screened_dist(
        XSource::InCore(&x),
        &grid,
        &req.cfg,
        &req.opts,
        GridSchedule::Packed,
    )
    .unwrap();
    let expected = format_omega(
        &hpconcord::coordinator::select_by_density(&cli.results, 0.1).unwrap().fit.omega,
    );

    let server = Server::start(ServeOptions::default()).unwrap();
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let cold_job = client.submit(&req, None, 0.1).unwrap();
    client.wait(cold_job).unwrap();
    let warm_job = client.submit(&req, None, 0.1).unwrap();
    client.wait(warm_job).unwrap();

    assert_eq!(client.result_omega(cold_job).unwrap(), expected);
    assert_eq!(client.result_omega(warm_job).unwrap(), expected);

    let cold = client.bill(cold_job).unwrap();
    let warm = client.bill(warm_job).unwrap();
    assert!(!cold.bool_or("screen_cached", true).unwrap(), "first sweep must be cold");
    assert!(warm.bool_or("screen_cached", false).unwrap(), "second sweep must hit the cache");
    assert!(cold.f64_or("screen_time", 0.0).unwrap() > 0.0);
    assert_eq!(warm.f64_or("screen_time", -1.0).unwrap(), 0.0);
    assert!(
        warm.f64_or("total_time", 0.0).unwrap() < cold.f64_or("total_time", 0.0).unwrap(),
        "amortized screening must strictly shrink the bill"
    );
    client.shutdown().unwrap();
    server.join();
}

// ---------------------------------------------------------------- //
// Error paths on the wire                                          //
// ---------------------------------------------------------------- //

/// Raw-socket misuse: a non-JSON line, an unknown kind, and a missing
/// job field all get `{"ok":false}` replies on a connection that keeps
/// working afterwards.
#[test]
fn malformed_frames_get_error_replies_and_the_connection_survives() {
    let server = Server::start(ServeOptions::default()).unwrap();
    let addr = server.addr();
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut ask = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(reply.trim_end()).unwrap()
    };

    let r = ask("this is not json");
    assert!(!r.bool_or("ok", true).unwrap());

    let r = ask(r#"{"op":"submit","kind":"spiral"}"#);
    assert!(!r.bool_or("ok", true).unwrap());
    assert!(r.str_or("error", "").unwrap().contains("unknown kind"));

    let r = ask(r#"{"op":"wait"}"#);
    assert!(!r.bool_or("ok", true).unwrap());
    assert!(r.str_or("error", "").unwrap().contains("job"));

    // The connection is still serviceable.
    let r = ask(r#"{"op":"ping"}"#);
    assert!(r.bool_or("ok", false).unwrap());

    let mut client = Client::connect(&addr.to_string()).unwrap();
    client.shutdown().unwrap();
    server.join();
}
