//! Memory-bounded wave execution: the bit-identity test wall.
//!
//! The contract under test is determinism rule 7 in `ARCHITECTURE.md`:
//! the memory budget (`--mem-budget`, wave packing under per-task
//! `MemFootprint`s) and the gram panel width (`--gram-block`, the
//! streamed screening pass) are **schedule-only** knobs. Any budget
//! that admits a schedule and any panel width produce bit-identical
//! omegas, objectives, and Lemma-3.3/3.5 counters — only the modeled
//! peak residency (`CostSummary::peak_mem_words`) and the wave layout
//! move. A budget too small for the largest single component is a
//! clean error, never a panic or a silent overrun.

use hpconcord::concord::{
    fit_screened_distributed, screen_distributed_multi, screen_streamed, ConcordConfig,
    ScreenedDistFit, ScreenedDistOptions, Variant,
};
use hpconcord::coordinator::{stability_selection_dist, StabilityConfig};
use hpconcord::cost::MemFootprint;
use hpconcord::io::XSource;
use hpconcord::linalg::Mat;
use hpconcord::prelude::*;

mod common;
use common::disjoint_blocks;

fn bits(m: &Mat) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// A machine whose flops dwarf its communication: the planner then
/// gives even small screened components multi-rank fabrics, so every
/// component enters the wave packer and the memory budget genuinely
/// reshapes the schedule.
fn flop_heavy() -> MachineParams {
    MachineParams {
        alpha: 1.0e-13,
        beta: 1.0e-13,
        gamma_dense: 1.0e-6,
        gamma_sparse: 8.0e-6,
        beta_mem: 0.0,
    }
}

fn base_cfg(threads: usize, mem_budget: u64) -> ConcordConfig {
    ConcordConfig {
        lambda1: 0.02,
        lambda2: 0.1,
        tol: 0.0, // fixed budget: every component runs exactly max_iter
        max_iter: 6,
        variant: Variant::Cov,
        threads,
        ranks_budget: 32,
        mem_budget,
        ..Default::default()
    }
}

fn dist_opts() -> ScreenedDistOptions {
    ScreenedDistOptions {
        total_ranks: 8,
        machine: flop_heavy(),
        small_cutoff: 0,
        fixed: None,
        sequential: false,
        gram_block: 0,
    }
}

/// Per-component resident words of the executed schedule, in wave
/// order.
fn footprints(out: &ScreenedDistFit) -> Vec<u64> {
    out.schedule
        .waves
        .iter()
        .flat_map(|w| w.entries.iter().map(|e| e.mem.words()))
        .collect()
}

/// ISSUE acceptance: omegas, objective bits and the metered counters
/// are bit-identical across `--mem-budget` ∈ {unbounded, tight,
/// exactly-one-wave-fits} × threads {1, 4} on the shared 4-block
/// fixture — the budget only splits waves.
#[test]
fn mem_budget_is_a_schedule_only_knob() {
    // Four blocks at λ₁ = 0.02: n_each = 400 measures 5.1σ on this
    // seed (tools/verify_fixture_margins.py).
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0x9A1D);
    let opts = dist_opts();
    let baseline = fit_screened_distributed(XSource::InCore(&x), &base_cfg(1, 0), &opts).unwrap();
    let per = footprints(&baseline);
    assert_eq!(per.len(), 4, "fixture must screen into 4 fabric components");
    let tight = per.iter().copied().max().unwrap();
    let one_wave: u64 = per.iter().sum();
    assert!(
        baseline.schedule.waves.len() < per.len(),
        "rank budget 32 must co-schedule components, or tightness is vacuous"
    );

    for budget in [0u64, tight, one_wave] {
        for threads in [1usize, 4] {
            let tag = format!("mem budget {budget} threads {threads}");
            let out =
                fit_screened_distributed(XSource::InCore(&x), &base_cfg(threads, budget), &opts)
                    .unwrap();
            assert_eq!(bits(&out.fit.omega), bits(&baseline.fit.omega), "{tag}: omega drift");
            assert_eq!(
                out.fit.objective.to_bits(),
                baseline.fit.objective.to_bits(),
                "{tag}: objective drift"
            );
            assert_eq!(out.fit.iterations, baseline.fit.iterations, "{tag}");
            // Lemma-3.3/3.5 counters are machine facts: the schedule
            // cannot move a single message, word, or flop.
            assert_eq!(out.cost.total, baseline.cost.total, "{tag}: counter drift");
            assert_eq!(out.cost.max_per_rank, baseline.cost.max_per_rank, "{tag}");
            // And the schedule honors the budget on every wave.
            if budget > 0 {
                for (w, wave) in out.schedule.waves.iter().enumerate() {
                    assert!(wave.mem_words() <= budget, "{tag}: wave {w} over budget");
                }
                assert!(out.schedule.peak_mem_words() <= budget, "{tag}");
                assert!(out.solve_cost.peak_mem_words <= budget, "{tag}");
            }
        }
    }

    // The tight budget really splits waves: one equal-footprint
    // component per wave, and the modeled peak drops strictly below
    // the unbounded schedule's.
    let tight_run =
        fit_screened_distributed(XSource::InCore(&x), &base_cfg(1, tight), &opts).unwrap();
    assert_eq!(tight_run.schedule.waves.len(), per.len(), "tight budget: one wave each");
    assert!(tight_run.schedule.peak_mem_words() < baseline.schedule.peak_mem_words());
}

/// A budget below the largest single component is a clean `anyhow`
/// error (shrinking ranks cannot shrink data), not a panic.
#[test]
fn budget_below_largest_component_is_a_clean_error() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0x9A1D);
    let opts = dist_opts();
    let err = fit_screened_distributed(XSource::InCore(&x), &base_cfg(1, 100), &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("memory budget"), "unexpected error: {msg}");
    // The smallest feasible budget — exactly the largest component —
    // still schedules.
    let need = MemFootprint::for_component(x.rows(), 10).words();
    assert!(fit_screened_distributed(XSource::InCore(&x), &base_cfg(1, need), &opts).is_ok());
}

/// Ragged [12, 6, 6, 6] blocks: packing under the tight budget keeps
/// every wave's resident words within budget and the executed bill's
/// peak strictly below the unbounded fold, without touching results.
#[test]
fn tight_budget_bounds_the_modeled_peak() {
    let x = disjoint_blocks(&[12, 6, 6, 6], 200, 0x51ab);
    let opts = dist_opts();
    let unbounded = fit_screened_distributed(XSource::InCore(&x), &base_cfg(1, 0), &opts).unwrap();
    let per = footprints(&unbounded);
    assert_eq!(per.len(), 4);
    let tight = per.iter().copied().max().unwrap();
    assert_eq!(tight, MemFootprint::for_component(x.rows(), 12).words());

    let bounded =
        fit_screened_distributed(XSource::InCore(&x), &base_cfg(1, tight), &opts).unwrap();
    for wave in &bounded.schedule.waves {
        assert!(wave.mem_words() <= tight);
    }
    assert!(bounded.schedule.peak_mem_words() <= tight);
    assert!(
        bounded.solve_cost.peak_mem_words < unbounded.solve_cost.peak_mem_words,
        "budgeted peak {} must undercut unbounded peak {}",
        bounded.solve_cost.peak_mem_words,
        unbounded.solve_cost.peak_mem_words
    );
    assert_eq!(bits(&bounded.fit.omega), bits(&unbounded.fit.omega));
}

/// The streamed gram pass is bit-identical to the in-core pass —
/// labelings, degrees, diagonal, and counters — at every panel width,
/// including widths that leave a ragged final panel, across thread
/// counts. Only the modeled X residency shrinks.
#[test]
fn streamed_gram_is_bit_identical_to_in_core() {
    let x = disjoint_blocks(&[10, 10, 10, 10], 400, 0x9A1D);
    let (n, p) = (x.rows(), x.cols());
    let thresholds = [0.02, 0.05];
    let machine = MachineParams::edison_like();
    let incore = screen_distributed_multi(&x, &thresholds, 8, machine, 1);
    assert_eq!(incore.cost.peak_mem_words, ((n * p) + p * p) as u64);

    for gram_block in [1usize, 7, n, n + 13] {
        for threads in [1usize, 4] {
            let tag = format!("gram block {gram_block} threads {threads}");
            let streamed = screen_streamed(&x, &thresholds, 8, machine, threads, gram_block);
            assert_eq!(streamed.levels.len(), incore.levels.len(), "{tag}");
            for (s, r) in streamed.levels.iter().zip(&incore.levels) {
                assert_eq!(s.components.comp, r.components.comp, "{tag}: labeling drift");
                assert_eq!(s.components.count, r.components.count, "{tag}");
                let sd: Vec<u64> = s.degrees.iter().map(|v| v.to_bits()).collect();
                let rd: Vec<u64> = r.degrees.iter().map(|v| v.to_bits()).collect();
                assert_eq!(sd, rd, "{tag}: degree drift");
            }
            let sdiag: Vec<u64> = streamed.diag.iter().map(|v| v.to_bits()).collect();
            let rdiag: Vec<u64> = incore.diag.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sdiag, rdiag, "{tag}: diag drift");
            assert_eq!(streamed.cost.total, incore.cost.total, "{tag}: counter drift");
            assert_eq!(streamed.cost.max_per_rank, incore.cost.max_per_rank, "{tag}");
            // Modeled residency: one panel of X instead of all of it.
            let resident = gram_block.min(n);
            assert_eq!(streamed.cost.peak_mem_words, ((resident * p) + p * p) as u64, "{tag}");
        }
    }
}

/// NaN-cutoff degradation: `|s_ij| > NaN` is false for every edge, so
/// both passes agree on the all-singletons labeling — streaming does
/// not change NaN handling.
#[test]
fn streamed_gram_matches_in_core_under_nan_cutoff() {
    let x = disjoint_blocks(&[10, 10], 200, 0x0BAD);
    let p = x.cols();
    let thresholds = [f64::NAN];
    let machine = MachineParams::edison_like();
    let incore = screen_distributed_multi(&x, &thresholds, 4, machine, 1);
    let streamed = screen_streamed(&x, &thresholds, 4, machine, 1, 7);
    assert_eq!(incore.levels[0].components.count, p, "NaN cutoff must isolate every variable");
    assert_eq!(streamed.levels[0].components.comp, incore.levels[0].components.comp);
    assert_eq!(streamed.levels[0].components.count, incore.levels[0].components.count);
    let sd: Vec<u64> = streamed.levels[0].degrees.iter().map(|v| v.to_bits()).collect();
    let rd: Vec<u64> = incore.levels[0].degrees.iter().map(|v| v.to_bits()).collect();
    assert_eq!(sd, rd);
}

/// Stability selection's screening bill models ~one subsample copy
/// resident at a time — not B/2 retained dense copies — now that
/// subsamples are materialized per-pass and solves rebuild their
/// sub-matrices lazily from row-index views.
#[test]
fn stability_screen_peak_models_one_subsample() {
    let x = disjoint_blocks(&[8, 8, 8], 200, 0xF00D);
    let (n, p) = (x.rows(), x.cols());
    let base = ConcordConfig {
        lambda1: 0.02,
        tol: 0.0,
        max_iter: 4,
        variant: Variant::Cov,
        threads: 1,
        ranks_budget: 8,
        ..Default::default()
    };
    let cfg = StabilityConfig { subsamples: 4, fraction: 0.5, threshold: 0.6, seed: 7, workers: 2 };
    let opts = ScreenedDistOptions {
        total_ranks: 4,
        machine: flop_heavy(),
        small_cutoff: 0,
        fixed: None,
        sequential: false,
        gram_block: 0,
    };
    let out = stability_selection_dist(XSource::InCore(&x), &base, &cfg, &opts).unwrap();
    let m = ((n as f64) * cfg.fraction).round() as usize;
    // Every pass screens one m × p subsample; the serial fold maxes
    // equal peaks, so the bill reports exactly one copy's residency.
    assert_eq!(out.bill.screen.peak_mem_words, ((m * p) + p * p) as u64);
    // Strictly below what retaining all B dense copies would cost.
    assert!(out.bill.screen.peak_mem_words < (cfg.subsamples * m * p) as u64);
    // And the lazy row-view solves stayed exact: stable edges never
    // cross the exactly-screened-apart blocks.
    for &(i, j) in &out.edges {
        assert_eq!(i / 8, j / 8, "cross-block stable edge ({i}, {j})");
    }
}
