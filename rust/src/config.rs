//! Minimal TOML-subset configuration (serde/toml are not vendored in
//! this offline image).
//!
//! Supported: `[section]` headers, `key = value` with integer, float,
//! boolean, quoted-string and flat numeric-array values, `#` comments.
//! That covers every run configuration the launcher needs; see
//! `examples/configs/*.toml`.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<f64>),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(i) => Ok(*i as f64),
            Value::Float(f) => Ok(*f),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as u64),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[f64]> {
        match self {
            Value::Array(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }
}

/// Parsed config: `section.key` → value (top-level keys use section "").
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: HashMap<String, Value>,
}

impl Config {
    /// Parse the TOML subset.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            values.insert(full_key, parse_value(val.trim(), lineno + 1)?);
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.values.get(key).map(|v| v.as_f64()).transpose().map(|o| o.unwrap_or(default))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        self.values.get(key).map(|v| v.as_usize()).transpose().map(|o| o.unwrap_or(default))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        self.values.get(key).map(|v| v.as_u64()).transpose().map(|o| o.unwrap_or(default))
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        self.values.get(key).map(|v| v.as_bool()).transpose().map(|o| o.unwrap_or(default))
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> Result<&'a str> {
        self.values.get(key).map(|v| v.as_str()).transpose().map(|o| o.unwrap_or(default))
    }

    pub fn array_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.values.get(key) {
            Some(v) => Ok(v.as_array()?.to_vec()),
            None => Ok(default.to_vec()),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(body.to_string()));
    }
    if let Some(body) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let mut arr = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            arr.push(
                part.parse::<f64>()
                    .map_err(|_| anyhow!("line {lineno}: bad array element {part:?}"))?,
            );
        }
        return Ok(Value::Array(arr));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {lineno}: cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run configuration
workload = "chain"   # graph type
p = 1024

[solver]
lambda1 = 0.3
lambda2 = 0.0
grid = [0.1, 0.2, 0.3]
verbose = true

[fabric]
ranks = 16

[serve]
addr = "127.0.0.1:9911"
ranks_budget = 12
mem_budget = 200000
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("workload"), Some(&Value::Str("chain".into())));
        assert_eq!(c.get("p"), Some(&Value::Int(1024)));
        assert_eq!(c.get("solver.lambda1"), Some(&Value::Float(0.3)));
        assert_eq!(c.get("solver.verbose"), Some(&Value::Bool(true)));
        assert_eq!(c.get("fabric.ranks"), Some(&Value::Int(16)));
        assert_eq!(
            c.get("solver.grid"),
            Some(&Value::Array(vec![0.1, 0.2, 0.3]))
        );
    }

    #[test]
    fn defaults_and_typed_accessors() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.f64_or("solver.lambda2", 9.0).unwrap(), 0.0);
        assert_eq!(c.f64_or("solver.missing", 9.0).unwrap(), 9.0);
        assert_eq!(c.usize_or("fabric.ranks", 1).unwrap(), 16);
        assert!(c.bool_or("solver.verbose", false).unwrap());
        assert!(c.bool_or("solver.absent", true).unwrap());
        assert!(c.bool_or("p", false).is_err());
        assert_eq!(c.str_or("workload", "x").unwrap(), "chain");
        assert_eq!(c.array_or("solver.grid", &[]).unwrap(), vec![0.1, 0.2, 0.3]);
    }

    /// The `serve` subcommand reads its bind address and global budgets
    /// from a `[serve]` section through the generic accessors — pin the
    /// key spellings the launcher uses.
    #[test]
    fn serve_section_keys_resolve() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("serve.addr", "127.0.0.1:7878").unwrap(), "127.0.0.1:9911");
        assert_eq!(c.usize_or("serve.ranks_budget", 0).unwrap(), 12);
        assert_eq!(c.u64_or("serve.mem_budget", 0).unwrap(), 200_000);
        // Absent section: the launcher defaults apply.
        let empty = Config::default();
        assert_eq!(empty.str_or("serve.addr", "127.0.0.1:7878").unwrap(), "127.0.0.1:7878");
    }

    #[test]
    fn type_errors_are_reported() {
        let c = Config::parse(SAMPLE).unwrap();
        assert!(c.get("workload").unwrap().as_f64().is_err());
        assert!(c.get("p").unwrap().as_bool().is_err());
    }

    #[test]
    fn negative_integers_are_rejected_by_unsigned_accessors() {
        // --mem-budget / fabric.mem_budget and friends must never wrap a
        // negative config value into a huge unsigned budget.
        let c = Config::parse("[fabric]\nmem_budget = -1\nranks = -8").unwrap();
        assert!(c.u64_or("fabric.mem_budget", 0).is_err());
        assert!(c.usize_or("fabric.ranks", 1).is_err());
        // ...while non-negative values and absent keys stay fine.
        let ok = Config::parse("[fabric]\nmem_budget = 103936").unwrap();
        assert_eq!(ok.u64_or("fabric.mem_budget", 0).unwrap(), 103936);
        assert_eq!(ok.u64_or("fabric.absent", 7).unwrap(), 7);
        // Floats are not silently truncated to integers.
        let f = Config::parse("[fabric]\nmem_budget = 1.5").unwrap();
        assert!(f.u64_or("fabric.mem_budget", 0).is_err());
    }

    #[test]
    fn malformed_lines_error_with_line_numbers() {
        let e = Config::parse("key_without_value\n").unwrap_err();
        assert!(format!("{e}").contains("line 1"));
        let e = Config::parse("a = 1\nb = @@@\n").unwrap_err();
        assert!(format!("{e}").contains("line 2"));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let c = Config::parse("name = \"a # b\"").unwrap();
        assert_eq!(c.get("name"), Some(&Value::Str("a # b".into())));
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("no equals sign").is_err());
        assert!(Config::parse("x = what").is_err());
    }
}
