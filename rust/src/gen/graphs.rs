//! Chain and random-graph precision matrices + Gaussian samplers
//! (paper §4: "banded and random strictly diagonally dominant Ω⁰'s,
//! corresponding to chain and random graphs, ... average degree 2 for
//! the chain graphs and 60 for the random graphs").

use crate::linalg::{banded_cholesky, cholesky, solve_lower_transpose, Csr, Mat};
use crate::rng::Rng;

/// A generated problem: data, ground truth, and provenance.
#[derive(Debug, Clone)]
pub struct Problem {
    /// Observations, n × p.
    pub x: Mat,
    /// Ground-truth precision matrix Ω⁰ (sparse).
    pub omega0: Csr,
    /// Average vertex degree of the ground-truth graph.
    pub avg_degree: f64,
}

/// Chain-graph precision: tridiagonal, 1.25 on the diagonal and −0.5 on
/// the first off-diagonals (strictly diagonally dominant ⇒ positive
/// definite; average degree 2).
pub fn chain_precision(p: usize) -> Csr {
    let mut tri = Vec::with_capacity(3 * p);
    for i in 0..p {
        tri.push((i, i, 1.25));
        if i + 1 < p {
            tri.push((i, i + 1, -0.5));
            tri.push((i + 1, i, -0.5));
        }
    }
    Csr::from_triplets(p, p, &mut tri)
}

/// Random-graph precision with target average degree `deg`: symmetric
/// support with uniform ±[0.2, 0.6] off-diagonal weights, diagonal set
/// to row ℓ₁ mass + 0.5 (strict diagonal dominance).
pub fn random_precision(p: usize, deg: usize, rng: &mut Rng) -> Csr {
    assert!(deg < p, "degree must be < p");
    let n_edges = p * deg / 2;
    let mut edges = std::collections::HashSet::new();
    let mut tri: Vec<(usize, usize, f64)> = Vec::with_capacity(2 * n_edges + p);
    let mut row_mass = vec![0.0f64; p];
    while edges.len() < n_edges {
        let i = rng.below(p as u64) as usize;
        let j = rng.below(p as u64) as usize;
        if i == j {
            continue;
        }
        let key = (i.min(j), i.max(j));
        if !edges.insert(key) {
            continue;
        }
        let sign = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        let w = sign * (0.2 + 0.4 * rng.uniform());
        tri.push((key.0, key.1, w));
        tri.push((key.1, key.0, w));
        row_mass[key.0] += w.abs();
        row_mass[key.1] += w.abs();
    }
    for (i, &m) in row_mass.iter().enumerate() {
        tri.push((i, i, m + 0.5));
    }
    Csr::from_triplets(p, p, &mut tri)
}

/// Sample n rows of N(0, (Ω⁰)⁻¹) via a dense Cholesky of Ω⁰
/// (appropriate for the random graphs; O(p³) once).
pub fn sample_dense(omega0: &Csr, n: usize, rng: &mut Rng) -> Mat {
    let p = omega0.rows();
    let l = cholesky(&omega0.to_dense()).expect("precision must be PD");
    let mut x = Mat::zeros(n, p);
    for i in 0..n {
        let z = rng.normal_vec(p);
        let xi = solve_lower_transpose(&l, &z);
        x.row_mut(i).copy_from_slice(&xi);
    }
    x
}

/// Sample n rows of N(0, (Ω⁰)⁻¹) for a banded Ω⁰ with bandwidth `bw`
/// (chain: bw = 1). O(n·p·bw) after an O(p·bw²) factorization.
pub fn sample_banded(omega0: &Csr, bw: usize, n: usize, rng: &mut Rng) -> Mat {
    let p = omega0.rows();
    let dense_entry = |i: usize, j: usize| -> f64 {
        let (idx, vals) = omega0.row(i);
        match idx.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    };
    let l = banded_cholesky(p, bw, dense_entry).expect("precision must be PD");
    let mut x = Mat::zeros(n, p);
    for i in 0..n {
        let z = rng.normal_vec(p);
        let xi = l.solve_transpose(&z);
        x.row_mut(i).copy_from_slice(&xi);
    }
    x
}

/// Chain problem (paper Fig. 2/4a setting).
pub fn chain_problem(p: usize, n: usize, rng: &mut Rng) -> Problem {
    let omega0 = chain_precision(p);
    let x = sample_banded(&omega0, 1, n, rng);
    let avg = (omega0.nnz() - p) as f64 / p as f64;
    Problem { x, omega0, avg_degree: avg }
}

/// Random-graph problem (paper Fig. 2/4b/4c setting; the paper's
/// degree-60 default is scaled by the caller alongside p).
pub fn random_problem(p: usize, n: usize, deg: usize, rng: &mut Rng) -> Problem {
    let omega0 = random_precision(p, deg, rng);
    let x = sample_dense(&omega0, n, rng);
    let avg = (omega0.nnz() - p) as f64 / p as f64;
    Problem { x, omega0, avg_degree: avg }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_precision_structure() {
        let c = chain_precision(6);
        assert_eq!(c.nnz(), 6 + 2 * 5);
        let d = c.to_dense();
        assert_eq!(d.get(0, 0), 1.25);
        assert_eq!(d.get(2, 3), -0.5);
        assert_eq!(d.get(0, 2), 0.0);
    }

    #[test]
    fn random_precision_degree_and_dominance() {
        let mut rng = Rng::new(1);
        let p = 60;
        let deg = 8;
        let omega = random_precision(p, deg, &mut rng);
        let avg = (omega.nnz() - p) as f64 / p as f64;
        assert!((avg - deg as f64).abs() < 1.0, "avg degree {avg}");
        // Strict diagonal dominance on every row.
        let d = omega.to_dense();
        for i in 0..p {
            let off: f64 = (0..p).filter(|&j| j != i).map(|j| d.get(i, j).abs()).sum();
            assert!(d.get(i, i) > off, "row {i} not dominant");
        }
        // Symmetry.
        assert!(d.max_abs_diff(&d.transpose()) == 0.0);
    }

    #[test]
    fn banded_and_dense_samplers_agree_in_distribution() {
        // Same seed streams differ, so compare sample covariances of the
        // chain model against the true covariance loosely.
        let p = 6;
        let n = 30_000;
        let omega0 = chain_precision(p);
        let mut rng = Rng::new(2);
        let x = sample_banded(&omega0, 1, n, &mut rng);
        // Empirical covariance ≈ (Ω⁰)⁻¹.
        let l = cholesky(&omega0.to_dense()).unwrap();
        let mut truth = Mat::zeros(p, p);
        for j in 0..p {
            let mut e = vec![0.0; p];
            e[j] = 1.0;
            let y = crate::linalg::solve_lower(&l, &e);
            let col = solve_lower_transpose(&l, &y);
            for i in 0..p {
                truth.set(i, j, col[i]);
            }
        }
        let mut emp = Mat::zeros(p, p);
        for r in 0..n {
            for i in 0..p {
                for j in 0..p {
                    emp.set(i, j, emp.get(i, j) + x.get(r, i) * x.get(r, j));
                }
            }
        }
        emp.scale(1.0 / n as f64);
        assert!(emp.max_abs_diff(&truth) < 0.05, "{}", emp.max_abs_diff(&truth));
    }

    #[test]
    fn problems_have_consistent_shapes() {
        let mut rng = Rng::new(3);
        let pr = chain_problem(20, 15, &mut rng);
        assert_eq!(pr.x.shape(), (15, 20));
        assert_eq!(pr.omega0.rows(), 20);
        assert!((pr.avg_degree - 2.0).abs() < 0.2);
        let pr = random_problem(24, 10, 4, &mut rng);
        assert_eq!(pr.x.shape(), (10, 24));
    }
}
