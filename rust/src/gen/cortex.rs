//! Synthetic cortex: the stand-in for the Human Connectome Project fMRI
//! covariance of paper §5 (see DESIGN.md §1 substitutions).
//!
//! Two hemispheres of `p_hemi` "voxels" each, placed on unit spheres by
//! a Fibonacci lattice. A ground-truth parcellation (the Glasser et al.
//! reference role) assigns each voxel to its nearest of `k` random seed
//! parcels. The ground-truth precision matrix connects each voxel to its
//! `m` nearest neighbours — strongly within a parcel, weakly across —
//! and never across hemispheres, reproducing the block-diagonal
//! hemisphere structure the paper observes in its estimates (§S.3.3).
//! Sampling the resulting Gaussian gives synthetic "BOLD" data whose
//! partial-correlation graph carries recoverable parcel structure.

use crate::linalg::{Csr, Mat};
use crate::rng::Rng;

use super::graphs::sample_dense;

/// The synthetic cortex: geometry, ground truth, and data.
#[derive(Debug, Clone)]
pub struct Cortex {
    /// 3D coordinates of every voxel (unit sphere per hemisphere).
    pub coords: Vec<[f64; 3]>,
    /// 0 = left hemisphere, 1 = right.
    pub hemisphere: Vec<u8>,
    /// Ground-truth parcel label per voxel (globally indexed).
    pub parcels: Vec<usize>,
    /// Number of parcels per hemisphere.
    pub k_per_hemi: usize,
    /// Ground-truth precision matrix Ω⁰ (block-diagonal by hemisphere).
    pub omega0: Csr,
    /// Synthetic observations, n × p.
    pub x: Mat,
}

impl Cortex {
    /// Total voxels p.
    pub fn p(&self) -> usize {
        self.coords.len()
    }

    /// Voxel indices of one hemisphere.
    pub fn hemi_indices(&self, h: u8) -> Vec<usize> {
        (0..self.p()).filter(|&i| self.hemisphere[i] == h).collect()
    }

    /// Ground-truth labels restricted to one hemisphere (reference
    /// clustering for the Jaccard comparison).
    pub fn hemi_parcels(&self, h: u8) -> Vec<usize> {
        self.hemi_indices(h).iter().map(|&i| self.parcels[i]).collect()
    }
}

/// Fibonacci sphere lattice: `n` well-spread points on the unit sphere.
fn fibonacci_sphere(n: usize) -> Vec<[f64; 3]> {
    let golden = std::f64::consts::PI * (3.0 - 5.0f64.sqrt());
    (0..n)
        .map(|i| {
            let y = 1.0 - 2.0 * (i as f64 + 0.5) / n as f64;
            let r = (1.0 - y * y).sqrt();
            let th = golden * i as f64;
            [r * th.cos(), y, r * th.sin()]
        })
        .collect()
}

fn dist2(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2) + (a[2] - b[2]).powi(2)
}

/// Build a synthetic cortex with `p_hemi` voxels and `k` parcels per
/// hemisphere, `m`-nearest-neighbour connectivity, and `n` samples.
/// Adds a global BOLD-like confound (see [`synthetic_cortex_confound`])
/// at the default strength 0.6.
pub fn synthetic_cortex(p_hemi: usize, k: usize, m: usize, n: usize, rng: &mut Rng) -> Cortex {
    synthetic_cortex_confound(p_hemi, k, m, n, 0.6, rng)
}

/// As [`synthetic_cortex`], with an explicit global-confound strength.
///
/// Resting-state BOLD data carries a *global signal* shared by every
/// voxel; it inflates all marginal correlations (so magnitude-thresholding
/// the covariance picks spurious cross-parcel edges) while the partial
/// correlation structure — the inverse covariance — absorbs it as a
/// rank-one perturbation spread thinly over all entries. This is exactly
/// the marginal-vs-partial contrast the paper's §5 baseline comparison
/// probes, so the generator models it: each sample gets `confound · g`
/// added to every coordinate, g ~ N(0, 1).
pub fn synthetic_cortex_confound(
    p_hemi: usize,
    k: usize,
    m: usize,
    n: usize,
    confound: f64,
    rng: &mut Rng,
) -> Cortex {
    assert!(k >= 1 && m >= 1 && p_hemi > m);
    let p = 2 * p_hemi;
    let sphere = fibonacci_sphere(p_hemi);
    let mut coords = Vec::with_capacity(p);
    let mut hemisphere = Vec::with_capacity(p);
    for h in 0..2u8 {
        // Offset hemispheres along x so geometry stays distinct.
        let dx = if h == 0 { -2.0 } else { 2.0 };
        for c in &sphere {
            coords.push([c[0] + dx, c[1], c[2]]);
            hemisphere.push(h);
        }
    }

    // Ground-truth parcels: nearest of k random seeds, per hemisphere.
    let mut parcels = vec![0usize; p];
    for h in 0..2u8 {
        let idx: Vec<usize> = (0..p).filter(|&i| hemisphere[i] == h).collect();
        let seeds = rng.sample_indices(idx.len(), k);
        for &i in &idx {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (s, &sv) in seeds.iter().enumerate() {
                let d = dist2(&coords[i], &coords[idx[sv]]);
                if d < bd {
                    bd = d;
                    best = s;
                }
            }
            parcels[i] = h as usize * k + best;
        }
    }

    // Precision: m nearest neighbours within the hemisphere; intra-parcel
    // edges strong, inter-parcel weak. Symmetrized union of kNN edges.
    let mut edges: std::collections::HashMap<(usize, usize), f64> =
        std::collections::HashMap::new();
    for i in 0..p {
        let mut cands: Vec<(f64, usize)> = (0..p)
            .filter(|&j| j != i && hemisphere[j] == hemisphere[i])
            .map(|j| (dist2(&coords[i], &coords[j]), j))
            .collect();
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for &(_, j) in cands.iter().take(m) {
            let key = (i.min(j), i.max(j));
            let w = if parcels[i] == parcels[j] { -0.9 } else { -0.15 };
            edges.insert(key, w);
        }
    }
    let mut row_mass = vec![0.0f64; p];
    let mut tri: Vec<(usize, usize, f64)> = Vec::with_capacity(2 * edges.len() + p);
    for (&(i, j), &w) in &edges {
        tri.push((i, j, w));
        tri.push((j, i, w));
        row_mass[i] += w.abs();
        row_mass[j] += w.abs();
    }
    for (i, &mass) in row_mass.iter().enumerate() {
        tri.push((i, i, mass + 0.5));
    }
    let omega0 = Csr::from_triplets(p, p, &mut tri);
    let mut x = sample_dense(&omega0, n, rng);
    if confound != 0.0 {
        for i in 0..n {
            let g = confound * rng.normal();
            for v in x.row_mut(i) {
                *v += g;
            }
        }
    }
    Cortex { coords, hemisphere, parcels, k_per_hemi: k, omega0, x }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fibonacci_points_on_unit_sphere() {
        for pt in fibonacci_sphere(50) {
            let r2 = pt[0] * pt[0] + pt[1] * pt[1] + pt[2] * pt[2];
            assert!((r2 - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn cortex_is_block_diagonal_by_hemisphere() {
        let mut rng = Rng::new(1);
        let cx = synthetic_cortex(30, 3, 4, 20, &mut rng);
        let d = cx.omega0.to_dense();
        for i in 0..cx.p() {
            for j in 0..cx.p() {
                if cx.hemisphere[i] != cx.hemisphere[j] {
                    assert_eq!(d.get(i, j), 0.0, "cross-hemisphere edge ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn cortex_shapes_and_parcels() {
        let mut rng = Rng::new(2);
        let cx = synthetic_cortex(25, 4, 3, 15, &mut rng);
        assert_eq!(cx.p(), 50);
        assert_eq!(cx.x.shape(), (15, 50));
        assert_eq!(cx.hemi_indices(0).len(), 25);
        // Parcel ids: left in [0, 4), right in [4, 8).
        for &i in &cx.hemi_indices(0) {
            assert!(cx.parcels[i] < 4);
        }
        for &i in &cx.hemi_indices(1) {
            assert!((4..8).contains(&cx.parcels[i]));
        }
        // Every hemisphere has at least 2 distinct parcels realized.
        let mut left: Vec<usize> = cx.hemi_parcels(0);
        left.sort_unstable();
        left.dedup();
        assert!(left.len() >= 2);
    }

    #[test]
    fn precision_is_positive_definite() {
        let mut rng = Rng::new(3);
        let cx = synthetic_cortex(20, 3, 3, 5, &mut rng);
        assert!(crate::linalg::cholesky(&cx.omega0.to_dense()).is_ok());
    }

    #[test]
    fn intra_parcel_edges_stronger() {
        let mut rng = Rng::new(4);
        let cx = synthetic_cortex(40, 3, 4, 5, &mut rng);
        let d = cx.omega0.to_dense();
        let mut intra: Vec<f64> = Vec::new();
        let mut inter: Vec<f64> = Vec::new();
        for i in 0..cx.p() {
            for j in (i + 1)..cx.p() {
                let v = d.get(i, j);
                if v != 0.0 {
                    if cx.parcels[i] == cx.parcels[j] {
                        intra.push(v.abs());
                    } else {
                        inter.push(v.abs());
                    }
                }
            }
        }
        assert!(!intra.is_empty() && !inter.is_empty());
        let ai = intra.iter().sum::<f64>() / intra.len() as f64;
        let bi = inter.iter().sum::<f64>() / inter.len() as f64;
        assert!(ai > bi * 2.0);
    }
}
