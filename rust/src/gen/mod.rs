//! Synthetic data generation: the paper's evaluation workloads.
//!
//! §4 uses banded ("chain", average degree 2) and random (average degree
//! 60) strictly diagonally dominant precision matrices Ω⁰ with Gaussian
//! samples; §5 uses an fMRI covariance we replace with a synthetic
//! cortex ([`cortex`], see DESIGN.md substitutions). Sampling never
//! forms Σ = (Ω⁰)⁻¹: with Ω⁰ = LLᵀ, x = L⁻ᵀz for z ~ N(0, I) has
//! covariance (Ω⁰)⁻¹ (banded Cholesky makes chain sampling O(p)).

pub mod cortex;
pub mod graphs;

pub use cortex::{synthetic_cortex, Cortex};
pub use graphs::{chain_precision, chain_problem, random_precision, random_problem, Problem};
