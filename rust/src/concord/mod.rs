//! The CONCORD/PseudoNet estimator (paper §2) and the HP-CONCORD
//! proximal gradient solvers (paper §3).
//!
//! The estimate is the minimizer of
//!
//! ```text
//!   -log det(Ω_D²) + tr(Ω S Ω) + λ₁‖Ω_X‖₁ + (λ₂/2)‖Ω‖_F²        (1)
//! ```
//!
//! solved by proximal gradient with backtracking line search
//! (Algorithm 1). Three drivers share the same block-level math
//! ([`ops`]):
//!
//! - [`single_node::fit_single_node`] — the shared-memory path (the
//!   BigQUIC head-to-head setting), optionally running its fused
//!   line-search trials on the AOT-compiled JAX/Pallas artifacts via
//!   PJRT ([`crate::runtime`]);
//! - [`cov::fit_cov_rank`] — **Algorithm 2** (Cov): computes S = XᵀX/n
//!   once, then W = ΩS per trial via the 1.5D multiply;
//! - [`obs::fit_obs_rank`] — **Algorithm 3** (Obs): never forms S;
//!   computes Y = ΩXᵀ per trial and Z = YX/n per iteration.
//!
//! [`fit_distributed`] wraps either rank program in a [`Fabric`] run and
//! returns the assembled estimate plus the metered communication costs.
//! [`screened_dist::fit_screened_distributed`] composes screening with
//! the distributed layer: a distributed screening pass splits the
//! problem into connected components, the cost model sizes one fabric
//! per component ([`crate::cost::schedule`]), and the per-component
//! estimates are stitched back into the global block-diagonal omega.
//! The wave execution itself lives in the reusable [`executor`] layer:
//! job-tagged component solves packed under a global rank budget — the
//! single fit is one client; grid sweeps and stability selection
//! submit every (job, component) pair into the same machinery.

pub mod cov;
pub mod dist_common;
pub mod executor;
pub mod obs;
pub mod ops;
pub mod request;
pub mod screened_dist;
pub mod screening;
pub mod single_node;

pub use executor::{
    split_by_counts, ExecutorJob, ExecutorRun, ExecutorTask, FabricExecutor, TaskOutcome,
};
pub use request::{EstimationRequest, RequestKind, RequestOutcome, WorkloadSpec};
pub use screened_dist::{
    fit_screened_distributed, screen_distributed_multi, screen_streamed, screen_streamed_src,
    MultiScreenPass, ScreenLevel, ScreenedDistFit, ScreenedDistOptions,
};
// Deprecated pre-`XSource` shims, re-exported for one release.
#[allow(deprecated)]
pub use screened_dist::{fit_screened_distributed_mat, fit_screened_distributed_src};
pub use screening::{fit_with_screening, fit_with_screening_on, ComponentStat, ScreenedFit};
pub use single_node::fit_single_node;

use crate::linalg::Mat;
use crate::rng::Rng;
use crate::simnet::{cost::CostSummary, Counters, Fabric, MachineParams};
use std::sync::Arc;

/// Which HP-CONCORD variant to run (paper §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Compute S = XᵀX/n once; W = ΩS per trial. Wins when d/p is small
    /// relative to n/(p−n)·1/t (Lemma 3.1).
    Cov,
    /// Never form S; Y = ΩXᵀ per trial, Z = YX/n per iteration. Wins in
    /// the n ≪ p, denser-iterate regime.
    Obs,
    /// Choose by Lemma 3.1's crossover rule with a pilot estimate of d.
    Auto,
}

/// Solver configuration (tuning parameters of problem (1) + controls).
///
/// Every field has a sensible default, so configs are usually built by
/// struct update. Changing `threads` or `tile` never changes the
/// estimate — only wall-clock (the kernel layer's determinism
/// contract; see `ARCHITECTURE.md`):
///
/// ```
/// use hpconcord::concord::{fit_single_node, ConcordConfig};
/// use hpconcord::linalg::TileConfig;
/// use hpconcord::prelude::*;
///
/// let mut rng = Rng::new(7);
/// let problem = gen::chain_problem(24, 80, &mut rng);
/// let base = ConcordConfig { lambda1: 0.25, max_iter: 50, ..Default::default() };
/// let fast = ConcordConfig { threads: 4, tile: TileConfig::new(32, 64, 64), ..base };
/// let a = fit_single_node(&problem.x, &base).unwrap();
/// let b = fit_single_node(&problem.x, &fast).unwrap();
/// assert_eq!(a.omega.max_abs_diff(&b.omega), 0.0); // bit-identical
/// ```
#[derive(Debug, Clone, Copy)]
pub struct ConcordConfig {
    /// ℓ₁ penalty λ₁ on the off-diagonal entries.
    pub lambda1: f64,
    /// Squared-Frobenius penalty λ₂ (λ₂ = 0 recovers plain CONCORD).
    pub lambda2: f64,
    /// Convergence tolerance ε on max |Ω⁽ᵏ⁺¹⁾ − Ω⁽ᵏ⁾|.
    pub tol: f64,
    /// Cap on proximal gradient iterations.
    pub max_iter: usize,
    /// Cap on line-search halvings per iteration.
    pub max_linesearch: usize,
    pub variant: Variant,
    /// Node-local worker threads for every local kernel (the paper's
    /// per-node `t`: §4 runs threaded MKL on 24 cores per node). Applies
    /// to the single-node solver and to each simulated rank's local
    /// multiplies and fused passes. Results are bit-identical at any
    /// value — threading only changes wall-clock, never the estimate or
    /// the metered communication (see `rust/tests/parallel_determinism.rs`).
    pub threads: usize,
    /// Cache-blocking shape of the packed GEMM/SpMM kernel layer
    /// ([`crate::linalg::tile`]). Installed process-wide when a fit
    /// starts; like `threads`, it moves wall-clock only — results are
    /// bit-identical at every tile shape. CLI: `--tile mc,kc,nc`.
    pub tile: crate::linalg::TileConfig,
    /// Global rank budget for the screened solver's concurrent wave
    /// schedule ([`screened_dist`]): independent component fabrics are
    /// packed into waves whose rank teams sum to at most this many
    /// ranks and run at the same time. `0` (the default) means "use the
    /// fabric's `total_ranks`". A budget below a component's planned
    /// fabric re-plans it to the cheapest runnable power-of-two that
    /// fits (which *does* change the component's fabric, like
    /// passing different `--ranks` would); at any fixed budget the
    /// wave schedule itself only reorders launches — per-component
    /// results are bit-identical to running the same plans one after
    /// another. CLI: `--ranks-budget N`; TOML: `fabric.budget`.
    pub ranks_budget: usize,
    /// Global memory budget in **words** for the screened solver's wave
    /// schedule: no wave's summed [`MemFootprint`]s (extracted `n·|c|`
    /// sub-matrices plus `|c|²` working sets) may exceed it, so peak
    /// residency is bounded by the budget instead of the whole job
    /// list. `0` (the default) means unbounded. A single component
    /// whose footprint alone exceeds a nonzero budget is a clean error
    /// — memory, unlike ranks, cannot be shrunk. Like `ranks_budget`,
    /// a schedule-only knob (determinism rule 7): results are
    /// bit-identical at every value that runs. CLI: `--mem-budget N`;
    /// TOML: `fabric.mem_budget`.
    ///
    /// [`MemFootprint`]: crate::cost::MemFootprint
    pub mem_budget: u64,
    /// Microkernel ISA lane for the packed GEMM layer
    /// ([`crate::linalg::simd`]). Installed process-wide when a fit
    /// starts. Every lane runs the scalar microkernel's exact
    /// per-element op sequence (one multiply + one add per k, ascending,
    /// never FMA), so — like `tile` — this is value-preserving
    /// (determinism rule 10): results are bit-identical on every lane,
    /// only throughput moves. [`KernelLane::Auto`] (the default) picks
    /// the widest lane the host supports; a forced lane the host lacks
    /// is rejected at the front door. CLI: `--kernel
    /// scalar|avx2|avx512|auto`; TOML: `solver.kernel`.
    ///
    /// [`KernelLane::Auto`]: crate::linalg::KernelLane::Auto
    pub kernel: crate::linalg::KernelLane,
    /// Pin pool workers to cores (`worker i` → logical CPU
    /// `i % available_parallelism`) so packed panels stop migrating
    /// between per-core caches mid-solve. Schedule-only like `threads`
    /// (rule 10): the partition and per-chunk op sequences are
    /// unchanged, so results are bit-identical pinned or not; a no-op
    /// where the platform lacks `sched_setaffinity`. CLI:
    /// `--pin-cores`; TOML: `solver.pin_cores`.
    pub pin_cores: bool,
}

impl Default for ConcordConfig {
    fn default() -> Self {
        ConcordConfig {
            lambda1: 0.3,
            lambda2: 0.0,
            tol: 1e-5,
            max_iter: 500,
            max_linesearch: 40,
            variant: Variant::Auto,
            threads: 1,
            tile: crate::linalg::TileConfig::DEFAULT,
            ranks_budget: 0,
            mem_budget: 0,
            kernel: crate::linalg::KernelLane::Auto,
            pin_cores: false,
        }
    }
}

/// A fitted estimate plus the solver statistics the paper's cost model
/// needs (s = iterations, t = mean line-search trials, d = mean nnz/row).
#[derive(Debug, Clone)]
pub struct ConcordFit {
    /// Estimate Ω̂ (symmetric; exactly sparse off the diagonal).
    pub omega: Mat,
    /// Proximal gradient iterations taken (the paper's s).
    pub iterations: usize,
    /// Mean line-search trials per iteration (the paper's t).
    pub mean_linesearch: f64,
    /// Mean nonzeros per row of the iterates (the paper's d).
    pub mean_row_nnz: f64,
    /// Final smooth objective value g(Ω̂).
    pub objective: f64,
    pub converged: bool,
}

/// Running tally of (s, t, d) across an optimization.
#[derive(Debug, Default, Clone, Copy)]
pub struct SolveStats {
    pub iters: usize,
    pub trials: usize,
    pub nnz_samples: u64,
    pub nnz_total: u64,
}

impl SolveStats {
    pub fn mean_linesearch(&self) -> f64 {
        if self.iters == 0 {
            0.0
        } else {
            self.trials as f64 / self.iters as f64
        }
    }

    pub fn mean_row_nnz(&self) -> f64 {
        if self.nnz_samples == 0 {
            0.0
        } else {
            self.nnz_total as f64 / self.nnz_samples as f64
        }
    }
}

/// Pick Cov vs Obs by Lemma 3.1: Cov wins iff d/p < n/(p−n) · 1/t.
/// `d_est` is a pilot estimate of the mean iterate row-density, `t_est`
/// of line-search trials (the paper observed 5–15 per prox iteration).
pub fn choose_variant(n: usize, p: usize, d_est: f64, t_est: f64) -> Variant {
    if n >= p {
        return Variant::Cov;
    }
    let lhs = d_est / p as f64;
    let rhs = (n as f64 / (p - n) as f64) / t_est;
    if lhs < rhs {
        Variant::Cov
    } else {
        Variant::Obs
    }
}

/// Result of a distributed fit: the estimate plus metered costs.
#[derive(Debug)]
pub struct DistFit {
    pub fit: ConcordFit,
    pub cost: CostSummary,
    pub variant: Variant,
}

/// One fabric execution with the raw per-rank counters retained —
/// screened runs aggregate several such fabrics, and the lemma tests
/// pin per-rank L/W inside each component's fabric.
#[derive(Debug)]
pub struct DistRun {
    pub fit: ConcordFit,
    pub cost: CostSummary,
    /// Rank-indexed counters of this fabric.
    pub counters: Vec<Counters>,
    pub variant: Variant,
}

/// Resolve [`Variant::Auto`] by Lemma 3.1 with a pilot density estimate;
/// concrete variants pass through.
fn resolve_variant(x: &Mat, cfg: &ConcordConfig) -> Variant {
    match cfg.variant {
        Variant::Auto => {
            let mut rng = Rng::new(0x5eed);
            let d_est = pilot_density(x, cfg, &mut rng);
            choose_variant(x.rows(), x.cols(), d_est, 10.0)
        }
        v => v,
    }
}

/// Run HP-CONCORD on a simulated P-rank machine with replication factors
/// `c_x` (data operands) and `c_omega` (iterate). The observation matrix
/// is shared read-only with the ranks, which slice out their own parts —
/// standing in for the paper's pre-distributed data. Requires
/// c_x·c_omega ≤ P (powers of two) and p divisible by the team counts.
///
/// Returns the assembled estimate plus the fabric's metered α-β-γ
/// communication bill:
///
/// ```
/// use hpconcord::concord::{fit_distributed, ConcordConfig, Variant};
/// use hpconcord::prelude::*;
///
/// let mut rng = Rng::new(3);
/// let problem = gen::chain_problem(16, 60, &mut rng);
/// let cfg = ConcordConfig { lambda1: 0.3, variant: Variant::Cov, ..Default::default() };
/// let out = fit_distributed(&problem.x, &cfg, 4, 2, 2, MachineParams::edison_like());
/// assert_eq!(out.fit.omega.shape(), (16, 16));
/// assert!(out.cost.max_per_rank.messages > 0); // Lemma 3.3 counts were metered
/// ```
pub fn fit_distributed(
    x: &Mat,
    cfg: &ConcordConfig,
    p_ranks: usize,
    c_x: usize,
    c_omega: usize,
    machine: MachineParams,
) -> DistFit {
    let run = run_distributed(x, cfg, p_ranks, c_x, c_omega, machine);
    DistFit { fit: run.fit, cost: run.cost, variant: run.variant }
}

/// [`fit_distributed`] keeping the rank-indexed [`Counters`] — the
/// building block the screened distributed solver runs once per
/// component.
pub fn run_distributed(
    x: &Mat,
    cfg: &ConcordConfig,
    p_ranks: usize,
    c_x: usize,
    c_omega: usize,
    machine: MachineParams,
) -> DistRun {
    crate::linalg::tile::install(cfg.tile);
    crate::linalg::simd::install(cfg.kernel);
    crate::util::pool::set_pin_cores(cfg.pin_cores);
    let variant = resolve_variant(x, cfg);
    let x = Arc::new(x.clone());
    let cfg = *cfg;
    let fabric = Fabric::with_machine(p_ranks, machine);
    let run = match variant {
        Variant::Cov => fabric.run(move |comm| cov::fit_cov_rank(comm, &x, &cfg, c_x, c_omega)),
        Variant::Obs | Variant::Auto => {
            fabric.run(move |comm| obs::fit_obs_rank(comm, &x, &cfg, c_x, c_omega))
        }
    };
    let cost = run.summary();
    DistRun {
        fit: dist_common::assemble_fit(run.results),
        cost,
        counters: run.counters,
        variant,
    }
}

/// Cheap pilot estimate of the iterate density d: a few prox iterations
/// on a column-subsampled problem.
fn pilot_density(x: &Mat, cfg: &ConcordConfig, rng: &mut Rng) -> f64 {
    let p = x.cols();
    let sample_p = p.min(128);
    let cols = rng.sample_indices(p, sample_p);
    let xs = Mat::from_fn(x.rows(), sample_p, |i, j| x.get(i, cols[j]));
    let mut sub_cfg = *cfg;
    sub_cfg.max_iter = 3;
    sub_cfg.variant = Variant::Cov;
    let fit = single_node::fit_single_node(&xs, &sub_cfg).expect("pilot fit");
    fit.mean_row_nnz * (p as f64 / sample_p as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma31_crossover_rule() {
        // d/p < n/(p-n)/t → Cov. Supplementary S.1 examples: with t=10,
        // r_obs=0.1 the threshold is r_nnz ≈ 0.011.
        let p = 1000;
        let n = 100;
        assert_eq!(choose_variant(n, p, 5.0, 10.0), Variant::Cov); // 0.005 < 0.011
        assert_eq!(choose_variant(n, p, 50.0, 10.0), Variant::Obs); // 0.05 > 0.011
        assert_eq!(choose_variant(2000, 1000, 999.0, 10.0), Variant::Cov);
    }

    #[test]
    fn stats_means() {
        let s = SolveStats { iters: 4, trials: 10, nnz_samples: 8, nnz_total: 24 };
        assert_eq!(s.mean_linesearch(), 2.5);
        assert_eq!(s.mean_row_nnz(), 3.0);
    }
}
