//! Shared machinery for the distributed Cov/Obs rank programs: global
//! scalar reductions over layer groups, tag management, per-rank fit
//! fragments and their assembly.

use crate::linalg::Mat;
use crate::simnet::Comm;

use super::{ConcordFit, SolveStats};

/// Monotone tag allocator. Every rank advances it identically (the
/// solver control flow is globally deterministic), so matching calls on
/// different ranks agree on tags without coordination.
#[derive(Debug, Clone, Copy)]
pub struct TagGen(u64);

impl TagGen {
    pub fn new() -> Self {
        TagGen(1)
    }

    /// Reserve a range of `stride` tags; returns its base.
    pub fn next(&mut self, stride: u64) -> u64 {
        let t = self.0;
        self.0 += stride;
        t
    }
}

impl Default for TagGen {
    fn default() -> Self {
        Self::new()
    }
}

/// Elementwise sum over a layer group (one rank per team — every block
/// counted exactly once), with every rank of the world participating in
/// its own layer's reduction so all ranks end with the global value.
pub fn global_sum(comm: &mut Comm, group: &[usize], tag: u64, vals: Vec<f64>) -> Vec<f64> {
    if group.len() <= 1 {
        vals
    } else {
        comm.sum_reduce(group, tag, vals)
    }
}

/// Max over a layer group.
pub fn global_max(comm: &mut Comm, group: &[usize], tag: u64, val: f64) -> f64 {
    if group.len() <= 1 {
        return val;
    }
    comm.allgather(group, tag, vec![val])
        .into_iter()
        .map(|v| v[0])
        .fold(f64::NEG_INFINITY, f64::max)
}

/// Objective-piece accumulator carried through the global reduction:
/// `[bad_diag_flag, logd, trace_term, fro]`. A positive flag anywhere
/// poisons the objective to +∞ (non-positive diagonal ⇒ reject trial).
pub fn combine_objective(parts: &[f64], lam2: f64) -> f64 {
    if parts[0] > 0.0 {
        f64::INFINITY
    } else {
        -parts[1] + 0.5 * parts[2] + 0.5 * lam2 * parts[3]
    }
}

/// One rank's share of a finished fit.
#[derive(Debug, Clone)]
pub struct RankFit {
    /// Global row offset of `omega_block`.
    pub row_start: usize,
    /// This rank's block rows of the estimate.
    pub omega_block: Mat,
    /// True on exactly one replica per block (layer 0).
    pub primary: bool,
    pub stats: SolveStats,
    pub objective: f64,
    pub converged: bool,
}

/// Stitch the per-rank fragments into a full [`ConcordFit`].
pub fn assemble_fit(mut results: Vec<RankFit>) -> ConcordFit {
    results.retain(|r| r.primary);
    assert!(!results.is_empty(), "no primary rank fragments");
    results.sort_by_key(|r| r.row_start);
    let stats = results[0].stats;
    let objective = results[0].objective;
    let converged = results[0].converged;
    let blocks: Vec<Mat> = results.into_iter().map(|r| r.omega_block).collect();
    let omega = Mat::vstack(&blocks);
    ConcordFit {
        omega,
        iterations: stats.iters,
        mean_linesearch: stats.mean_linesearch(),
        mean_row_nnz: stats.mean_row_nnz(),
        objective,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::Fabric;

    #[test]
    fn tag_gen_reserves_disjoint_ranges() {
        let mut t = TagGen::new();
        let a = t.next(100);
        let b = t.next(10);
        let c = t.next(1);
        assert!(a + 100 <= b);
        assert!(b + 10 <= c);
    }

    #[test]
    fn global_max_across_group() {
        let run = Fabric::new(4).run(|comm| {
            let group: Vec<usize> = (0..comm.size()).collect();
            global_max(comm, &group, 3, comm.rank() as f64)
        });
        assert!(run.results.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn combine_objective_poisoned_by_flag() {
        assert!(combine_objective(&[1.0, 0.0, 0.0, 0.0], 0.0).is_infinite());
        // -logd + tr/2 + (lam2/2)*fro = -2 + 2.5 + 1.
        let v = combine_objective(&[0.0, 2.0, 5.0, 4.0], 0.5);
        assert!((v - (-2.0 + 2.5 + 1.0)).abs() < 1e-15);
    }

    #[test]
    fn assemble_orders_and_filters() {
        let frag = |start: usize, val: f64, primary| RankFit {
            row_start: start,
            omega_block: Mat::from_vec(1, 2, vec![val, val]),
            primary,
            stats: SolveStats { iters: 3, trials: 6, nnz_samples: 2, nnz_total: 4 },
            objective: 1.5,
            converged: true,
        };
        let fit = assemble_fit(vec![
            frag(1, 2.0, true),
            frag(0, 1.0, true),
            frag(0, 9.0, false), // replica, dropped
        ]);
        assert_eq!(fit.omega.rows(), 2);
        assert_eq!(fit.omega.get(0, 0), 1.0);
        assert_eq!(fit.omega.get(1, 0), 2.0);
        assert_eq!(fit.iterations, 3);
        assert_eq!(fit.mean_linesearch, 2.0);
    }
}
