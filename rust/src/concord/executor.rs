//! The **fabric executor**: the reusable wave-execution layer every
//! grid coordinator schedules through.
//!
//! Screening decomposes problems into independent component solves;
//! the executor is the one place those solves are packed and launched.
//! A client submits
//!
//! - **jobs** ([`ExecutorJob`]): one per independent problem sharing
//!   the schedule — a standalone fit is one job, a (λ₁, λ₂) sweep
//!   submits one per grid point, stability selection one per
//!   subsample. A job carries its data matrix and solver config.
//! - **tasks** ([`ExecutorTask`]): the flat, job-tagged list of every
//!   component solve — which job it belongs to ([`JobTag`]), the
//!   component's global column indices, its [`FabricPlan`], and the
//!   [`ProblemShape`] the packer re-prices with if the plan must
//!   shrink under the budget.
//!
//! [`FabricExecutor::run`] then packs every multi-rank plan with
//! [`plan_concurrent`] under the global rank budget — waves may mix
//! fabrics from *different jobs* — launches each wave's fabrics
//! concurrently on disjoint rank teams via the deterministic scoped
//! pool, and returns the outcomes in task-submission order plus the
//! schedule's critical-path bill (per-wave
//! [`CostSummary::merge_concurrent`], waves folded with
//! [`CostSummary::merge_sequential`]). Tasks whose plan says `P = 1`
//! never enter the packer: they run on the unmetered single-node path,
//! exactly as a standalone screened fit routes them.
//!
//! **Determinism** (rule 6 in `ARCHITECTURE.md`): tasks share no
//! mutable state and land in task-indexed slots, so the schedule —
//! sequential reference or wave-concurrent, any budget, any wave
//! mixing — changes only *when* a fabric launches and what the bill
//! says, never any result bit. Clients reassemble per job in component
//! order, so cross-job packing is invisible in every estimate
//! (`rust/tests/grid_schedule.rs`).
//!
//! The executor does not install the kernel tile shape: clients
//! install `cfg.tile` *before planning* (plans are priced at the
//! installed tile) and the per-fabric rank programs re-install it.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::cost::schedule::{
    plan_concurrent, ConcurrentSchedule, FabricPlan, JobTag, ScheduledComponent,
};
use crate::cost::ProblemShape;
use crate::linalg::Mat;
use crate::simnet::{cost::CostSummary, Counters, MachineParams};
use crate::util::pool::{chunk_ranges, par_map};

use super::screening::extract_columns;
use super::{fit_single_node, run_distributed, ConcordConfig, ConcordFit};

/// One submitted problem: the data matrix and the solver config its
/// component tasks run under. Job `j` of a batch is `jobs[j]`.
#[derive(Debug, Clone, Copy)]
pub struct ExecutorJob<'a> {
    /// Observations (n × p) the component columns are extracted from.
    pub x: &'a Mat,
    /// Solver configuration for every component of this job.
    pub cfg: ConcordConfig,
}

/// One schedulable component solve of some job.
#[derive(Debug, Clone)]
pub struct ExecutorTask {
    /// Which job's component this is (unique per submission).
    pub tag: JobTag,
    /// Ascending global column indices of the component in its job's x.
    pub indices: Vec<usize>,
    /// The planner's fabric choice (`ranks == 1`: single-node path,
    /// never packed). A wider plan than the budget is shrunk by the
    /// packer.
    pub plan: FabricPlan,
    /// Shape the packer re-prices with when shrinking `plan`.
    pub shape: ProblemShape,
}

/// What one executed task produced.
#[derive(Debug)]
pub struct TaskOutcome {
    pub tag: JobTag,
    /// The component's global column indices (moved from the task).
    pub indices: Vec<usize>,
    pub fit: ConcordFit,
    /// The plan that actually ran (budget-shrunk and variant-resolved).
    pub plan: FabricPlan,
    /// Metered cost of this task's fabric (zero on the unmetered
    /// single-node path).
    pub cost: CostSummary,
    /// Rank-indexed counters of the fabric (empty single-node).
    pub counters: Vec<Counters>,
    /// Which wave launched it (`None`: direct single-node task, or a
    /// sequential-mode launch where no waves ran).
    pub wave: Option<usize>,
}

/// Outcome of one executor run.
#[derive(Debug)]
pub struct ExecutorRun {
    /// One outcome per submitted task, in task-submission order.
    pub outcomes: Vec<TaskOutcome>,
    /// The cross-job wave schedule the fabric tasks ran under.
    pub schedule: ConcurrentSchedule,
    /// Critical-path bill of the executed schedule (fabric tasks only;
    /// single-node tasks are unmetered): per-wave concurrent merges
    /// folded sequentially, or the plain serial fold in sequential
    /// mode. Screening is the client's to add.
    pub cost: CostSummary,
}

/// The wave-execution engine: packs job-tagged component plans under a
/// global rank budget and launches them. Pure configuration — build
/// one per batch and call [`FabricExecutor::run`].
#[derive(Debug, Clone, Copy)]
pub struct FabricExecutor {
    /// Global concurrent rank budget the waves are packed under.
    pub budget: usize,
    /// Node-local worker threads used when re-pricing shrunk plans
    /// (clients pass their config's thread count).
    pub threads: usize,
    pub machine: MachineParams,
    /// Launch scheduled fabrics one at a time in tag order with serial
    /// billing instead of wave-concurrently — the reference mode the
    /// equivalence suites compare against. Plans (including budget
    /// shrinks) are identical either way, so results are bit-identical.
    pub sequential: bool,
}

/// One solve's products before the task's indices are moved in.
struct Solved {
    fit: ConcordFit,
    plan: FabricPlan,
    cost: CostSummary,
    counters: Vec<Counters>,
    wave: Option<usize>,
}

/// Solve one task with its final plan: a fabric run for `P > 1`, the
/// (unmetered) single-node path otherwise.
fn solve_task(
    job: &ExecutorJob<'_>,
    task: &ExecutorTask,
    plan: FabricPlan,
    machine: MachineParams,
    wave: Option<usize>,
) -> Result<Solved> {
    let sub_x = extract_columns(job.x, &task.indices);
    if plan.ranks <= 1 {
        let fit = fit_single_node(&sub_x, &job.cfg)?;
        Ok(Solved { fit, plan, cost: CostSummary::default(), counters: Vec::new(), wave })
    } else {
        let mut sub_cfg = job.cfg;
        sub_cfg.variant = plan.variant;
        let run = run_distributed(&sub_x, &sub_cfg, plan.ranks, plan.c_x, plan.c_omega, machine);
        Ok(Solved {
            fit: run.fit,
            plan: FabricPlan { variant: run.variant, ..plan },
            cost: run.cost,
            counters: run.counters,
            wave,
        })
    }
}

impl FabricExecutor {
    /// Pack and run every task. Outcomes come back in task-submission
    /// order whatever the schedule did; the first failing task (by
    /// submission order) propagates as the error.
    pub fn run(&self, jobs: &[ExecutorJob<'_>], tasks: Vec<ExecutorTask>) -> Result<ExecutorRun> {
        let mut index: HashMap<JobTag, usize> = HashMap::with_capacity(tasks.len());
        for (t, task) in tasks.iter().enumerate() {
            if task.tag.job >= jobs.len() {
                bail!("task {:?} names job {} of {}", task.tag, task.tag.job, jobs.len());
            }
            if index.insert(task.tag, t).is_some() {
                bail!("duplicate task tag {:?}", task.tag);
            }
        }

        // Split: P = 1 plans run directly on the single-node path and
        // never enter the packer; everything else is packed.
        let mut direct: Vec<usize> = Vec::new();
        let mut candidates: Vec<(JobTag, FabricPlan, ProblemShape)> = Vec::new();
        for (t, task) in tasks.iter().enumerate() {
            if task.plan.ranks <= 1 {
                direct.push(t);
            } else {
                candidates.push((task.tag, task.plan, task.shape));
            }
        }
        let schedule = plan_concurrent(&candidates, self.budget, self.threads, &self.machine);

        // Outcomes land in task-indexed slots so clients reassemble in
        // a fixed order whatever the launch order was (determinism
        // rule 6: float accumulation across solves is a function of
        // the decomposition only, never of the schedule).
        let mut slots: Vec<Option<Result<Solved>>> = Vec::new();
        slots.resize_with(tasks.len(), || None);
        for &t in &direct {
            let task = &tasks[t];
            slots[t] = Some(solve_task(&jobs[task.tag.job], task, task.plan, self.machine, None));
        }

        let mut cost = CostSummary::default();
        if self.sequential {
            // Reference mode: same plans, one launch at a time in tag
            // (job-major) order, serial billing.
            let mut entries: Vec<&ScheduledComponent> =
                schedule.waves.iter().flat_map(|w| w.entries.iter()).collect();
            entries.sort_by_key(|e| e.tag);
            for e in entries {
                let t = index[&e.tag];
                let out = solve_task(&jobs[e.tag.job], &tasks[t], e.plan, self.machine, None);
                if let Ok(sv) = &out {
                    cost.merge_sequential(&sv.cost);
                }
                slots[t] = Some(out);
            }
        } else {
            for (w, wave) in schedule.waves.iter().enumerate() {
                // One scoped pool worker per fabric in the wave:
                // disjoint rank teams running at the same time.
                // `par_map` returns in entry order, so billing and
                // bookkeeping are schedule-deterministic.
                let ranges = chunk_ranges(wave.entries.len(), wave.entries.len(), 1);
                let outs = par_map(&ranges, |_, start, _| {
                    let e = &wave.entries[start];
                    let t = index[&e.tag];
                    (t, solve_task(&jobs[e.tag.job], &tasks[t], e.plan, self.machine, Some(w)))
                });
                let mut wave_bill = CostSummary::default();
                for (t, out) in outs {
                    if let Ok(sv) = &out {
                        wave_bill.merge_concurrent(&sv.cost);
                    }
                    slots[t] = Some(out);
                }
                cost.merge_sequential(&wave_bill);
            }
        }

        let mut outcomes = Vec::with_capacity(tasks.len());
        for (task, slot) in tasks.into_iter().zip(slots) {
            let solved = slot.expect("every submitted task was launched")?;
            outcomes.push(TaskOutcome {
                tag: task.tag,
                indices: task.indices,
                fit: solved.fit,
                plan: solved.plan,
                cost: solved.cost,
                counters: solved.counters,
                wave: solved.wave,
            });
        }
        Ok(ExecutorRun { outcomes, schedule, cost })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::rng::Rng;

    fn executor() -> FabricExecutor {
        FabricExecutor {
            budget: 8,
            threads: 1,
            machine: MachineParams::default(),
            sequential: false,
        }
    }

    fn single_node_task(job: usize, component: usize, indices: Vec<usize>) -> ExecutorTask {
        let shape = ProblemShape { p: indices.len() as f64, n: 40.0, s: 40.0, t: 10.0, d: 2.0 };
        ExecutorTask {
            tag: JobTag { job, component },
            indices,
            plan: FabricPlan::single_node(Variant::Cov),
            shape,
        }
    }

    #[test]
    fn duplicate_tags_are_rejected() {
        let mut rng = Rng::new(1);
        let prob = gen::chain_problem(6, 40, &mut rng);
        let jobs = [ExecutorJob { x: &prob.x, cfg: ConcordConfig::default() }];
        let tasks = vec![single_node_task(0, 0, vec![0, 1]), single_node_task(0, 0, vec![2, 3])];
        assert!(executor().run(&jobs, tasks).is_err());
    }

    #[test]
    fn unknown_job_is_rejected() {
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(6, 40, &mut rng);
        let jobs = [ExecutorJob { x: &prob.x, cfg: ConcordConfig::default() }];
        let tasks = vec![single_node_task(1, 0, vec![0, 1])];
        assert!(executor().run(&jobs, tasks).is_err());
    }

    /// Single-node plans never enter the packer: empty schedule, zero
    /// bill, outcomes in submission order across two jobs.
    #[test]
    fn single_node_tasks_bypass_the_packer() {
        let mut rng = Rng::new(3);
        let a = gen::chain_problem(6, 40, &mut rng);
        let b = gen::chain_problem(6, 40, &mut rng);
        let cfg = ConcordConfig { lambda1: 0.3, max_iter: 20, ..Default::default() };
        let jobs = [ExecutorJob { x: &a.x, cfg }, ExecutorJob { x: &b.x, cfg }];
        let tasks = vec![
            single_node_task(0, 0, vec![0, 1, 2]),
            single_node_task(1, 0, vec![3, 4, 5]),
        ];
        let run = executor().run(&jobs, tasks).unwrap();
        assert_eq!(run.outcomes.len(), 2);
        assert_eq!(run.outcomes[0].tag, JobTag { job: 0, component: 0 });
        assert_eq!(run.outcomes[1].tag, JobTag { job: 1, component: 0 });
        for out in &run.outcomes {
            assert_eq!(out.fit.omega.rows(), 3);
            assert!(out.wave.is_none());
            assert!(out.counters.is_empty());
        }
        assert!(run.schedule.waves.is_empty());
        assert_eq!(run.cost.time, 0.0);
        assert_eq!(run.cost.total, Counters::default());
    }
}
