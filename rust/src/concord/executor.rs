//! The **fabric executor**: the reusable wave-execution layer every
//! grid coordinator schedules through.
//!
//! Screening decomposes problems into independent component solves;
//! the executor is the one place those solves are packed and launched.
//! A client submits
//!
//! - **jobs** ([`ExecutorJob`]): one per independent problem sharing
//!   the schedule — a standalone fit is one job, a (λ₁, λ₂) sweep
//!   submits one per grid point, stability selection one per
//!   subsample. A job carries its data matrix and solver config.
//! - **tasks** ([`ExecutorTask`]): the flat, job-tagged list of every
//!   component solve — which job it belongs to ([`JobTag`]), the
//!   component's global column indices, its [`FabricPlan`], and the
//!   [`ProblemShape`] the packer re-prices with if the plan must
//!   shrink under the budget.
//!
//! [`FabricExecutor::run`] then packs every multi-rank plan with
//! [`plan_concurrent`] under the global rank budget *and* the global
//! memory budget — waves may mix fabrics from *different jobs* —
//! launches each wave's fabrics concurrently on disjoint rank teams
//! via the deterministic scoped pool, and returns the outcomes in
//! task-submission order plus the schedule's critical-path bill
//! (per-wave [`CostSummary::merge_concurrent`], waves folded with
//! [`CostSummary::merge_sequential`]). Tasks whose plan says `P = 1`
//! never enter the packer: they run on the unmetered single-node path,
//! exactly as a standalone screened fit routes them.
//!
//! **Memory-bounded execution**: each task's column sub-matrix is
//! extracted at *wave launch* and dropped when the wave's outcomes
//! land, so the executor's peak residency is the sum of the current
//! wave's [`MemFootprint`]s — what [`plan_concurrent`] bounded under
//! `mem_budget` — never the whole job list's. Jobs may additionally
//! carry a row view ([`ExecutorJob::rows`]) so clients like stability
//! selection never retain dense subsample copies: the sub-matrix is
//! rebuilt from the row-index list per task, element-for-element
//! identical to extracting from a materialized copy. The modeled peak
//! lands in [`CostSummary::peak_mem_words`].
//!
//! **Determinism** (rules 6 and 7 in `ARCHITECTURE.md`): tasks share
//! no mutable state and land in task-indexed slots, so the schedule —
//! sequential reference or wave-concurrent, any rank or memory budget,
//! any wave mixing — changes only *when* a fabric launches and what
//! the bill says, never any result bit. Clients reassemble per job in
//! component order, so cross-job packing is invisible in every
//! estimate (`rust/tests/grid_schedule.rs`,
//! `rust/tests/memory_budget.rs`).
//!
//! The executor does not install the kernel tile shape: clients
//! install `cfg.tile` *before planning* (plans are priced at the
//! installed tile) and the per-fabric rank programs re-install it.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::cost::schedule::{
    plan_concurrent, ConcurrentSchedule, FabricPlan, JobTag, MemFootprint, PackItem,
    ScheduledComponent,
};
use crate::cost::ProblemShape;
use crate::io::XSource;
use crate::linalg::Mat;
use crate::simnet::{cost::CostSummary, Counters, MachineParams};
use crate::util::pool::{chunk_ranges, par_map};

use super::{fit_single_node, run_distributed, ConcordConfig, ConcordFit};

/// One submitted problem: the data source and the solver config its
/// component tasks run under. Job `j` of a batch is `jobs[j]`.
#[derive(Debug, Clone)]
pub struct ExecutorJob<'a> {
    /// Observations (n × p) the component columns are extracted from —
    /// in-core or an on-disk HPCX file ([`XSource`]). The backend is a
    /// schedule-only knob (determinism rule 8): every extraction is
    /// pure data movement, bit-identical across backends.
    pub x: XSource<'a>,
    /// Solver configuration for every component of this job.
    pub cfg: ConcordConfig,
    /// Optional row view: `Some(rows)` means this job's data is the
    /// listed rows of `x` (a stability subsample, say) rebuilt lazily
    /// per task, so no dense row-subset copy is ever retained between
    /// tasks. `None` means all of `x`'s rows.
    pub rows: Option<Vec<usize>>,
}

impl ExecutorJob<'_> {
    /// Materialize one task's sub-matrix — the only copy of this job's
    /// data a running task holds. Element-for-element identical to
    /// extracting the columns from a materialized row-subset copy, so
    /// the lazy view is invisible downstream (bit-for-bit), and
    /// identical across backends (an on-disk source streams row panels
    /// instead of borrowing the matrix). Errs only on on-disk I/O
    /// failure.
    pub fn extract(&self, indices: &[usize]) -> Result<Mat> {
        match &self.rows {
            None => self.x.extract_columns(indices),
            Some(rows) => self.x.extract_rows_columns(rows, indices),
        }
    }

    /// Sample rows this job's tasks see (the row view's length, or all
    /// of the source's rows).
    pub fn n_rows(&self) -> usize {
        self.rows.as_ref().map(Vec::len).unwrap_or_else(|| self.x.rows())
    }
}

/// One schedulable component solve of some job.
#[derive(Debug, Clone)]
pub struct ExecutorTask {
    /// Which job's component this is (unique per submission).
    pub tag: JobTag,
    /// Ascending global column indices of the component in its job's x.
    pub indices: Vec<usize>,
    /// The planner's fabric choice (`ranks == 1`: single-node path,
    /// never packed). A wider plan than the budget is shrunk by the
    /// packer.
    pub plan: FabricPlan,
    /// Shape the packer re-prices with when shrinking `plan`.
    pub shape: ProblemShape,
    /// Words resident while this task runs (extracted sub-matrix plus
    /// working set) — what the packer charges against `mem_budget`.
    pub mem: MemFootprint,
}

/// What one executed task produced.
#[derive(Debug)]
pub struct TaskOutcome {
    pub tag: JobTag,
    /// The component's global column indices (moved from the task).
    pub indices: Vec<usize>,
    pub fit: ConcordFit,
    /// The plan that actually ran (budget-shrunk and variant-resolved).
    pub plan: FabricPlan,
    /// Metered cost of this task's fabric (zero on the unmetered
    /// single-node path).
    pub cost: CostSummary,
    /// Rank-indexed counters of the fabric (empty single-node).
    pub counters: Vec<Counters>,
    /// Which wave launched it (`None`: direct single-node task, or a
    /// sequential-mode launch where no waves ran).
    pub wave: Option<usize>,
}

/// Outcome of one executor run.
#[derive(Debug)]
pub struct ExecutorRun {
    /// One outcome per submitted task, in task-submission order.
    pub outcomes: Vec<TaskOutcome>,
    /// The cross-job wave schedule the fabric tasks ran under.
    pub schedule: ConcurrentSchedule,
    /// Critical-path bill of the executed schedule (fabric tasks only;
    /// single-node tasks are unmetered): per-wave concurrent merges
    /// folded sequentially, or the plain serial fold in sequential
    /// mode. Screening is the client's to add.
    pub cost: CostSummary,
}

/// Partition task-submission-ordered outcomes back into contiguous
/// per-job groups of the given lengths — the inverse of the submission
/// convention every coordinator (and the serve layer) uses: job 0's
/// tasks first, then job 1's, and so on. Panics if the counts don't
/// cover the outcomes exactly; that is caller bookkeeping gone wrong,
/// not a runtime condition.
pub fn split_by_counts(outcomes: Vec<TaskOutcome>, counts: &[usize]) -> Vec<Vec<TaskOutcome>> {
    assert_eq!(
        counts.iter().sum::<usize>(),
        outcomes.len(),
        "per-job task counts must cover every outcome"
    );
    let mut it = outcomes.into_iter();
    counts.iter().map(|&c| it.by_ref().take(c).collect()).collect()
}

/// The wave-execution engine: packs job-tagged component plans under a
/// global rank budget and launches them. Pure configuration — build
/// one per batch and call [`FabricExecutor::run`].
#[derive(Debug, Clone, Copy)]
pub struct FabricExecutor {
    /// Global concurrent rank budget the waves are packed under.
    pub budget: usize,
    /// Global memory budget in words (0 = unbounded): no wave's
    /// footprint sum may exceed it, and a single task larger than it
    /// is a clean error (memory, unlike ranks, cannot be shrunk).
    pub mem_budget: u64,
    /// Node-local worker threads used when re-pricing shrunk plans
    /// (clients pass their config's thread count).
    pub threads: usize,
    pub machine: MachineParams,
    /// Launch scheduled fabrics one at a time in tag order with serial
    /// billing instead of wave-concurrently — the reference mode the
    /// equivalence suites compare against. Plans (including budget
    /// shrinks) are identical either way, so results are bit-identical.
    pub sequential: bool,
}

/// One solve's products before the task's indices are moved in.
struct Solved {
    fit: ConcordFit,
    plan: FabricPlan,
    cost: CostSummary,
    counters: Vec<Counters>,
    wave: Option<usize>,
}

/// Solve one task with its final plan and its already-extracted
/// sub-matrix: a fabric run for `P > 1`, the (unmetered) single-node
/// path otherwise. The caller owns the sub-matrix's lifetime — the
/// executor extracts at wave launch and drops when the wave lands —
/// and `mem` is the task's modeled residency, billed on the outcome's
/// `peak_mem_words` (the one field the single-node path sets: its
/// sub-matrix is just as resident as a fabric's).
fn solve_task(
    cfg: &ConcordConfig,
    sub_x: &Mat,
    mem: MemFootprint,
    plan: FabricPlan,
    machine: MachineParams,
    wave: Option<usize>,
) -> Result<Solved> {
    if plan.ranks <= 1 {
        let fit = fit_single_node(sub_x, cfg)?;
        let cost = CostSummary { peak_mem_words: mem.words(), ..CostSummary::default() };
        Ok(Solved { fit, plan, cost, counters: Vec::new(), wave })
    } else {
        let mut sub_cfg = *cfg;
        sub_cfg.variant = plan.variant;
        let run = run_distributed(sub_x, &sub_cfg, plan.ranks, plan.c_x, plan.c_omega, machine);
        let mut cost = run.cost;
        cost.peak_mem_words = mem.words();
        Ok(Solved {
            fit: run.fit,
            plan: FabricPlan { variant: run.variant, ..plan },
            cost,
            counters: run.counters,
            wave,
        })
    }
}

impl FabricExecutor {
    /// Pack and run every task. Outcomes come back in task-submission
    /// order whatever the schedule did; the first failing task (by
    /// submission order) propagates as the error.
    pub fn run(&self, jobs: &[ExecutorJob<'_>], tasks: Vec<ExecutorTask>) -> Result<ExecutorRun> {
        let mut index: HashMap<JobTag, usize> = HashMap::with_capacity(tasks.len());
        for (t, task) in tasks.iter().enumerate() {
            if task.tag.job >= jobs.len() {
                bail!("task {:?} names job {} of {}", task.tag, task.tag.job, jobs.len());
            }
            if index.insert(task.tag, t).is_some() {
                bail!("duplicate task tag {:?}", task.tag);
            }
            // Memory cannot be shrunk the way ranks can: a task bigger
            // than the whole budget can never run, whatever the
            // schedule. Catch it up front (single-node tasks included —
            // the packer below only sees the fabric candidates).
            if self.mem_budget > 0 && task.mem.words() > self.mem_budget {
                bail!(
                    "task {:?} needs {} words resident but the memory budget is {} words; \
                     raise --mem-budget or screen harder",
                    task.tag,
                    task.mem.words(),
                    self.mem_budget
                );
            }
        }

        // Split: P = 1 plans run directly on the single-node path and
        // never enter the packer; everything else is packed.
        let mut direct: Vec<usize> = Vec::new();
        let mut candidates: Vec<PackItem> = Vec::new();
        for (t, task) in tasks.iter().enumerate() {
            if task.plan.ranks <= 1 {
                direct.push(t);
            } else {
                candidates.push(PackItem {
                    tag: task.tag,
                    plan: task.plan,
                    shape: task.shape,
                    mem: task.mem,
                });
            }
        }
        let schedule = plan_concurrent(
            &candidates,
            self.budget,
            self.mem_budget,
            self.threads,
            &self.machine,
        )?;

        // Outcomes land in task-indexed slots so clients reassemble in
        // a fixed order whatever the launch order was (determinism
        // rule 6: float accumulation across solves is a function of
        // the decomposition only, never of the schedule).
        let mut slots: Vec<Option<Result<Solved>>> = Vec::new();
        slots.resize_with(tasks.len(), || None);
        let mut cost = CostSummary::default();
        for &t in &direct {
            let task = &tasks[t];
            let job = &jobs[task.tag.job];
            // One direct sub-matrix at a time; it drops right here.
            let sub_x = job.extract(&task.indices)?;
            slots[t] =
                Some(solve_task(&job.cfg, &sub_x, task.mem, task.plan, self.machine, None));
            // Unmetered path: only the residency peak is billed.
            cost.peak_mem_words = cost.peak_mem_words.max(task.mem.words());
        }

        if self.sequential {
            // Reference mode: same plans, one launch at a time in tag
            // (job-major) order, serial billing. One sub-matrix is
            // resident at a time, dropped before the next launch.
            let mut entries: Vec<&ScheduledComponent> =
                schedule.waves.iter().flat_map(|w| w.entries.iter()).collect();
            entries.sort_by_key(|e| e.tag);
            for e in entries {
                let t = index[&e.tag];
                let job = &jobs[e.tag.job];
                let sub_x = job.extract(&tasks[t].indices)?;
                let out =
                    solve_task(&job.cfg, &sub_x, tasks[t].mem, e.plan, self.machine, None);
                if let Ok(sv) = &out {
                    cost.merge_sequential(&sv.cost);
                }
                slots[t] = Some(out);
            }
        } else {
            for (w, wave) in schedule.waves.iter().enumerate() {
                // Extract the wave's sub-matrices at launch: exactly
                // this wave's footprints are resident while it runs —
                // the packer bounded their sum by `mem_budget` — and
                // the whole batch drops when the wave's outcomes land.
                let subs: Vec<Mat> = wave
                    .entries
                    .iter()
                    .map(|e| jobs[e.tag.job].extract(&tasks[index[&e.tag]].indices))
                    .collect::<Result<Vec<Mat>>>()?;
                // One scoped pool worker per fabric in the wave:
                // disjoint rank teams running at the same time.
                // `par_map` returns in entry order, so billing and
                // bookkeeping are schedule-deterministic.
                let ranges = chunk_ranges(wave.entries.len(), wave.entries.len(), 1);
                let outs = par_map(&ranges, |_, start, _| {
                    let e = &wave.entries[start];
                    let t = index[&e.tag];
                    let job = &jobs[e.tag.job];
                    (
                        t,
                        solve_task(&job.cfg, &subs[start], e.mem, e.plan, self.machine, Some(w)),
                    )
                });
                let mut wave_bill = CostSummary::default();
                for (t, out) in outs {
                    if let Ok(sv) = &out {
                        wave_bill.merge_concurrent(&sv.cost);
                    }
                    slots[t] = Some(out);
                }
                drop(subs);
                cost.merge_sequential(&wave_bill);
            }
        }

        // Bill the source-side residency: the widest panel (or whole
        // in-core matrix) any job's backend keeps resident to serve
        // extraction reads (determinism rule 8's residency term).
        cost.x_panel_words = jobs.iter().map(|j| j.x.panel_words()).max().unwrap_or(0);

        let mut outcomes = Vec::with_capacity(tasks.len());
        for (task, slot) in tasks.into_iter().zip(slots) {
            let solved = slot.expect("every submitted task was launched")?;
            outcomes.push(TaskOutcome {
                tag: task.tag,
                indices: task.indices,
                fit: solved.fit,
                plan: solved.plan,
                cost: solved.cost,
                counters: solved.counters,
                wave: solved.wave,
            });
        }
        Ok(ExecutorRun { outcomes, schedule, cost })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::rng::Rng;

    fn executor() -> FabricExecutor {
        FabricExecutor {
            budget: 8,
            mem_budget: 0,
            threads: 1,
            machine: MachineParams::default(),
            sequential: false,
        }
    }

    fn single_node_task(job: usize, component: usize, indices: Vec<usize>) -> ExecutorTask {
        let shape = ProblemShape { p: indices.len() as f64, n: 40.0, s: 40.0, t: 10.0, d: 2.0 };
        let mem = MemFootprint::for_component(40, indices.len());
        ExecutorTask {
            tag: JobTag { job, component },
            indices,
            plan: FabricPlan::single_node(Variant::Cov),
            shape,
            mem,
        }
    }

    #[test]
    fn duplicate_tags_are_rejected() {
        let mut rng = Rng::new(1);
        let prob = gen::chain_problem(6, 40, &mut rng);
        let jobs = [ExecutorJob {
            x: XSource::InCore(&prob.x),
            cfg: ConcordConfig::default(),
            rows: None,
        }];
        let tasks = vec![single_node_task(0, 0, vec![0, 1]), single_node_task(0, 0, vec![2, 3])];
        assert!(executor().run(&jobs, tasks).is_err());
    }

    #[test]
    fn unknown_job_is_rejected() {
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(6, 40, &mut rng);
        let jobs = [ExecutorJob {
            x: XSource::InCore(&prob.x),
            cfg: ConcordConfig::default(),
            rows: None,
        }];
        let tasks = vec![single_node_task(1, 0, vec![0, 1])];
        assert!(executor().run(&jobs, tasks).is_err());
    }

    /// Single-node plans never enter the packer: empty schedule, zero
    /// bill, outcomes in submission order across two jobs.
    #[test]
    fn single_node_tasks_bypass_the_packer() {
        let mut rng = Rng::new(3);
        let a = gen::chain_problem(6, 40, &mut rng);
        let b = gen::chain_problem(6, 40, &mut rng);
        let cfg = ConcordConfig { lambda1: 0.3, max_iter: 20, ..Default::default() };
        let jobs = [
            ExecutorJob { x: XSource::InCore(&a.x), cfg, rows: None },
            ExecutorJob { x: XSource::InCore(&b.x), cfg, rows: None },
        ];
        let tasks = vec![
            single_node_task(0, 0, vec![0, 1, 2]),
            single_node_task(1, 0, vec![3, 4, 5]),
        ];
        let run = executor().run(&jobs, tasks).unwrap();
        assert_eq!(run.outcomes.len(), 2);
        assert_eq!(run.outcomes[0].tag, JobTag { job: 0, component: 0 });
        assert_eq!(run.outcomes[1].tag, JobTag { job: 1, component: 0 });
        for out in &run.outcomes {
            assert_eq!(out.fit.omega.rows(), 3);
            assert!(out.wave.is_none());
            assert!(out.counters.is_empty());
        }
        assert!(run.schedule.waves.is_empty());
        assert_eq!(run.cost.time, 0.0);
        assert_eq!(run.cost.total, Counters::default());
        // Direct tasks still bill their residency: one sub-matrix at a
        // time, so the peak is the largest footprint, not the sum.
        assert_eq!(run.cost.peak_mem_words, MemFootprint::for_component(40, 3).words());
    }

    /// A task wider than a nonzero memory budget is rejected before
    /// anything runs — a clean error, never a panic — and the same
    /// submission passes once the budget covers it.
    #[test]
    fn task_over_mem_budget_is_a_clean_error() {
        let mut rng = Rng::new(4);
        let prob = gen::chain_problem(6, 40, &mut rng);
        let cfg = ConcordConfig { lambda1: 0.3, max_iter: 5, ..Default::default() };
        let jobs = [ExecutorJob { x: XSource::InCore(&prob.x), cfg, rows: None }];
        let need = MemFootprint::for_component(40, 3).words();
        let tight = FabricExecutor { mem_budget: need - 1, ..executor() };
        let err = tight.run(&jobs, vec![single_node_task(0, 0, vec![0, 1, 2])]).unwrap_err();
        assert!(format!("{err}").contains("memory budget"), "{err}");
        let fits = FabricExecutor { mem_budget: need, ..executor() };
        assert!(fits.run(&jobs, vec![single_node_task(0, 0, vec![0, 1, 2])]).is_ok());
    }

    /// `split_by_counts` is the exact inverse of contiguous per-job
    /// submission: groups come back in job order with the job's tags.
    #[test]
    fn split_by_counts_inverts_contiguous_submission() {
        let mut rng = Rng::new(9);
        let prob = gen::chain_problem(6, 40, &mut rng);
        let cfg = ConcordConfig { lambda1: 0.3, max_iter: 10, ..Default::default() };
        let jobs = [
            ExecutorJob { x: XSource::InCore(&prob.x), cfg, rows: None },
            ExecutorJob { x: XSource::InCore(&prob.x), cfg, rows: None },
        ];
        let tasks = vec![
            single_node_task(0, 0, vec![0, 1]),
            single_node_task(0, 1, vec![2, 3]),
            single_node_task(1, 0, vec![4, 5]),
        ];
        let run = executor().run(&jobs, tasks).unwrap();
        let groups = split_by_counts(run.outcomes, &[2, 1]);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].len(), 2);
        assert_eq!(groups[1].len(), 1);
        assert_eq!(groups[0][0].tag, JobTag { job: 0, component: 0 });
        assert_eq!(groups[0][1].tag, JobTag { job: 0, component: 1 });
        assert_eq!(groups[1][0].tag, JobTag { job: 1, component: 0 });
    }

    /// A job carrying a row view solves exactly as if the row subset
    /// had been materialized up front — the lazy rebuild is
    /// bit-invisible.
    #[test]
    fn row_view_jobs_match_materialized_subsamples() {
        let mut rng = Rng::new(5);
        let prob = gen::chain_problem(6, 60, &mut rng);
        let cfg = ConcordConfig { lambda1: 0.3, max_iter: 20, ..Default::default() };
        let rows: Vec<usize> = vec![3, 7, 11, 19, 20, 31, 44, 58];
        let dense = Mat::from_fn(rows.len(), prob.x.cols(), |i, j| prob.x.get(rows[i], j));

        let lazy_jobs = [ExecutorJob { x: XSource::InCore(&prob.x), cfg, rows: Some(rows) }];
        let lazy =
            executor().run(&lazy_jobs, vec![single_node_task(0, 0, vec![1, 2, 4])]).unwrap();
        let dense_jobs = [ExecutorJob { x: XSource::InCore(&dense), cfg, rows: None }];
        let full =
            executor().run(&dense_jobs, vec![single_node_task(0, 0, vec![1, 2, 4])]).unwrap();
        let bits = |m: &Mat| m.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&lazy.outcomes[0].fit.omega), bits(&full.outcomes[0].fit.omega));
        assert_eq!(
            lazy.outcomes[0].fit.objective.to_bits(),
            full.outcomes[0].fit.objective.to_bits()
        );
    }
}
