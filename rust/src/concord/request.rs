//! The unified estimation request: one builder owning everything that
//! used to be spread across [`ConcordConfig`], [`ScreenedDistOptions`]
//! and ad-hoc CLI pin/budget plumbing in `main.rs`.
//!
//! Every front door — `solve`/`sweep` on the CLI, and every job the
//! `serve` layer admits over the wire — constructs one
//! [`EstimationRequest`] and executes it through [`EstimationRequest::run`],
//! so the batch prologue (`batch_setup`: tile install, budget
//! resolution, pin validation) has exactly one caller path by
//! construction. A request is pure data (no open files, no threads):
//! the X it runs over is supplied at execution time as an [`XSource`],
//! which keeps determinism rule 8 intact — the same request over
//! either backend returns bit-identical estimates.

use anyhow::{anyhow, Result};

use crate::cli::Args;
use crate::config::Config;
use crate::coordinator::{
    run_sweep_screened_dist, stability_selection_dist, GridSchedule, GridSpec,
    ScreenedDistSweepOutcome, StabilityConfig, StabilityDistOutcome,
};
use crate::gen;
use crate::io::XSource;
use crate::linalg::{KernelLane, TileConfig, TileSpec};
use crate::rng::Rng;
use crate::simnet::cost::GridBill;
use crate::simnet::MachineParams;

use super::screened_dist::{solves_view, ScreenedDistFit};
use super::{fit_screened_distributed, ConcordConfig, ScreenedDistOptions, Variant};

/// The synthetic workload a request runs over when no `--x-file` is
/// given: the generator's knobs, as pure data (the ground-truth omega
/// the support metrics read comes from regenerating it).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Generator name: `chain` or `random`.
    pub name: String,
    pub p: usize,
    pub n: usize,
    /// Target degree (the `random` workload only).
    pub deg: usize,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec { name: "chain".to_string(), p: 256, n: 100, deg: 8, seed: 42 }
    }
}

impl WorkloadSpec {
    /// CLI/TOML resolution (CLI flags win): `--workload/--p/--n/--deg/
    /// --seed`, TOML `workload`/`p`/`n`/`deg`.
    pub fn from_args(args: &Args, cfg: &Config) -> Result<WorkloadSpec> {
        Ok(WorkloadSpec {
            name: args.str_or("workload", cfg.str_or("workload", "chain")?),
            p: args.usize_or("p", cfg.usize_or("p", 256)?)?,
            n: args.usize_or("n", cfg.usize_or("n", 100)?)?,
            deg: args.usize_or("deg", cfg.usize_or("deg", 8)?)?,
            seed: args.u64_or("seed", 42)?,
        })
    }

    /// Generate the named problem; unknown names are a clean error.
    pub fn generate(&self) -> Result<gen::Problem> {
        let mut rng = Rng::new(self.seed);
        match self.name.as_str() {
            "chain" => Ok(gen::chain_problem(self.p, self.n, &mut rng)),
            "random" => Ok(gen::random_problem(self.p, self.n, self.deg, &mut rng)),
            other => Err(anyhow!("unknown workload {other:?} (chain|random)")),
        }
    }
}

/// What a request asks for: one screened distributed fit, a (λ₁, λ₂)
/// grid sweep, or stability selection over row subsamples.
#[derive(Debug, Clone)]
pub enum RequestKind {
    Solve,
    Sweep {
        grid: GridSpec,
        /// Run the per-point reference schedule instead of the packed
        /// grid schedule (bit-identical results; the bill changes).
        per_point: bool,
    },
    Stability { stab: StabilityConfig },
}

/// The outcome of [`EstimationRequest::run`], one variant per
/// [`RequestKind`].
#[derive(Debug)]
pub enum RequestOutcome {
    Solve(Box<ScreenedDistFit>),
    Sweep(ScreenedDistSweepOutcome),
    Stability(StabilityDistOutcome),
}

impl RequestOutcome {
    /// The grid-level billing view of any outcome: a single fit bills
    /// its screening pass and wave schedule as a one-job grid.
    pub fn bill(&self) -> GridBill {
        match self {
            RequestOutcome::Solve(fit) => GridBill {
                screen: fit.screen_cost,
                waves: fit.solve_cost,
                per_job: vec![solves_view(&fit.solves)],
            },
            RequestOutcome::Sweep(out) => out.bill.clone(),
            RequestOutcome::Stability(out) => out.bill.clone(),
        }
    }
}

/// One estimation request: the solver tuning, the distributed options,
/// the workload (or on-disk X path) and the kind, in one place.
#[derive(Debug, Clone)]
pub struct EstimationRequest {
    pub kind: RequestKind,
    pub cfg: ConcordConfig,
    pub opts: ScreenedDistOptions,
    pub workload: WorkloadSpec,
    /// HPCX path replacing the generated workload's X (the workload
    /// still names the problem shape the file must match).
    pub x_file: Option<String>,
}

impl EstimationRequest {
    /// A request of the given kind with default tuning.
    pub fn new(kind: RequestKind) -> EstimationRequest {
        EstimationRequest {
            kind,
            cfg: ConcordConfig::default(),
            opts: ScreenedDistOptions::default(),
            workload: WorkloadSpec::default(),
            x_file: None,
        }
    }

    /// The CLI/TOML resolution path shared by `solve`, `sweep` and the
    /// server's defaults: solver tuning from `--lambda1`/`[solver]`,
    /// fabric knobs from `--ranks`/`--ranks-budget`/`--mem-budget`/
    /// `[fabric]`, screening knobs from `--screen-cutoff`/
    /// `--gram-block`/`[screen]`, replication pins from
    /// `--cx`/`--comega`, and the workload/x-file pair. CLI flags win
    /// over the config file; defaults match the type-level defaults.
    pub fn from_args(kind: RequestKind, args: &Args, cfg: &Config) -> Result<EstimationRequest> {
        let mut req = EstimationRequest::new(kind);
        let kernel = kernel_lane(args, cfg)?;
        req.cfg = ConcordConfig {
            lambda1: args.f64_or("lambda1", cfg.f64_or("solver.lambda1", 0.3)?)?,
            lambda2: args.f64_or("lambda2", cfg.f64_or("solver.lambda2", 0.0)?)?,
            tol: args.f64_or("tol", cfg.f64_or("solver.tol", 1e-5)?)?,
            max_iter: args.usize_or("max-iter", cfg.usize_or("solver.max_iter", 500)?)?,
            max_linesearch: args
                .usize_or("max-linesearch", cfg.usize_or("solver.max_linesearch", 40)?)?,
            variant: parse_variant(&args.str_or("variant", cfg.str_or("solver.variant", "auto")?)),
            threads: node_threads(args, cfg)?,
            tile: resolve_tile(args, cfg, kernel)?,
            kernel,
            // Pool worker→core pinning: CLI --pin-cores (a bare flag),
            // TOML solver.pin_cores. Schedule-only (rule 10).
            pin_cores: args.has("pin-cores") || cfg.bool_or("solver.pin_cores", false)?,
            // Global concurrent rank budget for screened distributed
            // solving (0 = "use --ranks"): CLI --ranks-budget, TOML
            // fabric.budget.
            ranks_budget: args.usize_or("ranks-budget", cfg.usize_or("fabric.budget", 0)?)?,
            // Host-memory budget in f64 words for wave packing (0 =
            // unbounded): CLI --mem-budget, TOML fabric.mem_budget. A
            // schedule-only knob — results are bit-identical at any
            // value that admits a schedule (determinism rule 7).
            // Parsed as u64 end to end: no narrowing cast between
            // user input and packer.
            mem_budget: args.u64_or("mem-budget", cfg.u64_or("fabric.mem_budget", 0)?)?,
        };
        let ranks = args.usize_or("ranks", cfg.usize_or("fabric.ranks", 8)?)?;
        let c_x = args.usize_or("cx", cfg.usize_or("fabric.cx", 1)?)?;
        let c_o = args.usize_or("comega", cfg.usize_or("fabric.comega", 1)?)?;
        let pinned = args.has("cx")
            || args.has("comega")
            || cfg.get("fabric.cx").is_some()
            || cfg.get("fabric.comega").is_some();
        req.opts = ScreenedDistOptions {
            total_ranks: ranks,
            machine: MachineParams::default(),
            small_cutoff: args.usize_or("screen-cutoff", cfg.usize_or("screen.cutoff", 4)?)?,
            fixed: if pinned { Some((ranks, c_x, c_o)) } else { None },
            sequential: false,
            // Row-panel width for the streamed gram pass (0 = in-core):
            // CLI --gram-block, TOML screen.gram_block. Bit-identical
            // to the in-core pass at any width (rules 1 and 7).
            gram_block: args.usize_or("gram-block", cfg.usize_or("screen.gram_block", 0)?)?,
        };
        req.workload = WorkloadSpec::from_args(args, cfg)?;
        let path = args.str_or("x-file", cfg.str_or("solver.x_file", "")?);
        req.x_file = if path.is_empty() { None } else { Some(path) };
        Ok(req)
    }

    /// The λ₁ thresholds this request's screening pass scans — the
    /// screening-artifact cache keys on these (plus the dataset
    /// fingerprint and the fabric/panel knobs).
    pub fn thresholds(&self) -> Vec<f64> {
        match &self.kind {
            RequestKind::Sweep { grid, .. } => grid.lambda1.clone(),
            _ => vec![self.cfg.lambda1],
        }
    }

    /// Execute the request over `x` through the canonical `XSource`
    /// entry points — the one shared path behind the CLI and the
    /// server (determinism rule 9: any front door yields the bytes
    /// this call yields).
    pub fn run(&self, x: XSource<'_>) -> Result<RequestOutcome> {
        match &self.kind {
            RequestKind::Solve => {
                let fit = fit_screened_distributed(x, &self.cfg, &self.opts)?;
                Ok(RequestOutcome::Solve(Box::new(fit)))
            }
            RequestKind::Sweep { grid, per_point } => {
                let mode =
                    if *per_point { GridSchedule::PerPoint } else { GridSchedule::Packed };
                let out = run_sweep_screened_dist(x, grid, &self.cfg, &self.opts, mode)?;
                Ok(RequestOutcome::Sweep(out))
            }
            RequestKind::Stability { stab } => {
                let out = stability_selection_dist(x, &self.cfg, stab, &self.opts)?;
                Ok(RequestOutcome::Stability(out))
            }
        }
    }
}

/// Variant names as the CLI and the wire protocol spell them; anything
/// else falls back to `auto` (the historical CLI behavior).
pub fn parse_variant(name: &str) -> Variant {
    match name {
        "cov" => Variant::Cov,
        "obs" => Variant::Obs,
        _ => Variant::Auto,
    }
}

/// The kernel layer's cache-blocking shape: `--tile mc,kc,nc`, else the
/// config file's `solver.tile = [mc, kc, nc]`, else the compile-time
/// default. Bit-identical results at any value — a throughput knob.
pub fn tile_config(args: &Args, cfg: &Config) -> Result<TileConfig> {
    let raw = args.str_or("tile", "");
    if !raw.is_empty() {
        return TileConfig::parse(&raw);
    }
    let from_file = cfg.array_or("solver.tile", &[])?;
    if from_file.is_empty() {
        Ok(TileConfig::DEFAULT)
    } else {
        TileConfig::from_f64s(&from_file)
    }
}

/// The microkernel ISA lane: `--kernel scalar|avx2|avx512|auto`, else
/// the config file's `solver.kernel`, else `auto`. A forced concrete
/// lane this host cannot run is a clean error here — the install-time
/// fallback would silently hand back the scalar kernel, and a user who
/// forced a lane wants to know it did not happen.
pub fn kernel_lane(args: &Args, cfg: &Config) -> Result<KernelLane> {
    let raw = args.str_or("kernel", cfg.str_or("solver.kernel", "auto")?);
    let lane = KernelLane::parse(&raw)?;
    if !lane.available() {
        return Err(anyhow!(
            "--kernel {}: this host does not support the {} lane \
             (use --kernel auto to pick the best available)",
            lane.as_str(),
            lane.as_str()
        ));
    }
    Ok(lane)
}

/// Resolve the tile shape including `--tile auto` (TOML:
/// `solver.tile_auto = true`): a short deterministic calibration sweep
/// times the [`crate::linalg::tile::AUTO_CANDIDATES`] on a fixed
/// synthetic workload and installs the fastest. The sweep runs under
/// `kernel` — the lane the solve itself will run — so the winner
/// reflects real throughput. Calibration is sound at any outcome:
/// tiles are value-preserving, so a noisy timer can only cost
/// wall-clock, never a result bit.
fn resolve_tile(args: &Args, cfg: &Config, kernel: KernelLane) -> Result<TileConfig> {
    let raw = args.str_or("tile", "");
    let spec = if !raw.is_empty() {
        TileSpec::parse(&raw)?
    } else if cfg.bool_or("solver.tile_auto", false)? {
        TileSpec::Auto
    } else {
        TileSpec::Fixed(tile_config(args, cfg)?)
    };
    match spec {
        TileSpec::Fixed(t) => Ok(t),
        TileSpec::Auto => {
            crate::linalg::simd::install(kernel);
            let cal = crate::linalg::dense::calibrate_tile();
            println!("{}", cal.summary());
            Ok(cal.winner)
        }
    }
}

/// The node-local thread count (the paper's per-node t): `--threads N`,
/// else the config file's `solver.threads`, else `--threads auto` /
/// `solver.threads = 0` picks the host's available parallelism.
pub fn node_threads(args: &Args, cfg: &Config) -> Result<usize> {
    let raw = args.str_or("threads", "");
    let n = if raw == "auto" {
        0
    } else if raw.is_empty() {
        cfg.usize_or("solver.threads", 1)?
    } else {
        args.usize_or("threads", 1)?
    };
    Ok(if n == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        n
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(str::to_string).collect();
        Args::parse(&argv)
    }

    #[test]
    fn from_args_resolves_cli_over_defaults() {
        let args = parse("solve --lambda1 0.45 --ranks 16 --ranks-budget 6 --mem-budget 999");
        let req =
            EstimationRequest::from_args(RequestKind::Solve, &args, &Config::default()).unwrap();
        assert_eq!(req.cfg.lambda1, 0.45);
        assert_eq!(req.opts.total_ranks, 16);
        assert_eq!(req.cfg.ranks_budget, 6);
        assert_eq!(req.cfg.mem_budget, 999);
        assert!(req.opts.fixed.is_none());
        assert!(req.x_file.is_none());
    }

    #[test]
    fn pins_only_when_replication_is_explicit() {
        let cfg = Config::default();
        let none = EstimationRequest::from_args(RequestKind::Solve, &parse("solve"), &cfg);
        assert!(none.unwrap().opts.fixed.is_none());
        let some = EstimationRequest::from_args(
            RequestKind::Solve,
            &parse("solve --ranks 4 --cx 2"),
            &cfg,
        );
        assert_eq!(some.unwrap().opts.fixed, Some((4, 2, 1)));
    }

    #[test]
    fn thresholds_follow_the_kind() {
        let solve = EstimationRequest::new(RequestKind::Solve);
        assert_eq!(solve.thresholds(), vec![solve.cfg.lambda1]);
        let grid = GridSpec { lambda1: vec![0.2, 0.5], lambda2: vec![0.0] };
        let sweep =
            EstimationRequest::new(RequestKind::Sweep { grid: grid.clone(), per_point: false });
        assert_eq!(sweep.thresholds(), grid.lambda1);
    }

    #[test]
    fn kernel_and_pinning_resolve_from_cli() {
        let cfg = Config::default();
        let req = EstimationRequest::from_args(
            RequestKind::Solve,
            &parse("solve --kernel scalar --pin-cores"),
            &cfg,
        )
        .unwrap();
        assert_eq!(req.cfg.kernel, KernelLane::Scalar);
        assert!(req.cfg.pin_cores);
        let def = EstimationRequest::from_args(RequestKind::Solve, &parse("solve"), &cfg).unwrap();
        assert_eq!(def.cfg.kernel, KernelLane::Auto);
        assert!(!def.cfg.pin_cores);
    }

    #[test]
    fn garbage_kernel_is_a_clean_error() {
        let err = EstimationRequest::from_args(
            RequestKind::Solve,
            &parse("solve --kernel mmx"),
            &Config::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("scalar|avx2|avx512|auto"), "{err}");
    }

    #[test]
    fn tile_auto_calibrates_to_a_candidate() {
        // The calibration sweep must install one of the published
        // candidates; which one wins is host-dependent (and harmless —
        // tiles are value-preserving).
        let req = EstimationRequest::from_args(
            RequestKind::Solve,
            &parse("solve --tile auto --kernel scalar"),
            &Config::default(),
        )
        .unwrap();
        assert!(crate::linalg::tile::AUTO_CANDIDATES.contains(&req.cfg.tile));
    }

    #[test]
    fn unknown_workload_is_a_clean_error() {
        let spec = WorkloadSpec { name: "spiral".into(), ..Default::default() };
        let err = spec.generate().unwrap_err();
        assert!(err.to_string().contains("unknown workload"), "{err}");
    }
}
