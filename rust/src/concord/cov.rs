//! **Algorithm 2** — the Cov variant of HP-CONCORD, as a rank program
//! for the simulated fabric.
//!
//! Cov pays the one-time cost of S = XᵀX/n (1.5D concat-mode multiply,
//! rotating Xᵀ row slabs over the c_X grid), then computes W⁽ᵏ⁾ = Ω⁽ᵏ⁾S
//! per line-search trial by rotating the *sparse iterate* over the c_Ω
//! grid against the stationary dense S column blocks — the sparse-dense
//! shift that can beat 2D/2.5D/3D algorithms by orders of magnitude
//! (paper §3, citing [29]).
//!
//! Layouts (paper Fig. 1, left): S and W live in 1D block *columns* over
//! the c_X grid's teams; Ω rotates in 1D block rows over the c_Ω grid.
//! After the global transpose of W, the gradient/prox run in the X
//! grid's block-row layout, and the new iterate is redistributed back to
//! the Ω grid ("converts Ω back to 1D block row layout"); when
//! c_X = c_Ω the redistribution is free.

use std::sync::Arc;

use crate::dist::{
    mult_concat, redistribute_rows, transpose_block_rows, Block, ConcatAxis, Layout1D, RepGrid,
};
use crate::linalg::{Csr, Mat};
use crate::simnet::Comm;

use super::dist_common::{combine_objective, global_max, global_sum, RankFit, TagGen};
use super::ops;
use super::{ConcordConfig, SolveStats};

/// Run Cov on this rank; see [`super::fit_distributed`].
pub fn fit_cov_rank(
    comm: &mut Comm,
    x: &Arc<Mat>,
    cfg: &ConcordConfig,
    c_x: usize,
    c_omega: usize,
) -> RankFit {
    let p_ranks = comm.size();
    let (n, p) = x.shape();
    let grid_x = RepGrid::new(p_ranks, c_x);
    let grid_o = RepGrid::new(p_ranks, c_omega);
    let lx = Layout1D::new(p, grid_x.teams()); // S/W cols, G/Ω rows in X layout
    let lo = Layout1D::new(p, grid_o.teams()); // Ω rotation parts
    let rank = comm.rank();
    let my_x = grid_x.team_of(rank);
    let my_o = grid_o.team_of(rank);
    let x_layer_group = grid_x.layer_members(grid_x.layer_of(rank));
    let mut tags = TagGen::new();
    // Node-local threads (the paper's per-node t): every local multiply
    // and fused pass below fans out over this many workers; results are
    // bit-identical at any value, and the metered L/W never change.
    let threads = cfg.threads.max(1);

    let (cs, ce) = lx.range(my_x); // my column range (and X-layout row range)
    let width = ce - cs;
    let (ors, ore) = lo.range(my_o); // my Ω rotation part rows

    // One-time: S(:, cs..ce) = XᵀX/n via rotated Xᵀ row slabs.
    let xt_slab = Block::Dense(x.col_block(cs, ce).transpose()); // my Xᵀ rows (width × n)
    let x_fixed = x.col_block(cs, ce); // n × width
    let mut s_cols = mult_concat(
        comm,
        &grid_x,
        &grid_x,
        tags.next(10_000),
        &xt_slab,
        ConcatAxis::Rows,
        &lx,
        width,
        |comm, _idx, blk| {
            let a = blk.as_dense();
            comm.count_flops_dense(2 * (a.rows() * n * width) as u64);
            a.matmul_mt(&x_fixed, threads)
        },
    );
    s_cols.scale(1.0 / n as f64); // p × width

    // Iterate, in both layouts: X-layout block rows (for G/prox/objective)
    // and Ω-grid rotation part (for the W multiply).
    let mut omega_x = Mat::from_fn(width, p, |i, j| f64::from(cs + i == j));
    // The Ω-grid copy is only needed to seed the first W multiply; the
    // line-search trials redistribute each candidate themselves.
    let omega_o = Mat::from_fn(ore - ors, p, |i, j| f64::from(ors + i == j));

    // W(:, my cols) = Ω·S via rotated sparse Ω parts (Algorithm 2 l. 3/10).
    let w_step = |comm: &mut Comm, tags: &mut TagGen, om_part: &Mat| -> Mat {
        let part = Block::Sparse(Csr::from_dense(om_part, 0.0));
        mult_concat(
            comm,
            &grid_o,
            &grid_x,
            tags.next(10_000),
            &part,
            ConcatAxis::Rows,
            &lo,
            width,
            |comm, _idx, blk| {
                let (out, fd, fs) = blk.matmul_mt(&s_cols, threads);
                comm.count_flops_dense(fd);
                comm.count_flops_sparse(fs);
                out
            },
        )
    };

    // Objective from X-layout pieces: tr(WΩ) = Σ W(:,cols)∘Ω(:,cols) and
    // Ω(:,cols) = Ω(cols,:)ᵀ by symmetry of the iterate.
    let objective = |comm: &mut Comm, tags: &mut TagGen, om_x: &Mat, w_cols: &Mat| -> f64 {
        let parts = match ops::diag_fro_parts_block_mt(om_x, cs, threads) {
            Some([logd, fro]) => {
                let tr = w_cols.dot_elem(&om_x.transpose());
                vec![0.0, logd, tr, fro]
            }
            None => vec![1.0, 0.0, 0.0, 0.0],
        };
        let global = global_sum(comm, &x_layer_group, tags.next(10), parts);
        combine_objective(&global, cfg.lambda2)
    };

    let mut w_cols = w_step(comm, &mut tags, &omega_o); // p × width
    let mut stats = SolveStats::default();
    let mut converged = false;
    let mut g_final = f64::INFINITY;

    for _it in 0..cfg.max_iter {
        stats.iters += 1;

        // Global transpose of W (Algorithm 2 line 5): our storage of the
        // column block is Wᵀ's block rows, so one distributed transpose
        // yields W's block rows; both slabs then live in the X layout.
        let wt_rows = w_cols.transpose(); // Wᵀ(cols,:) = my block rows of Wᵀ
        let (w_rows, _) = transpose_block_rows(comm, &grid_x, tags.next(10), &wt_rows, &lx);

        let grad = ops::gradient_block_mt(&omega_x, &w_rows, &wt_rows, cs, cfg.lambda2, threads);
        let g_prev = objective(comm, &mut tags, &omega_x, &w_cols);

        // Line search (Algorithm 2 lines 8-12).
        let mut tau = 1.0;
        let mut accepted = None;
        for _ls in 0..cfg.max_linesearch {
            stats.trials += 1;
            let omega_x_new = ops::prox_block_mt(&omega_x, &grad, cs, tau, cfg.lambda1, threads);
            // Back to the Ω grid for the rotation (free when c_X = c_Ω).
            let omega_o_new = redistribute_rows(
                comm,
                tags.next(100),
                &omega_x_new,
                &grid_x,
                &lx,
                &grid_o,
                &lo,
            );
            let w_new = w_step(comm, &mut tags, &omega_o_new);
            let g_new = objective(comm, &mut tags, &omega_x_new, &w_new);
            let ls_local = ops::linesearch_parts_block_mt(&omega_x, &omega_x_new, &grad, threads);
            let ls = global_sum(comm, &x_layer_group, tags.next(10), ls_local.to_vec());
            let _ = &omega_o_new; // candidate lives only within the trial
            if ops::accepts(g_new, g_prev, [ls[0], ls[1]], tau) {
                accepted = Some((omega_x_new, w_new, g_new));
                break;
            }
            accepted = Some((omega_x_new, w_new, g_new));
            tau *= 0.5;
        }
        let (omega_x_new, w_new, g_new) = accepted.expect("at least one trial");

        let delta_local = omega_x.max_abs_diff(&omega_x_new);
        let delta = global_max(comm, &x_layer_group, tags.next(10), delta_local);
        omega_x = omega_x_new;
        w_cols = w_new;
        g_final = g_new;

        let nnz = global_sum(
            comm,
            &x_layer_group,
            tags.next(10),
            vec![omega_x.nnz() as f64],
        )[0] as u64;
        stats.nnz_samples += p as u64;
        stats.nnz_total += nnz;

        if delta < cfg.tol {
            converged = true;
            break;
        }
    }

    RankFit {
        row_start: cs,
        omega_block: omega_x,
        primary: grid_x.layer_of(rank) == 0,
        stats,
        objective: g_final,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::dist_common::assemble_fit;
    use crate::concord::single_node::fit_single_node;
    use crate::concord::Variant;
    use crate::rng::Rng;
    use crate::simnet::Fabric;

    fn test_cfg() -> ConcordConfig {
        ConcordConfig {
            lambda1: 0.25,
            lambda2: 0.1,
            tol: 1e-6,
            max_iter: 200,
            variant: Variant::Cov,
            ..Default::default()
        }
    }

    #[test]
    fn cov_matches_single_node_across_configs() {
        let mut rng = Rng::new(31);
        let (n, p) = (20usize, 16usize);
        let x = Mat::from_fn(n, p, |_, _| rng.normal());
        let cfg = test_cfg();
        let reference = fit_single_node(&x, &cfg).unwrap();

        // Cov's gram step rotates Xᵀ against X on the same c_X grid, so
        // it additionally needs c_X² ≤ P (the paper's L_Cov = P/c_X² + …
        // term presumes the same).
        for &(pr, cx, co) in &[
            (1usize, 1usize, 1usize),
            (4, 1, 1),
            (4, 2, 2),
            (4, 2, 1),
            (4, 1, 2),
            (8, 2, 4),
            (16, 4, 2),
        ] {
            let x = Arc::new(x.clone());
            let run = Fabric::new(pr).run(move |comm| fit_cov_rank(comm, &x, &cfg, cx, co));
            let fit = assemble_fit(run.results);
            assert_eq!(fit.iterations, reference.iterations, "P={pr} cx={cx} co={co}");
            assert!(
                fit.omega.max_abs_diff(&reference.omega) < 1e-8,
                "P={pr} cx={cx} co={co}: {}",
                fit.omega.max_abs_diff(&reference.omega)
            );
        }
    }

    /// Cov and Obs are two factorizations of the same math: their
    /// estimates must agree.
    #[test]
    fn cov_and_obs_agree_distributed() {
        let mut rng = Rng::new(32);
        let (n, p) = (10usize, 16usize);
        let xm = Mat::from_fn(n, p, |_, _| rng.normal());
        let cfg = test_cfg();
        let x1 = Arc::new(xm.clone());
        let cov = assemble_fit(
            Fabric::new(4)
                .run(move |comm| fit_cov_rank(comm, &x1, &cfg, 2, 2))
                .results,
        );
        let x2 = Arc::new(xm);
        let obs = assemble_fit(
            Fabric::new(4)
                .run(move |comm| super::super::obs::fit_obs_rank(comm, &x2, &cfg, 2, 2))
                .results,
        );
        assert!(cov.omega.max_abs_diff(&obs.omega) < 1e-7);
    }
}
