//! Covariance screening and block decomposition — the paper's
//! "divide-and-conquer strategy based on a block structure assumption"
//! future-work item (§6), via the exact thresholding rule of Mazumder &
//! Hastie [35] (cited by the paper's §5 baseline).
//!
//! For the ℓ₁-penalized criterion, variables i and j can only be
//! connected in the estimate if they are connected in the graph
//! `{|S_ij| > λ₁}`. Decomposing that graph into connected components
//! splits one p×p problem into independent sub-problems — and the fMRI
//! estimates' hemisphere-block-diagonal structure (§S.3.3) is exactly
//! this phenomenon surfacing in data.
//!
//! This module owns the pieces every screened path shares:
//!
//! - [`UnionFind`] and [`Components`]: the disjoint-set decomposition
//!   of the thresholded gram graph ([`gram_components`]), also used by
//!   the distributed screening pass in [`super::screened_dist`], which
//!   merges per-rank block-row labelings through the same structure;
//! - [`nested_components`]: per-threshold components for a λ₁ grid,
//!   computed by refinement — the threshold graphs are nested, so each
//!   level only rescans within the previous level's components (the
//!   reuse the screened sweep in [`crate::coordinator::sweep`] relies
//!   on; its distributed analogue is the amortized multi-threshold
//!   pass [`super::screened_dist::screen_distributed_multi`], which
//!   replays one shared thresholded edge list per level over gram rows
//!   formed once);
//! - [`extract_columns`] / [`scatter_block`] / the singleton closed
//!   form `ω_ii = 1/√(s_ii + λ₂)`: sub-problem extraction and
//!   block-diagonal reassembly;
//! - [`ScreenAccum`]: the reassembly accumulator with **summed**
//!   iteration statistics — `fit.iterations` is the total across
//!   components and `mean_linesearch` the trial-weighted mean, so
//!   `iterations · mean_linesearch` is the total number of line-search
//!   trials exactly as in the unscreened fits (semantics pinned by
//!   `rust/tests/screening_equivalence.rs`).
//!
//! [`fit_with_screening`] runs the decomposition and solves each
//! component with the single-node solver; the distributed composition
//! (one sized fabric per component) lives in [`super::screened_dist`].

use anyhow::Result;

use crate::linalg::Mat;
use crate::runtime::native;

use super::{fit_single_node, ConcordConfig, ConcordFit};

/// Disjoint-set forest with path halving. Union keeps the *smaller*
/// root, so a set's representative is always its minimum member — which
/// makes labelings canonical (and mergeable across ranks: a labeling is
/// fully described by the pairs `(i, find(i))`).
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<usize>,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect() }
    }

    /// Representative (minimum member) of `i`'s set.
    pub fn find(&mut self, mut i: usize) -> usize {
        while self.parent[i] != i {
            self.parent[i] = self.parent[self.parent[i]];
            i = self.parent[i];
        }
        i
    }

    /// Merge the sets of `a` and `b`.
    pub fn union(&mut self, a: usize, b: usize) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra != rb {
            // Attach the larger root under the smaller: representatives
            // stay minimal, so labels are canonical without a relabel.
            let (lo, hi) = (ra.min(rb), ra.max(rb));
            self.parent[hi] = lo;
        }
    }

    /// Finish into a dense component labeling.
    pub fn into_components(mut self) -> Components {
        let n = self.parent.len();
        let raw: Vec<usize> = (0..n).map(|i| self.find(i)).collect();
        Components::from_raw_labels(&raw)
    }
}

/// A component labeling of `p` variables: `comp[i]` is variable `i`'s
/// component id, ids densely numbered `0..count` in order of each
/// component's smallest member. Member lists are bucketed once at
/// construction, so [`Components::members`] is a slice borrow — not the
/// O(p) label rescan per component (O(p²) across a fragmented fit) it
/// used to be.
#[derive(Debug, Clone)]
pub struct Components {
    pub comp: Vec<usize>,
    pub count: usize,
    /// `members[c]` = ascending member indices of component `c`
    /// (bucketed in [`Components::from_raw_labels`]; always consistent
    /// with `comp`).
    members: Vec<Vec<usize>>,
}

/// Equality is the labeling itself; `members` is derived from it.
impl PartialEq for Components {
    fn eq(&self, other: &Self) -> bool {
        self.comp == other.comp && self.count == other.count
    }
}

impl Eq for Components {}

impl Components {
    /// Renumber arbitrary labels densely by first appearance, bucketing
    /// each component's member list in the same single pass.
    pub fn from_raw_labels(raw: &[usize]) -> Components {
        let mut map = std::collections::HashMap::new();
        let mut comp = Vec::with_capacity(raw.len());
        let mut members: Vec<Vec<usize>> = Vec::new();
        for (i, &r) in raw.iter().enumerate() {
            let next = map.len();
            let id = *map.entry(r).or_insert(next);
            if id == members.len() {
                members.push(Vec::new());
            }
            members[id].push(i);
            comp.push(id);
        }
        Components { comp, count: map.len(), members }
    }

    /// Ascending member indices of component `c` (a borrow of the list
    /// bucketed at construction).
    pub fn members(&self, c: usize) -> &[usize] {
        &self.members[c]
    }

    /// Member count per component.
    pub fn sizes(&self) -> Vec<usize> {
        self.members.iter().map(Vec::len).collect()
    }

    /// Size of the largest component (the remaining hard work).
    pub fn largest(&self) -> usize {
        self.sizes().into_iter().max().unwrap_or(0)
    }
}

/// Connected components of the thresholded covariance graph
/// `{(i, j) : |S_ij| > threshold, i ≠ j}` via union-find over the
/// strict upper triangle (`s` is a gram matrix, hence symmetric; both
/// triangles are consulted anyway for robustness).
pub fn gram_components(s: &Mat, threshold: f64) -> Components {
    let p = s.rows();
    let mut uf = UnionFind::new(p);
    for i in 0..p {
        for j in (i + 1)..p {
            if s.get(i, j).abs() > threshold || s.get(j, i).abs() > threshold {
                uf.union(i, j);
            }
        }
    }
    uf.into_components()
}

/// [`gram_components`] as a plain label vector (compatibility surface;
/// numbering is identical: ids ascend with each component's smallest
/// member).
pub fn covariance_components(s: &Mat, threshold: f64) -> Vec<usize> {
    gram_components(s, threshold).comp
}

/// Components for every threshold of a λ₁ grid (any order, returned
/// aligned with the input), computed by nested refinement: thresholds
/// are visited ascending, and each level's edges `{|S_ij| > λ}` are a
/// subset of the previous level's, so only pairs *inside* an existing
/// component are rescanned — the screened sweep's cross-grid reuse.
pub fn nested_components(s: &Mat, thresholds: &[f64]) -> Vec<Components> {
    let p = s.rows();
    let mut order: Vec<usize> = (0..thresholds.len()).collect();
    // total_cmp: a NaN threshold (e.g. user-typed "nan" on the CLI)
    // sorts last and simply yields all-singleton components instead of
    // panicking mid-sort.
    order.sort_by(|&a, &b| thresholds[a].total_cmp(&thresholds[b]));
    let mut out: Vec<Option<Components>> = vec![None; thresholds.len()];
    let mut prev: Option<Components> = None;
    for &k in &order {
        let thr = thresholds[k];
        let comps = match &prev {
            None => gram_components(s, thr),
            Some(coarse) => {
                let mut uf = UnionFind::new(p);
                for c in 0..coarse.count {
                    let idx = coarse.members(c);
                    for (a, &i) in idx.iter().enumerate() {
                        for &j in &idx[a + 1..] {
                            if s.get(i, j).abs() > thr || s.get(j, i).abs() > thr {
                                uf.union(i, j);
                            }
                        }
                    }
                }
                uf.into_components()
            }
        };
        out[k] = Some(comps.clone());
        prev = Some(comps);
    }
    out.into_iter().map(|o| o.expect("every threshold visited")).collect()
}

/// The columns of `x` named by `idx`, in order — the sub-problem data
/// of one component.
pub fn extract_columns(x: &Mat, idx: &[usize]) -> Mat {
    Mat::from_fn(x.rows(), idx.len(), |r, k| x.get(r, idx[k]))
}

/// Scatter a component's estimate back into the global block-diagonal
/// omega.
pub fn scatter_block(omega: &mut Mat, idx: &[usize], sub: &Mat) {
    for (a, &i) in idx.iter().enumerate() {
        for (b, &j) in idx.iter().enumerate() {
            omega.set(i, j, sub.get(a, b));
        }
    }
}

/// Singleton closed form: ω = argmin −log ω + (s_ii/2 + λ₂/2)ω² =
/// 1/√(s_ii + λ₂).
pub fn singleton_omega(s_ii: f64, lambda2: f64) -> f64 {
    1.0 / (s_ii + lambda2).sqrt()
}

/// Objective contribution of a singleton at its closed-form optimum.
pub fn singleton_objective(s_ii: f64, lambda2: f64) -> f64 {
    let w = singleton_omega(s_ii, lambda2);
    -w.ln() + 0.5 * s_ii * w * w + 0.5 * lambda2 * w * w
}

/// Per-component solver statistics of a screened fit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComponentStat {
    /// Component size (variables).
    pub size: usize,
    /// Proximal gradient iterations this component took.
    pub iterations: usize,
    /// Mean line-search trials per iteration within this component.
    pub mean_linesearch: f64,
    pub converged: bool,
}

/// Outcome of a screened fit.
#[derive(Debug)]
pub struct ScreenedFit {
    /// The assembled block-diagonal estimate. `fit.iterations` is the
    /// **sum** over components and `fit.mean_linesearch` the
    /// trial-weighted mean, so their product is the total line-search
    /// trial count (see [`ScreenAccum`]).
    pub fit: ConcordFit,
    /// Number of connected components the problem split into.
    pub components: usize,
    /// Size of the largest component (the remaining hard work).
    pub largest: usize,
    /// One entry per non-singleton component, in component order.
    pub per_component: Vec<ComponentStat>,
}

/// Reassembly accumulator shared by the single-node and distributed
/// screened paths. Iteration statistics are *summed* across components
/// (and `mean_linesearch` is the trial-weighted mean), fixing the old
/// max-iterations/divide-by-max inconsistency; the semantics are pinned
/// by a regression test in `rust/tests/screening_equivalence.rs`.
#[derive(Debug)]
pub(crate) struct ScreenAccum {
    omega: Mat,
    iterations: usize,
    trials: f64,
    objective: f64,
    converged: bool,
    per_component: Vec<ComponentStat>,
}

impl ScreenAccum {
    pub(crate) fn new(p: usize) -> Self {
        ScreenAccum {
            omega: Mat::zeros(p, p),
            iterations: 0,
            trials: 0.0,
            objective: 0.0,
            converged: true,
            per_component: Vec::new(),
        }
    }

    pub(crate) fn add_singleton(&mut self, i: usize, s_ii: f64, lambda2: f64) {
        self.omega.set(i, i, singleton_omega(s_ii, lambda2));
        self.objective += singleton_objective(s_ii, lambda2);
    }

    pub(crate) fn add_component(&mut self, idx: &[usize], sub: &ConcordFit) {
        scatter_block(&mut self.omega, idx, &sub.omega);
        self.iterations += sub.iterations;
        self.trials += sub.mean_linesearch * sub.iterations as f64;
        self.objective += sub.objective;
        self.converged &= sub.converged;
        self.per_component.push(ComponentStat {
            size: idx.len(),
            iterations: sub.iterations,
            mean_linesearch: sub.mean_linesearch,
            converged: sub.converged,
        });
    }

    pub(crate) fn finish(self, components: usize, largest: usize) -> ScreenedFit {
        let p = self.omega.rows();
        let nnz = self.omega.nnz();
        let iterations = self.iterations;
        ScreenedFit {
            fit: ConcordFit {
                omega: self.omega,
                iterations,
                mean_linesearch: if iterations > 0 {
                    self.trials / iterations as f64
                } else {
                    0.0
                },
                mean_row_nnz: nnz as f64 / p.max(1) as f64,
                objective: self.objective,
                converged: self.converged,
            },
            components,
            largest,
            per_component: self.per_component,
        }
    }
}

/// Fit with covariance screening: decompose at `λ₁`, solve each
/// component independently with the single-node solver, and reassemble
/// the block-diagonal estimate.
pub fn fit_with_screening(x: &Mat, cfg: &ConcordConfig) -> Result<ScreenedFit> {
    // Blocking shape, kernel lane and pinning for the gram pass
    // (throughput only; per-component fits re-install the same values).
    crate::linalg::tile::install(cfg.tile);
    crate::linalg::simd::install(cfg.kernel);
    crate::util::pool::set_pin_cores(cfg.pin_cores);
    let s = native::gram_mt(x, cfg.threads.max(1));
    let comps = gram_components(&s, cfg.lambda1);
    fit_with_screening_on(x, &s, &comps, cfg)
}

/// [`fit_with_screening`] on a precomputed gram matrix and component
/// decomposition — the entry point for sweeps that amortize `S = XᵀX/n`
/// and the [`nested_components`] refinement across a λ-grid.
pub fn fit_with_screening_on(
    x: &Mat,
    s: &Mat,
    comps: &Components,
    cfg: &ConcordConfig,
) -> Result<ScreenedFit> {
    let p = x.cols();
    assert_eq!(comps.comp.len(), p, "component labeling must cover every column");
    let mut acc = ScreenAccum::new(p);
    let mut largest = 0usize;
    for c in 0..comps.count {
        let idx = comps.members(c);
        largest = largest.max(idx.len());
        if idx.len() == 1 {
            acc.add_singleton(idx[0], s.get(idx[0], idx[0]), cfg.lambda2);
            continue;
        }
        let sub_x = extract_columns(x, idx);
        let sub = fit_single_node(&sub_x, cfg)?;
        acc.add_component(idx, &sub);
    }
    Ok(acc.finish(comps.count, largest))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::rng::Rng;

    /// Two independent chain blocks: screening must find ≥2 components
    /// and the screened fit must match the un-screened fit.
    #[test]
    fn screening_decomposes_independent_blocks() {
        let mut rng = Rng::new(1);
        let a = gen::chain_problem(10, 800, &mut rng);
        let b = gen::chain_problem(10, 800, &mut rng);
        // Concatenate columns: [Xa | Xb] — truly independent blocks.
        let x = Mat::from_fn(800, 20, |i, j| {
            if j < 10 {
                a.x.get(i, j)
            } else {
                b.x.get(i, j - 10)
            }
        });
        let cfg = ConcordConfig {
            lambda1: 0.25,
            lambda2: 0.1,
            tol: 1e-6,
            variant: Variant::Cov,
            ..Default::default()
        };
        let screened = fit_with_screening(&x, &cfg).unwrap();
        assert!(screened.components >= 2, "components {}", screened.components);
        let plain = fit_single_node(&x, &cfg).unwrap();
        let diff = screened.fit.omega.max_abs_diff(&plain.omega);
        // Same estimator up to the cross-block entries the full solve
        // keeps at (near) zero.
        assert!(diff < 5e-2, "diff {diff}");
        // Within-block entries match tightly.
        for i in 0..10 {
            for j in 0..10 {
                assert!(
                    (screened.fit.omega.get(i, j) - plain.omega.get(i, j)).abs() < 2e-2,
                    "block entry ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn huge_lambda_gives_all_singletons_closed_form() {
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(12, 100, &mut rng);
        let cfg = ConcordConfig { lambda1: 100.0, lambda2: 0.5, ..Default::default() };
        let out = fit_with_screening(&prob.x, &cfg).unwrap();
        assert_eq!(out.components, 12);
        assert_eq!(out.largest, 1);
        assert!(out.per_component.is_empty(), "singletons carry no solver stats");
        assert_eq!(out.fit.iterations, 0);
        let s = native::gram(&prob.x);
        for i in 0..12 {
            let want = 1.0 / (s.get(i, i) + 0.5).sqrt();
            assert!((out.fit.omega.get(i, i) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn components_respect_threshold() {
        let mut s = Mat::eye(4);
        s.set(0, 1, 0.5);
        s.set(1, 0, 0.5);
        s.set(2, 3, 0.05);
        s.set(3, 2, 0.05);
        let comp = covariance_components(&s, 0.1);
        assert_eq!(comp[0], comp[1]);
        assert_ne!(comp[2], comp[3]);
        let comp = covariance_components(&s, 0.01);
        assert_eq!(comp[2], comp[3]);
    }

    #[test]
    fn screened_solve_is_faster_path_on_blocky_problem() {
        // Sanity: the screened path produces a block-diagonal estimate
        // with no cross-component entries at all.
        let mut rng = Rng::new(3);
        let a = gen::chain_problem(8, 400, &mut rng);
        let b = gen::chain_problem(8, 400, &mut rng);
        let x = Mat::from_fn(400, 16, |i, j| {
            if j < 8 {
                a.x.get(i, j)
            } else {
                b.x.get(i, j - 8)
            }
        });
        let cfg = ConcordConfig { lambda1: 0.3, tol: 1e-5, ..Default::default() };
        let out = fit_with_screening(&x, &cfg).unwrap();
        if out.components >= 2 {
            for i in 0..8 {
                for j in 8..16 {
                    assert_eq!(out.fit.omega.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn union_find_roots_are_minimum_members() {
        let mut uf = UnionFind::new(6);
        uf.union(4, 5);
        uf.union(1, 4);
        uf.union(2, 3);
        assert_eq!(uf.find(5), 1);
        assert_eq!(uf.find(3), 2);
        assert_eq!(uf.find(0), 0);
        let comps = uf.into_components();
        assert_eq!(comps.count, 3);
        assert_eq!(comps.comp, vec![0, 1, 2, 2, 1, 1]);
        assert_eq!(comps.members(1), vec![1, 4, 5]);
        assert_eq!(comps.sizes(), vec![1, 3, 2]);
        assert_eq!(comps.largest(), 3);
    }

    #[test]
    fn nested_refinement_matches_direct_on_fixture() {
        let mut s = Mat::eye(5);
        for (i, j, v) in [(0usize, 1usize, 0.9), (1, 2, 0.4), (3, 4, 0.2)] {
            s.set(i, j, v);
            s.set(j, i, v);
        }
        let thresholds = [0.5, 0.1, 0.3];
        let nested = nested_components(&s, &thresholds);
        for (k, &thr) in thresholds.iter().enumerate() {
            assert_eq!(nested[k], gram_components(&s, thr), "threshold {thr}");
        }
        // Coarsest level (0.1): {0,1,2} and {3,4}; finest (0.5): only 0–1.
        assert_eq!(nested[1].count, 2);
        assert_eq!(nested[0].count, 4);
    }
}
