//! Covariance screening and block decomposition — the paper's
//! "divide-and-conquer strategy based on a block structure assumption"
//! future-work item (§6), via the exact thresholding rule of Mazumder &
//! Hastie [35] (cited by the paper's §5 baseline).
//!
//! For the ℓ₁-penalized criterion, variables i and j can only be
//! connected in the estimate if they are connected in the graph
//! `{|S_ij| > λ₁}`. Decomposing that graph into connected components
//! splits one p×p problem into independent sub-problems — and the fMRI
//! estimates' hemisphere-block-diagonal structure (§S.3.3) is exactly
//! this phenomenon surfacing in data.
//!
//! `fit_with_screening` runs the decomposition and solves each component
//! with the single-node solver; singleton components have the diagonal
//! closed form ω_ii = argmin −log ω + (s_ii/2 + λ₂/2) ω² =
//! 1/√(s_ii + λ₂).

use anyhow::Result;

use crate::linalg::Mat;
use crate::runtime::native;

use super::{fit_single_node, ConcordConfig, ConcordFit};

/// Connected components of the thresholded covariance graph
/// `{(i, j) : |S_ij| > threshold, i ≠ j}`. Returns a component id per
/// variable.
pub fn covariance_components(s: &Mat, threshold: f64) -> Vec<usize> {
    let p = s.rows();
    let mut comp = vec![usize::MAX; p];
    let mut next = 0;
    let mut stack = Vec::new();
    for start in 0..p {
        if comp[start] != usize::MAX {
            continue;
        }
        comp[start] = next;
        stack.push(start);
        while let Some(v) = stack.pop() {
            for u in 0..p {
                if u != v && comp[u] == usize::MAX && s.get(v, u).abs() > threshold {
                    comp[u] = next;
                    stack.push(u);
                }
            }
        }
        next += 1;
    }
    comp
}

/// Outcome of a screened fit.
#[derive(Debug)]
pub struct ScreenedFit {
    pub fit: ConcordFit,
    /// Number of connected components the problem split into.
    pub components: usize,
    /// Size of the largest component (the remaining hard work).
    pub largest: usize,
}

/// Fit with covariance screening: decompose at `λ₁`, solve each
/// component independently, and reassemble the block-diagonal estimate.
pub fn fit_with_screening(x: &Mat, cfg: &ConcordConfig) -> Result<ScreenedFit> {
    let p = x.cols();
    let s = native::gram(x);
    let comp = covariance_components(&s, cfg.lambda1);
    let n_comp = comp.iter().copied().max().map_or(0, |m| m + 1);

    let mut omega = Mat::zeros(p, p);
    let mut iterations = 0usize;
    let mut trials = 0.0;
    let mut objective = 0.0;
    let mut converged = true;
    let mut largest = 0usize;

    for c in 0..n_comp {
        let idx: Vec<usize> = (0..p).filter(|&i| comp[i] == c).collect();
        largest = largest.max(idx.len());
        if idx.len() == 1 {
            // Singleton closed form: ω = 1/√(s_ii + λ₂).
            let i = idx[0];
            let w = 1.0 / (s.get(i, i) + cfg.lambda2).sqrt();
            omega.set(i, i, w);
            objective += -w.ln() + 0.5 * s.get(i, i) * w * w + 0.5 * cfg.lambda2 * w * w;
            continue;
        }
        // Solve the sub-problem on the component's columns.
        let sub_x = Mat::from_fn(x.rows(), idx.len(), |r, k| x.get(r, idx[k]));
        let sub = fit_single_node(&sub_x, cfg)?;
        iterations = iterations.max(sub.iterations);
        trials += sub.mean_linesearch * sub.iterations as f64;
        objective += sub.objective;
        converged &= sub.converged;
        for (a, &i) in idx.iter().enumerate() {
            for (b, &j) in idx.iter().enumerate() {
                omega.set(i, j, sub.omega.get(a, b));
            }
        }
    }

    let nnz = omega.nnz();
    Ok(ScreenedFit {
        fit: ConcordFit {
            omega,
            iterations,
            mean_linesearch: if iterations > 0 { trials / iterations as f64 } else { 0.0 },
            mean_row_nnz: nnz as f64 / p as f64,
            objective,
            converged,
        },
        components: n_comp,
        largest,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::rng::Rng;

    /// Two independent chain blocks: screening must find ≥2 components
    /// and the screened fit must match the un-screened fit.
    #[test]
    fn screening_decomposes_independent_blocks() {
        let mut rng = Rng::new(1);
        let a = gen::chain_problem(10, 800, &mut rng);
        let b = gen::chain_problem(10, 800, &mut rng);
        // Concatenate columns: [Xa | Xb] — truly independent blocks.
        let x = Mat::from_fn(800, 20, |i, j| {
            if j < 10 {
                a.x.get(i, j)
            } else {
                b.x.get(i, j - 10)
            }
        });
        let cfg = ConcordConfig {
            lambda1: 0.25,
            lambda2: 0.1,
            tol: 1e-6,
            variant: Variant::Cov,
            ..Default::default()
        };
        let screened = fit_with_screening(&x, &cfg).unwrap();
        assert!(screened.components >= 2, "components {}", screened.components);
        let plain = fit_single_node(&x, &cfg).unwrap();
        let diff = screened.fit.omega.max_abs_diff(&plain.omega);
        // Same estimator up to the cross-block entries the full solve
        // keeps at (near) zero.
        assert!(diff < 5e-2, "diff {diff}");
        // Within-block entries match tightly.
        for i in 0..10 {
            for j in 0..10 {
                assert!(
                    (screened.fit.omega.get(i, j) - plain.omega.get(i, j)).abs() < 2e-2,
                    "block entry ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn huge_lambda_gives_all_singletons_closed_form() {
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(12, 100, &mut rng);
        let cfg = ConcordConfig { lambda1: 100.0, lambda2: 0.5, ..Default::default() };
        let out = fit_with_screening(&prob.x, &cfg).unwrap();
        assert_eq!(out.components, 12);
        assert_eq!(out.largest, 1);
        let s = native::gram(&prob.x);
        for i in 0..12 {
            let want = 1.0 / (s.get(i, i) + 0.5).sqrt();
            assert!((out.fit.omega.get(i, i) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn components_respect_threshold() {
        let mut s = Mat::eye(4);
        s.set(0, 1, 0.5);
        s.set(1, 0, 0.5);
        s.set(2, 3, 0.05);
        s.set(3, 2, 0.05);
        let comp = covariance_components(&s, 0.1);
        assert_eq!(comp[0], comp[1]);
        assert_ne!(comp[2], comp[3]);
        let comp = covariance_components(&s, 0.01);
        assert_eq!(comp[2], comp[3]);
    }

    #[test]
    fn screened_solve_is_faster_path_on_blocky_problem() {
        // Sanity: the screened path produces a block-diagonal estimate
        // with no cross-component entries at all.
        let mut rng = Rng::new(3);
        let a = gen::chain_problem(8, 400, &mut rng);
        let b = gen::chain_problem(8, 400, &mut rng);
        let x = Mat::from_fn(400, 16, |i, j| {
            if j < 8 {
                a.x.get(i, j)
            } else {
                b.x.get(i, j - 8)
            }
        });
        let cfg = ConcordConfig { lambda1: 0.3, tol: 1e-5, ..Default::default() };
        let out = fit_with_screening(&x, &cfg).unwrap();
        if out.components >= 2 {
            for i in 0..8 {
                for j in 8..16 {
                    assert_eq!(out.fit.omega.get(i, j), 0.0);
                }
            }
        }
    }
}
