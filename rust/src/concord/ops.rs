//! Block-level CONCORD math, shared by the single-node and distributed
//! drivers. Every function operates on a horizontal slab of rows
//! `row_offset .. row_offset + block.rows()` of the global p×p iterate,
//! so the same code serves the full matrix (offset 0) and any 1D
//! block-row partition. These are the Rust twins of the L1 Pallas
//! kernels in `python/compile/kernels/concord.py`; the python test-suite
//! pins both against the same `ref.py` oracle semantics.

use crate::linalg::Mat;
use crate::util::pool::{chunk_ranges, par_map, par_rows_mut};

/// Fixed reduction granularity for the multithreaded scalar passes.
///
/// The `_mt` reductions accumulate serially *within* 64-row blocks and
/// then fold the block partials in ascending block order — a grouping
/// that depends only on the slab shape, never on the thread count. That
/// makes every `_mt` scalar result (objective, line-search pieces)
/// **identical at any thread count**, which is what lets the solvers'
/// line-search decisions — and therefore whole fits — be byte-for-byte
/// reproducible as `threads` varies (see the determinism suite in
/// `rust/tests/parallel_determinism.rs`).
///
/// This is the scalar-reduction half of the kernel layer's determinism
/// contract; the matrix half (per-element ascending-k accumulation in
/// the blocked GEMM/SpMM, invariant to [`crate::linalg::tile`] shapes)
/// is stated in `ARCHITECTURE.md` alongside it.
pub const REDUCE_BLOCK_ROWS: usize = 64;

/// Per-block partials for a `rows`×`row_width` slab, computed on up to
/// `threads` workers, returned in ascending block order. Slabs below
/// the spawn cutoff run on the caller thread — with the identical
/// block-ordered fold, so the value never depends on the path taken.
fn block_partials<T: Send>(
    rows: usize,
    row_width: usize,
    threads: usize,
    per_block: impl Fn(usize, usize) -> T + Sync,
) -> Vec<T> {
    let nblocks = rows.div_ceil(REDUCE_BLOCK_ROWS).max(1);
    let t = if rows * row_width < crate::util::pool::SPAWN_MIN_WORK {
        1
    } else {
        threads.max(1)
    };
    let ranges = chunk_ranges(nblocks, t, 1);
    let nested: Vec<Vec<T>> = par_map(&ranges, |_i, bs, be| {
        (bs..be)
            .map(|blk| {
                let s = blk * REDUCE_BLOCK_ROWS;
                let e = (s + REDUCE_BLOCK_ROWS).min(rows);
                per_block(s, e.max(s))
            })
            .collect()
    });
    nested.into_iter().flatten().collect()
}

/// Gradient slab (Algorithm 2 line 6):
/// G = −(Ω_D)⁻¹ + (W + Wᵀ)/2 + λ₂Ω, restricted to a row slab. `w` and
/// `wt` are the matching slabs of W and Wᵀ. Serial form of
/// [`gradient_block_mt`] (same kernel, one worker).
pub fn gradient_block(omega: &Mat, w: &Mat, wt: &Mat, row_offset: usize, lam2: f64) -> Mat {
    gradient_block_mt(omega, w, wt, row_offset, lam2, 1)
}

/// Proximal step slab (Algorithm 2 line 9): soft-threshold Ω − τG at
/// τλ₁ off the diagonal; the diagonal passes through un-thresholded
/// (the ℓ₁ penalty is on Ω_X only). Serial form of [`prox_block_mt`].
pub fn prox_block(omega: &Mat, g: &Mat, row_offset: usize, tau: f64, lam1: f64) -> Mat {
    prox_block_mt(omega, g, row_offset, tau, lam1, 1)
}

/// In-place fused prox (hot-path variant: no allocation). Writes into
/// `out`, which must be pre-sized. Serial form of
/// [`prox_block_into_mt`].
pub fn prox_block_into(
    omega: &Mat,
    g: &Mat,
    row_offset: usize,
    tau: f64,
    lam1: f64,
    out: &mut Mat,
) {
    prox_block_into_mt(omega, g, row_offset, tau, lam1, out, 1)
}

/// [`gradient_block`] on `threads` node-local workers. Rows are
/// independent, so the result is bit-identical at any thread count.
pub fn gradient_block_mt(
    omega: &Mat,
    w: &Mat,
    wt: &Mat,
    row_offset: usize,
    lam2: f64,
    threads: usize,
) -> Mat {
    let (rows, p) = omega.shape();
    debug_assert_eq!(w.shape(), (rows, p));
    debug_assert_eq!(wt.shape(), (rows, p));
    let mut g = Mat::zeros(rows, p);
    let body = |s: usize, e: usize, grows: &mut [f64]| {
        for i in s..e {
            let orow = omega.row(i);
            let wrow = w.row(i);
            let wtrow = wt.row(i);
            let grow = &mut grows[(i - s) * p..(i - s + 1) * p];
            for j in 0..p {
                grow[j] = 0.5 * (wrow[j] + wtrow[j]) + lam2 * orow[j];
            }
            let dcol = row_offset + i;
            if dcol < p {
                grow[dcol] -= 1.0 / orow[dcol];
            }
        }
    };
    if threads <= 1 || rows < 2 || rows * p < crate::util::pool::SPAWN_MIN_WORK {
        body(0, rows, g.data_mut());
        return g;
    }
    let ranges = chunk_ranges(rows, threads, 1);
    par_rows_mut(g.data_mut(), p, &ranges, |_i, s, e, grows| body(s, e, grows));
    g
}

/// [`prox_block`] on `threads` node-local workers (bit-identical).
pub fn prox_block_mt(
    omega: &Mat,
    g: &Mat,
    row_offset: usize,
    tau: f64,
    lam1: f64,
    threads: usize,
) -> Mat {
    let (rows, p) = omega.shape();
    let mut out = Mat::zeros(rows, p);
    prox_block_into_mt(omega, g, row_offset, tau, lam1, &mut out, threads);
    out
}

/// [`prox_block_into`] on `threads` node-local workers (bit-identical).
#[allow(clippy::too_many_arguments)]
pub fn prox_block_into_mt(
    omega: &Mat,
    g: &Mat,
    row_offset: usize,
    tau: f64,
    lam1: f64,
    out: &mut Mat,
    threads: usize,
) {
    let (rows, p) = omega.shape();
    debug_assert_eq!(g.shape(), (rows, p));
    debug_assert_eq!(out.shape(), (rows, p));
    let thresh = tau * lam1;
    let body = |s: usize, e: usize, orows: &mut [f64]| {
        for i in s..e {
            let orow = omega.row(i);
            let grow = g.row(i);
            let dst = &mut orows[(i - s) * p..(i - s + 1) * p];
            for j in 0..p {
                dst[j] = soft(orow[j] - tau * grow[j], thresh);
            }
            let dcol = row_offset + i;
            if dcol < p {
                dst[dcol] = orow[dcol] - tau * grow[dcol];
            }
        }
    };
    if threads <= 1 || rows < 2 || rows * p < crate::util::pool::SPAWN_MIN_WORK {
        body(0, rows, out.data_mut());
        return;
    }
    let ranges = chunk_ranges(rows, threads, 1);
    par_rows_mut(out.data_mut(), p, &ranges, |_i, s, e, orows| body(s, e, orows));
}

/// Fused gradient+prox slab (Algorithm 2 lines 6 and 9 in one pass):
/// the gradient of each row lands in a p-word scratch buffer that is
/// still L1-hot when the prox loop reads it back, eliminating the
/// slab-sized G round trip through memory that the composed pair pays.
/// Serial form of [`fused_gradient_prox_block_mt`].
///
/// Per-element operations are the composed pair's **verbatim** — the
/// gradient loop of [`gradient_block`], then the prox loop of
/// [`prox_block`], per row — so the result is bit-identical to
/// `prox_block(omega, &gradient_block(omega, w, wt, row_offset, lam2),
/// row_offset, tau, lam1)`. The C mirror measures the win
/// (`fused_concord_pass` vs `concord_gradient_prox_composed` in
/// `BENCH_simd_baseline.json`). The solver loop keeps the composed
/// pair, because it reuses one G across every line-search trial; this
/// pass serves callers that need exactly one (gradient, prox)
/// evaluation.
#[allow(clippy::too_many_arguments)]
pub fn fused_gradient_prox_block(
    omega: &Mat,
    w: &Mat,
    wt: &Mat,
    row_offset: usize,
    tau: f64,
    lam1: f64,
    lam2: f64,
) -> Mat {
    fused_gradient_prox_block_mt(omega, w, wt, row_offset, tau, lam1, lam2, 1)
}

/// [`fused_gradient_prox_block`] on `threads` node-local workers. Rows
/// are independent and each worker owns its scratch buffer, so the
/// result is bit-identical at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn fused_gradient_prox_block_mt(
    omega: &Mat,
    w: &Mat,
    wt: &Mat,
    row_offset: usize,
    tau: f64,
    lam1: f64,
    lam2: f64,
    threads: usize,
) -> Mat {
    let (rows, p) = omega.shape();
    debug_assert_eq!(w.shape(), (rows, p));
    debug_assert_eq!(wt.shape(), (rows, p));
    let thresh = tau * lam1;
    let mut out = Mat::zeros(rows, p);
    let body = |s: usize, e: usize, orows: &mut [f64]| {
        let mut gbuf = vec![0.0f64; p];
        for i in s..e {
            let orow = omega.row(i);
            let wrow = w.row(i);
            let wtrow = wt.row(i);
            let dcol = row_offset + i;
            // Gradient loop of gradient_block, into the hot buffer.
            for j in 0..p {
                gbuf[j] = 0.5 * (wrow[j] + wtrow[j]) + lam2 * orow[j];
            }
            if dcol < p {
                gbuf[dcol] -= 1.0 / orow[dcol];
            }
            // Prox loop of prox_block_into, from the hot buffer.
            let dst = &mut orows[(i - s) * p..(i - s + 1) * p];
            for j in 0..p {
                dst[j] = soft(orow[j] - tau * gbuf[j], thresh);
            }
            if dcol < p {
                dst[dcol] = orow[dcol] - tau * gbuf[dcol];
            }
        }
    };
    if threads <= 1 || rows < 2 || rows * p < crate::util::pool::SPAWN_MIN_WORK {
        body(0, rows, out.data_mut());
        return out;
    }
    let ranges = chunk_ranges(rows, threads, 1);
    par_rows_mut(out.data_mut(), p, &ranges, |_i, s, e, orows| body(s, e, orows));
    out
}

/// [`objective_parts_block`] over a sub-range of slab rows (absolute
/// diagonal offsets still come from `row_offset + i`).
fn objective_parts_range(
    omega: &Mat,
    w: &Mat,
    row_offset: usize,
    r0: usize,
    r1: usize,
) -> Option<[f64; 3]> {
    let p = omega.cols();
    let mut logd = 0.0;
    let mut tr = 0.0;
    let mut fro = 0.0;
    for i in r0..r1 {
        let orow = omega.row(i);
        let wrow = w.row(i);
        for j in 0..p {
            tr += wrow[j] * orow[j];
            fro += orow[j] * orow[j];
        }
        let dcol = row_offset + i;
        if dcol < p {
            let d = orow[dcol];
            if d <= 0.0 {
                return None;
            }
            logd += d.ln();
        }
    }
    Some([logd, tr, fro])
}

/// [`objective_parts_block`] on `threads` workers, with the fixed
/// [`REDUCE_BLOCK_ROWS`] reduction order: the returned value is a
/// function of the slab only — identical at every thread count.
pub fn objective_parts_block_mt(
    omega: &Mat,
    w: &Mat,
    row_offset: usize,
    threads: usize,
) -> Option<[f64; 3]> {
    let (rows, p) = omega.shape();
    debug_assert_eq!(w.shape(), (rows, p));
    let partials = block_partials(rows, p, threads, |s, e| {
        objective_parts_range(omega, w, row_offset, s, e)
    });
    let mut acc = [0.0f64; 3];
    for part in partials {
        let part = part?;
        for k in 0..3 {
            acc[k] += part[k];
        }
    }
    Some(acc)
}

/// [`diag_fro_parts_block`] over a sub-range of slab rows.
fn diag_fro_parts_range(
    omega: &Mat,
    row_offset: usize,
    r0: usize,
    r1: usize,
) -> Option<[f64; 2]> {
    let p = omega.cols();
    let mut logd = 0.0;
    let mut fro = 0.0;
    for i in r0..r1 {
        let orow = omega.row(i);
        for &v in orow {
            fro += v * v;
        }
        let dcol = row_offset + i;
        if dcol < p {
            let d = orow[dcol];
            if d <= 0.0 {
                return None;
            }
            logd += d.ln();
        }
    }
    Some([logd, fro])
}

/// [`diag_fro_parts_block`] on `threads` workers (fixed-block order,
/// thread-count invariant).
pub fn diag_fro_parts_block_mt(
    omega: &Mat,
    row_offset: usize,
    threads: usize,
) -> Option<[f64; 2]> {
    let rows = omega.rows();
    let partials = block_partials(rows, omega.cols(), threads, |r0, r1| {
        diag_fro_parts_range(omega, row_offset, r0, r1)
    });
    let mut acc = [0.0f64; 2];
    for part in partials {
        let part = part?;
        acc[0] += part[0];
        acc[1] += part[1];
    }
    Some(acc)
}

/// [`linesearch_parts_block`] over a sub-range of slab rows.
fn linesearch_parts_range(omega: &Mat, omega_new: &Mat, g: &Mat, r0: usize, r1: usize) -> [f64; 2] {
    let p = omega.cols();
    let mut dot = 0.0;
    let mut fro = 0.0;
    for i in r0..r1 {
        let o = omega.row(i);
        let on = omega_new.row(i);
        let gr = g.row(i);
        for j in 0..p {
            let diff = o[j] - on[j];
            dot += diff * gr[j];
            fro += diff * diff;
        }
    }
    [dot, fro]
}

/// [`linesearch_parts_block`] on `threads` workers (fixed-block order,
/// thread-count invariant).
pub fn linesearch_parts_block_mt(
    omega: &Mat,
    omega_new: &Mat,
    g: &Mat,
    threads: usize,
) -> [f64; 2] {
    let (rows, p) = omega.shape();
    debug_assert_eq!(omega_new.shape(), (rows, p));
    debug_assert_eq!(g.shape(), (rows, p));
    let partials = block_partials(rows, p, threads, |r0, r1| {
        linesearch_parts_range(omega, omega_new, g, r0, r1)
    });
    let mut acc = [0.0f64; 2];
    for part in partials {
        acc[0] += part[0];
        acc[1] += part[1];
    }
    acc
}

#[inline]
fn soft(z: f64, a: f64) -> f64 {
    if z > a {
        z - a
    } else if z < -a {
        z + a
    } else {
        0.0
    }
}

/// Objective pieces over a row slab: (Σ log Ω_ii, Σ W∘Ω, ‖Ω‖_F²) for the
/// diagonal entries/elements inside the slab. Returns `None` when any
/// in-slab diagonal entry is non-positive (objective undefined; the line
/// search treats this as an automatic reject).
///
/// The caller combines the global sums into the smooth objective
/// g(Ω) = −Σlog + tr/2 + (λ₂/2)·fro (Cov), or swaps the trace term for
/// ‖Y‖²_F/n (Obs). This is the function whose exact gradient is
/// Algorithm 2's G (the paper's line 7 prints a doubled log/trace form
/// inconsistent with its own gradient line; see ref.py and DESIGN.md —
/// the change only rescales the λ grid).
pub fn objective_parts_block(omega: &Mat, w: &Mat, row_offset: usize) -> Option<[f64; 3]> {
    let (rows, p) = omega.shape();
    debug_assert_eq!(w.shape(), (rows, p));
    objective_parts_range(omega, w, row_offset, 0, rows)
}

/// Diagonal-and-Frobenius pieces only (Obs objective, where the trace
/// term comes from ‖Y‖²_F instead of W∘Ω).
pub fn diag_fro_parts_block(omega: &Mat, row_offset: usize) -> Option<[f64; 2]> {
    diag_fro_parts_range(omega, row_offset, 0, omega.rows())
}

/// Line-search pieces over a slab: (tr((Ω−Ω′)ᵀG), ‖Ω−Ω′‖_F²).
pub fn linesearch_parts_block(omega: &Mat, omega_new: &Mat, g: &Mat) -> [f64; 2] {
    let (rows, p) = omega.shape();
    debug_assert_eq!(omega_new.shape(), (rows, p));
    debug_assert_eq!(g.shape(), (rows, p));
    linesearch_parts_range(omega, omega_new, g, 0, rows)
}

/// Sufficient-decrease check (Algorithm 2 line 12):
/// accept iff g(Ω′) ≤ g(Ω) − tr((Ω−Ω′)ᵀG) + ‖Ω−Ω′‖²/(2τ).
pub fn accepts(g_new: f64, g_prev: f64, ls_parts: [f64; 2], tau: f64) -> bool {
    g_new <= g_prev - ls_parts[0] + ls_parts[1] / (2.0 * tau)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn symmetric_posdiag(rng: &mut Rng, p: usize) -> Mat {
        let mut m = Mat::from_fn(p, p, |_, _| 0.1 * rng.normal());
        m.symmetrize();
        for i in 0..p {
            m.set(i, i, 1.0 + rng.uniform());
        }
        m
    }

    /// Full-matrix references (the ref.py formulas, transliterated).
    fn ref_gradient(omega: &Mat, w: &Mat, lam2: f64) -> Mat {
        let p = omega.rows();
        let wt = w.transpose();
        Mat::from_fn(p, p, |i, j| {
            let mut v = 0.5 * (w.get(i, j) + wt.get(i, j)) + lam2 * omega.get(i, j);
            if i == j {
                v -= 1.0 / omega.get(i, i);
            }
            v
        })
    }

    fn ref_prox(omega: &Mat, g: &Mat, tau: f64, lam1: f64) -> Mat {
        let p = omega.rows();
        Mat::from_fn(p, p, |i, j| {
            let z = omega.get(i, j) - tau * g.get(i, j);
            if i == j {
                z
            } else {
                soft(z, tau * lam1)
            }
        })
    }

    #[test]
    fn gradient_block_matches_full() {
        let mut rng = Rng::new(1);
        let p = 12;
        let omega = symmetric_posdiag(&mut rng, p);
        let w = Mat::from_fn(p, p, |_, _| rng.normal());
        let full = ref_gradient(&omega, &w, 0.4);
        // Split into two slabs and compare.
        let wt = w.transpose();
        for (r0, r1) in [(0, 5), (5, 12)] {
            let blk = gradient_block(
                &omega.row_block(r0, r1),
                &w.row_block(r0, r1),
                &wt.row_block(r0, r1),
                r0,
                0.4,
            );
            assert!(blk.max_abs_diff(&full.row_block(r0, r1)) < 1e-14);
        }
    }

    #[test]
    fn prox_block_matches_full_and_into_variant() {
        let mut rng = Rng::new(2);
        let p = 10;
        let omega = symmetric_posdiag(&mut rng, p);
        let g = Mat::from_fn(p, p, |_, _| rng.normal());
        let full = ref_prox(&omega, &g, 0.5, 0.7);
        for (r0, r1) in [(0, 3), (3, 10)] {
            let ob = omega.row_block(r0, r1);
            let gb = g.row_block(r0, r1);
            let blk = prox_block(&ob, &gb, r0, 0.5, 0.7);
            assert!(blk.max_abs_diff(&full.row_block(r0, r1)) < 1e-14);
            let mut out = Mat::zeros(r1 - r0, p);
            prox_block_into(&ob, &gb, r0, 0.5, 0.7, &mut out);
            assert!(out.max_abs_diff(&blk) == 0.0);
        }
    }

    #[test]
    fn mt_matrix_passes_bitwise_match_serial() {
        let mut rng = Rng::new(0xC1);
        // rows·p above pool::SPAWN_MIN_WORK so the parallel path really
        // fans out (smaller slabs legitimately stay serial).
        let rows = 300;
        let p = 300;
        let omega = {
            let mut m = Mat::from_fn(rows, p, |_, _| 0.1 * rng.normal());
            for i in 0..rows {
                m.set(i, (3 + i).min(p - 1), 1.5 + rng.uniform());
            }
            m
        };
        let w = Mat::from_fn(rows, p, |_, _| rng.normal());
        let wt = Mat::from_fn(rows, p, |_, _| rng.normal());
        let g_serial = gradient_block(&omega, &w, &wt, 3, 0.2);
        let prox_serial = prox_block(&omega, &g_serial, 3, 0.5, 0.3);
        for threads in 1..=8 {
            let g = gradient_block_mt(&omega, &w, &wt, 3, 0.2, threads);
            assert!(g.max_abs_diff(&g_serial) == 0.0, "gradient t={threads}");
            let px = prox_block_mt(&omega, &g, 3, 0.5, 0.3, threads);
            assert!(px.max_abs_diff(&prox_serial) == 0.0, "prox t={threads}");
            let mut out = Mat::zeros(rows, p);
            prox_block_into_mt(&omega, &g, 3, 0.5, 0.3, &mut out, threads);
            assert!(out.max_abs_diff(&prox_serial) == 0.0, "prox-into t={threads}");
        }
    }

    #[test]
    fn fused_pass_is_bitwise_the_composed_pair() {
        let mut rng = Rng::new(0xF5);
        // Above the spawn cutoff so the _mt path really fans out, with
        // a row_offset so the diagonal fixup lands mid-slab.
        let rows = 300;
        let p = 310;
        let omega = {
            let mut m = Mat::from_fn(rows, p, |_, _| 0.1 * rng.normal());
            for i in 0..rows {
                m.set(i, (7 + i).min(p - 1), 1.5 + rng.uniform());
            }
            m
        };
        let w = Mat::from_fn(rows, p, |_, _| rng.normal());
        let wt = Mat::from_fn(rows, p, |_, _| rng.normal());
        let (off, tau, lam1, lam2) = (7, 0.5, 0.3, 0.2);
        let composed = prox_block(
            &omega,
            &gradient_block(&omega, &w, &wt, off, lam2),
            off,
            tau,
            lam1,
        );
        let fused = fused_gradient_prox_block(&omega, &w, &wt, off, tau, lam1, lam2);
        assert!(fused.max_abs_diff(&composed) == 0.0, "serial fused != composed");
        for threads in 2..=8 {
            let mt = fused_gradient_prox_block_mt(&omega, &w, &wt, off, tau, lam1, lam2, threads);
            assert!(mt.max_abs_diff(&composed) == 0.0, "fused t={threads}");
        }
    }

    #[test]
    fn mt_scalar_passes_invariant_in_thread_count() {
        let mut rng = Rng::new(0xC2);
        // Spans several reduction blocks AND exceeds the spawn cutoff
        // (rows·p ≥ pool::SPAWN_MIN_WORK) so the fold genuinely runs on
        // multiple workers.
        let rows = 6 * REDUCE_BLOCK_ROWS + 17;
        let p = rows;
        let omega = symmetric_posdiag(&mut rng, p).row_block(0, rows);
        let w = Mat::from_fn(rows, p, |_, _| rng.normal());
        let omega_new = prox_block(&omega, &w, 0, 0.1, 0.2);
        let obj1 = objective_parts_block_mt(&omega, &w, 0, 1).unwrap();
        let df1 = diag_fro_parts_block_mt(&omega, 0, 1).unwrap();
        let ls1 = linesearch_parts_block_mt(&omega, &omega_new, &w, 1);
        for threads in 2..=8 {
            let obj = objective_parts_block_mt(&omega, &w, 0, threads).unwrap();
            let df = diag_fro_parts_block_mt(&omega, 0, threads).unwrap();
            let ls = linesearch_parts_block_mt(&omega, &omega_new, &w, threads);
            for k in 0..3 {
                assert_eq!(obj[k].to_bits(), obj1[k].to_bits(), "objective[{k}] t={threads}");
            }
            for k in 0..2 {
                assert_eq!(df[k].to_bits(), df1[k].to_bits(), "diag_fro[{k}] t={threads}");
                assert_eq!(ls[k].to_bits(), ls1[k].to_bits(), "linesearch[{k}] t={threads}");
            }
        }
        // And the blocked values agree with the serial reference to fp
        // accuracy (the grouping differs, the math does not).
        let serial = objective_parts_block(&omega, &w, 0).unwrap();
        for k in 0..3 {
            let scale = serial[k].abs().max(1.0);
            assert!((obj1[k] - serial[k]).abs() / scale < 1e-12, "part {k}");
        }
    }

    #[test]
    fn mt_objective_poisons_on_bad_diagonal_everywhere() {
        let mut omega = Mat::eye(REDUCE_BLOCK_ROWS + 5);
        omega.set(REDUCE_BLOCK_ROWS + 2, REDUCE_BLOCK_ROWS + 2, -1.0);
        let w = Mat::zeros(REDUCE_BLOCK_ROWS + 5, REDUCE_BLOCK_ROWS + 5);
        for threads in 1..=4 {
            assert!(objective_parts_block_mt(&omega, &w, 0, threads).is_none());
            assert!(diag_fro_parts_block_mt(&omega, 0, threads).is_none());
        }
    }

    #[test]
    fn prox_diagonal_untouched_by_threshold() {
        let p = 5;
        let omega = Mat::eye(p);
        let g = Mat::zeros(p, p);
        let out = prox_block(&omega, &g, 0, 1.0, 100.0);
        assert!(out.max_abs_diff(&Mat::eye(p)) == 0.0);
    }

    #[test]
    fn objective_parts_sum_over_slabs() {
        let mut rng = Rng::new(3);
        let p = 9;
        let omega = symmetric_posdiag(&mut rng, p);
        let w = Mat::from_fn(p, p, |_, _| rng.normal());
        let full = objective_parts_block(&omega, &w, 0).unwrap();
        let a = objective_parts_block(&omega.row_block(0, 4), &w.row_block(0, 4), 0).unwrap();
        let b = objective_parts_block(&omega.row_block(4, 9), &w.row_block(4, 9), 4).unwrap();
        for k in 0..3 {
            assert!((full[k] - (a[k] + b[k])).abs() < 1e-11, "part {k}");
        }
    }

    #[test]
    fn objective_rejects_nonpositive_diagonal() {
        let mut omega = Mat::eye(3);
        omega.set(1, 1, -0.5);
        assert!(objective_parts_block(&omega, &Mat::zeros(3, 3), 0).is_none());
        assert!(diag_fro_parts_block(&omega, 0).is_none());
        // But a slab that excludes the bad diagonal entry is fine.
        assert!(objective_parts_block(&omega.row_block(0, 1), &Mat::zeros(1, 3), 0).is_some());
    }

    #[test]
    fn linesearch_parts_closed_form() {
        // Ω − Ω′ = E (all ones): dot = ΣG, fro = p².
        let p = 4;
        let omega = Mat::from_fn(p, p, |_, _| 2.0);
        let omega_new = Mat::from_fn(p, p, |_, _| 1.0);
        let g = Mat::from_fn(p, p, |i, j| (i + j) as f64);
        let [dot, fro] = linesearch_parts_block(&omega, &omega_new, &g);
        let gsum: f64 = (0..p).flat_map(|i| (0..p).map(move |j| (i + j) as f64)).sum();
        assert_eq!(dot, gsum);
        assert_eq!(fro, (p * p) as f64);
    }

    #[test]
    fn accepts_inequality() {
        assert!(accepts(1.0, 1.0, [0.0, 0.0], 1.0));
        assert!(!accepts(2.0, 1.0, [0.5, 0.5], 1.0)); // 2 > 1 - 0.5 + 0.25
        assert!(accepts(0.9, 1.0, [0.5, 1.0], 1.0)); // 0.9 <= 1 - 0.5 + 0.5
    }
}
