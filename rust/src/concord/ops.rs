//! Block-level CONCORD math, shared by the single-node and distributed
//! drivers. Every function operates on a horizontal slab of rows
//! `row_offset .. row_offset + block.rows()` of the global p×p iterate,
//! so the same code serves the full matrix (offset 0) and any 1D
//! block-row partition. These are the Rust twins of the L1 Pallas
//! kernels in `python/compile/kernels/concord.py`; the python test-suite
//! pins both against the same `ref.py` oracle semantics.

use crate::linalg::Mat;

/// Gradient slab (Algorithm 2 line 6):
/// G = −(Ω_D)⁻¹ + (W + Wᵀ)/2 + λ₂Ω, restricted to a row slab. `w` and
/// `wt` are the matching slabs of W and Wᵀ.
pub fn gradient_block(omega: &Mat, w: &Mat, wt: &Mat, row_offset: usize, lam2: f64) -> Mat {
    let (rows, p) = omega.shape();
    debug_assert_eq!(w.shape(), (rows, p));
    debug_assert_eq!(wt.shape(), (rows, p));
    let mut g = Mat::zeros(rows, p);
    for i in 0..rows {
        let orow = omega.row(i);
        let wrow = w.row(i);
        let wtrow = wt.row(i);
        let grow = g.row_mut(i);
        for j in 0..p {
            grow[j] = 0.5 * (wrow[j] + wtrow[j]) + lam2 * orow[j];
        }
        let dcol = row_offset + i;
        if dcol < p {
            grow[dcol] -= 1.0 / orow[dcol];
        }
    }
    g
}

/// Proximal step slab (Algorithm 2 line 9): soft-threshold Ω − τG at
/// τλ₁ off the diagonal; the diagonal passes through un-thresholded
/// (the ℓ₁ penalty is on Ω_X only).
pub fn prox_block(omega: &Mat, g: &Mat, row_offset: usize, tau: f64, lam1: f64) -> Mat {
    let (rows, p) = omega.shape();
    debug_assert_eq!(g.shape(), (rows, p));
    let thresh = tau * lam1;
    let mut out = Mat::zeros(rows, p);
    for i in 0..rows {
        let orow = omega.row(i);
        let grow = g.row(i);
        let dst = out.row_mut(i);
        for j in 0..p {
            let z = orow[j] - tau * grow[j];
            dst[j] = soft(z, thresh);
        }
        let dcol = row_offset + i;
        if dcol < p {
            dst[dcol] = orow[dcol] - tau * grow[dcol];
        }
    }
    out
}

/// In-place fused prox (hot-path variant: no allocation). Writes into
/// `out`, which must be pre-sized.
pub fn prox_block_into(
    omega: &Mat,
    g: &Mat,
    row_offset: usize,
    tau: f64,
    lam1: f64,
    out: &mut Mat,
) {
    let (rows, p) = omega.shape();
    debug_assert_eq!(out.shape(), (rows, p));
    let thresh = tau * lam1;
    for i in 0..rows {
        let orow = omega.row(i);
        let grow = g.row(i);
        let dst = out.row_mut(i);
        for j in 0..p {
            dst[j] = soft(orow[j] - tau * grow[j], thresh);
        }
        let dcol = row_offset + i;
        if dcol < p {
            dst[dcol] = orow[dcol] - tau * grow[dcol];
        }
    }
}

#[inline]
fn soft(z: f64, a: f64) -> f64 {
    if z > a {
        z - a
    } else if z < -a {
        z + a
    } else {
        0.0
    }
}

/// Objective pieces over a row slab: (Σ log Ω_ii, Σ W∘Ω, ‖Ω‖_F²) for the
/// diagonal entries/elements inside the slab. Returns `None` when any
/// in-slab diagonal entry is non-positive (objective undefined; the line
/// search treats this as an automatic reject).
///
/// The caller combines the global sums into the smooth objective
/// g(Ω) = −Σlog + tr/2 + (λ₂/2)·fro (Cov), or swaps the trace term for
/// ‖Y‖²_F/n (Obs). This is the function whose exact gradient is
/// Algorithm 2's G (the paper's line 7 prints a doubled log/trace form
/// inconsistent with its own gradient line; see ref.py and DESIGN.md —
/// the change only rescales the λ grid).
pub fn objective_parts_block(omega: &Mat, w: &Mat, row_offset: usize) -> Option<[f64; 3]> {
    let (rows, p) = omega.shape();
    debug_assert_eq!(w.shape(), (rows, p));
    let mut logd = 0.0;
    let mut tr = 0.0;
    let mut fro = 0.0;
    for i in 0..rows {
        let orow = omega.row(i);
        let wrow = w.row(i);
        for j in 0..p {
            tr += wrow[j] * orow[j];
            fro += orow[j] * orow[j];
        }
        let dcol = row_offset + i;
        if dcol < p {
            let d = orow[dcol];
            if d <= 0.0 {
                return None;
            }
            logd += d.ln();
        }
    }
    Some([logd, tr, fro])
}

/// Diagonal-and-Frobenius pieces only (Obs objective, where the trace
/// term comes from ‖Y‖²_F instead of W∘Ω).
pub fn diag_fro_parts_block(omega: &Mat, row_offset: usize) -> Option<[f64; 2]> {
    let (rows, p) = omega.shape();
    let mut logd = 0.0;
    let mut fro = 0.0;
    for i in 0..rows {
        let orow = omega.row(i);
        for &v in orow {
            fro += v * v;
        }
        let dcol = row_offset + i;
        if dcol < p {
            let d = orow[dcol];
            if d <= 0.0 {
                return None;
            }
            logd += d.ln();
        }
    }
    Some([logd, fro])
}

/// Line-search pieces over a slab: (tr((Ω−Ω′)ᵀG), ‖Ω−Ω′‖_F²).
pub fn linesearch_parts_block(omega: &Mat, omega_new: &Mat, g: &Mat) -> [f64; 2] {
    let (rows, p) = omega.shape();
    debug_assert_eq!(omega_new.shape(), (rows, p));
    debug_assert_eq!(g.shape(), (rows, p));
    let mut dot = 0.0;
    let mut fro = 0.0;
    for i in 0..rows {
        let o = omega.row(i);
        let on = omega_new.row(i);
        let gr = g.row(i);
        for j in 0..p {
            let diff = o[j] - on[j];
            dot += diff * gr[j];
            fro += diff * diff;
        }
    }
    [dot, fro]
}

/// Sufficient-decrease check (Algorithm 2 line 12):
/// accept iff g(Ω′) ≤ g(Ω) − tr((Ω−Ω′)ᵀG) + ‖Ω−Ω′‖²/(2τ).
pub fn accepts(g_new: f64, g_prev: f64, ls_parts: [f64; 2], tau: f64) -> bool {
    g_new <= g_prev - ls_parts[0] + ls_parts[1] / (2.0 * tau)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn symmetric_posdiag(rng: &mut Rng, p: usize) -> Mat {
        let mut m = Mat::from_fn(p, p, |_, _| 0.1 * rng.normal());
        m.symmetrize();
        for i in 0..p {
            m.set(i, i, 1.0 + rng.uniform());
        }
        m
    }

    /// Full-matrix references (the ref.py formulas, transliterated).
    fn ref_gradient(omega: &Mat, w: &Mat, lam2: f64) -> Mat {
        let p = omega.rows();
        let wt = w.transpose();
        Mat::from_fn(p, p, |i, j| {
            let mut v = 0.5 * (w.get(i, j) + wt.get(i, j)) + lam2 * omega.get(i, j);
            if i == j {
                v -= 1.0 / omega.get(i, i);
            }
            v
        })
    }

    fn ref_prox(omega: &Mat, g: &Mat, tau: f64, lam1: f64) -> Mat {
        let p = omega.rows();
        Mat::from_fn(p, p, |i, j| {
            let z = omega.get(i, j) - tau * g.get(i, j);
            if i == j {
                z
            } else {
                soft(z, tau * lam1)
            }
        })
    }

    #[test]
    fn gradient_block_matches_full() {
        let mut rng = Rng::new(1);
        let p = 12;
        let omega = symmetric_posdiag(&mut rng, p);
        let w = Mat::from_fn(p, p, |_, _| rng.normal());
        let full = ref_gradient(&omega, &w, 0.4);
        // Split into two slabs and compare.
        let wt = w.transpose();
        for (r0, r1) in [(0, 5), (5, 12)] {
            let blk = gradient_block(
                &omega.row_block(r0, r1),
                &w.row_block(r0, r1),
                &wt.row_block(r0, r1),
                r0,
                0.4,
            );
            assert!(blk.max_abs_diff(&full.row_block(r0, r1)) < 1e-14);
        }
    }

    #[test]
    fn prox_block_matches_full_and_into_variant() {
        let mut rng = Rng::new(2);
        let p = 10;
        let omega = symmetric_posdiag(&mut rng, p);
        let g = Mat::from_fn(p, p, |_, _| rng.normal());
        let full = ref_prox(&omega, &g, 0.5, 0.7);
        for (r0, r1) in [(0, 3), (3, 10)] {
            let ob = omega.row_block(r0, r1);
            let gb = g.row_block(r0, r1);
            let blk = prox_block(&ob, &gb, r0, 0.5, 0.7);
            assert!(blk.max_abs_diff(&full.row_block(r0, r1)) < 1e-14);
            let mut out = Mat::zeros(r1 - r0, p);
            prox_block_into(&ob, &gb, r0, 0.5, 0.7, &mut out);
            assert!(out.max_abs_diff(&blk) == 0.0);
        }
    }

    #[test]
    fn prox_diagonal_untouched_by_threshold() {
        let p = 5;
        let omega = Mat::eye(p);
        let g = Mat::zeros(p, p);
        let out = prox_block(&omega, &g, 0, 1.0, 100.0);
        assert!(out.max_abs_diff(&Mat::eye(p)) == 0.0);
    }

    #[test]
    fn objective_parts_sum_over_slabs() {
        let mut rng = Rng::new(3);
        let p = 9;
        let omega = symmetric_posdiag(&mut rng, p);
        let w = Mat::from_fn(p, p, |_, _| rng.normal());
        let full = objective_parts_block(&omega, &w, 0).unwrap();
        let a = objective_parts_block(&omega.row_block(0, 4), &w.row_block(0, 4), 0).unwrap();
        let b = objective_parts_block(&omega.row_block(4, 9), &w.row_block(4, 9), 4).unwrap();
        for k in 0..3 {
            assert!((full[k] - (a[k] + b[k])).abs() < 1e-11, "part {k}");
        }
    }

    #[test]
    fn objective_rejects_nonpositive_diagonal() {
        let mut omega = Mat::eye(3);
        omega.set(1, 1, -0.5);
        assert!(objective_parts_block(&omega, &Mat::zeros(3, 3), 0).is_none());
        assert!(diag_fro_parts_block(&omega, 0).is_none());
        // But a slab that excludes the bad diagonal entry is fine.
        assert!(objective_parts_block(&omega.row_block(0, 1), &Mat::zeros(1, 3), 0).is_some());
    }

    #[test]
    fn linesearch_parts_closed_form() {
        // Ω − Ω′ = E (all ones): dot = ΣG, fro = p².
        let p = 4;
        let omega = Mat::from_fn(p, p, |_, _| 2.0);
        let omega_new = Mat::from_fn(p, p, |_, _| 1.0);
        let g = Mat::from_fn(p, p, |i, j| (i + j) as f64);
        let [dot, fro] = linesearch_parts_block(&omega, &omega_new, &g);
        let gsum: f64 = (0..p).flat_map(|i| (0..p).map(move |j| (i + j) as f64)).sum();
        assert_eq!(dot, gsum);
        assert_eq!(fro, (p * p) as f64);
    }

    #[test]
    fn accepts_inequality() {
        assert!(accepts(1.0, 1.0, [0.0, 0.0], 1.0));
        assert!(!accepts(2.0, 1.0, [0.5, 0.5], 1.0)); // 2 > 1 - 0.5 + 0.25
        assert!(accepts(0.9, 1.0, [0.5, 1.0], 1.0)); // 0.9 <= 1 - 0.5 + 0.5
    }
}
