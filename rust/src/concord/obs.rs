//! **Algorithm 3** — the Obs variant of HP-CONCORD, as a rank program
//! for the simulated fabric.
//!
//! Obs never forms the covariance matrix: per line-search trial it
//! computes Y⁽ᵏ⁾ = Ω⁽ᵏ⁾Xᵀ (1.5D sum-mode multiply, rotating the Xᵀ
//! slabs over the c_X grid while the sparse iterate stays put on the
//! c_Ω grid), and once per proximal iteration Z⁽ᵏ⁾ = Y⁽ᵏ⁾X/n (1.5D
//! concat-mode) plus a distributed transpose of Z. Everything else —
//! gradient, prox, objective, line-search — is embarrassingly parallel
//! over the iterate's block rows, with scalar reductions over layer
//! groups.
//!
//! Layouts (paper Fig. 1, right): Ω, Y, Z, G all live in 1D block rows
//! over the c_Ω grid's teams; Xᵀ row-slabs / X column-slabs live on the
//! c_X grid and rotate.

use std::sync::Arc;

use crate::dist::{
    mult_concat, mult_sum, transpose_block_rows, Block, ConcatAxis, Layout1D, RepGrid,
};
use crate::linalg::{Csr, Mat};
use crate::simnet::Comm;

use super::dist_common::{combine_objective, global_max, global_sum, RankFit, TagGen};
use super::ops;
use super::{ConcordConfig, SolveStats};

/// Run Obs on this rank. `x` is the full observation matrix (each rank
/// slices its own parts — the simulation stand-in for pre-distributed
/// data). Returns this rank's fragment of the fit.
pub fn fit_obs_rank(
    comm: &mut Comm,
    x: &Arc<Mat>,
    cfg: &ConcordConfig,
    c_x: usize,
    c_omega: usize,
) -> RankFit {
    let p_ranks = comm.size();
    let (n, p) = x.shape();
    let grid_x = RepGrid::new(p_ranks, c_x);
    let grid_o = RepGrid::new(p_ranks, c_omega);
    let lx = Layout1D::new(p, grid_x.teams()); // Xᵀ rows / X cols over X teams
    let lo = Layout1D::new(p, grid_o.teams()); // Ω/Y/Z rows over Ω teams
    let rank = comm.rank();
    let my_x = grid_x.team_of(rank);
    let my_o = grid_o.team_of(rank);
    let o_layer_group = grid_o.layer_members(grid_o.layer_of(rank));
    let mut tags = TagGen::new();
    // Node-local threads (the paper's per-node t): local multiplies and
    // fused passes fan out over this many workers; bit-identical at any
    // value, and the metered L/W never change.
    let threads = cfg.threads.max(1);

    // My rotated operands: Xᵀ slab (k-rows) and X column slab.
    let (xs, xe) = lx.range(my_x);
    let x_cols = x.col_block(xs, xe); // n × len
    let xt_slab = Block::Dense(x_cols.transpose()); // len × n
    let x_slab = Block::Dense(x_cols); // n × len (rotates for Z)

    // Iterate block rows.
    let (os, oe) = lo.range(my_o);
    let my_rows = oe - os;
    let mut omega = Mat::from_fn(my_rows, p, |i, j| f64::from(os + i == j));

    // Y = Ω Xᵀ for a given iterate block (sparse·dense over rotated Xᵀ).
    let y_step = |comm: &mut Comm, tags: &mut TagGen, om: &Mat| -> Mat {
        let om_sparse = Csr::from_dense(om, 0.0);
        mult_sum(
            comm,
            &grid_x,
            &grid_o,
            tags.next(10_000),
            &xt_slab,
            my_rows,
            n,
            |comm, idx, blk| {
                let (ks, ke) = lx.range(idx);
                let slab = blk.as_dense();
                let mut out = Mat::zeros(my_rows, n);
                // Row-partitioned over the node-local pool: each output
                // row is one serial run of the scatter kernel, so the
                // result is bit-identical at any thread count; the nnz
                // tally is an exact integer sum in chunk order.
                let body = |s: usize, e: usize, orows: &mut [f64]| -> u64 {
                    let mut nnz_used = 0u64;
                    for i in s..e {
                        let (cols, vals) = om_sparse.row(i);
                        let orow = &mut orows[(i - s) * n..(i - s + 1) * n];
                        for (&j, &v) in cols.iter().zip(vals) {
                            if j >= ks && j < ke {
                                nnz_used += 1;
                                let srow = slab.row(j - ks);
                                for t in 0..n {
                                    orow[t] += v * srow[t];
                                }
                            }
                        }
                    }
                    nnz_used
                };
                let nnz_used: u64 = if threads <= 1
                    || my_rows < 2
                    || om_sparse.nnz() * n < crate::util::pool::SPAWN_MIN_WORK
                {
                    body(0, my_rows, out.data_mut())
                } else {
                    use std::sync::atomic::{AtomicU64, Ordering};
                    let tally = AtomicU64::new(0);
                    let ranges = crate::util::pool::chunk_ranges(my_rows, threads, 1);
                    crate::util::pool::par_rows_mut(
                        out.data_mut(),
                        n,
                        &ranges,
                        |_i, s, e, orows| {
                            tally.fetch_add(body(s, e, orows), Ordering::Relaxed);
                        },
                    );
                    tally.load(Ordering::Relaxed)
                };
                comm.count_flops_sparse(2 * nnz_used * n as u64);
                out
            },
        )
    };

    // Objective for a candidate iterate: g = −2Σlog + ‖Y‖²/n + λ₂/2‖Ω‖².
    let objective = |comm: &mut Comm,
                     tags: &mut TagGen,
                     om: &Mat,
                     y: &Mat|
     -> f64 {
        let parts = match ops::diag_fro_parts_block_mt(om, os, threads) {
            Some([logd, fro]) => vec![0.0, logd, y.fro2() / n as f64, fro],
            None => vec![1.0, 0.0, 0.0, 0.0],
        };
        let global = global_sum(comm, &o_layer_group, tags.next(10), parts);
        combine_objective(&global, cfg.lambda2)
    };

    let mut y = y_step(comm, &mut tags, &omega);
    let mut stats = SolveStats::default();
    let mut converged = false;
    let mut g_final = f64::INFINITY;

    for _it in 0..cfg.max_iter {
        stats.iters += 1;

        // Z = Y·X/n over rotated X column slabs, then Zᵀ.
        let y_fixed = y.clone();
        let mut z = mult_concat(
            comm,
            &grid_x,
            &grid_o,
            tags.next(10_000),
            &x_slab,
            ConcatAxis::Cols,
            &lx,
            my_rows,
            |comm, _idx, blk| {
                let xb = blk.as_dense();
                comm.count_flops_dense(2 * (my_rows * n * xb.cols()) as u64);
                y_fixed.matmul_mt(xb, threads)
            },
        );
        z.scale(1.0 / n as f64);
        let (zt, _) = transpose_block_rows(comm, &grid_o, tags.next(10), &z, &lo);

        // Gradient and current objective.
        let grad = ops::gradient_block_mt(&omega, &z, &zt, os, cfg.lambda2, threads);
        let g_prev = objective(comm, &mut tags, &omega, &y);

        // Backtracking line search (Algorithm 3 lines 8-12).
        let mut tau = 1.0;
        let mut accepted = None;
        for _ls in 0..cfg.max_linesearch {
            stats.trials += 1;
            let omega_new = ops::prox_block_mt(&omega, &grad, os, tau, cfg.lambda1, threads);
            let y_new = y_step(comm, &mut tags, &omega_new);
            let g_new = objective(comm, &mut tags, &omega_new, &y_new);
            let ls_local = ops::linesearch_parts_block_mt(&omega, &omega_new, &grad, threads);
            let ls = global_sum(comm, &o_layer_group, tags.next(10), ls_local.to_vec());
            if ops::accepts(g_new, g_prev, [ls[0], ls[1]], tau) {
                accepted = Some((omega_new, y_new, g_new));
                break;
            }
            tau *= 0.5;
            accepted = Some((omega_new, y_new, g_new)); // keep last if cap hit
        }
        let (omega_new, y_new, g_new) = accepted.expect("at least one trial");

        let delta_local = omega.max_abs_diff(&omega_new);
        let delta = global_max(comm, &o_layer_group, tags.next(10), delta_local);
        omega = omega_new;
        y = y_new;
        g_final = g_new;

        let nnz = global_sum(
            comm,
            &o_layer_group,
            tags.next(10),
            vec![omega.nnz() as f64],
        )[0] as u64;
        stats.nnz_samples += p as u64;
        stats.nnz_total += nnz;

        if delta < cfg.tol {
            converged = true;
            break;
        }
    }

    RankFit {
        row_start: os,
        omega_block: omega,
        primary: grid_o.layer_of(rank) == 0,
        stats,
        objective: g_final,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::dist_common::assemble_fit;
    use crate::concord::single_node::fit_single_node;
    use crate::concord::Variant;
    use crate::rng::Rng;
    use crate::simnet::Fabric;

    fn test_cfg() -> ConcordConfig {
        ConcordConfig {
            lambda1: 0.25,
            lambda2: 0.1,
            tol: 1e-6,
            max_iter: 200,
            variant: Variant::Obs,
            ..Default::default()
        }
    }

    /// The distributed Obs solver must match the single-node solver to
    /// near machine precision for every replication configuration.
    #[test]
    fn obs_matches_single_node_across_configs() {
        let mut rng = Rng::new(21);
        let (n, p) = (12usize, 16usize);
        let x = Mat::from_fn(n, p, |_, _| rng.normal());
        let cfg = test_cfg();
        let reference = fit_single_node(&x, &cfg).unwrap();

        for &(pr, cx, co) in &[
            (1usize, 1usize, 1usize),
            (4, 1, 1),
            (4, 2, 1),
            (4, 1, 2),
            (4, 2, 2),
            (8, 2, 4),
            (8, 4, 2),
        ] {
            let x = Arc::new(x.clone());
            let run = Fabric::new(pr)
                .run(move |comm| fit_obs_rank(comm, &x, &cfg, cx, co));
            let fit = assemble_fit(run.results);
            assert_eq!(fit.iterations, reference.iterations, "P={pr} cx={cx} co={co}");
            assert!(
                fit.omega.max_abs_diff(&reference.omega) < 1e-8,
                "P={pr} cx={cx} co={co}: {}",
                fit.omega.max_abs_diff(&reference.omega)
            );
            assert!((fit.objective - reference.objective).abs() < 1e-8);
        }
    }

    /// Replication reduces the words moved per rank (the whole point of
    /// communication avoidance): c_X = 2 must move fewer words than
    /// c_X = 1 at equal P.
    #[test]
    fn replication_reduces_bandwidth() {
        let mut rng = Rng::new(22);
        let (n, p) = (10usize, 16usize);
        let x = Mat::from_fn(n, p, |_, _| rng.normal());
        let mut cfg = test_cfg();
        cfg.max_iter = 5;
        cfg.tol = 0.0;
        let words = |cx: usize, co: usize| {
            let x = Arc::new(x.clone());
            let run = Fabric::new(8).run(move |comm| fit_obs_rank(comm, &x, &cfg, cx, co));
            run.summary().max_per_rank.words
        };
        let w11 = words(1, 1);
        let w42 = words(4, 2);
        assert!(
            w42 < w11,
            "replication should cut per-rank words: {w42} !< {w11}"
        );
    }
}
