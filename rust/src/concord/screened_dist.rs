//! Screened **distributed** solving: exact thresholding composed with
//! the 1.5D fabric layer — the paper's §6 divide-and-conquer direction
//! at the distributed scale.
//!
//! Three stages:
//!
//! 1. **Distributed screening pass** ([`screen_distributed`]): a fabric
//!    of up to `total_ranks` ranks, each owning a 1D block of S's rows.
//!    Every rank forms its own rows of `S = XᵀX/n` locally, runs
//!    union-find over its rows' thresholded edges, and the per-rank
//!    labelings (pairs `(i, find(i))`, canonical because roots are
//!    minimum members) are allgathered and re-unioned — every rank ends
//!    with the global connected components, and the collective is
//!    metered like any other.
//! 2. **Component scheduling**: each non-singleton component gets a
//!    [`FabricPlan`] from the cost model ([`crate::cost::schedule`]),
//!    sizing `(P, c_X, c_Ω, variant)` to the component — with `d`
//!    estimated from the screened graph's mean degree, whose support is
//!    a superset of the estimate's by the exact thresholding rule.
//!    Components at or below `small_cutoff` (or whose plan says `P = 1`)
//!    run on the single-node path; singletons use the closed form. The
//!    fabric plans are then packed into **waves** under the global rank
//!    budget ([`plan_concurrent`]): within a wave every fabric runs at
//!    the same time on its own disjoint rank team (launched by the
//!    deterministic scoped pool), waves run back to back.
//! 3. **Reassembly**: per-component estimates are scattered into the
//!    global block-diagonal omega through the shared
//!    [`ScreenAccum`](super::screening::ScreenAccum) (summed iteration
//!    statistics, accumulated in component order whatever the launch
//!    order), and the per-fabric [`CostSummary`]s are folded per wave
//!    with [`CostSummary::merge_concurrent`] (per-wave max of modeled
//!    and comm time, counters summed) and across waves with
//!    [`CostSummary::merge_sequential`] — the reported bill is the
//!    schedule's critical path, not the serial sum.
//!
//! Within each component's fabric the rank programs are byte-for-byte
//! the ones `fit_distributed` runs on the extracted sub-problem, so the
//! Lemma 3.2/3.3 per-rank message/word counts are untouched by the
//! composition (`rust/tests/lemma_counts.rs`) and results are invariant
//! in the node-local thread count (`rust/tests/parallel_determinism.rs`).
//! Component solves are independent, so at a fixed budget the wave
//! schedule changes *when* a fabric launches, never what it computes:
//! per-component omegas and counters are bit-identical to running the
//! same plans one after another (`rust/tests/concurrent_schedule.rs`,
//! pinned against [`ScreenedDistOptions::sequential`]).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cost::schedule::{
    plan_component, plan_concurrent, runnable_on_fabric, ConcurrentSchedule, FabricPlan,
    ScheduledComponent,
};
use crate::cost::ProblemShape;
use crate::dist::Layout1D;
use crate::linalg::Mat;
use crate::simnet::{cost::CostSummary, Comm, Counters, Fabric, MachineParams};
use crate::util::pool::{chunk_ranges, par_map};

use super::screening::{extract_columns, Components, ComponentStat, ScreenAccum, UnionFind};
use super::{fit_single_node, run_distributed, ConcordConfig, ConcordFit};

/// Controls for the screened distributed solver.
#[derive(Debug, Clone, Copy)]
pub struct ScreenedDistOptions {
    /// Rank budget: the screening pass uses up to this many ranks, and
    /// no component fabric exceeds it.
    pub total_ranks: usize,
    pub machine: MachineParams,
    /// Components of at most this many variables skip the fabric and
    /// run on the single-node path.
    pub small_cutoff: usize,
    /// Override the scheduler with a fixed `(ranks, c_X, c_Ω)` for every
    /// above-cutoff component — equivalence tests and manual control.
    pub fixed: Option<(usize, usize, usize)>,
    /// Launch the scheduled component fabrics one after another instead
    /// of wave-concurrently, and bill them with
    /// [`CostSummary::merge_sequential`]. The *plans* are identical
    /// either way (the packer still runs, including any budget shrink),
    /// so results are bit-identical — this is the reference mode the
    /// concurrent-schedule equivalence tests compare against, and a
    /// way to read the old serial bill.
    pub sequential: bool,
}

impl Default for ScreenedDistOptions {
    fn default() -> Self {
        ScreenedDistOptions {
            total_ranks: 8,
            machine: MachineParams::default(),
            small_cutoff: 4,
            fixed: None,
            sequential: false,
        }
    }
}

/// One component's solve record.
#[derive(Debug)]
pub struct ComponentSolve {
    /// Ascending global column indices of this component.
    pub indices: Vec<usize>,
    /// The fabric it was assigned (`ranks == 1`: single-node path).
    pub plan: FabricPlan,
    /// Metered cost of this component's fabric (zero on the single-node
    /// path, which is not metered — exactly as in the unscreened case).
    pub cost: CostSummary,
    /// Rank-indexed counters of this component's fabric (empty on the
    /// single-node path).
    pub counters: Vec<Counters>,
    /// Which wave of the concurrent schedule launched this component
    /// (`None`: below-cutoff single-node work that never entered the
    /// packer, or a sequential-mode run where no waves were launched).
    pub wave: Option<usize>,
}

/// Outcome of a screened distributed fit.
#[derive(Debug)]
pub struct ScreenedDistFit {
    /// Assembled block-diagonal estimate; iteration statistics are
    /// summed across components (see [`super::screening::ScreenedFit`]).
    pub fit: ConcordFit,
    /// Aggregate bill of the screening pass plus every component
    /// *fabric* under the executed schedule: wave-concurrent by default
    /// (per-wave [`CostSummary::merge_concurrent`], waves folded with
    /// [`CostSummary::merge_sequential`] — the critical path), or the
    /// plain serial fold when [`ScreenedDistOptions::sequential`] is
    /// set. Counters are machine facts from metered fabrics only —
    /// components routed to the single-node path run unmetered (exactly
    /// like the plain single-node solver), so compare
    /// screened-vs-unscreened bills on fabric components, or consult
    /// each solve's `plan.modeled_time` for the model's view.
    pub cost: CostSummary,
    /// The screening pass's own share of `cost`.
    pub screen_cost: CostSummary,
    /// The wave schedule the fabric components ran under (also recorded
    /// in sequential mode, where it describes the plans but waves were
    /// launched one component at a time).
    pub schedule: ConcurrentSchedule,
    pub components: usize,
    pub largest: usize,
    /// One entry per non-singleton component, in component order —
    /// aligned with `per_component`.
    pub solves: Vec<ComponentSolve>,
    /// Per-component solver statistics (non-singleton components).
    pub per_component: Vec<ComponentStat>,
}

impl ScreenedDistFit {
    /// What the same plans would have billed launched one after another
    /// (screening pass + serial fold of every fabric) — the baseline
    /// the concurrent schedule's critical-path `cost` is compared to.
    pub fn sequential_bill(&self) -> CostSummary {
        let mut bill = self.screen_cost;
        for sv in &self.solves {
            bill.merge_sequential(&sv.cost);
        }
        bill
    }
}

/// What the screening fabric hands back to the leader.
struct ScreenPass {
    components: Components,
    /// Thresholded off-diagonal degree of every variable.
    degrees: Vec<f64>,
    /// Diagonal of S (singleton closed forms need `s_ii`).
    diag: Vec<f64>,
    cost: CostSummary,
}

/// The distributed screening pass: block-row gram + local union-find,
/// merged by one allgather of canonical labelings.
fn screen_distributed(
    x: &Mat,
    threshold: f64,
    p_ranks: usize,
    machine: MachineParams,
    threads: usize,
) -> ScreenPass {
    let p = x.cols();
    let layout = Layout1D::new(p, p_ranks);
    let shared = Arc::new(x.clone());
    let run = Fabric::with_machine(p_ranks, machine)
        .run(move |comm| screen_rank(comm, &shared, threshold, &layout, threads));
    let cost = run.summary();

    let mut degrees = vec![0.0f64; p];
    let mut diag = vec![0.0f64; p];
    for (rank, (_, deg, dg)) in run.results.iter().enumerate() {
        let (rs, re) = layout.range(rank);
        degrees[rs..re].copy_from_slice(deg);
        diag[rs..re].copy_from_slice(dg);
    }
    // Every rank holds the same merged labeling; rank 0's is canonical.
    let raw: Vec<usize> = run.results[0].0.iter().map(|&v| v as usize).collect();
    ScreenPass { components: Components::from_raw_labels(&raw), degrees, diag, cost }
}

/// One screening rank: local gram rows → local union-find → allgather
/// and merge. Returns (merged labels, my rows' degrees, my rows' s_ii).
fn screen_rank(
    comm: &mut Comm,
    x: &Arc<Mat>,
    threshold: f64,
    layout: &Layout1D,
    threads: usize,
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let p = x.cols();
    let n = x.rows();
    let (rs, re) = layout.range(comm.rank());
    let rows = re - rs;

    // My block rows of S = XᵀX/n.
    let xt_rows = x.col_block(rs, re).transpose(); // rows × n
    comm.count_flops_dense(2 * (rows * n * p) as u64);
    let mut s_rows = xt_rows.matmul_mt(x, threads); // rows × p
    s_rows.scale(1.0 / n.max(1) as f64);

    // Union-find over my rows' thresholded edges.
    let mut uf = UnionFind::new(p);
    let mut degrees = vec![0.0f64; rows];
    let mut diag = vec![0.0f64; rows];
    for i in rs..re {
        diag[i - rs] = s_rows.get(i - rs, i);
        for j in 0..p {
            if j != i && s_rows.get(i - rs, j).abs() > threshold {
                degrees[i - rs] += 1.0;
                uf.union(i, j);
            }
        }
    }

    // A labeling is fully described by the pairs (i, find(i)); the join
    // of all ranks' labelings is the connectivity of the union of their
    // edge sets — i.e. the global components.
    let local: Vec<f64> = (0..p).map(|i| uf.find(i) as f64).collect();
    let team: Vec<usize> = (0..comm.size()).collect();
    let all = comm.allgather(&team, 1, local);
    let mut merged = UnionFind::new(p);
    for labels in &all {
        for (i, &r) in labels.iter().enumerate() {
            merged.union(i, r as usize);
        }
    }
    let labels: Vec<f64> = (0..p).map(|i| merged.find(i) as f64).collect();
    (labels, degrees, diag)
}

/// What one scheduled (or below-cutoff) component's solve produced.
struct SolveOutcome {
    fit: ConcordFit,
    plan: FabricPlan,
    cost: CostSummary,
    counters: Vec<Counters>,
    wave: Option<usize>,
}

/// Solve one component with its final plan: a fabric run for `P > 1`,
/// the (unmetered) single-node path otherwise — exactly the per-
/// component body the sequential loop used to run.
fn solve_component(
    x: &Mat,
    idx: &[usize],
    cfg: &ConcordConfig,
    plan: FabricPlan,
    machine: MachineParams,
    wave: Option<usize>,
) -> Result<SolveOutcome> {
    let sub_x = extract_columns(x, idx);
    if plan.ranks <= 1 {
        let fit = fit_single_node(&sub_x, cfg)?;
        Ok(SolveOutcome { fit, plan, cost: CostSummary::default(), counters: Vec::new(), wave })
    } else {
        let mut sub_cfg = *cfg;
        sub_cfg.variant = plan.variant;
        let run = run_distributed(&sub_x, &sub_cfg, plan.ranks, plan.c_x, plan.c_omega, machine);
        Ok(SolveOutcome {
            fit: run.fit,
            plan: FabricPlan { variant: run.variant, ..plan },
            cost: run.cost,
            counters: run.counters,
            wave,
        })
    }
}

/// Fit with screening on the distributed path: screen on a fabric, give
/// every non-trivial component a cost-model-sized fabric plan, pack the
/// plans into waves under the global rank budget, launch each wave's
/// fabrics concurrently on disjoint rank teams, and reassemble the
/// global block-diagonal estimate with the schedule's critical-path
/// bill. Small components solve single-node; singletons use the closed
/// form.
pub fn fit_screened_distributed(
    x: &Mat,
    cfg: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Result<ScreenedDistFit> {
    let p = x.cols();
    let n = x.rows();
    assert!(opts.total_ranks >= 1, "need at least one rank");
    // Install the blocking shape before any planning: the scheduler's
    // Lemma 3.5 pricing reads the installed tile's cache-reuse term, so
    // plans must see this fit's tile — not whatever a previous fit left
    // behind (and every component is then planned under the same price).
    crate::linalg::tile::install(cfg.tile);
    // The global concurrent rank budget: waves of component fabrics are
    // packed under it. Default ("auto", 0) is the fabric's own rank
    // count, so out of the box a wave may run several planned fabrics
    // at once but never widens any single one.
    let budget = if cfg.ranks_budget == 0 { opts.total_ranks } else { cfg.ranks_budget };
    // A pinned fabric must satisfy the same runnability constraints the
    // scheduler enforces; catch it here as a clean error instead of a
    // RepGrid panic inside a spawned rank thread.
    if let Some((ranks, c_x, c_omega)) = opts.fixed {
        if !runnable_on_fabric(ranks, c_x, c_omega, cfg.variant) {
            bail!(
                "pinned fabric P={ranks} c_X={c_x} c_Ω={c_omega} is not runnable \
                 for {:?} (power-of-two replication with c_X·c_Ω ≤ P required)",
                cfg.variant
            );
        }
        // Shrinking would silently violate the pin; refuse instead.
        if ranks > budget {
            bail!(
                "pinned fabric P={ranks} exceeds the concurrent rank budget {budget} \
                 (raise --ranks-budget or drop the --cx/--comega pin)"
            );
        }
    }
    let threads = cfg.threads.max(1);

    let screen_ranks = opts.total_ranks.min(p.max(1));
    let screen = screen_distributed(x, cfg.lambda1, screen_ranks, opts.machine, threads);
    let comps = &screen.components;

    // --- Plan every non-trivial component, then pack the fabric plans
    // into waves. Components whose plan says P = 1 (small, or priced
    // out of parallelism) never enter the packer: they run on the
    // unmetered single-node path exactly as before.
    let mut largest = 0usize;
    let mut single_node: Vec<(usize, FabricPlan)> = Vec::new();
    let mut candidates: Vec<(usize, FabricPlan, ProblemShape)> = Vec::new();
    for c in 0..comps.count {
        let idx = comps.members(c);
        largest = largest.max(idx.len());
        if idx.len() == 1 {
            continue;
        }
        // d estimated from the screened graph's mean degree: its
        // support contains the estimate's (exact thresholding).
        let deg_sum: f64 = idx.iter().map(|&i| screen.degrees[i]).sum();
        let d_est = 1.0 + deg_sum / idx.len() as f64;
        let shape = ProblemShape {
            p: idx.len() as f64,
            n: n as f64,
            s: 40.0,
            t: 10.0,
            d: d_est.min(idx.len() as f64),
        };
        let plan = if idx.len() <= opts.small_cutoff {
            FabricPlan::single_node(cfg.variant)
        } else if let Some((ranks, c_x, c_omega)) = opts.fixed {
            if ranks <= idx.len() {
                FabricPlan { ranks, c_x, c_omega, variant: cfg.variant, modeled_time: 0.0 }
            } else {
                // A pinned fabric wider than the component would leave
                // teams empty; degrade to the single-node path.
                FabricPlan::single_node(cfg.variant)
            }
        } else {
            plan_component(&shape, opts.total_ranks, threads, &opts.machine, cfg.variant)
        };
        if plan.ranks <= 1 {
            single_node.push((c, plan));
        } else {
            candidates.push((c, plan, shape));
        }
    }
    let schedule = plan_concurrent(&candidates, budget, threads, &opts.machine);

    // --- Execute. Outcomes land in a component-indexed table so the
    // reassembly below runs in component order whatever the launch
    // order was — float accumulation order (objective, trial sums) is a
    // function of the decomposition only, never of the schedule.
    let mut outcomes: Vec<Option<Result<SolveOutcome>>> = Vec::new();
    outcomes.resize_with(comps.count, || None);
    for &(c, plan) in &single_node {
        outcomes[c] = Some(solve_component(x, comps.members(c), cfg, plan, opts.machine, None));
    }

    let mut cost = screen.cost;
    if opts.sequential {
        // Reference mode: same plans, launched one component at a time
        // in component order, serial billing — the pre-wave behavior.
        let mut entries: Vec<&ScheduledComponent> =
            schedule.waves.iter().flat_map(|w| w.entries.iter()).collect();
        entries.sort_by_key(|e| e.component);
        for e in entries {
            let idx = comps.members(e.component);
            let out = solve_component(x, idx, cfg, e.plan, opts.machine, None);
            if let Ok(ref sv) = out {
                cost.merge_sequential(&sv.cost);
            }
            outcomes[e.component] = Some(out);
        }
    } else {
        for (w, wave) in schedule.waves.iter().enumerate() {
            // One scoped pool worker per fabric in the wave: disjoint
            // rank teams running at the same time. `par_map` returns in
            // entry order, so billing and bookkeeping are
            // schedule-deterministic.
            let ranges = chunk_ranges(wave.entries.len(), wave.entries.len(), 1);
            let outs = par_map(&ranges, |_, start, _| {
                let e = &wave.entries[start];
                let idx = comps.members(e.component);
                (e.component, solve_component(x, idx, cfg, e.plan, opts.machine, Some(w)))
            });
            let mut wave_bill = CostSummary::default();
            for (c, out) in outs {
                if let Ok(ref sv) = out {
                    wave_bill.merge_concurrent(&sv.cost);
                }
                outcomes[c] = Some(out);
            }
            cost.merge_sequential(&wave_bill);
        }
    }

    // --- Reassemble in component order.
    let mut acc = ScreenAccum::new(p);
    let mut solves = Vec::new();
    for c in 0..comps.count {
        let idx = comps.members(c);
        if idx.len() == 1 {
            acc.add_singleton(idx[0], screen.diag[idx[0]], cfg.lambda2);
            continue;
        }
        let out = outcomes[c].take().expect("every non-singleton component was solved")?;
        acc.add_component(idx, &out.fit);
        solves.push(ComponentSolve {
            indices: idx.to_vec(),
            plan: out.plan,
            cost: out.cost,
            counters: out.counters,
            wave: out.wave,
        });
    }

    let screened = acc.finish(comps.count, largest);
    Ok(ScreenedDistFit {
        fit: screened.fit,
        cost,
        screen_cost: screen.cost,
        schedule,
        components: comps.count,
        largest,
        solves,
        per_component: screened.per_component,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::screening::gram_components;
    use crate::gen;
    use crate::rng::Rng;
    use crate::runtime::native;

    /// The distributed screening pass must agree with the single-node
    /// component decomposition at every rank count.
    #[test]
    fn distributed_screening_matches_serial_components() {
        let mut rng = Rng::new(11);
        let prob = gen::chain_problem(18, 60, &mut rng);
        let s = native::gram(&prob.x);
        for threshold in [0.05, 0.2, 0.5, 2.0] {
            let want = gram_components(&s, threshold);
            for ranks in [1usize, 2, 3, 4, 8] {
                let pass = screen_distributed(
                    &prob.x,
                    threshold,
                    ranks,
                    MachineParams::default(),
                    1,
                );
                assert_eq!(
                    pass.components, want,
                    "threshold {threshold} ranks {ranks} disagree"
                );
            }
        }
    }

    /// Degrees and diagonal come back in global index order whatever
    /// the rank count; singletons use s_ii exactly.
    #[test]
    fn screening_pass_diag_and_degrees_are_rank_count_invariant() {
        let mut rng = Rng::new(12);
        let prob = gen::chain_problem(10, 50, &mut rng);
        let one = screen_distributed(&prob.x, 0.2, 1, MachineParams::default(), 1);
        let four = screen_distributed(&prob.x, 0.2, 4, MachineParams::default(), 2);
        assert_eq!(one.diag, four.diag);
        assert_eq!(one.degrees, four.degrees);
    }

    /// A rank budget larger than p is clamped rather than spawning
    /// empty-row ranks.
    #[test]
    fn tiny_problem_clamps_rank_budget() {
        let mut rng = Rng::new(13);
        let prob = gen::chain_problem(3, 30, &mut rng);
        let cfg = ConcordConfig { lambda1: 0.3, max_iter: 30, ..Default::default() };
        let opts = ScreenedDistOptions { total_ranks: 16, ..Default::default() };
        let out = fit_screened_distributed(&prob.x, &cfg, &opts).unwrap();
        assert_eq!(out.fit.omega.rows(), 3);
        assert!(out.components >= 1);
    }

    /// All-singleton decomposition: closed forms only, no solves, and
    /// the omega diagonal matches 1/√(s_ii + λ₂).
    #[test]
    fn all_singletons_use_closed_form() {
        let mut rng = Rng::new(14);
        let prob = gen::chain_problem(8, 40, &mut rng);
        let cfg = ConcordConfig { lambda1: 50.0, lambda2: 0.25, ..Default::default() };
        let out =
            fit_screened_distributed(&prob.x, &cfg, &ScreenedDistOptions::default()).unwrap();
        assert_eq!(out.components, 8);
        assert_eq!(out.largest, 1);
        assert!(out.solves.is_empty());
        let s = native::gram(&prob.x);
        for i in 0..8 {
            let want = 1.0 / (s.get(i, i) + 0.25).sqrt();
            assert!((out.fit.omega.get(i, i) - want).abs() < 1e-12, "diag {i}");
        }
    }
}
