//! Screened **distributed** solving: exact thresholding composed with
//! the 1.5D fabric layer — the paper's §6 divide-and-conquer direction
//! at the distributed scale.
//!
//! Three stages, each owned by its own layer:
//!
//! 1. **Distributed screening pass** ([`screen_distributed_multi`], or
//!    its memory-bounded twin [`screen_streamed`]): a
//!    fabric of up to `total_ranks` ranks, each owning a 1D block of
//!    S's rows. Every rank forms its own rows of `S = XᵀX/n` locally —
//!    **once**, however many λ₁ thresholds are requested — then replays
//!    a shared thresholded edge list per level (the distributed
//!    analogue of [`nested_components`](super::screening::nested_components)'s
//!    refinement reuse: the threshold graphs are nested, so one scan of
//!    the gram rows serves every level). The per-rank, per-level
//!    labelings (pairs `(i, find(i))`, canonical because roots are
//!    minimum members) are allgathered in **one** metered collective
//!    and re-unioned per level — every rank ends with the global
//!    connected components of every threshold, and the gram + gather
//!    are billed exactly once for the whole list.
//! 2. **Planning**: each non-singleton component gets a [`FabricPlan`]
//!    from the cost model ([`crate::cost::schedule`]), sizing
//!    `(P, c_X, c_Ω, variant)` to the component — with `d` estimated
//!    from the screened graph's mean degree, whose support is a
//!    superset of the estimate's by the exact thresholding rule.
//!    Components at or below `small_cutoff` (or whose plan says
//!    `P = 1`) run on the single-node path; singletons use the closed
//!    form. [`plan_job_tasks`] is a pure function of one job's level,
//!    so a grid point planned inside a packed sweep is planned exactly
//!    as a standalone fit plans it.
//! 3. **Execution + reassembly**: the job-tagged tasks go to the
//!    [`FabricExecutor`](super::executor::FabricExecutor), which packs
//!    them into waves under the global rank budget and launches each
//!    wave's fabrics concurrently on disjoint rank teams;
//!    [`reassemble_job`] scatters the per-component estimates back into
//!    the block-diagonal omega through the shared `ScreenAccum` in
//!    component order, whatever the launch order. The bill is the
//!    screening pass plus the executed schedule's critical path.
//!
//! [`fit_screened_distributed`] is the thin single-job client of that
//! machinery; the grid coordinators ([`crate::coordinator::sweep`],
//! [`crate::coordinator::stability`]) reuse the same pieces to pack
//! *every* (grid point, component) and (subsample, component) pair into
//! one shared schedule.
//!
//! Within each component's fabric the rank programs are byte-for-byte
//! the ones `fit_distributed` runs on the extracted sub-problem, so the
//! Lemma 3.2/3.3 per-rank message/word counts are untouched by the
//! composition (`rust/tests/lemma_counts.rs`) and results are invariant
//! in the node-local thread count (`rust/tests/parallel_determinism.rs`).
//! Component solves are independent, so at a fixed budget the wave
//! schedule changes *when* a fabric launches, never what it computes:
//! per-component omegas and counters are bit-identical to running the
//! same plans one after another (`rust/tests/concurrent_schedule.rs`,
//! pinned against [`ScreenedDistOptions::sequential`]), and the
//! amortized multi-threshold pass yields bit-identical components,
//! degrees and diagonals to screening each threshold on its own
//! (`rust/tests/grid_schedule.rs`).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cost::schedule::{
    plan_component, runnable_on_fabric, ConcurrentSchedule, FabricPlan, JobTag, MemFootprint,
};
use crate::cost::ProblemShape;
use crate::dist::Layout1D;
use crate::io::{XDisk, XSource, DEFAULT_PANEL_ROWS};
use crate::linalg::Mat;
use crate::simnet::{cost::CostSummary, Comm, Counters, Fabric, MachineParams};
use crate::util::pool::{chunk_ranges, par_rows_mut};

use super::executor::{ExecutorJob, ExecutorTask, FabricExecutor, TaskOutcome};
use super::screening::{Components, ComponentStat, ScreenAccum, ScreenedFit, UnionFind};
use super::{ConcordConfig, ConcordFit};

/// Controls for the screened distributed solver.
#[derive(Debug, Clone, Copy)]
pub struct ScreenedDistOptions {
    /// Rank budget: the screening pass uses up to this many ranks, and
    /// no component fabric exceeds it.
    pub total_ranks: usize,
    pub machine: MachineParams,
    /// Components of at most this many variables skip the fabric and
    /// run on the single-node path.
    pub small_cutoff: usize,
    /// Override the scheduler with a fixed `(ranks, c_X, c_Ω)` for every
    /// above-cutoff component — equivalence tests and manual control.
    pub fixed: Option<(usize, usize, usize)>,
    /// Launch the scheduled component fabrics one after another instead
    /// of wave-concurrently, and bill them with
    /// [`CostSummary::merge_sequential`]. The *plans* are identical
    /// either way (the packer still runs, including any budget shrink),
    /// so results are bit-identical — this is the reference mode the
    /// concurrent-schedule equivalence tests compare against, and a
    /// way to read the old serial bill.
    pub sequential: bool,
    /// Row-panel width of the streamed gram pass: each screening rank
    /// accumulates its rows of `S = XᵀX/n` over ascending panels of
    /// this many sample rows, so only one panel of X need be resident
    /// at a time. `0` (and any value ≥ n) takes the in-core path.
    /// Bit-identical either way — panel streaming only partitions the
    /// ascending-k accumulation (determinism rules 1 and 7).
    pub gram_block: usize,
}

impl Default for ScreenedDistOptions {
    fn default() -> Self {
        ScreenedDistOptions {
            total_ranks: 8,
            machine: MachineParams::default(),
            small_cutoff: 4,
            fixed: None,
            sequential: false,
            gram_block: 0,
        }
    }
}

/// One component's solve record.
#[derive(Debug)]
pub struct ComponentSolve {
    /// Ascending global column indices of this component.
    pub indices: Vec<usize>,
    /// The fabric it was assigned (`ranks == 1`: single-node path).
    pub plan: FabricPlan,
    /// Metered cost of this component's fabric (zero on the single-node
    /// path, which is not metered — exactly as in the unscreened case).
    pub cost: CostSummary,
    /// Rank-indexed counters of this component's fabric (empty on the
    /// single-node path).
    pub counters: Vec<Counters>,
    /// Which wave of the concurrent schedule launched this component
    /// (`None`: below-cutoff single-node work that never entered the
    /// packer, or a sequential-mode run where no waves were launched).
    pub wave: Option<usize>,
}

/// Outcome of a screened distributed fit.
#[derive(Debug)]
pub struct ScreenedDistFit {
    /// Assembled block-diagonal estimate; iteration statistics are
    /// summed across components (see [`super::screening::ScreenedFit`]).
    pub fit: ConcordFit,
    /// Aggregate bill of the screening pass plus every component
    /// *fabric* under the executed schedule: wave-concurrent by default
    /// (per-wave [`CostSummary::merge_concurrent`], waves folded with
    /// [`CostSummary::merge_sequential`] — the critical path), or the
    /// plain serial fold when [`ScreenedDistOptions::sequential`] is
    /// set. Counters are machine facts from metered fabrics only —
    /// components routed to the single-node path run unmetered (exactly
    /// like the plain single-node solver), so compare
    /// screened-vs-unscreened bills on fabric components, or consult
    /// each solve's `plan.modeled_time` for the model's view.
    pub cost: CostSummary,
    /// The screening pass's own share of `cost`.
    pub screen_cost: CostSummary,
    /// The executed wave schedule's share of `cost` (`cost` is
    /// `screen_cost` ⊕ `solve_cost`, folded sequentially).
    pub solve_cost: CostSummary,
    /// The wave schedule the fabric components ran under (also recorded
    /// in sequential mode, where it describes the plans but waves were
    /// launched one component at a time).
    pub schedule: ConcurrentSchedule,
    pub components: usize,
    pub largest: usize,
    /// One entry per non-singleton component, in component order —
    /// aligned with `per_component`.
    pub solves: Vec<ComponentSolve>,
    /// Per-component solver statistics (non-singleton components).
    pub per_component: Vec<ComponentStat>,
}

impl ScreenedDistFit {
    /// What the same plans would have billed launched one after another
    /// (screening pass + serial fold of every fabric) — the baseline
    /// the concurrent schedule's critical-path `cost` is compared to.
    pub fn sequential_bill(&self) -> CostSummary {
        let mut bill = self.screen_cost;
        bill.merge_sequential(&solves_view(&self.solves));
        bill
    }
}

/// One λ₁ level of an amortized screening pass: the global component
/// decomposition and per-variable thresholded degrees at that
/// threshold.
#[derive(Debug)]
pub struct ScreenLevel {
    pub components: Components,
    /// Thresholded off-diagonal degree of every variable (the planner's
    /// `d` estimate reads the component means).
    pub degrees: Vec<f64>,
}

/// What the multi-threshold screening fabric hands back to the leader:
/// one [`ScreenLevel`] per requested threshold (aligned with the input
/// list) over a single gram + single allgather bill.
#[derive(Debug)]
pub struct MultiScreenPass {
    pub levels: Vec<ScreenLevel>,
    /// Diagonal of S (threshold-independent; singleton closed forms
    /// need `s_ii`).
    pub diag: Vec<f64>,
    /// The whole pass's metered bill — the gram and the labeling
    /// collective are paid once however many levels were requested.
    pub cost: CostSummary,
}

/// What the single-threshold screening fabric hands back (the
/// [`screen_distributed_multi`] special case the unit tests pin).
#[cfg(test)]
struct ScreenPass {
    components: Components,
    /// Thresholded off-diagonal degree of every variable.
    degrees: Vec<f64>,
    /// Diagonal of S (singleton closed forms need `s_ii`).
    diag: Vec<f64>,
    cost: CostSummary,
}

/// The amortized distributed screening pass: block-row gram formed
/// once, every threshold's components refined from one shared edge
/// list, all labelings merged by **one** allgather. Level `k` is
/// bit-identical (components, degrees, diag) to a standalone
/// single-threshold pass at `thresholds[k]` — only the bill changes.
pub fn screen_distributed_multi(
    x: &Mat,
    thresholds: &[f64],
    p_ranks: usize,
    machine: MachineParams,
    threads: usize,
) -> MultiScreenPass {
    screen_streamed(x, thresholds, p_ranks, machine, threads, 0)
}

/// The memory-bounded screening pass: identical to
/// [`screen_distributed_multi`] except each rank forms its gram rows
/// over ascending row panels of `gram_block` samples, so the pass
/// never needs an `|rows| × n` transposed slab of X resident —
/// one `gram_block × p` panel is the whole X working set. Labelings,
/// degrees, diagonal **and counters** are bit-identical to the in-core
/// pass at every panel width (`gram_block ∈ {0, ≥ n}` *is* the in-core
/// pass): panel streaming only partitions the ascending-k
/// accumulation, and storing/loading f64 partials between panels is
/// exact — determinism rules 1 and 7. The pass's modeled residency
/// (panel + gram rows) is billed on `cost.peak_mem_words`.
pub fn screen_streamed(
    x: &Mat,
    thresholds: &[f64],
    p_ranks: usize,
    machine: MachineParams,
    threads: usize,
    gram_block: usize,
) -> MultiScreenPass {
    screen_streamed_src(XSource::InCore(x), thresholds, p_ranks, machine, threads, gram_block)
        .expect("in-core screening cannot fail")
}

/// Effective gram panel height of an on-disk pass: `gram_block` when
/// given, the default read panel otherwise — on disk there is never a
/// whole-matrix slab, so "unstreamed" still means one panel.
fn disk_gram_block(gram_block: usize, n: usize) -> usize {
    if gram_block == 0 {
        DEFAULT_PANEL_ROWS.min(n)
    } else {
        gram_block.min(n)
    }
}

/// [`screen_streamed`] over either X backend (determinism rule 8: the
/// backend is a schedule-only knob, so labelings, degrees, diagonal
/// and counters are bit-identical across `InCore`/`OnDisk` — the disk
/// gram reads ascending panels into the same shared accumulation
/// kernel). Only the modeled residencies move: `peak_mem_words` prices
/// the effective panel and `x_panel_words` the source's own footprint
/// (the whole backing matrix in core, one panel on disk). Errors are
/// disk I/O only — the in-core arm cannot fail.
pub fn screen_streamed_src(
    x: XSource<'_>,
    thresholds: &[f64],
    p_ranks: usize,
    machine: MachineParams,
    threads: usize,
    gram_block: usize,
) -> Result<MultiScreenPass> {
    let p = x.cols();
    let n = x.rows();
    let t_levels = thresholds.len();
    let layout = Layout1D::new(p, p_ranks);
    let src = ScreenSource::from_xsource(x);
    let thr: Vec<f64> = thresholds.to_vec();
    let run = Fabric::with_machine(p_ranks, machine)
        .run(move |comm| screen_rank_multi(comm, &src, &thr, &layout, threads, gram_block));
    let mut cost = run.summary();
    // Modeled host residency of the pass: the gram rows (p² words
    // across the simulated ranks) plus the X working set — all n rows
    // in-core, one panel when streamed or read from disk. A
    // schedule-only model: it never feeds back into plans or results.
    let x_resident = match x {
        XSource::InCore(_) => {
            if gram_block == 0 {
                n
            } else {
                gram_block.min(n)
            }
        }
        XSource::OnDisk(_) => disk_gram_block(gram_block, n),
    };
    cost.peak_mem_words = ((x_resident * p) as u64) + ((p * p) as u64);
    // Source-side residency: in core the backing matrix itself stays
    // resident whatever panel the gram walks; on disk only the
    // effective panel ever exists in memory.
    cost.x_panel_words = match x {
        XSource::InCore(_) => (n * p) as u64,
        XSource::OnDisk(_) => (disk_gram_block(gram_block, n) * p) as u64,
    };
    let results: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> =
        run.results.into_iter().collect::<Result<_>>()?;

    let mut degrees = vec![0.0f64; t_levels * p];
    let mut diag = vec![0.0f64; p];
    for (rank, (_, deg, dg)) in results.iter().enumerate() {
        let (rs, re) = layout.range(rank);
        let rows = re - rs;
        diag[rs..re].copy_from_slice(dg);
        for k in 0..t_levels {
            degrees[k * p + rs..k * p + re].copy_from_slice(&deg[k * rows..(k + 1) * rows]);
        }
    }
    // Every rank holds the same merged labelings; rank 0's are
    // canonical.
    let merged = &results[0].0;
    let levels = (0..t_levels)
        .map(|k| {
            let raw: Vec<usize> =
                merged[k * p..(k + 1) * p].iter().map(|&v| v as usize).collect();
            ScreenLevel {
                components: Components::from_raw_labels(&raw),
                degrees: degrees[k * p..(k + 1) * p].to_vec(),
            }
        })
        .collect();
    Ok(MultiScreenPass { levels, diag, cost })
}

/// Single-threshold screening: the one-level special case.
#[cfg(test)]
fn screen_distributed(
    x: &Mat,
    threshold: f64,
    p_ranks: usize,
    machine: MachineParams,
    threads: usize,
) -> ScreenPass {
    let mut multi =
        screen_distributed_multi(x, std::slice::from_ref(&threshold), p_ranks, machine, threads);
    let level = multi.levels.pop().expect("one threshold, one level");
    ScreenPass {
        components: level.components,
        degrees: level.degrees,
        diag: multi.diag,
        cost: multi.cost,
    }
}

/// The owned X handle a screening rank closure captures:
/// [`Fabric::run`] needs `'static`, so the borrowed [`XSource`] is
/// promoted — one shared `Arc` clone of the in-core matrix for the
/// whole fabric, or the fd-less [`XDisk`] handle (each rank opens its
/// own reads).
#[derive(Clone)]
enum ScreenSource {
    InCore(Arc<Mat>),
    OnDisk(XDisk),
}

impl ScreenSource {
    fn from_xsource(x: XSource<'_>) -> ScreenSource {
        match x {
            XSource::InCore(m) => ScreenSource::InCore(Arc::new(m.clone())),
            XSource::OnDisk(d) => ScreenSource::OnDisk(d.clone()),
        }
    }

    fn rows(&self) -> usize {
        match self {
            ScreenSource::InCore(x) => x.rows(),
            ScreenSource::OnDisk(d) => d.rows(),
        }
    }

    fn cols(&self) -> usize {
        match self {
            ScreenSource::InCore(x) => x.cols(),
            ScreenSource::OnDisk(d) => d.cols(),
        }
    }
}

/// One screening rank: local gram rows once → per-level union-find over
/// the shared thresholded edge list → one allgather, merged per level.
/// Returns (per-level merged labels, per-level row degrees, row s_ii),
/// each flattened level-major. `Err` only on disk I/O — the in-core
/// source cannot fail.
fn screen_rank_multi(
    comm: &mut Comm,
    src: &ScreenSource,
    thresholds: &[f64],
    layout: &Layout1D,
    threads: usize,
    gram_block: usize,
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
    let p = src.cols();
    let n = src.rows();
    let t_levels = thresholds.len();
    let (rs, re) = layout.range(comm.rank());
    let rows = re - rs;

    // My block rows of S = XᵀX/n — formed once for every level. The
    // flop count is a machine fact: identical on every gram path
    // (panel width and X backend are schedule-only knobs, rules 7/8).
    comm.count_flops_dense(2 * (rows * n * p) as u64);
    let mut s_rows = match src {
        ScreenSource::InCore(x) => {
            if gram_block == 0 || gram_block >= n {
                // In-core: materialize the transposed slab, blocked
                // kernel.
                let xt_rows = x.col_block(rs, re).transpose(); // rows × n
                xt_rows.matmul_mt(x, threads) // rows × p
            } else {
                gram_rows_streamed(x, rs, re, gram_block, threads)
            }
        }
        ScreenSource::OnDisk(xd) => {
            gram_rows_streamed_disk(xd, rs, re, disk_gram_block(gram_block, n), threads)?
        }
    };
    s_rows.scale(1.0 / n.max(1) as f64);

    let mut diag = vec![0.0f64; rows];
    for i in rs..re {
        diag[i - rs] = s_rows.get(i - rs, i);
    }

    // The refinement reuse: one scan of the gram rows keeps every edge
    // that could pass *any* level (the threshold graphs are nested, so
    // the loosest threshold's edge set contains them all). Replaying
    // the (i, j)-ascending list per level performs exactly the union
    // sequence a standalone scan at that threshold performs — NaN
    // thresholds pass no edges either way (`min` ignores NaN, and
    // `a > NaN` is false).
    let min_thr = thresholds.iter().copied().fold(f64::INFINITY, f64::min);
    let mut edges: Vec<(usize, usize, f64)> = Vec::new();
    for i in rs..re {
        for j in 0..p {
            if j != i {
                let a = s_rows.get(i - rs, j).abs();
                if a > min_thr {
                    edges.push((i, j, a));
                }
            }
        }
    }

    // Per-level union-find over my rows' thresholded edges. A labeling
    // is fully described by the pairs (i, find(i)); the join of all
    // ranks' labelings is the connectivity of the union of their edge
    // sets — i.e. the global components of that level.
    let mut local: Vec<f64> = Vec::with_capacity(t_levels * p);
    let mut degrees = vec![0.0f64; t_levels * rows];
    for (k, &thr) in thresholds.iter().enumerate() {
        let mut uf = UnionFind::new(p);
        for &(i, j, a) in &edges {
            if a > thr {
                degrees[k * rows + (i - rs)] += 1.0;
                uf.union(i, j);
            }
        }
        local.extend((0..p).map(|i| uf.find(i) as f64));
    }

    // One metered collective carries every level's labeling: messages
    // are paid once for the whole λ₁ list, words scale with the list.
    let team: Vec<usize> = (0..comm.size()).collect();
    let all = comm.allgather(&team, 1, local);
    let mut merged: Vec<f64> = Vec::with_capacity(t_levels * p);
    for k in 0..t_levels {
        let mut uf = UnionFind::new(p);
        for labels in &all {
            for (i, &r) in labels[k * p..(k + 1) * p].iter().enumerate() {
                uf.union(i, r as usize);
            }
        }
        merged.extend((0..p).map(|i| uf.find(i) as f64));
    }
    Ok((merged, degrees, diag))
}

/// The shared panel kernel every streamed gram path accumulates
/// through: add `panelᵀ[:, rs..rs+rows] · panel` into `out` (the
/// rank's gram rows, partitioned across the worker `ranges`). Each
/// output element is written by exactly one worker and receives its
/// `x[k][rs+r] · x[k][j]` terms in ascending-k order within the panel;
/// callers feed panels in ascending order and storing/loading the f64
/// partial between panels is exact — so the in-core streamed and
/// on-disk grams are bit-identical to the `transpose + matmul_mt` path
/// at every `(block, threads)` and on either backend (determinism
/// rules 1, 7 and 8).
fn gram_panel_accumulate(
    out: &mut [f64],
    panel: &[f64],
    rs: usize,
    p: usize,
    ranges: &[(usize, usize)],
) {
    let panel_rows = panel.len() / p;
    par_rows_mut(out, p, ranges, |_, r0, r1, chunk| {
        for r in r0..r1 {
            let acc = &mut chunk[(r - r0) * p..(r - r0 + 1) * p];
            for k in 0..panel_rows {
                let row = &panel[k * p..(k + 1) * p];
                let xa = row[rs + r];
                for (o, &xb) in acc.iter_mut().zip(row) {
                    *o += xa * xb;
                }
            }
        }
    });
}

/// Row-panel streamed gram rows: `S_rows = (X[:, rs..re])ᵀ · X`,
/// accumulated over ascending panels of `block` sample rows through
/// [`gram_panel_accumulate`]. Unlike the in-core `matmul_mt` path no
/// `rows × n` transposed slab is materialized: one `block`-row panel
/// of X is the entire X working set (rule 7: a schedule-only knob).
fn gram_rows_streamed(x: &Mat, rs: usize, re: usize, block: usize, threads: usize) -> Mat {
    let n = x.rows();
    let p = x.cols();
    let rows = re - rs;
    let mut s_rows = Mat::zeros(rows, p);
    let ranges = chunk_ranges(rows, threads.max(1), 1);
    let step = block.max(1);
    let mut k0 = 0usize;
    while k0 < n {
        let k1 = (k0 + step).min(n);
        gram_panel_accumulate(s_rows.data_mut(), &x.data()[k0 * p..k1 * p], rs, p, &ranges);
        k0 = k1;
    }
    s_rows
}

/// [`gram_rows_streamed`] reading its panels from an HPCX file: the
/// same ascending-panel walk over [`gram_panel_accumulate`], with each
/// panel read into one reused buffer — the X working set is one
/// `block × p` panel however large n is. Bit-identical to the in-core
/// paths (rule 8): the read is pure data movement into the same
/// kernel.
fn gram_rows_streamed_disk(
    xd: &XDisk,
    rs: usize,
    re: usize,
    block: usize,
    threads: usize,
) -> Result<Mat> {
    let n = xd.rows();
    let p = xd.cols();
    let rows = re - rs;
    let mut s_rows = Mat::zeros(rows, p);
    let ranges = chunk_ranges(rows, threads.max(1), 1);
    let step = block.max(1);
    let mut f = xd.open_file()?;
    let mut buf = vec![0.0f64; step.min(n.max(1)) * p];
    let mut k0 = 0usize;
    while k0 < n {
        let k1 = (k0 + step).min(n);
        let panel = &mut buf[..(k1 - k0) * p];
        xd.read_rows_into(&mut f, k0, k1, panel)?;
        gram_panel_accumulate(s_rows.data_mut(), panel, rs, p, &ranges);
        k0 = k1;
    }
    Ok(s_rows)
}

/// Resolve the global concurrent rank budget: `cfg.ranks_budget`, with
/// `0` ("auto") meaning the fabric's own rank count — out of the box a
/// wave may run several planned fabrics at once but never widens any
/// single one.
pub(crate) fn resolve_budget(cfg: &ConcordConfig, opts: &ScreenedDistOptions) -> usize {
    if cfg.ranks_budget == 0 {
        opts.total_ranks
    } else {
        cfg.ranks_budget
    }
}

/// A pinned fabric must satisfy the same runnability constraints the
/// scheduler enforces, and must fit the budget (shrinking would
/// silently violate the pin); catch both here as clean errors instead
/// of a RepGrid panic inside a spawned rank thread.
pub(crate) fn validate_pin(
    opts: &ScreenedDistOptions,
    variant: super::Variant,
    budget: usize,
) -> Result<()> {
    if let Some((ranks, c_x, c_omega)) = opts.fixed {
        if !runnable_on_fabric(ranks, c_x, c_omega, variant) {
            bail!(
                "pinned fabric P={ranks} c_X={c_x} c_Ω={c_omega} is not runnable \
                 for {variant:?} (power-of-two replication with c_X·c_Ω ≤ P required)"
            );
        }
        if ranks > budget {
            bail!(
                "pinned fabric P={ranks} exceeds the concurrent rank budget {budget} \
                 (raise --ranks-budget or drop the --cx/--comega pin)"
            );
        }
    }
    Ok(())
}

/// Plan every non-singleton component of one job's screening level as a
/// job-tagged executor task. A pure function of the level and config —
/// a grid point planned inside a packed sweep gets exactly the plans a
/// standalone [`fit_screened_distributed`] would give it. Each task
/// carries its [`MemFootprint`] (`n·|c|` sub-matrix + `|c|²` working
/// set) for the packer's memory budget.
pub fn plan_job_tasks(
    job: usize,
    level: &ScreenLevel,
    n: usize,
    cfg: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Vec<ExecutorTask> {
    let comps = &level.components;
    let threads = cfg.threads.max(1);
    let mut tasks = Vec::new();
    for c in 0..comps.count {
        let idx = comps.members(c);
        if idx.len() == 1 {
            continue;
        }
        // d estimated from the screened graph's mean degree: its
        // support contains the estimate's (exact thresholding).
        let deg_sum: f64 = idx.iter().map(|&i| level.degrees[i]).sum();
        let d_est = 1.0 + deg_sum / idx.len() as f64;
        let shape = ProblemShape {
            p: idx.len() as f64,
            n: n as f64,
            s: 40.0,
            t: 10.0,
            d: d_est.min(idx.len() as f64),
        };
        let plan = if idx.len() <= opts.small_cutoff {
            FabricPlan::single_node(cfg.variant)
        } else if let Some((ranks, c_x, c_omega)) = opts.fixed {
            if ranks <= idx.len() {
                FabricPlan { ranks, c_x, c_omega, variant: cfg.variant, modeled_time: 0.0 }
            } else {
                // A pinned fabric wider than the component would leave
                // teams empty; degrade to the single-node path.
                FabricPlan::single_node(cfg.variant)
            }
        } else {
            plan_component(&shape, opts.total_ranks, threads, &opts.machine, cfg.variant)
        };
        tasks.push(ExecutorTask {
            tag: JobTag { job, component: c },
            indices: idx.to_vec(),
            plan,
            shape,
            mem: MemFootprint::for_component(n, idx.len()),
        });
    }
    tasks
}

/// Reassemble one job's block-diagonal estimate from its task outcomes.
/// `outcomes` must hold the job's non-singleton components in component
/// order (as [`plan_job_tasks`] submits them); singletons use the
/// closed form on `diag`. Accumulation runs in component order whatever
/// the launch order was, so float sums (objective, trial counts) are a
/// function of the decomposition only — never of the schedule.
pub fn reassemble_job(
    comps: &Components,
    diag: &[f64],
    lambda2: f64,
    outcomes: Vec<TaskOutcome>,
) -> (ScreenedFit, Vec<ComponentSolve>) {
    let p = comps.comp.len();
    let mut acc = ScreenAccum::new(p);
    let mut solves = Vec::with_capacity(outcomes.len());
    let mut outs = outcomes.into_iter();
    for c in 0..comps.count {
        let idx = comps.members(c);
        if idx.len() == 1 {
            acc.add_singleton(idx[0], diag[idx[0]], lambda2);
            continue;
        }
        let out = outs.next().expect("one outcome per non-singleton component");
        debug_assert_eq!(out.tag.component, c, "outcomes must arrive in component order");
        acc.add_component(idx, &out.fit);
        solves.push(ComponentSolve {
            indices: out.indices,
            plan: out.plan,
            cost: out.cost,
            counters: out.counters,
            wave: out.wave,
        });
    }
    assert!(outs.next().is_none(), "surplus outcomes for this job");
    (acc.finish(comps.count, comps.largest()), solves)
}

/// Serial fold of one job's metered fabric solves — the per-job billing
/// view the grid coordinators record in `GridBill::per_job`.
pub(crate) fn solves_view(solves: &[ComponentSolve]) -> CostSummary {
    let mut view = CostSummary::default();
    for sv in solves {
        view.merge_sequential(&sv.cost);
    }
    view
}

/// The resolved knobs every executor client starts from.
pub(crate) struct BatchSetup {
    pub budget: usize,
    pub threads: usize,
    /// Screening fabric width (clamped so no rank owns zero rows).
    pub screen_ranks: usize,
}

/// Shared solver prologue: install the blocking shape **before any
/// planning** (the scheduler's Lemma 3.5 pricing reads the installed
/// tile's cache-reuse term, so plans must see this batch's tile — not
/// whatever a previous fit left behind), resolve the concurrent rank
/// budget, and validate a pinned fabric. The standalone fit and the
/// grid coordinators all run exactly this, so their planning is
/// identical by construction.
pub(crate) fn batch_setup(
    p: usize,
    cfg: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Result<BatchSetup> {
    assert!(opts.total_ranks >= 1, "need at least one rank");
    crate::linalg::tile::install(cfg.tile);
    crate::linalg::simd::install(cfg.kernel);
    crate::util::pool::set_pin_cores(cfg.pin_cores);
    let budget = resolve_budget(cfg, opts);
    validate_pin(opts, cfg.variant, budget)?;
    Ok(BatchSetup {
        budget,
        threads: cfg.threads.max(1),
        screen_ranks: opts.total_ranks.min(p.max(1)),
    })
}

/// Deprecated `&Mat` shim for [`fit_screened_distributed`] — kept one
/// release for out-of-tree callers of the pre-`XSource` signature.
#[deprecated(since = "0.2.0", note = "use fit_screened_distributed(XSource::InCore(x), ..)")]
pub fn fit_screened_distributed_mat(
    x: &Mat,
    cfg: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Result<ScreenedDistFit> {
    fit_screened_distributed(XSource::InCore(x), cfg, opts)
}

/// Deprecated alias from when the `XSource` entry point was the `_src`
/// twin of a `&Mat` wrapper; [`fit_screened_distributed`] *is* that
/// function now.
#[deprecated(since = "0.2.0", note = "renamed to fit_screened_distributed")]
pub fn fit_screened_distributed_src(
    x: XSource<'_>,
    cfg: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Result<ScreenedDistFit> {
    fit_screened_distributed(x, cfg, opts)
}

/// Fit with screening on the distributed path: screen on a fabric, give
/// every non-trivial component a cost-model-sized fabric plan, and hand
/// the job-tagged tasks to the [`FabricExecutor`] — waves of fabrics
/// under the global rank budget, reassembled into the global
/// block-diagonal estimate with the schedule's critical-path bill.
/// Small components solve single-node; singletons use the closed form.
/// This is the executor's thin single-job client; the grid
/// coordinators submit many jobs into one shared schedule the same way.
/// Takes either X backend ([`XSource::InCore`] or the CLI's `--x-file`
/// via [`XSource::OnDisk`]); determinism rule 8 makes the backend a
/// schedule-only knob, so the estimate, objective and every metered
/// counter are bit-for-bit identical across backends — only the modeled
/// source residency (`x_panel_words`, and `peak_mem_words` of the
/// screening pass) moves. `rust/tests/out_of_core.rs` is the wall.
pub fn fit_screened_distributed(
    x: XSource<'_>,
    cfg: &ConcordConfig,
    opts: &ScreenedDistOptions,
) -> Result<ScreenedDistFit> {
    let p = x.cols();
    let setup = batch_setup(p, cfg, opts)?;
    let mut pass = screen_streamed_src(
        x,
        std::slice::from_ref(&cfg.lambda1),
        setup.screen_ranks,
        opts.machine,
        setup.threads,
        opts.gram_block,
    )?;
    let level = pass.levels.pop().expect("one threshold, one level");

    let tasks = plan_job_tasks(0, &level, x.rows(), cfg, opts);
    let executor = FabricExecutor {
        budget: setup.budget,
        mem_budget: cfg.mem_budget,
        threads: setup.threads,
        machine: opts.machine,
        sequential: opts.sequential,
    };
    let run = executor.run(&[ExecutorJob { x, cfg: *cfg, rows: None }], tasks)?;

    let components = level.components.count;
    let (screened, solves) =
        reassemble_job(&level.components, &pass.diag, cfg.lambda2, run.outcomes);
    let mut cost = pass.cost;
    cost.merge_sequential(&run.cost);
    Ok(ScreenedDistFit {
        fit: screened.fit,
        cost,
        screen_cost: pass.cost,
        solve_cost: run.cost,
        schedule: run.schedule,
        components,
        largest: screened.largest,
        solves,
        per_component: screened.per_component,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::screening::gram_components;
    use crate::gen;
    use crate::rng::Rng;
    use crate::runtime::native;

    /// The distributed screening pass must agree with the single-node
    /// component decomposition at every rank count.
    #[test]
    fn distributed_screening_matches_serial_components() {
        let mut rng = Rng::new(11);
        let prob = gen::chain_problem(18, 60, &mut rng);
        let s = native::gram(&prob.x);
        for threshold in [0.05, 0.2, 0.5, 2.0] {
            let want = gram_components(&s, threshold);
            for ranks in [1usize, 2, 3, 4, 8] {
                let pass = screen_distributed(
                    &prob.x,
                    threshold,
                    ranks,
                    MachineParams::default(),
                    1,
                );
                assert_eq!(
                    pass.components, want,
                    "threshold {threshold} ranks {ranks} disagree"
                );
            }
        }
    }

    /// Degrees and diagonal come back in global index order whatever
    /// the rank count; singletons use s_ii exactly.
    #[test]
    fn screening_pass_diag_and_degrees_are_rank_count_invariant() {
        let mut rng = Rng::new(12);
        let prob = gen::chain_problem(10, 50, &mut rng);
        let one = screen_distributed(&prob.x, 0.2, 1, MachineParams::default(), 1);
        let four = screen_distributed(&prob.x, 0.2, 4, MachineParams::default(), 2);
        assert_eq!(one.diag, four.diag);
        assert_eq!(one.degrees, four.degrees);
    }

    /// The amortized multi-threshold pass is level-for-level identical
    /// to standalone single-threshold passes — components, degrees,
    /// diag — while the gram is billed exactly once for the whole list.
    #[test]
    fn multi_threshold_pass_matches_per_threshold_passes() {
        let mut rng = Rng::new(15);
        let prob = gen::chain_problem(14, 60, &mut rng);
        let thresholds = [0.4, 0.1, 0.25, 0.1]; // unsorted, with a dupe
        for ranks in [1usize, 3, 4] {
            let multi = screen_distributed_multi(
                &prob.x,
                &thresholds,
                ranks,
                MachineParams::default(),
                2,
            );
            assert_eq!(multi.levels.len(), thresholds.len());
            let mut single_gram_flops = 0;
            for (k, &thr) in thresholds.iter().enumerate() {
                let single =
                    screen_distributed(&prob.x, thr, ranks, MachineParams::default(), 2);
                assert_eq!(
                    multi.levels[k].components, single.components,
                    "ranks {ranks} level {k}"
                );
                assert_eq!(multi.levels[k].degrees, single.degrees, "ranks {ranks} level {k}");
                assert_eq!(multi.diag, single.diag, "ranks {ranks}");
                single_gram_flops = single.cost.total.flops_dense;
            }
            // One gram for four levels: dense flops equal a single
            // pass's, not four of them.
            assert_eq!(multi.cost.total.flops_dense, single_gram_flops, "ranks {ranks}");
            // One collective: the multi pass sends no more messages
            // than a single-threshold pass.
            assert_eq!(
                multi.cost.total.messages,
                screen_distributed(&prob.x, 0.1, ranks, MachineParams::default(), 2)
                    .cost
                    .total
                    .messages,
                "ranks {ranks}"
            );
        }
    }

    /// A rank budget larger than p is clamped rather than spawning
    /// empty-row ranks.
    #[test]
    fn tiny_problem_clamps_rank_budget() {
        let mut rng = Rng::new(13);
        let prob = gen::chain_problem(3, 30, &mut rng);
        let cfg = ConcordConfig { lambda1: 0.3, max_iter: 30, ..Default::default() };
        let opts = ScreenedDistOptions { total_ranks: 16, ..Default::default() };
        let out = fit_screened_distributed(XSource::InCore(&prob.x), &cfg, &opts).unwrap();
        assert_eq!(out.fit.omega.rows(), 3);
        assert!(out.components >= 1);
    }

    /// All-singleton decomposition: closed forms only, no solves, and
    /// the omega diagonal matches 1/√(s_ii + λ₂).
    #[test]
    fn all_singletons_use_closed_form() {
        let mut rng = Rng::new(14);
        let prob = gen::chain_problem(8, 40, &mut rng);
        let cfg = ConcordConfig { lambda1: 50.0, lambda2: 0.25, ..Default::default() };
        let opts = ScreenedDistOptions::default();
        let out = fit_screened_distributed(XSource::InCore(&prob.x), &cfg, &opts).unwrap();
        assert_eq!(out.components, 8);
        assert_eq!(out.largest, 1);
        assert!(out.solves.is_empty());
        let s = native::gram(&prob.x);
        for i in 0..8 {
            let want = 1.0 / (s.get(i, i) + 0.25).sqrt();
            assert!((out.fit.omega.get(i, i) - want).abs() < 1e-12, "diag {i}");
        }
    }
}
