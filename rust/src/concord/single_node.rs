//! Single-node (shared-memory) HP-CONCORD — the setting of the BigQUIC
//! head-to-head (paper Figure 4, "Obs-1"/"Cov-1" curves).
//!
//! Runs Algorithm 1 with the fused native kernels ([`crate::runtime::native`])
//! at any problem size; [`fit_single_node_with_engine`] routes the fused
//! line-search trial through the AOT-compiled JAX/Pallas artifact when
//! one matches the problem size, keeping Python off the request path
//! while exercising the L1/L2 layers end to end.

use anyhow::Result;

use crate::linalg::Mat;
use crate::runtime::{native, Engine};

use super::{ConcordConfig, ConcordFit, SolveStats};

/// Fit CONCORD/PseudoNet on one node with the native kernels.
///
/// `x` is the n×p observation matrix; the returned
/// [`ConcordFit`](super::ConcordFit) carries the symmetric, exactly
/// sparse estimate Ω̂ plus the solver statistics the cost model needs
/// (s, t̄, d̄):
///
/// ```
/// use hpconcord::concord::{fit_single_node, ConcordConfig};
/// use hpconcord::prelude::*;
///
/// let mut rng = Rng::new(42);
/// let problem = gen::chain_problem(32, 120, &mut rng);
/// let cfg = ConcordConfig { lambda1: 0.3, ..Default::default() };
/// let fit = fit_single_node(&problem.x, &cfg).unwrap();
/// assert_eq!(fit.omega.shape(), (32, 32));
/// assert!(fit.omega.nnz() < 32 * 32); // ℓ₁ made it exactly sparse
/// assert!(fit.iterations >= 1 && fit.mean_row_nnz > 0.0);
/// ```
pub fn fit_single_node(x: &Mat, cfg: &ConcordConfig) -> Result<ConcordFit> {
    fit_impl(x, cfg, None)
}

/// Fit with the PJRT engine when it has a `trial_p{p}` artifact for this
/// size; silently falls back to the native kernels otherwise.
pub fn fit_single_node_with_engine(
    x: &Mat,
    cfg: &ConcordConfig,
    engine: &mut Engine,
) -> Result<ConcordFit> {
    fit_impl(x, cfg, Some(engine))
}

fn fit_impl(x: &Mat, cfg: &ConcordConfig, mut engine: Option<&mut Engine>) -> Result<ConcordFit> {
    crate::linalg::tile::install(cfg.tile);
    crate::linalg::simd::install(cfg.kernel);
    crate::util::pool::set_pin_cores(cfg.pin_cores);
    let p = x.cols();
    let use_engine = engine.as_ref().map(|e| e.has_trial(p)).unwrap_or(false);
    let threads = cfg.threads.max(1);

    let s = native::gram_mt(x, threads);
    let mut omega = Mat::eye(p);
    let mut w = native::w_step_mt(&omega, &s, threads);
    let mut stats = SolveStats::default();
    let mut converged = false;
    let mut g_final = f64::INFINITY;

    for _it in 0..cfg.max_iter {
        stats.iters += 1;
        let (grad, g_prev) = native::gradobj_mt(&omega, &w, cfg.lambda2, threads);

        let mut tau = 1.0;
        let mut last: Option<native::Trial> = None;
        for _ls in 0..cfg.max_linesearch {
            stats.trials += 1;
            let t = if use_engine {
                let e = engine.as_deref_mut().expect("engine");
                let out =
                    e.trial(&omega, &grad, &s, g_prev, tau, cfg.lambda1, cfg.lambda2)?;
                native::Trial {
                    omega_new: out.omega_new,
                    w_new: out.w_new,
                    g_new: out.g_new,
                    rhs: out.rhs,
                    accept: out.accept,
                }
            } else {
                native::trial_mt(
                    &omega, &grad, &s, g_prev, tau, cfg.lambda1, cfg.lambda2, threads,
                )
            };
            let ok = t.accept;
            last = Some(t);
            if ok {
                break;
            }
            tau *= 0.5;
        }
        let t = last.expect("at least one trial");
        let delta = omega.max_abs_diff(&t.omega_new);
        omega = t.omega_new;
        w = t.w_new;
        g_final = t.g_new;
        stats.nnz_samples += p as u64;
        stats.nnz_total += omega.nnz() as u64;

        if delta < cfg.tol {
            converged = true;
            break;
        }
    }

    Ok(ConcordFit {
        omega,
        iterations: stats.iters,
        mean_linesearch: stats.mean_linesearch(),
        mean_row_nnz: stats.mean_row_nnz(),
        objective: g_final,
        converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::rng::Rng;

    /// S = I (orthonormalized columns): optimum is diagonal with entries
    /// 1/sqrt(1+λ₂) — same closed form as the python test-suite pins.
    #[test]
    fn identity_covariance_closed_form() {
        let p = 6;
        let n = 64;
        let mut rng = Rng::new(0);
        // Gram-Schmidt to unit columns, then scale by sqrt(n) so that
        // Xᵀ X / n = I exactly.
        let mut cols: Vec<Vec<f64>> = (0..p).map(|_| rng.normal_vec(n)).collect();
        for j in 0..p {
            for k in 0..j {
                let d: f64 = (0..n).map(|i| cols[j][i] * cols[k][i]).sum();
                for i in 0..n {
                    cols[j][i] -= d * cols[k][i];
                }
            }
            let nrm: f64 = cols[j].iter().map(|v| v * v).sum::<f64>().sqrt();
            for v in cols[j].iter_mut() {
                *v /= nrm;
            }
        }
        let x = Mat::from_fn(n, p, |i, j| cols[j][i] * (n as f64).sqrt());
        // tol: near the optimum the sufficient-decrease test goes
        // numerically blind (objective differences ~e^2 drop below the
        // f64 ulp of g), so max|dOmega| floors around ~1e-8; 1e-7 is the
        // tightest honest tolerance here.
        let cfg = ConcordConfig {
            lambda1: 2.0,
            lambda2: 0.5,
            tol: 1e-7,
            variant: Variant::Cov,
            ..Default::default()
        };
        let fit = fit_single_node(&x, &cfg).unwrap();
        assert!(fit.converged);
        let want = (1.0f64 / 1.5).sqrt();
        for i in 0..p {
            assert!(
                (fit.omega.get(i, i) - want).abs() < 1e-6,
                "diag {i}: got {} want {want} (iters {}, converged {})",
                fit.omega.get(i, i),
                fit.iterations,
                fit.converged
            );
            for j in 0..p {
                if i != j {
                    assert_eq!(fit.omega.get(i, j), 0.0, "offdiag ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn objective_decreases_monotonically_via_linesearch() {
        // Run two fits with different iteration caps: more iterations
        // must not increase the objective.
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(40, 12, |_, _| rng.normal());
        let base =
            ConcordConfig { lambda1: 0.2, tol: 0.0, variant: Variant::Cov, ..Default::default() };
        let short = ConcordConfig { max_iter: 3, ..base };
        let long = ConcordConfig { max_iter: 30, ..base };
        let f1 = fit_single_node(&x, &short).unwrap();
        let f2 = fit_single_node(&x, &long).unwrap();
        assert!(f2.objective <= f1.objective + 1e-12);
    }

    #[test]
    fn estimate_is_symmetric() {
        let mut rng = Rng::new(6);
        let x = Mat::from_fn(50, 10, |_, _| rng.normal());
        let cfg = ConcordConfig { lambda1: 0.3, tol: 1e-7, ..Default::default() };
        let fit = fit_single_node(&x, &cfg).unwrap();
        let omega_t = fit.omega.transpose();
        assert!(fit.omega.max_abs_diff(&omega_t) < 1e-9);
    }

    #[test]
    fn larger_lambda1_gives_sparser_estimate() {
        let mut rng = Rng::new(7);
        let x = Mat::from_fn(60, 15, |_, _| rng.normal());
        let mk = |l1| ConcordConfig { lambda1: l1, tol: 1e-6, ..Default::default() };
        let sparse = fit_single_node(&x, &mk(0.8)).unwrap();
        let dense = fit_single_node(&x, &mk(0.05)).unwrap();
        assert!(sparse.omega.nnz() < dense.omega.nnz());
    }

    #[test]
    fn huge_lambda1_gives_diagonal() {
        let mut rng = Rng::new(8);
        let x = Mat::from_fn(30, 8, |_, _| rng.normal());
        let cfg = ConcordConfig { lambda1: 50.0, tol: 1e-8, ..Default::default() };
        let fit = fit_single_node(&x, &cfg).unwrap();
        for i in 0..8 {
            for j in 0..8 {
                if i != j {
                    assert_eq!(fit.omega.get(i, j), 0.0);
                }
            }
        }
    }
}
