//! The wire protocol: line-delimited JSON, hand-rolled in the style of
//! [`crate::util::bench_record`] (std only — no serde).
//!
//! Every request and every response is **one** JSON object on **one**
//! line. The value model is the minimal JSON subset the service needs
//! ([`Json`]): null, booleans, f64 numbers, strings, arrays, objects.
//! Encoding is compact (the line protocol forbids raw newlines) with
//! the same string-escaping conventions as the bench recorder; numbers
//! ride Rust's shortest-round-trip f64 formatting, so a value parsed
//! back from its own encoding is bit-identical. Values outside f64's
//! exact integer range (the dataset fingerprint) travel as hex strings,
//! never as numbers.

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value (the protocol's value model).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match; the protocol never repeats
    /// keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Field as f64 with a default when absent; a present field of the
    /// wrong type is a clean error, not a silent default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(|| anyhow!("field {key:?} must be a number")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        let v = self.f64_or(key, default as f64)?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("field {key:?} must be a non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        let v = self.f64_or(key, default as f64)?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("field {key:?} must be a non-negative integer, got {v}");
        }
        Ok(v as u64)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or_else(|| anyhow!("field {key:?} must be a boolean")),
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(String::from)
                .ok_or_else(|| anyhow!("field {key:?} must be a string")),
        }
    }

    /// Field as a list of f64 with a default when absent.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => {
                let items =
                    v.as_arr().ok_or_else(|| anyhow!("field {key:?} must be an array"))?;
                items
                    .iter()
                    .map(|it| {
                        it.as_f64()
                            .ok_or_else(|| anyhow!("field {key:?} must hold numbers only"))
                    })
                    .collect()
            }
        }
    }

    /// Compact single-line encoding (the line protocol's frame body).
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => out.push_str(&json_num(*v)),
            Json::Str(s) => out.push_str(&json_str(s)),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&json_str(k));
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON value from `text` (the whole line must be the
    /// value — trailing garbage is a clean error, exactly what a framed
    /// line protocol wants).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing bytes after the JSON value at offset {pos}");
        }
        Ok(value)
    }
}

/// Escape a string the same way the bench recorder does: `"`, `\`,
/// newline, tab, carriage return, and all other control bytes as
/// `\u00XX`.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Numbers use Rust's shortest-round-trip f64 formatting; JSON has no
/// non-finite literals, so those degrade to null (the reader treats a
/// null bill field as absent).
fn json_num(v: f64) -> String {
    // `{}` is shortest-round-trip and omits a trailing `.0` for
    // integral values — fine for JSON, which does not distinguish 1
    // from 1.0.
    if v.is_finite() { format!("{v}") } else { "null".to_string() }
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\r' | b'\n') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, want: u8) -> Result<()> {
    if *pos < bytes.len() && bytes[*pos] == want {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected {:?} at offset {}", want as char, *pos);
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected ',' or ']' in array at offset {}", *pos),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, b':')?;
                let value = parse_value(bytes, pos)?;
                fields.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => bail!("expected ',' or '}}' in object at offset {}", *pos),
                }
            }
        }
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("bad literal at offset {}", *pos);
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).expect("ascii number bytes");
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| anyhow!("bad number {text:?} at offset {start}"))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                        let hex = std::str::from_utf8(hex)
                            .map_err(|_| anyhow!("non-ascii \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| anyhow!("bad \\u escape {hex:?}"))?;
                        // The protocol only ever emits BMP escapes
                        // (control bytes); surrogates are a clean error.
                        let c = char::from_u32(code)
                            .ok_or_else(|| anyhow!("\\u{hex} is not a scalar value"))?;
                        out.push(c);
                        *pos += 4;
                    }
                    _ => bail!("bad escape at offset {}", *pos),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (multi-byte sequences pass
                // through unmodified — the input is a &str).
                let rest = std::str::from_utf8(&bytes[*pos..]).expect("valid utf8 tail");
                let c = rest.chars().next().expect("non-empty tail");
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

/// Shorthand for building response/request objects.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// The uniform error frame: `{"ok":false,"error":...}`.
pub fn error_frame(message: &str) -> Json {
    obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(message.to_string()))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_value_shape() {
        let v = obj(vec![
            ("null", Json::Null),
            ("flag", Json::Bool(true)),
            ("num", Json::Num(-12.5e-3)),
            ("text", Json::Str("line\nbreak \"quoted\" \\ tab\t".to_string())),
            ("arr", Json::Arr(vec![Json::Num(1.0), Json::Bool(false), Json::Str("x".into())])),
            ("nested", obj(vec![("k", Json::Num(3.0))])),
        ]);
        let encoded = v.encode();
        assert!(!encoded.contains('\n'), "frames must be single lines: {encoded:?}");
        assert_eq!(Json::parse(&encoded).unwrap(), v);
    }

    #[test]
    fn f64_round_trip_is_bit_exact() {
        for v in [0.45, 1.0 / 3.0, 6.02214076e23, -0.0, f64::MIN_POSITIVE] {
            let encoded = Json::Num(v).encode();
            let back = Json::parse(&encoded).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v} -> {encoded}");
        }
    }

    #[test]
    fn malformed_frames_are_clean_errors() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "{} trailing", "1e"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn typed_accessors_reject_wrong_types() {
        let v = obj(vec![("s", Json::Str("x".into())), ("n", Json::Num(1.5))]);
        assert!(v.f64_or("s", 0.0).is_err());
        assert!(v.usize_or("n", 0).is_err(), "1.5 is not an integer");
        assert!(v.str_or("n", "").is_err());
        assert_eq!(v.f64_or("absent", 7.0).unwrap(), 7.0);
    }
}
