//! The multi-tenant estimation service: a long-running server that
//! admits solve / sweep / stability jobs over a line-delimited JSON
//! protocol, schedules them through the shared [`FabricExecutor`]
//! under the operator's global rank and memory budgets, and reuses
//! screening artifacts across jobs through a dataset-fingerprint-keyed
//! cache.
//!
//! Layering (std only — `TcpListener` plus the hand-rolled JSON of
//! [`protocol`], in the style of `util::bench_record`):
//!
//! - [`protocol`] — the wire format: one JSON frame per line, a
//!   minimal value model with bit-exact f64 round-trips.
//! - [`cache`] — the screening-artifact cache, keyed on
//!   ([`crate::io::x_fingerprint`], λ₁ thresholds, fabric knobs).
//! - [`server`] — the admission queue, the scheduler that drains it
//!   into rolling executor cycles, and the [`Client`] half the CLI's
//!   `client` subcommand and the CI smoke drive.
//!
//! **Determinism rule 9**: the service is a *schedule-only* layer.
//! Admission order, cross-tenant wave packing, global budget
//! overrides, and cache hits change when work runs and what the bill
//! says — never a result bit. A served omega is byte-for-byte the
//! `--out-omega` file of the equivalent CLI invocation
//! (`rust/tests/service.rs` pins this).
//!
//! [`FabricExecutor`]: crate::concord::FabricExecutor
//! [`Client`]: server::Client

pub mod cache;
pub mod protocol;
pub mod server;

pub use cache::{ScreenCache, ScreenKey};
pub use protocol::Json;
pub use server::{
    omega_text, request_from_frame, request_to_frame, Client, ServeOptions, Server,
};
