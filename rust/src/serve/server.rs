//! The estimation server: admission queue, scheduler, and the TCP
//! front door speaking the line protocol of [`super::protocol`].
//!
//! One listener thread accepts connections; each connection gets a
//! reader thread that parses one frame per line and replies with one
//! frame per line. `submit` frames become queued jobs; a single
//! scheduler thread drains the queue in admission order, packing every
//! queued **solve** into one shared [`FabricExecutor`] run per cycle
//! (waves may mix fabrics from different tenants) and running sweeps
//! and stability selections through the same canonical entry points
//! the CLI uses. Screening artifacts are reused across jobs through
//! the fingerprint-keyed [`ScreenCache`].
//!
//! **Determinism rule 9**: the service is a schedule-only layer. Every
//! job's estimate is produced by the same screening pass (cached or
//! fresh — bit-identical either way), the same per-component plans,
//! and the same executor math as the equivalent CLI invocation, so a
//! served omega is byte-for-byte the CLI's `--out-omega` file
//! (`rust/tests/service.rs`). Only bills and wave schedules reflect
//! the multi-tenant packing.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use crate::concord::executor::{split_by_counts, ExecutorJob, FabricExecutor};
use crate::concord::request::parse_variant;
use crate::concord::screened_dist::{
    batch_setup, plan_job_tasks, reassemble_job, solves_view, BatchSetup,
};
use crate::concord::{
    screen_streamed_src, EstimationRequest, MultiScreenPass, RequestKind, RequestOutcome,
    Variant, WorkloadSpec,
};
use crate::coordinator::sweep::sweep_dist_packed_with;
use crate::coordinator::{select_by_density, GridSpec, StabilityConfig};
use crate::io::{format_omega, x_fingerprint, XDisk, XSource};
use crate::linalg::Mat;
use crate::simnet::cost::{CostSummary, GridBill};

use super::cache::{ScreenCache, ScreenKey};
use super::protocol::{error_frame, obj, Json};

/// Server configuration. The global budgets, when nonzero, override
/// every admitted job's own `--ranks-budget`/`--mem-budget`: the
/// operator's capacity wins over tenant requests. Both are
/// schedule-only knobs (rule 7), so overriding them never changes a
/// result bit.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, `host:port`; port 0 binds an ephemeral port
    /// (reported by [`Server::addr`]).
    pub addr: String,
    /// Global concurrent rank budget (0 = honor per-job budgets).
    pub ranks_budget: usize,
    /// Global memory budget in f64 words (0 = honor per-job budgets).
    pub mem_budget: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { addr: "127.0.0.1:0".to_string(), ranks_budget: 0, mem_budget: 0 }
    }
}

/// Job lifecycle, as the `status`/`wait` ops report it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Queued,
    Running,
    Done,
    Failed,
}

fn phase_name(p: Phase) -> &'static str {
    match p {
        Phase::Queued => "queued",
        Phase::Running => "running",
        Phase::Done => "done",
        Phase::Failed => "failed",
    }
}

/// What a finished job hands back over the wire.
struct JobResult {
    /// [`format_omega`] bytes of the job's estimate — the exact bytes
    /// the CLI's `--out-omega` writes (rule 9's contract).
    omega: String,
    bill: GridBill,
    /// Whether the screening pass was a cache hit (`bill.screen` is
    /// then zero: the pass was billed once by the job that computed
    /// it).
    screen_cached: bool,
}

struct Job {
    req: EstimationRequest,
    /// Client-claimed dataset fingerprint (hex over the wire); a
    /// mismatch with the dataset is a clean per-job failure.
    claim: Option<u64>,
    /// Sweep model-selection target density for the returned omega.
    select_density: f64,
    phase: Phase,
    result: Option<JobResult>,
    error: Option<String>,
}

struct State {
    jobs: Vec<Job>,
    queue: VecDeque<usize>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
    cache: ScreenCache,
    opts: ServeOptions,
    addr: SocketAddr,
}

impl Shared {
    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().expect("server state poisoned")
    }
}

/// A running estimation server. Drop-safe: [`Server::join`] blocks
/// until a client's `shutdown` frame (or [`Server::shutdown`]) stops
/// the accept loop and the scheduler.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    sched: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving. The listener, the scheduler and the
    /// per-connection readers are all spawned here; the call returns
    /// as soon as the socket is bound (the bound address is
    /// [`Server::addr`]).
    pub fn start(opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding serve address {:?}", opts.addr))?;
        let addr = listener.local_addr().context("reading the bound serve address")?;
        let shared = Arc::new(Shared {
            state: Mutex::new(State { jobs: Vec::new(), queue: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
            cache: ScreenCache::new(),
            opts,
            addr,
        });
        let sched = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || scheduler(&shared))
        };
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, &shared))
        };
        Ok(Server { addr, shared, accept: Some(accept), sched: Some(sched) })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the server to stop: already-queued jobs finish, new
    /// submissions are refused, and the accept loop unblocks.
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    /// Block until the server has fully stopped (scheduler drained,
    /// accept loop exited).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sched.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.lock().shutdown {
            break;
        }
        let Ok(stream) = stream else { continue };
        let shared = Arc::clone(shared);
        std::thread::spawn(move || handle_conn(stream, &shared));
    }
}

fn handle_conn(stream: TcpStream, shared: &Arc<Shared>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let reader = BufReader::new(read_half);
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply =
            handle_frame(&line, shared).unwrap_or_else(|e| error_frame(&format!("{e:#}")));
        if writeln!(writer, "{}", reply.encode()).is_err() {
            break;
        }
        if shared.lock().shutdown {
            // Unblock the accept loop so the whole server can exit.
            let _ = TcpStream::connect(shared.addr);
            break;
        }
    }
}

/// One frame in, one frame out. Every error becomes a uniform
/// `{"ok":false,"error":...}` reply; the connection survives bad
/// frames (malformed JSON, unknown ops, bad field types).
fn handle_frame(line: &str, shared: &Shared) -> Result<Json> {
    let frame = Json::parse(line)?;
    let op = frame.str_or("op", "")?;
    match op.as_str() {
        "ping" => Ok(obj(vec![("ok", Json::Bool(true)), ("op", Json::Str("pong".into()))])),
        "submit" => submit(&frame, shared),
        "status" => {
            let st = shared.lock();
            let id = job_id(&frame, &st)?;
            Ok(status_frame(&st, id))
        }
        "wait" => {
            let mut st = shared.lock();
            let id = job_id(&frame, &st)?;
            while matches!(st.jobs[id].phase, Phase::Queued | Phase::Running) {
                st = shared.cv.wait(st).expect("server state poisoned");
            }
            Ok(status_frame(&st, id))
        }
        "result" => {
            let st = shared.lock();
            let id = job_id(&frame, &st)?;
            let r = finished(&st, id)?;
            let rows: Vec<Json> =
                r.omega.lines().map(|row| Json::Str(row.to_string())).collect();
            Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("result".into())),
                ("job", Json::Num(id as f64)),
                ("omega", Json::Arr(rows)),
            ]))
        }
        "bill" => {
            let st = shared.lock();
            let id = job_id(&frame, &st)?;
            let r = finished(&st, id)?;
            Ok(bill_frame(id, r))
        }
        "shutdown" => {
            {
                let mut st = shared.lock();
                st.shutdown = true;
            }
            shared.cv.notify_all();
            Ok(obj(vec![("ok", Json::Bool(true)), ("op", Json::Str("shutdown".into()))]))
        }
        other => {
            bail!("unknown op {other:?} (submit|status|wait|result|bill|ping|shutdown)")
        }
    }
}

fn job_id(frame: &Json, st: &State) -> Result<usize> {
    if frame.get("job").is_none() {
        bail!("this op needs a \"job\" field");
    }
    let id = frame.usize_or("job", 0)?;
    if id >= st.jobs.len() {
        bail!("unknown job {id} ({} submitted)", st.jobs.len());
    }
    Ok(id)
}

fn finished<'a>(st: &'a State, id: usize) -> Result<&'a JobResult> {
    match st.jobs[id].phase {
        Phase::Done => Ok(st.jobs[id].result.as_ref().expect("done job has a result")),
        Phase::Failed => {
            let msg = st.jobs[id].error.clone().unwrap_or_else(|| "unknown".to_string());
            bail!("job {id} failed: {msg}")
        }
        other => bail!("job {id} is not done (state {})", phase_name(other)),
    }
}

fn status_frame(st: &State, id: usize) -> Json {
    let job = &st.jobs[id];
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("status".into())),
        ("job", Json::Num(id as f64)),
        ("state", Json::Str(phase_name(job.phase).to_string())),
    ];
    if let Some(err) = &job.error {
        fields.push(("error", Json::Str(err.clone())));
    }
    obj(fields)
}

fn bill_frame(id: usize, r: &JobResult) -> Json {
    let total = r.bill.total();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("bill".into())),
        ("job", Json::Num(id as f64)),
        ("screen_cached", Json::Bool(r.screen_cached)),
        ("screen_time", Json::Num(r.bill.screen.time)),
        ("waves_time", Json::Num(r.bill.waves.time)),
        ("total_time", Json::Num(total.time)),
        ("comm_time", Json::Num(total.comm_time)),
        ("messages", Json::Num(total.total.messages as f64)),
        ("words", Json::Num(total.total.words as f64)),
        ("flops_dense", Json::Num(total.total.flops_dense as f64)),
        ("flops_sparse", Json::Num(total.total.flops_sparse as f64)),
        ("peak_mem_words", Json::Num(total.peak_mem_words as f64)),
    ])
}

fn submit(frame: &Json, shared: &Shared) -> Result<Json> {
    let (req, claim, select_density) = request_from_frame(frame)?;
    let id = {
        let mut st = shared.lock();
        if st.shutdown {
            bail!("server is shutting down");
        }
        let id = st.jobs.len();
        st.jobs.push(Job {
            req,
            claim,
            select_density,
            phase: Phase::Queued,
            result: None,
            error: None,
        });
        st.queue.push_back(id);
        id
    };
    shared.cv.notify_all();
    Ok(obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("submit".into())),
        ("job", Json::Num(id as f64)),
    ]))
}

/// Decode a `submit` frame into a request plus the serve-only fields
/// (fingerprint claim, sweep selection density). Field names mirror
/// the CLI flags with `_` for `-`; absent fields take the same
/// defaults [`EstimationRequest::from_args`] resolves.
pub fn request_from_frame(frame: &Json) -> Result<(EstimationRequest, Option<u64>, f64)> {
    let kind = match frame.str_or("kind", "solve")?.as_str() {
        "solve" => RequestKind::Solve,
        "sweep" => RequestKind::Sweep {
            grid: GridSpec {
                lambda1: frame.f64_list_or("l1", &[0.2, 0.3, 0.45])?,
                lambda2: frame.f64_list_or("l2", &[0.0])?,
            },
            per_point: frame.bool_or("per_point", false)?,
        },
        "stability" => RequestKind::Stability {
            stab: StabilityConfig {
                subsamples: frame.usize_or("subsamples", 8)?,
                fraction: frame.f64_or("fraction", 0.5)?,
                threshold: frame.f64_or("stab_threshold", 0.7)?,
                seed: frame.u64_or("stab_seed", 0)?,
                ..StabilityConfig::default()
            },
        },
        other => bail!("unknown kind {other:?} (solve|sweep|stability)"),
    };
    let mut req = EstimationRequest::new(kind);
    req.cfg.lambda1 = frame.f64_or("lambda1", req.cfg.lambda1)?;
    req.cfg.lambda2 = frame.f64_or("lambda2", req.cfg.lambda2)?;
    req.cfg.tol = frame.f64_or("tol", req.cfg.tol)?;
    req.cfg.max_iter = frame.usize_or("max_iter", req.cfg.max_iter)?;
    req.cfg.max_linesearch = frame.usize_or("max_linesearch", req.cfg.max_linesearch)?;
    req.cfg.variant = parse_variant(&frame.str_or("variant", "auto")?);
    req.cfg.threads = frame.usize_or("threads", 1)?.max(1);
    req.cfg.ranks_budget = frame.usize_or("ranks_budget", 0)?;
    req.cfg.mem_budget = frame.u64_or("mem_budget", 0)?;
    req.opts.total_ranks = frame.usize_or("ranks", req.opts.total_ranks)?;
    req.opts.small_cutoff = frame.usize_or("screen_cutoff", req.opts.small_cutoff)?;
    req.opts.gram_block = frame.usize_or("gram_block", req.opts.gram_block)?;
    if frame.get("cx").is_some() || frame.get("comega").is_some() {
        let c_x = frame.usize_or("cx", 1)?;
        let c_o = frame.usize_or("comega", 1)?;
        req.opts.fixed = Some((req.opts.total_ranks, c_x, c_o));
    }
    if let Some(w) = frame.get("workload") {
        req.workload = WorkloadSpec {
            name: w.str_or("name", &req.workload.name)?,
            p: w.usize_or("p", req.workload.p)?,
            n: w.usize_or("n", req.workload.n)?,
            deg: w.usize_or("deg", req.workload.deg)?,
            seed: w.u64_or("seed", req.workload.seed)?,
        };
    }
    let path = frame.str_or("x_file", "")?;
    req.x_file = if path.is_empty() { None } else { Some(path) };
    let claim = frame.str_or("fingerprint", "")?;
    let claim = if claim.is_empty() {
        None
    } else {
        Some(u64::from_str_radix(&claim, 16).map_err(|_| {
            anyhow!("field \"fingerprint\" must be a hex u64, got {claim:?}")
        })?)
    };
    let density = frame.f64_or("select_density", 0.1)?;
    Ok((req, claim, density))
}

/// Encode a request as the `submit` frame [`request_from_frame`]
/// decodes — the client side of the protocol. Lossless for every
/// field the wire carries (`rust/tests/service.rs` round-trips it).
pub fn request_to_frame(
    req: &EstimationRequest,
    fingerprint: Option<u64>,
    select_density: f64,
) -> Json {
    let num = Json::Num;
    let mut fields: Vec<(&str, Json)> = vec![("op", Json::Str("submit".into()))];
    match &req.kind {
        RequestKind::Solve => fields.push(("kind", Json::Str("solve".into()))),
        RequestKind::Sweep { grid, per_point } => {
            fields.push(("kind", Json::Str("sweep".into())));
            let l1 = grid.lambda1.iter().map(|&v| num(v)).collect();
            let l2 = grid.lambda2.iter().map(|&v| num(v)).collect();
            fields.push(("l1", Json::Arr(l1)));
            fields.push(("l2", Json::Arr(l2)));
            fields.push(("per_point", Json::Bool(*per_point)));
        }
        RequestKind::Stability { stab } => {
            fields.push(("kind", Json::Str("stability".into())));
            fields.push(("subsamples", num(stab.subsamples as f64)));
            fields.push(("fraction", num(stab.fraction)));
            fields.push(("stab_threshold", num(stab.threshold)));
            fields.push(("stab_seed", num(stab.seed as f64)));
        }
    }
    let variant = match req.cfg.variant {
        Variant::Cov => "cov",
        Variant::Obs => "obs",
        Variant::Auto => "auto",
    };
    fields.push(("lambda1", num(req.cfg.lambda1)));
    fields.push(("lambda2", num(req.cfg.lambda2)));
    fields.push(("tol", num(req.cfg.tol)));
    fields.push(("max_iter", num(req.cfg.max_iter as f64)));
    fields.push(("max_linesearch", num(req.cfg.max_linesearch as f64)));
    fields.push(("variant", Json::Str(variant.to_string())));
    fields.push(("threads", num(req.cfg.threads as f64)));
    fields.push(("ranks_budget", num(req.cfg.ranks_budget as f64)));
    fields.push(("mem_budget", num(req.cfg.mem_budget as f64)));
    fields.push(("ranks", num(req.opts.total_ranks as f64)));
    fields.push(("screen_cutoff", num(req.opts.small_cutoff as f64)));
    fields.push(("gram_block", num(req.opts.gram_block as f64)));
    if let Some((_, c_x, c_o)) = req.opts.fixed {
        fields.push(("cx", num(c_x as f64)));
        fields.push(("comega", num(c_o as f64)));
    }
    let w = &req.workload;
    fields.push((
        "workload",
        obj(vec![
            ("name", Json::Str(w.name.clone())),
            ("p", num(w.p as f64)),
            ("n", num(w.n as f64)),
            ("deg", num(w.deg as f64)),
            ("seed", num(w.seed as f64)),
        ]),
    ));
    if let Some(path) = &req.x_file {
        fields.push(("x_file", Json::Str(path.clone())));
    }
    if let Some(fp) = fingerprint {
        fields.push(("fingerprint", Json::Str(format!("{fp:016x}"))));
    }
    fields.push(("select_density", num(select_density)));
    obj(fields)
}

// ---------------------------------------------------------------- //
// Scheduler: admission-ordered cycles over the shared executor.    //
// ---------------------------------------------------------------- //

fn scheduler(shared: &Arc<Shared>) {
    loop {
        let batch: Vec<usize> = {
            let mut st = shared.lock();
            loop {
                if !st.queue.is_empty() {
                    break;
                }
                if st.shutdown {
                    return;
                }
                st = shared.cv.wait(st).expect("server state poisoned");
            }
            let batch: Vec<usize> = st.queue.drain(..).collect();
            for &id in &batch {
                st.jobs[id].phase = Phase::Running;
            }
            batch
        };
        run_cycle(shared, &batch);
    }
}

fn finish_ok(shared: &Shared, id: usize, result: JobResult) {
    {
        let mut st = shared.lock();
        st.jobs[id].phase = Phase::Done;
        st.jobs[id].result = Some(result);
    }
    shared.cv.notify_all();
}

fn finish_err(shared: &Shared, id: usize, err: &anyhow::Error) {
    {
        let mut st = shared.lock();
        st.jobs[id].phase = Phase::Failed;
        st.jobs[id].error = Some(format!("{err:#}"));
    }
    shared.cv.notify_all();
}

/// A job's dataset for one cycle: the generated workload matrix or the
/// opened on-disk file. Held for the cycle's duration so executor jobs
/// can borrow [`XSource`] views of it.
enum Data {
    Mem(Mat),
    Disk(XDisk),
}

impl Data {
    fn source(&self) -> XSource<'_> {
        match self {
            Data::Mem(m) => XSource::InCore(m),
            Data::Disk(d) => XSource::OnDisk(d),
        }
    }
}

/// One admitted job, validated and bound to its dataset.
struct Prep {
    id: usize,
    req: EstimationRequest,
    select_density: f64,
    data: Data,
    fingerprint: u64,
}

/// Resolve a job's dataset and fingerprint, applying the server's
/// global budget overrides. A claimed fingerprint that does not match
/// the dataset is the protocol's "cached artifact does not describe
/// this X" error — caught here, before any screening or solving.
fn prepare(
    opts: &ServeOptions,
    req: &mut EstimationRequest,
    claim: Option<u64>,
) -> Result<(Data, u64)> {
    if opts.ranks_budget > 0 {
        req.cfg.ranks_budget = opts.ranks_budget;
    }
    if opts.mem_budget > 0 {
        req.cfg.mem_budget = opts.mem_budget;
    }
    let data = match &req.x_file {
        Some(path) => Data::Disk(XDisk::open(Path::new(path))?),
        None => Data::Mem(req.workload.generate()?.x),
    };
    let fp = x_fingerprint(data.source())?;
    if let Some(want) = claim {
        if want != fp {
            bail!(
                "dataset fingerprint mismatch: request pins {want:016x} but the dataset \
                 fingerprints to {fp:016x} — cached artifacts for the pinned X do not \
                 describe this one"
            );
        }
    }
    Ok((data, fp))
}

fn run_cycle(shared: &Shared, batch: &[usize]) {
    // Snapshot the batch's requests outside any long-held lock.
    let specs: Vec<(usize, EstimationRequest, Option<u64>, f64)> = {
        let st = shared.lock();
        batch
            .iter()
            .map(|&id| {
                let j = &st.jobs[id];
                (id, j.req.clone(), j.claim, j.select_density)
            })
            .collect()
    };

    let mut preps: Vec<Prep> = Vec::new();
    for (id, mut req, claim, select_density) in specs {
        match prepare(&shared.opts, &mut req, claim) {
            Ok((data, fingerprint)) => {
                preps.push(Prep { id, req, select_density, data, fingerprint });
            }
            Err(e) => finish_err(shared, id, &e),
        }
    }

    // Every queued solve shares one executor run (cross-tenant wave
    // packing); sweeps and stability selections run in admission order
    // through the same canonical pipelines the CLI drives.
    let (solves, others): (Vec<&Prep>, Vec<&Prep>) =
        preps.iter().partition(|p| matches!(p.req.kind, RequestKind::Solve));
    for (id, result) in run_solve_group(shared, &solves) {
        match result {
            Ok(r) => finish_ok(shared, id, r),
            Err(e) => finish_err(shared, id, &e),
        }
    }
    for p in others {
        match run_single(shared, p) {
            Ok(r) => finish_ok(shared, p.id, r),
            Err(e) => finish_err(shared, p.id, &e),
        }
    }
}

/// Get the screening pass for `key`, computing and caching it on a
/// miss. The boolean is `true` on a hit — the caller's bill then
/// carries a zero screening share (the pass was billed once, by the
/// job that computed it).
fn screen_or_reuse(
    shared: &Shared,
    key: ScreenKey,
    x: XSource<'_>,
    thresholds: &[f64],
    setup: &BatchSetup,
    req: &EstimationRequest,
) -> Result<(Arc<MultiScreenPass>, bool)> {
    if let Some(pass) = shared.cache.get(&key) {
        return Ok((pass, true));
    }
    let pass = Arc::new(screen_streamed_src(
        x,
        thresholds,
        setup.screen_ranks,
        req.opts.machine,
        setup.threads,
        req.opts.gram_block,
    )?);
    shared.cache.insert(key, Arc::clone(&pass));
    Ok((pass, false))
}

/// One solve job past its prologue: budgets resolved, screening pass
/// in hand (cached or fresh), ready to plan into the shared run.
struct Ready<'a> {
    p: &'a Prep,
    setup: BatchSetup,
    pass: Arc<MultiScreenPass>,
    cached: bool,
}

/// The standalone solver's prologue for one admitted job: batch setup
/// (tile install, budget resolution, pin validation) and the screening
/// pass, via the cache.
fn solve_prologue<'a>(shared: &Shared, p: &'a Prep) -> Result<Ready<'a>> {
    let x = p.data.source();
    let setup = batch_setup(x.cols(), &p.req.cfg, &p.req.opts)?;
    let thresholds = [p.req.cfg.lambda1];
    let key =
        ScreenKey::new(p.fingerprint, &thresholds, setup.screen_ranks, p.req.opts.gram_block);
    let (pass, cached) = screen_or_reuse(shared, key, x, &thresholds, &setup, &p.req)?;
    Ok(Ready { p, setup, pass, cached })
}

/// All of a cycle's solve jobs through one shared executor run. Each
/// job screens (or reuses) its own pass, plans its components exactly
/// as the standalone solver would, and the flat task list is packed
/// into one cross-tenant wave schedule. Outcomes reassemble per job in
/// submission order — bit-identical to each job's standalone run
/// (rules 6, 7 and 9).
fn run_solve_group<'a>(
    shared: &Shared,
    group: &[&'a Prep],
) -> Vec<(usize, Result<JobResult>)> {
    let mut out: Vec<(usize, Result<JobResult>)> = Vec::new();
    let mut ready: Vec<Ready<'a>> = Vec::new();
    for &p in group {
        match solve_prologue(shared, p) {
            Ok(r) => ready.push(r),
            Err(e) => out.push((p.id, Err(e))),
        }
    }
    if ready.is_empty() {
        return out;
    }

    // Plan each job under its own installed tile (exactly the
    // standalone prologue), tagging tasks with the job's slot in this
    // cycle so the packed outcomes split back per job.
    let mut exec_jobs: Vec<ExecutorJob<'_>> = Vec::with_capacity(ready.len());
    let mut tasks = Vec::new();
    let mut counts = Vec::with_capacity(ready.len());
    for (slot, r) in ready.iter().enumerate() {
        crate::linalg::tile::install(r.p.req.cfg.tile);
        crate::linalg::simd::install(r.p.req.cfg.kernel);
        crate::util::pool::set_pin_cores(r.p.req.cfg.pin_cores);
        let x = r.p.data.source();
        let level = &r.pass.levels[0];
        let mut job_tasks = plan_job_tasks(slot, level, x.rows(), &r.p.req.cfg, &r.p.req.opts);
        counts.push(job_tasks.len());
        tasks.append(&mut job_tasks);
        exec_jobs.push(ExecutorJob { x, cfg: r.p.req.cfg, rows: None });
    }

    // One budget pair for the shared schedule: the widest admitted
    // rank budget, and a memory bound no tighter than any job asked
    // for (0 = some job ran unbounded). Schedule-only (rule 7).
    let budget = ready.iter().map(|r| r.setup.budget).max().unwrap_or(1);
    let threads = ready.iter().map(|r| r.setup.threads).max().unwrap_or(1);
    let mem_budget = if ready.iter().any(|r| r.p.req.cfg.mem_budget == 0) {
        0
    } else {
        ready.iter().map(|r| r.p.req.cfg.mem_budget).max().unwrap_or(0)
    };
    let executor = FabricExecutor {
        budget,
        mem_budget,
        threads,
        machine: ready[0].p.req.opts.machine,
        sequential: false,
    };
    let run = match executor.run(&exec_jobs, tasks) {
        Ok(run) => run,
        Err(e) => {
            let msg = format!("{e:#}");
            for r in &ready {
                out.push((r.p.id, Err(anyhow!("shared solve wave failed: {msg}"))));
            }
            return out;
        }
    };

    let groups = split_by_counts(run.outcomes, &counts);
    for (r, outs) in ready.iter().zip(groups) {
        let level = &r.pass.levels[0];
        let (screened, solves) =
            reassemble_job(&level.components, &r.pass.diag, r.p.req.cfg.lambda2, outs);
        let screen = if r.cached { CostSummary::default() } else { r.pass.cost };
        let own = solves_view(&solves);
        let bill = GridBill { screen, waves: own, per_job: vec![own] };
        out.push((
            r.p.id,
            Ok(JobResult {
                omega: format_omega(&screened.fit.omega),
                bill,
                screen_cached: r.cached,
            }),
        ));
    }
    out
}

/// One sweep or stability job. The packed sweep path reuses cached
/// screening passes (one pass per distinct dataset/threshold-list
/// key); the per-point reference sweep and stability selection go
/// through [`EstimationRequest::run`] unchanged — stability screens
/// per subsample, and subsamples are never cache candidates (each has
/// its own row set, hence its own fingerprint-less data).
fn run_single(shared: &Shared, p: &Prep) -> Result<JobResult> {
    let x = p.data.source();
    if let RequestKind::Sweep { grid, per_point: false } = &p.req.kind {
        let setup = batch_setup(x.cols(), &p.req.cfg, &p.req.opts)?;
        let key =
            ScreenKey::new(p.fingerprint, &grid.lambda1, setup.screen_ranks, p.req.opts.gram_block);
        let (pass, cached) = screen_or_reuse(shared, key, x, &grid.lambda1, &setup, &p.req)?;
        let screen = if cached { CostSummary::default() } else { pass.cost };
        let out =
            sweep_dist_packed_with(x, grid, &p.req.cfg, &p.req.opts, &setup, &pass, screen)?;
        let sel = select_by_density(&out.results, p.select_density)
            .ok_or_else(|| anyhow!("sweep produced no results (empty grid)"))?;
        return Ok(JobResult {
            omega: format_omega(&sel.fit.omega),
            bill: out.bill.clone(),
            screen_cached: cached,
        });
    }
    let outcome = p.req.run(x)?;
    let omega = match &outcome {
        RequestOutcome::Solve(fit) => format_omega(&fit.fit.omega),
        RequestOutcome::Sweep(out) => {
            let sel = select_by_density(&out.results, p.select_density)
                .ok_or_else(|| anyhow!("sweep produced no results (empty grid)"))?;
            format_omega(&sel.fit.omega)
        }
        RequestOutcome::Stability(out) => format_omega(&out.frequency),
    };
    Ok(JobResult { omega, bill: outcome.bill(), screen_cached: false })
}

// ---------------------------------------------------------------- //
// Client half: the framing's other end, shared by the CLI `client`  //
// subcommand, the tests, and the CI smoke.                          //
// ---------------------------------------------------------------- //

/// A blocking protocol client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let writer = TcpStream::connect(addr)
            .with_context(|| format!("connecting to estimation server at {addr}"))?;
        let reader = BufReader::new(writer.try_clone().context("cloning client socket")?);
        Ok(Client { reader, writer })
    }

    /// Send one frame, read one reply. A `{"ok":false}` reply becomes
    /// the error it carries.
    pub fn call(&mut self, frame: &Json) -> Result<Json> {
        writeln!(self.writer, "{}", frame.encode()).context("writing request frame")?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading reply frame")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        let reply = Json::parse(line.trim_end())?;
        if !reply.bool_or("ok", false)? {
            bail!("server error: {}", reply.str_or("error", "unknown")?);
        }
        Ok(reply)
    }

    /// Submit a request and return its job id.
    pub fn submit(
        &mut self,
        req: &EstimationRequest,
        fingerprint: Option<u64>,
        select_density: f64,
    ) -> Result<usize> {
        let reply = self.call(&request_to_frame(req, fingerprint, select_density))?;
        if reply.get("job").is_none() {
            bail!("submit reply carried no job id");
        }
        reply.usize_or("job", 0)
    }

    /// Block until the job reaches a terminal state; errors if it
    /// failed.
    pub fn wait(&mut self, job: usize) -> Result<()> {
        let frame =
            obj(vec![("op", Json::Str("wait".into())), ("job", Json::Num(job as f64))]);
        let reply = self.call(&frame)?;
        let state = reply.str_or("state", "")?;
        if state != "done" {
            bail!("job {job} ended in state {state:?}: {}", reply.str_or("error", "unknown")?);
        }
        Ok(())
    }

    /// Fetch a finished job's omega as the exact `--out-omega` bytes.
    pub fn result_omega(&mut self, job: usize) -> Result<String> {
        let frame =
            obj(vec![("op", Json::Str("result".into())), ("job", Json::Num(job as f64))]);
        let reply = self.call(&frame)?;
        omega_text(&reply)
    }

    /// Fetch a finished job's bill frame.
    pub fn bill(&mut self, job: usize) -> Result<Json> {
        let frame =
            obj(vec![("op", Json::Str("bill".into())), ("job", Json::Num(job as f64))]);
        self.call(&frame)
    }

    /// Ask the server to shut down (idempotent).
    pub fn shutdown(&mut self) -> Result<()> {
        self.call(&obj(vec![("op", Json::Str("shutdown".into()))]))?;
        Ok(())
    }
}

/// Rebuild the `--out-omega` byte stream from a `result` reply: one
/// row per array entry, newline-terminated — byte-identical to
/// [`format_omega`] on the server side (the rows travel as JSON
/// strings containing only `[0-9.e+- ]`, which escape to themselves).
pub fn omega_text(reply: &Json) -> Result<String> {
    let rows = reply
        .get("omega")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("reply has no \"omega\" rows"))?;
    let mut text = String::new();
    for row in rows {
        text.push_str(row.as_str().ok_or_else(|| anyhow!("omega rows must be strings"))?);
        text.push('\n');
    }
    Ok(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_solve() -> EstimationRequest {
        let mut req = EstimationRequest::new(RequestKind::Solve);
        req.workload = WorkloadSpec { p: 16, n: 40, ..WorkloadSpec::default() };
        req.cfg.max_iter = 30;
        req.opts.total_ranks = 4;
        req
    }

    #[test]
    fn submit_wait_result_bill_round_trip() {
        let server = Server::start(ServeOptions::default()).unwrap();
        let addr = server.addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        let job = client.submit(&tiny_solve(), None, 0.1).unwrap();
        client.wait(job).unwrap();
        let omega = client.result_omega(job).unwrap();
        assert_eq!(omega.lines().count(), 16, "one row per variable");
        let bill = client.bill(job).unwrap();
        assert!(!bill.bool_or("screen_cached", true).unwrap(), "first pass is cold");
        client.shutdown().unwrap();
        server.join();
    }

    #[test]
    fn second_identical_job_hits_the_screen_cache() {
        let server = Server::start(ServeOptions::default()).unwrap();
        let addr = server.addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        let a = client.submit(&tiny_solve(), None, 0.1).unwrap();
        client.wait(a).unwrap();
        let b = client.submit(&tiny_solve(), None, 0.1).unwrap();
        client.wait(b).unwrap();
        assert_eq!(client.result_omega(a).unwrap(), client.result_omega(b).unwrap());
        let cold = client.bill(a).unwrap();
        let warm = client.bill(b).unwrap();
        assert!(!cold.bool_or("screen_cached", true).unwrap());
        assert!(warm.bool_or("screen_cached", false).unwrap());
        assert_eq!(warm.f64_or("screen_time", -1.0).unwrap(), 0.0);
        assert!(
            warm.f64_or("total_time", 0.0).unwrap()
                < cold.f64_or("total_time", 0.0).unwrap(),
            "amortized screening must strictly shrink the bill"
        );
        client.shutdown().unwrap();
        server.join();
    }

    #[test]
    fn malformed_and_unknown_frames_get_error_replies() {
        let server = Server::start(ServeOptions::default()).unwrap();
        let addr = server.addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        // Unknown op: clean error, connection survives.
        let err = client.call(&obj(vec![("op", Json::Str("frobnicate".into()))]));
        assert!(err.unwrap_err().to_string().contains("unknown op"));
        // Unknown job id.
        let err = client
            .call(&obj(vec![("op", Json::Str("status".into())), ("job", Json::Num(7.0))]));
        assert!(err.unwrap_err().to_string().contains("unknown job"));
        // Still alive for a valid frame on the same connection.
        client.call(&obj(vec![("op", Json::Str("ping".into()))])).unwrap();
        client.shutdown().unwrap();
        server.join();
    }

    #[test]
    fn fingerprint_mismatch_fails_the_job_cleanly() {
        let server = Server::start(ServeOptions::default()).unwrap();
        let addr = server.addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        let job = client.submit(&tiny_solve(), Some(0xdead_beef), 0.1).unwrap();
        let err = client.wait(job).unwrap_err();
        assert!(err.to_string().contains("fingerprint mismatch"), "{err}");
        client.shutdown().unwrap();
        server.join();
    }
}
