//! The screening-artifact cache: dataset-fingerprint-keyed reuse of
//! [`MultiScreenPass`] results across jobs.
//!
//! Screening is a pure function of (dataset contents, λ₁ threshold
//! list, screening fabric width, gram panel width) — the pass is
//! deterministic and bit-identical across backends (rules 1, 7, 8) —
//! so two jobs whose keys match would recompute the *same* components,
//! degrees and diagonal. The cache hands the second job the first
//! job's artifact instead: results are unchanged by construction
//! (determinism rule 9), and the screening pass is billed exactly once
//! — a cache hit contributes a zero screening share to its job's
//! [`GridBill`](crate::simnet::cost::GridBill).
//!
//! Thresholds are keyed by their f64 **bit patterns**: exact-match
//! semantics, no epsilon surprises (0.1 + 0.2 is a different key than
//! 0.3, exactly as it is a different screening pass).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::concord::MultiScreenPass;

/// What makes two screening passes interchangeable.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ScreenKey {
    /// [`crate::io::x_fingerprint`] of the dataset.
    pub fingerprint: u64,
    /// λ₁ thresholds as f64 bit patterns, in request order (the level
    /// list is order-aligned with the thresholds).
    pub thresholds: Vec<u64>,
    /// Screening fabric width (counters in the cached bill depend on
    /// it, so passes at different widths are not interchanged).
    pub screen_ranks: usize,
    /// Gram panel width (bill-only, but keyed for the same reason).
    pub gram_block: usize,
}

impl ScreenKey {
    pub fn new(
        fingerprint: u64,
        thresholds: &[f64],
        screen_ranks: usize,
        gram_block: usize,
    ) -> ScreenKey {
        ScreenKey {
            fingerprint,
            thresholds: thresholds.iter().map(|t| t.to_bits()).collect(),
            screen_ranks,
            gram_block,
        }
    }
}

/// A thread-safe map from [`ScreenKey`] to the shared screening
/// artifact. Entries are never evicted: a serve process holds one
/// artifact per distinct (dataset, threshold list, fabric) it has
/// screened, which is the working set the multi-tenant workload
/// shares by design.
#[derive(Default)]
pub struct ScreenCache {
    entries: Mutex<HashMap<ScreenKey, Arc<MultiScreenPass>>>,
}

impl ScreenCache {
    pub fn new() -> ScreenCache {
        ScreenCache::default()
    }

    /// The cached pass for `key`, if one exists (a hit: the caller
    /// must bill its screening share as zero).
    pub fn get(&self, key: &ScreenKey) -> Option<Arc<MultiScreenPass>> {
        self.entries.lock().expect("screen cache poisoned").get(key).cloned()
    }

    /// Store a freshly computed pass under `key`.
    pub fn insert(&self, key: ScreenKey, pass: Arc<MultiScreenPass>) {
        self.entries.lock().expect("screen cache poisoned").insert(key, pass);
    }

    /// Number of cached artifacts (observability only).
    pub fn len(&self) -> usize {
        self.entries.lock().expect("screen cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
