//! Per-rank communicator: metered point-to-point sends and the
//! collectives HP-CONCORD needs (team allgather, team sum-reduce, direct
//! and Bruck all-to-all, barrier).
//!
//! Every payload is a `Vec<f64>`; messages carry a `(src, tag)` header
//! and out-of-order arrivals are parked in a mailbox so tag-matched
//! receives behave like MPI. Sends are counted into [`Counters`] at the
//! sender (the paper's convention: L and W count *sent* messages/words).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Barrier};

use super::cost::Counters;

/// A point-to-point message.
pub(crate) struct Msg {
    pub src: usize,
    pub tag: u64,
    pub payload: Vec<f64>,
}

/// Handle a rank's program uses to communicate. One per thread.
pub struct Comm {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    mailbox: HashMap<(usize, u64), Vec<Vec<f64>>>,
    barrier: Arc<Barrier>,
    /// Global monotone tag source for internally generated collectives.
    tag_source: Arc<AtomicU64>,
    pub counters: Counters,
}

impl Comm {
    pub(crate) fn new(
        rank: usize,
        size: usize,
        senders: Vec<Sender<Msg>>,
        receiver: Receiver<Msg>,
        barrier: Arc<Barrier>,
        tag_source: Arc<AtomicU64>,
    ) -> Self {
        Comm {
            rank,
            size,
            senders,
            receiver,
            mailbox: HashMap::new(),
            barrier,
            tag_source,
            counters: Counters::default(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Send `payload` to `dest` under `tag`. Metered: 1 message,
    /// `payload.len()` words. Self-sends are delivered but *not* metered
    /// (no network traversal), matching the convention in the paper's
    /// counts where a processor's own block needs no communication.
    pub fn send(&mut self, dest: usize, tag: u64, payload: Vec<f64>) {
        let words = payload.len() as u64;
        self.send_with_words(dest, tag, payload, words);
    }

    /// Send with an explicit word count. Used by the operand-block paths:
    /// the paper's bandwidth model counts *matrix elements* (nnz for
    /// sparse), not wire encodings, so block shifts meter
    /// [`crate::dist::Block::words`] rather than the CSR envelope.
    pub fn send_with_words(&mut self, dest: usize, tag: u64, payload: Vec<f64>, words: u64) {
        if dest != self.rank {
            self.counters.messages += 1;
            self.counters.words += words;
        }
        self.senders[dest]
            .send(Msg { src: self.rank, tag, payload })
            .expect("simnet: receiver hung up");
    }

    /// Blocking tag-matched receive from `src`.
    pub fn recv(&mut self, src: usize, tag: u64) -> Vec<f64> {
        if let Some(q) = self.mailbox.get_mut(&(src, tag)) {
            if !q.is_empty() {
                return q.remove(0);
            }
        }
        loop {
            let msg = self.receiver.recv().expect("simnet: channel closed");
            if msg.src == src && msg.tag == tag {
                return msg.payload;
            }
            self.mailbox.entry((msg.src, msg.tag)).or_default().push(msg.payload);
        }
    }

    /// Simultaneous send+receive (ring shifts). Channels are unbounded,
    /// so send-then-recv cannot deadlock.
    pub fn sendrecv(
        &mut self,
        dest: usize,
        src: usize,
        tag: u64,
        payload: Vec<f64>,
    ) -> Vec<f64> {
        self.send(dest, tag, payload);
        self.recv(src, tag)
    }

    /// Full-world barrier.
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    /// Count local compute against the metered model.
    pub fn count_flops_dense(&mut self, flops: u64) {
        self.counters.flops_dense += flops;
    }

    pub fn count_flops_sparse(&mut self, flops: u64) {
        self.counters.flops_sparse += flops;
    }

    /// Fresh tag for an internally generated collective round. All ranks
    /// must call collectives in the same order, so per-call explicit tags
    /// keep rounds separated without global coordination.
    fn fresh_tag(&self) -> u64 {
        // One shared atomic would desynchronize ranks (each rank bumps it
        // independently); instead reserve the high bit and let callers'
        // explicit tags stay below it.
        const COLLECTIVE_BASE: u64 = 1 << 62;
        COLLECTIVE_BASE + self.tag_source.load(Ordering::Relaxed)
    }

    /// Team all-gather: every member ends with every member's
    /// contribution, indexed by team position. `team` must list the same
    /// ranks in the same order on every member. Direct exchange:
    /// (|team|-1) messages per rank.
    pub fn allgather(&mut self, team: &[usize], tag: u64, mine: Vec<f64>) -> Vec<Vec<f64>> {
        let me = team.iter().position(|&r| r == self.rank).expect("not in team");
        let mut out: Vec<Vec<f64>> = vec![Vec::new(); team.len()];
        for (i, &r) in team.iter().enumerate() {
            if i != me {
                self.send(r, tag, mine.clone());
            }
        }
        out[me] = mine;
        for (i, &r) in team.iter().enumerate() {
            if i != me {
                out[i] = self.recv(r, tag);
            }
        }
        out
    }

    /// Team elementwise sum-reduce with result on every member
    /// (allreduce); Algorithm 4 line 8.
    ///
    /// Power-of-two teams use a recursive-doubling butterfly: log2(c)
    /// rounds of full-vector exchange (words = len·log2(c) per rank vs
    /// len·(c−1) for the naive gather). Additions are ordered lower-half
    /// + upper-half at every level, so every member computes the
    /// bit-identical result — the distributed solvers rely on globally
    /// identical line-search decisions.
    pub fn sum_reduce(&mut self, team: &[usize], tag: u64, mine: Vec<f64>) -> Vec<f64> {
        let c = team.len();
        if c > 1 && c.is_power_of_two() {
            let me = team.iter().position(|&r| r == self.rank).expect("not in team");
            let mut acc = mine;
            let rounds = c.trailing_zeros();
            for k in 0..rounds {
                let bit = 1usize << k;
                let partner = team[me ^ bit];
                let theirs = self.sendrecv(partner, partner, tag + k as u64, acc.clone());
                debug_assert_eq!(theirs.len(), acc.len());
                // Deterministic order: lower block + upper block.
                if me & bit == 0 {
                    for (a, v) in acc.iter_mut().zip(&theirs) {
                        *a += v;
                    }
                } else {
                    let mut new = theirs;
                    for (v, a) in new.iter_mut().zip(&acc) {
                        *v += a;
                    }
                    acc = new;
                }
            }
            return acc;
        }
        // General teams: gather-and-sum (deterministic team order).
        let n = mine.len();
        let parts = self.allgather(team, tag, mine);
        let mut acc = vec![0.0; n];
        for p in parts {
            debug_assert_eq!(p.len(), n);
            for (a, v) in acc.iter_mut().zip(&p) {
                *a += v;
            }
        }
        acc
    }

    /// Direct (pairwise) all-to-all within a team: `parts[i]` goes to
    /// team member i; returns what each member sent to us. (|team|-1)
    /// messages per rank.
    pub fn alltoall_direct(
        &mut self,
        team: &[usize],
        tag: u64,
        mut parts: Vec<Vec<f64>>,
    ) -> Vec<Vec<f64>> {
        assert_eq!(parts.len(), team.len());
        let me = team.iter().position(|&r| r == self.rank).expect("not in team");
        let mut out: Vec<Vec<f64>> = vec![Vec::new(); team.len()];
        out[me] = std::mem::take(&mut parts[me]);
        for i in 0..team.len() {
            if i != me {
                self.send(team[i], tag, std::mem::take(&mut parts[i]));
            }
        }
        for (i, &r) in team.iter().enumerate() {
            if i != me {
                out[i] = self.recv(r, tag);
            }
        }
        out
    }

    /// Bruck all-to-all within a team of power-of-two size with
    /// equal-length parts: ⌈log₂ Q⌉ messages per rank, each carrying
    /// Q/2 blocks — the O(log Q) messages / O(w·Q·log Q) words schedule
    /// the paper's transpose analysis (Lemma 3.2 / §S.2.4) assumes.
    pub fn alltoall_bruck(
        &mut self,
        team: &[usize],
        tag: u64,
        parts: Vec<Vec<f64>>,
    ) -> Vec<Vec<f64>> {
        let q = team.len();
        assert_eq!(parts.len(), q);
        assert!(q.is_power_of_two(), "bruck requires power-of-two team");
        if q == 1 {
            return parts;
        }
        let w = parts[0].len();
        assert!(parts.iter().all(|p| p.len() == w), "bruck requires equal parts");
        let me = team.iter().position(|&r| r == self.rank).expect("not in team");

        // Phase 1: local rotation so block b holds data for (me + b) mod q.
        let mut blocks: Vec<Vec<f64>> = (0..q).map(|b| parts[(me + b) % q].clone()).collect();

        // Phase 2: log2(q) exchange rounds.
        let rounds = q.trailing_zeros();
        for k in 0..rounds {
            let bit = 1usize << k;
            let dest = team[(me + bit) % q];
            let src = team[(me + q - bit) % q];
            // Pack blocks whose index has bit k set.
            let send_idx: Vec<usize> = (0..q).filter(|b| b & bit != 0).collect();
            let mut buf = Vec::with_capacity(send_idx.len() * w);
            for &b in &send_idx {
                buf.extend_from_slice(&blocks[b]);
            }
            let recvd = self.sendrecv(dest, src, tag + k as u64, buf);
            for (slot, &b) in send_idx.iter().enumerate() {
                blocks[b] = recvd[slot * w..(slot + 1) * w].to_vec();
            }
        }

        // Phase 3: inverse rotation — after the exchanges, block b holds
        // the data *from* member (me - b) mod q.
        let mut out = vec![Vec::new(); q];
        for (b, block) in blocks.into_iter().enumerate() {
            out[(me + q - b) % q] = block;
        }
        out
    }

    /// Exchange with an irregular partner set: send `outgoing[(dest,
    /// payload)]`, receive one message from each rank in `expect_from`.
    /// Returns `(src, payload)` pairs. Used by the distributed transpose,
    /// where the partner set is the Lemma 3.2 neighbourhood.
    pub fn exchange(
        &mut self,
        tag: u64,
        outgoing: Vec<(usize, Vec<f64>)>,
        expect_from: &[usize],
    ) -> Vec<(usize, Vec<f64>)> {
        let mut keep = Vec::new();
        for (dest, payload) in outgoing {
            if dest == self.rank {
                keep.push((self.rank, payload));
            } else {
                self.send(dest, tag, payload);
            }
        }
        let mut out = keep;
        for &src in expect_from {
            if src != self.rank {
                out.push((src, self.recv(src, tag)));
            }
        }
        out
    }

    #[allow(dead_code)]
    pub(crate) fn noop_tag(&self) -> u64 {
        self.fresh_tag()
    }
}

/// A team-scoped convenience wrapper: fixes the member list and provides
/// position-indexed operations.
pub struct TeamComm<'a> {
    pub comm: &'a mut Comm,
    pub members: Vec<usize>,
}

impl<'a> TeamComm<'a> {
    pub fn new(comm: &'a mut Comm, members: Vec<usize>) -> Self {
        debug_assert!(members.contains(&comm.rank()));
        TeamComm { comm, members }
    }

    pub fn position(&self) -> usize {
        let r = self.comm.rank();
        self.members.iter().position(|&m| m == r).unwrap()
    }

    pub fn allgather(&mut self, tag: u64, mine: Vec<f64>) -> Vec<Vec<f64>> {
        self.comm.allgather(&self.members, tag, mine)
    }

    pub fn sum_reduce(&mut self, tag: u64, mine: Vec<f64>) -> Vec<f64> {
        self.comm.sum_reduce(&self.members, tag, mine)
    }
}
