//! Simulated message-passing fabric — the MPI-over-Cray substitute.
//!
//! The paper's experiments ran MPI on Cray XC30 supercomputers; none of
//! that hardware exists here, and the paper's *claims* (Lemmas 3.1–3.5,
//! Figures 2–4) are statements about message, word, and flop counts under
//! the classic `T = F·γ + L·α + W·β` model. This module therefore gives
//! each simulated rank a real OS thread and real channel-based
//! communication (distributed numerics are genuinely exercised, not
//! faked), while **every send is metered** into per-rank α/β/γ counters:
//!
//! - [`cost::MachineParams`] — α (per message), β (per word),
//!   γ_dense/γ_sparse (per flop, matching the paper's observation that
//!   γ_sparse ≫ γ_dense drives the Cov/Obs crossover);
//! - [`cost::Counters`] — per-rank tallies; modeled runtime is the max
//!   over ranks of `F·γ + L·α + W·β` (critical path), totals are also
//!   reported (the paper quotes totals in its lemmas).
//!
//! Collectives are built from point-to-point sends so their costs accrue
//! naturally; the all-to-all used by the distributed transpose has both a
//! direct pairwise variant and a Bruck log-round variant (the paper's
//! transpose analysis assumes the latter: `log₂ Q` messages).

pub mod comm;
pub mod cost;
pub mod fabric;

pub use comm::{Comm, TeamComm};
pub use cost::{Counters, MachineParams};
pub use fabric::{Fabric, SimRun};
