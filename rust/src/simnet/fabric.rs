//! Fabric: spawn P simulated ranks, run a rank program on each, join, and
//! collect results + metered costs.

use std::sync::atomic::AtomicU64;
use std::sync::mpsc;
use std::sync::{Arc, Barrier};

use super::comm::{Comm, Msg};
use super::cost::{CostSummary, Counters, MachineParams};

/// A P-rank simulated machine.
#[derive(Debug, Clone)]
pub struct Fabric {
    p: usize,
    machine: MachineParams,
}

/// Results of one fabric run: per-rank return values and counters.
#[derive(Debug)]
pub struct SimRun<T> {
    pub results: Vec<T>,
    pub counters: Vec<Counters>,
    pub machine: MachineParams,
}

impl<T> SimRun<T> {
    /// Critical-path modeled time and totals under the run's machine.
    pub fn summary(&self) -> CostSummary {
        CostSummary::from_counters(&self.counters, &self.machine)
    }

    /// Summary under a different machine (re-pricing the same counts).
    pub fn summary_with(&self, m: &MachineParams) -> CostSummary {
        CostSummary::from_counters(&self.counters, m)
    }
}

impl Fabric {
    pub fn new(p: usize) -> Self {
        Fabric { p, machine: MachineParams::default() }
    }

    pub fn with_machine(p: usize, machine: MachineParams) -> Self {
        Fabric { p, machine }
    }

    pub fn size(&self) -> usize {
        self.p
    }

    pub fn machine(&self) -> MachineParams {
        self.machine
    }

    /// Run `program(comm) -> T` on every rank concurrently; returns
    /// rank-indexed results and counters. The program receives a
    /// [`Comm`] wired to all other ranks.
    ///
    /// Ranks are OS threads with channel links: numerics are genuinely
    /// distributed (data is partitioned; nothing is shared), while the
    /// single-host execution keeps the runs deterministic and portable.
    pub fn run<T, F>(&self, program: F) -> SimRun<T>
    where
        T: Send + 'static,
        F: Fn(&mut Comm) -> T + Send + Sync + 'static,
    {
        let p = self.p;
        let (senders, receivers): (Vec<_>, Vec<_>) = (0..p).map(|_| mpsc::channel::<Msg>()).unzip();
        let barrier = Arc::new(Barrier::new(p));
        let tags = Arc::new(AtomicU64::new(0));
        let program = Arc::new(program);

        let mut handles = Vec::with_capacity(p);
        for (rank, receiver) in receivers.into_iter().enumerate() {
            let senders = senders.clone();
            let barrier = barrier.clone();
            let tags = tags.clone();
            let program = program.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rank-{rank}"))
                    .stack_size(8 << 20)
                    .spawn(move || {
                        let mut comm = Comm::new(rank, p, senders, receiver, barrier, tags);
                        let out = program(&mut comm);
                        (out, comm.counters)
                    })
                    .expect("spawn rank thread"),
            );
        }
        drop(senders);

        let mut results = Vec::with_capacity(p);
        let mut counters = Vec::with_capacity(p);
        for h in handles {
            let (out, c) = h.join().expect("rank panicked");
            results.push(out);
            counters.push(c);
        }
        SimRun { results, counters, machine: self.machine }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_shift_delivers_and_meters() {
        let p = 4;
        let run = Fabric::new(p).run(move |comm| {
            let r = comm.rank();
            let next = (r + 1) % comm.size();
            let prev = (r + comm.size() - 1) % comm.size();
            let got = comm.sendrecv(next, prev, 7, vec![r as f64; 3]);
            got[0] as usize
        });
        // Everyone receives their left neighbour's rank.
        for (r, &got) in run.results.iter().enumerate() {
            assert_eq!(got, (r + p - 1) % p);
        }
        for c in &run.counters {
            assert_eq!(c.messages, 1);
            assert_eq!(c.words, 3);
        }
    }

    #[test]
    fn self_send_not_metered() {
        let run = Fabric::new(2).run(|comm| {
            let r = comm.rank();
            let got = comm.sendrecv(r, r, 1, vec![42.0]);
            got[0]
        });
        assert!(run.results.iter().all(|&v| v == 42.0));
        assert!(run.counters.iter().all(|c| c.messages == 0 && c.words == 0));
    }

    #[test]
    fn out_of_order_tags_are_buffered() {
        let run = Fabric::new(2).run(|comm| {
            if comm.rank() == 0 {
                comm.send(1, 10, vec![1.0]);
                comm.send(1, 20, vec![2.0]);
                0.0
            } else {
                // Receive in reverse tag order.
                let b = comm.recv(0, 20);
                let a = comm.recv(0, 10);
                a[0] * 10.0 + b[0]
            }
        });
        assert_eq!(run.results[1], 12.0);
    }

    #[test]
    fn allgather_collects_in_team_order() {
        let run = Fabric::new(4).run(|comm| {
            let team = vec![0, 1, 2, 3];
            let parts = comm.allgather(&team, 5, vec![comm.rank() as f64]);
            parts.iter().map(|p| p[0]).collect::<Vec<_>>()
        });
        for res in &run.results {
            assert_eq!(res, &[0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn sum_reduce_sums_elementwise() {
        let run = Fabric::new(3).run(|comm| {
            let team = vec![0, 1, 2];
            comm.sum_reduce(&team, 9, vec![comm.rank() as f64, 1.0])
        });
        for res in &run.results {
            assert_eq!(res, &vec![3.0, 3.0]);
        }
    }

    #[test]
    fn subteam_collectives_do_not_cross() {
        let run = Fabric::new(4).run(|comm| {
            let team = if comm.rank() < 2 { vec![0, 1] } else { vec![2, 3] };
            comm.sum_reduce(&team, 11, vec![comm.rank() as f64])
        });
        assert_eq!(run.results[0], vec![1.0]);
        assert_eq!(run.results[3], vec![5.0]);
    }

    #[test]
    fn bruck_matches_direct() {
        for p in [2usize, 4, 8] {
            let run = Fabric::new(p).run(move |comm| {
                let team: Vec<usize> = (0..comm.size()).collect();
                let r = comm.rank() as f64;
                // parts[i] = [100*me + i] * 2
                let parts: Vec<Vec<f64>> =
                    (0..p).map(|i| vec![100.0 * r + i as f64, -1.0]).collect();
                let got = comm.alltoall_bruck(&team, 50, parts.clone());
                let direct = comm.alltoall_direct(&team, 500, parts);
                (got, direct)
            });
            for (r, (got, direct)) in run.results.iter().enumerate() {
                assert_eq!(got, direct, "p={p} rank={r}");
                for (src, blk) in got.iter().enumerate() {
                    assert_eq!(blk[0], 100.0 * src as f64 + r as f64);
                }
            }
        }
    }

    #[test]
    fn bruck_message_count_is_log2() {
        let p = 8;
        let run = Fabric::new(p).run(move |comm| {
            let team: Vec<usize> = (0..comm.size()).collect();
            let parts: Vec<Vec<f64>> = (0..p).map(|i| vec![i as f64; 4]).collect();
            comm.alltoall_bruck(&team, 1, parts);
        });
        for c in &run.counters {
            assert_eq!(c.messages, 3, "log2(8) rounds");
            // Each round carries q/2 = 4 blocks of 4 words.
            assert_eq!(c.words, 3 * 4 * 4);
        }
    }

    #[test]
    fn exchange_irregular() {
        let run = Fabric::new(3).run(|comm| {
            // Ring: everyone sends to (r+1)%3, expects from (r+2)%3.
            let r = comm.rank();
            let to = (r + 1) % 3;
            let from = (r + 2) % 3;
            let got = comm.exchange(77, vec![(to, vec![r as f64])], &[from]);
            got[0].1[0]
        });
        assert_eq!(run.results, vec![2.0, 0.0, 1.0]);
    }

    #[test]
    fn flop_counting() {
        let run = Fabric::new(2).run(|comm| {
            comm.count_flops_dense(100);
            comm.count_flops_sparse(7);
        });
        for c in &run.counters {
            assert_eq!(c.flops_dense, 100);
            assert_eq!(c.flops_sparse, 7);
        }
    }
}
