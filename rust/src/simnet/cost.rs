//! The α-β-γ machine model and per-rank cost counters.
//!
//! `T = F·γ + L·α + W·β` (paper §3, "Final computation and communication
//! costs"): F flops at γ seconds each, L messages at α seconds latency,
//! W words at β seconds each. The paper distinguishes γ_sparse ≫ γ_dense
//! ("most of Cov's cost comes from sparse-dense matrix multiplications,
//! which have higher time per flop") — that distinction is what delays
//! the Cov/Obs crossover past Lemma 3.1's prediction in Figure 2, so we
//! model it explicitly.

/// Machine constants: seconds per flop / message / word.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineParams {
    /// Seconds to initiate one message (latency), the paper's α.
    pub alpha: f64,
    /// Seconds to transfer one word (8-byte f64), the paper's β.
    pub beta: f64,
    /// Seconds per flop in dense-dense multiplication **at full cache
    /// reuse** (the packed blocked kernel's rate).
    pub gamma_dense: f64,
    /// Seconds per flop in sparse-dense multiplication (≫ γ_dense).
    pub gamma_sparse: f64,
    /// Seconds per word of *node-local* memory traffic (the intra-node
    /// analogue of β). A dense kernel that moves `w` words per flop
    /// runs at an effective `γ_dense + w·β_mem` seconds per flop —
    /// the cache-reuse term `CostBreakdown::time_with_tile` charges
    /// (see `linalg::tile::TileConfig::gemm_words_per_flop`). Zero
    /// recovers the pre-tile pricing exactly.
    pub beta_mem: f64,
}

impl MachineParams {
    /// Edison-like defaults, per MPI process (2 processes/node on two
    /// 12-core Xeon E5-2695v2): ~10 GFLOP/s effective dense rate per
    /// process, ~8× worse per-flop rate for irregular sparse-dense,
    /// ~1 µs MPI latency, ~8 GB/s injection bandwidth (1 ns per 8-byte
    /// word), ~5 GWord/s node-local streaming per process (β_mem
    /// 2·10⁻¹⁰ s/word — at ½ word/flop a naive unblocked GEMM prices
    /// 2× off dense peak). Ratios, not absolutes, drive every figure's
    /// shape.
    pub fn edison_like() -> Self {
        MachineParams {
            alpha: 1.0e-6,
            beta: 1.0e-9,
            gamma_dense: 1.0e-10,
            gamma_sparse: 8.0e-10,
            beta_mem: 2.0e-10,
        }
    }

    /// Calibrate γ_dense from a measured local GEMM rate (flops/sec) on
    /// this host, keeping the Edison-like α/β/γ_sparse/β_mem ratios.
    pub fn calibrated(dense_flops_per_sec: f64) -> Self {
        let gamma_dense = 1.0 / dense_flops_per_sec;
        MachineParams {
            alpha: 1.0e-6,
            beta: 1.0e-9,
            gamma_dense,
            gamma_sparse: 8.0 * gamma_dense,
            beta_mem: 2.0 * gamma_dense,
        }
    }

    /// Speed the modeled dense flop rate up by `scale` (> 1 = faster),
    /// leaving every other constant alone — the per-ISA pricing hook
    /// for `--kernel` (`cost --kernel avx512` divides γ_dense by the
    /// lane's measured speedup, `linalg::KernelLane::gamma_scale`).
    /// Only γ_dense moves: the SIMD lanes vectorize the dense
    /// microkernel, while the sparse gather and the network are
    /// untouched — which is exactly why a wider lane shifts the
    /// Cov/Obs crossover and the best replication choice.
    pub fn with_dense_rate_scale(mut self, scale: f64) -> Self {
        assert!(scale > 0.0, "dense rate scale must be positive, got {scale}");
        self.gamma_dense /= scale;
        self
    }
}

impl Default for MachineParams {
    fn default() -> Self {
        Self::edison_like()
    }
}

/// Per-rank tallies of the four cost components.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Counters {
    /// Messages sent by this rank (the paper's per-processor L).
    pub messages: u64,
    /// Words (f64 elements) sent by this rank (the paper's W).
    pub words: u64,
    /// Dense-dense flops executed by this rank.
    pub flops_dense: u64,
    /// Sparse-dense flops executed by this rank.
    pub flops_sparse: u64,
}

impl Counters {
    /// Modeled wall time of this rank: F·γ + L·α + W·β.
    pub fn modeled_time(&self, m: &MachineParams) -> f64 {
        self.flops_dense as f64 * m.gamma_dense
            + self.flops_sparse as f64 * m.gamma_sparse
            + self.messages as f64 * m.alpha
            + self.words as f64 * m.beta
    }

    /// Communication-only modeled time (L·α + W·β).
    pub fn comm_time(&self, m: &MachineParams) -> f64 {
        self.messages as f64 * m.alpha + self.words as f64 * m.beta
    }

    /// Component-wise sum.
    pub fn add(&mut self, other: &Counters) {
        self.messages += other.messages;
        self.words += other.words;
        self.flops_dense += other.flops_dense;
        self.flops_sparse += other.flops_sparse;
    }

    /// Component-wise max — folding per-rank critical-path counts
    /// across independently-run fabrics.
    pub fn max_elementwise(&mut self, other: &Counters) {
        self.messages = self.messages.max(other.messages);
        self.words = self.words.max(other.words);
        self.flops_dense = self.flops_dense.max(other.flops_dense);
        self.flops_sparse = self.flops_sparse.max(other.flops_sparse);
    }
}

/// Aggregate view over all ranks of a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostSummary {
    /// Critical-path modeled time: max over ranks.
    pub time: f64,
    /// Communication-only critical path.
    pub comm_time: f64,
    /// Totals across ranks (the quantities in the paper's lemmas).
    pub total: Counters,
    /// Per-rank maxima (per-processor critical-path counts).
    pub max_per_rank: Counters,
    /// Modeled peak resident words of the *host process* running the
    /// simulation: extracted X sub-matrices plus per-component working
    /// sets live at once. Unlike the time fields, the merge semantics
    /// invert: concurrent phases are resident *together* (footprints
    /// add), sequential phases free one before the next (peaks max).
    pub peak_mem_words: u64,
    /// Modeled words of the X *source* kept resident to serve reads
    /// (determinism rule 8's residency term): the whole n·p matrix for
    /// an in-core run, one row panel for an on-disk run
    /// ([`crate::io::XSource::panel_words`]). Unlike `peak_mem_words`,
    /// this maxes under *both* merges — the source backing storage is
    /// shared across phases and waves, so residencies coexist rather
    /// than accumulate.
    pub x_panel_words: u64,
}

impl CostSummary {
    /// Fold another fabric's summary into this one under a *sequential*
    /// schedule (its ranks start after this one's finish): critical-path
    /// times add, totals add, per-rank maxima take the component-wise
    /// max. This is how a screened run aggregates its screening pass
    /// plus one sized fabric per component into a single bill.
    pub fn merge_sequential(&mut self, other: &CostSummary) {
        self.time += other.time;
        self.comm_time += other.comm_time;
        self.total.add(&other.total);
        self.max_per_rank.max_elementwise(&other.max_per_rank);
        // Sequential phases free their memory before the next starts:
        // the peak is the larger phase, not the sum.
        self.peak_mem_words = self.peak_mem_words.max(other.peak_mem_words);
        // The X source is shared across phases: one resident panel (or
        // matrix) serves both, so the term maxes rather than adds.
        self.x_panel_words = self.x_panel_words.max(other.x_panel_words);
    }

    /// Fold another fabric's summary into this one under a *concurrent*
    /// schedule (both fabrics run at the same time on disjoint rank
    /// teams): critical-path times take the max — the wave finishes
    /// when its slowest fabric does — while totals still add (they are
    /// machine facts, independent of when the work ran) and per-rank
    /// maxima take the component-wise max. Folding a whole wave this
    /// way and then folding waves with
    /// [`merge_sequential`](CostSummary::merge_sequential) makes the
    /// reported bill the schedule's critical path, not the serial sum.
    pub fn merge_concurrent(&mut self, other: &CostSummary) {
        self.time = self.time.max(other.time);
        self.comm_time = self.comm_time.max(other.comm_time);
        self.total.add(&other.total);
        self.max_per_rank.max_elementwise(&other.max_per_rank);
        // Concurrent phases are resident together: footprints add —
        // the inverse of the time semantics above.
        self.peak_mem_words += other.peak_mem_words;
        // Concurrent readers still share one X source (the backing
        // matrix or file panel buffer is not duplicated per fabric):
        // max under the concurrent fold too.
        self.x_panel_words = self.x_panel_words.max(other.x_panel_words);
    }

    /// True when nothing was metered into this summary. This is the
    /// shape a cache-amortized screening share takes in a serve-layer
    /// bill (`crate::serve`): the pass was billed once by the job that
    /// computed it, and every later hit carries a zero share.
    pub fn is_unbilled(&self) -> bool {
        self.time == 0.0
            && self.comm_time == 0.0
            && self.total == Counters::default()
            && self.max_per_rank == Counters::default()
            && self.peak_mem_words == 0
            && self.x_panel_words == 0
    }

    pub fn from_counters(per_rank: &[Counters], m: &MachineParams) -> Self {
        let mut s = CostSummary::default();
        for c in per_rank {
            s.time = s.time.max(c.modeled_time(m));
            s.comm_time = s.comm_time.max(c.comm_time(m));
            s.total.add(c);
            s.max_per_rank.messages = s.max_per_rank.messages.max(c.messages);
            s.max_per_rank.words = s.max_per_rank.words.max(c.words);
            s.max_per_rank.flops_dense = s.max_per_rank.flops_dense.max(c.flops_dense);
            s.max_per_rank.flops_sparse = s.max_per_rank.flops_sparse.max(c.flops_sparse);
        }
        s
    }
}

/// Grid-level billing view of a multi-job schedule: an (amortized)
/// screening share, the executed cross-job wave schedule's critical
/// path, and per-job serial views of each job's own metered fabrics.
///
/// Built by the grid coordinators ([`crate::coordinator::sweep`],
/// [`crate::coordinator::stability`]) on top of the executor layer
/// ([`crate::concord::executor`]): `screen` is everything billed for
/// component discovery (one amortized pass for a packed sweep, the
/// serial fold of per-job passes when screening cannot be shared —
/// e.g. stability subsamples, which each own their data), `waves` is
/// the shared schedule's critical path (per-wave concurrent merges
/// folded sequentially), and `per_job[j]` is the *view* "job j's
/// metered fabric solves folded serially" — what that job alone would
/// have billed for solving, schedule aside.
#[derive(Debug, Clone, Default)]
pub struct GridBill {
    /// Screening share of the bill (billed once under amortization).
    pub screen: CostSummary,
    /// Critical path of the executed cross-job wave schedule.
    pub waves: CostSummary,
    /// Per-job serial fold of that job's own metered fabric solves.
    pub per_job: Vec<CostSummary>,
}

impl GridBill {
    /// The grid's bill: screening plus the cross-job critical path.
    pub fn total(&self) -> CostSummary {
        let mut t = self.screen;
        t.merge_sequential(&self.waves);
        t
    }

    /// True when this bill's screening share was amortized away — the
    /// job reused a cached pass and billed nothing for component
    /// discovery. The serve protocol reports this as `screen_cached`.
    pub fn screen_amortized(&self) -> bool {
        self.screen.is_unbilled()
    }

    /// What the same screening + solves would have billed with *no*
    /// cross-job packing: the screening share followed by every job's
    /// fabrics one after another. The packed `total()` never exceeds
    /// this; it undercuts it strictly as soon as any wave ran two
    /// fabrics at once.
    pub fn sequential(&self) -> CostSummary {
        let mut t = self.screen;
        for job in &self.per_job {
            t.merge_sequential(job);
        }
        t
    }
}

/// Re-export for `CostModel` naming used in docs/examples.
pub type CostModel = MachineParams;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_time_is_linear_combination() {
        let m = MachineParams {
            alpha: 2.0,
            beta: 3.0,
            gamma_dense: 5.0,
            gamma_sparse: 7.0,
            beta_mem: 0.0,
        };
        let c = Counters { messages: 1, words: 10, flops_dense: 100, flops_sparse: 1000 };
        assert_eq!(c.modeled_time(&m), 2.0 + 30.0 + 500.0 + 7000.0);
        assert_eq!(c.comm_time(&m), 32.0);
    }

    #[test]
    fn summary_takes_max_and_total() {
        let m = MachineParams {
            alpha: 1.0,
            beta: 0.0,
            gamma_dense: 0.0,
            gamma_sparse: 0.0,
            beta_mem: 0.0,
        };
        let a = Counters { messages: 4, words: 1, flops_dense: 0, flops_sparse: 0 };
        let b = Counters { messages: 2, words: 9, flops_dense: 3, flops_sparse: 0 };
        let s = CostSummary::from_counters(&[a, b], &m);
        assert_eq!(s.time, 4.0);
        assert_eq!(s.total.messages, 6);
        assert_eq!(s.total.words, 10);
        assert_eq!(s.max_per_rank.messages, 4);
        assert_eq!(s.max_per_rank.words, 9);
    }

    #[test]
    fn merge_sequential_adds_times_and_totals_maxes_per_rank() {
        let m = MachineParams {
            alpha: 1.0,
            beta: 0.0,
            gamma_dense: 0.0,
            gamma_sparse: 0.0,
            beta_mem: 0.0,
        };
        let a = CostSummary::from_counters(
            &[Counters { messages: 4, words: 1, flops_dense: 2, flops_sparse: 0 }],
            &m,
        );
        let b = CostSummary::from_counters(
            &[Counters { messages: 1, words: 9, flops_dense: 5, flops_sparse: 3 }],
            &m,
        );
        let mut s = a;
        s.merge_sequential(&b);
        assert_eq!(s.time, a.time + b.time);
        assert_eq!(s.total.messages, 5);
        assert_eq!(s.total.words, 10);
        assert_eq!(s.total.flops_dense, 7);
        assert_eq!(s.max_per_rank.messages, 4);
        assert_eq!(s.max_per_rank.words, 9);
        assert_eq!(s.max_per_rank.flops_sparse, 3);
    }

    #[test]
    fn merge_concurrent_maxes_times_adds_totals() {
        let m = MachineParams {
            alpha: 1.0,
            beta: 0.0,
            gamma_dense: 0.0,
            gamma_sparse: 0.0,
            beta_mem: 0.0,
        };
        let a = CostSummary::from_counters(
            &[Counters { messages: 4, words: 1, flops_dense: 2, flops_sparse: 0 }],
            &m,
        );
        let b = CostSummary::from_counters(
            &[Counters { messages: 1, words: 9, flops_dense: 5, flops_sparse: 3 }],
            &m,
        );
        let mut c = a;
        c.merge_concurrent(&b);
        assert_eq!(c.time, a.time.max(b.time));
        assert_eq!(c.comm_time, a.comm_time.max(b.comm_time));
        // Totals are machine facts: identical to the sequential fold.
        let mut s = a;
        s.merge_sequential(&b);
        assert_eq!(c.total, s.total);
        assert_eq!(c.max_per_rank, s.max_per_rank);
        // And the concurrent critical path never exceeds the serial sum.
        assert!(c.time <= s.time);
        assert!(c.comm_time <= s.comm_time);
    }

    /// GridBill views: `total` is screen ⊕ waves, `sequential` is
    /// screen ⊕ per-job folds; counters agree whenever the waves bill
    /// was itself folded from the same per-job costs, and the packed
    /// total never exceeds the sequential view.
    #[test]
    fn grid_bill_views_are_consistent() {
        let m = MachineParams {
            alpha: 1.0,
            beta: 0.0,
            gamma_dense: 0.0,
            gamma_sparse: 0.0,
            beta_mem: 0.0,
        };
        let screen = CostSummary::from_counters(
            &[Counters { messages: 3, words: 2, flops_dense: 10, flops_sparse: 0 }],
            &m,
        );
        let a = CostSummary::from_counters(
            &[Counters { messages: 4, words: 1, flops_dense: 2, flops_sparse: 0 }],
            &m,
        );
        let b = CostSummary::from_counters(
            &[Counters { messages: 1, words: 9, flops_dense: 5, flops_sparse: 3 }],
            &m,
        );
        // One wave running both jobs' fabrics at once.
        let mut waves = a;
        waves.merge_concurrent(&b);
        let bill = GridBill { screen, waves, per_job: vec![a, b] };

        let total = bill.total();
        assert_eq!(total.time, screen.time + waves.time);
        assert_eq!(total.total.messages, 3 + 4 + 1);
        assert_eq!(total.total.flops_dense, 10 + 2 + 5);

        let seq = bill.sequential();
        assert_eq!(seq.time, screen.time + a.time + b.time);
        // Counters are machine facts: both views agree.
        assert_eq!(seq.total, total.total);
        // Packing two nonzero fabrics strictly undercuts the serial view.
        assert!(total.time < seq.time);
        assert!(GridBill::default().total().time == 0.0);
    }

    /// Peak-memory merge semantics invert the time semantics: the
    /// concurrent fold *adds* footprints (both resident at once), the
    /// sequential fold *maxes* them (one freed before the next).
    #[test]
    fn peak_mem_merges_invert_time_semantics() {
        let a = CostSummary { peak_mem_words: 100, ..CostSummary::default() };
        let b = CostSummary { peak_mem_words: 40, ..CostSummary::default() };
        let mut conc = a;
        conc.merge_concurrent(&b);
        assert_eq!(conc.peak_mem_words, 140);
        let mut seq = a;
        seq.merge_sequential(&b);
        assert_eq!(seq.peak_mem_words, 100);
        // A wave folded concurrently, then waves folded sequentially:
        // the bill reports the largest wave's residency.
        let mut wave2 = CostSummary { peak_mem_words: 70, ..CostSummary::default() };
        wave2.merge_concurrent(&CostSummary { peak_mem_words: 90, ..CostSummary::default() });
        let mut bill = conc;
        bill.merge_sequential(&wave2);
        assert_eq!(bill.peak_mem_words, 160);
    }

    /// The X-source residency term maxes under *both* folds: the
    /// backing matrix / panel buffer is shared, so neither a wave of
    /// concurrent fabrics nor a sequence of phases duplicates it.
    #[test]
    fn x_panel_words_max_under_both_merges() {
        let a = CostSummary { x_panel_words: 500, ..CostSummary::default() };
        let b = CostSummary { x_panel_words: 120, ..CostSummary::default() };
        let mut conc = a;
        conc.merge_concurrent(&b);
        assert_eq!(conc.x_panel_words, 500);
        let mut seq = a;
        seq.merge_sequential(&b);
        assert_eq!(seq.x_panel_words, 500);
    }

    /// A default (all-zero) screening share reads as amortized; any
    /// metered screening share does not.
    #[test]
    fn amortized_screen_share_is_detectable() {
        assert!(CostSummary::default().is_unbilled());
        let m = MachineParams::edison_like();
        let metered = CostSummary::from_counters(
            &[Counters { messages: 1, words: 2, flops_dense: 3, flops_sparse: 0 }],
            &m,
        );
        assert!(!metered.is_unbilled());
        let warm = GridBill { screen: CostSummary::default(), ..GridBill::default() };
        assert!(warm.screen_amortized());
        let cold = GridBill { screen: metered, ..GridBill::default() };
        assert!(!cold.screen_amortized());
    }

    #[test]
    fn dense_rate_scale_moves_only_gamma_dense() {
        let base = MachineParams::edison_like();
        let fast = base.with_dense_rate_scale(4.0);
        assert_eq!(fast.gamma_dense, base.gamma_dense / 4.0);
        assert_eq!(fast.gamma_sparse, base.gamma_sparse);
        assert_eq!(fast.alpha, base.alpha);
        assert_eq!(fast.beta, base.beta);
        assert_eq!(fast.beta_mem, base.beta_mem);
        // scale 1 is the identity.
        assert_eq!(base.with_dense_rate_scale(1.0), base);
    }

    #[test]
    fn edison_like_ordering() {
        let m = MachineParams::edison_like();
        assert!(m.gamma_dense < m.gamma_sparse);
        assert!(m.gamma_sparse < m.beta);
        assert!(m.beta < m.alpha);
        // Node-local streaming is slower than a cached flop but faster
        // than the network: γ_dense < β_mem < β.
        assert!(m.gamma_dense < m.beta_mem);
        assert!(m.beta_mem < m.beta);
    }
}
