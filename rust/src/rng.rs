//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we ship a small, well-known
//! generator: SplitMix64 (Steele, Lea & Flood 2014) — a 64-bit
//! permutation with provably full period, used by JDK's `SplittableRandom`
//! and as the seeding PRNG of xoshiro. Statistical quality is far beyond
//! what synthetic-data generation needs, and determinism across runs is a
//! requirement for the reproducibility harness (every experiment records
//! its seed).

/// SplitMix64 PRNG with Box–Muller Gaussian sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of the Box–Muller pair.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // u1 in (0,1] so ln(u1) is finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index vector.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent stream (for per-rank generators).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(5);
        let mut b = a.split();
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
