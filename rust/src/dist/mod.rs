//! The 1.5D communication-avoiding distribution layer (paper §3 and
//! Algorithm 4, after Koanantakool et al. 2016 [29]).
//!
//! HP-CONCORD's multiplies always pair a **small, often sparse, rotating
//! operand** (the iterate Ω, or an Xᵀ slab) with a **large stationary
//! operand** (S's column blocks, X's column slabs, a rank's Y). The 1.5D
//! schedule replicates both sides — `c_R` copies of the rotating
//! operand's teams ([`RepGrid`]) and `c_F` copies of the stationary
//! side's — and lets each stationary replica visit only `T_R/c_F` of the
//! rotating parts, so per-rank latency drops to `P/(c_R·c_F)` messages
//! and bandwidth to `nnz(R)/c_F` words (Lemma 3.3; pinned by the unit
//! tests in [`mult15d`] and `rust/tests/lemma_counts.rs`).
//!
//! Pieces:
//!
//! - [`RepGrid`]/[`Layout1D`]: the `(layer, team)` process grid and the
//!   balanced 1D block-row (or column) partition;
//! - [`Block`]: a dense or CSR operand part; shifted parts are metered
//!   at their *element* count (nnz for sparse) per the paper's W;
//! - [`rotate_parts`]: the designated-source part shift (Lemma 3.3);
//! - [`mult_concat`]/[`mult_sum`]: the concat-mode (Algorithm 2's
//!   W = Ω·S, Algorithm 3's Z = Y·X) and sum-mode (Algorithm 3's
//!   Y = Ω·Xᵀ) 1.5D multiplies, combining over the stationary grid's
//!   replica teams;
//! - [`transpose_block_rows`]: the distributed transpose (Lemma 3.2):
//!   layer-split Bruck all-to-all + replica-team allgather, giving the
//!   `log₂(T) + (c−1)` message profile the paper's analysis assumes;
//! - [`redistribute_rows`]: 1D block-row re-layout between grids (free
//!   when the two grids coincide, as in Algorithm 2 with c_X = c_Ω).

pub mod block;
pub mod layout;
pub mod mult15d;
pub mod transpose;

pub use block::{Block, ConcatAxis};
pub use layout::{Layout1D, RepGrid};
pub use mult15d::{mult_concat, mult_sum, rotate_parts};
pub use transpose::{redistribute_rows, transpose_block_rows};
