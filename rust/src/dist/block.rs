//! Operand parts shifted by the 1.5D schedule: dense or CSR, with the
//! paper's bandwidth accounting (a shifted part costs its *element*
//! count — nnz for sparse — not its wire envelope).

use crate::linalg::{Csr, Mat};

/// Concatenation axis for [`super::mult_concat`] results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConcatAxis {
    /// Piece `q` supplies the output's block rows `layout.range(q)`.
    Rows,
    /// Piece `q` supplies the output's block columns `layout.range(q)`.
    Cols,
}

/// One operand part: a dense block or an exactly-sparse CSR block.
#[derive(Debug, Clone)]
pub enum Block {
    Dense(Mat),
    Sparse(Csr),
}

impl Block {
    pub fn rows(&self) -> usize {
        match self {
            Block::Dense(m) => m.rows(),
            Block::Sparse(c) => c.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Block::Dense(m) => m.cols(),
            Block::Sparse(c) => c.cols(),
        }
    }

    /// Words moved when this part is shifted (paper's W convention:
    /// matrix elements; nnz for sparse parts).
    pub fn words(&self) -> u64 {
        match self {
            Block::Dense(m) => (m.rows() * m.cols()) as u64,
            Block::Sparse(c) => c.nnz() as u64,
        }
    }

    /// Dense view; panics on sparse blocks (callers know their layouts).
    pub fn as_dense(&self) -> &Mat {
        match self {
            Block::Dense(m) => m,
            Block::Sparse(_) => panic!("expected dense block"),
        }
    }

    /// C = self · B with the flop split the cost model prices:
    /// returns (product, dense flops, sparse flops).
    pub fn matmul(&self, b: &Mat) -> (Mat, u64, u64) {
        self.matmul_mt(b, 1)
    }

    /// [`Block::matmul`] on `threads` node-local threads (bit-identical
    /// to the serial product at any thread count).
    pub fn matmul_mt(&self, b: &Mat, threads: usize) -> (Mat, u64, u64) {
        match self {
            Block::Dense(m) => {
                let flops = 2 * (m.rows() * m.cols() * b.cols()) as u64;
                (m.matmul_mt(b, threads), flops, 0)
            }
            Block::Sparse(c) => {
                let flops = c.spmm_flops(b.cols());
                (c.spmm_mt(b, threads), 0, flops)
            }
        }
    }

    /// Flatten to an f64 wire payload (prefixed with kind + shape).
    pub fn encode(&self) -> Vec<f64> {
        match self {
            Block::Dense(m) => {
                let mut v = Vec::with_capacity(3 + m.rows() * m.cols());
                v.push(0.0);
                v.push(m.rows() as f64);
                v.push(m.cols() as f64);
                v.extend_from_slice(m.data());
                v
            }
            Block::Sparse(c) => {
                let mut v = Vec::with_capacity(4 + c.rows() + 1 + 2 * c.nnz());
                v.push(1.0);
                v.push(c.rows() as f64);
                v.push(c.cols() as f64);
                v.push(c.nnz() as f64);
                v.extend(c.indptr().iter().map(|&i| i as f64));
                v.extend(c.indices().iter().map(|&j| j as f64));
                v.extend_from_slice(c.values());
                v
            }
        }
    }

    /// Inverse of [`Block::encode`].
    pub fn decode(buf: &[f64]) -> Block {
        assert!(buf.len() >= 3, "block payload too short");
        let kind = buf[0];
        let rows = buf[1] as usize;
        let cols = buf[2] as usize;
        if kind == 0.0 {
            assert_eq!(buf.len(), 3 + rows * cols, "dense payload size");
            Block::Dense(Mat::from_vec(rows, cols, buf[3..].to_vec()))
        } else {
            let nnz = buf[3] as usize;
            let mut off = 4;
            let indptr: Vec<usize> = buf[off..off + rows + 1].iter().map(|&v| v as usize).collect();
            off += rows + 1;
            let indices: Vec<usize> = buf[off..off + nnz].iter().map(|&v| v as usize).collect();
            off += nnz;
            let values = buf[off..off + nnz].to_vec();
            assert_eq!(off + nnz, buf.len(), "sparse payload size");
            Block::Sparse(Csr::from_raw(rows, cols, indptr, indices, values))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn dense_roundtrip_and_words() {
        let mut rng = Rng::new(1);
        let m = rand_mat(&mut rng, 3, 5);
        let b = Block::Dense(m.clone());
        assert_eq!(b.words(), 15);
        match Block::decode(&b.encode()) {
            Block::Dense(d) => assert_eq!(d, m),
            _ => panic!("kind flipped"),
        }
    }

    #[test]
    fn sparse_roundtrip_counts_nnz() {
        let mut rng = Rng::new(2);
        let dense = Mat::from_fn(6, 4, |_, _| if rng.uniform() < 0.3 { rng.normal() } else { 0.0 });
        let c = Csr::from_dense(&dense, 0.0);
        let b = Block::Sparse(c.clone());
        assert_eq!(b.words(), c.nnz() as u64);
        match Block::decode(&b.encode()) {
            Block::Sparse(d) => assert_eq!(d, c),
            _ => panic!("kind flipped"),
        }
    }

    #[test]
    fn matmul_matches_dense_reference_and_flop_split() {
        let mut rng = Rng::new(3);
        let a = rand_mat(&mut rng, 4, 6);
        let b = rand_mat(&mut rng, 6, 3);
        let (c_dense, fd, fs) = Block::Dense(a.clone()).matmul(&b);
        assert_eq!(fd, 2 * 4 * 6 * 3);
        assert_eq!(fs, 0);
        assert!(c_dense.max_abs_diff(&a.matmul(&b)) == 0.0);

        let sp = Csr::from_dense(&a, 0.0);
        let (c_sp, fd2, fs2) = Block::Sparse(sp.clone()).matmul(&b);
        assert_eq!(fd2, 0);
        assert_eq!(fs2, sp.spmm_flops(3));
        assert!(c_sp.max_abs_diff(&a.matmul(&b)) < 1e-12);
    }
}
