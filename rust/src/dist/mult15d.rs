//! The 1.5D multiply schedule (paper Algorithm 4 / Lemma 3.3).
//!
//! [`rotate_parts`] moves the rotating operand's parts with a
//! designated-source schedule: part `q`'s `c_R` replicas split the
//! `P/c_F` ranks that need it, so every rank sends at most
//! `P/(c_R·c_F)` messages of `nnz(part)` words — i.e. `nnz(R)/c_F`
//! words total — exactly Lemma 3.3's per-processor counts (pinned in
//! the tests below and cross-checked at solver level in
//! `rust/tests/lemma_counts.rs`).
//!
//! [`mult_concat`] and [`mult_sum`] wrap the rotation with the result
//! combine over the *stationary* grid's replica teams: the `c_F`
//! replicas of a stationary part each process a disjoint `T_R/c_F`
//! chunk of the rotating parts, then allgather (concat mode) or
//! sum-reduce (sum mode) so every rank ends with the full product.

use super::block::{Block, ConcatAxis};
use super::layout::{Layout1D, RepGrid};
use crate::linalg::Mat;
use crate::simnet::Comm;

/// Visit `T_R/c_F` rotating parts on every rank (ascending part order),
/// shifting each from a deterministic source replica. `mine` is this
/// rank's own part (its R-team's). The visitor receives the global part
/// index and the part itself.
///
/// Schedule invariants (Lemma 3.3):
/// - the `c_F` stationary layers partition the `T_R` parts into
///   contiguous chunks, so a stationary team's replicas jointly see
///   every part exactly once;
/// - per-rank sends ≤ `P/(c_R·c_F)` messages and
///   ≤ `nnz(part)·P/(c_R·c_F) = nnz(R)/c_F` words;
/// - ranks that already hold a part never receive it (replicas serve
///   only non-holders).
pub fn rotate_parts(
    comm: &mut Comm,
    grid_r: &RepGrid,
    grid_f: &RepGrid,
    tag: u64,
    mine: &Block,
    mut visit: impl FnMut(&mut Comm, usize, &Block),
) {
    let p = comm.size();
    assert_eq!(grid_r.size(), p, "rotating grid size mismatch");
    assert_eq!(grid_f.size(), p, "stationary grid size mismatch");
    let t_r = grid_r.teams();
    let c_r = grid_r.layers();
    let t_f = grid_f.teams();
    let c_f = grid_f.layers();
    assert_eq!(
        t_r % c_f,
        0,
        "1.5D schedule needs c_F | T_R (T_R = {t_r}, c_F = {c_f}; require c_R·c_F ≤ P)"
    );
    let chunk = t_r / c_f;
    let rank = comm.rank();
    let my_r_team = grid_r.team_of(rank);
    let my_r_layer = grid_r.layer_of(rank);

    // Phase 1 — serve: my part belongs to exactly one stationary layer's
    // chunk; among that layer's ranks, those whose in-layer position maps
    // to my replica layer fetch from me. All sends are posted before any
    // receive (channels are unbounded, so this cannot deadlock).
    let consumer_layer = my_r_team / chunk;
    let payload = mine.encode();
    let words = mine.words();
    for pos in 0..t_f {
        let dest = consumer_layer * t_f + pos;
        if dest == rank || grid_r.team_of(dest) == my_r_team {
            continue; // self, or a fellow replica that already holds it
        }
        if pos % c_r == my_r_layer {
            comm.send_with_words(dest, tag + my_r_team as u64, payload.clone(), words);
        }
    }

    // Phase 2 — visit my chunk in ascending part order.
    let my_f_layer = grid_f.layer_of(rank);
    let my_pos = rank % t_f; // position within my stationary layer
    for q in (my_f_layer * chunk)..((my_f_layer + 1) * chunk) {
        if q == my_r_team {
            visit(comm, q, mine);
        } else {
            let src = (my_pos % c_r) * t_r + q;
            let buf = comm.recv(src, tag + q as u64);
            let blk = Block::decode(&buf);
            visit(comm, q, &blk);
        }
    }
}

/// 1.5D concat-mode multiply: every rank computes `local(q, part_q)` for
/// its chunk of rotating parts, then the stationary replica team
/// allgathers the pieces so each rank ends with all `T_R` pieces
/// concatenated along `axis` in part order. `other_dim` is the pieces'
/// shared non-concatenated dimension.
#[allow(clippy::too_many_arguments)]
pub fn mult_concat(
    comm: &mut Comm,
    grid_r: &RepGrid,
    grid_f: &RepGrid,
    tag: u64,
    mine: &Block,
    axis: ConcatAxis,
    layout_r: &Layout1D,
    other_dim: usize,
    mut local: impl FnMut(&mut Comm, usize, &Block) -> Mat,
) -> Mat {
    let t_r = grid_r.teams();
    assert_eq!(layout_r.parts(), t_r, "rotation layout must match the rotating grid");
    let mut pieces: Vec<(usize, Mat)> = Vec::new();
    rotate_parts(comm, grid_r, grid_f, tag, mine, |comm, q, blk| {
        let out = local(comm, q, blk);
        let want = match axis {
            ConcatAxis::Rows => (layout_r.len(q), other_dim),
            ConcatAxis::Cols => (other_dim, layout_r.len(q)),
        };
        assert_eq!(out.shape(), want, "piece {q} has the wrong shape");
        pieces.push((q, out));
    });

    let rank = comm.rank();
    let c_f = grid_f.layers();
    let total = layout_r.total();
    let mut out = match axis {
        ConcatAxis::Rows => Mat::zeros(total, other_dim),
        ConcatAxis::Cols => Mat::zeros(other_dim, total),
    };
    let mut place = |q: usize, data: &[f64]| {
        let (s, e) = layout_r.range(q);
        match axis {
            ConcatAxis::Rows => {
                let w = other_dim;
                for r in s..e {
                    out.row_mut(r)[..w].copy_from_slice(&data[(r - s) * w..(r - s + 1) * w]);
                }
            }
            ConcatAxis::Cols => {
                let w = e - s;
                for i in 0..other_dim {
                    out.row_mut(i)[s..e].copy_from_slice(&data[i * w..(i + 1) * w]);
                }
            }
        }
    };

    if c_f == 1 {
        // My chunk is all of them; no combine needed.
        for (q, m) in &pieces {
            place(*q, m.data());
        }
        return out;
    }

    // Bundle my pieces (ascending q), allgather over the stationary
    // replica team (ordered by layer — i.e. by chunk), then place every
    // layer's pieces by its chunk's shapes.
    let chunk = t_r / c_f;
    let mut bundle = Vec::new();
    for (_, m) in &pieces {
        bundle.extend_from_slice(m.data());
    }
    let group = grid_f.team_members(grid_f.team_of(rank));
    let all = comm.allgather(&group, tag + grid_r.size() as u64 + 1, bundle);
    for (layer, data) in all.iter().enumerate() {
        let mut off = 0;
        for q in (layer * chunk)..((layer + 1) * chunk) {
            let n = layout_r.len(q) * other_dim;
            place(q, &data[off..off + n]);
            off += n;
        }
        assert_eq!(off, data.len(), "bundle size from layer {layer}");
    }
    out
}

/// 1.5D sum-mode multiply: every rank accumulates `local(q, part_q)`
/// over its chunk (ascending part order), then the stationary replica
/// team sum-reduces, leaving the full `out_rows × out_cols` sum on every
/// rank. The reduction is the deterministic butterfly in
/// [`Comm::sum_reduce`], so results are identical across runs.
#[allow(clippy::too_many_arguments)]
pub fn mult_sum(
    comm: &mut Comm,
    grid_r: &RepGrid,
    grid_f: &RepGrid,
    tag: u64,
    mine: &Block,
    out_rows: usize,
    out_cols: usize,
    mut local: impl FnMut(&mut Comm, usize, &Block) -> Mat,
) -> Mat {
    let mut acc = Mat::zeros(out_rows, out_cols);
    rotate_parts(comm, grid_r, grid_f, tag, mine, |comm, q, blk| {
        let part = local(comm, q, blk);
        assert_eq!(part.shape(), (out_rows, out_cols), "partial {q} has the wrong shape");
        acc.add_scaled(1.0, &part);
    });
    let group = grid_f.team_members(grid_f.team_of(comm.rank()));
    if group.len() <= 1 {
        return acc;
    }
    let data = comm.sum_reduce(&group, tag + grid_r.size() as u64 + 1, acc.data().to_vec());
    Mat::from_vec(out_rows, out_cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Csr;
    use crate::rng::Rng;
    use crate::simnet::Fabric;
    use std::sync::Arc;

    /// Lemma 3.3, pinned: per-rank messages ≤ P/(c_R·c_F) and words ≤
    /// nnz(R)/c_F, with equality when no requester is itself a holder.
    #[test]
    fn rotation_counts_match_lemma33() {
        let p_ranks = 16;
        for (c_r, c_f) in [(1usize, 1usize), (1, 2), (2, 2), (2, 4), (4, 2), (1, 16)] {
            let grid_r = RepGrid::new(p_ranks, c_r);
            let grid_f = RepGrid::new(p_ranks, c_f);
            let elems = 6u64; // 2×3 dense part
            let run = Fabric::new(p_ranks).run(move |comm| {
                let mine = Block::Dense(Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64));
                let mut seen = Vec::new();
                rotate_parts(comm, &grid_r, &grid_f, 0, &mine, |_c, q, _b| seen.push(q));
                seen
            });
            let bound_msgs = (p_ranks / (c_r * c_f)) as u64;
            let bound_words = (grid_r.teams() as u64 * elems) / c_f as u64;
            for (rank, c) in run.counters.iter().enumerate() {
                assert!(
                    c.messages <= bound_msgs,
                    "rank {rank}: {} msgs > {bound_msgs} (c_R={c_r}, c_F={c_f})",
                    c.messages
                );
                assert!(
                    c.words <= bound_words,
                    "rank {rank}: {} words > {bound_words} (c_R={c_r}, c_F={c_f})",
                    c.words
                );
            }
            // Coverage: each stationary team's replicas see every part
            // exactly once, in ascending order.
            let t_r = grid_r.teams();
            for team in 0..grid_f.teams() {
                let mut all: Vec<usize> = grid_f
                    .team_members(team)
                    .iter()
                    .flat_map(|&r| run.results[r].clone())
                    .collect();
                all.sort_unstable();
                assert_eq!(all, (0..t_r).collect::<Vec<_>>(), "c_R={c_r} c_F={c_f}");
            }
        }
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    /// Concat-mode W = Ω·S against the serial product, dense and sparse
    /// rotating operands, across replication configurations.
    #[test]
    fn mult_concat_matches_serial_product() {
        let p_dim = 12;
        let width = 5;
        let mut rng = Rng::new(7);
        let omega = {
            let mut m = rand_mat(&mut rng, p_dim, p_dim);
            // sparsify to exercise the CSR path
            for i in 0..p_dim {
                for j in 0..p_dim {
                    if (i + j) % 3 == 0 && i != j {
                        m.set(i, j, 0.0);
                    }
                }
            }
            m
        };
        let s = Arc::new(rand_mat(&mut rng, p_dim, width));
        let want = omega.matmul(&s);
        let omega = Arc::new(omega);

        for (p_ranks, c_r, c_f) in
            [(4usize, 1usize, 1usize), (4, 2, 1), (4, 1, 2), (8, 2, 2), (8, 2, 4)]
        {
            let grid_r = RepGrid::new(p_ranks, c_r);
            let grid_f = RepGrid::new(p_ranks, c_f);
            let layout = Layout1D::new(p_dim, grid_r.teams());
            let omega = omega.clone();
            let s = s.clone();
            let run = Fabric::new(p_ranks).run(move |comm| {
                let (rs, re) = layout.range(grid_r.team_of(comm.rank()));
                let mine = Block::Sparse(Csr::from_dense(&omega.row_block(rs, re), 0.0));
                mult_concat(
                    comm,
                    &grid_r,
                    &grid_f,
                    10,
                    &mine,
                    ConcatAxis::Rows,
                    &layout,
                    width,
                    |_c, _q, blk| blk.matmul(&s).0,
                )
            });
            for (rank, got) in run.results.iter().enumerate() {
                assert!(
                    got.max_abs_diff(&want) < 1e-12,
                    "P={p_ranks} c_R={c_r} c_F={c_f} rank={rank}"
                );
            }
        }
    }

    /// Sum-mode Y = Ω·Xᵀ against the serial product.
    #[test]
    fn mult_sum_matches_serial_product() {
        let p_dim = 8;
        let n = 6;
        let mut rng = Rng::new(8);
        let omega = rand_mat(&mut rng, p_dim, p_dim);
        let xt = Arc::new(rand_mat(&mut rng, p_dim, n)); // Xᵀ: p × n
        let want = omega.matmul(&xt);
        let omega = Arc::new(omega);

        for (p_ranks, c_x, c_o) in [(4usize, 1usize, 1usize), (4, 2, 2), (8, 2, 4), (8, 4, 2)] {
            let grid_x = RepGrid::new(p_ranks, c_x);
            let grid_o = RepGrid::new(p_ranks, c_o);
            let lx = Layout1D::new(p_dim, grid_x.teams());
            let lo = Layout1D::new(p_dim, grid_o.teams());
            let omega = omega.clone();
            let xt = xt.clone();
            let run = Fabric::new(p_ranks).run(move |comm| {
                let rank = comm.rank();
                // My rotating part: Xᵀ's block rows on the X grid.
                let (ks, ke) = lx.range(grid_x.team_of(rank));
                let mine = Block::Dense(xt.row_block(ks, ke));
                // My stationary rows of Ω on the Ω grid.
                let (os, oe) = lo.range(grid_o.team_of(rank));
                let om_rows = omega.row_block(os, oe);
                let y = mult_sum(
                    comm,
                    &grid_x,
                    &grid_o,
                    20,
                    &mine,
                    oe - os,
                    n,
                    |_c, q, blk| {
                        let (s, e) = lx.range(q);
                        om_rows.col_block(s, e).matmul(blk.as_dense())
                    },
                );
                (os, y)
            });
            for (rank, (os, y)) in run.results.iter().enumerate() {
                let rows = y.rows();
                let want_block = want.row_block(*os, os + rows);
                assert!(
                    y.max_abs_diff(&want_block) < 1e-12,
                    "P={p_ranks} c_X={c_x} c_Ω={c_o} rank={rank}"
                );
            }
        }
    }
}
