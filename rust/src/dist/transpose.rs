//! Distributed transpose and block-row redistribution (Lemma 3.2).
//!
//! [`transpose_block_rows`] turns a 1D block-row distribution of a
//! square matrix into the block rows of its transpose. The work is
//! layer-split: replica layer ℓ of each team exchanges only its 1/c
//! row-slice in a within-layer all-to-all (Bruck when the team count is
//! a power of two — `log₂(T)` messages per rank), then the replica team
//! allgathers the c column-slices (`c − 1` messages). That is the
//! `log₂(T) + (c−1)` per-rank message profile the paper's transpose
//! analysis assumes; `rust/tests/lemma_counts.rs` pins it.
//!
//! [`redistribute_rows`] re-layouts block rows from one grid onto
//! another with a designated-source schedule (each destination receives
//! every needed row exactly once); when the grids and layouts coincide
//! it degenerates to a local copy — Algorithm 2's "converts Ω back to
//! 1D block row layout" is free when c_X = c_Ω.

use super::layout::{Layout1D, RepGrid};
use crate::linalg::Mat;
use crate::simnet::Comm;

/// Global row range of replica layer `layer`'s slice of team `team`'s
/// block (the block's rows split evenly over the grid's layers).
fn layer_slice(layout: &Layout1D, layers: usize, team: usize, layer: usize) -> (usize, usize) {
    let (s, e) = layout.range(team);
    let sub = Layout1D::new(e - s, layers);
    let (a, b) = sub.range(layer);
    (s + a, s + b)
}

/// Distributed transpose of a square matrix in 1D block-row layout:
/// `local` is this rank's team's rows `layout.range(team)` (all
/// columns); returns this team's block rows of the transpose plus the
/// words this rank sent. Pure data movement — the result is bit-exact.
pub fn transpose_block_rows(
    comm: &mut Comm,
    grid: &RepGrid,
    tag: u64,
    local: &Mat,
    layout: &Layout1D,
) -> (Mat, u64) {
    let p_total = layout.total();
    assert_eq!(layout.parts(), grid.teams(), "layout must match the grid's teams");
    assert_eq!(local.cols(), p_total, "local block must span all columns");
    let rank = comm.rank();
    let t = grid.teams();
    let c = grid.layers();
    let my_team = grid.team_of(rank);
    let my_layer = grid.layer_of(rank);
    let (rs, re) = layout.range(my_team);
    let my_rows = re - rs;
    assert_eq!(local.rows(), my_rows, "local block must be this team's rows");
    let words_before = comm.counters.words;

    // Part for destination team u: (A[my layer-slice rows, range(u)])ᵀ,
    // i.e. a (len(u) × slice) row-major block of Aᵀ.
    let (ms, me) = layer_slice(layout, c, my_team, my_layer);
    let slice_rows = me - ms;
    let mut parts: Vec<Vec<f64>> = Vec::with_capacity(t);
    for u in 0..t {
        let (us, ue) = layout.range(u);
        let mut part = Vec::with_capacity((ue - us) * slice_rows);
        for col in us..ue {
            for row in ms..me {
                part.push(local.get(row - rs, col));
            }
        }
        parts.push(part);
    }

    let layer_group = grid.layer_members(my_layer);
    let equal_parts = parts.windows(2).all(|w| w[0].len() == w[1].len());
    let received = if t == 1 {
        parts
    } else if t.is_power_of_two() && equal_parts {
        comm.alltoall_bruck(&layer_group, tag, parts)
    } else {
        comm.alltoall_direct(&layer_group, tag, parts)
    };

    // received[u] = (A[u's layer-slice rows, my range])ᵀ: my_rows ×
    // slice(u) — the transpose's columns at u's layer-ℓ slice.
    let mut out = Mat::zeros(my_rows, p_total);
    {
        let mut fill = |u: usize, layer: usize, data: &[f64]| {
            let (cs, ce) = layer_slice(layout, c, u, layer);
            let w = ce - cs;
            assert_eq!(data.len(), my_rows * w, "transpose piece size (u={u}, layer={layer})");
            for i in 0..my_rows {
                out.row_mut(i)[cs..ce].copy_from_slice(&data[i * w..(i + 1) * w]);
            }
        };
        for (u, piece) in received.iter().enumerate() {
            fill(u, my_layer, piece);
        }

        // Replica-team allgather: each layer contributes its column
        // slices so every member ends with all columns.
        if c > 1 {
            let team_group = grid.team_members(my_team);
            let mut bundle = Vec::new();
            for piece in &received {
                bundle.extend_from_slice(piece);
            }
            let all = comm.allgather(&team_group, tag + t as u64 + 1, bundle);
            for (layer, data) in all.iter().enumerate() {
                if layer == my_layer {
                    continue;
                }
                let mut off = 0;
                for u in 0..t {
                    let (cs, ce) = layer_slice(layout, c, u, layer);
                    let n = my_rows * (ce - cs);
                    fill(u, layer, &data[off..off + n]);
                    off += n;
                }
                assert_eq!(off, data.len(), "allgather bundle from layer {layer}");
            }
        }
    }
    (out, comm.counters.words - words_before)
}

/// Move 1D block rows from (`grid_from`, `layout_from`) to (`grid_to`,
/// `layout_to`). `mat` is this rank's from-rows; returns its to-rows.
/// Each destination row is fetched exactly once, from the from-replica
/// whose layer matches the destination layer (mod c_from) — so
/// identical grids/layouts move zero bytes.
pub fn redistribute_rows(
    comm: &mut Comm,
    tag: u64,
    mat: &Mat,
    grid_from: &RepGrid,
    layout_from: &Layout1D,
    grid_to: &RepGrid,
    layout_to: &Layout1D,
) -> Mat {
    let p = comm.size();
    assert_eq!(grid_from.size(), p);
    assert_eq!(grid_to.size(), p);
    assert_eq!(layout_from.total(), layout_to.total(), "row universes differ");
    assert_eq!(layout_from.parts(), grid_from.teams());
    assert_eq!(layout_to.parts(), grid_to.teams());
    let w = mat.cols();
    let rank = comm.rank();
    let my_from_team = grid_from.team_of(rank);
    let my_from_layer = grid_from.layer_of(rank);
    let (fs, fe) = layout_from.range(my_from_team);
    assert_eq!(mat.rows(), fe - fs, "mat must be this rank's from-rows");
    let c_from = grid_from.layers();
    let t_from = grid_from.teams();

    // Send to every destination whose to-range overlaps my rows and
    // whose designated source layer is mine.
    for dest in 0..p {
        if dest == rank || grid_to.layer_of(dest) % c_from != my_from_layer {
            continue;
        }
        let (ts, te) = layout_to.range(grid_to.team_of(dest));
        let (os, oe) = (ts.max(fs), te.min(fe));
        if os >= oe {
            continue;
        }
        let mut payload = Vec::with_capacity((oe - os) * w);
        for r in os..oe {
            payload.extend_from_slice(mat.row(r - fs));
        }
        comm.send(dest, tag + my_from_team as u64, payload);
    }

    // Assemble my to-rows from the overlapping from-teams.
    let (ts, te) = layout_to.range(grid_to.team_of(rank));
    let src_layer = grid_to.layer_of(rank) % c_from;
    let mut out = Mat::zeros(te - ts, w);
    for ft in 0..t_from {
        let (s, e) = layout_from.range(ft);
        let (os, oe) = (ts.max(s), te.min(e));
        if os >= oe {
            continue;
        }
        let src = src_layer * t_from + ft;
        if src == rank {
            for r in os..oe {
                out.row_mut(r - ts).copy_from_slice(mat.row(r - fs));
            }
        } else {
            let payload = comm.recv(src, tag + ft as u64);
            assert_eq!(payload.len(), (oe - os) * w, "redistribute payload from team {ft}");
            for r in os..oe {
                out.row_mut(r - ts)
                    .copy_from_slice(&payload[(r - os) * w..(r - os + 1) * w]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::simnet::Fabric;
    use std::sync::Arc;

    #[test]
    fn transpose_is_exact_across_replication() {
        let p_dim = 24;
        let mut rng = Rng::new(11);
        let a = Arc::new(Mat::from_fn(p_dim, p_dim, |_, _| rng.normal()));
        let at = a.transpose();
        for (p_ranks, c) in [(4usize, 1usize), (8, 2), (8, 4), (16, 4), (6, 2)] {
            let grid = RepGrid::new(p_ranks, c);
            let layout = Layout1D::new(p_dim, grid.teams());
            let a = a.clone();
            let run = Fabric::new(p_ranks).run(move |comm| {
                let team = grid.team_of(comm.rank());
                let (s, e) = layout.range(team);
                let local = a.row_block(s, e);
                let (t, _) = transpose_block_rows(comm, &grid, 3, &local, &layout);
                (s, t)
            });
            for (rank, (s, t)) in run.results.iter().enumerate() {
                let want = at.row_block(*s, s + t.rows());
                assert!(
                    t.max_abs_diff(&want) == 0.0,
                    "P={p_ranks} c={c} rank={rank}: transpose not exact"
                );
            }
        }
    }

    #[test]
    fn transpose_message_profile_is_log_t_plus_c_minus_1() {
        let p_dim = 32;
        let a = Arc::new(Mat::from_fn(p_dim, p_dim, |i, j| (i * p_dim + j) as f64));
        for (p_ranks, c) in [(8usize, 1usize), (8, 2), (16, 4)] {
            let grid = RepGrid::new(p_ranks, c);
            let layout = Layout1D::new(p_dim, grid.teams());
            let a = a.clone();
            let run = Fabric::new(p_ranks).run(move |comm| {
                let (s, e) = layout.range(grid.team_of(comm.rank()));
                let local = a.row_block(s, e);
                transpose_block_rows(comm, &grid, 5, &local, &layout);
            });
            let t = grid.teams() as u64;
            let want = t.trailing_zeros() as u64 + (c as u64 - 1);
            for counters in &run.counters {
                assert_eq!(
                    counters.messages, want,
                    "P={p_ranks} c={c}: log2({t}) Bruck + (c-1) allgather"
                );
            }
        }
    }

    #[test]
    fn redistribute_between_grids_is_exact() {
        let p_dim = 16;
        let width = 7;
        let mut rng = Rng::new(12);
        let a = Arc::new(Mat::from_fn(p_dim, width, |_, _| rng.normal()));
        for (p_ranks, c_from, c_to) in
            [(8usize, 2usize, 1usize), (8, 1, 2), (8, 2, 4), (8, 4, 2), (4, 1, 1)]
        {
            let gf = RepGrid::new(p_ranks, c_from);
            let gt = RepGrid::new(p_ranks, c_to);
            let lf = Layout1D::new(p_dim, gf.teams());
            let lt = Layout1D::new(p_dim, gt.teams());
            let a = a.clone();
            let run = Fabric::new(p_ranks).run(move |comm| {
                let (s, e) = lf.range(gf.team_of(comm.rank()));
                let mine = a.row_block(s, e);
                let out = redistribute_rows(comm, 9, &mine, &gf, &lf, &gt, &lt);
                (lt.range(gt.team_of(comm.rank())).0, out)
            });
            for (rank, (s, out)) in run.results.iter().enumerate() {
                let want = a.row_block(*s, s + out.rows());
                assert!(
                    out.max_abs_diff(&want) == 0.0,
                    "P={p_ranks} c_from={c_from} c_to={c_to} rank={rank}"
                );
            }
        }
    }

    #[test]
    fn redistribute_identical_grids_moves_nothing() {
        let p_dim = 12;
        let a = Arc::new(Mat::from_fn(p_dim, 3, |i, j| (i * 3 + j) as f64));
        let grid = RepGrid::new(8, 2);
        let layout = Layout1D::new(p_dim, grid.teams());
        let run = Fabric::new(8).run(move |comm| {
            let (s, e) = layout.range(grid.team_of(comm.rank()));
            let mine = a.row_block(s, e);
            let out = redistribute_rows(comm, 4, &mine, &grid, &layout, &grid, &layout);
            out.max_abs_diff(&mine)
        });
        assert!(run.results.iter().all(|&d| d == 0.0));
        for c in &run.counters {
            assert_eq!(c.messages, 0, "same-grid redistribution must be free");
            assert_eq!(c.words, 0);
        }
    }
}
