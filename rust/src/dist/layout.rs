//! Process grids and 1D block layouts.
//!
//! A [`RepGrid`] arranges `P` ranks as `c` replication *layers* ×
//! `T = P/c` *teams* (rank = layer·T + team). Every team owns one part
//! of the partitioned operand and its `c` replicas (one per layer) hold
//! identical copies. A *layer group* (one rank per team) covers every
//! part exactly once — it is the group the solvers' global reductions
//! run over. [`Layout1D`] is the balanced contiguous partition of `p`
//! rows (or columns) over the grid's teams.

/// A `c`-way replicated process grid over `P` ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RepGrid {
    p: usize,
    c: usize,
}

impl RepGrid {
    /// `p_ranks` ranks with replication factor `c` (must divide evenly).
    pub fn new(p_ranks: usize, c: usize) -> Self {
        assert!(c >= 1, "replication factor must be >= 1");
        assert!(p_ranks >= c, "need at least c ranks (P={p_ranks}, c={c})");
        assert_eq!(p_ranks % c, 0, "c must divide P (P={p_ranks}, c={c})");
        RepGrid { p: p_ranks, c }
    }

    /// Total ranks P.
    pub fn size(&self) -> usize {
        self.p
    }

    /// Replication factor c (number of layers).
    pub fn layers(&self) -> usize {
        self.c
    }

    /// Number of teams T = P/c (distinct operand parts).
    pub fn teams(&self) -> usize {
        self.p / self.c
    }

    /// Team index of a rank.
    pub fn team_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.p);
        rank % self.teams()
    }

    /// Layer index of a rank.
    pub fn layer_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.p);
        rank / self.teams()
    }

    /// Rank at (layer, team).
    pub fn rank_at(&self, layer: usize, team: usize) -> usize {
        debug_assert!(layer < self.c && team < self.teams());
        layer * self.teams() + team
    }

    /// All ranks in a layer, ascending team order (one rank per team).
    pub fn layer_members(&self, layer: usize) -> Vec<usize> {
        (0..self.teams()).map(|t| self.rank_at(layer, t)).collect()
    }

    /// All replicas of a team, ascending layer order (`c` ranks).
    pub fn team_members(&self, team: usize) -> Vec<usize> {
        (0..self.c).map(|l| self.rank_at(l, team)).collect()
    }
}

/// Balanced contiguous 1D partition of `total` indices over `parts`
/// slots: the first `total % parts` slots get one extra index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout1D {
    total: usize,
    parts: usize,
}

impl Layout1D {
    pub fn new(total: usize, parts: usize) -> Self {
        assert!(parts >= 1, "need at least one part");
        Layout1D { total, parts }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn parts(&self) -> usize {
        self.parts
    }

    /// Half-open index range of part `i`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        assert!(i < self.parts, "part {i} out of {} parts", self.parts);
        let base = self.total / self.parts;
        let rem = self.total % self.parts;
        let start = i * base + i.min(rem);
        let len = base + usize::from(i < rem);
        (start, start + len)
    }

    /// Length of part `i`.
    pub fn len(&self, i: usize) -> usize {
        let (s, e) = self.range(i);
        e - s
    }

    /// True when some part is empty (total < parts).
    pub fn is_empty(&self) -> bool {
        self.total < self.parts
    }

    /// The part owning global index `idx`.
    pub fn owner_of(&self, idx: usize) -> usize {
        assert!(idx < self.total);
        let base = self.total / self.parts;
        let rem = self.total % self.parts;
        let fat = rem * (base + 1); // indices covered by the fat parts
        if idx < fat {
            idx / (base + 1)
        } else {
            rem + (idx - fat) / base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_indexing_roundtrips() {
        let g = RepGrid::new(16, 4);
        assert_eq!(g.teams(), 4);
        assert_eq!(g.layers(), 4);
        for rank in 0..16 {
            assert_eq!(g.rank_at(g.layer_of(rank), g.team_of(rank)), rank);
        }
        assert_eq!(g.layer_members(1), vec![4, 5, 6, 7]);
        assert_eq!(g.team_members(2), vec![2, 6, 10, 14]);
    }

    #[test]
    fn layer_groups_partition_ranks() {
        let g = RepGrid::new(12, 3);
        let mut seen = vec![false; 12];
        for l in 0..g.layers() {
            for r in g.layer_members(l) {
                assert!(!seen[r]);
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn grid_rejects_nondividing_c() {
        RepGrid::new(10, 3);
    }

    #[test]
    fn layout_ranges_cover_exactly() {
        for (total, parts) in [(16usize, 4usize), (17, 4), (3, 4), (0, 2), (7, 1)] {
            let l = Layout1D::new(total, parts);
            let mut next = 0;
            for i in 0..parts {
                let (s, e) = l.range(i);
                assert_eq!(s, next, "total={total} parts={parts} i={i}");
                assert!(e >= s);
                next = e;
            }
            assert_eq!(next, total);
        }
    }

    #[test]
    fn layout_owner_matches_ranges() {
        for (total, parts) in [(16usize, 4usize), (17, 5), (9, 2)] {
            let l = Layout1D::new(total, parts);
            for idx in 0..total {
                let o = l.owner_of(idx);
                let (s, e) = l.range(o);
                assert!(s <= idx && idx < e, "total={total} parts={parts} idx={idx}");
            }
        }
    }

    #[test]
    fn layout_balance_within_one() {
        let l = Layout1D::new(23, 4);
        let lens: Vec<usize> = (0..4).map(|i| l.len(i)).collect();
        assert_eq!(lens.iter().sum::<usize>(), 23);
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }
}
