//! Runtime execution of the AOT-compiled JAX/Pallas artifacts (L2+L1)
//! from Rust (L3) — plus a pure-Rust twin of every artifact op.
//!
//! `make artifacts` runs `python/compile/aot.py` **once**, lowering the
//! CONCORD step graphs to HLO *text* (`artifacts/*.hlo.txt` + a
//! `manifest.txt` index). This module loads that text through the `xla`
//! crate's PJRT CPU client (`HloModuleProto::from_text_file` →
//! `XlaComputation` → `compile` → `execute`), so Python never runs on
//! the request path.
//!
//! [`native`] implements the same operations in pure Rust at any shape;
//! it is both the fallback when no artifact matches and the oracle for
//! the engine-vs-native equivalence tests (`rust/tests/`).

pub mod engine;
pub mod native;

pub use engine::{Engine, TrialOutput};
