//! Pure-Rust implementations of the AOT artifact operations.
//!
//! Shape-for-shape, value-for-value twins of `python/compile/model.py`'s
//! graphs: the single-node solver runs on these at arbitrary p, and the
//! integration tests assert the PJRT-executed artifacts agree with them
//! to near machine precision.

use crate::concord::ops;
use crate::linalg::{Csr, Mat};

/// S = (1/n)·XᵀX (model.gram).
pub fn gram(x: &Mat) -> Mat {
    let n = x.rows();
    let xt = x.transpose();
    let mut s = xt.matmul(x);
    s.scale(1.0 / n as f64);
    s
}

/// W = Ω·S (model.w_step). Exploits the iterate's exact sparsity via a
/// CSR pass when it pays (density below ~40%), matching the paper's
/// sparse-dense local multiply.
pub fn w_step(omega: &Mat, s: &Mat) -> Mat {
    let p = omega.rows();
    let density = omega.nnz() as f64 / (p * p) as f64;
    if density < 0.4 {
        Csr::from_dense(omega, 0.0).spmm(s)
    } else {
        omega.matmul(s)
    }
}

/// (G, g(Ω)) from the iterate and W = ΩS (model.gradient_obj). Returns
/// g = +∞ when the diagonal is non-positive.
pub fn gradobj(omega: &Mat, w: &Mat, lam2: f64) -> (Mat, f64) {
    let wt = w.transpose();
    let g_mat = ops::gradient_block(omega, w, &wt, 0, lam2);
    let g_val = match ops::objective_parts_block(omega, w, 0) {
        Some([logd, tr, fro]) => -logd + 0.5 * tr + 0.5 * lam2 * fro,
        None => f64::INFINITY,
    };
    (g_mat, g_val)
}

/// Output bundle of one fused line-search trial.
#[derive(Debug, Clone)]
pub struct Trial {
    pub omega_new: Mat,
    pub w_new: Mat,
    pub g_new: f64,
    pub rhs: f64,
    pub accept: bool,
}

/// One fused line-search trial (model.concord_trial): prox step, new W,
/// new objective, sufficient-decrease RHS, accept flag.
pub fn trial(
    omega: &Mat,
    grad: &Mat,
    s: &Mat,
    g_prev: f64,
    tau: f64,
    lam1: f64,
    lam2: f64,
) -> Trial {
    let omega_new = ops::prox_block(omega, grad, 0, tau, lam1);
    let w_new = w_step(&omega_new, s);
    let g_new = match ops::objective_parts_block(&omega_new, &w_new, 0) {
        Some([logd, tr, fro]) => -logd + 0.5 * tr + 0.5 * lam2 * fro,
        None => f64::INFINITY,
    };
    let ls = ops::linesearch_parts_block(omega, &omega_new, grad);
    let rhs = g_prev - ls[0] + ls[1] / (2.0 * tau);
    Trial { omega_new, w_new, g_new, rhs, accept: g_new <= rhs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn gram_matches_definition() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(7, 5, |_, _| rng.normal());
        let s = gram(&x);
        for i in 0..5 {
            for j in 0..5 {
                let mut want = 0.0;
                for k in 0..7 {
                    want += x.get(k, i) * x.get(k, j);
                }
                want /= 7.0;
                assert!((s.get(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn w_step_sparse_and_dense_paths_agree() {
        let mut rng = Rng::new(2);
        let p = 20;
        // Sparse iterate (density ~0.1) exercises the CSR path.
        let omega = Mat::from_fn(p, p, |i, j| {
            if i == j {
                1.5
            } else if rng.uniform() < 0.1 {
                rng.normal()
            } else {
                0.0
            }
        });
        let s = Mat::from_fn(p, p, |_, _| rng.normal());
        let got = w_step(&omega, &s);
        let want = omega.matmul(&s);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn trial_accept_consistency() {
        let mut rng = Rng::new(3);
        let p = 8;
        let x = Mat::from_fn(30, p, |_, _| rng.normal());
        let s = gram(&x);
        let omega = Mat::eye(p);
        let w = w_step(&omega, &s);
        let (grad, g0) = gradobj(&omega, &w, 0.1);
        // Small enough tau must accept (Lipschitz smooth part).
        let mut tau = 1.0;
        let mut accepted = false;
        for _ in 0..60 {
            let t = trial(&omega, &grad, &s, g0, tau, 0.3, 0.1);
            assert_eq!(t.accept, t.g_new <= t.rhs);
            if t.accept {
                accepted = true;
                assert!(t.g_new.is_finite());
                break;
            }
            tau *= 0.5;
        }
        assert!(accepted);
    }

    #[test]
    fn trial_infinite_objective_on_bad_diagonal() {
        // A huge tau drives the diagonal negative; g_new must be +inf
        // and the trial rejected.
        let p = 4;
        let omega = Mat::eye(p);
        let grad = Mat::from_fn(p, p, |i, j| if i == j { 100.0 } else { 0.0 });
        let s = Mat::eye(p);
        let t = trial(&omega, &grad, &s, 0.0, 1.0, 0.1, 0.0);
        assert!(t.g_new.is_infinite());
        assert!(!t.accept);
    }
}
