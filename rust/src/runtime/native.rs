//! Pure-Rust implementations of the AOT artifact operations.
//!
//! Shape-for-shape, value-for-value twins of `python/compile/model.py`'s
//! graphs: the single-node solver runs on these at arbitrary p, and the
//! integration tests assert the PJRT-executed artifacts agree with them
//! to near machine precision.
//!
//! Every operation has a `_mt` form taking the node-local thread count
//! (the paper's per-node `t`); the plain forms are the serial `t = 1`
//! case. All `_mt` results are identical at any thread count (matrix
//! passes bit-for-bit, scalar reductions via the fixed-block order of
//! [`ops::REDUCE_BLOCK_ROWS`]).

use crate::concord::ops;
use crate::linalg::{Csr, Mat};

/// S = (1/n)·XᵀX (model.gram).
pub fn gram(x: &Mat) -> Mat {
    gram_mt(x, 1)
}

/// [`gram`] on `threads` node-local workers.
pub fn gram_mt(x: &Mat, threads: usize) -> Mat {
    let n = x.rows();
    let xt = x.transpose();
    let mut s = xt.matmul_mt(x, threads);
    s.scale(1.0 / n as f64);
    s
}

/// W = Ω·S (model.w_step). Exploits the iterate's exact sparsity via a
/// CSR pass when it pays (density below ~40%), matching the paper's
/// sparse-dense local multiply.
pub fn w_step(omega: &Mat, s: &Mat) -> Mat {
    w_step_mt(omega, s, 1)
}

/// [`w_step`] on `threads` node-local workers. The sparse/dense routing
/// decision depends only on the iterate's density, so the thread count
/// never changes which kernel runs — only how its rows are partitioned.
pub fn w_step_mt(omega: &Mat, s: &Mat, threads: usize) -> Mat {
    let p = omega.rows();
    let density = omega.nnz() as f64 / (p * p) as f64;
    if density < 0.4 {
        Csr::from_dense(omega, 0.0).spmm_mt(s, threads)
    } else {
        omega.matmul_mt(s, threads)
    }
}

/// (G, g(Ω)) from the iterate and W = ΩS (model.gradient_obj). Returns
/// g = +∞ when the diagonal is non-positive.
pub fn gradobj(omega: &Mat, w: &Mat, lam2: f64) -> (Mat, f64) {
    gradobj_mt(omega, w, lam2, 1)
}

/// [`gradobj`] on `threads` node-local workers.
pub fn gradobj_mt(omega: &Mat, w: &Mat, lam2: f64, threads: usize) -> (Mat, f64) {
    let wt = w.transpose();
    let g_mat = ops::gradient_block_mt(omega, w, &wt, 0, lam2, threads);
    let g_val = match ops::objective_parts_block_mt(omega, w, 0, threads) {
        Some([logd, tr, fro]) => -logd + 0.5 * tr + 0.5 * lam2 * fro,
        None => f64::INFINITY,
    };
    (g_mat, g_val)
}

/// Output bundle of one fused line-search trial.
#[derive(Debug, Clone)]
pub struct Trial {
    pub omega_new: Mat,
    pub w_new: Mat,
    pub g_new: f64,
    pub rhs: f64,
    pub accept: bool,
}

/// One fused line-search trial (model.concord_trial): prox step, new W,
/// new objective, sufficient-decrease RHS, accept flag.
pub fn trial(
    omega: &Mat,
    grad: &Mat,
    s: &Mat,
    g_prev: f64,
    tau: f64,
    lam1: f64,
    lam2: f64,
) -> Trial {
    trial_mt(omega, grad, s, g_prev, tau, lam1, lam2, 1)
}

/// [`trial`] on `threads` node-local workers.
#[allow(clippy::too_many_arguments)]
pub fn trial_mt(
    omega: &Mat,
    grad: &Mat,
    s: &Mat,
    g_prev: f64,
    tau: f64,
    lam1: f64,
    lam2: f64,
    threads: usize,
) -> Trial {
    let omega_new = ops::prox_block_mt(omega, grad, 0, tau, lam1, threads);
    let w_new = w_step_mt(&omega_new, s, threads);
    let g_new = match ops::objective_parts_block_mt(&omega_new, &w_new, 0, threads) {
        Some([logd, tr, fro]) => -logd + 0.5 * tr + 0.5 * lam2 * fro,
        None => f64::INFINITY,
    };
    let ls = ops::linesearch_parts_block_mt(omega, &omega_new, grad, threads);
    let rhs = g_prev - ls[0] + ls[1] / (2.0 * tau);
    Trial { omega_new, w_new, g_new, rhs, accept: g_new <= rhs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn gram_matches_definition() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(7, 5, |_, _| rng.normal());
        let s = gram(&x);
        for i in 0..5 {
            for j in 0..5 {
                let mut want = 0.0;
                for k in 0..7 {
                    want += x.get(k, i) * x.get(k, j);
                }
                want /= 7.0;
                assert!((s.get(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn w_step_sparse_and_dense_paths_agree() {
        let mut rng = Rng::new(2);
        let p = 20;
        // Sparse iterate (density ~0.1) exercises the CSR path.
        let omega = Mat::from_fn(p, p, |i, j| {
            if i == j {
                1.5
            } else if rng.uniform() < 0.1 {
                rng.normal()
            } else {
                0.0
            }
        });
        let s = Mat::from_fn(p, p, |_, _| rng.normal());
        let got = w_step(&omega, &s);
        let want = omega.matmul(&s);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn trial_accept_consistency() {
        let mut rng = Rng::new(3);
        let p = 8;
        let x = Mat::from_fn(30, p, |_, _| rng.normal());
        let s = gram(&x);
        let omega = Mat::eye(p);
        let w = w_step(&omega, &s);
        let (grad, g0) = gradobj(&omega, &w, 0.1);
        // Small enough tau must accept (Lipschitz smooth part).
        let mut tau = 1.0;
        let mut accepted = false;
        for _ in 0..60 {
            let t = trial(&omega, &grad, &s, g0, tau, 0.3, 0.1);
            assert_eq!(t.accept, t.g_new <= t.rhs);
            if t.accept {
                accepted = true;
                assert!(t.g_new.is_finite());
                break;
            }
            tau *= 0.5;
        }
        assert!(accepted);
    }

    #[test]
    fn trial_infinite_objective_on_bad_diagonal() {
        // A huge tau drives the diagonal negative; g_new must be +inf
        // and the trial rejected.
        let p = 4;
        let omega = Mat::eye(p);
        let grad = Mat::from_fn(p, p, |i, j| if i == j { 100.0 } else { 0.0 });
        let s = Mat::eye(p);
        let t = trial(&omega, &grad, &s, 0.0, 1.0, 0.1, 0.0);
        assert!(t.g_new.is_infinite());
        assert!(!t.accept);
    }

    #[test]
    fn threaded_ops_are_thread_count_invariant() {
        let mut rng = Rng::new(4);
        let p = 70; // spans two reduction blocks
        let x = Mat::from_fn(40, p, |_, _| rng.normal());
        let s1 = gram_mt(&x, 1);
        let omega = Mat::eye(p);
        let w1 = w_step_mt(&omega, &s1, 1);
        let (g1, v1) = gradobj_mt(&omega, &w1, 0.1, 1);
        let t1 = trial_mt(&omega, &g1, &s1, v1, 0.5, 0.3, 0.1, 1);
        for threads in [2usize, 4, 7] {
            let s = gram_mt(&x, threads);
            assert!(s.max_abs_diff(&s1) == 0.0, "gram t={threads}");
            let w = w_step_mt(&omega, &s, threads);
            assert!(w.max_abs_diff(&w1) == 0.0, "w_step t={threads}");
            let (g, v) = gradobj_mt(&omega, &w, 0.1, threads);
            assert!(g.max_abs_diff(&g1) == 0.0, "grad t={threads}");
            assert_eq!(v.to_bits(), v1.to_bits(), "objective t={threads}");
            let t = trial_mt(&omega, &g, &s, v, 0.5, 0.3, 0.1, threads);
            assert!(t.omega_new.max_abs_diff(&t1.omega_new) == 0.0);
            assert!(t.w_new.max_abs_diff(&t1.w_new) == 0.0);
            assert_eq!(t.g_new.to_bits(), t1.g_new.to_bits());
            assert_eq!(t.rhs.to_bits(), t1.rhs.to_bits());
            assert_eq!(t.accept, t1.accept);
        }
    }
}
