//! PJRT engine: load the AOT HLO-text artifacts and execute them.
//!
//! The interchange format is HLO **text** — jax ≥ 0.5 serializes
//! HloModuleProto with 64-bit instruction ids which the image's
//! xla_extension 0.5.1 rejects; `HloModuleProto::from_text_file`
//! re-parses and reassigns ids (see /opt/xla-example/README.md).
//!
//! Executables are compiled lazily (first use per artifact) and cached.
//! All artifacts are lowered with `return_tuple=True`, so outputs are
//! unpacked with `to_tuple`.
//!
//! ## Feature gating
//!
//! The real engine needs the `xla` crate and a `libxla_extension`
//! install, neither of which exists in the default offline image. It is
//! therefore gated behind the non-default `pjrt` cargo feature (add the
//! `xla` dependency locally before enabling it). Without the feature,
//! [`Engine`] keeps the identical public API but `Engine::load` always
//! fails, so every caller takes its native-fallback branch and the PJRT
//! test-suite (`rust/tests/engine_pjrt.rs`) skips cleanly.

use crate::linalg::Mat;

/// Outputs of the fused `concord_trial` artifact.
#[derive(Debug, Clone)]
pub struct TrialOutput {
    pub omega_new: Mat,
    pub w_new: Mat,
    pub g_new: f64,
    pub rhs: f64,
    pub accept: bool,
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::TrialOutput;
    use crate::linalg::Mat;

    /// Stub engine for builds without the `pjrt` feature: the API of the
    /// real PJRT executor, with a `load` that always reports the runtime
    /// as unavailable. Callers treat that as "run the native fallback".
    pub struct Engine {
        _priv: (),
    }

    impl Engine {
        /// Always fails in non-`pjrt` builds.
        pub fn load(_dir: impl AsRef<Path>) -> Result<Engine> {
            bail!(
                "PJRT runtime not available: this binary was built without \
                 the `pjrt` feature (libxla_extension absent); using the \
                 native kernels instead"
            )
        }

        /// Artifact names available (none).
        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }

        /// Problem sizes p with a fused-trial artifact (none).
        pub fn trial_sizes(&self) -> Vec<usize> {
            Vec::new()
        }

        /// One fused line-search trial; unreachable (no artifacts).
        #[allow(clippy::too_many_arguments)]
        pub fn trial(
            &mut self,
            _omega: &Mat,
            _grad: &Mat,
            _s: &Mat,
            _g_prev: f64,
            _tau: f64,
            _lam1: f64,
            _lam2: f64,
        ) -> Result<TrialOutput> {
            bail!("PJRT engine not available (built without the `pjrt` feature)")
        }

        /// (G, g(Ω)); unreachable (no artifacts).
        pub fn gradobj(&mut self, _omega: &Mat, _w: &Mat, _lam2: f64) -> Result<(Mat, f64)> {
            bail!("PJRT engine not available (built without the `pjrt` feature)")
        }

        /// S = XᵀX/n; unreachable (no artifacts).
        pub fn gram(&mut self, _x: &Mat) -> Result<Mat> {
            bail!("PJRT engine not available (built without the `pjrt` feature)")
        }

        /// C = A·B; unreachable (no artifacts).
        pub fn matmul(&mut self, _a: &Mat, _b: &Mat) -> Result<Mat> {
            bail!("PJRT engine not available (built without the `pjrt` feature)")
        }

        /// True when a fused trial artifact exists for size p (never).
        pub fn has_trial(&self, _p: usize) -> bool {
            false
        }
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, bail, Context, Result};

    use super::TrialOutput;
    use crate::linalg::Mat;

    /// Parsed manifest entry.
    #[derive(Debug, Clone)]
    struct ArtifactMeta {
        kind: String,
        file: PathBuf,
        dims: HashMap<String, usize>,
    }

    /// PJRT-backed executor over the artifact directory.
    pub struct Engine {
        client: xla::PjRtClient,
        artifacts: HashMap<String, ArtifactMeta>,
        compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        /// Load the manifest from an artifact directory (built by
        /// `make artifacts`). Fails if the directory or manifest is
        /// missing; callers treat that as "run the native fallback".
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            let dir = dir.as_ref();
            let manifest = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            let mut artifacts = HashMap::new();
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                let mut name = None;
                let mut kind = None;
                let mut file = None;
                let mut dims = HashMap::new();
                for kv in line.split_whitespace() {
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| anyhow!("bad manifest token {kv:?}"))?;
                    match k {
                        "name" => name = Some(v.to_string()),
                        "kind" => kind = Some(v.to_string()),
                        "file" => file = Some(dir.join(v)),
                        _ => {
                            dims.insert(k.to_string(), v.parse::<usize>()?);
                        }
                    }
                }
                let name = name.ok_or_else(|| anyhow!("manifest line missing name: {line}"))?;
                artifacts.insert(
                    name,
                    ArtifactMeta {
                        kind: kind.ok_or_else(|| anyhow!("missing kind"))?,
                        file: file.ok_or_else(|| anyhow!("missing file"))?,
                        dims,
                    },
                );
            }
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Engine { client, artifacts, compiled: HashMap::new() })
        }

        /// Artifact names available.
        pub fn names(&self) -> Vec<&str> {
            self.artifacts.keys().map(|s| s.as_str()).collect()
        }

        /// Problem sizes p with a fused-trial artifact.
        pub fn trial_sizes(&self) -> Vec<usize> {
            let mut v: Vec<usize> = self
                .artifacts
                .values()
                .filter(|a| a.kind == "trial")
                .filter_map(|a| a.dims.get("p").copied())
                .collect();
            v.sort_unstable();
            v
        }

        fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.compiled.contains_key(name) {
                let meta = self
                    .artifacts
                    .get(name)
                    .ok_or_else(|| anyhow!("no artifact named {name}"))?;
                let path = meta
                    .file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path"))?
                    .to_string();
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parse {path}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
                self.compiled.insert(name.to_string(), exe);
            }
            Ok(&self.compiled[name])
        }

        fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let exe = self.executable(name)?;
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
            lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
        }

        /// One fused line-search trial via the `trial_p{p}` artifact.
        #[allow(clippy::too_many_arguments)]
        pub fn trial(
            &mut self,
            omega: &Mat,
            grad: &Mat,
            s: &Mat,
            g_prev: f64,
            tau: f64,
            lam1: f64,
            lam2: f64,
        ) -> Result<TrialOutput> {
            let p = omega.rows();
            let name = format!("trial_p{p}");
            let inputs = vec![
                mat_literal(omega)?,
                mat_literal(grad)?,
                mat_literal(s)?,
                scalar1(g_prev),
                scalar1(tau),
                scalar1(lam1),
                scalar1(lam2),
            ];
            let outs = self.execute(&name, &inputs)?;
            if outs.len() != 5 {
                bail!("trial artifact returned {} outputs, want 5", outs.len());
            }
            let omega_new = literal_mat(&outs[0], p, p)?;
            let w_new = literal_mat(&outs[1], p, p)?;
            let g_new = literal_scalar(&outs[2])?;
            let rhs = literal_scalar(&outs[3])?;
            let accept = literal_scalar(&outs[4])? != 0.0;
            Ok(TrialOutput { omega_new, w_new, g_new, rhs, accept })
        }

        /// (G, g(Ω)) via the `gradobj_p{p}` artifact.
        pub fn gradobj(&mut self, omega: &Mat, w: &Mat, lam2: f64) -> Result<(Mat, f64)> {
            let p = omega.rows();
            let name = format!("gradobj_p{p}");
            let outs =
                self.execute(&name, &[mat_literal(omega)?, mat_literal(w)?, scalar1(lam2)])?;
            Ok((literal_mat(&outs[0], p, p)?, literal_scalar(&outs[1])?))
        }

        /// S = XᵀX/n via the `gram_n{n}_p{p}` artifact (exact-shape only).
        pub fn gram(&mut self, x: &Mat) -> Result<Mat> {
            let (n, p) = x.shape();
            let name = format!("gram_n{n}_p{p}");
            let outs = self.execute(&name, &[mat_literal(x)?])?;
            literal_mat(&outs[0], p, p)
        }

        /// C = A·B via the `matmul_{m}x{k}x{n}` artifact (exact-shape only).
        pub fn matmul(&mut self, a: &Mat, b: &Mat) -> Result<Mat> {
            let (m, k) = a.shape();
            let n = b.cols();
            let name = format!("matmul_{m}x{k}x{n}");
            let outs = self.execute(&name, &[mat_literal(a)?, mat_literal(b)?])?;
            literal_mat(&outs[0], m, n)
        }

        /// True when a fused trial artifact exists for size p.
        pub fn has_trial(&self, p: usize) -> bool {
            self.artifacts.contains_key(&format!("trial_p{p}"))
        }
    }

    fn mat_literal(m: &Mat) -> Result<xla::Literal> {
        xla::Literal::vec1(m.data())
            .reshape(&[m.rows() as i64, m.cols() as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    fn scalar1(v: f64) -> xla::Literal {
        xla::Literal::vec1(&[v])
    }

    fn literal_mat(l: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
        let v = l.to_vec::<f64>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        if v.len() != rows * cols {
            bail!("literal size {} != {rows}x{cols}", v.len());
        }
        Ok(Mat::from_vec(rows, cols, v))
    }

    fn literal_scalar(l: &xla::Literal) -> Result<f64> {
        let v = l.to_vec::<f64>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        v.first().copied().ok_or_else(|| anyhow!("empty literal"))
    }
}

pub use imp::Engine;

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_unavailable() {
        let err = Engine::load("artifacts").unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    #[test]
    fn trial_output_is_plain_data() {
        let t = TrialOutput {
            omega_new: crate::linalg::Mat::eye(2),
            w_new: crate::linalg::Mat::eye(2),
            g_new: 1.0,
            rhs: 2.0,
            accept: true,
        };
        assert!(t.accept && t.g_new < t.rhs);
        assert_eq!(t.omega_new.rows(), t.w_new.rows());
    }
}
