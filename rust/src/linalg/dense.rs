//! Row-major dense f64 matrices and the cache-blocked, packed GEMM
//! kernel layer.
//!
//! The GEMM ([`Mat::matmul_into`]) is the hot path of the whole stack:
//! every local block multiply of the distributed 1.5D algorithm and
//! every single-node CONCORD iteration lands here (unless routed to a
//! PJRT artifact). It is organised BLIS-style around the
//! [`TileConfig`] blocking shape (see [`crate::linalg::tile`]): `nc`
//! columns of B are packed into [`NR`]-wide slivers, `kc × nc` k-panels
//! of that packed B are multiplied against `mc × kc` blocks of A packed
//! into [`MR`]-row slabs, and a fixed `MR × NR` register microkernel
//! does the flops with unit-stride loads from both packed operands.
//!
//! **Determinism rule** (the layer-wide contract pinned by
//! `rust/tests/parallel_determinism.rs`): every output element
//! accumulates in strictly ascending-k order, one `mul` + one `add` per
//! k — never a fused or reassociated grouping. That makes the blocked
//! product bit-for-bit identical to the naive triple loop
//! ([`Mat::matmul_naive`], retained as the oracle and bench baseline)
//! at every tile shape, and identical across any row partition — so
//! the `_mt` drop-ins are bitwise equal to serial at every thread
//! count for free. Tile shapes and threads move wall-clock only.
//!
//! Perf numbers live in `rust/benches/perf_hotpath.rs` (the
//! blocked-vs-naive GFLOP/s and tile-sweep tables).

use std::fmt;

use super::simd;
use super::tile::{self, MR, NR, TileConfig};

/// Row-major dense matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 64 {
            for i in 0..self.rows {
                write!(f, "\n  {:?}", &self.data[i * self.cols..(i + 1) * self.cols])?;
            }
        }
        Ok(())
    }
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Sub-matrix of rows `r0..r1` (cheap copy of contiguous storage).
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Sub-matrix of columns `c0..c1`.
    pub fn col_block(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut data = Vec::with_capacity(self.rows * w);
        for i in 0..self.rows {
            data.extend_from_slice(&self.row(i)[c0..c1]);
        }
        Mat { rows: self.rows, cols: w, data }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Block the transpose for cache locality on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// C = A · B via the blocked packed kernel at the installed
    /// [`tile::current`] shape.
    pub fn matmul(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        self.matmul_into(b, &mut c);
        c
    }

    /// Reference triple-loop product (ascending k, one multiply-add per
    /// step) — the kernel the blocked path must match **bit-for-bit**.
    ///
    /// Retained on the public surface as the determinism oracle of the
    /// tile-edge property tests and the baseline of the
    /// blocked-vs-naive bench table; never used on a hot path.
    pub fn matmul_naive(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "inner dimension mismatch");
        let (m, kk, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..kk {
                    s += self.data[i * kk + k] * b.data[k * n + j];
                }
                c.data[i * n + j] = s;
            }
        }
        c
    }

    /// C = A · B on `threads` node-local workers ([`Mat::matmul_into_mt`]).
    pub fn matmul_mt(&self, b: &Mat, threads: usize) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        self.matmul_into_mt(b, &mut c, threads);
        c
    }

    /// C += A · B (C must be zeroed by the caller for a plain product)
    /// at the installed [`tile::current`] shape.
    pub fn matmul_into(&self, b: &Mat, c: &mut Mat) {
        self.matmul_into_with(b, c, &tile::current());
    }

    /// [`Mat::matmul_into`] at an explicit tile shape. The result is
    /// bitwise invariant in `tile` (see the module docs); tests use
    /// this to sweep tile shapes without touching the process-global.
    pub fn matmul_into_with(&self, b: &Mat, c: &mut Mat, tile: &TileConfig) {
        assert_eq!(self.cols, b.rows, "inner dimension mismatch");
        assert_eq!(c.rows, self.rows);
        assert_eq!(c.cols, b.cols);
        gemm_rows(&self.data, self.cols, &b.data, b.cols, &mut c.data, tile);
    }

    /// [`Mat::matmul_into`] on `threads` node-local workers.
    ///
    /// Rows are partitioned into contiguous chunks (boundaries aligned
    /// to the microkernel height [`MR`] so only the final chunk runs
    /// ragged slabs — a perf nicety, not a correctness need). Each
    /// chunk runs the serial blocked kernel, whose per-element
    /// ascending-k order is row-independent, so the result is
    /// **bit-for-bit identical** to the serial product at every thread
    /// count and tile shape (the determinism property tests pin this).
    pub fn matmul_into_mt(&self, b: &Mat, c: &mut Mat, threads: usize) {
        self.matmul_into_mt_with(b, c, threads, &tile::current());
    }

    /// [`Mat::matmul_into_mt`] at an explicit tile shape.
    pub fn matmul_into_mt_with(&self, b: &Mat, c: &mut Mat, threads: usize, tile: &TileConfig) {
        assert_eq!(self.cols, b.rows, "inner dimension mismatch");
        assert_eq!(c.rows, self.rows);
        assert_eq!(c.cols, b.cols);
        let (m, kk, n) = (self.rows, self.cols, b.cols);
        if threads <= 1 || m < 2 || m * kk * n < crate::util::pool::SPAWN_MIN_WORK {
            gemm_rows(&self.data, kk, &b.data, n, &mut c.data, tile);
            return;
        }
        let ranges = crate::util::pool::chunk_ranges(m, threads, MR);
        let a = &self.data;
        let bd = &b.data;
        crate::util::pool::par_rows_mut(&mut c.data, n, &ranges, |_i, s, e, crows| {
            gemm_rows(&a[s * kk..e * kk], kk, bd, n, crows, tile);
        });
    }

    /// C = A · Bᵀ (used where the transposed layout is already at hand).
    pub fn matmul_bt(&self, b: &Mat) -> Mat {
        self.matmul_bt_mt(b, 1)
    }

    /// [`Mat::matmul_bt`] on `threads` node-local workers.
    ///
    /// Each output element is one independent run of the serial [`dot`]
    /// kernel, whose fixed 4-accumulator grouping never varies — so the
    /// result is bit-identical at any thread count and row tiling. Rows
    /// are processed in [`TileConfig::mc`]-high bands with the B-row
    /// loop outside the band (each streamed B row feeds a whole band of
    /// dots instead of one), which is a pure loop-order/cache change.
    pub fn matmul_bt_mt(&self, b: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, b.cols, "inner dimension mismatch (B is transposed)");
        let (m, kk, n) = (self.rows, self.cols, b.rows);
        let mc = tile::current().mc.max(1);
        let mut c = Mat::zeros(m, n);
        let a = &self.data;
        let bd = &b.data;
        let body = |s: usize, e: usize, crows: &mut [f64]| {
            let mut ic = s;
            while ic < e {
                let ie = (ic + mc).min(e);
                for j in 0..n {
                    let brow = &bd[j * kk..(j + 1) * kk];
                    for i in ic..ie {
                        let arow = &a[i * kk..(i + 1) * kk];
                        crows[(i - s) * n + j] = dot(arow, brow);
                    }
                }
                ic = ie;
            }
        };
        if threads <= 1 || m < 2 || m * kk * n < crate::util::pool::SPAWN_MIN_WORK {
            body(0, m, &mut c.data);
            return c;
        }
        let ranges = crate::util::pool::chunk_ranges(m, threads, 1);
        crate::util::pool::par_rows_mut(&mut c.data, n, &ranges, |_i, s, e, crows| {
            body(s, e, crows)
        });
        c
    }

    /// Elementwise: self += alpha * other.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale all entries.
    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Frobenius norm squared.
    pub fn fro2(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Elementwise dot: sum_ij A_ij B_ij.
    pub fn dot_elem(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        dot(&self.data, &other.data)
    }

    /// Diagonal as a vector (square matrices).
    pub fn diag(&self) -> Vec<f64> {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + i]).collect()
    }

    /// Number of nonzero entries (exact zero test — iterates are exactly
    /// sparse after soft-thresholding).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    /// Symmetrize in place: A <- (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.data[i * self.cols + j] + self.data[j * self.cols + i]);
                self.data[i * self.cols + j] = v;
                self.data[j * self.cols + i] = v;
            }
        }
    }

    /// Stack a list of row blocks (all with equal `cols`) vertically.
    pub fn vstack(blocks: &[Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            assert_eq!(b.cols, cols);
            data.extend_from_slice(&b.data);
        }
        Mat { rows, cols, data }
    }
}

/// The blocked packed GEMM over a contiguous row range: `c += a · b`
/// where `a` holds `r` rows of length `kk` and `c` the matching `r`
/// rows of length `n` (row-major, `b` is `kk × n`). This is the single
/// code path behind the serial and multithreaded matmuls — workers
/// call it on disjoint row chunks.
///
/// Loop nest (BLIS order): `jc` over `nc`-wide B column panels → `pc`
/// over `kc`-deep k-panels (B panel packed once here, reused by every
/// row block) → `ic` over `mc`-high A row blocks (A block packed here)
/// → `NR` slivers × `MR` slabs → microkernel. For a fixed output
/// element the k-panels are visited in ascending `pc` and the
/// microkernel walks each panel in ascending k, so the per-element
/// accumulation order is ascending k regardless of every tile choice —
/// the bitwise-vs-naive contract of the module docs.
fn gemm_rows(a: &[f64], kk: usize, b: &[f64], n: usize, c: &mut [f64], tile: &TileConfig) {
    let m = if kk == 0 {
        if n == 0 {
            0
        } else {
            c.len() / n
        }
    } else {
        a.len() / kk
    };
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || kk == 0 {
        return; // C += 0: nothing to do (matches the naive reference).
    }
    // Allocation-free fallback for tiny products (the simulated
    // fabric's per-rank blocks land here): plain i-k-j, one
    // multiply-add per (element, k) in ascending k — the exact order
    // the packed path produces, so the two paths are bitwise
    // interchangeable and the cutoff can never change results.
    const SMALL_GEMM_FLOPS: usize = 1 << 15;
    if m * kk * n < SMALL_GEMM_FLOPS {
        for i in 0..m {
            let arow = &a[i * kk..(i + 1) * kk];
            let crow = &mut c[i * n..(i + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                let brow = &b[k * n..(k + 1) * n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += aik * bj;
                }
            }
        }
        return;
    }
    let mc = tile.mc.max(1).min(m);
    let kc = tile.kc.max(1).min(kk);
    let nc = tile.nc.max(1).min(n);
    // The full-tile microkernel of the installed ISA lane (scalar,
    // AVX2, or AVX-512) — hoisted out of the nest; every lane is
    // bit-identical to `micro_full` (see `linalg::simd`), so dispatch
    // is as value-free as the tile shape itself.
    let micro = simd::active_micro();
    // Packed panels, padded up to whole MR slabs / NR slivers. Pad
    // lanes are never read (edge kernels bound by irb/jrb), they only
    // keep the slab/sliver stride uniform.
    let mut apack = vec![0.0f64; mc.div_ceil(MR) * MR * kc];
    let mut bpack = vec![0.0f64; nc.div_ceil(NR) * NR * kc];
    for jc in (0..n).step_by(nc) {
        let jb = nc.min(n - jc);
        let nslivers = jb.div_ceil(NR);
        for pc in (0..kk).step_by(kc) {
            let kb = kc.min(kk - pc);
            pack_b(b, n, pc, kb, jc, jb, &mut bpack);
            for ic in (0..m).step_by(mc) {
                let ib = mc.min(m - ic);
                pack_a(a, kk, ic, ib, pc, kb, &mut apack);
                let nslabs = ib.div_ceil(MR);
                for t in 0..nslivers {
                    let jr = t * NR;
                    let jrb = NR.min(jb - jr);
                    let bs = &bpack[t * kb * NR..(t + 1) * kb * NR];
                    for s in 0..nslabs {
                        let ir = s * MR;
                        let irb = MR.min(ib - ir);
                        let aslab = &apack[s * kb * MR..(s + 1) * kb * MR];
                        let coff = (ic + ir) * n + jc + jr;
                        if irb == MR && jrb == NR {
                            micro(aslab, bs, kb, &mut c[coff..], n);
                        } else {
                            micro_edge(aslab, bs, kb, &mut c[coff..], n, irb, jrb);
                        }
                    }
                }
            }
        }
    }
}

/// Pack rows `i0 .. i0+ib`, k-range `k0 .. k0+kb` of `a` into
/// [`MR`]-row slabs, k-major inside each slab (`apack[slab·kb·MR +
/// k·MR + r]`): the microkernel reads one contiguous `MR`-vector per k.
/// Ragged final slabs are zero-padded.
fn pack_a(a: &[f64], kk: usize, i0: usize, ib: usize, k0: usize, kb: usize, apack: &mut [f64]) {
    for s in 0..ib.div_ceil(MR) {
        let slab = &mut apack[s * kb * MR..(s + 1) * kb * MR];
        for k in 0..kb {
            for r in 0..MR {
                let row = s * MR + r;
                slab[k * MR + r] = if row < ib { a[(i0 + row) * kk + k0 + k] } else { 0.0 };
            }
        }
    }
}

/// Pack k-range `k0 .. k0+kb`, columns `j0 .. j0+jb` of `b` (`kk × n`
/// row-major) into [`NR`]-column slivers, k-major inside each sliver
/// (`bpack[sliver·kb·NR + k·NR + j]`). Ragged final slivers are
/// zero-padded.
fn pack_b(b: &[f64], n: usize, k0: usize, kb: usize, j0: usize, jb: usize, bpack: &mut [f64]) {
    for t in 0..jb.div_ceil(NR) {
        let sliver = &mut bpack[t * kb * NR..(t + 1) * kb * NR];
        for k in 0..kb {
            let brow = &b[(k0 + k) * n..(k0 + k + 1) * n];
            for j in 0..NR {
                let col = t * NR + j;
                sliver[k * NR + j] = if col < jb { brow[j0 + col] } else { 0.0 };
            }
        }
    }
}

/// The scalar register microkernel: a full [`MR`]`×`[`NR`] block of C
/// (row-stride `ldc`, starting at `c[0]`) accumulates one `kb`-deep
/// packed panel pair. The `MR × NR` accumulator array is loaded from
/// C, updated with one multiply-add per (element, k) in ascending k,
/// and stored back — LLVM keeps the 32 f64 accumulators in vector
/// registers and autovectorizes the [`NR`]-wide j-loop.
///
/// This is the determinism oracle of the dispatched ISA lanes in
/// [`crate::linalg::simd`]: every lane must reproduce its bits.
#[inline]
pub(crate) fn micro_full(apanel: &[f64], bpanel: &[f64], kb: usize, c: &mut [f64], ldc: usize) {
    let mut acc = [[0.0f64; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&c[r * ldc..r * ldc + NR]);
    }
    for k in 0..kb {
        let av = &apanel[k * MR..(k + 1) * MR];
        let bv = &bpanel[k * NR..(k + 1) * NR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let ar = av[r];
            for (j, accj) in accr.iter_mut().enumerate() {
                *accj += ar * bv[j];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        c[r * ldc..r * ldc + NR].copy_from_slice(accr);
    }
}

/// Ragged-edge microkernel: the `irb × jrb` (≤ [`MR`]`×`[`NR`]) corner
/// of a macro-tile. Scalar, but per-element it performs the exact same
/// ascending-k multiply-add sequence as [`micro_full`], so edges are
/// bitwise consistent with interior tiles.
fn micro_edge(
    apanel: &[f64],
    bpanel: &[f64],
    kb: usize,
    c: &mut [f64],
    ldc: usize,
    irb: usize,
    jrb: usize,
) {
    for r in 0..irb {
        for j in 0..jrb {
            let mut acc = c[r * ldc + j];
            for k in 0..kb {
                acc += apanel[k * MR + r] * bpanel[k * NR + j];
            }
            c[r * ldc + j] = acc;
        }
    }
}

/// One `--tile auto` calibration sweep: time every
/// [`tile::AUTO_CANDIDATES`] shape on a fixed synthetic p = 256 GEMM
/// and return the winner plus the timing table for the bill line.
///
/// The workload is formula-filled (no RNG state consumed, so running a
/// sweep cannot perturb anything seeded) and runs through the normal
/// blocked path with the *installed* kernel lane — callers install the
/// configured [`simd::KernelLane`] first so the sweep times what the
/// solve will run. Which candidate wins may vary with machine noise;
/// that is sound by construction, because tiles are schedule-only
/// (determinism rule 3) — `--tile auto` can move wall-clock, never a
/// byte. Cost: ~15 blocked p = 256 products, a few tens of ms.
pub fn calibrate_tile() -> tile::Calibration {
    const P: usize = 256;
    let a = Mat::from_fn(P, P, |i, j| ((i * 31 + j * 17) % 64) as f64 * 0.125 - 3.0);
    let b = Mat::from_fn(P, P, |i, j| ((i * 13 + j * 29) % 64) as f64 * 0.125 - 3.0);
    let mut c = Mat::zeros(P, P);
    let mut timings = Vec::with_capacity(tile::AUTO_CANDIDATES.len());
    for cand in tile::AUTO_CANDIDATES {
        let (stats, _) = crate::util::bench::time_fn(1, 2, || {
            c.data_mut().iter_mut().for_each(|v| *v = 0.0);
            a.matmul_into_with(&b, &mut c, &cand);
        });
        timings.push((cand, stats.min));
    }
    tile::Calibration::pick(timings)
}

/// y += a * x over contiguous slices; 4-way unrolled for
/// autovectorization. Each element sees exactly one `y_i += a·x_i`
/// regardless of slice length or unroll path — the SpMM column-panel
/// blocking relies on that elementwise invariance.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4 * 4;
    let (x4, xr) = x.split_at(chunks);
    let (y4, yr) = y.split_at_mut(chunks);
    for (xc, yc) in x4.chunks_exact(4).zip(y4.chunks_exact_mut(4)) {
        yc[0] += a * xc[0];
        yc[1] += a * xc[1];
        yc[2] += a * xc[2];
        yc[3] += a * xc[3];
    }
    for (xi, yi) in xr.iter().zip(yr.iter_mut()) {
        *yi += a * xi;
    }
}

/// Dot product over contiguous slices; 4 independent accumulators.
/// The grouping is fixed (a function of the slice length only), so
/// every caller — serial or threaded, any tile — gets identical bits.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4 * 4;
    for (xc, yc) in x[..chunks].chunks_exact(4).zip(y[..chunks].chunks_exact(4)) {
        acc[0] += xc[0] * yc[0];
        acc[1] += xc[1] * yc[1];
        acc[2] += xc[2] * yc[2];
        acc[3] += xc[3] * yc[3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks..x.len() {
        s += x[i] * y[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    fn bits(m: &Mat) -> Vec<u64> {
        m.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Tile shapes from degenerate to larger-than-any-test-matrix.
    fn tile_zoo() -> Vec<TileConfig> {
        vec![
            TileConfig::new(1, 1, 1),
            TileConfig::new(2, 3, 5),
            TileConfig::new(MR, 4, NR),
            TileConfig::new(7, 13, 11), // prime, misaligned with MR/NR
            TileConfig::DEFAULT,
            TileConfig::new(4096, 4096, 4096),
        ]
    }

    #[test]
    fn blocked_matmul_is_bitwise_naive_across_tiles() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (17, 9, 23),
            (64, 64, 64),
            (33, 70, 11),
            (MR + 1, 2, NR + 1),
            (129, 257, 65), // one past the default mc/kc boundaries
        ] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let naive = a.matmul_naive(&b);
            // The installed-default path…
            assert_eq!(bits(&a.matmul(&b)), bits(&naive), "{m}x{k}x{n} default");
            // …and every explicit tile shape.
            for tile in tile_zoo() {
                let mut c = Mat::zeros(m, n);
                a.matmul_into_with(&b, &mut c, &tile);
                assert_eq!(bits(&c), bits(&naive), "{m}x{k}x{n} tile {tile:?}");
            }
        }
    }

    /// End-to-end dispatch oracle: the blocked product under every
    /// *available* ISA lane is bitwise the naive product. Unavailable
    /// lanes are skipped (install clamps them to scalar, which the
    /// first iteration already covers).
    #[test]
    fn blocked_matmul_is_bitwise_naive_across_kernel_lanes() {
        use super::super::simd::{self, KernelLane};
        let mut rng = Rng::new(0xD15);
        // Big enough to clear the SMALL_GEMM_FLOPS cutoff so the
        // microkernel path actually runs, with ragged edges.
        let (m, k, n) = (131, 67, 75);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let naive = a.matmul_naive(&b);
        let prev = simd::active();
        for lane in [KernelLane::Scalar, KernelLane::Avx2, KernelLane::Avx512, KernelLane::Auto] {
            if !lane.available() {
                eprintln!("skipping {} lane: not available on this host", lane.as_str());
                continue;
            }
            simd::install(lane);
            // Other tests may race an install; sound either way — every
            // lane produces identical bits, which is what we assert.
            let mut c = Mat::zeros(m, n);
            a.matmul_into_with(&b, &mut c, &TileConfig::DEFAULT);
            assert_eq!(bits(&c), bits(&naive), "lane {}", lane.as_str());
        }
        simd::install(prev);
    }

    #[test]
    fn calibrate_tile_returns_a_candidate() {
        let cal = calibrate_tile();
        assert!(tile::AUTO_CANDIDATES.contains(&cal.winner));
        assert_eq!(cal.timings.len(), tile::AUTO_CANDIDATES.len());
        assert!(cal.timings.iter().all(|(_, s)| *s > 0.0));
    }

    #[test]
    fn matmul_into_accumulates_into_c() {
        let mut rng = Rng::new(7);
        let a = random_mat(&mut rng, 6, 5);
        let b = random_mat(&mut rng, 5, 9);
        let c0 = random_mat(&mut rng, 6, 9);
        // Reference: naive accumulation on top of the same starting C.
        let mut want = c0.clone();
        for i in 0..6 {
            for j in 0..9 {
                let mut s = want.get(i, j);
                for k in 0..5 {
                    s += a.get(i, k) * b.get(k, j);
                }
                want.set(i, j, s);
            }
        }
        let mut c = c0.clone();
        a.matmul_into(&b, &mut c);
        assert_eq!(bits(&c), bits(&want));
    }

    #[test]
    fn matmul_mt_bitwise_matches_serial_across_tiles() {
        let mut rng = Rng::new(0xA1);
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (2, 3, 4), (17, 9, 23), (64, 300, 5), (33, 70, 11)]
        {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let naive = a.matmul_naive(&b);
            for tile in [TileConfig::new(3, 5, 7), TileConfig::DEFAULT] {
                for threads in 1..=8 {
                    let mut par = Mat::zeros(m, n);
                    a.matmul_into_mt_with(&b, &mut par, threads, &tile);
                    assert_eq!(bits(&naive), bits(&par), "{m}x{k}x{n} t={threads} {tile:?}");
                }
            }
        }
    }

    #[test]
    fn matmul_bt_mt_bitwise_matches_serial() {
        let mut rng = Rng::new(0xA2);
        // Small case stays on the serial cutoff path; the large one
        // (m·k·n > pool::SPAWN_MIN_WORK) actually fans out.
        for &(m, k, n) in &[(21usize, 13usize, 9usize), (120, 90, 70)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, n, k);
            let serial = a.matmul_bt(&b);
            for threads in 1..=6 {
                assert_eq!(bits(&serial), bits(&a.matmul_bt_mt(&b, threads)), "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn matmul_mt_handles_degenerate_shapes() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        assert_eq!(a.matmul_mt(&b, 4).shape(), (0, 3));
        let a = Mat::zeros(4, 0);
        let b = Mat::zeros(0, 3);
        assert_eq!(a.matmul_mt(&b, 4), Mat::zeros(4, 3));
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(2, 1, vec![3.0, 4.0]);
        assert_eq!(a.matmul_mt(&b, 8).get(0, 0), 11.0);
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = random_mat(&mut rng, 13, 7);
        let b = random_mat(&mut rng, 7, 9);
        let bt = b.transpose();
        assert!(a.matmul_bt(&bt).max_abs_diff(&a.matmul(&b)) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = random_mat(&mut rng, 41, 67);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(4);
        let a = random_mat(&mut rng, 12, 12);
        let i = Mat::eye(12);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-14);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn blocks_roundtrip() {
        let mut rng = Rng::new(5);
        let a = random_mat(&mut rng, 10, 6);
        let top = a.row_block(0, 4);
        let bot = a.row_block(4, 10);
        assert_eq!(Mat::vstack(&[top, bot]), a);
        let left = a.col_block(0, 2);
        assert_eq!(left.get(3, 1), a.get(3, 1));
    }

    #[test]
    fn symmetrize_and_diag() {
        let mut rng = Rng::new(6);
        let mut a = random_mat(&mut rng, 8, 8);
        a.symmetrize();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(a.get(i, j), a.get(j, i));
            }
        }
        let d = a.diag();
        assert_eq!(d.len(), 8);
        assert_eq!(d[3], a.get(3, 3));
    }

    #[test]
    fn fro_and_dot_elem() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.fro2(), 30.0);
        let b = Mat::eye(2);
        assert_eq!(a.dot_elem(&b), 5.0);
    }

    #[test]
    fn nnz_counts_exact_zeros() {
        let a = Mat::from_vec(2, 2, vec![0.0, 2.0, 0.0, 4.0]);
        assert_eq!(a.nnz(), 2);
    }
}
