//! Row-major dense f64 matrices and a cache-blocked GEMM microkernel.
//!
//! The microkernel ([`Mat::matmul`]) is the hot path of the whole stack:
//! every local block multiply of the distributed 1.5D algorithm and every
//! single-node CONCORD iteration lands here (unless routed to a PJRT
//! artifact). It uses an i-k-j loop order (stream both B rows and C rows
//! sequentially), k-blocking for L1/L2 residency, and an unrolled
//! 4-accumulator inner loop that LLVM autovectorizes. Perf numbers and
//! the optimization log live in EXPERIMENTS.md §Perf.

use std::fmt;

/// Row-major dense matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 64 {
            for i in 0..self.rows {
                write!(f, "\n  {:?}", &self.data[i * self.cols..(i + 1) * self.cols])?;
            }
        }
        Ok(())
    }
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Sub-matrix of rows `r0..r1` (cheap copy of contiguous storage).
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Sub-matrix of columns `c0..c1`.
    pub fn col_block(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut data = Vec::with_capacity(self.rows * w);
        for i in 0..self.rows {
            data.extend_from_slice(&self.row(i)[c0..c1]);
        }
        Mat { rows: self.rows, cols: w, data }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Block the transpose for cache locality on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// C = A · B via the blocked microkernel.
    pub fn matmul(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        self.matmul_into(b, &mut c);
        c
    }

    /// C = A · B on `threads` node-local workers ([`Mat::matmul_into_mt`]).
    pub fn matmul_mt(&self, b: &Mat, threads: usize) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        self.matmul_into_mt(b, &mut c, threads);
        c
    }

    /// C += A · B (C must be zeroed by the caller for a plain product).
    ///
    /// i-k-j order with k-blocking and a 4×k-unrolled update: each pass
    /// over the contiguous C row folds in four B rows at once (4 fused
    /// multiply-adds per C element per load/store pair instead of one),
    /// unit-stride everywhere, autovectorizable (AVX2/FMA with the
    /// repo's `-C target-cpu=native`). §Perf step L3-2.
    pub fn matmul_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, b.rows, "inner dimension mismatch");
        assert_eq!(c.rows, self.rows);
        assert_eq!(c.cols, b.cols);
        gemm_rows(&self.data, self.cols, &b.data, b.cols, &mut c.data);
    }

    /// [`Mat::matmul_into`] on `threads` node-local workers.
    ///
    /// Rows are partitioned into contiguous chunks with boundaries
    /// aligned to the kernel's 2-row pairing, so each chunk runs the
    /// unmodified serial microkernel over the same row pairs in the same
    /// k-block order — the result is **bit-for-bit identical** to the
    /// serial product at every thread count (the parallel-equivalence
    /// property tests pin this).
    pub fn matmul_into_mt(&self, b: &Mat, c: &mut Mat, threads: usize) {
        assert_eq!(self.cols, b.rows, "inner dimension mismatch");
        assert_eq!(c.rows, self.rows);
        assert_eq!(c.cols, b.cols);
        let (m, kk, n) = (self.rows, self.cols, b.cols);
        if threads <= 1 || m < 2 || m * kk * n < crate::util::pool::SPAWN_MIN_WORK {
            gemm_rows(&self.data, kk, &b.data, n, &mut c.data);
            return;
        }
        let ranges = crate::util::pool::chunk_ranges(m, threads, 2);
        let a = &self.data;
        let bd = &b.data;
        crate::util::pool::par_rows_mut(&mut c.data, n, &ranges, |_i, s, e, crows| {
            gemm_rows(&a[s * kk..e * kk], kk, bd, n, crows);
        });
    }

    /// C = A · Bᵀ (used where the transposed layout is already at hand).
    pub fn matmul_bt(&self, b: &Mat) -> Mat {
        self.matmul_bt_mt(b, 1)
    }

    /// [`Mat::matmul_bt`] on `threads` node-local workers. Each output
    /// row is one independent run of the serial dot kernel, so the
    /// result is bit-identical at any thread count.
    pub fn matmul_bt_mt(&self, b: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, b.cols, "inner dimension mismatch (B is transposed)");
        let (m, kk, n) = (self.rows, self.cols, b.rows);
        let mut c = Mat::zeros(m, n);
        let a = &self.data;
        let bd = &b.data;
        let body = |s: usize, e: usize, crows: &mut [f64]| {
            for i in s..e {
                let arow = &a[i * kk..(i + 1) * kk];
                let crow = &mut crows[(i - s) * n..(i - s + 1) * n];
                for (j, cj) in crow.iter_mut().enumerate() {
                    let brow = &bd[j * kk..(j + 1) * kk];
                    *cj = dot(arow, brow);
                }
            }
        };
        if threads <= 1 || m < 2 || m * kk * n < crate::util::pool::SPAWN_MIN_WORK {
            body(0, m, &mut c.data);
            return c;
        }
        let ranges = crate::util::pool::chunk_ranges(m, threads, 1);
        crate::util::pool::par_rows_mut(&mut c.data, n, &ranges, |_i, s, e, crows| {
            body(s, e, crows)
        });
        c
    }

    /// Elementwise: self += alpha * other.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale all entries.
    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Frobenius norm squared.
    pub fn fro2(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Elementwise dot: sum_ij A_ij B_ij.
    pub fn dot_elem(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        dot(&self.data, &other.data)
    }

    /// Diagonal as a vector (square matrices).
    pub fn diag(&self) -> Vec<f64> {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + i]).collect()
    }

    /// Number of nonzero entries (exact zero test — iterates are exactly
    /// sparse after soft-thresholding).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    /// Symmetrize in place: A <- (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.data[i * self.cols + j] + self.data[j * self.cols + i]);
                self.data[i * self.cols + j] = v;
                self.data[j * self.cols + i] = v;
            }
        }
    }

    /// Stack a list of row blocks (all with equal `cols`) vertically.
    pub fn vstack(blocks: &[Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            assert_eq!(b.cols, cols);
            data.extend_from_slice(&b.data);
        }
        Mat { rows, cols, data }
    }
}

/// The GEMM microkernel over a contiguous row range: `c += a · b` where
/// `a` holds `r` rows of length `kk` and `c` the matching `r` rows of
/// length `n` (row-major, `b` is `kk × n`). This is the single code
/// path behind both the serial and the multithreaded matmul — workers
/// call it on disjoint even-aligned row chunks, which preserves the
/// 2-row pairing and k-block order and therefore produces bit-identical
/// results at every thread count.
fn gemm_rows(a: &[f64], kk: usize, b: &[f64], n: usize, c: &mut [f64]) {
    debug_assert_eq!(a.len() % kk.max(1), 0);
    let m = if kk == 0 { c.len() / n.max(1) } else { a.len() / kk };
    debug_assert_eq!(c.len(), m * n);
    const KC: usize = 256; // k-panel kept hot in L1/L2
    for k0 in (0..kk).step_by(KC) {
        let k1 = (k0 + KC).min(kk);
        // 2 C-rows per pass (§Perf step L3-3): each loaded B row
        // feeds two accumulator rows, halving B bandwidth. (A 4-row
        // variant measured *slower* — register pressure; §Perf L3-4.)
        let mut i = 0;
        while i + 2 <= m {
            let (chead, ctail) = c.split_at_mut((i + 1) * n);
            let c0 = &mut chead[i * n..];
            let c1 = &mut ctail[..n];
            let ar0 = &a[i * kk..(i + 1) * kk];
            let ar1 = &a[(i + 1) * kk..(i + 2) * kk];
            let mut k = k0;
            while k + 4 <= k1 {
                let (p0, p1, p2, p3) = (ar0[k], ar0[k + 1], ar0[k + 2], ar0[k + 3]);
                let (q0, q1, q2, q3) = (ar1[k], ar1[k + 1], ar1[k + 2], ar1[k + 3]);
                let b0 = &b[k * n..(k + 1) * n];
                let b1 = &b[(k + 1) * n..(k + 2) * n];
                let b2 = &b[(k + 2) * n..(k + 3) * n];
                let b3 = &b[(k + 3) * n..(k + 4) * n];
                for j in 0..n {
                    let (v0, v1, v2, v3) = (b0[j], b1[j], b2[j], b3[j]);
                    c0[j] += p0 * v0 + p1 * v1 + p2 * v2 + p3 * v3;
                    c1[j] += q0 * v0 + q1 * v1 + q2 * v2 + q3 * v3;
                }
                k += 4;
            }
            for k in k..k1 {
                let brow = &b[k * n..(k + 1) * n];
                if ar0[k] != 0.0 {
                    axpy(ar0[k], brow, c0);
                }
                if ar1[k] != 0.0 {
                    axpy(ar1[k], brow, &mut c1[..n]);
                }
            }
            i += 2;
        }
        // Remainder row: 4×k-unrolled single-row update.
        for i in i..m {
            let arow = &a[i * kk..(i + 1) * kk];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut k = k0;
            while k + 4 <= k1 {
                let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    k += 4; // free sparsity win for thresholded iterates
                    continue;
                }
                let b0 = &b[k * n..(k + 1) * n];
                let b1 = &b[(k + 1) * n..(k + 2) * n];
                let b2 = &b[(k + 2) * n..(k + 3) * n];
                let b3 = &b[(k + 3) * n..(k + 4) * n];
                for j in 0..n {
                    crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
                k += 4;
            }
            for k in k..k1 {
                let aik = arow[k];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[k * n..(k + 1) * n];
                axpy(aik, brow, crow);
            }
        }
    }
}

/// y += a * x over contiguous slices; 4-way unrolled for autovectorization.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4 * 4;
    let (x4, xr) = x.split_at(chunks);
    let (y4, yr) = y.split_at_mut(chunks);
    for (xc, yc) in x4.chunks_exact(4).zip(y4.chunks_exact_mut(4)) {
        yc[0] += a * xc[0];
        yc[1] += a * xc[1];
        yc[2] += a * xc[2];
        yc[3] += a * xc[3];
    }
    for (xi, yi) in xr.iter().zip(yr.iter_mut()) {
        *yi += a * xi;
    }
}

/// Dot product over contiguous slices; 4 independent accumulators.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4 * 4;
    for (xc, yc) in x[..chunks].chunks_exact(4).zip(y[..chunks].chunks_exact(4)) {
        acc[0] += xc[0] * yc[0];
        acc[1] += xc[1] * yc[1];
        acc[2] += xc[2] * yc[2];
        acc[3] += xc[3] * yc[3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks..x.len() {
        s += x[i] * y[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_many_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 23), (64, 64, 64), (33, 70, 11)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let c = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-10, "{m}x{k}x{n}");
        }
    }

    fn bits(m: &Mat) -> Vec<u64> {
        m.data().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn matmul_mt_bitwise_matches_serial() {
        let mut rng = Rng::new(0xA1);
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (2, 3, 4), (17, 9, 23), (64, 300, 5), (33, 70, 11)]
        {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let serial = a.matmul(&b);
            for threads in 1..=8 {
                let par = a.matmul_mt(&b, threads);
                assert_eq!(bits(&serial), bits(&par), "{m}x{k}x{n} t={threads}");
            }
        }
    }

    #[test]
    fn matmul_bt_mt_bitwise_matches_serial() {
        let mut rng = Rng::new(0xA2);
        // Small case stays on the serial cutoff path; the large one
        // (m·k·n > pool::SPAWN_MIN_WORK) actually fans out.
        for &(m, k, n) in &[(21usize, 13usize, 9usize), (120, 90, 70)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, n, k);
            let serial = a.matmul_bt(&b);
            for threads in 1..=6 {
                assert_eq!(bits(&serial), bits(&a.matmul_bt_mt(&b, threads)), "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn matmul_mt_handles_degenerate_shapes() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        assert_eq!(a.matmul_mt(&b, 4).shape(), (0, 3));
        let a = Mat::zeros(4, 0);
        let b = Mat::zeros(0, 3);
        assert_eq!(a.matmul_mt(&b, 4), Mat::zeros(4, 3));
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(2, 1, vec![3.0, 4.0]);
        assert_eq!(a.matmul_mt(&b, 8).get(0, 0), 11.0);
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = random_mat(&mut rng, 13, 7);
        let b = random_mat(&mut rng, 7, 9);
        let bt = b.transpose();
        assert!(a.matmul_bt(&bt).max_abs_diff(&a.matmul(&b)) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = random_mat(&mut rng, 41, 67);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(4);
        let a = random_mat(&mut rng, 12, 12);
        let i = Mat::eye(12);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-14);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn blocks_roundtrip() {
        let mut rng = Rng::new(5);
        let a = random_mat(&mut rng, 10, 6);
        let top = a.row_block(0, 4);
        let bot = a.row_block(4, 10);
        assert_eq!(Mat::vstack(&[top, bot]), a);
        let left = a.col_block(0, 2);
        assert_eq!(left.get(3, 1), a.get(3, 1));
    }

    #[test]
    fn symmetrize_and_diag() {
        let mut rng = Rng::new(6);
        let mut a = random_mat(&mut rng, 8, 8);
        a.symmetrize();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(a.get(i, j), a.get(j, i));
            }
        }
        let d = a.diag();
        assert_eq!(d.len(), 8);
        assert_eq!(d[3], a.get(3, 3));
    }

    #[test]
    fn fro_and_dot_elem() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.fro2(), 30.0);
        let b = Mat::eye(2);
        assert_eq!(a.dot_elem(&b), 5.0);
    }

    #[test]
    fn nnz_counts_exact_zeros() {
        let a = Mat::from_vec(2, 2, vec![0.0, 2.0, 0.0, 4.0]);
        assert_eq!(a.nnz(), 2);
    }
}
