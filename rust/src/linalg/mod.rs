//! Node-local linear algebra: the MKL-replacement substrate.
//!
//! The paper calls threaded MKL for every node-local matrix product; this
//! module is that substrate, in Rust:
//!
//! - [`dense`]: row-major f64 matrices with a cache-blocked GEMM
//!   microkernel (the distributed algorithm's local dense-dense multiply),
//! - [`sparse`]: CSR matrices with sparse·dense SpMM (the local
//!   `Ω_block · S_block` multiply — γ_sparse in the paper's cost model),
//! - [`chol`]: dense and banded Cholesky factorizations (used by the data
//!   generators to sample X ~ N(0, (Ω⁰)⁻¹) without ever forming Σ).
//!
//! The PJRT-backed path in [`crate::runtime`] offers AOT-compiled
//! alternatives at canonical shapes; everything here works at any shape
//! and is what the simulated ranks run.

pub mod chol;
pub mod dense;
pub mod sparse;

pub use chol::{banded_cholesky, cholesky, solve_lower, solve_lower_transpose, BandedChol};
pub use dense::Mat;
pub use sparse::Csr;
