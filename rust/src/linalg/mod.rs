//! Node-local linear algebra: the MKL-replacement substrate.
//!
//! The paper calls threaded MKL for every node-local matrix product; this
//! module is that substrate, in Rust:
//!
//! - [`dense`]: row-major f64 matrices with a cache-blocked, packed
//!   GEMM kernel (the distributed algorithm's local dense-dense
//!   multiply) and the naive reference kernel it must match bitwise,
//! - [`sparse`]: CSR matrices with column-blocked sparse·dense SpMM
//!   (the local `Ω_block · S_block` multiply — γ_sparse in the paper's
//!   cost model),
//! - [`tile`]: the `mc × kc × nc` blocking shapes both kernels read —
//!   compile-time defaults, a process-wide override (`--tile` /
//!   `ConcordConfig::tile`), the `--tile auto` calibration sweep, and
//!   the traffic model the cost layer prices,
//! - [`simd`]: runtime-dispatched AVX2/AVX-512 microkernel lanes
//!   (`--kernel`), every one bit-identical to the retained scalar
//!   microkernel (the determinism oracle),
//! - [`chol`]: dense and banded Cholesky factorizations (used by the data
//!   generators to sample X ~ N(0, (Ω⁰)⁻¹) without ever forming Σ).
//!
//! Every kernel obeys the layer's determinism contract (ascending-k
//! per-element accumulation; see `ARCHITECTURE.md`): tile shapes and
//! thread counts move wall-clock, never bits.
//!
//! The PJRT-backed path in [`crate::runtime`] offers AOT-compiled
//! alternatives at canonical shapes; everything here works at any shape
//! and is what the simulated ranks run.

pub mod chol;
pub mod dense;
pub mod simd;
pub mod sparse;
pub mod tile;

pub use chol::{banded_cholesky, cholesky, solve_lower, solve_lower_transpose, BandedChol};
pub use dense::Mat;
pub use simd::KernelLane;
pub use sparse::Csr;
pub use tile::{TileConfig, TileSpec};
