//! CSR sparse matrices and sparse·dense multiplication.
//!
//! HP-CONCORD's Cov variant multiplies the (sparse, soft-thresholded)
//! iterate Ω against the dense covariance S on every line-search
//! iteration; the Obs variant multiplies Ω against Xᵀ. Both are
//! sparse·dense SpMM with the sparse operand on the left — the case the
//! paper's 1.5D algorithm is designed around (shift the small sparse
//! operand, not the dense one). The paper's cost model charges these at
//! γ_sparse > γ_dense per flop; [`crate::simnet`] meters them separately.
//!
//! The SpMM is column-blocked: wide B/C operands are processed in
//! [`TileConfig::nc`]-wide panels (B panel packed contiguous) so the
//! active C sub-row stays L1-resident across a CSR row's nonzeros
//! instead of re-streaming a full p-wide row per nonzero. Like the
//! dense layer, blocking is a throughput knob only: each C element
//! accumulates over the row's nonzeros in ascending-k CSR order
//! whatever the panel width, so the blocked product is bit-for-bit
//! identical to the retained row-at-a-time reference
//! ([`Csr::spmm_reference`]) at every tile shape and thread count.

use super::dense::{axpy, Mat};
use super::tile::{self, TileConfig};

/// Compressed sparse row matrix (f64 values).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl Csr {
    /// Build from a dense matrix, keeping entries with |v| > threshold.
    pub fn from_dense(m: &Mat, threshold: f64) -> Self {
        let (rows, cols) = m.shape();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > threshold {
                    indices.push(j);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Build from explicit triplets (must not contain duplicates).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &mut Vec<(usize, usize, f64)>,
    ) -> Self {
        triplets.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        for &(i, j, v) in triplets.iter() {
            assert!(i < rows && j < cols);
            indptr[i + 1] += 1;
            indices.push(j);
            values.push(v);
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Rebuild from raw CSR arrays (the wire format of
    /// [`crate::dist::Block`]). Validated in debug builds.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(indptr.len(), rows + 1);
        debug_assert_eq!(indices.len(), values.len());
        debug_assert_eq!(*indptr.last().unwrap_or(&0), indices.len());
        debug_assert!(indices.iter().all(|&j| j < cols));
        Csr { rows, cols, indptr, indices, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-pointer array (length rows + 1).
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Column indices of the stored entries.
    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Values of the stored entries.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Average nonzeros per row — the paper's `d`.
    pub fn avg_row_nnz(&self) -> f64 {
        self.nnz() as f64 / self.rows as f64
    }

    /// (column indices, values) of row `i`.
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// Dense copy.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            for (&j, &v) in idx.iter().zip(vals) {
                m.set(i, j, v);
            }
        }
        m
    }

    /// C = self · B  (sparse·dense), column-blocked at the installed
    /// [`tile::current`] shape.
    pub fn spmm(&self, b: &Mat) -> Mat {
        self.spmm_mt(b, 1)
    }

    /// Reference row-at-a-time SpMM: each nonzero a_ik scales the full
    /// contiguous row k of B into the contiguous row i of C.
    ///
    /// Retained as the bitwise oracle of the column-blocked kernel (the
    /// tile-edge property tests) and the bench baseline; also the code
    /// path the blocked kernel takes when B is no wider than one panel.
    pub fn spmm_reference(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows(), "inner dimension mismatch");
        let n = b.cols();
        let mut c = Mat::zeros(self.rows, n);
        self.spmm_rows_direct(b, 0, self.rows, c.data_mut());
        c
    }

    /// The reference kernel over rows `s..e`, writing into that chunk's
    /// rows (`crows` holds `(e - s) · n` elements).
    fn spmm_rows_direct(&self, b: &Mat, s: usize, e: usize, crows: &mut [f64]) {
        let n = b.cols();
        for i in s..e {
            let (idx, vals) = self.row(i);
            let crow = &mut crows[(i - s) * n..(i - s + 1) * n];
            for (&k, &a) in idx.iter().zip(vals) {
                axpy(a, b.row(k), crow);
            }
        }
    }

    /// [`Csr::spmm`] on `threads` node-local workers.
    pub fn spmm_mt(&self, b: &Mat, threads: usize) -> Mat {
        self.spmm_mt_with(b, threads, &tile::current())
    }

    /// [`Csr::spmm_mt`] at an explicit tile shape.
    ///
    /// Output rows are independent (row i reads only CSR row i and the
    /// rows of B it indexes), so each worker runs the serial kernel
    /// over a contiguous row chunk; within a chunk the columns are
    /// processed in `tile.nc`-wide panels with the B panel packed
    /// contiguous and reused by every row of the chunk. Per element the
    /// nonzeros still apply in ascending CSR order, so the result is
    /// bit-identical to [`Csr::spmm_reference`] at every thread count
    /// and panel width.
    pub fn spmm_mt_with(&self, b: &Mat, threads: usize, tile: &TileConfig) -> Mat {
        assert_eq!(self.cols, b.rows(), "inner dimension mismatch");
        let n = b.cols();
        let mut c = Mat::zeros(self.rows, n);
        let nc = tile.nc.max(1);
        let pack = self.should_pack(b.rows(), n, tile);
        let body = |s: usize, e: usize, crows: &mut [f64]| {
            if !pack {
                self.spmm_rows_direct(b, s, e, crows);
                return;
            }
            let mut bpack = vec![0.0f64; b.rows() * nc];
            for jc in (0..n).step_by(nc) {
                let jb = nc.min(n - jc);
                for k in 0..b.rows() {
                    bpack[k * jb..(k + 1) * jb].copy_from_slice(&b.row(k)[jc..jc + jb]);
                }
                for i in s..e {
                    let (idx, vals) = self.row(i);
                    let crow = &mut crows[(i - s) * n + jc..(i - s) * n + jc + jb];
                    for (&k, &a) in idx.iter().zip(vals) {
                        axpy(a, &bpack[k * jb..(k + 1) * jb], crow);
                    }
                }
            }
        };
        if threads <= 1
            || self.rows < 2
            || self.nnz() * n < crate::util::pool::SPAWN_MIN_WORK
        {
            body(0, self.rows, &mut c.data_mut()[..]);
            return c;
        }
        let ranges = crate::util::pool::chunk_ranges(self.rows, threads, 1);
        crate::util::pool::par_rows_mut(c.data_mut(), n, &ranges, |_i, s, e, crows| {
            body(s, e, crows)
        });
        c
    }

    /// Whether the column-blocked SpMM should pack B panels for an
    /// `n`-column product, under the traffic model. Packing pays only
    /// when all of:
    ///
    /// - the output is wider than one panel (`n > nc`) — otherwise the
    ///   copy buys nothing,
    /// - the packed `b_rows × nc` panel fits the tile's `kc`-resident
    ///   B budget, the residency [`TileConfig::gemm_words_per_flop`]
    ///   assumes — a larger panel is re-streamed from slow memory per
    ///   row band and the copy is pure overhead (this is the condition
    ///   the old `nnz >= rows` predicate missed: at p = 1024, d = 0.02
    ///   the committed C-mirror baseline measured the packed path
    ///   *slower* than the reference),
    /// - the copy (`b_rows` words per panel column) amortizes against
    ///   the modeled naive-vs-blocked traffic gap over the panel's
    ///   `2·nnz` flops per column.
    ///
    /// Either path is bitwise identical — the predicate only picks the
    /// faster one, re-measured in `BENCH_simd_baseline.json` on both a
    /// pack-win and a fallback shape.
    pub fn should_pack(&self, b_rows: usize, n: usize, tile: &TileConfig) -> bool {
        let nc = tile.nc.max(1);
        let gap = TileConfig::NAIVE_WORDS_PER_FLOP - tile.gemm_words_per_flop();
        n > nc && b_rows <= tile.kc && (b_rows as f64) <= 2.0 * self.nnz() as f64 * gap
    }

    /// Flop count of `spmm` against an n-column dense operand: 2·nnz·n.
    pub fn spmm_flops(&self, n: usize) -> u64 {
        2 * self.nnz() as u64 * n as u64
    }

    /// Transposed copy (CSR of the transpose).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &j in &self.indices {
            counts[j + 1] += 1;
        }
        for j in 0..self.cols {
            counts[j + 1] += counts[j];
        }
        let mut indptr = counts.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            for (&j, &v) in idx.iter().zip(vals) {
                let pos = indptr[j];
                indices[pos] = i;
                values[pos] = v;
                indptr[j] += 1;
            }
        }
        // indptr was advanced; rebuild from counts.
        Csr { rows: self.cols, cols: self.rows, indptr: counts, indices, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_sparse(rng: &mut Rng, r: usize, c: usize, density: f64) -> Csr {
        let dense = Mat::from_fn(r, c, |_, _| {
            if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        });
        Csr::from_dense(&dense, 0.0)
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(1);
        let a = random_sparse(&mut rng, 13, 9, 0.3);
        assert_eq!(Csr::from_dense(&a.to_dense(), 0.0), a);
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let mut rng = Rng::new(2);
        for &(m, k, n, d) in &[(5, 7, 3, 0.4), (20, 20, 20, 0.1), (1, 8, 2, 1.0)] {
            let a = random_sparse(&mut rng, m, k, d);
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let got = a.spmm(&b);
            let want = a.to_dense().matmul(&b);
            assert!(got.max_abs_diff(&want) < 1e-12, "{m}x{k}x{n} d={d}");
        }
    }

    fn bitwise_eq(a: &Mat, b: &Mat) -> bool {
        a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn spmm_mt_bitwise_matches_reference_across_tiles() {
        let mut rng = Rng::new(0xB1);
        // The last case's nnz·n exceeds pool::SPAWN_MIN_WORK, so the
        // parallel path genuinely fans out; the small ones cover the
        // serial-cutoff branch. The narrow-nc/deep-kc tiles make the
        // traffic predicate pack (n > nc, rows ≤ kc, positive modeled
        // gap) with ragged final panels; the degenerate and huge tiles
        // land on the direct path (negative gap / n ≤ nc).
        let tiles = [
            TileConfig::new(1, 1, 1),
            TileConfig::new(2, 2, 3),
            TileConfig::new(64, 256, 3),
            TileConfig::new(32, 512, 7),
            TileConfig::DEFAULT,
            TileConfig::new(4096, 4096, 4096),
        ];
        for &(m, k, n, d) in &[
            (1usize, 4usize, 3usize, 0.5),
            (23, 17, 9, 0.2),
            (40, 40, 8, 0.05),
            (150, 200, 60, 0.4),
        ] {
            let a = random_sparse(&mut rng, m, k, d);
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let reference = a.spmm_reference(&b);
            assert!(bitwise_eq(&reference, &a.spmm(&b)), "{m}x{k}x{n} d={d} default tile");
            for tile in &tiles {
                for threads in 1..=8 {
                    let par = a.spmm_mt_with(&b, threads, tile);
                    assert!(
                        bitwise_eq(&reference, &par),
                        "{m}x{k}x{n} d={d} t={threads} tile {tile:?}"
                    );
                }
            }
        }
    }

    /// The sweep above must genuinely exercise both kernels: the
    /// traffic predicate packs for the deep-kc/narrow-nc tiles on the
    /// denser shapes and falls back for the degenerate ones.
    #[test]
    fn pack_predicate_splits_the_tile_zoo() {
        let mut rng = Rng::new(0xB3);
        let a = random_sparse(&mut rng, 150, 200, 0.4);
        assert!(a.should_pack(200, 60, &TileConfig::new(64, 256, 3)));
        assert!(a.should_pack(200, 60, &TileConfig::new(32, 512, 7)));
        // Tiny tiles model *more* traffic than naive (negative gap).
        assert!(!a.should_pack(200, 60, &TileConfig::new(1, 1, 1)));
        // One-panel output: nothing to reuse.
        assert!(!a.should_pack(200, 60, &TileConfig::new(4096, 4096, 4096)));
        // The measured regression shape (square p = 1024, d = 0.02
        // scaled down): B taller than the kc residency budget.
        assert!(!a.should_pack(1024, 1024, &TileConfig::DEFAULT));
        // A near-empty matrix can never amortize the panel copy.
        let sparse = random_sparse(&mut rng, 100, 200, 0.001);
        assert!(sparse.nnz() < 100, "fixture drifted: want a near-empty matrix");
        assert!(!sparse.should_pack(200, 2048, &TileConfig::DEFAULT));
    }

    #[test]
    fn raw_accessors_roundtrip() {
        let mut rng = Rng::new(0xB2);
        let a = random_sparse(&mut rng, 7, 5, 0.4);
        let b = Csr::from_raw(
            a.rows(),
            a.cols(),
            a.indptr().to_vec(),
            a.indices().to_vec(),
            a.values().to_vec(),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut rng = Rng::new(3);
        let a = random_sparse(&mut rng, 11, 17, 0.25);
        let got = a.transpose().to_dense();
        let want = a.to_dense().transpose();
        assert!(got.max_abs_diff(&want) == 0.0);
    }

    #[test]
    fn from_triplets_matches_from_dense() {
        let mut tri = vec![(1usize, 2usize, 3.0), (0, 0, 1.0), (2, 1, -2.0)];
        let a = Csr::from_triplets(3, 3, &mut tri);
        let mut d = Mat::zeros(3, 3);
        d.set(0, 0, 1.0);
        d.set(1, 2, 3.0);
        d.set(2, 1, -2.0);
        assert_eq!(a.to_dense(), d);
        assert_eq!(a.nnz(), 3);
        assert!((a.avg_row_nnz() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn threshold_drops_small_entries() {
        let d = Mat::from_vec(2, 2, vec![0.05, 1.0, -0.01, -2.0]);
        let a = Csr::from_dense(&d, 0.1);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn spmm_flops_formula() {
        let mut rng = Rng::new(4);
        let a = random_sparse(&mut rng, 10, 10, 0.5);
        assert_eq!(a.spmm_flops(7), 2 * a.nnz() as u64 * 7);
    }
}
