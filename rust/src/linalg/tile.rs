//! Cache-blocking parameters for the packed GEMM/SpMM kernel layer.
//!
//! The dense kernel ([`crate::linalg::Mat::matmul_into`]) is organised
//! BLIS-style around three loop tiles:
//!
//! - `nc`: B column-panel width — one packed `kc × nc` B panel is the
//!   unit of B reuse (streamed from L3/memory once per `kc` panel),
//! - `kc`: the k-panel depth — an `mc × kc` packed A block and the B
//!   panel's active slivers stay resident in L2/L1 across the whole
//!   macro-kernel,
//! - `mc`: A row-block height — bounds the packed A working set
//!   (`mc·kc` words) so it fits in L2.
//!
//! Inside a macro-tile, a fixed [`MR`]`×`[`NR`] register microkernel
//! walks the packed panels. The blocked SpMM
//! ([`crate::linalg::Csr::spmm`]) reuses `nc` as its B/C column-panel
//! width (CSR row bands × packed B column panels).
//!
//! ## Determinism contract
//!
//! Tile shapes are a **performance knob only**. Every kernel in the
//! layer accumulates each output element in strictly ascending-k order,
//! one fused-free multiply-add per k (see `ARCHITECTURE.md`,
//! "Determinism rules"), so the result is bit-for-bit identical to the
//! naive triple-loop reference ([`crate::linalg::Mat::matmul_naive`])
//! at *every* tile shape and thread count. `--tile 8,8,8` and
//! `--tile 4096,4096,4096` return byte-identical estimates; only
//! wall-clock moves. `rust/tests/parallel_determinism.rs` pins this.
//!
//! ## Selection
//!
//! Compile-time defaults ([`TileConfig::DEFAULT`]) are chosen for a
//! ~256 KiB-L2 / few-MiB-L3 core. Override per process with
//! [`install`] (the solvers install `ConcordConfig::tile` on entry; the
//! CLI exposes `--tile mc,kc,nc`), or let `--tile auto` run the short
//! measured sweep over [`AUTO_CANDIDATES`]
//! (`crate::linalg::dense::calibrate_tile`) and install the winner —
//! sound at any outcome because tiles are schedule-only. The cost model
//! prices the active tile through [`TileConfig::gemm_words_per_flop`]
//! (see `CostBreakdown::time_with_tile` in [`crate::cost`]).

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{anyhow, Result};

/// Register microkernel height: rows of C held in registers, and the
/// slab height of the packed A panel. With [`NR`] this sizes the
/// accumulator block at `MR × NR` f64 (4×8 = 4 AVX2 register rows).
pub const MR: usize = 4;

/// Register microkernel width: columns of C held in registers, and the
/// sliver width of the packed B panel.
pub const NR: usize = 8;

const DEFAULT_MC: usize = 128;
const DEFAULT_KC: usize = 256;
const DEFAULT_NC: usize = 512;

static TILE_MC: AtomicUsize = AtomicUsize::new(DEFAULT_MC);
static TILE_KC: AtomicUsize = AtomicUsize::new(DEFAULT_KC);
static TILE_NC: AtomicUsize = AtomicUsize::new(DEFAULT_NC);

/// The `mc × kc × nc` cache-blocking shape of the packed kernel layer.
///
/// Construct one explicitly, parse one from the CLI's `mc,kc,nc` form,
/// or take [`TileConfig::DEFAULT`]. Results never depend on the values
/// (see the module docs); the working sets do:
///
/// - packed A block: `mc · kc` words,
/// - packed B panel: `kc · nc` words,
/// - C macro-tile: `mc · nc` words.
///
/// # Examples
///
/// ```
/// use hpconcord::linalg::tile::TileConfig;
///
/// let t = TileConfig::parse("64,128,256").unwrap();
/// assert_eq!((t.mc, t.kc, t.nc), (64, 128, 256));
/// // Degenerate dims are clamped to 1, never zero.
/// assert_eq!(TileConfig::new(0, 0, 0), TileConfig::new(1, 1, 1));
/// // The blocked kernel's modeled memory traffic is far below naive's.
/// assert!(TileConfig::DEFAULT.gemm_words_per_flop() < TileConfig::NAIVE_WORDS_PER_FLOP / 10.0);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// A row-block height (rows of C per macro-tile).
    pub mc: usize,
    /// k-panel depth (inner-dimension block).
    pub kc: usize,
    /// B column-panel width (columns of C per panel).
    pub nc: usize,
}

impl TileConfig {
    /// Compile-time defaults: A block 128·256 = 256 KiB-of-f64 ≈ L2,
    /// B panel 256·512 = 1 MiB-of-f64 ≈ L3 slice, C tile 512 KiB.
    pub const DEFAULT: TileConfig = TileConfig { mc: DEFAULT_MC, kc: DEFAULT_KC, nc: DEFAULT_NC };

    /// Modeled slow-memory words per naive-kernel flop: the un-blocked
    /// triple loop re-streams one B word for every multiply-add pair
    /// (no reuse once p²·8 bytes exceeds cache), i.e. ½ word/flop. The
    /// cost model uses this as the "what if we hadn't blocked" price.
    pub const NAIVE_WORDS_PER_FLOP: f64 = 0.5;

    /// A tile shape with every dimension clamped to at least 1.
    pub fn new(mc: usize, kc: usize, nc: usize) -> TileConfig {
        TileConfig { mc: mc.max(1), kc: kc.max(1), nc: nc.max(1) }
    }

    /// Parse the CLI form `mc,kc,nc` (three positive integers).
    pub fn parse(s: &str) -> Result<TileConfig> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        if parts.len() != 3 {
            return Err(anyhow!("--tile expects mc,kc,nc — got {s:?}"));
        }
        let dim = |part: &str| -> Result<usize> {
            match part.parse::<usize>() {
                Ok(v) if v >= 1 => Ok(v),
                _ => Err(anyhow!("--tile dimension must be a positive integer, got {part:?}")),
            }
        };
        Ok(TileConfig { mc: dim(parts[0])?, kc: dim(parts[1])?, nc: dim(parts[2])? })
    }

    /// Build from a numeric config-file array (`solver.tile = [mc, kc,
    /// nc]`); every entry must be a positive integer-valued number.
    pub fn from_f64s(dims: &[f64]) -> Result<TileConfig> {
        if dims.len() != 3 {
            return Err(anyhow!("solver.tile expects [mc, kc, nc] — got {} entries", dims.len()));
        }
        let dim = |v: f64| -> Result<usize> {
            if v >= 1.0 && v.fract() == 0.0 && v <= usize::MAX as f64 {
                Ok(v as usize)
            } else {
                Err(anyhow!("solver.tile dimension must be a positive integer, got {v}"))
            }
        };
        Ok(TileConfig { mc: dim(dims[0])?, kc: dim(dims[1])?, nc: dim(dims[2])? })
    }

    /// Modeled slow-memory words moved per flop by the packed blocked
    /// kernel. Each `mc×kc` · `kc×nc` macro-tile does `2·mc·kc·nc`
    /// flops and moves `mc·kc` (pack A) + `kc·nc` (pack B) +
    /// `2·mc·nc` (C in/out per k-panel) words:
    ///
    /// ```text
    /// w(tile) = 1/(2·nc) + 1/(2·mc) + 1/kc
    /// ```
    ///
    /// → ~0.009 words/flop at the defaults vs the naive kernel's ½
    /// ([`TileConfig::NAIVE_WORDS_PER_FLOP`]). This is the cache-reuse
    /// term the Lemma 3.5 pricing charges against γ_dense (see
    /// `CostBreakdown::time_with_tile` in [`crate::cost`]).
    pub fn gemm_words_per_flop(&self) -> f64 {
        let (mc, kc, nc) = (self.mc as f64, self.kc as f64, self.nc as f64);
        1.0 / (2.0 * nc) + 1.0 / (2.0 * mc) + 1.0 / kc
    }
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig::DEFAULT
    }
}

impl std::fmt::Display for TileConfig {
    /// The CLI form `mc,kc,nc` — [`TileConfig::parse`]'s inverse.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{},{},{}", self.mc, self.kc, self.nc)
    }
}

/// The candidate shapes `--tile auto` times, bracketing the default
/// from "half-size everything" (small shared caches) to "taller A
/// block" (big-L2 cores). All dimensions are [`MR`]/[`NR`] multiples so
/// the sweep never times ragged-edge slabs. Order is fixed — ties in
/// the sweep break to the earlier candidate.
pub const AUTO_CANDIDATES: [TileConfig; 5] = [
    TileConfig { mc: 64, kc: 128, nc: 256 },
    TileConfig { mc: 96, kc: 192, nc: 384 },
    TileConfig::DEFAULT,
    TileConfig { mc: 192, kc: 384, nc: 768 },
    TileConfig { mc: 256, kc: 256, nc: 512 },
];

/// Result of one `--tile auto` calibration sweep
/// (`crate::linalg::dense::calibrate_tile`): the installed winner plus
/// the full timing table for the bill line. Whatever shape wins, the
/// solve's bytes are unchanged (determinism rule 3) — only wall-clock
/// rides on the choice.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// The fastest candidate (earliest wins ties).
    pub winner: TileConfig,
    /// `(candidate, best-rep seconds)` in sweep order.
    pub timings: Vec<(TileConfig, f64)>,
}

impl Calibration {
    /// Pick the winner from a sweep's timing table: minimum time, ties
    /// broken to the earlier (fixed-order) candidate.
    pub fn pick(timings: Vec<(TileConfig, f64)>) -> Calibration {
        assert!(!timings.is_empty(), "calibration sweep must time at least one candidate");
        let mut winner = timings[0];
        for &t in &timings[1..] {
            if t.1 < winner.1 {
                winner = t;
            }
        }
        Calibration { winner: winner.0, timings }
    }

    /// One-line record for the solve/serve bill.
    pub fn summary(&self) -> String {
        let best = self.timings.iter().find(|(t, _)| *t == self.winner).map_or(0.0, |(_, s)| *s);
        format!(
            "tile auto: calibrated {} candidates, installed {} ({:.2} ms/rep)",
            self.timings.len(),
            self.winner,
            best * 1e3
        )
    }
}

/// A `--tile` value before resolution: an explicit shape, or the
/// `auto` sentinel that triggers the calibration sweep at request
/// build time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileSpec {
    /// An explicit `mc,kc,nc` shape.
    Fixed(TileConfig),
    /// Run the calibration sweep and install the winner.
    Auto,
}

impl TileSpec {
    /// Parse the CLI form: `auto`, or `mc,kc,nc` as
    /// [`TileConfig::parse`].
    pub fn parse(s: &str) -> Result<TileSpec> {
        if s.trim().eq_ignore_ascii_case("auto") {
            Ok(TileSpec::Auto)
        } else {
            TileConfig::parse(s).map(TileSpec::Fixed)
        }
    }
}

/// Install `cfg` as the process-wide tile shape read by the kernel
/// entry points without an explicit `_with` tile argument
/// (`matmul_into`, `spmm`, …) and by the cost model's default pricing.
///
/// Solver entry points call this with `ConcordConfig::tile`. Concurrent
/// installs are benign — last writer wins per dimension, and a reader
/// racing an install may even see a mix of old and new dimensions —
/// because results are bitwise invariant to the tile (every dimension
/// is independently valid); only throughput is at stake. Tests that
/// need an exact shape pass it explicitly via the `_with` kernel
/// variants instead of reading [`current`].
pub fn install(cfg: TileConfig) {
    let cfg = TileConfig::new(cfg.mc, cfg.kc, cfg.nc);
    TILE_MC.store(cfg.mc, Ordering::Relaxed);
    TILE_KC.store(cfg.kc, Ordering::Relaxed);
    TILE_NC.store(cfg.nc, Ordering::Relaxed);
}

/// The currently-installed process-wide tile shape.
pub fn current() -> TileConfig {
    TileConfig {
        mc: TILE_MC.load(Ordering::Relaxed),
        kc: TILE_KC.load(Ordering::Relaxed),
        nc: TILE_NC.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_cli_form() {
        let t = TileConfig::parse("32, 64,128").unwrap();
        assert_eq!(t, TileConfig { mc: 32, kc: 64, nc: 128 });
        assert!(TileConfig::parse("32,64").is_err());
        assert!(TileConfig::parse("32,64,0").is_err());
        assert!(TileConfig::parse("a,b,c").is_err());
    }

    #[test]
    fn from_f64s_validates_integers() {
        assert_eq!(
            TileConfig::from_f64s(&[8.0, 16.0, 32.0]).unwrap(),
            TileConfig { mc: 8, kc: 16, nc: 32 }
        );
        assert!(TileConfig::from_f64s(&[8.0, 16.0]).is_err());
        assert!(TileConfig::from_f64s(&[8.5, 16.0, 32.0]).is_err());
        assert!(TileConfig::from_f64s(&[0.0, 16.0, 32.0]).is_err());
    }

    #[test]
    fn new_clamps_to_one() {
        assert_eq!(TileConfig::new(0, 5, 0), TileConfig { mc: 1, kc: 5, nc: 1 });
    }

    #[test]
    fn words_per_flop_closed_form() {
        let t = TileConfig::new(4, 8, 16);
        let want = 1.0 / 32.0 + 1.0 / 8.0 + 1.0 / 8.0;
        assert!((t.gemm_words_per_flop() - want).abs() < 1e-15);
        // More blocking → less traffic, monotonically.
        assert!(
            TileConfig::DEFAULT.gemm_words_per_flop()
                < TileConfig::new(8, 8, 8).gemm_words_per_flop()
        );
        assert!(TileConfig::DEFAULT.gemm_words_per_flop() < TileConfig::NAIVE_WORDS_PER_FLOP);
    }

    #[test]
    fn tile_spec_parses_auto_and_fixed() {
        assert_eq!(TileSpec::parse(" Auto ").unwrap(), TileSpec::Auto);
        assert_eq!(
            TileSpec::parse("16,32,64").unwrap(),
            TileSpec::Fixed(TileConfig::new(16, 32, 64))
        );
        assert!(TileSpec::parse("fastest").is_err());
    }

    #[test]
    fn calibration_picks_min_with_stable_ties() {
        let a = TileConfig::new(1, 2, 3);
        let b = TileConfig::new(4, 5, 6);
        let c = TileConfig::new(7, 8, 9);
        let cal = Calibration::pick(vec![(a, 2.0), (b, 1.0), (c, 1.0)]);
        assert_eq!(cal.winner, b, "ties break to the earlier candidate");
        assert_eq!(cal.timings.len(), 3);
        assert!(cal.summary().contains("4,5,6"), "{}", cal.summary());
    }

    #[test]
    fn auto_candidates_are_microkernel_aligned() {
        assert!(AUTO_CANDIDATES.contains(&TileConfig::DEFAULT));
        for cand in AUTO_CANDIDATES {
            assert_eq!(cand.mc % MR, 0, "{cand}");
            assert_eq!(cand.nc % NR, 0, "{cand}");
        }
    }

    #[test]
    fn display_roundtrips_through_parse() {
        let t = TileConfig::new(24, 48, 96);
        assert_eq!(TileConfig::parse(&t.to_string()).unwrap(), t);
    }

    #[test]
    fn install_sanitizes_and_current_stays_positive() {
        // Concurrent tests run solver fits that install their own
        // (default) tiles, so exact-state asserts would race; assert
        // the invariants instead: current() is always positive in every
        // dimension, and an install with a zero dimension never
        // publishes a zero.
        install(TileConfig { mc: 24, kc: 48, nc: 0 });
        for _ in 0..8 {
            let seen = current();
            assert!(seen.mc >= 1 && seen.kc >= 1 && seen.nc >= 1, "{seen:?}");
        }
        install(TileConfig::DEFAULT);
    }
}
