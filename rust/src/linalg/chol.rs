//! Cholesky factorizations: dense and banded.
//!
//! Used by the data generators ([`crate::gen`]) to sample
//! X ~ N(0, (Ω⁰)⁻¹) without ever forming the covariance: factor
//! Ω⁰ = L Lᵀ, draw z ~ N(0, I), and solve Lᵀ x = z — then
//! Cov(x) = L⁻ᵀ L⁻¹ = (Ω⁰)⁻¹. The banded variant makes chain-graph
//! sampling O(p·b²) so the large-p benchmark rows (Fig 4a) stay cheap.

use anyhow::{bail, Result};

use super::dense::Mat;

/// Dense lower-triangular Cholesky: A = L Lᵀ for symmetric positive
/// definite A. Returns L (full storage, upper part zeroed).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    if a.cols() != n {
        bail!("cholesky: matrix not square");
    }
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot of rows i and j of L over 0..j
            let mut s = a.get(i, j);
            let li = l.row(i);
            let lj = l.row(j);
            for k in 0..j {
                s -= li[k] * lj[k];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("cholesky: matrix not positive definite (pivot {i}: {s})");
                }
                l.set(i, i, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L y = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] * y[k];
        }
        y[i] = s / row[i];
    }
    y
}

/// Solve Lᵀ x = b for lower-triangular L (backward substitution).
pub fn solve_lower_transpose(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l.get(k, i) * x[k];
        }
        x[i] = s / l.get(i, i);
    }
    x
}

/// Banded lower Cholesky factor: row i stores L[i][i-bw..=i] in a
/// (bw+1)-wide band (column-offset layout).
#[derive(Debug, Clone)]
pub struct BandedChol {
    n: usize,
    bw: usize,
    /// band[i * (bw+1) + k] = L[i][i - bw + k], entries with i-bw+k < 0 unused.
    band: Vec<f64>,
}

impl BandedChol {
    #[inline]
    fn get(&self, i: usize, j: usize) -> f64 {
        // j in [i-bw, i]
        self.band[i * (self.bw + 1) + (j + self.bw - i)]
    }

    #[inline]
    fn set(&mut self, i: usize, j: usize, v: f64) {
        self.band[i * (self.bw + 1) + (j + self.bw - i)] = v;
    }

    /// Solve Lᵀ x = b (the sampling transform).
    pub fn solve_transpose(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = b[i];
            let kmax = (i + self.bw).min(n - 1);
            for k in (i + 1)..=kmax {
                s -= self.get(k, i) * x[k];
            }
            x[i] = s / self.get(i, i);
        }
        x
    }
}

/// Banded Cholesky of a symmetric positive definite matrix given as a
/// band accessor: `a(i, j)` for |i-j| <= bw (callers expose their sparse
/// or functional representation). O(n·bw²).
pub fn banded_cholesky(n: usize, bw: usize, a: impl Fn(usize, usize) -> f64) -> Result<BandedChol> {
    let mut l = BandedChol { n, bw, band: vec![0.0; n * (bw + 1)] };
    for i in 0..n {
        let jmin = i.saturating_sub(bw);
        for j in jmin..=i {
            let mut s = a(i, j);
            let kmin = jmin.max(j.saturating_sub(bw));
            for k in kmin..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    bail!("banded_cholesky: not positive definite (pivot {i}: {s})");
                }
                l.set(i, i, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Ok(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f64);
        }
        a
    }

    #[test]
    fn dense_cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        let a = random_spd(&mut rng, 12);
        let l = cholesky(&a).unwrap();
        let recon = l.matmul(&l.transpose());
        assert!(recon.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn dense_cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn triangular_solves_invert() {
        let mut rng = Rng::new(2);
        let a = random_spd(&mut rng, 9);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        // Solve A x = b via L y = b, Lᵀ x = y; check residual.
        let y = solve_lower(&l, &b);
        let x = solve_lower_transpose(&l, &y);
        for i in 0..9 {
            let mut s = 0.0;
            for j in 0..9 {
                s += a.get(i, j) * x[j];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn banded_matches_dense_on_tridiagonal() {
        let n = 30;
        // Chain precision: 1.25 on diagonal, -0.5 off.
        let a = Mat::from_fn(n, n, |i, j| {
            if i == j {
                1.25
            } else if i.abs_diff(j) == 1 {
                -0.5
            } else {
                0.0
            }
        });
        let dense_l = cholesky(&a).unwrap();
        let band_l = banded_cholesky(n, 1, |i, j| a.get(i, j)).unwrap();
        for i in 0..n {
            for j in i.saturating_sub(1)..=i {
                assert!((band_l.get(i, j) - dense_l.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn banded_solve_transpose_matches_dense() {
        let n = 20;
        let a = Mat::from_fn(n, n, |i, j| {
            if i == j {
                2.0
            } else if i.abs_diff(j) <= 2 {
                -0.3
            } else {
                0.0
            }
        });
        let mut rng = Rng::new(3);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let dense_l = cholesky(&a).unwrap();
        let band_l = banded_cholesky(n, 2, |i, j| a.get(i, j)).unwrap();
        let want = solve_lower_transpose(&dense_l, &b);
        let got = band_l.solve_transpose(&b);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn sampling_covariance_is_inverse_precision() {
        // Empirical check: x = L^-T z has covariance A^{-1}.
        let n = 4;
        let a = Mat::from_fn(n, n, |i, j| {
            if i == j {
                2.0
            } else if i.abs_diff(j) == 1 {
                -0.8
            } else {
                0.0
            }
        });
        let l = cholesky(&a).unwrap();
        let mut rng = Rng::new(4);
        let trials = 60_000;
        let mut cov = Mat::zeros(n, n);
        for _ in 0..trials {
            let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = solve_lower_transpose(&l, &z);
            for i in 0..n {
                for j in 0..n {
                    cov.set(i, j, cov.get(i, j) + x[i] * x[j]);
                }
            }
        }
        cov.scale(1.0 / trials as f64);
        // Compare against A^{-1} computed by solving for unit vectors.
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let y = solve_lower(&l, &e);
            let col = solve_lower_transpose(&l, &y);
            for i in 0..n {
                assert!((cov.get(i, j) - col[i]).abs() < 0.05);
            }
        }
    }
}
