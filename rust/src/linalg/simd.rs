//! Runtime-dispatched SIMD lanes for the packed GEMM microkernel.
//!
//! The scalar microkernel ([`crate::linalg::dense`]'s `micro_full`)
//! accumulates each output element in strictly ascending-k order, one
//! multiply and one add per k. The lanes here perform the *same*
//! per-element operation sequence with the [`NR`]-wide j-loop run 4- or
//! 8-wide: vectorizing across the eight **independent** output columns
//! reorders nothing within any one element, and every step is an
//! explicit vector multiply followed by an explicit vector add
//! (`vmulpd` + `vaddpd` — never a fused `vfmadd`, which would round
//! once instead of twice and change bits). Each lane is therefore
//! **bit-identical** to the scalar microkernel, which remains the
//! determinism oracle (ARCHITECTURE.md, determinism rule 10); the lane
//! is a pure throughput knob like threads and tiles.
//!
//! Dispatch: solver entry points call [`install`] with the configured
//! [`KernelLane`] (CLI `--kernel`, TOML `solver.kernel`; default
//! `auto`). `Auto` resolves to the best lane
//! `std::arch::is_x86_feature_detected!` reports; a forced lane the
//! host lacks falls back to scalar (the front doors reject it with a
//! clean error first — see `concord::request`). The blocked GEMM reads
//! the installed lane once per call via [`active_micro`].
//!
//! Measured on the container this repo grows in (single Xeon core,
//! `BENCH_simd_baseline.json`): scalar blocked 3.2, AVX2 17.9, AVX-512
//! 22.2 GFLOP/s at p = 512 — with the inline bitwise-vs-naive oracle
//! asserted for every lane. [`KernelLane::gamma_scale`] feeds those
//! ratios to the cost model.
//!
//! ## `unsafe` containment
//!
//! This file (plus the `vendor/affinity` libc shim) is the only place
//! in the tree allowed to spell `unsafe` — `tools/static_audit.py`
//! check 14 enforces that. Soundness of the two `target_feature`
//! microkernels rests on [`active_micro`]: it is the sole source of
//! their function pointers and re-checks feature detection before
//! handing one out.

use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{anyhow, Result};

use super::dense;

/// Microkernel signature shared by every lane: `(apanel, bpanel, kb,
/// c, ldc)` exactly as the scalar `micro_full`.
pub(crate) type MicroFn = fn(&[f64], &[f64], usize, &mut [f64], usize);

/// The GEMM microkernel ISA lane. A pure throughput knob: every lane
/// returns bit-identical results (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelLane {
    /// The retained portable microkernel — the determinism oracle.
    Scalar,
    /// 4-wide f64 (`__m256d`), two vectors per [`NR`]-sliver row.
    Avx2,
    /// 8-wide f64 (`__m512d`), one vector per [`NR`]-sliver row.
    Avx512,
    /// Resolve to the best detected lane at install time.
    Auto,
}

impl KernelLane {
    /// Parse the CLI/TOML form: `scalar`, `avx2`, `avx512`, or `auto`.
    pub fn parse(s: &str) -> Result<KernelLane> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelLane::Scalar),
            "avx2" => Ok(KernelLane::Avx2),
            "avx512" => Ok(KernelLane::Avx512),
            "auto" => Ok(KernelLane::Auto),
            other => Err(anyhow!(
                "--kernel expects scalar|avx2|avx512|auto, got {other:?}"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelLane::Scalar => "scalar",
            KernelLane::Avx2 => "avx2",
            KernelLane::Avx512 => "avx512",
            KernelLane::Auto => "auto",
        }
    }

    /// Whether this host can run the lane (`Scalar`/`Auto`: always).
    pub fn available(&self) -> bool {
        match self {
            KernelLane::Scalar | KernelLane::Auto => true,
            KernelLane::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelLane::Avx512 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
        }
    }

    /// The concrete lane this resolves to on this host: `Auto` becomes
    /// the best detected lane, an unavailable forced lane degrades to
    /// `Scalar` (callers that prefer an error over the fallback check
    /// [`KernelLane::available`] first).
    pub fn resolve(&self) -> KernelLane {
        match self {
            KernelLane::Auto => detect_best(),
            lane if lane.available() => *lane,
            _ => KernelLane::Scalar,
        }
    }

    /// Dense-GEMM throughput of the resolved lane relative to the
    /// scalar blocked kernel, from the C-mirror measurement committed
    /// in `BENCH_simd_baseline.json` (scalar 3.9, AVX2 19.1, AVX-512
    /// 24.4 GFLOP/s at p = 512 single-thread). The cost model divides
    /// `MachineParams::gamma_dense` by this
    /// (`MachineParams::with_dense_rate_scale`) so fabric pricing
    /// tracks the installed lane.
    pub fn gamma_scale(&self) -> f64 {
        match self.resolve() {
            KernelLane::Avx2 => 4.9,
            KernelLane::Avx512 => 6.3,
            _ => 1.0,
        }
    }
}

impl Default for KernelLane {
    fn default() -> Self {
        KernelLane::Auto
    }
}

/// Best lane the host supports, most capable first.
fn detect_best() -> KernelLane {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return KernelLane::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return KernelLane::Avx2;
        }
    }
    KernelLane::Scalar
}

const LANE_SCALAR: u8 = 0;
const LANE_AVX2: u8 = 1;
const LANE_AVX512: u8 = 2;

/// The installed lane. Starts scalar (the oracle) so library callers
/// that never install get the portable kernel; solver entry points
/// install the configured lane alongside `tile::install`.
static ACTIVE: AtomicU8 = AtomicU8::new(LANE_SCALAR);

/// Install `lane` as the process-wide microkernel lane and return the
/// concrete lane it resolved to (for the solve/serve bill line).
/// Concurrent installs are benign for the same reason concurrent
/// [`super::tile::install`]s are: every lane produces identical bits,
/// so a racing reader can only see a different throughput.
pub fn install(lane: KernelLane) -> KernelLane {
    let resolved = lane.resolve();
    let code = match resolved {
        KernelLane::Avx2 => LANE_AVX2,
        KernelLane::Avx512 => LANE_AVX512,
        _ => LANE_SCALAR,
    };
    ACTIVE.store(code, Ordering::Relaxed);
    resolved
}

/// The currently-installed concrete lane.
pub fn active() -> KernelLane {
    match ACTIVE.load(Ordering::Relaxed) {
        LANE_AVX2 => KernelLane::Avx2,
        LANE_AVX512 => KernelLane::Avx512,
        _ => KernelLane::Scalar,
    }
}

/// The microkernel of the installed lane. Feature detection is
/// re-checked here — the returned pointer is the only way to reach the
/// `target_feature` kernels, so a pointer is only ever handed out on a
/// host that detection approved (the soundness gate of the module
/// docs). Hoist the call out of the panel nest; one relaxed load plus
/// one detection read per GEMM call.
pub(crate) fn active_micro() -> MicroFn {
    #[cfg(target_arch = "x86_64")]
    {
        match active() {
            KernelLane::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
                return x86::micro_avx2;
            }
            KernelLane::Avx512 if std::arch::is_x86_feature_detected!("avx512f") => {
                return x86::micro_avx512;
            }
            _ => {}
        }
    }
    dense::micro_full
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd,
        _mm512_add_pd, _mm512_loadu_pd, _mm512_mul_pd, _mm512_set1_pd, _mm512_storeu_pd,
    };

    use super::super::tile::{MR, NR};

    // The register layouts below spell out MR = 4 rows × NR = 8 cols.
    const _: () = assert!(MR == 4 && NR == 8, "SIMD microkernels assume a 4x8 register block");

    /// Safe AVX2 entry. Only reachable through `active_micro`, which
    /// verified `is_x86_feature_detected!("avx2")` before returning
    /// this pointer.
    pub(super) fn micro_avx2(apanel: &[f64], bpanel: &[f64], kb: usize, c: &mut [f64], ldc: usize) {
        // SAFETY: AVX2 availability was checked by the sole supplier of
        // this function pointer (`active_micro`) and by the tests that
        // call it directly; slice bounds are asserted in the kernel.
        unsafe { micro_full_avx2(apanel, bpanel, kb, c, ldc) }
    }

    /// Safe AVX-512 entry; same contract as [`micro_avx2`].
    pub(super) fn micro_avx512(
        apanel: &[f64],
        bpanel: &[f64],
        kb: usize,
        c: &mut [f64],
        ldc: usize,
    ) {
        // SAFETY: as micro_avx2, with "avx512f".
        unsafe { micro_full_avx512(apanel, bpanel, kb, c, ldc) }
    }

    /// 4×8 microkernel, two `__m256d` accumulators per row (8 vector
    /// accumulators + 2 B vectors + 1 broadcast = 11 of 16 registers).
    /// Per output element: one `vmulpd` lane-product and one `vaddpd`
    /// lane-sum per k, ascending k — the scalar kernel's op sequence.
    ///
    /// # Safety
    ///
    /// Caller must ensure AVX2 is available. Slice layout is
    /// `micro_full`'s: `apanel` ≥ `kb·MR`, `bpanel` ≥ `kb·NR`, `c` ≥
    /// `(MR-1)·ldc + NR` (asserted).
    #[target_feature(enable = "avx2")]
    unsafe fn micro_full_avx2(
        apanel: &[f64],
        bpanel: &[f64],
        kb: usize,
        c: &mut [f64],
        ldc: usize,
    ) {
        assert!(apanel.len() >= kb * MR && bpanel.len() >= kb * NR);
        assert!(ldc >= NR && c.len() >= (MR - 1) * ldc + NR);
        let ap = apanel.as_ptr();
        let bp = bpanel.as_ptr();
        let cp = c.as_mut_ptr();
        let mut s00 = _mm256_loadu_pd(cp);
        let mut s01 = _mm256_loadu_pd(cp.add(4));
        let mut s10 = _mm256_loadu_pd(cp.add(ldc));
        let mut s11 = _mm256_loadu_pd(cp.add(ldc + 4));
        let mut s20 = _mm256_loadu_pd(cp.add(2 * ldc));
        let mut s21 = _mm256_loadu_pd(cp.add(2 * ldc + 4));
        let mut s30 = _mm256_loadu_pd(cp.add(3 * ldc));
        let mut s31 = _mm256_loadu_pd(cp.add(3 * ldc + 4));
        for k in 0..kb {
            let b0 = _mm256_loadu_pd(bp.add(k * NR));
            let b1 = _mm256_loadu_pd(bp.add(k * NR + 4));
            let a0 = _mm256_set1_pd(*ap.add(k * MR));
            s00 = _mm256_add_pd(s00, _mm256_mul_pd(a0, b0));
            s01 = _mm256_add_pd(s01, _mm256_mul_pd(a0, b1));
            let a1 = _mm256_set1_pd(*ap.add(k * MR + 1));
            s10 = _mm256_add_pd(s10, _mm256_mul_pd(a1, b0));
            s11 = _mm256_add_pd(s11, _mm256_mul_pd(a1, b1));
            let a2 = _mm256_set1_pd(*ap.add(k * MR + 2));
            s20 = _mm256_add_pd(s20, _mm256_mul_pd(a2, b0));
            s21 = _mm256_add_pd(s21, _mm256_mul_pd(a2, b1));
            let a3 = _mm256_set1_pd(*ap.add(k * MR + 3));
            s30 = _mm256_add_pd(s30, _mm256_mul_pd(a3, b0));
            s31 = _mm256_add_pd(s31, _mm256_mul_pd(a3, b1));
        }
        _mm256_storeu_pd(cp, s00);
        _mm256_storeu_pd(cp.add(4), s01);
        _mm256_storeu_pd(cp.add(ldc), s10);
        _mm256_storeu_pd(cp.add(ldc + 4), s11);
        _mm256_storeu_pd(cp.add(2 * ldc), s20);
        _mm256_storeu_pd(cp.add(2 * ldc + 4), s21);
        _mm256_storeu_pd(cp.add(3 * ldc), s30);
        _mm256_storeu_pd(cp.add(3 * ldc + 4), s31);
    }

    /// 4×8 microkernel, one `__m512d` accumulator per row.
    ///
    /// # Safety
    ///
    /// Caller must ensure AVX-512F is available; slice layout as
    /// [`micro_full_avx2`].
    #[target_feature(enable = "avx512f")]
    unsafe fn micro_full_avx512(
        apanel: &[f64],
        bpanel: &[f64],
        kb: usize,
        c: &mut [f64],
        ldc: usize,
    ) {
        assert!(apanel.len() >= kb * MR && bpanel.len() >= kb * NR);
        assert!(ldc >= NR && c.len() >= (MR - 1) * ldc + NR);
        let ap = apanel.as_ptr();
        let bp = bpanel.as_ptr();
        let cp = c.as_mut_ptr();
        let mut s0 = _mm512_loadu_pd(cp);
        let mut s1 = _mm512_loadu_pd(cp.add(ldc));
        let mut s2 = _mm512_loadu_pd(cp.add(2 * ldc));
        let mut s3 = _mm512_loadu_pd(cp.add(3 * ldc));
        for k in 0..kb {
            let bv = _mm512_loadu_pd(bp.add(k * NR));
            s0 = _mm512_add_pd(s0, _mm512_mul_pd(_mm512_set1_pd(*ap.add(k * MR)), bv));
            s1 = _mm512_add_pd(s1, _mm512_mul_pd(_mm512_set1_pd(*ap.add(k * MR + 1)), bv));
            s2 = _mm512_add_pd(s2, _mm512_mul_pd(_mm512_set1_pd(*ap.add(k * MR + 2)), bv));
            s3 = _mm512_add_pd(s3, _mm512_mul_pd(_mm512_set1_pd(*ap.add(k * MR + 3)), bv));
        }
        _mm512_storeu_pd(cp, s0);
        _mm512_storeu_pd(cp.add(ldc), s1);
        _mm512_storeu_pd(cp.add(2 * ldc), s2);
        _mm512_storeu_pd(cp.add(3 * ldc), s3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn parse_and_render_roundtrip() {
        for lane in [KernelLane::Scalar, KernelLane::Avx2, KernelLane::Avx512, KernelLane::Auto] {
            assert_eq!(KernelLane::parse(lane.as_str()).unwrap(), lane);
        }
        assert_eq!(KernelLane::parse(" AVX2 ").unwrap(), KernelLane::Avx2);
        assert!(KernelLane::parse("sse9").is_err());
    }

    #[test]
    fn resolve_is_concrete_and_available() {
        for lane in [KernelLane::Scalar, KernelLane::Avx2, KernelLane::Avx512, KernelLane::Auto] {
            let resolved = lane.resolve();
            assert_ne!(resolved, KernelLane::Auto);
            assert!(resolved.available(), "{lane:?} resolved to unavailable {resolved:?}");
        }
        assert_eq!(KernelLane::Scalar.gamma_scale(), 1.0);
        assert!(KernelLane::Auto.gamma_scale() >= 1.0);
    }

    #[test]
    fn install_roundtrips_and_clamps() {
        let prev = active();
        for lane in [KernelLane::Scalar, KernelLane::Avx2, KernelLane::Avx512, KernelLane::Auto] {
            let resolved = install(lane);
            // A racing test may re-install concurrently, so assert on
            // the returned lane (race-free), not on active().
            assert!(resolved.available());
            assert_ne!(resolved, KernelLane::Auto);
        }
        install(prev);
    }

    /// Every available SIMD lane must reproduce the scalar microkernel
    /// bit-for-bit on packed panels, partial C accumulation included —
    /// the determinism-rule-10 oracle at the smallest grain.
    #[test]
    fn simd_micro_lanes_are_bitwise_scalar() {
        #[cfg(target_arch = "x86_64")]
        {
            use super::super::tile::{MR, NR};
            let mut rng = Rng::new(0xC0FFEE);
            for kb in [1usize, 2, 7, 64, 256] {
                for ldc in [NR, NR + 3, 40] {
                    let apanel: Vec<f64> = (0..kb * MR).map(|_| rng.normal()).collect();
                    let bpanel: Vec<f64> = (0..kb * NR).map(|_| rng.normal()).collect();
                    let c0: Vec<f64> = (0..(MR - 1) * ldc + NR).map(|_| rng.normal()).collect();
                    let mut want = c0.clone();
                    dense::micro_full(&apanel, &bpanel, kb, &mut want, ldc);
                    let mut lanes_run = 0;
                    if std::arch::is_x86_feature_detected!("avx2") {
                        let mut got = c0.clone();
                        x86::micro_avx2(&apanel, &bpanel, kb, &mut got, ldc);
                        assert!(
                            want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                            "avx2 kb={kb} ldc={ldc}"
                        );
                        lanes_run += 1;
                    }
                    if std::arch::is_x86_feature_detected!("avx512f") {
                        let mut got = c0.clone();
                        x86::micro_avx512(&apanel, &bpanel, kb, &mut got, ldc);
                        assert!(
                            want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                            "avx512 kb={kb} ldc={ldc}"
                        );
                        lanes_run += 1;
                    }
                    if lanes_run == 0 {
                        eprintln!("skipping SIMD lane oracle: host has neither avx2 nor avx512f");
                        return;
                    }
                }
            }
        }
    }
}
