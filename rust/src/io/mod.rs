//! Out-of-core X: the on-disk sample format and the [`XSource`] seam.
//!
//! HP-CONCORD targets data "often on the order of terabytes" (paper
//! §1), so the full n×p observation matrix must never need to be
//! resident. This module provides the two halves of that:
//!
//! - **The `HPCX` binary format** — a 24-byte header (magic `HPCX`,
//!   u32 LE version, u64 LE n, u64 LE p) followed by the n·p samples
//!   as row-major little-endian f64. [`write_x`] writes it atomically
//!   (temp file + rename, so a failed write never leaves a partial
//!   output file); [`XDisk::open`] validates magic, version and the
//!   n·p/file-length consistency before any read. The CLI's `convert`
//!   subcommand writes it; `--x-file` / `solver.x_file` reads it.
//! - **[`XSource`]** — the backend enum every consumer of X reads
//!   through: `InCore(&Mat)` is today's zero-copy behavior, `OnDisk`
//!   reads row panels via `std::fs::File` + positioned reads (no new
//!   crates). The streamed screening gram, the executor's per-wave
//!   column extraction and the stability coordinator's subsample row
//!   views all route through it, so an on-disk run's peak residency is
//!   panels + per-wave sub-matrices instead of the whole matrix.
//!
//! **Determinism rule 8** (see ARCHITECTURE.md): the X backend is a
//! *schedule-only* knob — every extraction is pure data movement and
//! the on-disk gram accumulates the same products in the same
//! ascending-k order as the in-core pass, so on-disk and in-core runs
//! are bit-identical in estimates, objectives and metered counters.
//! Only the modeled source residency (`CostSummary::x_panel_words`)
//! moves. `rust/tests/out_of_core.rs` is the wall.

use std::fs::{self, File};
use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::Mat;

/// The format magic: the first four bytes of every HPCX file.
pub const X_MAGIC: [u8; 4] = *b"HPCX";

/// Current format version (bumped on any layout change).
pub const X_VERSION: u32 = 1;

/// Header size in bytes: magic (4) + version (4) + n (8) + p (8).
pub const X_HEADER_BYTES: u64 = 24;

/// Default row-panel height for on-disk reads (gram streaming when no
/// `--gram-block` is given, and column extraction). A throughput /
/// residency knob only — reads are pure data movement, so results are
/// bit-identical at any panel height (determinism rule 8).
pub const DEFAULT_PANEL_ROWS: usize = 256;

/// Temp-file sibling used by [`write_x`] so a failed write never
/// leaves a partial file under the target name.
fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Write `x` to `path` in the HPCX format, atomically: the bytes go to
/// a `.tmp` sibling first and are renamed into place only on success,
/// so an interrupted or failed write leaves no partial output file.
pub fn write_x(path: &Path, x: &Mat) -> Result<()> {
    let tmp = tmp_path(path);
    let written = (|| -> Result<()> {
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {} for the HPCX write", tmp.display()))?;
        let mut header = Vec::with_capacity(X_HEADER_BYTES as usize);
        header.extend_from_slice(&X_MAGIC);
        header.extend_from_slice(&X_VERSION.to_le_bytes());
        header.extend_from_slice(&(x.rows() as u64).to_le_bytes());
        header.extend_from_slice(&(x.cols() as u64).to_le_bytes());
        f.write_all(&header).context("writing the HPCX header")?;
        // Row-major LE f64 payload, buffered one row panel at a time.
        let p = x.cols();
        let mut buf = Vec::with_capacity(DEFAULT_PANEL_ROWS.min(x.rows().max(1)) * p * 8);
        let mut r0 = 0;
        while r0 < x.rows() {
            let r1 = (r0 + DEFAULT_PANEL_ROWS).min(x.rows());
            buf.clear();
            for &v in &x.data()[r0 * p..r1 * p] {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf).context("writing HPCX row panels")?;
            r0 = r1;
        }
        f.sync_all().context("syncing the HPCX file")?;
        Ok(())
    })();
    match written {
        Ok(()) => fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display())),
        Err(e) => {
            let _ = fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// A validated handle to an on-disk HPCX file. Holds the path and the
/// header dimensions, **not** an open file descriptor — so it is
/// `Clone + Send + Sync` for free and each read opens, seeks and reads
/// positionally (row panels are contiguous in the row-major layout).
#[derive(Debug, Clone)]
pub struct XDisk {
    path: PathBuf,
    n: usize,
    p: usize,
}

impl XDisk {
    /// Open and validate an HPCX file: magic, version, and the
    /// n·p/file-length consistency are all checked up front so every
    /// later panel read is a plain seek + `read_exact`.
    pub fn open(path: &Path) -> Result<XDisk> {
        let mut f = File::open(path)
            .with_context(|| format!("opening x-file {}", path.display()))?;
        let mut header = [0u8; X_HEADER_BYTES as usize];
        f.read_exact(&mut header).map_err(|e| {
            anyhow!("{}: truncated header (want {X_HEADER_BYTES} bytes): {e}", path.display())
        })?;
        if header[..4] != X_MAGIC {
            bail!(
                "{}: bad magic {:?} (want {:?} — not an HPCX x-file?)",
                path.display(),
                &header[..4],
                X_MAGIC
            );
        }
        let version = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
        if version != X_VERSION {
            bail!("{}: unsupported HPCX version {version} (want {X_VERSION})", path.display());
        }
        let n = u64::from_le_bytes(header[8..16].try_into().expect("8-byte slice"));
        let p = u64::from_le_bytes(header[16..24].try_into().expect("8-byte slice"));
        let words = n
            .checked_mul(p)
            .and_then(|w| w.checked_mul(8))
            .ok_or_else(|| anyhow!("{}: header dims n={n} p={p} overflow", path.display()))?;
        let want = X_HEADER_BYTES + words;
        let len = f.metadata().context("stat of the x-file")?.len();
        if len != want {
            bail!(
                "{}: file length {len} bytes does not match header n={n} p={p} \
                 (want {want} = {X_HEADER_BYTES} header + n·p·8 payload)",
                path.display()
            );
        }
        let n = usize::try_from(n)
            .map_err(|_| anyhow!("{}: n={n} exceeds usize", path.display()))?;
        let p = usize::try_from(p)
            .map_err(|_| anyhow!("{}: p={p} exceeds usize", path.display()))?;
        Ok(XDisk { path: path.to_path_buf(), n, p })
    }

    /// Sample count n (rows of X).
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Variable count p (columns of X).
    pub fn cols(&self) -> usize {
        self.p
    }

    /// The file this handle reads from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub(crate) fn open_file(&self) -> Result<File> {
        File::open(&self.path)
            .with_context(|| format!("reopening x-file {}", self.path.display()))
    }

    pub(crate) fn read_rows_into(
        &self,
        f: &mut File,
        r0: usize,
        r1: usize,
        out: &mut [f64],
    ) -> Result<()> {
        debug_assert!(r0 <= r1 && r1 <= self.n);
        debug_assert_eq!(out.len(), (r1 - r0) * self.p);
        let offset = X_HEADER_BYTES + (r0 * self.p * 8) as u64;
        f.seek(SeekFrom::Start(offset)).context("seeking to an x-file row panel")?;
        let mut bytes = vec![0u8; out.len() * 8];
        f.read_exact(&mut bytes).with_context(|| {
            format!("reading rows {r0}..{r1} of x-file {}", self.path.display())
        })?;
        for (v, chunk) in out.iter_mut().zip(bytes.chunks_exact(8)) {
            *v = f64::from_le_bytes(chunk.try_into().expect("8-byte chunk"));
        }
        Ok(())
    }

    /// Read the contiguous row panel `r0..r1` as a `(r1-r0) × p` matrix
    /// (one positioned read; bit-identical to the in-core rows).
    pub fn read_rows(&self, r0: usize, r1: usize) -> Result<Mat> {
        assert!(r0 <= r1 && r1 <= self.n, "panel {r0}..{r1} out of 0..{}", self.n);
        let mut data = vec![0.0f64; (r1 - r0) * self.p];
        let mut f = self.open_file()?;
        self.read_rows_into(&mut f, r0, r1, &mut data)?;
        Ok(Mat::from_vec(r1 - r0, self.p, data))
    }
}

/// Where X lives: the seam every consumer of the observation matrix
/// reads through. `InCore` is today's zero-copy behavior; `OnDisk`
/// streams row panels from an HPCX file so the full matrix is never
/// resident. The backend is a schedule-only knob (determinism rule 8):
/// both arms produce bit-identical extractions and grams — only the
/// modeled source residency ([`XSource::panel_words`]) differs.
#[derive(Debug, Clone, Copy)]
pub enum XSource<'a> {
    /// The whole matrix is resident; every view borrows it.
    InCore(&'a Mat),
    /// Row panels are read on demand from an on-disk HPCX file.
    OnDisk(&'a XDisk),
}

impl<'a> XSource<'a> {
    /// Sample count n.
    pub fn rows(&self) -> usize {
        match self {
            XSource::InCore(x) => x.rows(),
            XSource::OnDisk(d) => d.rows(),
        }
    }

    /// Variable count p.
    pub fn cols(&self) -> usize {
        match self {
            XSource::InCore(x) => x.cols(),
            XSource::OnDisk(d) => d.cols(),
        }
    }

    /// Words of X this backend keeps resident to serve reads: the
    /// whole matrix for `InCore`, one [`DEFAULT_PANEL_ROWS`]-row panel
    /// for `OnDisk`. Billed into `CostSummary::x_panel_words` (max
    /// across merges — the source is shared, residencies coexist).
    pub fn panel_words(&self) -> u64 {
        match self {
            XSource::InCore(x) => (x.rows() * x.cols()) as u64,
            XSource::OnDisk(d) => (DEFAULT_PANEL_ROWS.min(d.rows()) * d.cols()) as u64,
        }
    }

    /// Gather the columns `idx` over every row: the executor's per-wave
    /// sub-matrix extraction. Pure data movement — element-for-element
    /// equal to `extract_columns` on the in-core matrix. The on-disk
    /// arm streams [`DEFAULT_PANEL_ROWS`]-row panels so residency is
    /// one panel plus the extracted sub-matrix.
    pub fn extract_columns(&self, idx: &[usize]) -> Result<Mat> {
        match self {
            XSource::InCore(x) => {
                Ok(Mat::from_fn(x.rows(), idx.len(), |r, k| x.get(r, idx[k])))
            }
            XSource::OnDisk(d) => {
                let (n, p) = (d.rows(), d.cols());
                let mut out = Mat::zeros(n, idx.len());
                if idx.is_empty() {
                    return Ok(out);
                }
                let mut f = d.open_file()?;
                let panel = DEFAULT_PANEL_ROWS.min(n).max(1);
                let mut buf = vec![0.0f64; panel * p];
                let mut r0 = 0;
                while r0 < n {
                    let r1 = (r0 + panel).min(n);
                    let rows = &mut buf[..(r1 - r0) * p];
                    d.read_rows_into(&mut f, r0, r1, rows)?;
                    for r in r0..r1 {
                        let src = &rows[(r - r0) * p..(r - r0 + 1) * p];
                        for (k, &j) in idx.iter().enumerate() {
                            out.set(r, k, src[j]);
                        }
                    }
                    r0 = r1;
                }
                Ok(out)
            }
        }
    }

    /// Gather `(rows[i], idx[k])` — the executor's lazy subsample view
    /// (stability selection's row-subsampled component solves). The
    /// on-disk arm reads each requested row once, in the given order.
    pub fn extract_rows_columns(&self, rows: &[usize], idx: &[usize]) -> Result<Mat> {
        match self {
            XSource::InCore(x) => {
                Ok(Mat::from_fn(rows.len(), idx.len(), |i, k| x.get(rows[i], idx[k])))
            }
            XSource::OnDisk(d) => {
                let p = d.cols();
                let mut out = Mat::zeros(rows.len(), idx.len());
                if rows.is_empty() || idx.is_empty() {
                    return Ok(out);
                }
                let mut f = d.open_file()?;
                let mut buf = vec![0.0f64; p];
                for (i, &r) in rows.iter().enumerate() {
                    assert!(r < d.rows(), "row {r} out of 0..{}", d.rows());
                    d.read_rows_into(&mut f, r, r + 1, &mut buf)?;
                    for (k, &j) in idx.iter().enumerate() {
                        out.set(i, k, buf[j]);
                    }
                }
                Ok(out)
            }
        }
    }

    /// Materialize the full-width row subsample `rows` as an m × p
    /// matrix (the stability coordinator's per-subsample screening
    /// input). Bit-identical to gathering the same rows in core.
    pub fn subsample(&self, rows: &[usize]) -> Result<Mat> {
        match self {
            XSource::InCore(x) => {
                Ok(Mat::from_fn(rows.len(), x.cols(), |i, j| x.get(rows[i], j)))
            }
            XSource::OnDisk(d) => {
                let p = d.cols();
                let mut out = Mat::zeros(rows.len(), p);
                let mut f = d.open_file()?;
                let mut buf = vec![0.0f64; p];
                for (i, &r) in rows.iter().enumerate() {
                    assert!(r < d.rows(), "row {r} out of 0..{}", d.rows());
                    d.read_rows_into(&mut f, r, r + 1, &mut buf)?;
                    out.row_mut(i).copy_from_slice(&buf);
                }
                Ok(out)
            }
        }
    }
}

/// How many evenly spaced sample rows [`x_fingerprint`] hashes (first
/// and last rows always included when present).
const FINGERPRINT_ROWS: usize = 8;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a(hash: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *hash ^= b as u64;
        *hash = hash.wrapping_mul(FNV_PRIME);
    }
}

/// Dataset fingerprint: a 64-bit FNV-1a hash of the logical header
/// (n, p) plus up to [`FINGERPRINT_ROWS`] evenly spaced sample rows'
/// f64 bit patterns. Defined over the *contents*, not the backend —
/// an in-core matrix and its `convert`ed HPCX file fingerprint
/// identically, so the serve layer's screening-artifact cache keys
/// match across front doors. Sampled rather than exhaustive: reading
/// eight row panels prices the check at a few positioned reads however
/// many terabytes the payload is.
pub fn x_fingerprint(x: XSource<'_>) -> Result<u64> {
    let (n, p) = (x.rows(), x.cols());
    let mut hash = FNV_OFFSET;
    fnv1a(&mut hash, &(n as u64).to_le_bytes());
    fnv1a(&mut hash, &(p as u64).to_le_bytes());
    if n == 0 || p == 0 {
        return Ok(hash);
    }
    let samples = FINGERPRINT_ROWS.min(n);
    let mut row_bytes = vec![0u8; p * 8];
    for k in 0..samples {
        // Evenly spaced over [0, n): k·(n−1)/(samples−1), so the first
        // and last rows are always sampled.
        let r = if samples == 1 { 0 } else { k * (n - 1) / (samples - 1) };
        fnv1a(&mut hash, &(r as u64).to_le_bytes());
        match x {
            XSource::InCore(m) => {
                for (chunk, &v) in row_bytes.chunks_exact_mut(8).zip(&m.data()[r * p..(r + 1) * p])
                {
                    chunk.copy_from_slice(&v.to_le_bytes());
                }
            }
            XSource::OnDisk(d) => {
                let row = d.read_rows(r, r + 1)?;
                for (chunk, &v) in row_bytes.chunks_exact_mut(8).zip(row.data()) {
                    chunk.copy_from_slice(&v.to_le_bytes());
                }
            }
        }
        fnv1a(&mut hash, &row_bytes);
    }
    Ok(hash)
}

/// Render an estimate as whitespace-separated rows with full f64
/// round-trip precision: the **one** byte format behind the CLI's
/// `--out-omega` and the serve layer's result retrieval, so two runs
/// that claim bit-identical results can be compared with `cmp`
/// whichever front door produced them (determinism rule 9).
pub fn format_omega(omega: &Mat) -> String {
    use std::fmt::Write as _;
    let mut text = String::new();
    for i in 0..omega.rows() {
        for j in 0..omega.cols() {
            if j > 0 {
                text.push(' ');
            }
            write!(text, "{:.17e}", omega.get(i, j)).expect("string write");
        }
        text.push('\n');
    }
    text
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn temp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hpcx_io_{}_{name}.xbin", std::process::id()))
    }

    fn random_mat(n: usize, p: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, p, |_, _| rng.normal())
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let x = random_mat(37, 5, 0xC0FFEE);
        let path = temp("round_trip");
        write_x(&path, &x).unwrap();
        let d = XDisk::open(&path).unwrap();
        assert_eq!((d.rows(), d.cols()), (37, 5));
        let back = d.read_rows(0, 37).unwrap();
        for (a, b) in x.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn in_core_and_on_disk_views_agree() {
        let x = random_mat(23, 6, 7);
        let path = temp("views");
        write_x(&path, &x).unwrap();
        let d = XDisk::open(&path).unwrap();
        let idx = [4usize, 0, 5];
        let rows = [22usize, 0, 11];
        let a = XSource::InCore(&x);
        let b = XSource::OnDisk(&d);
        let (ca, cb) = (a.extract_columns(&idx).unwrap(), b.extract_columns(&idx).unwrap());
        assert_eq!(ca.data(), cb.data());
        let (ra, rb) = (
            a.extract_rows_columns(&rows, &idx).unwrap(),
            b.extract_rows_columns(&rows, &idx).unwrap(),
        );
        assert_eq!(ra.data(), rb.data());
        let (sa, sb) = (a.subsample(&rows).unwrap(), b.subsample(&rows).unwrap());
        assert_eq!(sa.data(), sb.data());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fingerprint_is_backend_invariant_and_content_sensitive() {
        let x = random_mat(41, 7, 0xF1A9);
        let path = temp("fingerprint");
        write_x(&path, &x).unwrap();
        let d = XDisk::open(&path).unwrap();
        let core = x_fingerprint(XSource::InCore(&x)).unwrap();
        let disk = x_fingerprint(XSource::OnDisk(&d)).unwrap();
        assert_eq!(core, disk, "same contents must fingerprint identically on both backends");
        // Flip one sampled element (row 0 is always sampled): the
        // fingerprint must move.
        let mut y = x.clone();
        y.set(0, 3, y.get(0, 3) + 1.0);
        assert_ne!(core, x_fingerprint(XSource::InCore(&y)).unwrap());
        // A different shape moves it even with an empty payload.
        let a = x_fingerprint(XSource::InCore(&Mat::zeros(2, 3))).unwrap();
        let b = x_fingerprint(XSource::InCore(&Mat::zeros(3, 2))).unwrap();
        assert_ne!(a, b);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn format_omega_is_full_precision_rows() {
        let m = Mat::from_fn(2, 2, |i, j| (i * 2 + j) as f64 + 0.5);
        let text = format_omega(&m);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let row: Vec<f64> = line.split(' ').map(|t| t.parse().unwrap()).collect();
            assert_eq!(row.len(), 2);
            for (j, v) in row.iter().enumerate() {
                assert_eq!(v.to_bits(), m.get(i, j).to_bits(), "round-trip at ({i},{j})");
            }
        }
    }

    #[test]
    fn panel_words_are_panels_not_the_matrix() {
        let x = random_mat(DEFAULT_PANEL_ROWS + 44, 3, 9);
        let path = temp("panel_words");
        write_x(&path, &x).unwrap();
        let d = XDisk::open(&path).unwrap();
        assert_eq!(XSource::InCore(&x).panel_words(), ((DEFAULT_PANEL_ROWS + 44) * 3) as u64);
        assert_eq!(XSource::OnDisk(&d).panel_words(), (DEFAULT_PANEL_ROWS * 3) as u64);
        std::fs::remove_file(&path).unwrap();
    }
}
