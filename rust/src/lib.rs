//! # HP-CONCORD
//!
//! A production-quality reproduction of *"Communication-Avoiding
//! Optimization Methods for Distributed Massive-Scale Sparse Inverse
//! Covariance Estimation"* (Koanantakool et al., 2017): the HP-CONCORD
//! communication-avoiding distributed proximal gradient method for the
//! CONCORD/PseudoNet estimator, plus every substrate its evaluation
//! depends on.
//!
//! ## Architecture (three layers)
//!
//! - **L3 (this crate)** — the coordinator and distributed runtime: the
//!   1.5D communication-avoiding matrix multiplication (paper Algorithm 4,
//!   [`dist`]) over a simulated message-passing fabric ([`simnet`]) with
//!   exact α-β-γ cost accounting, the Cov/Obs proximal-gradient drivers
//!   (paper Algorithms 2 and 3, [`concord`]), the analytic cost model
//!   (Lemmas 3.1–3.5, [`cost`]), the QUIC-style second-order baseline
//!   ([`bigquic`]), data generators, clustering and metrics for the fMRI
//!   case study, and a tuning-grid sweep coordinator ([`coordinator`]).
//!   A long-running multi-tenant estimation service ([`serve`]) fronts
//!   the same pipelines over a line-delimited JSON protocol, packing
//!   concurrent jobs through the shared executor and reusing screening
//!   artifacts via a dataset-fingerprint cache.
//! - **L2 (python/compile/model.py)** — CONCORD step graphs in JAX,
//!   AOT-lowered once to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas kernels (tiled GEMM, fused
//!   gradient/prox/objective passes) called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT CPU
//! client (`xla` crate, behind the non-default `pjrt` feature) so Python
//! never runs on the request path; a pure Rust fallback covers arbitrary
//! shapes and is the only path in the default offline build.
//!
//! ## Screened solving (the paper's §6 divide-and-conquer)
//!
//! Exact thresholding (Mazumder–Hastie) splits the problem into the
//! connected components of `{|S_ij| > λ₁}` losslessly.
//! [`concord::screening`] owns the decomposition (union-find, nested
//! per-λ₁ refinement, reassembly); [`concord::screened_dist`] composes
//! it with the distributed layer — a distributed screening pass, then
//! one cost-model-sized fabric per component ([`cost::schedule`]) —
//! and the sweep coordinator reuses one gram + one nested component
//! pass across a whole λ-grid (`coordinator::sweep::run_sweep_screened`).
//! CLI: `--screen` / `solver.screen = true`.
//!
//! ## The kernel layer: cache-blocked, packed, deterministic
//!
//! Every node-local multiply runs on the blocked kernel layer in
//! [`linalg`]: a BLIS-style `mc × kc × nc` tiling ([`linalg::tile`])
//! with packed A/B panels and a fixed `MR × NR` register microkernel
//! for dense GEMM, and column-blocked packed-panel SpMM for the sparse
//! `Ω·S` products. Blocking shapes come from compile-time defaults,
//! `ConcordConfig::tile`, or the CLI's `--tile mc,kc,nc` — and are
//! **throughput knobs only**: every kernel accumulates each output
//! element in strictly ascending-k order, so the blocked product is
//! bit-identical to the naive reference (`Mat::matmul_naive`,
//! `Csr::spmm_reference`) at every tile shape. `ARCHITECTURE.md` states
//! the layer's determinism rules; `rust/benches/perf_hotpath.rs` has
//! the blocked-vs-naive GFLOP/s tables.
//!
//! ## Node-local parallelism (the paper's per-node `t`)
//!
//! The paper models each node as threaded MKL on 24 cores: every
//! node-local multiply runs on `t` threads and the Lemma 3.1–3.5 flop
//! terms divide by `t`. This crate mirrors that with a deterministic
//! scoped pool ([`util::pool`], no external deps): `Mat::matmul_mt` /
//! `Mat::matmul_bt_mt` / `Csr::spmm_mt` and the fused CONCORD passes
//! (`concord::ops::*_mt`) partition rows into contiguous chunks and run
//! the unmodified serial inner loops, so results are **bit-for-bit
//! identical at every thread count** — scalar reductions use a fixed
//! 64-row block order ([`concord::ops::REDUCE_BLOCK_ROWS`]) for the
//! same reason. The knob is `ConcordConfig::threads` /
//! `QuicConfig::threads` (CLI `--threads N|auto`); it accelerates the
//! single-node solver, every simulated rank's local kernels, and the
//! BigQUIC baseline, while the metered message/word counts are
//! provably untouched (`rust/tests/parallel_determinism.rs`,
//! `rust/tests/lemma_counts.rs`). The cost model prices threading via
//! `CostBreakdown::time_with_threads` (flops/(P·t)) and the kernel
//! layer's cache reuse via `CostBreakdown::time_with_tile`
//! (γ_dense + w(tile)·β_mem per dense flop).
//!
//! ## Quick start
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't get the xla rpath link flag,
//! # // so they can't locate libxla_extension's bundled libstdc++ at runtime.
//! use hpconcord::prelude::*;
//! use hpconcord::concord::{self, ConcordConfig};
//!
//! let mut rng = Rng::new(42);
//! let problem = gen::chain_problem(64, 200, &mut rng);
//! let cfg = ConcordConfig { lambda1: 0.2, ..Default::default() };
//! let fit = concord::fit_single_node(&problem.x, &cfg).unwrap();
//! println!("converged in {} iterations", fit.iterations);
//! ```

pub mod bigquic;
pub mod cli;
pub mod cluster;
pub mod concord;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod dist;
pub mod gen;
pub mod io;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod simnet;
pub mod util;

/// Convenient glob-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::gen;
    pub use crate::linalg::{Csr, Mat};
    pub use crate::metrics;
    pub use crate::rng::Rng;
    pub use crate::simnet::{Fabric, MachineParams};
}
