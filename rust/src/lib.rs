//! # HP-CONCORD
//!
//! A production-quality reproduction of *"Communication-Avoiding
//! Optimization Methods for Distributed Massive-Scale Sparse Inverse
//! Covariance Estimation"* (Koanantakool et al., 2017): the HP-CONCORD
//! communication-avoiding distributed proximal gradient method for the
//! CONCORD/PseudoNet estimator, plus every substrate its evaluation
//! depends on.
//!
//! ## Architecture (three layers)
//!
//! - **L3 (this crate)** — the coordinator and distributed runtime: the
//!   1.5D communication-avoiding matrix multiplication (paper Algorithm 4)
//!   over a simulated message-passing fabric ([`simnet`]) with exact
//!   α-β-γ cost accounting, the Cov/Obs proximal-gradient drivers (paper
//!   Algorithms 2 and 3, [`concord`]), the analytic cost model (Lemmas
//!   3.1–3.5, [`cost`]), the QUIC-style second-order baseline
//!   ([`bigquic`]), data generators, clustering and metrics for the fMRI
//!   case study, and a tuning-grid sweep coordinator ([`coordinator`]).
//! - **L2 (python/compile/model.py)** — CONCORD step graphs in JAX,
//!   AOT-lowered once to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas kernels (tiled GEMM, fused
//!   gradient/prox/objective passes) called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT CPU
//! client (`xla` crate) so Python never runs on the request path; a pure
//! Rust fallback covers arbitrary shapes.
//!
//! ## Quick start
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't get the xla rpath link flag,
//! # // so they can't locate libxla_extension's bundled libstdc++ at runtime.
//! use hpconcord::prelude::*;
//! use hpconcord::concord::{self, ConcordConfig};
//!
//! let mut rng = Rng::new(42);
//! let problem = gen::chain_problem(64, 200, &mut rng);
//! let cfg = ConcordConfig { lambda1: 0.2, ..Default::default() };
//! let fit = concord::fit_single_node(&problem.x, &cfg).unwrap();
//! println!("converged in {} iterations", fit.iterations);
//! ```

pub mod bigquic;
pub mod cli;
pub mod cluster;
pub mod concord;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod dist;
pub mod gen;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod simnet;
pub mod util;

/// Convenient glob-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::gen;
    pub use crate::linalg::{Csr, Mat};
    pub use crate::metrics;
    pub use crate::rng::Rng;
    pub use crate::simnet::{Fabric, MachineParams};
}
