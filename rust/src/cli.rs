//! Hand-rolled CLI (clap is not vendored offline): `--key value` /
//! `--flag` options plus positional arguments, with typed accessors.
//! The launcher subcommands live in `main.rs` and are built from these
//! parts plus [`crate::config::Config`] files.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` options and bare `--flag`s (value "true").
    options: HashMap<String, String>,
}

impl Args {
    /// Parse an argv tail (without the program name). An option takes a
    /// value unless the next token is another option or absent.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                let takes_value = argv
                    .get(i + 1)
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false);
                if takes_value {
                    out.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                out.positional.push(tok.clone());
                i += 1;
            }
        }
        out
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad number {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    /// Comma-separated f64 list.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.options.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<f64>().map_err(|_| anyhow!("--{key}: bad list {v:?}")))
                .collect(),
        }
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
hpconcord — communication-avoiding sparse inverse covariance estimation

USAGE: hpconcord <COMMAND> [OPTIONS]

COMMANDS:
  solve    Fit one problem (single-node or simulated-distributed)
           --workload chain|random  --p N --n N [--deg N] [--seed S]
           --lambda1 F --lambda2 F [--tol F] [--max-iter N]
           --mode single|dist  [--ranks P --cx C --comega C]
           [--threads N|auto]  (node-local worker threads, the paper's t)
           [--tile mc,kc,nc|auto]  (cache-blocking shape of the packed
             GEMM/SpMM kernels; results are bit-identical at any tile —
             only throughput moves. Default 128,256,512. `auto` runs a
             short measured sweep over published candidates at startup
             and installs the winner — sound at any outcome, since the
             tile is value-preserving. TOML: solver.tile = [mc,kc,nc]
             or solver.tile_auto = true)
           [--kernel scalar|avx2|avx512|auto]  (GEMM microkernel ISA
             lane, dispatched once at startup. Every lane runs the
             scalar kernel's exact per-element op sequence — one mul +
             one add per k, never FMA — so results are bit-identical
             on every lane (determinism rule 10); auto (the default)
             picks the widest lane the host supports, and forcing a
             lane the host lacks is a clean error. TOML: solver.kernel)
           [--pin-cores]  (pin pool workers to cores, worker i → CPU
             i mod available_parallelism, so packed panels stop
             migrating between per-core caches; schedule-only — bits
             never move; no-op where unsupported. TOML:
             solver.pin_cores)
           [--variant cov|obs|auto]  [--config FILE]  [--artifacts DIR]
           [--screen]  (exact-thresholding screening: split into the
             connected components of {|S_ij| > λ1}; in dist mode the
             cost model sizes one fabric per component, --ranks caps
             each fabric, and explicit --cx/--comega pin every fabric)
           [--screen-cutoff N]  (components ≤ N solve single-node; 4)
           [--ranks-budget N]  (global concurrent rank budget: screened
             component fabrics are packed into waves of ≤ N ranks and
             run at the same time; default --ranks. A fixed budget only
             reorders launches — results are bit-identical; a budget
             below a planned fabric shrinks that plan to fit)
           [--mem-budget N]  (host-memory budget in f64 words for wave
             packing: each task bills n·|c| words for its extracted
             sub-matrix plus |c|² working set, and waves are packed so
             resident footprints never exceed N; 0 = unbounded. A
             schedule-only knob — results are bit-identical at any
             budget that admits a schedule; a component too large to
             fit alone is a clean error)
           [--gram-block N]  (stream the screening gram in row panels
             of N samples so screening never needs all of X resident;
             0 = in-core. Bit-identical to the in-core pass)
           [--x-file FILE]  (read X from an on-disk HPCX file written
             by `convert` instead of keeping it in memory; requires
             --mode dist with --screen. The X backend is a
             schedule-only knob (determinism rule 8): the estimate,
             objective and counters are bit-identical to the in-core
             run — only the modeled source residency moves. TOML:
             solver.x_file)
           [--out-omega FILE]  (write the estimate as whitespace-
             separated rows, full f64 round-trip precision)
  sweep    (λ1, λ2) grid sweep via the coordinator
           --l1 a,b,c --l2 a,b  [--workers N]  + workload options
           [--screen]  (screened sweep: one gram + nested components
             reused across the whole λ grid)
           [--mode dist]  (requires --screen: the *grid* is the
             scheduling unit — one amortized distributed screening
             pass covers the whole λ1 list (gram + labeling collective
             billed once), and every (grid point, component) fabric is
             packed into one shared wave schedule under --ranks-budget;
             waves may mix grid points. Results are bit-identical to
             solving each point alone. --ranks/--cx/--comega/
             --ranks-budget/--mem-budget/--gram-block/--x-file as in
             solve; --workers is single-node-sweep only)
           [--per-point]  (dist only: solve every grid point standalone
             — its own screening pass, its own waves; the billing
             baseline and equivalence reference)
           [--out-csv FILE]  (write the grid as CSV — λ1, λ2, density,
             iterations, components, per-point modeled seconds — for
             offline model selection)
           [--select-density T] [--out-omega FILE]  (write the estimate
             whose off-diagonal density is closest to T; default 0.1)
  serve    Long-running multi-tenant estimation service: admits solve /
           sweep / stability jobs over a line-delimited JSON protocol
           (one frame per line over TCP), packs concurrent jobs through
           the shared wave executor under the operator's global budgets,
           and reuses screening artifacts across jobs keyed on the
           dataset fingerprint. A served result is byte-for-byte the
           `--out-omega` of the equivalent CLI run (determinism rule 9).
           [--addr HOST:PORT]  (bind address; default 127.0.0.1:7878,
             TOML serve.addr; port 0 picks a free port, printed as
             \"serving on ADDR\" at startup)
           [--ranks-budget N] [--mem-budget N]  (global caps applied to
             every admitted job — schedule-only: they override the
             per-job knobs but never a result bit; TOML
             serve.ranks_budget / serve.mem_budget)
  client   Submit one job to a running server and wait for it
           --addr HOST:PORT  [--kind solve|sweep|stability]
           + the solve/sweep workload and solver options (the request
             travels over the wire; the server loads or generates X)
           [--subsamples N --fraction F --stab-threshold F
             --stab-seed S]  (stability kind)
           [--select-density T]  (sweep kind: which point's omega the
             `result` op returns; default 0.1)
           [--out-omega FILE]  (write the returned estimate — compares
             equal via cmp with a local run's --out-omega)
           [--shutdown]  (ask the server to exit instead of submitting)
  convert  Write a workload's X to an on-disk HPCX file for later
           `solve`/`sweep ... --x-file` runs (24-byte header — magic
           \"HPCX\", version, n, p — then row-major LE f64; written
           atomically via a temp file, so a failed convert leaves no
           partial output)
           --out FILE  + workload options (--workload/--p/--n/--deg/
             --seed/--config: the same options generate the same X, so
             a convert + --x-file run is the in-core run's bit-exact
             twin)
  cost     Analytic cost model (Lemmas 3.1–3.5) over replication grid
           --p N --n N --s F --t F --d F --procs P [--threads N]
           [--variant cov|obs]  [--tile mc,kc,nc]  (prices the dense
             flops with the tile's cache-reuse term)
           [--kernel scalar|avx2|avx512|auto]  (prices γ_dense at the
             lane's measured speedup over the scalar blocked kernel —
             see BENCH_simd_baseline.json)
  fmri     Synthetic-cortex parcellation pipeline (paper §5, scaled)
           [--p-hemi N] [--parcels K] [--samples N] [--seed S]
  engine   List and smoke-run the AOT artifacts through PJRT
           [--artifacts DIR]
  help     Show this message

NOTES:
  Library users: the `_src`-suffixed screened entry points
  (fit_screened_distributed_src and friends) are deprecated — the
  canonical functions now take an XSource directly; `_mat` shims cover
  in-core callers for one release.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_and_flags() {
        let a = Args::parse(&argv("solve --p 128 --workload chain --verbose"));
        assert_eq!(a.subcommand(), Some("solve"));
        assert_eq!(a.usize_or("p", 0).unwrap(), 128);
        assert_eq!(a.str_or("workload", "x"), "chain");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_errors() {
        let a = Args::parse(&argv("solve --p abc"));
        assert!(a.usize_or("p", 0).is_err());
    }

    #[test]
    fn negative_and_garbage_budget_knobs_error_cleanly() {
        // A negative token is a value (it does not start with "--"), and
        // the unsigned parsers must reject it rather than wrap.
        let a = Args::parse(&argv("solve --mem-budget -5 --gram-block 2.5 --ranks-budget 1e3"));
        assert!(a.u64_or("mem-budget", 0).is_err());
        assert!(a.usize_or("gram-block", 0).is_err());
        assert!(a.usize_or("ranks-budget", 0).is_err());
        // Error text names the offending flag so the user can find it.
        let e = a.u64_or("mem-budget", 0).unwrap_err();
        assert!(format!("{e}").contains("mem-budget"));
    }

    #[test]
    fn float_knobs_reject_garbage() {
        let a = Args::parse(&argv("sweep --l1 0.1,zz --select-density x"));
        assert!(a.f64_list_or("l1", &[]).is_err());
        assert!(a.f64_or("select-density", 0.1).is_err());
    }

    #[test]
    fn lists_parse() {
        let a = Args::parse(&argv("sweep --l1 0.1,0.2,0.5"));
        assert_eq!(a.f64_list_or("l1", &[]).unwrap(), vec![0.1, 0.2, 0.5]);
        assert_eq!(a.f64_list_or("l2", &[9.0]).unwrap(), vec![9.0]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("cost"));
        assert_eq!(a.f64_or("t", 10.0).unwrap(), 10.0);
        assert_eq!(a.u64_or("seed", 7).unwrap(), 7);
    }
}
