//! Undirected weighted graphs, built from estimate sparsity patterns.

use crate::linalg::Mat;

/// Adjacency-list undirected weighted graph.
#[derive(Debug, Clone)]
pub struct Graph {
    /// adj[v] = (neighbour, weight); both directions stored.
    pub adj: Vec<Vec<(usize, f64)>>,
}

impl Graph {
    pub fn new(n: usize) -> Graph {
        Graph { adj: vec![Vec::new(); n] }
    }

    pub fn n(&self) -> usize {
        self.adj.len()
    }

    /// Add an undirected edge (caller avoids duplicates).
    pub fn add_edge(&mut self, u: usize, v: usize, w: f64) {
        assert_ne!(u, v, "no self loops");
        self.adj[u].push((v, w));
        self.adj[v].push((u, w));
    }

    /// Partial-correlation graph of an estimate: edge (i, j) iff
    /// |Ω̂_ij| > tol, weighted by |Ω̂_ij| (paper §1: the sparsity pattern
    /// of the inverse covariance is the partial correlation graph).
    pub fn from_sparsity(omega: &Mat, tol: f64) -> Graph {
        let p = omega.rows();
        let mut g = Graph::new(p);
        for i in 0..p {
            for j in (i + 1)..p {
                let v = omega.get(i, j).abs();
                if v > tol {
                    g.add_edge(i, j, v);
                }
            }
        }
        g
    }

    /// Induced subgraph on `nodes` (re-indexed 0..nodes.len()).
    pub fn subgraph(&self, nodes: &[usize]) -> Graph {
        let mut index = vec![usize::MAX; self.n()];
        for (new, &old) in nodes.iter().enumerate() {
            index[old] = new;
        }
        let mut g = Graph::new(nodes.len());
        for (new_u, &old_u) in nodes.iter().enumerate() {
            for &(old_v, w) in &self.adj[old_u] {
                let new_v = index[old_v];
                if new_v != usize::MAX && new_u < new_v {
                    g.add_edge(new_u, new_v, w);
                }
            }
        }
        g
    }

    /// Weighted degree of every vertex.
    pub fn degrees(&self) -> Vec<f64> {
        self.adj.iter().map(|ns| ns.iter().map(|&(_, w)| w).sum()).collect()
    }

    /// Unweighted degree (edge count) of every vertex — the function the
    /// persistence watershed sweeps (§S.3.4 maps the degree of each
    /// vertex in the inverse covariance graph onto the surface).
    pub fn edge_counts(&self) -> Vec<f64> {
        self.adj.iter().map(|ns| ns.len() as f64).collect()
    }

    /// Total edge weight (each edge once).
    pub fn total_weight(&self) -> f64 {
        self.adj.iter().flatten().map(|&(_, w)| w).sum::<f64>() / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_sparsity_thresholds() {
        let mut m = Mat::eye(4);
        m.set(0, 1, 0.5);
        m.set(1, 0, 0.5);
        m.set(2, 3, 1e-9);
        m.set(3, 2, 1e-9);
        let g = Graph::from_sparsity(&m, 1e-6);
        assert_eq!(g.adj[0], vec![(1, 0.5)]);
        assert!(g.adj[2].is_empty());
        assert_eq!(g.total_weight(), 0.5);
    }

    #[test]
    fn subgraph_reindexes() {
        let mut g = Graph::new(5);
        g.add_edge(0, 2, 1.0);
        g.add_edge(2, 4, 2.0);
        g.add_edge(1, 3, 3.0);
        let sub = g.subgraph(&[0, 2, 4]);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.adj[0], vec![(1, 1.0)]);
        assert_eq!(sub.adj[1], vec![(0, 1.0), (2, 2.0)]);
    }

    #[test]
    fn degrees_and_counts() {
        let mut g = Graph::new(3);
        g.add_edge(0, 1, 0.5);
        g.add_edge(0, 2, 1.5);
        assert_eq!(g.degrees(), vec![2.0, 0.5, 1.5]);
        assert_eq!(g.edge_counts(), vec![2.0, 1.0, 1.0]);
    }
}
