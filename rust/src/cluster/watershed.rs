//! Persistent-homology watershed parcellation (paper §S.3.4).
//!
//! The vertex degree of the partial-correlation graph is mapped onto the
//! cortical "surface" (here: the voxel neighbourhood graph standing in
//! for the triangulation), and a watershed sweep from highest to lowest
//! value grows one label per local maximum. The resulting
//! over-segmentation is coarsened with persistence: when two label
//! components meet at a vertex v, the dual-graph edge between them gets
//! the value `min(a₁, a₂) − f(v)` (a_i = max f over the component —
//! exactly the persistence of v), and components joined by edges with
//! value ≤ ε are merged. Raising ε coarsens the parcellation.

use super::graph::Graph;

/// Watershed + persistence merge. `surface` is the neighbourhood graph
/// (mesh substitute), `f` the per-vertex function (degree in the partial
/// correlation graph), `epsilon` the persistence simplification
/// threshold. Returns per-vertex parcel labels (0..k).
pub fn watershed_persistence(surface: &Graph, f: &[f64], epsilon: f64) -> Vec<usize> {
    let n = surface.n();
    assert_eq!(f.len(), n);
    // Sweep order: decreasing f (ties by index for determinism).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| f[b].partial_cmp(&f[a]).unwrap().then(a.cmp(&b)));

    let mut label = vec![usize::MAX; n];
    let mut births: Vec<f64> = Vec::new(); // birth (max f) per raw label
    let mut uf = UnionFind::new(0);

    for &v in &order {
        // Labelled neighbours (already swept).
        let mut seen: Vec<usize> = surface.adj[v]
            .iter()
            .filter_map(|&(u, _)| (label[u] != usize::MAX).then(|| uf.find(label[u])))
            .collect();
        seen.sort_unstable();
        seen.dedup();
        match seen.len() {
            0 => {
                // Local maximum: start a new label.
                let l = births.len();
                births.push(f[v]);
                uf.push();
                label[v] = l;
            }
            1 => {
                label[v] = seen[0];
            }
            _ => {
                // Components meet at v: propagate the label with maximum
                // starting value; record/merge dual edges by persistence.
                let best = *seen
                    .iter()
                    .max_by(|&&a, &&b| {
                        uf.birth(a, &births)
                            .partial_cmp(&uf.birth(b, &births))
                            .unwrap()
                    })
                    .unwrap();
                label[v] = best;
                for &other in &seen {
                    if other == best {
                        continue;
                    }
                    let persistence =
                        uf.birth(best, &births).min(uf.birth(other, &births)) - f[v];
                    if persistence <= epsilon {
                        uf.union(best, other);
                    }
                }
            }
        }
    }

    // Final labels through the union-find, renumbered densely.
    let mut map = std::collections::HashMap::new();
    (0..n)
        .map(|v| {
            let root = uf.find(label[v]);
            let next = map.len();
            *map.entry(root).or_insert(next)
        })
        .collect()
}

/// Neighbourhood-average smoothing of a vertex field (`rounds` passes of
/// f(v) ← mean over {v} ∪ N(v)). The §S.3.4 degree field is integer-
/// quantized at small scales; a little smoothing de-plateaus it so the
/// watershed basins follow regional density rather than single-vertex
/// ties. Used by the fMRI pipeline before [`watershed_persistence`].
pub fn smooth_field(surface: &Graph, f: &[f64], rounds: usize) -> Vec<f64> {
    let mut cur = f.to_vec();
    for _ in 0..rounds {
        let mut next = vec![0.0; cur.len()];
        for v in 0..surface.n() {
            let mut sum = cur[v];
            let mut cnt = 1.0;
            for &(u, _) in &surface.adj[v] {
                sum += cur[u];
                cnt += 1.0;
            }
            next[v] = sum / cnt;
        }
        cur = next;
    }
    cur
}

/// Union-find over raw watershed labels, tracking per-component max birth.
struct UnionFind {
    parent: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect() }
    }

    fn push(&mut self) {
        let l = self.parent.len();
        self.parent.push(l);
    }

    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let root = self.find(self.parent[x]);
            self.parent[x] = root;
        }
        self.parent[x]
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            // Root at the lower index: keeps the oldest (highest-birth
            // first-created) label as representative deterministically.
            let (lo, hi) = (ra.min(rb), ra.max(rb));
            self.parent[hi] = lo;
        }
    }

    /// Max birth over the component of x (birth vector indexed by raw
    /// label; components are created in decreasing birth order, so the
    /// root — lowest index — has the max birth).
    fn birth(&mut self, x: usize, births: &[f64]) -> f64 {
        let r = self.find(x);
        births[r]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A path graph 0-1-...-(n-1).
    fn path(n: usize) -> Graph {
        let mut g = Graph::new(n);
        for i in 0..n - 1 {
            g.add_edge(i, i + 1, 1.0);
        }
        g
    }

    #[test]
    fn two_peaks_two_parcels_at_zero_epsilon() {
        // f: peak at 2 (value 5), valley at 4 (1), peak at 6 (4).
        let f = vec![2.0, 3.0, 5.0, 2.0, 1.0, 3.0, 4.0, 2.0];
        let g = path(8);
        let labels = watershed_persistence(&g, &f, 0.0);
        assert_eq!(labels[2], labels[1]);
        assert_eq!(labels[6], labels[5]);
        assert_ne!(labels[2], labels[6], "{labels:?}");
    }

    #[test]
    fn large_epsilon_merges_everything() {
        let f = vec![2.0, 3.0, 5.0, 2.0, 1.0, 3.0, 4.0, 2.0];
        let g = path(8);
        let labels = watershed_persistence(&g, &f, 100.0);
        assert!(labels.iter().all(|&l| l == labels[0]), "{labels:?}");
    }

    #[test]
    fn epsilon_between_persistences_merges_weak_peak_only() {
        // Peaks: v2 (5), v6 (4), v10 (4.8); valleys v4 (1), v8 (3.5).
        // Persistence of the v6 peak against v10: min(4, 4.8) - 3.5 = 0.5.
        // Persistence of the merged right blob against v2: much larger.
        let f = vec![2.0, 3.0, 5.0, 2.0, 1.0, 3.0, 4.0, 3.6, 3.5, 4.0, 4.8, 3.0];
        let g = path(12);
        let labels = watershed_persistence(&g, &f, 1.0);
        assert_eq!(labels[6], labels[10], "weak peak merged: {labels:?}");
        assert_ne!(labels[2], labels[6], "strong split kept: {labels:?}");
    }

    #[test]
    fn monotone_in_epsilon() {
        let f: Vec<f64> = (0..30)
            .map(|i| ((i as f64) * 0.9).sin() * 3.0 + (i as f64 * 0.13).cos())
            .collect();
        let g = path(30);
        let count = |eps: f64| {
            let l = watershed_persistence(&g, &f, eps);
            let mut s = l.clone();
            s.sort_unstable();
            s.dedup();
            s.len()
        };
        let (c0, c1, c2) = (count(0.0), count(1.0), count(10.0));
        assert!(c0 >= c1 && c1 >= c2, "{c0} {c1} {c2}");
        assert!(c0 >= 2);
        assert_eq!(c2, 1);
    }

    #[test]
    fn smoothing_preserves_mean_and_flattens() {
        let g = path(10);
        let f = vec![0.0, 10.0, 0.0, 10.0, 0.0, 10.0, 0.0, 10.0, 0.0, 10.0];
        let s = smooth_field(&g, &f, 3);
        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
        // Mean roughly preserved, variance strictly reduced.
        assert!((mean(&s) - mean(&f)).abs() < 1.5);
        let var = |xs: &[f64]| {
            let m = mean(xs);
            xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
        };
        assert!(var(&s) < var(&f) / 2.0);
        // Zero rounds is the identity.
        assert_eq!(smooth_field(&g, &f, 0), f);
    }

    #[test]
    fn constant_function_single_parcel() {
        let g = path(10);
        let labels = watershed_persistence(&g, &vec![1.0; 10], 0.0);
        assert!(labels.iter().all(|&l| l == labels[0]));
    }
}
