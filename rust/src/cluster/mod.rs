//! Graph clustering for the fMRI case study (paper §5): the partial
//! correlation graph from an HP-CONCORD estimate is clustered with
//! either the Louvain method [13] or the persistent-homology watershed
//! of §S.3.4, and compared against a reference parcellation with the
//! modified Jaccard score ([`crate::metrics::jaccard`]).

pub mod graph;
pub mod louvain;
pub mod watershed;

pub use graph::Graph;
pub use louvain::{louvain, louvain_levels};
pub use watershed::{smooth_field, watershed_persistence};
