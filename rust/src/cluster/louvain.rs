//! The Louvain community-detection method (Blondel et al. [13]):
//! greedy modularity optimization with graph aggregation.
//!
//! Phase 1 repeatedly moves single vertices to the neighbouring
//! community with the largest modularity gain; phase 2 contracts each
//! community to a vertex and repeats. Each aggregation level yields a
//! clustering — the paper's §S.3.6 evaluates both "k = 0" (the final,
//! coarsest level) and "k = max # clusters from Louvain" (the finest
//! level), so [`louvain_levels`] returns all of them.

use super::graph::Graph;

/// All aggregation levels, finest first; each is a label vector over the
/// original vertices.
pub fn louvain_levels(g: &Graph) -> Vec<Vec<usize>> {
    let mut levels: Vec<Vec<usize>> = Vec::new();
    // Mapping from original vertex to current-level vertex.
    let mut mapping: Vec<usize> = (0..g.n()).collect();
    let mut current = g.clone();
    // Self-loop weight per current-level vertex (intra-community weight
    // accumulated by aggregation; counts toward degrees and m2).
    let mut selfw = vec![0.0f64; g.n()];
    loop {
        let (labels, improved) = one_level(&current, &selfw);
        let communities = renumber(&labels);
        let n_comms = communities.iter().copied().max().map_or(0, |m| m + 1);
        // Compose with the running mapping to label original vertices.
        let level_labels: Vec<usize> =
            mapping.iter().map(|&cv| communities[cv]).collect();
        if !improved && !levels.is_empty() {
            break;
        }
        levels.push(level_labels.clone());
        if n_comms == current.n() {
            break; // no contraction possible
        }
        let (agg, agg_selfw) = aggregate(&current, &selfw, &communities, n_comms);
        current = agg;
        selfw = agg_selfw;
        mapping = level_labels;
        if n_comms <= 1 {
            break;
        }
    }
    levels
}

/// Final (coarsest) Louvain clustering.
pub fn louvain(g: &Graph) -> Vec<usize> {
    louvain_levels(g).pop().expect("at least one level")
}

/// One local-move phase; returns (community of each vertex, improved?).
/// `selfw[v]` is v's self-loop weight (from prior aggregations): it adds
/// to v's degree and to m2 but can never be moved away from v.
fn one_level(g: &Graph, selfw: &[f64]) -> (Vec<usize>, bool) {
    let n = g.n();
    let m2 = 2.0 * g.total_weight() + selfw.iter().sum::<f64>();
    if m2 == 0.0 {
        return ((0..n).collect(), false);
    }
    let k: Vec<f64> = g
        .degrees()
        .iter()
        .zip(selfw)
        .map(|(d, s)| d + s)
        .collect();
    let mut comm: Vec<usize> = (0..n).collect();
    let mut sigma_tot: Vec<f64> = k.clone(); // total degree per community
    let mut improved_any = false;
    // Deterministic sweep order; repeat until a full pass makes no move.
    for _pass in 0..n.max(8) {
        let mut moved = false;
        for v in 0..n {
            let cv = comm[v];
            // Weights from v to each neighbouring community.
            let mut links: std::collections::HashMap<usize, f64> =
                std::collections::HashMap::new();
            for &(u, w) in &g.adj[v] {
                if u != v {
                    *links.entry(comm[u]).or_insert(0.0) += w;
                }
            }
            let w_own = links.get(&cv).copied().unwrap_or(0.0);
            // Remove v from its community.
            sigma_tot[cv] -= k[v];
            // Best gain: ΔQ ∝ w_vc − k_v·Σ_tot(c)/m2.
            let mut best_c = cv;
            let mut best_gain = w_own - k[v] * sigma_tot[cv] / m2;
            for (&c, &w_vc) in &links {
                if c == cv {
                    continue;
                }
                let gain = w_vc - k[v] * sigma_tot[c] / m2;
                if gain > best_gain + 1e-12 {
                    best_gain = gain;
                    best_c = c;
                }
            }
            sigma_tot[best_c] += k[v];
            if best_c != cv {
                comm[v] = best_c;
                moved = true;
                improved_any = true;
            }
        }
        if !moved {
            break;
        }
    }
    (comm, improved_any)
}

/// Renumber arbitrary labels to 0..k (first-seen order).
fn renumber(labels: &[usize]) -> Vec<usize> {
    let mut map = std::collections::HashMap::new();
    labels
        .iter()
        .map(|&l| {
            let next = map.len();
            *map.entry(l).or_insert(next)
        })
        .collect()
}

/// Contract communities into vertices, summing parallel edge weights;
/// intra-community weight (and existing self-loops) becomes the new
/// vertices' self-loop weight (doubled, per the modularity convention).
fn aggregate(
    g: &Graph,
    selfw: &[f64],
    comm: &[usize],
    n_comms: usize,
) -> (Graph, Vec<f64>) {
    let mut weights: std::collections::HashMap<(usize, usize), f64> =
        std::collections::HashMap::new();
    let mut new_selfw = vec![0.0f64; n_comms];
    for v in 0..g.n() {
        new_selfw[comm[v]] += selfw[v];
        for &(u, w) in &g.adj[v] {
            if v < u {
                let (a, b) = (comm[v].min(comm[u]), comm[v].max(comm[u]));
                if a != b {
                    *weights.entry((a, b)).or_insert(0.0) += w;
                } else {
                    new_selfw[a] += 2.0 * w;
                }
            }
        }
    }
    let mut out = Graph::new(n_comms);
    for ((a, b), w) in weights {
        out.add_edge(a, b, w);
    }
    (out, new_selfw)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two k-cliques joined by one weak edge.
    fn two_cliques(k: usize) -> Graph {
        let mut g = Graph::new(2 * k);
        for off in [0, k] {
            for i in 0..k {
                for j in (i + 1)..k {
                    g.add_edge(off + i, off + j, 1.0);
                }
            }
        }
        g.add_edge(0, k, 0.1);
        g
    }

    #[test]
    fn separates_two_cliques() {
        let g = two_cliques(6);
        let labels = louvain(&g);
        // All of clique 1 in one community, clique 2 in another.
        for i in 1..6 {
            assert_eq!(labels[i], labels[0]);
            assert_eq!(labels[6 + i], labels[6]);
        }
        assert_ne!(labels[0], labels[6]);
    }

    #[test]
    fn levels_get_coarser() {
        let g = two_cliques(5);
        let levels = louvain_levels(&g);
        assert!(!levels.is_empty());
        let count = |ls: &Vec<usize>| {
            let mut v = ls.clone();
            v.sort_unstable();
            v.dedup();
            v.len()
        };
        for w in levels.windows(2) {
            assert!(count(&w[1]) <= count(&w[0]), "levels must coarsen");
        }
    }

    #[test]
    fn empty_graph_is_singletons() {
        let g = Graph::new(5);
        let labels = louvain(&g);
        let mut sorted = labels.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
    }

    #[test]
    fn ring_of_cliques_finds_cliques() {
        // 4 triangles in a ring, weakly connected.
        let mut g = Graph::new(12);
        for c in 0..4 {
            let b = 3 * c;
            g.add_edge(b, b + 1, 1.0);
            g.add_edge(b, b + 2, 1.0);
            g.add_edge(b + 1, b + 2, 1.0);
            g.add_edge(b + 2, (b + 3) % 12, 0.05);
        }
        let labels = louvain(&g);
        for c in 0..4 {
            let b = 3 * c;
            assert_eq!(labels[b], labels[b + 1]);
            assert_eq!(labels[b], labels[b + 2]);
        }
    }
}
