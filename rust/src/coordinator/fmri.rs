//! The §5 case study as a reusable pipeline: synthetic cortex →
//! (λ₁, λ₂) sweep → density-targeted model selection → per-hemisphere
//! clustering (persistence watershed, Louvain, covariance-threshold
//! baseline) → modified-Jaccard scores against the ground-truth
//! parcellation (the Glasser-et-al. role).
//!
//! Used by `hpconcord fmri`, `examples/fmri_parcellation.rs` (the
//! end-to-end driver) and `benches/fmri_table2.rs` (Table 2 / S.9–S.16
//! reproduction).

use crate::cluster::{louvain, louvain_levels, smooth_field, watershed_persistence, Graph};
use crate::concord::ConcordConfig;
use crate::gen::{synthetic_cortex, Cortex};
use crate::linalg::Mat;
use crate::metrics::jaccard_similarity;
use crate::rng::Rng;
use crate::runtime::native;

use super::sweep::{run_sweep, select_by_density, GridSpec};

/// Pipeline parameters (paper-scaled defaults live in `Default`).
#[derive(Debug, Clone)]
pub struct FmriParams {
    pub p_hemi: usize,
    pub parcels: usize,
    /// kNN connectivity of the ground-truth precision and the surface
    /// mesh substitute.
    pub knn: usize,
    pub samples: usize,
    pub seed: u64,
    pub lambda1_grid: Vec<f64>,
    pub lambda2_grid: Vec<f64>,
    /// Persistence simplification thresholds to evaluate (paper: ε ∈
    /// {0, 3} for more/fewer clusters).
    pub epsilons: Vec<f64>,
    pub workers: usize,
}

impl Default for FmriParams {
    fn default() -> Self {
        FmriParams {
            p_hemi: 96,
            parcels: 5,
            knn: 6,
            samples: 200,
            seed: 7,
            lambda1_grid: vec![0.15, 0.22, 0.3, 0.4, 0.55, 0.75],
            lambda2_grid: vec![0.0, 0.1],
            epsilons: vec![0.0, 3.0],
            workers: 2,
        }
    }
}

/// One clustering's evaluation.
#[derive(Debug, Clone)]
pub struct MethodScore {
    pub hemisphere: u8,
    pub method: String,
    pub clusters: usize,
    pub jaccard: f64,
}

/// The study's outcome.
#[derive(Debug)]
pub struct FmriOutcome {
    pub scores: Vec<MethodScore>,
    /// Selected tuning parameters (density-matched to the truth).
    pub lambda1: f64,
    pub lambda2: f64,
    /// Off-diagonal density of the chosen estimate vs the truth's.
    pub density: f64,
    pub target_density: f64,
    /// Fraction of the estimate's off-diagonal mass that crosses
    /// hemispheres (paper §S.3.3: should be ≈ 0 — block-diagonal).
    pub cross_hemisphere_fraction: f64,
    pub cortex: Cortex,
    /// The chosen estimate (for downstream analyses / plots).
    pub omega: Mat,
}

/// kNN neighbourhood graph over one hemisphere's voxel coordinates — the
/// triangulated-surface substitute that the watershed sweeps.
pub fn hemisphere_mesh(cortex: &Cortex, h: u8, k: usize) -> Graph {
    let idx = cortex.hemi_indices(h);
    let mut g = Graph::new(idx.len());
    for (a, &i) in idx.iter().enumerate() {
        let mut cands: Vec<(f64, usize)> = idx
            .iter()
            .enumerate()
            .filter(|&(b, _)| b != a)
            .map(|(b, &j)| {
                let d: f64 = (0..3)
                    .map(|c| (cortex.coords[i][c] - cortex.coords[j][c]).powi(2))
                    .sum();
                (d, b)
            })
            .collect();
        cands.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        for &(_, b) in cands.iter().take(k) {
            if !g.adj[a].iter().any(|&(n, _)| n == b) {
                g.add_edge(a, b, 1.0);
            }
        }
    }
    g
}

fn cluster_count(labels: &[usize]) -> usize {
    let mut s = labels.to_vec();
    s.sort_unstable();
    s.dedup();
    s.len()
}

/// Run the full study.
pub fn run_fmri_study(params: &FmriParams) -> FmriOutcome {
    let mut rng = Rng::new(params.seed);
    let cortex =
        synthetic_cortex(params.p_hemi, params.parcels, params.knn, params.samples, &mut rng);
    let p = cortex.p();

    // Target density: the ground truth's off-diagonal density (the paper
    // tunes until estimates are "equally sparse").
    let target_density = (cortex.omega0.nnz() - p) as f64 / (p * p - p) as f64;

    // Sweep the grid and select the density-matched estimate.
    let base = ConcordConfig { tol: 1e-4, max_iter: 150, ..Default::default() };
    let grid = GridSpec {
        lambda1: params.lambda1_grid.clone(),
        lambda2: params.lambda2_grid.clone(),
    };
    let outcome = run_sweep(&cortex.x, &grid, &base, params.workers);
    let chosen = select_by_density(&outcome.results, target_density).expect("non-empty sweep");
    let omega = chosen.fit.omega.clone();

    // Block-diagonal check (paper §S.3.3).
    let mut cross = 0usize;
    let mut total = 0usize;
    for i in 0..p {
        for j in 0..p {
            if i != j && omega.get(i, j) != 0.0 {
                total += 1;
                if cortex.hemisphere[i] != cortex.hemisphere[j] {
                    cross += 1;
                }
            }
        }
    }
    let cross_fraction = if total == 0 { 0.0 } else { cross as f64 / total as f64 };

    // Covariance-threshold baseline: keep the largest-|S_ij| entries at
    // the same density (paper's marginal-correlation baseline row).
    let s = native::gram(&cortex.x);
    let baseline = threshold_to_density(&s, target_density);

    let graph = Graph::from_sparsity(&omega, 1e-12);
    let base_graph = Graph::from_sparsity(&baseline, 1e-12);

    let mut scores = Vec::new();
    for h in 0..2u8 {
        let idx = cortex.hemi_indices(h);
        let truth = cortex.hemi_parcels(h);
        let mesh = hemisphere_mesh(&cortex, h, params.knn);
        let sub = graph.subgraph(&idx);
        // Smooth the quantized degree field so watershed basins track
        // regional density (see cluster::watershed::smooth_field).
        let f = smooth_field(&mesh, &sub.edge_counts(), 2);

        for &eps in &params.epsilons {
            let labels = watershed_persistence(&mesh, &f, eps);
            scores.push(MethodScore {
                hemisphere: h,
                method: format!("persistence ε={eps}"),
                clusters: cluster_count(&labels),
                jaccard: jaccard_similarity(&labels, &truth),
            });
        }

        let levels = louvain_levels(&sub);
        if let Some(coarse) = levels.last() {
            scores.push(MethodScore {
                hemisphere: h,
                method: "louvain k=0".to_string(),
                clusters: cluster_count(coarse),
                jaccard: jaccard_similarity(coarse, &truth),
            });
        }
        if levels.len() > 1 {
            let fine = &levels[0];
            scores.push(MethodScore {
                hemisphere: h,
                method: "louvain k=max".to_string(),
                clusters: cluster_count(fine),
                jaccard: jaccard_similarity(fine, &truth),
            });
        }

        // Baseline: Louvain on the thresholded-covariance graph.
        let bsub = base_graph.subgraph(&idx);
        let blabels = louvain(&bsub);
        scores.push(MethodScore {
            hemisphere: h,
            method: "cov-threshold".to_string(),
            clusters: cluster_count(&blabels),
            jaccard: jaccard_similarity(&blabels, &truth),
        });
    }

    FmriOutcome {
        scores,
        lambda1: chosen.job.cfg.lambda1,
        lambda2: chosen.job.cfg.lambda2,
        density: chosen.density,
        target_density,
        cross_hemisphere_fraction: cross_fraction,
        cortex,
        omega,
    }
}

/// Zero all but the top-magnitude off-diagonal entries of `m`, keeping
/// approximately the requested off-diagonal density (symmetric pairs).
pub fn threshold_to_density(m: &Mat, density: f64) -> Mat {
    let p = m.rows();
    let keep_pairs = ((density * (p * p - p) as f64) / 2.0).round() as usize;
    let mut mags: Vec<(f64, usize, usize)> = Vec::with_capacity(p * (p - 1) / 2);
    for i in 0..p {
        for j in (i + 1)..p {
            mags.push((m.get(i, j).abs(), i, j));
        }
    }
    mags.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut out = Mat::zeros(p, p);
    for i in 0..p {
        out.set(i, i, m.get(i, i));
    }
    for &(_, i, j) in mags.iter().take(keep_pairs) {
        out.set(i, j, m.get(i, j));
        out.set(j, i, m.get(j, i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> FmriParams {
        FmriParams {
            p_hemi: 32,
            parcels: 3,
            knn: 4,
            samples: 150,
            seed: 11,
            lambda1_grid: vec![0.2, 0.3, 0.45, 0.65],
            lambda2_grid: vec![0.1],
            epsilons: vec![0.0, 3.0],
            workers: 2,
        }
    }

    #[test]
    fn pipeline_runs_and_is_block_diagonal() {
        let out = run_fmri_study(&tiny_params());
        assert!(!out.scores.is_empty());
        // Density selection lands in the right ballpark.
        assert!(out.density > 0.0 && out.density < 4.0 * out.target_density + 0.1);
        // Hemisphere block structure mostly recovered (§S.3.3).
        assert!(
            out.cross_hemisphere_fraction < 0.2,
            "cross fraction {}",
            out.cross_hemisphere_fraction
        );
    }

    #[test]
    fn clusterings_beat_trivial_and_scores_in_range() {
        let out = run_fmri_study(&tiny_params());
        for s in &out.scores {
            assert!((0.0..=1.0).contains(&s.jaccard), "{s:?}");
            assert!(s.clusters >= 1);
        }
        // At least one method per hemisphere does clearly better than a
        // single-cluster baseline would.
        for h in 0..2u8 {
            let best = out
                .scores
                .iter()
                .filter(|s| s.hemisphere == h)
                .map(|s| s.jaccard)
                .fold(0.0, f64::max);
            let truth = out.cortex.hemi_parcels(h);
            let trivial = jaccard_similarity(&vec![0; truth.len()], &truth);
            assert!(best > trivial, "h={h}: best {best} !> trivial {trivial}");
        }
    }

    #[test]
    fn threshold_to_density_hits_target() {
        let mut rng = crate::rng::Rng::new(3);
        let m = Mat::from_fn(20, 20, |_, _| rng.normal());
        let out = threshold_to_density(&m, 0.2);
        let off_nnz = out.nnz() - 20;
        let density = off_nnz as f64 / (20.0 * 19.0);
        assert!((density - 0.2).abs() < 0.05, "density {density}");
    }
}
