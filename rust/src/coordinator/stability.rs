//! Stability selection (Meinshausen & Bühlmann [37], cited by the paper
//! as the resampling workload that makes scalability "prohibitive"
//! without HP-CONCORD): fit the estimator on many row subsamples and
//! keep the edges selected in at least a `threshold` fraction of them.
//!
//! This is the second first-class coordinator workload (after the λ
//! grid): B independent fits batched over the worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use crate::concord::{fit_single_node, ConcordConfig};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Stability-selection configuration.
#[derive(Debug, Clone, Copy)]
pub struct StabilityConfig {
    /// Number of subsample fits B.
    pub subsamples: usize,
    /// Fraction of rows per subsample (M&B use 0.5).
    pub fraction: f64,
    /// Selection frequency threshold π (M&B recommend 0.6–0.9).
    pub threshold: f64,
    pub seed: u64,
    pub workers: usize,
}

impl Default for StabilityConfig {
    fn default() -> Self {
        StabilityConfig { subsamples: 20, fraction: 0.5, threshold: 0.7, seed: 0, workers: 2 }
    }
}

/// Result: per-edge selection frequencies and the stable edge set.
#[derive(Debug)]
pub struct StabilityOutcome {
    /// Selection frequency of each (i, j) pair, i < j, in [0, 1];
    /// row-major upper triangle.
    pub frequency: Mat,
    /// Stable edges (frequency ≥ threshold).
    pub edges: Vec<(usize, usize)>,
    pub subsamples: usize,
}

/// Run stability selection with the worker pool.
pub fn stability_selection(
    x: &Mat,
    base: &ConcordConfig,
    cfg: &StabilityConfig,
) -> StabilityOutcome {
    let (n, p) = x.shape();
    let m = ((n as f64) * cfg.fraction).round().max(2.0) as usize;
    let x = Arc::new(x.clone());
    let base = *base;
    let scfg = *cfg;
    let next = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<Mat>();

    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let x = Arc::clone(&x);
        let next = Arc::clone(&next);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let b = next.fetch_add(1, Ordering::SeqCst);
            if b >= scfg.subsamples {
                break;
            }
            // Independent, reproducible subsample per index.
            let mut rng = Rng::new(scfg.seed ^ (0x5AB1E ^ (b as u64) << 20));
            let rows = rng.sample_indices(n, m);
            let sub = Mat::from_fn(m, p, |i, j| x.get(rows[i], j));
            let fit = fit_single_node(&sub, &base).expect("stability fit");
            // Indicator of selected off-diagonal support.
            let mut ind = Mat::zeros(p, p);
            for i in 0..p {
                for j in 0..p {
                    if i != j && fit.omega.get(i, j) != 0.0 {
                        ind.set(i, j, 1.0);
                    }
                }
            }
            tx.send(ind).expect("leader gone");
        }));
    }
    drop(tx);

    let mut freq = Mat::zeros(p, p);
    for ind in rx {
        freq.add_scaled(1.0 / cfg.subsamples as f64, &ind);
    }
    for h in handles {
        h.join().expect("stability worker panicked");
    }

    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if freq.get(i, j) >= cfg.threshold {
                edges.push((i, j));
            }
        }
    }
    StabilityOutcome { frequency: freq, edges, subsamples: cfg.subsamples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::metrics;
    use crate::rng::Rng;

    fn base_cfg() -> ConcordConfig {
        ConcordConfig {
            lambda1: 0.3,
            lambda2: 0.05,
            tol: 1e-4,
            max_iter: 120,
            variant: Variant::Cov,
            ..Default::default()
        }
    }

    #[test]
    fn frequencies_are_probabilities_and_symmetricish() {
        let mut rng = Rng::new(1);
        let prob = gen::chain_problem(12, 200, &mut rng);
        let out = stability_selection(
            &prob.x,
            &base_cfg(),
            &StabilityConfig { subsamples: 8, workers: 3, ..Default::default() },
        );
        for i in 0..12 {
            for j in 0..12 {
                let f = out.frequency.get(i, j);
                assert!((0.0..=1.0 + 1e-12).contains(&f));
            }
        }
        // Estimates are symmetric, so frequencies are too.
        assert!(out.frequency.max_abs_diff(&out.frequency.transpose()) < 1e-12);
    }

    #[test]
    fn stable_edges_favor_true_support() {
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(14, 600, &mut rng);
        let out = stability_selection(
            &prob.x,
            &base_cfg(),
            &StabilityConfig { subsamples: 12, threshold: 0.8, workers: 2, ..Default::default() },
        );
        assert!(!out.edges.is_empty(), "no stable edges found");
        // Build the stable-support estimate and score it.
        let mut est = Mat::eye(14);
        for &(i, j) in &out.edges {
            est.set(i, j, 1.0);
            est.set(j, i, 1.0);
        }
        let m = metrics::support_metrics(&est, &prob.omega0, 0.5);
        assert!(m.ppv > 0.9, "stability PPV {}", m.ppv);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(3);
        let prob = gen::chain_problem(10, 120, &mut rng);
        let cfg = StabilityConfig { subsamples: 6, workers: 3, seed: 9, ..Default::default() };
        let a = stability_selection(&prob.x, &base_cfg(), &cfg);
        let b = stability_selection(&prob.x, &base_cfg(), &cfg);
        assert!(a.frequency.max_abs_diff(&b.frequency) == 0.0);
        assert_eq!(a.edges, b.edges);
    }
}
