//! Stability selection (Meinshausen & Bühlmann [37], cited by the paper
//! as the resampling workload that makes scalability "prohibitive"
//! without HP-CONCORD): fit the estimator on many row subsamples and
//! keep the edges selected in at least a `threshold` fraction of them.
//!
//! This is the second first-class coordinator workload (after the λ
//! grid): B independent fits batched over the worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::Result;

use crate::concord::{fit_screened_distributed, fit_single_node, ConcordConfig, ScreenedDistOptions};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::simnet::cost::CostSummary;

/// Stability-selection configuration.
#[derive(Debug, Clone, Copy)]
pub struct StabilityConfig {
    /// Number of subsample fits B.
    pub subsamples: usize,
    /// Fraction of rows per subsample (M&B use 0.5).
    pub fraction: f64,
    /// Selection frequency threshold π (M&B recommend 0.6–0.9).
    pub threshold: f64,
    pub seed: u64,
    pub workers: usize,
}

impl Default for StabilityConfig {
    fn default() -> Self {
        StabilityConfig { subsamples: 20, fraction: 0.5, threshold: 0.7, seed: 0, workers: 2 }
    }
}

/// Result: per-edge selection frequencies and the stable edge set.
#[derive(Debug)]
pub struct StabilityOutcome {
    /// Selection frequency of each (i, j) pair, i < j, in [0, 1];
    /// row-major upper triangle.
    pub frequency: Mat,
    /// Stable edges (frequency ≥ threshold).
    pub edges: Vec<(usize, usize)>,
    pub subsamples: usize,
}

/// Row indices of subsample `b`: one reproducible stream per index,
/// shared by the single-node and distributed paths (so both draw the
/// *same* subsamples for a given seed).
fn subsample_rows(n: usize, m: usize, seed: u64, b: usize) -> Vec<usize> {
    let mut rng = Rng::new(seed ^ (0x5AB1E ^ (b as u64) << 20));
    rng.sample_indices(n, m)
}

/// The stable edge set: upper-triangle pairs selected in at least a
/// `threshold` fraction of subsamples.
fn stable_edges(freq: &Mat, threshold: f64) -> Vec<(usize, usize)> {
    let p = freq.rows();
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if freq.get(i, j) >= threshold {
                edges.push((i, j));
            }
        }
    }
    edges
}

/// Run stability selection with the worker pool.
pub fn stability_selection(
    x: &Mat,
    base: &ConcordConfig,
    cfg: &StabilityConfig,
) -> StabilityOutcome {
    let (n, p) = x.shape();
    let m = ((n as f64) * cfg.fraction).round().max(2.0) as usize;
    let x = Arc::new(x.clone());
    let base = *base;
    let scfg = *cfg;
    let next = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<Mat>();

    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let x = Arc::clone(&x);
        let next = Arc::clone(&next);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let b = next.fetch_add(1, Ordering::SeqCst);
            if b >= scfg.subsamples {
                break;
            }
            let rows = subsample_rows(n, m, scfg.seed, b);
            let sub = Mat::from_fn(m, p, |i, j| x.get(rows[i], j));
            let fit = fit_single_node(&sub, &base).expect("stability fit");
            // Indicator of selected off-diagonal support.
            let mut ind = Mat::zeros(p, p);
            for i in 0..p {
                for j in 0..p {
                    if i != j && fit.omega.get(i, j) != 0.0 {
                        ind.set(i, j, 1.0);
                    }
                }
            }
            tx.send(ind).expect("leader gone");
        }));
    }
    drop(tx);

    let mut freq = Mat::zeros(p, p);
    for ind in rx {
        freq.add_scaled(1.0 / cfg.subsamples as f64, &ind);
    }
    for h in handles {
        h.join().expect("stability worker panicked");
    }

    let edges = stable_edges(&freq, cfg.threshold);
    StabilityOutcome { frequency: freq, edges, subsamples: cfg.subsamples }
}

/// Result of distributed screened stability selection: frequencies and
/// stable edges as in [`StabilityOutcome`], plus the metered bill.
#[derive(Debug)]
pub struct StabilityDistOutcome {
    /// Selection frequency of each (i, j) pair in [0, 1].
    pub frequency: Mat,
    /// Stable edges (frequency ≥ threshold).
    pub edges: Vec<(usize, usize)>,
    pub subsamples: usize,
    /// Aggregate bill: subsample fits run one after another (each fit's
    /// own bill is already its concurrent-schedule critical path), so
    /// the per-fit summaries fold with `merge_sequential`.
    pub cost: CostSummary,
}

/// Stability selection over the screened **distributed** solver: every
/// subsample fit runs [`fit_screened_distributed`] — screening fabric,
/// per-component plans, and the same concurrent wave packer
/// ([`crate::cost::schedule::plan_concurrent`]) under the rank budget in
/// `base.ranks_budget`. Subsamples execute in index order (parallelism
/// comes from each fit's waves, which own the machine-wide rank budget
/// one fit at a time; `cfg.workers` is ignored here), drawing the same
/// reproducible row subsamples as [`stability_selection`], so the
/// outcome is deterministic given the seed.
pub fn stability_selection_dist(
    x: &Mat,
    base: &ConcordConfig,
    cfg: &StabilityConfig,
    opts: &ScreenedDistOptions,
) -> Result<StabilityDistOutcome> {
    let (n, p) = x.shape();
    let m = ((n as f64) * cfg.fraction).round().max(2.0) as usize;
    let mut freq = Mat::zeros(p, p);
    let mut cost = CostSummary::default();
    for b in 0..cfg.subsamples {
        let rows = subsample_rows(n, m, cfg.seed, b);
        let sub = Mat::from_fn(m, p, |i, j| x.get(rows[i], j));
        let fit = fit_screened_distributed(&sub, base, opts)?;
        cost.merge_sequential(&fit.cost);
        for i in 0..p {
            for j in 0..p {
                if i != j && fit.fit.omega.get(i, j) != 0.0 {
                    freq.set(i, j, freq.get(i, j) + 1.0 / cfg.subsamples as f64);
                }
            }
        }
    }
    let edges = stable_edges(&freq, cfg.threshold);
    Ok(StabilityDistOutcome { frequency: freq, edges, subsamples: cfg.subsamples, cost })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concord::Variant;
    use crate::gen;
    use crate::metrics;
    use crate::rng::Rng;

    fn base_cfg() -> ConcordConfig {
        ConcordConfig {
            lambda1: 0.3,
            lambda2: 0.05,
            tol: 1e-4,
            max_iter: 120,
            variant: Variant::Cov,
            ..Default::default()
        }
    }

    #[test]
    fn frequencies_are_probabilities_and_symmetricish() {
        let mut rng = Rng::new(1);
        let prob = gen::chain_problem(12, 200, &mut rng);
        let out = stability_selection(
            &prob.x,
            &base_cfg(),
            &StabilityConfig { subsamples: 8, workers: 3, ..Default::default() },
        );
        for i in 0..12 {
            for j in 0..12 {
                let f = out.frequency.get(i, j);
                assert!((0.0..=1.0 + 1e-12).contains(&f));
            }
        }
        // Estimates are symmetric, so frequencies are too.
        assert!(out.frequency.max_abs_diff(&out.frequency.transpose()) < 1e-12);
    }

    #[test]
    fn stable_edges_favor_true_support() {
        let mut rng = Rng::new(2);
        let prob = gen::chain_problem(14, 600, &mut rng);
        let out = stability_selection(
            &prob.x,
            &base_cfg(),
            &StabilityConfig { subsamples: 12, threshold: 0.8, workers: 2, ..Default::default() },
        );
        assert!(!out.edges.is_empty(), "no stable edges found");
        // Build the stable-support estimate and score it.
        let mut est = Mat::eye(14);
        for &(i, j) in &out.edges {
            est.set(i, j, 1.0);
            est.set(j, i, 1.0);
        }
        let m = metrics::support_metrics(&est, &prob.omega0, 0.5);
        assert!(m.ppv > 0.9, "stability PPV {}", m.ppv);
    }

    /// The distributed screened variant is deterministic given the
    /// seed, returns probabilities, and meters the screening fabrics it
    /// ran (the screening pass alone guarantees a nonzero bill).
    #[test]
    fn dist_variant_is_deterministic_and_metered() {
        use crate::simnet::MachineParams;
        let mut rng = Rng::new(4);
        let prob = gen::chain_problem(10, 120, &mut rng);
        let cfg = StabilityConfig { subsamples: 4, workers: 1, seed: 11, ..Default::default() };
        // β_mem = 0: planning must not race other tests' tile installs.
        let machine = MachineParams { beta_mem: 0.0, ..MachineParams::edison_like() };
        let opts = ScreenedDistOptions { total_ranks: 4, machine, ..Default::default() };
        let a = stability_selection_dist(&prob.x, &base_cfg(), &cfg, &opts).unwrap();
        let b = stability_selection_dist(&prob.x, &base_cfg(), &cfg, &opts).unwrap();
        assert!(a.frequency.max_abs_diff(&b.frequency) == 0.0);
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.cost.total, b.cost.total);
        assert!(a.cost.total.messages > 0, "screening passes must be metered");
        for i in 0..10 {
            for j in 0..10 {
                let f = a.frequency.get(i, j);
                assert!((0.0..=1.0 + 1e-12).contains(&f));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(3);
        let prob = gen::chain_problem(10, 120, &mut rng);
        let cfg = StabilityConfig { subsamples: 6, workers: 3, seed: 9, ..Default::default() };
        let a = stability_selection(&prob.x, &base_cfg(), &cfg);
        let b = stability_selection(&prob.x, &base_cfg(), &cfg);
        assert!(a.frequency.max_abs_diff(&b.frequency) == 0.0);
        assert_eq!(a.edges, b.edges);
    }
}
